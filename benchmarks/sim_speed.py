"""Paper Table II + Fig. 6: simulation accuracy for fixed-length
workloads at growing request counts, and simulator runtime efficiency.

Vidur / LLMServingSim are not available offline; the comparison here is
TokenSim vs the real engine ("Local" in Table II) plus TokenSim's own
wall-clock scaling (the Fig. 6 claim is that TokenSim needs no
pre-training pass and stays lightweight)."""
from __future__ import annotations

import time

import jax

from repro.configs import get_smoke_config
from repro.core.metrics import Results
from repro.core.simulator import SimSpec, Simulation, WorkerSpec
from repro.core.mem.block_manager import BlockManager, MemoryConfig
from repro.core.workload import WorkloadSpec
from repro.models import model_zoo as zoo
from repro.serving.engine import EngineConfig, ServingEngine

from benchmarks.common import Bench, fmt

NUM_BLOCKS, BLOCK_SIZE, MAX_BATCH = 160, 8, 8


def run(request_counts=(20, 40, 60, 80, 100)):
    b = Bench("sim_speed_tab2_fig6")
    cfg = get_smoke_config("llama2-7b")
    model = zoo.build(cfg)
    params = zoo.init_params(model, jax.random.key(0))

    # calibrate once on the smallest count; first pass warms the jit
    # cache so measured walls are compute, not compilation
    from repro.core.workload import generate
    wl0 = WorkloadSpec(num_requests=request_counts[0], qps=0.0, seed=99,
                       lengths="fixed", prompt_len=32, output_len=10)
    samples = None
    for _ in range(2):
        eng0 = ServingEngine(model, params, EngineConfig(
            num_blocks=NUM_BLOCKS, block_size=BLOCK_SIZE,
            max_batch=MAX_BATCH, max_pages_per_seq=16))
        for r in generate(wl0):
            eng0.add_request(r)
        eng0.run()
        samples = [(r.mix, r.wall) for r in eng0.records]

    max_err = 0.0
    for n in request_counts:
        wl = WorkloadSpec(num_requests=n, qps=0.0, seed=1,
                          lengths="fixed", prompt_len=32, output_len=10)
        # real engine total time
        eng = ServingEngine(model, params, EngineConfig(
            num_blocks=NUM_BLOCKS, block_size=BLOCK_SIZE,
            max_batch=MAX_BATCH, max_pages_per_seq=16))
        t0 = time.perf_counter()
        for r in generate(wl):
            eng.add_request(r)
        eng.run()
        real_total = eng.clock
        real_wall = time.perf_counter() - t0

        spec = SimSpec(arch=cfg, workers=[WorkerSpec(hw="CPU")],
                       workload=wl, local_policy="continuous",
                       max_batch=MAX_BATCH, backend="tabular",
                       backend_samples=samples, block_size=BLOCK_SIZE)
        sim = Simulation(spec)
        sim.workers[0].mem = BlockManager(MemoryConfig(
            num_blocks=NUM_BLOCKS, block_size=BLOCK_SIZE,
            kv_bytes_per_token=1.0))
        res = sim.run()
        sim_total = max(r.t_finish for r in res.finished)
        err = abs(sim_total - real_total) / real_total * 100
        max_err = max(max_err, err)
        b.add(requests=n, real_total_s=fmt(real_total),
              sim_total_s=fmt(sim_total), pct_err=fmt(err, 2),
              sim_wall_s=fmt(res.wall_time),
              real_wall_s=fmt(real_wall),
              speedup=fmt(real_wall / max(res.wall_time, 1e-9), 1))
    b.finish(derived=f"max_total_time_err={max_err:.2f}%_no_pretraining")
    return max_err


if __name__ == "__main__":
    run()
