"""Paper Table II + Fig. 6: simulation accuracy for fixed-length
workloads at growing request counts, simulator runtime efficiency, and
the million-request streaming scaling curve (docs/PERFORMANCE.md).

Three entry points:

* ``run()`` (default): TokenSim vs the real JAX engine ("Local" in
  Table II) on 20-100 requests — accuracy plus wall-clock speedup.
  Vidur / LLMServingSim are not available offline; the Fig. 6 claim is
  that TokenSim needs no pre-training pass and stays lightweight.
* ``run_scaling()`` (``--scale``): 10^4 → 10^6 requests across >= 8
  workers in streaming mode (``SimSpec(streaming=True,
  retain_requests=False)``), asserting live ``Request`` objects stay
  bounded (no O(num_requests) residency) and reporting the wall-clock /
  RSS scaling curve pasted into docs/PERFORMANCE.md.
* ``run_smoke()`` (``--smoke``, wired into scripts/ci.sh): a 10k-request
  streaming run under a time/RSS budget whose sketch P50/P99 must land
  within 1% of the exact-mode percentiles — the scale-regression gate.
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.core.simulator import SimSpec, Simulation, WorkerSpec
from repro.core.workload import WorkloadSpec

from benchmarks.common import Bench, fmt

NUM_BLOCKS, BLOCK_SIZE, MAX_BATCH = 160, 8, 8


def _current_rss_mb() -> float:
    """Resident set size right now (not the process-lifetime peak, which
    would attribute earlier runs' memory to the run being measured)."""
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmRSS:"):
                    return int(line.split()[1]) / 1024.0
    except OSError:
        pass
    # no /proc: fall back to the lifetime peak (the best getrusage
    # offers); ru_maxrss is KB on Linux but bytes on macOS
    import resource
    import sys
    peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    return peak / (1024.0 * 1024.0) if sys.platform == "darwin" \
        else peak / 1024.0


def run(request_counts=(20, 40, 60, 80, 100)):
    # jax + model building only needed for the Table II comparison, so
    # the streaming scaling/smoke paths stay import-light
    import jax

    from repro.configs import get_smoke_config
    from repro.core.mem.block_manager import BlockManager, MemoryConfig
    from repro.core.workload import generate
    from repro.models import model_zoo as zoo
    from repro.serving.engine import EngineConfig, ServingEngine

    b = Bench("sim_speed_tab2_fig6")
    cfg = get_smoke_config("llama2-7b")
    model = zoo.build(cfg)
    params = zoo.init_params(model, jax.random.key(0))

    # calibrate once on the smallest count; first pass warms the jit
    # cache so measured walls are compute, not compilation
    wl0 = WorkloadSpec(num_requests=request_counts[0], qps=0.0, seed=99,
                       lengths="fixed", prompt_len=32, output_len=10)
    samples = None
    for _ in range(2):
        eng0 = ServingEngine(model, params, EngineConfig(
            num_blocks=NUM_BLOCKS, block_size=BLOCK_SIZE,
            max_batch=MAX_BATCH, max_pages_per_seq=16))
        for r in generate(wl0):
            eng0.add_request(r)
        eng0.run()
        samples = [(r.mix, r.wall) for r in eng0.records]

    max_err = 0.0
    for n in request_counts:
        wl = WorkloadSpec(num_requests=n, qps=0.0, seed=1,
                          lengths="fixed", prompt_len=32, output_len=10)
        # real engine total time
        eng = ServingEngine(model, params, EngineConfig(
            num_blocks=NUM_BLOCKS, block_size=BLOCK_SIZE,
            max_batch=MAX_BATCH, max_pages_per_seq=16))
        t0 = time.perf_counter()
        for r in generate(wl):
            eng.add_request(r)
        eng.run()
        real_total = eng.clock
        real_wall = time.perf_counter() - t0

        spec = SimSpec(arch=cfg, workers=[WorkerSpec(hw="CPU")],
                       workload=wl, local_policy="continuous",
                       max_batch=MAX_BATCH, backend="tabular",
                       backend_samples=samples, block_size=BLOCK_SIZE)
        sim = Simulation(spec)
        sim.workers[0].mem = BlockManager(MemoryConfig(
            num_blocks=NUM_BLOCKS, block_size=BLOCK_SIZE,
            kv_bytes_per_token=1.0))
        res = sim.run()
        sim_total = max(r.t_finish for r in res.finished)
        err = abs(sim_total - real_total) / real_total * 100
        max_err = max(max_err, err)
        b.add(requests=n, real_total_s=fmt(real_total),
              sim_total_s=fmt(sim_total), pct_err=fmt(err, 2),
              sim_wall_s=fmt(res.wall_time),
              real_wall_s=fmt(real_wall),
              speedup=fmt(real_wall / max(res.wall_time, 1e-9), 1))
    b.finish(derived=f"max_total_time_err={max_err:.2f}%_no_pretraining")
    return max_err


def _scale_spec(n: int, n_workers: int, qps: float) -> SimSpec:
    """Streaming drop-mode spec for the scaling curve: short fixed
    outputs keep total token volume (the real cost driver) tractable
    while the request count sweeps three orders of magnitude."""
    return SimSpec(
        arch="llama2-7b",
        workers=[WorkerSpec() for _ in range(n_workers)],
        workload=WorkloadSpec(num_requests=n, qps=qps, seed=7,
                              lengths="fixed", prompt_len=64, output_len=8),
        max_batch=128, streaming=True, retain_requests=False)


def run_scaling(request_counts=(10_000, 100_000, 1_000_000),
                n_workers: int = 8, qps: float = 1000.0,
                live_cap: int = 100_000):
    """Streaming-mode scaling curve: wall time, events, peak live
    requests and peak RSS vs request count.  Fails if live requests
    ever approach O(num_requests) — the bounded-memory contract."""
    b = Bench("sim_speed_scaling")
    for n in request_counts:
        sim = Simulation(_scale_spec(n, n_workers, qps))
        res = sim.run()
        assert res.stats is not None and res.stats.n_finished == n, \
            (n, res.stats and res.stats.n_finished)
        assert res.max_live < min(live_cap, max(1000, n // 2)), \
            f"live requests not bounded: {res.max_live} of {n}"
        rss = _current_rss_mb()
        b.add(requests=n, workers=n_workers, qps=qps,
              wall_s=fmt(res.wall_time, 2), sim_time_s=fmt(res.sim_time, 1),
              iterations=res.events, max_live=res.max_live,
              kreq_per_s=fmt(n / max(res.wall_time, 1e-9) / 1e3, 1),
              rss_mb=fmt(rss, 1))
        print(f"  scaling n={n}: wall={res.wall_time:.2f}s "
              f"max_live={res.max_live} rss={rss:.0f}MB")
    b.finish(derived=f"streaming_{max(request_counts)}req_"
                     f"{n_workers}workers_bounded_live")


def run_obs_overhead(n: int = 4000, n_workers: int = 4, reps: int = 5,
                     disabled_budget: float = 1.02,
                     full_budget: float = 1.10, retries: int = 1):
    """Observability overhead gate (docs/OBSERVABILITY.md): the same
    sim with obs absent, obs constructed-but-disabled, and full
    tracing+timeseries+attribution.  Disabled must cost <2% and full
    <10% over the baseline CPU time.

    Methodology: a saturated batch (all arrivals at t=0, full
    ``max_batch`` occupancy) is the steady-state-serving shape the
    overhead contract is stated for — per-iteration recording
    amortizes over the whole batch there.  Degenerate workloads with
    single-digit batches pay proportionally more (recording cost is
    per event, the sim's cost per event is tiny).  Configs are timed
    in interleaved rounds, comparing per-config *medians* of CPU time
    (``process_time``, immune to scheduler preemption) with the GC
    parked during each run — allocation-triggered gen-2 collections
    scan the whole heap and land on whichever config happens to trip
    the threshold, which is variance, not overhead.  Identical
    configs land within ~1.5% under this protocol (single runs swing
    +/-20% on shared CI hosts); a failing comparison re-measures once
    before failing the gate."""
    import gc
    import statistics
    from dataclasses import replace

    from repro.obs import ObsSpec

    base_spec = SimSpec(
        arch="llama2-7b",
        workers=[WorkerSpec() for _ in range(n_workers)],
        workload=WorkloadSpec(num_requests=n, qps=0.0, seed=7,
                              lengths="fixed", prompt_len=64,
                              output_len=64),
        max_batch=128, streaming=True, retain_requests=False)
    cfgs = [("base", base_spec),
            ("disabled", replace(base_spec, obs=ObsSpec())),
            ("full", replace(base_spec, obs=ObsSpec.full()))]

    for attempt in range(retries + 1):
        walls = {name: [] for name, _ in cfgs}
        for _ in range(reps):
            for name, spec in cfgs:
                gc.collect()
                gc.disable()
                try:
                    t0 = time.process_time()
                    Simulation(spec).run()
                    walls[name].append(time.process_time() - t0)
                finally:
                    gc.enable()
        base = statistics.median(walls["base"])
        r_off = statistics.median(walls["disabled"]) / base
        r_full = statistics.median(walls["full"]) / base
        if r_off < disabled_budget and r_full < full_budget:
            break
    assert r_off < disabled_budget, \
        f"disabled-obs overhead {r_off:.3f}x >= {disabled_budget}x"
    assert r_full < full_budget, \
        f"full-obs overhead {r_full:.3f}x >= {full_budget}x"
    print(f"obs_overhead,OK,n={n},base={base:.2f}s,"
          f"disabled={r_off:.3f}x,full={r_full:.3f}x")
    b = Bench("sim_speed_obs_overhead")
    b.add(n=n, base_cpu_s=fmt(base, 3), disabled_x=fmt(r_off, 3),
          full_x=fmt(r_full, 3))
    b.finish(derived=f"disabled={r_off:.3f}x_full={r_full:.3f}x")


def run_smoke(n: int = 10_000, n_workers: int = 8, qps: float = 1000.0,
              wall_budget_s: float = 60.0, rss_budget_mb: float = 1024.0):
    """CI gate (scripts/ci.sh): streaming 10k run within a time/RSS
    budget, sketch P50/P99 within 1% of exact mode on the same sim.
    The exact-mode baseline runs first and is excluded from the
    budgets: the wall clock covers only the streaming run and the RSS
    gate samples current (not lifetime-peak) residency after it, so
    the gate measures streaming mode, not the baseline."""
    from dataclasses import replace
    exact = Simulation(replace(_scale_spec(n, n_workers, qps),
                               streaming=False,
                               retain_requests=True)).run()
    t0 = time.perf_counter()
    stream = Simulation(_scale_spec(n, n_workers, qps)).run()
    wall = time.perf_counter() - t0
    es, ss = exact.summary(), stream.summary()
    assert ss["n_finished"] == es["n_finished"] == n
    for k in ("latency_p50", "latency_p99", "ttft_p50", "ttft_p99",
              "latency_mean", "latency_max", "throughput_rps"):
        rel = abs(ss[k] - es[k]) / max(abs(es[k]), 1e-12)
        assert rel < 0.01, f"{k}: streaming {ss[k]} vs exact {es[k]} " \
                           f"({rel:.2%} > 1%)"
    assert stream.max_live < n // 2, \
        f"live requests not bounded: {stream.max_live} of {n}"
    assert wall < wall_budget_s, f"streaming smoke too slow: {wall:.1f}s"
    rss = _current_rss_mb()
    assert rss < rss_budget_mb, f"RSS {rss:.0f}MB over budget"
    p99_err = abs(ss["latency_p99"] - es["latency_p99"]) \
        / es["latency_p99"]
    print(f"sim_speed_smoke,OK,n={n},wall={wall:.1f}s,rss={rss:.0f}MB,"
          f"max_live={stream.max_live},p99_rel_err={p99_err:.4%}")
    # persist the gate numbers so CI can upload them as an artifact
    b = Bench("sim_speed_smoke")
    b.add(n=n, wall_s=fmt(wall, 2), rss_mb=fmt(rss, 1),
          max_live=stream.max_live, p99_rel_err=fmt(p99_err, 6))
    b.finish(derived=f"wall={wall:.1f}s_rss={rss:.0f}MB")
    run_obs_overhead()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="10k streaming CI smoke (time/RSS/accuracy gate)")
    ap.add_argument("--scale", action="store_true",
                    help="10^4-10^6 request streaming scaling curve")
    ap.add_argument("--counts", type=int, nargs="+",
                    help="override request counts for --scale")
    args = ap.parse_args(argv)
    if args.smoke:
        run_smoke()
    elif args.scale:
        run_scaling(tuple(args.counts) if args.counts
                    else (10_000, 100_000, 1_000_000))
    else:
        run()
    return 0


if __name__ == "__main__":
    sys.exit(main())
