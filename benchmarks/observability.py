"""Observability pipeline gate (docs/OBSERVABILITY.md).

Exercises the full ``repro.obs`` stack on a memory-pressured TP=2
cluster and on a pipeline-parallel (pp=2) worker, then checks the
exported artifacts against their contracts:

* the Chrome trace-event JSON is well-formed, request spans nest and
  are contiguous, and per-request span durations sum to the measured
  latency (``validate_chrome_trace`` returns no errors);
* latency attribution conserves: per request, the TTFT components sum
  to the measured TTFT and the decode components to the measured
  decode span within 1e-6 s — in exact mode and in streaming
  drop-mode (``retain_requests=False``);
* the time-series recorder stays within its row cap (stride-doubling
  decimation).

``run_smoke()`` (``--smoke``, wired into scripts/ci.sh) runs the same
checks on a smaller sim and leaves ``results/obs/trace.json`` for CI
to upload as an artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.configs import get_config
from repro.core.costmodel.operators import kv_bytes_per_token, param_bytes
from repro.core.simulator import (ParallelSpec, SimSpec, Simulation,
                                  WorkerSpec)
from repro.core.workload import WorkloadSpec
from repro.obs import ObsSpec, validate_chrome_trace

from benchmarks.common import Bench, fmt

OUT_DIR = os.path.join("results", "obs")
#: per-request conservation tolerance (seconds) — the acceptance bar
EPS = 1e-6


def _pressure_spec(n: int = 64, *, tp: int = 2, cap_interval: float = 0.5,
                   ts_cap: int = 4096) -> SimSpec:
    """TP-sharded variant of the benchmarks/kv_hierarchy.py pressure
    recipe: a KV pool holding ~10 prompts, so decode growth swaps."""
    cfg = get_config("llama2-7b")
    kvt = kv_bytes_per_token(cfg, 2, tp)
    ctx, out = 1024, 192
    cap = (param_bytes(cfg, 2, tp) + (10 * ctx + 4 * out) * kvt) / 0.9
    return SimSpec(
        arch="llama2-7b",
        workers=[WorkerSpec(hw="A100", tp=tp, mem_cap_override=cap)
                 for _ in range(2)],
        workload=WorkloadSpec(num_requests=n, qps=0.0, seed=0,
                              lengths="fixed", prompt_len=ctx,
                              output_len=out),
        local_policy="continuous", preemption_mode="swap",
        obs=ObsSpec.full(sample_interval=cap_interval,
                         timeseries_cap=ts_cap))


def _pp_spec(n: int = 32) -> SimSpec:
    """pp=2 roofline worker: the only backend that reports comm/bubble
    in ``IterationPlan``, so attribution shows those components."""
    return SimSpec(
        arch="llama2-7b", backend="roofline",
        workers=[WorkerSpec(hw="A100")],
        parallel=ParallelSpec(pp=2, microbatches=4),
        workload=WorkloadSpec(num_requests=n, qps=4.0, seed=1,
                              lengths="fixed", prompt_len=512,
                              output_len=64),
        obs=ObsSpec.full())


def _conservation_errors(res) -> float:
    """Worst per-request |sum(components) - measured span| in seconds."""
    worst = 0.0
    for r in res.finished:
        f = r.obs.final
        ttft = r.t_first_token - r.arrival_time
        worst = max(worst, abs(sum(f["ttft"].values()) - ttft))
        if r.t_finish is not None and r.t_first_token is not None:
            dec = r.t_finish - r.t_first_token
            worst = max(worst, abs(sum(f["decode"].values()) - dec))
    return worst


def _check(res, *, trace_path: str) -> dict:
    res.export_trace(trace_path)
    with open(trace_path) as f:
        data = json.load(f)
    errors = validate_chrome_trace(data)
    assert not errors, f"trace invalid: {errors[:5]}"
    worst = _conservation_errors(res)
    assert worst < EPS, f"attribution not conserved: {worst:.3e}s"
    n_rows = len(res.timeseries.rows())
    assert n_rows <= res.timeseries.cap, \
        f"timeseries unbounded: {n_rows} > cap {res.timeseries.cap}"
    return {"events": len(data["traceEvents"]), "ts_rows": n_rows,
            "conservation_err": worst}


def run(quick: bool = False) -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    b = Bench("observability")

    res = Simulation(_pressure_spec(32 if quick else 64)).run()
    info = _check(res, trace_path=os.path.join(OUT_DIR, "trace.json"))
    mem = res.memory_summary()
    assert mem["swap_preempts"] > 0, "pressure sim produced no swaps"
    bd = res.time_breakdown()
    assert bd["mode"] == "exact" and "swap" in bd["ttft_mean"] | \
        bd["decode_mean"], bd
    b.add(case="pressure_tp2", requests=len(res.finished),
          swap_preempts=mem["swap_preempts"],
          trace_events=info["events"], ts_rows=info["ts_rows"],
          conservation_err=fmt(info["conservation_err"], 9))

    pp = Simulation(_pp_spec(16 if quick else 32)).run()
    info = _check(pp, trace_path=os.path.join(OUT_DIR, "trace_pp2.json"))
    bd = pp.time_breakdown()
    assert "comm" in bd["decode_mean"] and "bubble" in bd["decode_mean"], \
        f"pp=2 attribution missing comm/bubble: {sorted(bd['decode_mean'])}"
    b.add(case="pipeline_pp2", requests=len(pp.finished),
          trace_events=info["events"], ts_rows=info["ts_rows"],
          conservation_err=fmt(info["conservation_err"], 9))
    b.finish(derived="trace_valid_attribution_conserved_1e-6")


def run_smoke(n: int = 48) -> None:
    """CI gate: trace schema + span nesting + attribution conservation
    + bounded time series, artifact at results/obs/trace.json."""
    os.makedirs(OUT_DIR, exist_ok=True)
    res = Simulation(_pressure_spec(n, ts_cap=256)).run()
    info = _check(res, trace_path=os.path.join(OUT_DIR, "trace.json"))

    # streaming drop-mode attribution still folds and conserves in the
    # aggregate (per-request objects are gone by design)
    from dataclasses import replace
    spec = replace(_pressure_spec(n, ts_cap=256),
                   streaming=True, retain_requests=False)
    stream = Simulation(spec).run()
    sb, eb = stream.time_breakdown(), res.time_breakdown()
    assert sb["mode"] == "streaming" and sb["n"] == eb["n"], (sb, eb)
    for comp, v in eb["ttft_mean"].items():
        assert abs(sb["ttft_mean"][comp] - v) < 1e-9, (comp, sb, eb)
    print(f"observability_smoke,OK,n={n},trace_events={info['events']},"
          f"ts_rows={info['ts_rows']},"
          f"conservation_err={info['conservation_err']:.3e}")
    b = Bench("observability_smoke")
    b.add(n=n, trace_events=info["events"], ts_rows=info["ts_rows"],
          conservation_err=fmt(info["conservation_err"], 9))
    b.finish(derived=f"trace_valid_err<{EPS}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: schema + conservation + bounded rows")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    if args.smoke:
        run_smoke()
    else:
        run(quick=args.quick)
    return 0


if __name__ == "__main__":
    sys.exit(main())
