"""§Roofline: three-term roofline per (arch × shape × mesh) from the
compiled dry-run artifacts.

Terms (seconds, per the assignment):
  compute    = HLO_FLOPs / (chips × 197 TF bf16)
  memory     = HLO_bytes / (chips × 819 GB/s)
  collective = collective_bytes / (chips × 50 GB/s ICI)

HLO accounting note (documented in EXPERIMENTS.md §Roofline): XLA's
``cost_analysis`` counts a while-loop body ONCE, so the dry-run used for
this table is lowered in **counting mode** (``scan_layers=False`` —
layer loops unrolled).  The one loop that remains is flash attention's
internal q/kv block sweep; its trip count is known statically, so its
FLOPs/bytes are added analytically (``attn_correction``), and the method
is validated against fully-unrolled compiles at small scale in
tests/test_roofline_accounting.py.
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, Optional

from repro.configs import get_config, get_shape
from repro.configs.base import DECODE, TRAIN
from repro.core.costmodel.backends import cost_analysis_dict  # noqa: F401
#    (re-exported: the calibration tests read it from this module)

PEAK_FLOPS = 197e12          # bf16 per chip (TPU v5e)
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")


# ---------------------------------------------------------------------------
# Analytic attention-loop correction (the only loop left in counting mode)
# ---------------------------------------------------------------------------
def _tri_pairs(nq: int, nk: int, bq: int, bk: int) -> int:
    return sum(1 for qi in range(nq) for ki in range(nk)
               if ki * bk <= qi * bq + bq - 1)


def attn_correction(arch: str, shape_name: str, settings: Dict,
                    n_devices: int):
    """(extra_flops, extra_bytes) per device for the blocked-attention
    inner loop beyond the single counted block pair."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if cfg.family in ("ssm",):
        return 0.0, 0.0
    if shape.kind == DECODE:
        return 0.0, 0.0            # decode attention has no inner loop
    s = shape.seq_len
    bsz = shape.global_batch
    impl = settings.get("attn_impl", "blocked")
    bq = min(settings.get("attn_block_q", 1024), s)
    bk = min(settings.get("attn_block_kv", 1024), s)
    if s <= settings.get("naive_attn_max_seq", 2048):
        return 0.0, 0.0            # naive path: fully counted
    nq, nk = s // bq, s // bk
    if impl == "blocked_causal":
        pairs = _tri_pairs(nq, nk, bq, bk)
    else:
        pairs = nq * nk
    # per-pair global flops: QK^T + PV with all q heads
    hq, hd = cfg.n_heads, cfg.head_dim
    layers = {"dense": cfg.num_layers, "moe": cfg.num_layers,
              "vlm": cfg.num_layers,
              "hybrid": (cfg.num_layers // cfg.attn_period
                         if cfg.attn_period else 0),
              "audio": cfg.n_enc_layers + cfg.n_dec_layers,
              "encdec": cfg.n_enc_layers + cfg.n_dec_layers}[cfg.family]
    if cfg.family in ("audio", "encdec"):
        # decoder self-attn over s; encoder over enc_seq (usually naive)
        layers = cfg.n_dec_layers
    f_pair = 4.0 * bsz * bq * bk * hq * hd
    b_pair = bsz * (bq + 2 * bk) * hq * hd * 2.0     # q + kv tiles, bf16
    mult = 1.0
    if shape.kind == TRAIN:
        # fwd + (full remat ? recompute : 0) + bwd(2x)
        mult = 4.0 if settings.get("remat") == "full" else 3.0
    extra_pairs = max(0, pairs - 1) * layers
    return (extra_pairs * f_pair * mult / n_devices,
            extra_pairs * b_pair * mult / n_devices)


def model_flops(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N active."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    n = cfg.active_param_count()
    if shape.kind == TRAIN:
        return 6.0 * n * shape.tokens
    return 2.0 * n * shape.tokens


@dataclass
class Cell:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_dev: float
    bytes_dev: float
    coll_dev: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float
    note: str


NOTES = {
    "compute": ("compute-bound: raise arithmetic efficiency (causal-only "
                "attention, grouped MoE GEMM, remat policy)"),
    "memory": ("HBM-bound: shrink resident bytes/step (KV dtype, paging, "
               "fewer cache re-reads, better fusion)"),
    "collective": ("ICI-bound: cut or overlap collectives (reshard, 1D "
                   "weight layout, gradient compression, async)"),
}


def extrapolate(ra: Dict, rb: Dict) -> Dict:
    """Finite-difference depth extrapolation: every cost component is
    affine in depth (identical layers), so two reduced-depth unrolled
    compiles determine (per-layer, constant) exactly; totals are
    reconstructed at the full depth.  Cross-validated against full-depth
    unrolled compiles in tests/test_roofline_accounting.py and against
    the 5 full-depth artifacts kept in results/dryrun_count/."""
    a = ra["depth_override"]
    b = rb["depth_override"]
    cfg = get_config(ra["arch"])
    L = cfg.num_layers

    def lerp(fa, fb):
        unit = (fb - fa) / (b - a)
        const = fb - b * unit
        return max(0.0, const + L * unit)

    out = json.loads(json.dumps(rb))        # deep copy of the b-run
    out.pop("depth_override")
    for k in ("flops", "bytes accessed", "transcendentals"):
        if k in rb.get("cost", {}) and k in ra.get("cost", {}):
            out["cost"][k] = lerp(ra["cost"][k], rb["cost"][k])
    ca, cb = ra["collectives"], rb["collectives"]
    for kind in cb:
        if isinstance(cb[kind], dict):
            out["collectives"][kind]["bytes"] = lerp(
                ca[kind]["bytes"], cb[kind]["bytes"])
            out["collectives"][kind]["count"] = lerp(
                ca[kind]["count"], cb[kind]["count"])
    out["collectives"]["total_bytes"] = lerp(ca["total_bytes"],
                                             cb["total_bytes"])
    return out


def load_cell(path: str) -> Optional[Cell]:
    return cell_from_record(json.load(open(path)))


def _analytic_decode_cost(arch: str, shape_name: str, n_devices: int):
    """Decode-cell compute/bytes from the operator graph (per device).

    At decode sizes XLA's marginal per-layer flops / bytes-accessed are
    dominated by fusion bookkeeping and are NOT depth-affine (measured:
    2-4x spread between probe and full-depth compiles of the same cell,
    while prefill/train agree to <8% and collectives exactly).  The
    operator graph is the accounting the simulator itself is validated
    on, so decode cells use it; collectives still come from the HLO."""
    from repro.core.costmodel.operators import BatchMix, OperatorGraph
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    g = OperatorGraph.from_config(cfg, tp=16)     # per model-shard
    mix = BatchMix.from_batch(
        [], [shape.seq_len] * shape.global_batch,
        enc_tokens=0)
    f, b = g.totals(mix)
    dp = n_devices // 16                          # batch sharded over data
    return f / dp, b / dp


def cell_from_record(r: Dict) -> Optional[Cell]:
    if "skipped" in r or "error" in r:
        return None
    arch, shape = r["arch"], r["shape"]
    nd = r["n_devices"]
    settings = r.get("settings", {})
    f_corr, b_corr = attn_correction(arch, shape, settings, nd)
    flops = r["cost"].get("flops", 0.0) + f_corr
    bts = r["cost"].get("bytes accessed", 0.0) + b_corr
    if get_shape(shape).kind == DECODE:
        flops, bts = _analytic_decode_cost(arch, shape, nd)
    coll = r["collectives"]["total_bytes"]
    terms = {"compute": flops / PEAK_FLOPS,
             "memory": bts / HBM_BW,
             "collective": coll / ICI_BW}
    dom = max(terms, key=terms.get)
    mf = model_flops(arch, shape)
    ratio = mf / max(flops * nd, 1.0)
    return Cell(arch=arch, shape=shape, mesh=r["mesh"], n_devices=nd,
                flops_dev=flops, bytes_dev=bts, coll_dev=coll,
                compute_s=terms["compute"], memory_s=terms["memory"],
                collective_s=terms["collective"], dominant=dom,
                model_flops=mf, useful_ratio=ratio, note=NOTES[dom])


def build_table(dirname: str, pattern: str = "*_single_*.json"):
    """Builds cells from depth-probe pairs (``*_dA/_dB.json``) when
    present, else from single full-depth artifacts."""
    by_cell: Dict[str, Dict[int, Dict]] = {}
    singles = []
    for path in sorted(glob.glob(os.path.join(dirname, pattern))):
        r = json.load(open(path))
        if "skipped" in r or "error" in r:
            continue
        if "depth_override" in r:
            key = f'{r["arch"]}|{r["shape"]}|{r["mesh"]}'
            by_cell.setdefault(key, {})[r["depth_override"]] = r
        else:
            singles.append(r)
    cells = []
    for key, runs in sorted(by_cell.items()):
        if len(runs) < 2:
            continue
        ds = sorted(runs)
        c = cell_from_record(extrapolate(runs[ds[0]], runs[ds[-1]]))
        if c:
            cells.append(c)
    probed = {(c.arch, c.shape, c.mesh) for c in cells}
    for r in singles:
        if (r["arch"], r["shape"], r["mesh"]) not in probed:
            c = cell_from_record(r)
            if c:
                cells.append(c)
    cells.sort(key=lambda c: (c.arch, c.shape))
    return cells


def to_markdown(cells, title="Roofline (single-pod 16x16, per chip)"):
    lines = [f"### {title}", "",
             "| arch | shape | compute_s | memory_s | collective_s | "
             "dominant | MODEL_FLOPS | useful | bottleneck note |",
             "|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        lines.append(
            f"| {c.arch} | {c.shape} | {c.compute_s:.3e} | "
            f"{c.memory_s:.3e} | {c.collective_s:.3e} | {c.dominant} | "
            f"{c.model_flops:.3e} | {c.useful_ratio:.2f} | {c.note} |")
    return "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(RESULTS, "dryrun_probe"))
    ap.add_argument("--pattern", default="*_single_*.json")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    cells = build_table(args.dir, args.pattern)
    md = to_markdown(cells)
    print(md)
    if args.out:
        with open(args.out, "w") as f:
            f.write(md + "\n")
    print(f"\nroofline_report,{len(cells)},cells")


if __name__ == "__main__":
    main()
