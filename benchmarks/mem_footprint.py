"""Paper Fig. 13 / Finding 5: prefill vs decode worker memory timelines
in a disaggregated deployment; halving prefill memory is ~free."""
from __future__ import annotations

from repro.core.simulator import SimSpec, WorkerSpec, simulate
from repro.core.workload import WorkloadSpec

from benchmarks.common import Bench, fmt


def run(n_req: int = 1500):
    b = Bench("mem_footprint_fig13")
    out = {}
    for variant, prefill_mem in (("full", 80e9), ("half", 40e9)):
        spec = SimSpec(
            arch="llama2-7b",
            workers=[WorkerSpec(hw="A100", role="prefill",
                                mem_cap_override=prefill_mem),
                     WorkerSpec(hw="A100", role="decode")] +
                    [WorkerSpec(hw="A100", role="decode")],
            global_policy="disagg",
            workload=WorkloadSpec(num_requests=n_req, qps=12.0, seed=0,
                                  lengths="fixed", prompt_len=128,
                                  output_len=1024),
            local_policy="continuous", max_batch=256,
            max_batched_tokens=8192)
        res = simulate(spec)
        peaks = {}
        means = {}
        for wid, tl in res.worker_mem.items():
            if not tl:
                peaks[wid] = means[wid] = 0.0
                continue
            used = [s.used_bytes for s in tl]
            peaks[wid] = max(used)
            means[wid] = sum(used) / len(used)
        out[variant] = (res.throughput(), peaks, means)
        b.add(variant=variant,
              throughput=fmt(res.throughput()),
              prefill_peak_gb=fmt(peaks.get(0, 0) / 1e9, 2),
              decode_peak_gb=fmt(max(peaks.get(1, 0),
                                     peaks.get(2, 0)) / 1e9, 2),
              prefill_mean_gb=fmt(means.get(0, 0) / 1e9, 2),
              decode_mean_gb=fmt(max(means.get(1, 0),
                                     means.get(2, 0)) / 1e9, 2))
    thr_full, peaks_full, _ = out["full"]
    thr_half, _, _ = out["half"]
    # Finding 5: prefill uses far less memory than decode; halving it
    # barely moves throughput
    decode_peak = max(peaks_full.get(1, 0), peaks_full.get(2, 0))
    prefill_peak = peaks_full.get(0, 1)
    b.finish(derived=f"finding5_decode/prefill_peak="
                     f"{decode_peak / max(prefill_peak, 1):.1f}x"
                     f"_halfmem_thr={thr_half / thr_full:.3f}")
    return out


if __name__ == "__main__":
    run()
