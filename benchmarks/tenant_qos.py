"""§Tenant QoS: multi-tenant isolation and fairness studies.

Three studies on one A100 worker (all deterministic, <60 s total):

1. **Noisy neighbor** — a premium tenant's TTFT p99 alone, vs. sharing
   the cluster with an abusive free tenant, unlimited and rate-limited.
   The QoS claim: priority scheduling + a token-bucket rate limit keeps
   the premium degradation under 10%, where the unlimited neighbor
   degrades it by integer factors.
2. **WFQ shares** — backlogged tenants with weights 1:2:4 must receive
   token throughput in that ratio (within 10%), i.e. weighted Jain ≈ 1.
3. **Rate-limit frontier** — sweeping the free tier's rate limit traces
   the premium-latency vs. free-goodput/fairness frontier.

Usage:  PYTHONPATH=src python -m benchmarks.tenant_qos
"""
from __future__ import annotations

import sys

from benchmarks.common import Bench, fmt
from repro.core import SimSpec, TenantSpec, TenantTier, WorkerSpec, simulate
from repro.core.workload import WorkloadSpec

ARCH = "llama2-7b"
PROMPT, OUT = 256, 128
COST = PROMPT + OUT


def wl(n, qps, seed):
    return WorkloadSpec(num_requests=n, qps=qps, seed=seed,
                        lengths="fixed", prompt_len=PROMPT, output_len=OUT)


def premium(n=120, qps=6.0):
    return TenantSpec("premium",
                      TenantTier(name="premium", priority=10, weight=8.0,
                                 ttft_slo=2.0, tpot_slo=0.5),
                      wl(n, qps, seed=1))


def noisy(rate, inflight=0, n=400, qps=60.0):
    """The abuser: 10x the premium load.  ``rate``/``inflight`` are the
    QoS knobs (0 = unlimited); the full QoS tier uses both — the bucket
    bounds admitted token rate, the inflight cap bounds how much of the
    decode batch (and KV) the tenant can occupy at once."""
    return TenantSpec("noisy",
                      TenantTier(name="noisy", priority=0, weight=1.0,
                                 rate_tokens_per_s=rate,
                                 burst_tokens=2 * rate if rate else 0.0,
                                 admission_policy="shed" if rate else
                                 "queue",
                                 shed_timeout=5.0, max_inflight=inflight,
                                 ttft_slo=10.0, tpot_slo=2.0),
                      wl(n, qps, seed=2))


def _run(tenants, *, policy="priority", until=None, mem=0.5):
    return simulate(SimSpec(
        arch=ARCH, workers=[WorkerSpec(hw="A100", gpu_mem_util=mem)],
        global_policy=policy, local_policy="continuous",
        max_batch=48, max_batched_tokens=4096,
        tenants=tenants, until=until))


# ---------------------------------------------------------------------------
def noisy_neighbor(bench: Bench, scale: float = 1.0) -> bool:
    prem = lambda: premium(n=int(120 * scale))
    noi = lambda **kw: noisy(n=int(400 * scale), **kw)
    alone = _run([prem()])
    base = alone.tenant_summary()["premium"]

    rows = [("premium_alone", base, None)]
    unlimited = _run([prem(), noi(rate=0.0)])
    rows.append(("with_unlimited_noisy",
                 unlimited.tenant_summary()["premium"],
                 unlimited.tenant_summary()["noisy"]))
    limited = _run([prem(), noi(rate=3_000.0, inflight=4)])
    rows.append(("with_qos_limited_noisy",
                 limited.tenant_summary()["premium"],
                 limited.tenant_summary()["noisy"]))

    for name, prem, noi in rows:
        bench.add(study="noisy_neighbor", scenario=name,
                  premium_ttft_p99=fmt(prem["ttft_p99"]),
                  premium_lat_p99=fmt(prem["latency_p99"]),
                  premium_slo=fmt(prem["slo_attainment"]),
                  noisy_goodput=fmt(noi["goodput_rps"]) if noi else "",
                  noisy_rejected=noi["n_rejected"] if noi else "")
    degr = rows[2][1]["ttft_p99"] / base["ttft_p99"] - 1.0
    ok = degr <= 0.10
    # diagnostics go to stderr: run.py's stdout is a parseable CSV stream
    print(f"noisy-neighbor: premium ttft_p99 alone={base['ttft_p99']:.3f}s "
          f"unlimited={rows[1][1]['ttft_p99']:.3f}s "
          f"ratelimited={rows[2][1]['ttft_p99']:.3f}s "
          f"(degradation {degr * 100:+.1f}%, "
          f"{'OK' if ok else 'VIOLATION'})", file=sys.stderr)
    return ok


def wfq_shares(bench: Bench, scale: float = 1.0) -> bool:
    weights = {"bronze": 1.0, "silver": 2.0, "gold": 4.0}
    ts = [TenantSpec(t, TenantTier(name=t, weight=w),
                     wl(int(400 * scale), qps=0.0, seed=10 + i))
          for i, (t, w) in enumerate(sorted(weights.items()))]
    res = _run(ts, policy="wfq", until=25.0 * scale)
    tps = res.tenant_token_throughputs()
    total_w = sum(weights.values())
    total_tps = sum(tps.values())
    ok = True
    for t in sorted(weights):
        want = weights[t] / total_w
        got = tps[t] / max(total_tps, 1e-9)
        err = got / want - 1.0
        ok &= abs(err) <= 0.10
        bench.add(study="wfq_shares", scenario=t, weight=weights[t],
                  want_share=fmt(want), got_share=fmt(got),
                  err_pct=fmt(err * 100, 1))
    jw = res.fairness_index(weighted=True)
    print(f"wfq-shares: weighted Jain={jw:.4f} "
          f"({'OK' if ok and jw > 0.99 else 'VIOLATION'})", file=sys.stderr)
    return ok and jw > 0.99


def rate_frontier(bench: Bench, scale: float = 1.0) -> None:
    """Tightening the noisy tier's budget trades its goodput for the
    premium tier's latency: the isolation/utilization frontier."""
    points = ((1_000.0, 2), (2_000.0, 4), (4_000.0, 8),
              (8_000.0, 16), (0.0, 0))
    if scale < 1.0:
        points = points[1:2] + points[-1:]       # quick: one capped + unlimited
    for rate, inflight in points:
        res = _run([premium(n=int(120 * scale)),
                    noisy(rate=rate, inflight=inflight,
                          n=int(400 * scale))])
        s = res.tenant_summary()
        bench.add(study="rate_frontier",
                  scenario=f"rate={int(rate)},cap={inflight}" if rate
                  else "unlimited",
                  premium_ttft_p99=fmt(s["premium"]["ttft_p99"]),
                  premium_slo=fmt(s["premium"]["slo_attainment"]),
                  noisy_goodput=fmt(s["noisy"]["goodput_rps"]),
                  noisy_rejected=s["noisy"]["n_rejected"],
                  fairness=fmt(res.fairness_index()))


def run(quick: bool = False):
    main(quick=quick)


def main(quick: bool = False):
    scale = 0.4 if quick else 1.0
    b = Bench("tenant_qos_noisy")
    ok_a = noisy_neighbor(b, scale)
    b.finish("PASS" if ok_a else "FAIL")
    b = Bench("tenant_qos_wfq")
    ok_b = wfq_shares(b, scale)
    b.finish("PASS" if ok_b else "FAIL")
    b = Bench("tenant_qos_frontier")
    rate_frontier(b, scale)
    b.finish("PASS" if (ok_a and ok_b) else "FAIL")


if __name__ == "__main__":
    main()
