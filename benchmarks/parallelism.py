"""Parallelism & topology exploration (docs/PARALLELISM.md): the
TP-vs-PP crossover, driven through the resumable sweep harness.

Sweeps parallelism strategy (tensor vs pipeline splits of 4 A100s, plus
the single-GPU reference) across interconnect topologies via
``repro.explore.run_sweep``, caching one JSON per grid point under
``results/bench/parallelism_sweep/`` and emitting ``sweep.csv`` +
``pareto.csv`` (throughput x P99 TTFT x $/token frontier).

Reproduced finding (LLMServingSim-style exploration): **TP wins
intra-node, PP wins across slow inter-node links.**  On an NVLinked
``dgx-a100`` node, tensor parallelism shards the weight streams and its
ring all-reduces ride a 300 GB/s link, so TP4 beats PP4; with one GPU
per node behind <= 100 Gbps NICs (``cross-node-100g``), every per-layer
all-reduce pays inter-node latency + bandwidth while pipeline stages
exchange only per-token activations at their boundaries, so PP4 beats
TP4.

``--smoke`` runs the CI gates (scripts/ci.sh): TP2-over-NVLink must
beat single-GPU throughput, the measured pipeline bubble fraction must
match the closed form ``(pp-1)/(microbatches+pp-1)`` within 2% (both at
the backend and end-to-end), ``ParallelSpec(1,1,1)`` must be
byte-identical to the pre-parallelism cost model, and the crossover
corners must hold.
"""
from __future__ import annotations

import os
import sys

from repro.configs import get_config
from repro.core.comm import p2p_time
from repro.core.costmodel.backends import PipelineBackend
from repro.core.costmodel.hardware import CLUSTERS, HARDWARE, ParallelSpec
from repro.core.costmodel.operators import BatchMix
from repro.core.simulator import SimSpec, WorkerSpec, simulate
from repro.core.workload import WorkloadSpec
from repro.explore import run_sweep, SweepSpec

from benchmarks.common import RESULTS_DIR, Bench, ensure_dir, fmt

MODEL = "llama2-7b"
#: cache-invalidation tag for the sweep's per-point JSON cache — the
#: cache cannot see code changes, so bump this whenever the cost model
#: or this benchmark's builder changes meaning (or run with --force)
COST_MODEL_VERSION = "1"
#: 4-device strategies plus the single-GPU reference; microbatches=2
#: keeps decode weight re-streaming bounded (each micro-batch re-reads
#: its stage's weights, so deep micro-batching hurts decode)
STRATEGIES = ("tp1xpp1", "tp2xpp1", "tp4xpp1", "tp2xpp2", "tp1xpp4")
TOPOLOGIES = ("dgx-a100", "cross-node-100g")
SWEEP_DIR = os.path.join(RESULTS_DIR, "parallelism_sweep")


def _parse(strategy: str):
    tp, pp = strategy.split("x")
    return int(tp[2:]), int(pp[2:])


def _workload(n: int = 48) -> WorkloadSpec:
    return WorkloadSpec(num_requests=n, qps=0.0, seed=0, lengths="fixed",
                        prompt_len=256, output_len=64)


def build_point(point: dict) -> SimSpec:
    """Module-level sweep builder (multiprocessing needs it picklable)."""
    tp, pp = _parse(point["strategy"])
    return SimSpec(
        arch=MODEL, workers=[WorkerSpec(hw="A100")],
        workload=_workload(),
        parallel=ParallelSpec(tp=tp, pp=pp, microbatches=2),
        cluster=point["cluster"])


def _tput(rows, cluster: str, strategy: str) -> float:
    for r in rows:
        if r["cluster"] == cluster and r["strategy"] == strategy:
            return r["throughput"]
    raise KeyError((cluster, strategy))


def assert_crossover(rows) -> dict:
    """TP best intra-node, PP best across slow inter-node links."""
    tp4_fast = _tput(rows, "dgx-a100", "tp4xpp1")
    pp4_fast = _tput(rows, "dgx-a100", "tp1xpp4")
    tp4_slow = _tput(rows, "cross-node-100g", "tp4xpp1")
    pp4_slow = _tput(rows, "cross-node-100g", "tp1xpp4")
    assert tp4_fast > pp4_fast, \
        f"TP should win intra-node: tp4={tp4_fast} pp4={pp4_fast}"
    assert pp4_slow > tp4_slow, \
        f"PP should win across 100G links: pp4={pp4_slow} tp4={tp4_slow}"
    return {"tp_over_pp_intra": tp4_fast / pp4_fast,
            "pp_over_tp_inter": pp4_slow / tp4_slow}


def run(quick: bool = False, processes: int = 0,
        force: bool = False) -> dict:
    """Driver entry point (benchmarks/run.py): sweep the strategy x
    topology grid (resumably), assert the crossover, extract the
    frontier.  ``quick`` trims nothing here — the grid is already
    CI-sized (10 points of a 48-request closed batch)."""
    b = Bench("parallelism")
    sweep = SweepSpec(name="parallelism", builder=build_point,
                      axes={"strategy": list(STRATEGIES),
                            "cluster": list(TOPOLOGIES)},
                      version=COST_MODEL_VERSION)
    ensure_dir()
    result = run_sweep(sweep, SWEEP_DIR, processes=processes,
                       force=force, verbose=True)
    for row in result.rows:
        b.add(cluster=row["cluster"], strategy=row["strategy"],
              throughput=fmt(row["throughput"]),
              p99_ttft=fmt(row["p99_ttft"]),
              p99_tbt=fmt(row["p99_tbt"], 5),
              cost_per_1k_tokens=fmt(row["cost_per_1k_tokens"]),
              bubble=fmt(row.get("bubble_fraction", 0.0), 4),
              pareto=int(row in result.frontier))
    ratios = assert_crossover(result.rows)
    print(f"frontier: {len(result.frontier)}/{len(result.rows)} points "
          f"-> {result.pareto_path}")
    for row in result.frontier:
        print(f"  {row['cluster']:>16s} {row['strategy']:>8s}  "
              f"tput={row['throughput']:.2f}/s  "
              f"p99_ttft={row['p99_ttft']:.2f}s  "
              f"$/1k={row['cost_per_1k_tokens']:.3f}")
    b.finish(derived=f"tp_intra={ratios['tp_over_pp_intra']:.2f}x_"
                     f"pp_inter={ratios['pp_over_tp_inter']:.2f}x")
    return {"rows": result.rows, "frontier": result.frontier, **ratios}


# ---------------------------------------------------------------------------
# CI smoke gates (scripts/ci.sh)
# ---------------------------------------------------------------------------
def smoke_tp_beats_single() -> dict:
    """TP>1 over NVLink must beat a single GPU end-to-end."""
    single = simulate(SimSpec(arch=MODEL, workload=_workload()))
    tp2 = simulate(SimSpec(arch=MODEL, workload=_workload(),
                           parallel=ParallelSpec(tp=2),
                           cluster="dgx-a100"))
    assert tp2.throughput() > single.throughput(), \
        f"TP2/NVLink {tp2.throughput():.2f} <= " \
        f"single-GPU {single.throughput():.2f} req/s"
    print(f"tp-speedup OK: TP2/NVLink {tp2.throughput():.2f} req/s vs "
          f"single-GPU {single.throughput():.2f} req/s")
    return {"gate": "tp_speedup",
            "value": fmt(tp2.throughput() / single.throughput()),
            "threshold": ">1"}


def smoke_bubble_closed_form() -> dict:
    """Pipeline cost gate: (a) the backend's iteration time and bubble
    must match an independent recomputation from the stage rooflines
    and link formulas (bubble/span alone would be tautological — the
    backend defines both from the same step); (b) the end-to-end
    bubble fraction accounted through worker/Results must match the
    closed form (pp-1)/(m+pp-1) within 2%."""
    pp, m = 4, 8
    closed = (pp - 1) / (m + pp - 1)
    backend = PipelineBackend.for_model(
        get_config(MODEL), HARDWARE["A100"],
        ParallelSpec(pp=pp, microbatches=m), CLUSTERS["dgx-a100"])
    mix = BatchMix.from_batch([], [512] * 32)
    total = backend.iteration_time(mix)
    bubble, _, span = backend.last_breakdown
    # independent step recomputation: slowest stage on the micro-batch
    # plus the slowest boundary hand-off
    s = 1.0 / m
    micro = BatchMix(new_tokens=mix.new_tokens * s,
                     attn_units=mix.attn_units * s,
                     kv_read_tokens=mix.kv_read_tokens * s,
                     n_seqs=mix.n_seqs * s,
                     padded_tokens=mix.padded_tokens * s)
    step = max(st.iteration_time(micro) for st in backend.stages) \
        + max(p2p_time(backend.act_bytes_per_token * micro.new_tokens,
                       link) for link in backend.boundary_links)
    expect = backend.overhead + (m + pp - 1) * step
    assert abs(total - expect) <= 1e-9 * expect, \
        f"backend total {total} vs independent recomputation {expect}"
    assert abs(bubble - (pp - 1) * step) <= 1e-9 * bubble, \
        f"backend bubble {bubble} vs independent {(pp - 1) * step}"
    assert abs(span - (total - backend.overhead)) <= 1e-9 * span
    res = simulate(SimSpec(
        arch=MODEL, workload=_workload(32),
        parallel=ParallelSpec(pp=pp, microbatches=m),
        cluster="dgx-a100"))
    measured = res.parallel_summary()["bubble_fraction"]
    assert abs(measured - closed) <= 0.02 * closed, \
        f"e2e bubble {measured:.4f} vs closed form {closed:.4f}"
    print(f"bubble OK: e2e {measured:.4f} ~ closed form {closed:.4f} "
          f"(pp={pp}, m={m}); backend matches independent step "
          f"recomputation")
    return {"gate": "bubble_closed_form", "value": fmt(measured, 4),
            "threshold": f"{closed:.4f}+-2%"}


def smoke_byte_identity() -> dict:
    """ParallelSpec(1,1,1) must not perturb the pre-parallelism model."""
    wl = WorkloadSpec(num_requests=64, qps=8.0, seed=3)
    base = simulate(SimSpec(arch=MODEL, workload=wl))
    par = simulate(SimSpec(arch=MODEL, workload=wl,
                           parallel=ParallelSpec(tp=1, pp=1, replicas=1),
                           cluster="dgx-a100"))
    a = [(r.id, r.t_first_token, r.t_finish) for r in base.requests]
    c = [(r.id, r.t_first_token, r.t_finish) for r in par.requests]
    assert a == c, "ParallelSpec(1,1,1) changed simulated latencies"
    print("byte-identity OK: ParallelSpec(1,1,1) == pre-change model "
          "on 64 requests")
    return {"gate": "byte_identity", "value": 1, "threshold": "equal"}


def smoke_crossover() -> dict:
    """The crossover corners only (4 sims, no sweep cache)."""
    rows = []
    for cluster in TOPOLOGIES:
        for strategy in ("tp4xpp1", "tp1xpp4"):
            point = {"cluster": cluster, "strategy": strategy}
            res = simulate(build_point(point))
            rows.append({**point, "throughput": res.throughput()})
    ratios = assert_crossover(rows)
    print(f"crossover OK: TP {ratios['tp_over_pp_intra']:.2f}x better "
          f"intra-node, PP {ratios['pp_over_tp_inter']:.2f}x better "
          f"across 100G links")
    return {"gate": "tp_pp_crossover",
            "value": f"tp_intra={ratios['tp_over_pp_intra']:.2f}x;"
                     f"pp_inter={ratios['pp_over_tp_inter']:.2f}x",
            "threshold": "both>1"}


def main(argv) -> int:
    if "--smoke" in argv:
        # record the gate outcomes as a CSV so CI can upload them as an
        # artifact (.github/workflows/ci.yml)
        b = Bench("parallelism_smoke")
        b.add(**smoke_tp_beats_single())
        b.add(**smoke_bubble_closed_form())
        b.add(**smoke_byte_identity())
        b.add(**smoke_crossover())
        b.finish(derived="all_gates_passed")
        return 0
    run(quick="--quick" in argv,
        processes=4 if "--parallel" in argv else 0,
        force="--force" in argv)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
