"""Shared benchmark utilities: CSV rows + timing."""
from __future__ import annotations

import csv
import os
import time
from typing import Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "bench")


def ensure_dir() -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return RESULTS_DIR


class Bench:
    """Collects rows; prints a compact CSV block per benchmark."""

    def __init__(self, name: str):
        self.name = name
        self.rows: List[Dict] = []
        self.t0 = time.perf_counter()

    def add(self, **row):
        self.rows.append(row)

    def finish(self, derived: str = "") -> float:
        wall = time.perf_counter() - self.t0
        ensure_dir()
        path = os.path.join(RESULTS_DIR, f"{self.name}.csv")
        if self.rows:
            keys = list(self.rows[0].keys())
            with open(path, "w", newline="") as f:
                w = csv.DictWriter(f, fieldnames=keys)
                w.writeheader()
                for r in self.rows:
                    w.writerow(r)
        us_per_call = wall / max(1, len(self.rows)) * 1e6
        print(f"{self.name},{us_per_call:.1f},{derived}")
        return wall


def fmt(x, nd=4):
    if isinstance(x, float):
        return round(x, nd)
    return x
