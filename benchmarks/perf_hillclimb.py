"""§Perf hillclimb driver: hypothesis → change → re-lower → confirm/refute.

Each iteration re-runs the depth-probe dry-run for one cell with changed
``RunSettings`` (or sharding knobs), extrapolates the roofline terms, and
compares against the previous iteration — emitting the §Perf log rows
for EXPERIMENTS.md.

Usage:
    python -m benchmarks.perf_hillclimb            # run all iterations
    python -m benchmarks.perf_hillclimb --report   # just print the log
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from dataclasses import dataclass
from typing import Dict, List, Optional

from benchmarks.roofline_report import RESULTS, build_table

PERF_DIR = os.path.join(RESULTS, "dryrun_perf")
BASE_DIR = os.path.join(RESULTS, "dryrun_probe")

BASELINE = {"attn_impl": "blocked", "moe_impl": "dense_onehot",
            "remat": "full", "scan_layers": False}


@dataclass
class Iteration:
    cell: str                  # "arch|shape"
    name: str
    hypothesis: str
    settings: Dict            # full settings dict for the run
    expect: str               # which term should move, and how
    ref: Optional[str] = None  # iteration to diff against (None=baseline)


# The three hillclimbed cells, picked from the baseline table:
#   A granite-moe-3b × train_4k    — worst useful-FLOPs ratio (0.15)
#   B qwen3-14b × prefill_32k      — collective-bound serving cell, most
#                                    representative of the paper (serving)
#   C qwen2-0.5b × train_4k        — smallest model, largest relative
#                                    collective+memory overheads
ITERATIONS: List[Iteration] = [
    # ---- cell A: granite-moe-3b-a800m × train_4k -----------------------
    Iteration(
        cell="granite-moe-3b-a800m|train_4k", name="A1_moe_sort",
        hypothesis=(
            "dense_onehot computes all 48 (padded) experts per token: MoE "
            "FFN FLOPs are E/k = 48/8 = 6x the active FLOPs. Dropless "
            "grouped-GEMM (ragged_dot) computes only routed tokens -> "
            "MoE FFN compute drops ~6x; MoE FFN is the bulk of this "
            "model's FLOPs, so the compute term should fall >2x."),
        settings={**BASELINE, "moe_impl": "sort"},
        expect="compute down >2x"),
    Iteration(
        cell="granite-moe-3b-a800m|train_4k", name="A2_remat_dots",
        hypothesis=(
            "remat=full recomputes the whole forward during backward: "
            "total = fwd+refwd+bwd = 8*N*D vs 6*N*D without. Saving "
            "matmul outputs (dots_saveable) removes the re-forward -> "
            "compute term down ~25% on top of A1."),
        settings={**BASELINE, "remat": "dots_saveable"},
        expect="compute down ~25%"),
    Iteration(
        cell="granite-moe-3b-a800m|train_4k", name="A3_causal_attn",
        hypothesis=(
            "granite-3b at S=4096: attention rectangle = 4*S*hq*hd per "
            "token-layer = 2.5e6*32L = 8e7 ... ~33% of this small-expert "
            "model's train FLOPs. Causal-only blocks halve it -> expect "
            "~15-17% off compute."),
        settings={**BASELINE, "attn_impl": "blocked_causal"},
        expect="compute down"),
    # ---- cell B: qwen3-14b × prefill_32k (serving) ---------------------
    Iteration(
        cell="qwen3-14b|prefill_32k", name="B1_replicate_weights",
        hypothesis=(
            "The baseline plan ZeRO-3-shards weights even for serving, so "
            "every layer all-gathers its weights during prefill. Serving "
            "should replicate weights over 'data' (fsdp_params=False): "
            "14B bf16 / 16-way TP = 1.75 GB/device, well under 16 GB -> "
            "per-layer weight all-gathers vanish; collective term drops "
            "to the TP activation all-reduces only."),
        settings={**BASELINE, "fsdp_params": False},
        expect="collective down"),
    Iteration(
        cell="qwen3-14b|prefill_32k", name="B2_embed_fsdp",
        hypothesis=(
            "The vocab-parallel embedding gather forces GSPMD into a "
            "'replicate-then-repartition' reshard of the (B,S,d) "
            "activations (XLA warns 'involuntary full rematerialization')"
            " — a constant ~80 GB/device all-gather term in the probe. "
            "Sharding the (untied) embedding over d_model/'data' instead "
            "makes the gather local -> the constant all-gather term "
            "collapses."),
        settings={**BASELINE, "fsdp_params": False,
                  "embed_shard": "fsdp"},
        expect="collective down", ref="B1_replicate_weights"),
    Iteration(
        cell="qwen3-14b|prefill_32k", name="B3_causal_attn",
        hypothesis=(
            "At S=32k the attention rectangle is 4*S*hq*hd = 6.7e8 FLOPs "
            "per token-layer x 40 layers = 2.7e10/token — roughly EQUAL "
            "to the 2*N = 2.8e10/token of the linears. Attention is "
            "~47% of prefill FLOPs; causal-only blocks halve it -> "
            "expect ~23% off the compute term."),
        settings={**BASELINE, "fsdp_params": False,
                  "embed_shard": "fsdp",
                  "attn_impl": "blocked_causal"},
        expect="compute down ~23%", ref="B2_embed_fsdp"),
    # ---- cell C: qwen2-0.5b × train_4k ---------------------------------
    Iteration(
        cell="qwen2-0.5b|train_4k", name="C1_no_fsdp",
        hypothesis=(
            "A 0.5B model does not need ZeRO-3: FSDP all-gathers every "
            "layer's weights each step (fwd+refwd+bwd). Replicating "
            "non-embedding weights over 'data' removes those all-gathers "
            "-> collective term down; per-device memory rises by ~12B/16 "
            "x params (trivial for 0.5B)."),
        settings={**BASELINE, "fsdp_params": False},
        expect="collective down"),
    Iteration(
        cell="qwen2-0.5b|train_4k", name="C2_remat_causal",
        hypothesis=(
            "qwen2 at S=4096 with d_model=896 has a high attention:"
            "linear FLOPs ratio (~33% of train FLOPs) — causal-only "
            "attention halves it -> ~17-23% off compute. dots_saveable "
            "is stacked but expected inert in this counter (see A2: XLA "
            "CSE already merges the unrolled re-forward)."),
        settings={**BASELINE, "fsdp_params": False,
                  "attn_impl": "blocked_causal",
                  "remat": "dots_saveable"},
        expect="compute down", ref="C1_no_fsdp"),
]


def run_probe(arch: str, shape: str, settings: Dict, outdir: str) -> None:
    os.makedirs(outdir, exist_ok=True)
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--mesh", "single",
           "--depth-probe", "--settings", json.dumps(settings),
           "--out", outdir]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(RESULTS), "src")
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(f"probe failed: {r.stdout[-2000:]}"
                           f"{r.stderr[-2000:]}")


def load_terms(dirname: str, arch: str, shape: str) -> Optional[Dict]:
    cells = build_table(dirname, f"{arch}_{shape}_single_*.json")
    for c in cells:
        if c.arch == arch and c.shape == shape:
            return {"compute": c.compute_s, "memory": c.memory_s,
                    "collective": c.collective_s, "dominant": c.dominant,
                    "useful": c.useful_ratio}
    return None


def main():
    report_only = "--report" in sys.argv
    log = []
    done: Dict[str, Dict] = {}
    for it in ITERATIONS:
        arch, shape = it.cell.split("|")
        outdir = os.path.join(PERF_DIR, it.name)
        if not report_only and not (
                os.path.isdir(outdir) and len(os.listdir(outdir)) >= 2):
            print(f"[run] {it.name} ({arch} x {shape})", flush=True)
            run_probe(arch, shape, it.settings, outdir)
        base = done.get(it.ref) if it.ref else None
        if base is None:
            base = load_terms(BASE_DIR, arch, shape)
        after = load_terms(outdir, arch, shape)
        if base is None or after is None:
            print(f"[skip] {it.name}: missing artifacts")
            continue
        deltas = {k: (after[k] / base[k] - 1.0) * 100
                  for k in ("compute", "memory", "collective")
                  if base[k] > 0}
        entry = {"iteration": it.name, "cell": it.cell,
                 "hypothesis": it.hypothesis, "expect": it.expect,
                 "before": base, "after": after,
                 "delta_pct": {k: round(v, 1) for k, v in deltas.items()}}
        entry["vs"] = it.ref or "baseline"
        log.append(entry)
        done[it.name] = after
        print(f"[done] {it.name}: " +
              " ".join(f"{k}:{v:+.1f}%" for k, v in deltas.items()),
              flush=True)
    os.makedirs(PERF_DIR, exist_ok=True)
    with open(os.path.join(PERF_DIR, "perf_log.json"), "w") as f:
        json.dump(log, f, indent=1)
    print(f"perf_hillclimb,{len(log)},iterations->"
          f"{os.path.join(PERF_DIR, 'perf_log.json')}")


if __name__ == "__main__":
    main()
