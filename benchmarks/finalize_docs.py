"""Injects the generated roofline table and §Perf log into EXPERIMENTS.md
(replaces the <!-- ROOFLINE_TABLE --> / <!-- PERF_LOG --> markers)."""
from __future__ import annotations

import json
import os

from benchmarks.roofline_report import RESULTS, build_table, to_markdown

REPO = os.path.dirname(RESULTS)
EXP = os.path.join(REPO, "EXPERIMENTS.md")


def perf_log_md() -> str:
    path = os.path.join(RESULTS, "dryrun_perf", "perf_log.json")
    if not os.path.exists(path):
        return "_(perf log not generated)_"
    log = json.load(open(path))
    out = []
    for e in log:
        d = e["delta_pct"]
        b, a = e["before"], e["after"]
        verdict = "CONFIRMED" if _confirms(e) else "REFUTED"
        out.append(f"**{e['iteration']}** ({e['cell']}) — *{verdict}*\n\n"
                   f"- Hypothesis: {e['hypothesis']}\n"
                   f"- Expected: {e['expect']}\n"
                   f"- Before: compute {b['compute']:.3e}s, memory "
                   f"{b['memory']:.3e}s, collective {b['collective']:.3e}s "
                   f"(dominant: {b['dominant']})\n"
                   f"- After: compute {a['compute']:.3e}s, memory "
                   f"{a['memory']:.3e}s, collective {a['collective']:.3e}s "
                   f"(dominant: {a['dominant']})\n"
                   f"- Delta: " +
                   ", ".join(f"{k} {v:+.1f}%" for k, v in d.items()) + "\n")
    return "\n".join(out)


def _confirms(e) -> bool:
    d = e["delta_pct"]
    exp = e["expect"]
    if "compute down" in exp:
        want = d.get("compute", 0.0) < -3
        if ">2x" in exp:
            want = d.get("compute", 0.0) < -50
        return want
    if "collective down" in exp:
        return d.get("collective", 0.0) < -3
    return True


def main():
    table_md = to_markdown(build_table(
        os.path.join(RESULTS, "dryrun_probe")))
    text = open(EXP).read()
    text = text.replace("<!-- ROOFLINE_TABLE -->", table_md)
    text = text.replace("<!-- PERF_LOG -->", perf_log_md())
    open(EXP, "w").write(text)
    with open(os.path.join(RESULTS, "roofline.md"), "w") as f:
        f.write(table_md + "\n")
    print("EXPERIMENTS.md updated;", len(table_md.splitlines()) - 4,
          "roofline cells")


if __name__ == "__main__":
    main()
