"""Heterogeneous fleet economics (docs/HETEROGENEITY.md): mixed
hardware and mixed models in one cluster.

Part A reproduces the paper's hardware-substitution economics on the
cheap-decode axis: a disaggregated fleet that prefilllls on one A100 and
decodes on L4s (bandwidth-per-dollar cards) against a homogeneous
all-A100 fleet of the same slot count, at the same offered load and the
same SLOs.  The finding: **the split fleet wins on $/1M generated
tokens at equal SLO attainment** — prefill is FLOPs-bound (keep the
A100), decode is bandwidth-bound (L4 at 1/5 the price covers it), so
the dollar-weighted fleet price drops faster than the tail grows.
``spec_price`` (repro.explore.sweep) prices exactly the fleet the
simulator builds, pinned by tests/test_hetero_fleet.py.

Part B demonstrates multi-model serving: two models pinned to disjoint
worker pools (llama2-7b on A100s, qwen2-0.5b on L4s) behind the
``model_routed`` global policy, with per-model latency/SLO breakdowns
read from ``Results.model_summary()``.  The routing invariant — no
worker ever serves a model it does not host — is asserted on every run,
not sampled.

``--smoke`` runs both parts at CI scale and hard-asserts the cost win
and the zero-cross-dispatch invariant (wired into scripts/ci.sh).
"""
from __future__ import annotations

import sys

from repro.core.simulator import SimSpec, WorkerSpec, simulate
from repro.core.tenancy import TenantSpec, TenantTier
from repro.core.workload import WorkloadSpec
from repro.explore.sweep import spec_price

from benchmarks.common import Bench, fmt

BIG, SMALL = "llama2-7b", "qwen2-0.5b"
#: generous enough that both fleets attain ~all of them at the offered
#: load — the comparison is $/token at *equal* attainment, not a tail
#: shoot-out (the split fleet's decode is slower per token, just not
#: SLO-violating)
TTFT_SLO, MTPOT_SLO = 10.0, 0.3


# ---------------------------------------------------------------------------
# Part A: split prefill/decode fleet vs homogeneous, $/1M tokens
# ---------------------------------------------------------------------------
def _fleet_specs(n_req: int, qps: float):
    """(label, SimSpec) pairs for the 4-slot fleet comparison."""
    wl = WorkloadSpec(num_requests=n_req, qps=qps, seed=0,
                      lengths="fixed", prompt_len=256, output_len=128)
    homo = SimSpec(
        arch=BIG, workers=[WorkerSpec(hw="A100") for _ in range(4)],
        global_policy="least_loaded", workload=wl)
    split = SimSpec(
        arch=BIG,
        workers=[WorkerSpec(hw="A100", role="prefill")] +
                [WorkerSpec(hw="L4", role="decode") for _ in range(3)],
        global_policy="disagg", workload=wl)
    return [("homogeneous_4xA100", homo),
            ("split_1xA100p_3xL4d", split)]


def _economics(spec: SimSpec):
    """(cost per 1M generated tokens, SLO attainment, finished) — the
    row Part A compares across fleets."""
    res = simulate(spec)
    fin = res.finished
    tokens = sum(r.tokens_generated for r in fin)
    n_ok = sum(1 for r in fin if r.meets_slo(TTFT_SLO, MTPOT_SLO))
    attain = n_ok / len(fin) if fin else 0.0
    cost_1m = spec_price(spec) * res.sim_time / tokens * 1e6 \
        if tokens else float("nan")
    return cost_1m, attain, len(fin), res


def run_cost_comparison(b: Bench, n_req: int, qps: float):
    """Part A driver: returns {label: (cost_1m, attainment)}."""
    out = {}
    for label, spec in _fleet_specs(n_req, qps):
        cost_1m, attain, n_fin, res = _economics(spec)
        out[label] = (cost_1m, attain)
        b.add(part="cost", fleet=label, price=fmt(spec_price(spec), 2),
              finished=n_fin, slo_attainment=fmt(attain),
              cost_per_1M_tokens=fmt(cost_1m, 2),
              p99_ttft=fmt(res.latency_stats()["p99"], 3))
    return out


def assert_cost_win(out):
    """The split fleet must be cheaper per token at (near-)equal SLO
    attainment — the reproduced finding, gated in CI."""
    c_homo, a_homo = out["homogeneous_4xA100"]
    c_split, a_split = out["split_1xA100p_3xL4d"]
    assert c_split < c_homo, \
        f"split fleet should be cheaper: {c_split:.1f} >= {c_homo:.1f}"
    assert a_split >= 0.99 * a_homo, \
        f"cost win must hold at equal SLO: {a_split:.3f} < {a_homo:.3f}"
    return c_homo / c_split


# ---------------------------------------------------------------------------
# Part B: two models on disjoint pools behind model_routed
# ---------------------------------------------------------------------------
def _multi_model_spec(n_each: int) -> SimSpec:
    tier = TenantTier()
    return SimSpec(
        arch=BIG,
        workers=[WorkerSpec(hw="A100"), WorkerSpec(hw="A100"),
                 WorkerSpec(hw="L4", arch=SMALL),
                 WorkerSpec(hw="L4", arch=SMALL)],
        global_policy="model_routed",
        tenants=[
            TenantSpec(tenant_id="big", tier=tier,
                       workload=WorkloadSpec(num_requests=n_each,
                                             qps=4.0, seed=1,
                                             model=BIG)),
            TenantSpec(tenant_id="small", tier=tier,
                       workload=WorkloadSpec(num_requests=n_each,
                                             qps=8.0, seed=2,
                                             model=SMALL))])


def run_model_routing(b: Bench, n_each: int):
    """Part B driver: route two models, assert the invariant, report
    per-model summaries.  Returns the Results."""
    spec = _multi_model_spec(n_each)
    res = simulate(spec)
    fin = [r for r in res.requests if r.t_finish is not None]
    assert len(fin) == 2 * n_each, \
        f"lost {2 * n_each - len(fin)} requests"
    # routing invariant: every worker served only its hosted model
    hosted = {wid: m for wid, m in (res.worker_models or {}).items()}
    for r in fin:
        assert hosted[r.worker_id] == r.model, \
            f"request {r.id} ({r.model}) ran on worker " \
            f"{r.worker_id} hosting {hosted[r.worker_id]}"
    summary = res.model_summary(ttft_slo=TTFT_SLO, mtpot_slo=MTPOT_SLO)
    assert set(summary) == {BIG, SMALL}
    for model in sorted(summary):
        row = summary[model]
        b.add(part="routing", fleet=model, price="",
              finished=row["n_finished"],
              slo_attainment=fmt(row["slo_attainment"]),
              cost_per_1M_tokens="",
              p99_ttft=fmt(row["ttft_p99"], 3))
    return res, summary


# ---------------------------------------------------------------------------
def run(quick: bool = False):
    """Driver entry point (benchmarks/run.py)."""
    b = Bench("hetero_fleet")
    n_req = 120 if quick else 400
    out = run_cost_comparison(b, n_req, qps=4.0)
    ratio = assert_cost_win(out)
    _, summary = run_model_routing(b, 60 if quick else 200)
    b.finish(derived=f"split_fleet_cost_win={ratio:.2f}x"
                     f"_models={len(summary)}")
    return out


def main(argv) -> int:
    if "--smoke" in argv:
        # CI gates (scripts/ci.sh): cost win + exact routing at CI scale
        b = Bench("hetero_fleet_smoke")
        out = run_cost_comparison(b, n_req=80, qps=4.0)
        ratio = assert_cost_win(out)
        print(f"cost-win OK: split fleet {ratio:.2f}x cheaper per 1M "
              f"tokens at equal SLO attainment")
        res, summary = run_model_routing(b, n_each=40)
        print(f"model-routing OK: 80/80 finished, zero cross-model "
              f"dispatches, per-model p99 TTFT "
              + ", ".join(f"{m}={summary[m]['ttft_p99']:.3f}s"
                          for m in sorted(summary)))
        b.finish(derived=f"cost_win={ratio:.2f}x_routing_exact")
        return 0
    run(quick="--quick" in argv)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
