"""Speculative-decoding sweep: K x acceptance-rate x batch size.

Reproduces the two headline properties of speculation in serving:

  * at batch 1 (weight-bandwidth-bound decode), K=4 drafts with
    acceptance >= 0.8 yield >= 1.5x effective tokens per target step,
  * at high batch occupancy the same configuration is net-NEGATIVE in
    token throughput (the crossover: verify work becomes compute-bound,
    so rejected draft tokens and the draft model's own iterations cost
    more than the extra tokens are worth).

Run:  PYTHONPATH=src python -m benchmarks.spec_decode
      PYTHONPATH=src python -m benchmarks.run --quick --only spec_decode
"""
from __future__ import annotations

from benchmarks.common import Bench, fmt
from repro.core import (AcceptanceModel, SimSpec, SpecDecodeSpec, WorkerSpec,
                        simulate)
from repro.core.workload import WorkloadSpec

ARCH = "llama2-7b"
DRAFT = "qwen2-0.5b"


def _case(*, batch: int, k: int = 0, acc: float = 0.0,
          num_requests: int = 0, output_len: int = 64):
    """One simulation: spec decoding enabled iff ``k > 0``."""
    wl = WorkloadSpec(
        num_requests=num_requests or max(2 * batch, 8), qps=0.0,
        lengths="fixed", prompt_len=128, output_len=output_len, seed=0)
    spec = None
    if k > 0:
        spec = SpecDecodeSpec(draft_arch=DRAFT, lookahead=k,
                              acceptance=AcceptanceModel(rate=acc))
    return simulate(SimSpec(
        arch=ARCH, workers=[WorkerSpec(hw="A100")], workload=wl,
        max_batch=batch, max_batched_tokens=4096, spec_decode=spec))


def run(quick: bool = False) -> None:
    bench = Bench("spec_decode")
    batches = (1, 64) if quick else (1, 16, 64)
    ks = (4,) if quick else (2, 4, 8)
    accs = (0.8,) if quick else (0.5, 0.8, 0.95)

    # ---- sweep: K x acceptance x batch --------------------------------
    for batch in batches:
        base = _case(batch=batch)
        base_tps = base.token_throughput()
        for k in ks:
            for acc in accs:
                res = _case(batch=batch, k=k, acc=acc)
                s = res.spec_summary()
                bench.add(batch=batch, k=k, acc=acc,
                          base_tps=fmt(base_tps, 1),
                          spec_tps=fmt(res.token_throughput(), 1),
                          speedup=fmt(res.token_throughput() / base_tps, 3),
                          eff_tokens_per_step=fmt(
                              s["eff_tokens_per_step"], 3),
                          acceptance=fmt(s["acceptance_rate"], 3))

    # ---- headline checks (report FAIL, don't abort the driver) --------
    lo = _case(batch=1, k=4, acc=0.8)
    eff = lo.spec_summary()["eff_tokens_per_step"]
    lo_base = _case(batch=1).token_throughput()
    hi, hi_base = _case(batch=64, k=4, acc=0.8), _case(batch=64)
    ok = (eff >= 1.5                                   # >=1.5x tokens/step
          and lo.token_throughput() > lo_base          # net-positive at b=1
          and hi.token_throughput() < hi_base.token_throughput())  # crossover

    bench.finish(
        f"{'PASS' if ok else 'FAIL'} "
        f"eff_tokens_per_step@b1={eff:.2f} "
        f"b1_speedup={lo.token_throughput() / lo_base:.2f} "
        f"b64_speedup={hi.token_throughput() / hi_base.token_throughput():.2f}")
    print(f"batch=1  : {lo_base:8.1f} -> {lo.token_throughput():8.1f} tok/s "
          f"({eff:.2f} tokens/step) — speculation wins")
    print(f"batch=64 : {hi_base.token_throughput():8.1f} -> "
          f"{hi.token_throughput():8.1f} tok/s — crossover: speculation "
          f"net-negative at high occupancy")


if __name__ == "__main__":
    run()
