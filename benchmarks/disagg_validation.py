"""Paper Fig. 7 (DistServe comparison): disaggregated P/D simulation vs a
real two-stage pipeline.

The "real" side runs actual JAX compute in two stages with their own
virtual clocks: prefill iterations on worker P, a bandwidth-priced KV
transfer (the measured KV bytes over a configured link), then decode
iterations on worker D — the same structure DistServe measures on two
A100s (64-in/64-out fixed requests)."""
from __future__ import annotations

import jax

from repro.configs import get_smoke_config
from repro.core.comm import LinkSpec
from repro.core.costmodel.operators import kv_bytes_per_token
from repro.core.mem.block_manager import BlockManager, MemoryConfig
from repro.core.simulator import SimSpec, Simulation, WorkerSpec
from repro.core.workload import WorkloadSpec, generate
from repro.models import model_zoo as zoo
from repro.serving.engine import EngineConfig, ServingEngine

from benchmarks.common import Bench, fmt

LINK_BW = 2e9          # bytes/s, playing the measured inter-GPU bandwidth
IN_LEN, OUT_LEN = 32, 16


def real_two_stage(model, params, wl):
    """Stage P: prefill-only engine; stage D: decode-only engine fed by
    P's completions + a transfer delay.  Returns per-request records."""
    reqs = generate(wl)
    kv_per_tok = kv_bytes_per_token(model.cfg)

    ecP = EngineConfig(num_blocks=256, block_size=8, max_batch=4,
                       max_pages_per_seq=16)
    engP = ServingEngine(model, params, ecP)
    # stage P: prefills only (output_len 1), honoring Poisson arrivals
    # on P's virtual clock
    import copy
    p_reqs = []
    for r in reqs:
        pr = copy.copy(r)
        pr.output_len = 1
        pr.token_times = []
        p_reqs.append(pr)
    pendingP = sorted(p_reqs, key=lambda r: r.arrival_time)
    while pendingP or engP.has_work:
        while pendingP and pendingP[0].arrival_time <= engP.clock + 1e-12:
            engP.add_request(pendingP.pop(0))
        if engP.step() is None:
            if pendingP:
                engP.clock = pendingP[0].arrival_time
                continue
            break

    # decode on D, arrival = P finish + transfer
    engD = ServingEngine(model, params, ecP)
    transfer = {r.id: kv_per_tok * r.prompt_len / LINK_BW for r in reqs}
    order = sorted(p_reqs, key=lambda r: (r.t_finish + transfer[r.id]))
    d_reqs = []
    for pr in order:
        dr = copy.copy(next(r for r in reqs if r.id == pr.id))
        dr.arrival_time = pr.t_finish + transfer[pr.id]
        dr.output_len = OUT_LEN - 1
        dr.token_times = []
        d_reqs.append(dr)
    pending = sorted(d_reqs, key=lambda r: r.arrival_time)
    while pending or engD.has_work:
        while pending and pending[0].arrival_time <= engD.clock + 1e-12:
            engD.add_request(pending.pop(0))
        if engD.step() is None:
            if pending:
                engD.clock = pending[0].arrival_time
                continue
            break
    total = max(r.t_finish for r in d_reqs)
    return total


def run(counts=(10, 20, 40, 60)):
    b = Bench("disagg_validation_fig7")
    cfg = get_smoke_config("llama2-7b")
    model = zoo.build(cfg)
    params = zoo.init_params(model, jax.random.key(0))

    # calibrate the sim from a colocated run (2 passes: warm the jit
    # cache first so walls measure compute, not compilation)
    wl_cal = WorkloadSpec(num_requests=20, qps=0.0, seed=55,
                          lengths="fixed", prompt_len=IN_LEN,
                          output_len=OUT_LEN)
    samples = None
    for _ in range(2):
        eng = ServingEngine(model, params, EngineConfig(
            num_blocks=256, block_size=8, max_batch=4,
            max_pages_per_seq=16))
        for r in generate(wl_cal):
            eng.add_request(r)
        eng.run()
        samples = [(r.mix, r.wall) for r in eng.records]

    max_err = 0.0
    for n in counts:
        wl = WorkloadSpec(num_requests=n, qps=8.0, seed=2,
                          lengths="fixed", prompt_len=IN_LEN,
                          output_len=OUT_LEN)
        real_total = real_two_stage(model, params, wl)

        spec = SimSpec(
            arch=cfg,
            workers=[WorkerSpec(hw="CPU", role="prefill"),
                     WorkerSpec(hw="CPU", role="decode")],
            global_policy="disagg", workload=wl,
            local_policy="continuous", max_batch=4,
            backend="tabular", backend_samples=samples, block_size=8,
            kv_link=LinkSpec("pcie-measured", bandwidth=LINK_BW,
                             latency=0.0))
        sim = Simulation(spec)
        for w in sim.workers:
            w.mem = BlockManager(MemoryConfig(
                num_blocks=256, block_size=8, kv_bytes_per_token=1.0))
        res = sim.run()
        sim_total = max(r.t_finish for r in res.finished)
        err = abs(sim_total - real_total) / real_total * 100
        max_err = max(max_err, err)
        b.add(requests=n, real_total_s=fmt(real_total),
              sim_total_s=fmt(sim_total), pct_err=fmt(err, 2))
    b.finish(derived=f"max_disagg_total_err={max_err:.2f}%")
    return max_err


if __name__ == "__main__":
    run()
