"""Chaos & availability exploration (docs/RELIABILITY.md): replicas x
MTBF x recovery-cost sweep over the fault-injection layer, driven
through the resumable sweep harness.

Each grid point runs a fixed observation horizon with one stochastic
``FaultProcess`` per worker (exponential MTBF/MTTR) and a configurable
model-reload latency, then folds ``Results.availability_summary()`` into
the metrics row; ``repro.explore`` caches one JSON per point under
``results/bench/chaos_sweep/`` and emits ``sweep.csv`` + ``pareto.csv``
(the service-availability x $/token frontier).  Because fault timelines
are drawn from a dedicated per-worker RNG — never from simulation
content — every point observes the *same* per-worker outage schedule,
so availability comparisons across the grid are paired, not sampled.

Reproduced finding: **replication buys availability at linear cost** —
service availability improves monotonically with replicas (an r+1-way
outage needs every r-way outage *plus* one more simultaneous failure),
while $/token scales with the devices deployed; the knee of the
frontier moves with MTBF and with how expensive recovery is.

``--smoke`` runs the CI gates (scripts/ci.sh): a zero-fault
``ChaosSpec`` is byte-identical to the no-chaos baseline, no request is
lost or duplicated under stochastic failures, availability improves
monotonically with replicas, KV surviving in the host swap tier beats
full re-prefill on mean TTFT, and the same seed reproduces identical
availability numbers.
"""
from __future__ import annotations

import os
import sys

from repro.core.faults import ChaosSpec, FaultProcess, FaultSpec
from repro.core.simulator import SimSpec, WorkerSpec, simulate
from repro.core.workload import WorkloadSpec
from repro.explore import run_sweep, SweepSpec
from repro.explore.sweep import default_metrics

from benchmarks.common import RESULTS_DIR, Bench, ensure_dir, fmt

MODEL = "llama2-7b"
#: cache-invalidation tag for the per-point JSON cache (bump when the
#: fault model or this builder changes meaning, or run with --force)
CHAOS_MODEL_VERSION = "2"
SWEEP_DIR = os.path.join(RESULTS_DIR, "chaos_sweep")
#: fixed observation horizon (s): every point measures availability
#: over the same window, with arrivals spanning past it.  Long enough
#: that even the gentlest MTBF axis value fires a few failures per
#: worker (~3 expected at mtbf=60)
HORIZON = 180.0
MTTR = 5.0
TARGET = 0.995

REPLICAS = (1, 2, 3)
MTBFS = (20.0, 60.0)
RELOADS = (2.0, 15.0)


def _chaos(replicas: int, mtbf: float, reload: float,
           seed: int = 7) -> ChaosSpec:
    """One independent exponential fail/repair process per worker.
    Worker i's timeline depends only on (seed, i), so a grid point with
    more replicas sees the exact same outages on the shared workers."""
    return ChaosSpec(
        processes=tuple(FaultProcess(worker=i, mtbf=mtbf, mttr=MTTR,
                                     seed=seed + i)
                        for i in range(replicas)),
        reload_time=reload)


def build_point(point: dict) -> SimSpec:
    """Module-level sweep builder (multiprocessing needs it picklable)."""
    r = point["replicas"]
    return SimSpec(
        arch=MODEL,
        workers=[WorkerSpec() for _ in range(r)],
        workload=WorkloadSpec(num_requests=int(4 * HORIZON * 1.5),
                              qps=4.0, seed=0),
        chaos=_chaos(r, point["mtbf"], point["reload"]),
        until=HORIZON)


def chaos_metrics(spec: SimSpec, res) -> dict:
    """Default (throughput, tail latency, $/token) row + the
    availability/error-budget fields the frontier is extracted over."""
    row = default_metrics(spec, res)
    av = res.availability_summary(target=TARGET)
    row.update(
        service_availability=av["service_availability"],
        capacity_availability=av["capacity_availability"],
        n_failures=av["n_failures"],
        service_downtime_s=av["service_downtime_s"],
        mttr_observed_s=av["mttr_observed_s"],
        burn_rate=av["burn_rate"],
        request_success_rate=av["request_success_rate"])
    return row


OBJECTIVES = {"service_availability": "max", "cost_per_1k_tokens": "min"}


def run(quick: bool = False, processes: int = 0,
        force: bool = False) -> dict:
    """Driver entry point (benchmarks/run.py): sweep the replicas x
    MTBF x reload grid (resumably), extract the availability-vs-cost
    frontier, and pin the monotone-replication finding."""
    b = Bench("chaos_sweep")
    axes = {"replicas": list(REPLICAS[:2] if quick else REPLICAS),
            "mtbf": list(MTBFS[:1] if quick else MTBFS),
            "reload": list(RELOADS[:1] if quick else RELOADS)}
    sweep = SweepSpec(name="chaos_sweep", builder=build_point,
                      axes=axes, metrics=chaos_metrics,
                      version=CHAOS_MODEL_VERSION)
    ensure_dir()
    result = run_sweep(sweep, SWEEP_DIR, processes=processes,
                       objectives=OBJECTIVES, force=force, verbose=True)
    for row in result.rows:
        b.add(replicas=row["replicas"], mtbf=row["mtbf"],
              reload=row["reload"],
              service_availability=fmt(row["service_availability"], 6),
              capacity_availability=fmt(row["capacity_availability"], 6),
              n_failures=row["n_failures"],
              burn_rate=fmt(row["burn_rate"], 3),
              throughput=fmt(row["throughput"]),
              cost_per_1k_tokens=fmt(row["cost_per_1k_tokens"]),
              pareto=int(row in result.frontier))
    # paired timelines make this exact, not statistical
    for mtbf in axes["mtbf"]:
        for reload in axes["reload"]:
            avs = [r["service_availability"] for r in result.rows
                   if r["mtbf"] == mtbf and r["reload"] == reload]
            assert all(b >= a for a, b in zip(avs, avs[1:])), \
                f"replication must not hurt availability: {avs}"
    print(f"frontier: {len(result.frontier)}/{len(result.rows)} points "
          f"-> {result.pareto_path}")
    for row in result.frontier:
        print(f"  r={row['replicas']} mtbf={row['mtbf']:.0f}s "
              f"reload={row['reload']:.0f}s  "
              f"avail={row['service_availability']:.4f}  "
              f"$/1k={row['cost_per_1k_tokens']:.3f}")
    best = max(result.rows, key=lambda r: r["service_availability"])
    b.finish(derived=f"best_avail={best['service_availability']:.4f}"
                     f"@r{best['replicas']}")
    return {"rows": result.rows, "frontier": result.frontier}


# ---------------------------------------------------------------------------
# CI smoke gates (scripts/ci.sh)
# ---------------------------------------------------------------------------
def _sig(res):
    return [(r.id, r.t_first_token, r.t_finish, tuple(r.token_times))
            for r in sorted(res.requests, key=lambda r: r.id)]


def smoke_zero_fault_identity() -> dict:
    """An empty ChaosSpec must not perturb the simulation at all."""
    base = dict(arch=MODEL, workers=[WorkerSpec(), WorkerSpec()],
                workload=WorkloadSpec(num_requests=100, qps=10.0,
                                      seed=3))
    r0 = simulate(SimSpec(**base))
    r1 = simulate(SimSpec(**base, chaos=ChaosSpec()))
    assert _sig(r0) == _sig(r1), \
        "zero-fault chaos changed simulated latencies"
    print("zero-fault identity OK: ChaosSpec() == no-chaos baseline "
          "on 100 requests")
    return {"gate": "zero_fault_identity", "value": 1,
            "threshold": "equal"}


def smoke_no_loss_under_failures() -> dict:
    """Every admitted request finishes exactly once despite repeated
    worker failures (orphan redispatch + cluster-outage parking)."""
    res = simulate(SimSpec(
        arch=MODEL, workers=[WorkerSpec(), WorkerSpec()],
        workload=WorkloadSpec(num_requests=120, qps=8.0, seed=3),
        chaos=ChaosSpec(
            processes=(FaultProcess(worker=0, mtbf=6.0, mttr=1.0,
                                    seed=7),
                       FaultProcess(worker=1, mtbf=9.0, mttr=1.0,
                                    seed=7)),
            reload_time=2.0)))
    fin = [r for r in res.requests if r.t_finish is not None]
    assert len(fin) == 120, f"lost {120 - len(fin)} requests"
    assert all(r.tokens_generated == r.output_len and
               len(r.token_times) == r.output_len for r in fin), \
        "a request emitted a wrong token count (loss or duplication)"
    n_fail = sum(1 for e in res.fault_events if e.kind == "fail")
    assert n_fail > 0, "chaos never fired; the gate tested nothing"
    print(f"no-loss OK: 120/120 finished exactly once across "
          f"{n_fail} injected failures")
    return {"gate": "no_loss_under_failures", "value": n_fail,
            "threshold": "120/120 finished"}


def smoke_monotone_replicas() -> dict:
    """Paired outage schedules over a fixed horizon: service
    availability must be monotone nondecreasing in replica count, and
    3 replicas must strictly beat 1."""
    avs = []
    for r in (1, 2, 3):
        res = simulate(SimSpec(
            arch=MODEL, workers=[WorkerSpec() for _ in range(r)],
            workload=WorkloadSpec(num_requests=400, qps=5.0, seed=0),
            chaos=_chaos(r, mtbf=10.0, reload=2.0),
            until=60.0))
        avs.append(res.availability_summary()["service_availability"])
    assert all(b >= a for a, b in zip(avs, avs[1:])), \
        f"availability decreased with replicas: {avs}"
    assert avs[2] > avs[0], \
        f"3 replicas must strictly beat 1: {avs}"
    print(f"monotone-replicas OK: availability "
          f"{' -> '.join(f'{a:.4f}' for a in avs)} for r=1,2,3")
    return {"gate": "monotone_replicas",
            "value": ";".join(f"{a:.4f}" for a in avs),
            "threshold": "nondecreasing"}


def _swap_survival_spec(survive: bool) -> SimSpec:
    """Memory-pressure config calibrated so requests sit in the host
    swap tier when worker 0 dies at t=3 (see tests/test_chaos.py)."""
    return SimSpec(
        arch=MODEL,
        workers=[WorkerSpec(gpu_mem_util=0.19),
                 WorkerSpec(gpu_mem_util=0.19)],
        workload=WorkloadSpec(num_requests=80, qps=40.0, seed=4,
                              lengths="fixed", prompt_len=512,
                              output_len=64),
        preemption_mode="swap",
        faults=[FaultSpec(time=3.0, worker=0, kind="fail")],
        chaos=ChaosSpec(reload_time=1.0, host_kv_survives=survive))


def smoke_swap_survival_beats_recompute() -> dict:
    """KV surviving in host DRAM must beat full re-prefill on TTFT."""
    surv = simulate(_swap_survival_spec(True))
    reco = simulate(_swap_survival_spec(False))
    adopted = sum(s["adopted"] for s in surv.swap_stats.values())
    assert adopted > 0, "no KV was adopted; the gate tested nothing"
    mean = lambda res: sum(  # noqa: E731
        r.ttft for r in res.finished) / len(res.finished)
    t_s, t_r = mean(surv), mean(reco)
    assert t_s < t_r, \
        f"swap survival should lower mean TTFT: {t_s:.5f} >= {t_r:.5f}"
    print(f"swap-survival OK: mean TTFT {t_s:.5f}s (resume from host) "
          f"< {t_r:.5f}s (re-prefill), {adopted} adoption(s)")
    return {"gate": "swap_survival_ttft",
            "value": fmt(t_r - t_s, 5), "threshold": ">0"}


def smoke_availability_reproducible() -> dict:
    """Same seed, same fault timeline, same availability numbers."""
    spec = dict(arch=MODEL, workers=[WorkerSpec(), WorkerSpec()],
                workload=WorkloadSpec(num_requests=120, qps=8.0,
                                      seed=3))
    chaos = _chaos(2, mtbf=8.0, reload=1.0)
    a = simulate(SimSpec(**spec, chaos=chaos)).availability_summary()
    b = simulate(SimSpec(**spec, chaos=chaos)).availability_summary()
    assert a == b, "same-seed availability summaries differ"
    print(f"reproducibility OK: availability "
          f"{a['service_availability']:.6f} identical across runs")
    return {"gate": "availability_reproducible",
            "value": fmt(a["service_availability"], 6),
            "threshold": "equal"}


def main(argv) -> int:
    if "--smoke" in argv:
        # record the gate outcomes as a CSV so CI can upload them as an
        # artifact (.github/workflows/ci.yml)
        b = Bench("chaos_sweep_smoke")
        b.add(**smoke_zero_fault_identity())
        b.add(**smoke_no_loss_under_failures())
        b.add(**smoke_monotone_replicas())
        b.add(**smoke_swap_survival_beats_recompute())
        b.add(**smoke_availability_reproducible())
        b.finish(derived="all_gates_passed")
        return 0
    run(quick="--quick" in argv,
        processes=4 if "--parallel" in argv else 0,
        force="--force" in argv)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
