"""Closed-loop autoscaling economics (docs/AUTOSCALING.md): the
cost-vs-SLO Pareto of adaptive fleets against static provisioning.

A diurnal workload (sinusoidal arrival rate, peak ~= 4x trough) is
served by seven fleet configurations: four static A100 fleets (1-4
replicas, the classic peak-vs-trough provisioning dilemma) and three
closed-loop autoscalers (``threshold``, ``target_utilization``,
``predictive_ema``) scaling one template worker between 1 and 4
replicas, paying the full ``HardwareSpec.reload_time`` + warm-up lag on
every scale-up.  Each point reports SLO attainment (streaming sketches,
so the full run handles ~10^6 requests in drop mode) and the
uptime-weighted **$/1M generated tokens** from
``Results.scaling_summary()`` — a scaled-down worker stops billing the
moment it retires.

The reproduced finding, hard-asserted on every run: **at least one
adaptive policy strictly dominates the best static fleet** — lower
$/1M tokens at equal-or-better SLO attainment — because a static fleet
sized for the peak idles (and bills) through every trough, while the
autoscaler follows the sinusoid at the cost of a bounded provisioning
lag.

``--smoke`` gates three invariants at CI scale (wired into
scripts/ci.sh): scale-up actually fires under a burst, scale-down
drains retire without losing a single request, and a *disabled*
autoscaler is byte-inert (identical per-token timelines to a spec with
no autoscaler at all).
"""
from __future__ import annotations

import os
import sys

from repro.core.autoscale import AutoscaleSpec
from repro.core.simulator import SimSpec, WorkerSpec, simulate
from repro.core.workload import WorkloadSpec
from repro.explore.sweep import SweepSpec, default_metrics, run_sweep

from benchmarks.common import Bench, RESULTS_DIR, ensure_dir, fmt

MODEL = "llama2-7b"
#: cache-invalidation tag (see SweepSpec.version): bump when the
#: builder, cost model, or autoscaler semantics change meaning
VERSION = "autoscale-v2"
SWEEP_DIR = os.path.join(RESULTS_DIR, "autoscale")

#: streaming SLO folded into the sketches: generous enough that a
#: right-sized fleet attains ~all requests — the comparison is $/1M
#: tokens at equal attainment, not a tail shoot-out
TTFT_SLO, TPOT_SLO = 5.0, 0.5
#: mean arrival rate; the diurnal peak is QPS*(1+AMP), trough
#: QPS*(1-AMP) — sized so the peak needs ~4 A100 workers and the
#: trough ~1
QPS = 14.0
AMP = 0.85
#: full diurnal cycles over the horizon (period is derived from
#: num_requests/QPS so quick and full runs see the same shape); keep
#: the rate slope gentle relative to the ~30s provisioning lag or no
#: reactive policy can scale ahead of the rising edge
N_CYCLES = 3

STATIC = ("static-1", "static-2", "static-3", "static-4")
ADAPTIVE = ("threshold", "target_utilization", "predictive_ema")
CONFIGS = STATIC + ADAPTIVE


def _workload(n_req: int) -> WorkloadSpec:
    horizon = n_req / QPS
    return WorkloadSpec(
        num_requests=n_req, qps=QPS, seed=7, arrival="diurnal",
        diurnal_period=horizon / N_CYCLES, diurnal_amplitude=AMP)


def _autoscale(policy: str, n_req: int) -> AutoscaleSpec:
    """Shared controller settings; only the policy varies across the
    sweep so the Pareto isolates the decision rule.  The control
    interval and cooldown scale with the diurnal period: the loop must
    sample the sinusoid much faster than it moves."""
    period = (n_req / QPS) / N_CYCLES
    return AutoscaleSpec(
        policy=policy, min_replicas=1, max_replicas=4,
        interval=max(1.0, period / 100.0),
        cooldown=max(2.0, period / 60.0),
        queue_high=1.0, queue_low=0.3, util_low=0.25,
        target_util=0.5, ttft_slo=TTFT_SLO, slo_target=0.999)


def build_point(point: dict) -> SimSpec:
    """Module-level so pool workers can unpickle it."""
    cfg, n_req = point["config"], point["n_req"]
    if cfg.startswith("static-"):
        n_workers, autoscale = int(cfg.split("-")[1]), None
    else:
        n_workers, autoscale = 1, _autoscale(cfg, n_req)
    return SimSpec(
        arch=MODEL,
        workers=[WorkerSpec(hw="A100") for _ in range(n_workers)],
        global_policy="least_loaded",
        workload=_workload(n_req),
        retain_requests=False,
        streaming_slo=(TTFT_SLO, TPOT_SLO),
        autoscale=autoscale)


def autoscale_metrics(spec: SimSpec, res) -> dict:
    """default_metrics + SLO attainment + the scaling/billing block.
    The event log and fleet-size series stay out of the row (they are
    lists; the CSV stays flat) — tests read them from Results."""
    row = default_metrics(spec, res)
    st = res.stats
    row["slo_attainment"] = st.n_slo_ok / st.n_finished \
        if st is not None and st.n_finished else float("nan")
    sc = res.scaling_summary()
    for k in ("n_scale_up", "n_scale_down", "fleet_size_min",
              "fleet_size_max", "fleet_size_avg", "fleet_size_final",
              "worker_seconds", "scale_up_lag_s", "billed_cost",
              "cost_per_1m_tokens", "cost_per_1m_prefill_tokens",
              "cost_per_1m_decode_tokens"):
        row[k] = sc[k]
    return row


OBJECTIVES = {"slo_attainment": "max", "cost_per_1m_tokens": "min"}


def best_static(rows) -> dict:
    """The static fleet the adaptive policies must beat: highest SLO
    attainment, ties broken by cheaper $/1M tokens."""
    statics = [r for r in rows if r["config"] in STATIC]
    return max(statics, key=lambda r: (r["slo_attainment"],
                                       -r["cost_per_1m_tokens"]))


def dominating_policies(rows) -> list:
    """Adaptive rows that strictly dominate the best static fleet:
    lower $/1M tokens at equal-or-better SLO attainment."""
    ref = best_static(rows)
    return [r for r in rows
            if r["config"] in ADAPTIVE
            and r["slo_attainment"] >= ref["slo_attainment"]
            and r["cost_per_1m_tokens"] < ref["cost_per_1m_tokens"]]


def run(quick: bool = False, processes: int = 0, force: bool = False):
    n_req = 30_000 if quick else 1_000_000
    sweep = SweepSpec(
        name="autoscale", builder=build_point,
        axes={"config": list(CONFIGS), "n_req": [n_req]},
        metrics=autoscale_metrics, version=VERSION)
    ensure_dir()
    result = run_sweep(sweep, SWEEP_DIR, processes=processes,
                       objectives=OBJECTIVES, force=force, verbose=True)

    b = Bench("autoscale")
    for r in result.rows:
        b.add(config=r["config"], finished=r["finished"],
              slo_attainment=fmt(r["slo_attainment"]),
              cost_per_1m_tokens=fmt(r["cost_per_1m_tokens"], 2),
              fleet_avg=fmt(r["fleet_size_avg"], 2),
              fleet_max=r["fleet_size_max"],
              n_scale_up=r["n_scale_up"],
              n_scale_down=r["n_scale_down"],
              scale_up_lag_s=fmt(r["scale_up_lag_s"], 2),
              billed_cost=fmt(r["billed_cost"], 1),
              p99_ttft=fmt(r["p99_ttft"], 3))

    ref = best_static(result.rows)
    winners = dominating_policies(result.rows)
    assert winners, (
        "no adaptive policy dominated the best static fleet "
        f"({ref['config']}: attain={ref['slo_attainment']:.4f}, "
        f"$/1M={ref['cost_per_1m_tokens']:.2f}) — rows: "
        + "; ".join(
            f"{r['config']}: attain={r['slo_attainment']:.4f}, "
            f"$/1M={r['cost_per_1m_tokens']:.2f}"
            for r in result.rows if r["config"] in ADAPTIVE))
    win = min(winners, key=lambda r: r["cost_per_1m_tokens"])
    saving = 1.0 - win["cost_per_1m_tokens"] / ref["cost_per_1m_tokens"]
    print(f"\nbest static: {ref['config']} "
          f"(attain={ref['slo_attainment']:.4f}, "
          f"$/1M={ref['cost_per_1m_tokens']:.2f})")
    print(f"dominating:  {win['config']} "
          f"(attain={win['slo_attainment']:.4f}, "
          f"$/1M={win['cost_per_1m_tokens']:.2f}, "
          f"saving={saving:.1%})")
    print("\nPareto frontier (attainment max, $/1M min):")
    for r in result.frontier:
        print(f"  {r['config']:>20}: attain={r['slo_attainment']:.4f}  "
              f"$/1M={r['cost_per_1m_tokens']:.2f}  "
              f"fleet_avg={r['fleet_size_avg']:.2f}")
    b.finish(derived=f"{win['config']}_saves_{saving:.0%}_vs_"
                     f"{ref['config']}")
    return result


# ---------------------------------------------------------------------------
# smoke gates (scripts/ci.sh)
# ---------------------------------------------------------------------------
def _sig(res):
    """Byte-comparable per-request timeline signature."""
    return [(r.id, r.t_first_token, r.t_finish, tuple(r.token_times))
            for r in sorted(res.requests, key=lambda r: r.id)]


def _smoke_spec(n_workers: int, autoscale, *, qps: float = 20.0,
                n_req: int = 400, seed: int = 3) -> SimSpec:
    wl = WorkloadSpec(num_requests=n_req, qps=qps, seed=seed,
                      arrival="diurnal", diurnal_period=20.0,
                      diurnal_amplitude=0.9)
    return SimSpec(
        arch=MODEL,
        workers=[WorkerSpec(hw="A100") for _ in range(n_workers)],
        global_policy="least_loaded", workload=wl,
        autoscale=autoscale)


#: fast provisioning for the smoke gates only — the full sweep pays
#: the real ``HardwareSpec.reload_time``
_SMOKE_LAG = 0.5


def smoke_scale_up_under_burst() -> dict:
    """The controller must actually add capacity when the diurnal peak
    arrives, and every request must still finish exactly once."""
    spec = _smoke_spec(1, AutoscaleSpec(
        policy="threshold", min_replicas=1, max_replicas=4,
        interval=1.0, cooldown=2.0, queue_high=2.0,
        reload_time=_SMOKE_LAG))
    res = simulate(spec)
    sc = res.scaling_summary()
    ids = [r.id for r in res.finished]
    assert len(ids) == len(set(ids)) == spec.workload.num_requests, \
        f"lost/duplicated requests: {len(ids)} finished"
    assert sc["n_scale_up"] >= 1, "no scale-up under burst"
    assert sc["fleet_size_max"] > 1, "fleet never grew"
    ready = [e for e in res.scale_events if e.action == "up_ready"]
    assert ready, "scale-ups never became dispatch-eligible"
    print(f"  scale_up_under_burst: n_up={sc['n_scale_up']} "
          f"fleet_max={sc['fleet_size_max']} "
          f"lag={sc['scale_up_lag_s']:.2f}s")
    return {"gate": "scale_up_under_burst",
            "value": sc["n_scale_up"], "threshold": 1}


def smoke_drain_no_loss() -> dict:
    """Scale-down must drain: an over-provisioned fleet under light
    load retires workers without losing a single in-flight request."""
    spec = _smoke_spec(4, AutoscaleSpec(
        policy="threshold", min_replicas=1, max_replicas=4,
        interval=1.0, cooldown=2.0, queue_low=2.0, util_low=0.9,
        reload_time=_SMOKE_LAG), qps=2.0, n_req=200)
    res = simulate(spec)
    sc = res.scaling_summary()
    ids = [r.id for r in res.finished]
    assert len(ids) == len(set(ids)) == spec.workload.num_requests, \
        f"lost/duplicated requests: {len(ids)} finished"
    assert sc["n_scale_down"] >= 1, "no scale-down under light load"
    retired = [e for e in res.scale_events
               if e.action == "down_retired"]
    assert retired, "drains never completed into retirement"
    print(f"  drain_no_loss: n_down={sc['n_scale_down']} "
          f"retired={len(retired)} "
          f"fleet_final={sc['fleet_size_final']}")
    return {"gate": "drain_no_loss",
            "value": sc["n_scale_down"], "threshold": 1}


def smoke_disabled_inertness() -> dict:
    """AutoscaleSpec(enabled=False) must be byte-inert: identical
    per-token timelines to autoscale=None (the golden-pin property,
    also pinned against a frozen JSON in tests/test_autoscale.py)."""
    r0 = simulate(_smoke_spec(2, None))
    r1 = simulate(_smoke_spec(2, AutoscaleSpec(enabled=False)))
    assert _sig(r0) == _sig(r1), \
        "disabled autoscaler perturbed the simulation"
    assert r0.sim_time == r1.sim_time
    assert r1.scale_events is None, \
        "disabled autoscaler emitted scale events"
    print(f"  disabled_inertness: {len(r0.requests)} requests "
          "byte-identical")
    return {"gate": "disabled_inertness",
            "value": len(r0.requests), "threshold": 1}


def main(argv) -> int:
    if "--smoke" in argv:
        ensure_dir()
        b = Bench("autoscale_smoke")
        for gate in (smoke_scale_up_under_burst, smoke_drain_no_loss,
                     smoke_disabled_inertness):
            b.add(**gate())
        b.finish(derived="all_gates_passed")
        print("autoscale smoke: all gates passed")
        return 0
    run(quick="--quick" in argv,
        processes=4 if "--parallel" in argv else 0,
        force="--force" in argv)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
