"""Cache-aware prefix-affinity routing study (docs/ROUTING.md): the
cluster-level TTFT win from routing shared-system-prompt traffic at the
worker already holding the prefix KV, plus the remote-KV-tier fetch
path that replaces re-prefill when the transfer undercuts compute.

The sweep crosses share length x global policy x fleet size at equal
offered load.  A prefix-blind policy (``round_robin``) spreads each
prefix group over every worker, so concurrent same-prefix requests
rarely overlap on a host and each landing re-prefills the system
prompt; ``prefix_affinity`` concentrates a group on its cache-holding
worker (load-aware: it diverts off an overloaded holder and prices a
peer/remote KV fetch against re-prefill compute), so the shared tokens
are prefilled once and then hit.

``--smoke`` runs the CI gates instead (scripts/ci.sh):

* **ttft-win** — at equal load, ``prefix_affinity`` must strictly beat
  prefix-blind ``round_robin`` on P50 TTFT for a shared-prefix
  workload (the paper-level claim of this study);
* **wrapper-noop** — on a workload with *no* shared prefixes,
  ``prefix_affinity(inner=round_robin)`` must be byte-identical to
  plain ``round_robin`` (the policy adds zero perturbation when it has
  nothing to do; the seed-level disabled path is pinned by the golden
  pins in tests/golden/);
* **fault-no-loss** — killing the cache-holding worker mid-run must
  invalidate its registry claims and lose no requests;
* **fetch-attribution** — with attribution on, fetch time must appear
  as its own component and every request's decomposition must still
  sum to its measured latency (conservation to 1e-6).
"""
from __future__ import annotations

import sys

from repro.core.faults import FaultSpec
from repro.core.mem.remote_store import RemoteKVSpec
from repro.core.metrics import percentile
from repro.core.simulator import SimSpec, WorkerSpec, simulate
from repro.core.workload import WorkloadSpec
from repro.obs import ObsSpec

from benchmarks.common import Bench, fmt

#: sweep axes: shared-prefix length (tokens), fleet size
SHARES = (0, 128, 512)
FLEETS = (2, 4)
QUICK_SHARES = (0, 512)
QUICK_FLEETS = (4,)

#: policies compared at equal load; the prefix-blind baselines run
#: without the remote tier (fully routing-unaware)
POLICIES = ("round_robin", "least_loaded", "prefix_affinity")


def _wl(share_len: int, *, n: int = 160, qps: float = 30.0,
        groups: int = 8, seed: int = 5) -> WorkloadSpec:
    return WorkloadSpec(num_requests=n, qps=qps, seed=seed,
                        lengths="fixed", prompt_len=64, output_len=64,
                        shared_prefix_len=share_len,
                        shared_prefix_groups=groups)


def _spec(policy: str, n_workers: int, wl: WorkloadSpec, *,
          remote: bool = False, faults=(), obs=None,
          policy_kw=None) -> SimSpec:
    return SimSpec(
        arch="llama2-7b",
        workers=[WorkerSpec(hw="A100", gpu_mem_util=0.3)
                 for _ in range(n_workers)],
        workload=wl, prefix_sharing=True, global_policy=policy,
        global_policy_kw=policy_kw or {},
        remote_kv=RemoteKVSpec() if remote else None,
        faults=faults, obs=obs)


def _p50_ttft(res) -> float:
    return percentile(res.ttfts(), 50)


def run(quick: bool = False) -> dict:
    """Driver entry point (benchmarks/run.py): the share x policy x
    fleet sweep; returns {(policy, share, fleet): p50_ttft} and asserts
    the headline win at the sweep's largest shared prefix."""
    b = Bench("prefix_routing")
    shares = QUICK_SHARES if quick else SHARES
    fleets = QUICK_FLEETS if quick else FLEETS
    grid = {}
    for fleet in fleets:
        for share in shares:
            wl = _wl(share)
            for policy in POLICIES:
                res = simulate(_spec(policy, fleet, wl,
                                     remote=policy == "prefix_affinity"))
                ro = res.routing_summary()
                p50 = _p50_ttft(res)
                grid[(policy, share, fleet)] = p50
                b.add(policy=policy, share_len=share, fleet=fleet,
                      p50_ttft=fmt(p50, 5),
                      p99_ttft=fmt(percentile(res.ttfts(), 99), 5),
                      throughput=fmt(res.throughput()),
                      hit_rate=fmt(ro["affinity_hit_rate"], 3),
                      fetches=ro["fetches"],
                      fetch_time_s=fmt(ro["fetch_time_s"], 5))
            base = grid[("round_robin", share, fleet)]
            aff = grid[("prefix_affinity", share, fleet)]
            print(f"fleet={fleet} share={share:4d}  p50 TTFT "
                  f"rr={base:.4f}s affinity={aff:.4f}s  "
                  f"({base / aff:.2f}x)")
    share = max(shares)
    for fleet in fleets:
        base = grid[("round_robin", share, fleet)]
        aff = grid[("prefix_affinity", share, fleet)]
        assert aff < base, \
            f"prefix_affinity lost at fleet={fleet}: {aff} >= {base}"
    b.finish(derived=f"p50_ttft_win="
                     f"{grid[('round_robin', share, fleets[-1])] / grid[('prefix_affinity', share, fleets[-1])]:.2f}x")
    return {"grid": grid}


# ---------------------------------------------------------------------------
def smoke_ttft_win() -> None:
    """prefix_affinity must strictly beat prefix-blind round_robin on
    P50 TTFT at equal load, and must actually be routing on affinity
    (not winning by accident)."""
    wl = _wl(512)
    base = simulate(_spec("round_robin", 4, wl))
    aff = simulate(_spec("prefix_affinity", 4, wl, remote=True))
    p_base, p_aff = _p50_ttft(base), _p50_ttft(aff)
    ro = aff.routing_summary()
    assert ro["affinity_hits"] > 0, "affinity never routed warm"
    assert p_aff < p_base, \
        f"no TTFT win: affinity {p_aff:.4f}s >= round_robin {p_base:.4f}s"
    assert len(aff.finished) == len(base.finished), "finished count diverged"
    print(f"ttft-win OK: p50 TTFT {p_base:.4f}s -> {p_aff:.4f}s "
          f"({p_base / p_aff:.2f}x, hit_rate="
          f"{ro['affinity_hit_rate']:.2f}, fetches={ro['fetches']})")


def smoke_wrapper_noop() -> None:
    """With no shared prefixes the wrapper must fall through to its
    inner policy with byte-identical results."""
    wl = _wl(0)
    outs = []
    for policy in ("round_robin", "prefix_affinity"):
        kw = {"inner": "round_robin"} if policy == "prefix_affinity" \
            else None
        res = simulate(_spec(policy, 3, wl, policy_kw=kw))
        outs.append([(r.id, r.t_first_token, r.t_finish)
                     for r in res.requests])
    assert outs[0] == outs[1], \
        "prefix_affinity perturbed a no-shared-prefix workload"
    print("wrapper-noop OK: 160 prefix-free requests byte-identical")


def smoke_fault_no_loss() -> None:
    """Kill a worker mid-run: its registry claims must die with it and
    every request must still finish (re-routed, not lost)."""
    wl = _wl(512, n=120, qps=20.0)
    faults = (FaultSpec(time=2.0, worker=0, kind="fail", duration=3.0),)
    res = simulate(_spec("prefix_affinity", 3, wl, remote=True,
                         faults=faults))
    ro = res.routing_summary()
    assert len(res.finished) == 120, \
        f"lost requests under failure: {len(res.finished)}/120"
    assert ro["registry_invalidations"] > 0, \
        "worker death did not invalidate its registry entries"
    print(f"fault-no-loss OK: 120/120 finished, "
          f"{ro['registry_invalidations']} registry entries invalidated")


def smoke_fetch_attribution() -> None:
    """Fetch time must be attributed as its own component and the
    decomposition must stay conserved (sum == measured, 1e-6)."""
    wl = _wl(512, n=100)
    res = simulate(_spec("prefix_affinity", 4, wl, remote=True,
                         obs=ObsSpec(attribution=True)))
    assert res.routing_summary()["fetch_time_s"] > 0, \
        "no fetches exercised: gate is vacuous"
    bd = res.time_breakdown()
    attributed = bd["ttft_mean"].get("fetch", 0.0) \
        + bd["decode_mean"].get("fetch", 0.0)
    assert attributed > 0, "fetch time missing from the breakdown"
    worst = 0.0
    for r in res.finished:
        f = r.obs.final
        worst = max(worst,
                    abs(sum(f["ttft"].values()) - r.ttft),
                    abs(sum(f["decode"].values())
                        - (r.t_finish - r.t_first_token)))
    assert worst < 1e-6, f"attribution no longer conserved: {worst}"
    print(f"fetch-attribution OK: mean fetch {attributed * 1e3:.3f}ms, "
          f"conservation residual {worst:.2e}")


def main(argv) -> int:
    if "--smoke" in argv:
        smoke_ttft_win()
        smoke_wrapper_noop()
        smoke_fault_no_loss()
        smoke_fetch_attribution()
        return 0
    run(quick="--quick" in argv)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
