"""Paper Fig. 15 / Finding 7: sweep the prefill device's FLOPS, memory
capacity and bandwidth in a disaggregated node — prefill wants FLOPS."""
from __future__ import annotations

from repro.core.simulator import SimSpec, WorkerSpec, simulate
from repro.core.workload import WorkloadSpec

from benchmarks.common import Bench, fmt

TTFT_SLO, MTPOT_SLO = 15.0, 0.3


def max_goodput(prefill_overrides, n_prefill, n_req, rates,
                mem_cap=None):
    peak = 0.0
    workers = [WorkerSpec(hw="A100", role="prefill",
                          hw_overrides=prefill_overrides,
                          mem_cap_override=mem_cap)
               for _ in range(n_prefill)] + \
              [WorkerSpec(hw="A100", role="decode")
               for _ in range(8 - n_prefill)]
    for qps in rates:
        spec = SimSpec(
            arch="llama2-7b", workers=workers, global_policy="disagg",
            workload=WorkloadSpec(num_requests=n_req, qps=qps, seed=0),
            local_policy="continuous", max_batch=256,
            max_batched_tokens=8192)
        res = simulate(spec)
        peak = max(peak, res.slo_goodput(ttft_slo=TTFT_SLO,
                                         mtpot_slo=MTPOT_SLO))
    return peak


def run(n_req: int = 800):
    b = Bench("platform_sweep_fig15")
    # rates chosen to SATURATE the prefill stage (TTFT SLO binds): one
    # A100 prefills ~14k tok/s of ~170-token ShareGPT prompts => ~80 QPS
    rates = (30.0, 60.0, 90.0)
    base_flops = 312e12
    base_bw = 2.039e12
    out = {}
    for n_prefill in (1, 2):
        ref = max_goodput({}, n_prefill, n_req, rates)
        out[(n_prefill, "Ori", 1.0)] = ref
        b.add(config=f"P{n_prefill}-D{8 - n_prefill}", knob="Ori",
              scale=1.0, goodput=fmt(ref), vs_ori=1.0)
        for scale in (0.25, 0.5, 2.0, 4.0):
            gp = max_goodput({"flops": base_flops * scale}, n_prefill,
                             n_req, rates)
            out[(n_prefill, "T", scale)] = gp
            b.add(config=f"P{n_prefill}-D{8 - n_prefill}", knob="T",
                  scale=scale, goodput=fmt(gp), vs_ori=fmt(gp / ref, 3))
        for scale in (0.125, 0.25, 0.5, 2.0, 4.0):
            gp = max_goodput({"mem_bw": base_bw * scale}, n_prefill,
                             n_req, rates)
            out[(n_prefill, "B", scale)] = gp
            b.add(config=f"P{n_prefill}-D{8 - n_prefill}", knob="B",
                  scale=scale, goodput=fmt(gp), vs_ori=fmt(gp / ref, 3))
        for scale in (0.25, 0.5, 2.0, 4.0):
            gp = max_goodput({}, n_prefill, n_req, rates,
                             mem_cap=80e9 * scale)
            out[(n_prefill, "C", scale)] = gp
            b.add(config=f"P{n_prefill}-D{8 - n_prefill}", knob="C",
                  scale=scale, goodput=fmt(gp), vs_ori=fmt(gp / ref, 3))
    # Finding 7: halving FLOPS hurts; halving BW/capacity ~doesn't
    t_half = out[(1, "T", 0.5)] / out[(1, "Ori", 1.0)]
    b_half = out[(1, "B", 0.5)] / out[(1, "Ori", 1.0)]
    c_half = out[(1, "C", 0.5)] / out[(1, "Ori", 1.0)]
    b.finish(derived=f"finding7_half_T={t_half:.2f}_half_B={b_half:.2f}"
                     f"_half_C={c_half:.2f}")
    return out


if __name__ == "__main__":
    run()
