"""Benchmark driver: one module per paper table/figure.

Prints one CSV line per benchmark: ``name,us_per_call,derived`` where
``derived`` carries the reproduced finding. Full row data lands in
results/bench/*.csv.  ``--quick`` shrinks request counts (CI).

The registry is self-checking (``--check-registry``, wired into
scripts/ci.sh): every module in benchmarks/ must either appear in
``benches`` below or be listed in ``NON_BENCHMARKS``, and every module
named in ``SMOKE_GATED`` (the ones scripts/ci.sh runs with ``--smoke``)
must actually expose a ``main`` accepting ``--smoke`` — so adding a
benchmark without registering it, or wiring a smoke gate that silently
does not exist, fails CI instead of silently skipping coverage.
"""
from __future__ import annotations

import argparse
import os
import pkgutil
import sys
import traceback

#: modules in benchmarks/ that are infrastructure, not benchmarks —
#: perf_hillclimb is the §Perf iteration driver (subprocess dry-runs
#: feeding EXPERIMENTS.md), not a table/figure reproduction
NON_BENCHMARKS = {"common", "run", "finalize_docs", "roofline_report",
                  "perf_hillclimb"}
#: benchmarks scripts/ci.sh runs as `--smoke` CI gates; each must expose
#: main(argv) handling "--smoke"
SMOKE_GATED = {"sim_speed", "kv_hierarchy", "parallelism",
               "observability", "chaos_sweep", "hetero_fleet",
               "autoscale", "prefix_routing"}


def discover_modules() -> set:
    """Every importable module name under benchmarks/."""
    here = os.path.dirname(os.path.abspath(__file__))
    return {m.name for m in pkgutil.iter_modules([here])}


def check_registry(registered: set) -> list:
    """Registry drift errors (empty list = OK)."""
    import importlib
    import inspect

    errors = []
    discovered = discover_modules()
    for name in sorted(discovered - registered - NON_BENCHMARKS):
        errors.append(
            f"benchmarks/{name}.py is not registered: add it to the "
            f"benches list in benchmarks/run.py (or to NON_BENCHMARKS "
            f"if it is not a benchmark)")
    for name in sorted(registered - discovered):
        errors.append(f"registered benchmark {name!r} has no module "
                      f"benchmarks/{name}.py")
    for name in sorted(SMOKE_GATED):
        if name not in discovered:
            errors.append(f"SMOKE_GATED benchmark {name!r} has no module "
                          f"benchmarks/{name}.py")
            continue
        mod = importlib.import_module(f"benchmarks.{name}")
        main = getattr(mod, "main", None)
        if not callable(main):
            errors.append(f"benchmarks/{name}.py is SMOKE_GATED but has "
                          f"no main(argv)")
        elif "--smoke" not in inspect.getsource(mod):
            errors.append(f"benchmarks/{name}.py is SMOKE_GATED but its "
                          f"main() does not handle --smoke; the "
                          f"scripts/ci.sh gate would silently no-op")
    return errors


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--check-registry", action="store_true",
                    help="verify benchmark-module registry + smoke "
                         "gates, run nothing")
    args = ap.parse_args(argv)
    q = args.quick

    from benchmarks import (autoscale, batching, chaos_sweep,
                            disagg_ratio, disagg_validation,
                            hardware_sub, hetero_fleet, kv_hierarchy,
                            mem_footprint, memcache, memratio,
                            observability, parallelism, platform_sweep,
                            prefix_routing, sim_speed, spec_decode,
                            tenant_qos, validation)

    benches = [
        ("validation", lambda: validation.run(n_req=20 if q else 40)),
        ("sim_speed", lambda: sim_speed.run(
            request_counts=(10, 20) if q else (20, 40, 60, 80, 100))),
        ("disagg_validation", lambda: disagg_validation.run(
            counts=(8, 16) if q else (10, 20, 40, 60))),
        ("batching", lambda: batching.run(n_req=300 if q else 2000)),
        ("memratio", lambda: memratio.run(n_req=400 if q else 2000)),
        ("disagg_ratio", lambda: disagg_ratio.run(n_req=150 if q else 600)),
        ("hardware_sub", lambda: hardware_sub.run(n_req=150 if q else 500)),
        ("mem_footprint", lambda: mem_footprint.run(
            n_req=300 if q else 1500)),
        ("memcache", lambda: memcache.run(n_req=300 if q else 1200)),
        ("platform_sweep", lambda: platform_sweep.run(
            n_req=200 if q else 800)),
        ("tenant_qos", lambda: tenant_qos.run(quick=q)),
        ("spec_decode", lambda: spec_decode.run(quick=q)),
        ("kv_hierarchy", lambda: kv_hierarchy.run(quick=q)),
        ("parallelism", lambda: parallelism.run(quick=q)),
        ("observability", lambda: observability.run(quick=q)),
        ("chaos_sweep", lambda: chaos_sweep.run(quick=q)),
        ("hetero_fleet", lambda: hetero_fleet.run(quick=q)),
        ("autoscale", lambda: autoscale.run(quick=q)),
        ("prefix_routing", lambda: prefix_routing.run(quick=q)),
    ]
    errors = check_registry({name for name, _ in benches})
    for e in errors:
        print(f"registry FAIL: {e}", file=sys.stderr)
    if errors:
        return 2
    if args.check_registry:
        print(f"registry OK: {len(benches)} benchmarks registered, "
              f"{len(SMOKE_GATED)} smoke-gated "
              f"({', '.join(sorted(SMOKE_GATED))})")
        return 0

    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in benches:
        if only and name not in only:
            continue
        try:
            fn()
        except Exception:                               # noqa: BLE001
            failed += 1
            print(f"{name},ERROR,", file=sys.stdout)
            traceback.print_exc()
    # roofline report appends its own line if artifacts exist
    try:
        from benchmarks import roofline_report
        d = os.path.join(roofline_report.RESULTS, "dryrun_probe")
        if os.path.isdir(d) and os.listdir(d):
            cells = roofline_report.build_table(d)
            md = roofline_report.to_markdown(cells)
            out = os.path.join(roofline_report.RESULTS, "roofline.md")
            with open(out, "w") as f:
                f.write(md + "\n")
            print(f"roofline_report,{len(cells)},cells->results/roofline.md")
    except Exception:                                   # noqa: BLE001
        traceback.print_exc()
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
