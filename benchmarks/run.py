"""Benchmark driver: one module per paper table/figure.

Prints one CSV line per benchmark: ``name,us_per_call,derived`` where
``derived`` carries the reproduced finding. Full row data lands in
results/bench/*.csv.  ``--quick`` shrinks request counts (CI).
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args(argv)
    q = args.quick

    from benchmarks import (batching, disagg_ratio, disagg_validation,
                            hardware_sub, kv_hierarchy, mem_footprint,
                            memcache, memratio, platform_sweep, sim_speed,
                            spec_decode, tenant_qos, validation)

    benches = [
        ("validation", lambda: validation.run(n_req=20 if q else 40)),
        ("sim_speed", lambda: sim_speed.run(
            request_counts=(10, 20) if q else (20, 40, 60, 80, 100))),
        ("disagg_validation", lambda: disagg_validation.run(
            counts=(8, 16) if q else (10, 20, 40, 60))),
        ("batching", lambda: batching.run(n_req=300 if q else 2000)),
        ("memratio", lambda: memratio.run(n_req=400 if q else 2000)),
        ("disagg_ratio", lambda: disagg_ratio.run(n_req=150 if q else 600)),
        ("hardware_sub", lambda: hardware_sub.run(n_req=150 if q else 500)),
        ("mem_footprint", lambda: mem_footprint.run(
            n_req=300 if q else 1500)),
        ("memcache", lambda: memcache.run(n_req=300 if q else 1200)),
        ("platform_sweep", lambda: platform_sweep.run(
            n_req=200 if q else 800)),
        ("tenant_qos", lambda: tenant_qos.run(quick=q)),
        ("spec_decode", lambda: spec_decode.run(quick=q)),
        ("kv_hierarchy", lambda: kv_hierarchy.run(quick=q)),
    ]
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in benches:
        if only and name not in only:
            continue
        try:
            fn()
        except Exception:                               # noqa: BLE001
            failed += 1
            print(f"{name},ERROR,", file=sys.stdout)
            traceback.print_exc()
    # roofline report appends its own line if artifacts exist
    try:
        import os
        from benchmarks import roofline_report
        d = os.path.join(roofline_report.RESULTS, "dryrun_probe")
        if os.path.isdir(d) and os.listdir(d):
            cells = roofline_report.build_table(d)
            md = roofline_report.to_markdown(cells)
            out = os.path.join(roofline_report.RESULTS, "roofline.md")
            with open(out, "w") as f:
                f.write(md + "\n")
            print(f"roofline_report,{len(cells)},cells->results/roofline.md")
    except Exception:                                   # noqa: BLE001
        traceback.print_exc()
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
