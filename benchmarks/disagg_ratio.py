"""Paper Fig. 11 / Finding 3: best prefill:decode device ratio on an
8-GPU node across input/output length grids, llama2-7b and opt-13b."""
from __future__ import annotations

from repro.core.simulator import SimSpec, WorkerSpec, simulate
from repro.core.workload import WorkloadSpec

from benchmarks.common import Bench, fmt

LENGTHS = ((128, 128), (128, 512), (128, 1024),
           (512, 128), (512, 512), (1024, 128))
RATIOS = ((1, 7), (2, 6), (3, 5), (4, 4))
TTFT_SLO, MTPOT_SLO = 15.0, 0.3


def best_ratio_for(arch, in_len, out_len, n_req, rates):
    best = (0.0, None)
    rows = []
    for p, d in RATIOS:
        workers = [WorkerSpec(hw="A100", role="prefill")
                   for _ in range(p)] + \
                  [WorkerSpec(hw="A100", role="decode") for _ in range(d)]
        peak = 0.0
        for qps in rates:
            spec = SimSpec(
                arch=arch, workers=workers, global_policy="disagg",
                workload=WorkloadSpec(num_requests=n_req, qps=qps, seed=0,
                                      lengths="fixed", prompt_len=in_len,
                                      output_len=out_len),
                local_policy="continuous", max_batch=256,
                max_batched_tokens=8192)
            res = simulate(spec)
            gp = res.slo_goodput(ttft_slo=TTFT_SLO, mtpot_slo=MTPOT_SLO)
            peak = max(peak, gp)
        rows.append((p, d, peak))
        if peak > best[0]:
            best = (peak, (p, d))
    return best, rows


def run(n_req: int = 600):
    b = Bench("disagg_ratio_fig11")
    finding3 = {}
    for arch in ("llama2-7b", "opt-13b"):
        for in_len, out_len in LENGTHS:
            rates = (4.0, 8.0, 16.0, 24.0)
            (peak, (p, d)), rows = best_ratio_for(arch, in_len, out_len,
                                                  n_req, rates)
            for pp, dd, gp in rows:
                b.add(arch=arch, in_len=in_len, out_len=out_len,
                      prefill=pp, decode=dd, peak_goodput=fmt(gp))
            finding3[(arch, in_len, out_len)] = (p, d)
    # Finding 3: longer outputs shift the best ratio toward more decode
    # capacity per prefill device... the paper states optimal ratio depends
    # primarily on output length; report the trend.
    short_o = finding3[("llama2-7b", 128, 128)]
    long_o = finding3[("llama2-7b", 128, 1024)]
    b.finish(derived=f"best_P/D_128out={short_o[0]}/{short_o[1]}"
                     f"_1024out={long_o[0]}/{long_o[1]}")
    return finding3


if __name__ == "__main__":
    run()
