"""Paper Figs. 4/5 (validation protocol): TokenSim vs the *real* engine.

The paper validates against vLLM on an A100; this container has neither,
so the ground truth is our real JAX paged-KV engine (same scheduler and
memory classes — see DESIGN.md §validation).  Protocol is the paper's:
sweep QPS, compare throughput and P50/P99/max latency, and the latency
CDF; report per-metric error and the geometric-mean error.
"""
from __future__ import annotations

import math

import jax

from repro.configs import get_smoke_config
from repro.core.mem.block_manager import BlockManager, MemoryConfig
from repro.core.metrics import Results, percentile
from repro.core.simulator import SimSpec, Simulation, WorkerSpec
from repro.core.workload import WorkloadSpec, generate
from repro.models import model_zoo as zoo
from repro.serving.engine import EngineConfig, ServingEngine

from benchmarks.common import Bench, fmt

NUM_BLOCKS, BLOCK_SIZE, MAX_BATCH = 160, 8, 8


def run_engine_with_arrivals(model, params, wl: WorkloadSpec):
    """Real engine with Poisson arrivals tracked on its virtual clock."""
    reqs = generate(wl)
    eng = ServingEngine(model, params, EngineConfig(
        num_blocks=NUM_BLOCKS, block_size=BLOCK_SIZE, max_batch=MAX_BATCH,
        max_pages_per_seq=24))
    pending = list(reqs)
    while pending or eng.has_work:
        while pending and pending[0].arrival_time <= eng.clock + 1e-12:
            eng.add_request(pending.pop(0))
        rec = eng.step()
        if rec is None:
            if pending:
                eng.clock = pending[0].arrival_time
                continue
            break
    return reqs, eng


def run_sim(wl: WorkloadSpec, samples):
    cfg = get_smoke_config("llama2-7b")
    spec = SimSpec(arch=cfg, workers=[WorkerSpec(hw="CPU")], workload=wl,
                   local_policy="continuous", max_batch=MAX_BATCH,
                   backend="tabular", backend_samples=samples,
                   block_size=BLOCK_SIZE)
    sim = Simulation(spec)
    sim.workers[0].mem = BlockManager(MemoryConfig(
        num_blocks=NUM_BLOCKS, block_size=BLOCK_SIZE,
        kv_bytes_per_token=1.0))
    return sim.run()


def rel_err(a, b):
    return abs(a - b) / max(abs(b), 1e-12)


def run(n_req: int = 40):
    b = Bench("validation_fig4_5")
    cfg = get_smoke_config("llama2-7b")
    model = zoo.build(cfg)
    params = zoo.init_params(model, jax.random.key(0))

    # calibration pass (separate seed — no train/test leakage).
    # Run twice: the first pass warms the jit cache so measured walls are
    # compute, not compilation.
    cal_wl = WorkloadSpec(num_requests=n_req, qps=0.0, seed=123,
                          max_prompt_len=64, max_output_len=24)
    run_engine_with_arrivals(model, params, cal_wl)          # warm-up
    _, cal_eng = run_engine_with_arrivals(model, params, cal_wl)
    samples = [(r.mix, r.wall) for r in cal_eng.records]

    errs = []
    for qps_scale in (0.5, 1.0, 2.0):
        # express QPS relative to single-engine capacity
        cap = len(cal_eng.finished) / max(cal_eng.clock, 1e-9)
        qps = cap * qps_scale
        wl = WorkloadSpec(num_requests=n_req, qps=qps, seed=7,
                          max_prompt_len=64, max_output_len=24)
        reqs, eng = run_engine_with_arrivals(model, params, wl)
        real = Results(requests=reqs, sim_time=eng.clock)

        sim = run_sim(wl, samples)
        for name, rv, sv in [
                ("throughput", real.throughput(), sim.throughput()),
                ("p50", percentile(real.latencies(), 50),
                 percentile(sim.latencies(), 50)),
                ("p99", percentile(real.latencies(), 99),
                 percentile(sim.latencies(), 99)),
                ("max", max(real.latencies()), max(sim.latencies()))]:
            e = rel_err(sv, rv)
            errs.append(e)
            b.add(qps=fmt(qps, 2), metric=name, real=fmt(rv),
                  sim=fmt(sv), rel_err=fmt(e))
        # CDF alignment (Fig. 5): max vertical gap between CDFs
        rl = sorted(real.latencies())
        sl = sorted(sim.latencies())
        gap = max(abs(a - b) / max(rl[-1], 1e-9)
                  for a, b in zip(rl, sl))
        b.add(qps=fmt(qps, 2), metric="cdf_max_gap", real=0.0,
              sim=0.0, rel_err=fmt(gap))

    geo = math.exp(sum(math.log(max(e, 1e-6)) for e in errs) / len(errs))
    b.finish(derived=f"geomean_err={geo * 100:.2f}%")
    return geo


if __name__ == "__main__":
    run()
