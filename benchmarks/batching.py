"""Paper Fig. 9 / Finding 1: static vs continuous batching normalized
latency as request rate grows, at several batch-size caps."""
from __future__ import annotations

from repro.core.metrics import percentile
from repro.core.simulator import SimSpec, WorkerSpec, simulate
from repro.core.workload import WorkloadSpec

from benchmarks.common import Bench, fmt

RATES = (2.0, 4.0, 8.0, 12.0, 16.0, 20.0)
BATCHES = (8, 16, 32, 0)           # 0 => "inf" (no limit)
N_REQ = 2000                        # paper uses 50k; scaled for CPU time


def run(n_req: int = N_REQ):
    b = Bench("batching_fig9")
    finding1 = []
    for policy in ("static", "continuous"):
        for cap in BATCHES:
            for qps in RATES:
                spec = SimSpec(
                    arch="llama2-7b", workers=[WorkerSpec(hw="A100")],
                    workload=WorkloadSpec(num_requests=n_req, qps=qps,
                                          seed=0),
                    local_policy=policy,
                    max_batch=cap if cap else 4096,
                    max_batched_tokens=4096)
                res = simulate(spec)
                norm = res.normalized_latencies()
                row = dict(policy=policy,
                           batch="inf" if cap == 0 else cap, qps=qps,
                           norm_lat_mean=fmt(sum(norm) / len(norm)),
                           norm_lat_p99=fmt(percentile(norm, 99)),
                           p99=fmt(res.latency_stats()["p99"]),
                           throughput=fmt(res.throughput()))
                b.add(**row)
                if cap == 16:
                    finding1.append((policy, qps, row["norm_lat_mean"]))
    # Finding 1 check: at the highest rate continuous << static
    s = [x for p, q, x in finding1 if p == "static" and q == RATES[-1]][0]
    c = [x for p, q, x in finding1 if p == "continuous" and q == RATES[-1]][0]
    b.finish(derived=f"finding1_static/continuous_norm_lat={s / c:.1f}x")
    return s / c


if __name__ == "__main__":
    run()
