"""Hierarchical KV memory study (docs/MEMORY.md): the swap-vs-recompute
preemption crossover and shared-prefix copy-on-write capacity gains.

Two experiments:

1. **Preemption-mode crossover** — a memory-starved A100 worker under
   backlog, sweeping context length x PCIe bandwidth x
   ``preemption_mode``.  Swap wins where the PCIe round trip undercuts
   re-prefill compute (long contexts, fast links); recompute wins for
   short contexts on slow links, where scattered per-block DMA overhead
   and low transfer efficiency dominate.  Preemptions are rare but
   catastrophic at long context (each one forfeits a whole-context
   re-prefill) and frequent but cheap at short context — the sweep
   reports end-to-end throughput, so both frequency and unit cost count.

2. **Shared-prefix capacity** — a shared-1k-token-system-prompt
   workload on a small-memory worker; prefix sharing stores the system
   prompt's KV once instead of per request, raising the max concurrent
   batch (the effective capacity) by >= 1.5x.

``--smoke`` runs the CI gates instead (scripts/ci.sh): (a) swap mode
must not deadlock at ~95% memory pressure — every request finishes even
when the device is nearly full and victims cycle through host DRAM; and
(b) with no overlapping prefixes, prefix sharing must be a no-op —
results byte-identical to a non-sharing run.
"""
from __future__ import annotations

import sys

from repro.configs import get_config
from repro.core.costmodel.operators import kv_bytes_per_token
from repro.core.simulator import SimSpec, WorkerSpec, simulate
from repro.core.workload import WorkloadSpec, generate

from benchmarks.common import Bench, fmt

KVT = kv_bytes_per_token(get_config("llama2-7b"), 2)  # ~0.52 MB/token

CTXS = (64, 256, 1024, 2048)
PCIE = (4e9, 16e9, 64e9)
#: the corners the crossover assertion uses; --quick sweeps only these
QUICK_CTXS = (64, 2048)
QUICK_PCIE = (4e9, 64e9)


def _pressure_spec(ctx: int, pcie: float, mode: str, *, n: int = 48,
                   out: int = 256, slots: int = 12) -> SimSpec:
    """A worker whose KV pool holds ~``slots`` prompts of ``ctx`` tokens
    plus a few outputs of decode headroom: admission over-commits, so
    decode growth preempts continuously."""
    kv_budget = (slots * ctx + 4 * out) * KVT
    cap = (13.5e9 + kv_budget) / 0.9      # params + KV at 0.9 util
    wl = WorkloadSpec(num_requests=n, qps=0.0, seed=0, lengths="fixed",
                      prompt_len=ctx, output_len=out)
    return SimSpec(
        arch="llama2-7b",
        workers=[WorkerSpec(hw="A100", mem_cap_override=cap,
                            hw_overrides={"pcie_bw": pcie})],
        workload=wl, preemption_mode=mode)


def run_crossover(b: Bench, ctxs=CTXS, pcies=PCIE) -> dict:
    grid = {}
    for ctx in ctxs:
        for pcie in pcies:
            tput = {}
            for mode in ("recompute", "swap"):
                res = simulate(_pressure_spec(ctx, pcie, mode))
                m = res.memory_summary()
                tput[mode] = res.throughput()
                b.add(exp="crossover", ctx=ctx, pcie_gbps=pcie / 1e9,
                      mode=mode, throughput=fmt(res.throughput()),
                      p99=fmt(res.latency_stats()["p99"]),
                      preempts=m["preempts"],
                      swap_preempts=m["swap_preempts"],
                      swap_gb=fmt(m.get("swap_bytes_out", 0.0) / 1e9, 2))
            grid[(ctx, pcie)] = tput["swap"] / tput["recompute"]
            print(f"ctx={ctx:5d} pcie={pcie/1e9:4.0f}GB/s  "
                  f"swap/recompute throughput = {grid[(ctx, pcie)]:.3f}  "
                  f"-> {'swap' if grid[(ctx, pcie)] > 1 else 'recompute'}")
    # the classic crossover: swap wins at long context / fast PCIe,
    # recompute wins at short context on a slow link
    long_fast = grid[(max(ctxs), max(pcies))]
    short_slow = grid[(min(ctxs), min(pcies))]
    assert long_fast > 1.0, \
        f"swap should win at long ctx/fast PCIe: {long_fast}"
    assert short_slow < 1.0, \
        f"recompute should win at short ctx/slow PCIe: {short_slow}"
    return grid


def _capacity_spec(share: bool, *, n: int = 64, prefix: int = 1000,
                   private: int = 64, out: int = 64) -> SimSpec:
    # pool sized to ~12 full (non-shared) requests
    kv_budget = 12 * (prefix + private + out) * KVT
    cap = (13.5e9 + kv_budget) / 0.9
    wl = WorkloadSpec(num_requests=n, qps=0.0, seed=0, lengths="fixed",
                      prompt_len=private, output_len=out,
                      shared_prefix_len=prefix, shared_prefix_groups=1)
    return SimSpec(
        arch="llama2-7b",
        workers=[WorkerSpec(hw="A100", mem_cap_override=cap)],
        workload=wl, prefix_sharing=share)


def run_capacity() -> float:
    b = Bench("kv_hierarchy_capacity")
    batch = {}
    for share in (False, True):
        res = simulate(_capacity_spec(share))
        batch[share] = max(s.n_running for s in res.worker_mem[0])
        m = res.memory_summary()
        b.add(sharing=int(share), max_batch=batch[share],
              throughput=fmt(res.throughput()),
              p99=fmt(res.latency_stats()["p99"]),
              shared_tokens=m["shared_tokens"],
              prefix_hit_rate=fmt(m["prefix_hit_rate"], 3))
    gain = batch[True] / batch[False]
    print(f"max concurrent batch: shared={batch[True]} "
          f"unshared={batch[False]}  gain={gain:.2f}x")
    assert gain >= 1.5, f"prefix sharing capacity gain {gain:.2f}x < 1.5x"
    b.finish(derived=f"prefix_capacity={gain:.2f}x")
    return gain


# ---------------------------------------------------------------------------
def smoke_no_deadlock() -> None:
    """Swap mode at ~95% device-memory pressure must drain the workload
    (victims cycle device -> host -> device without wedging)."""
    spec = _pressure_spec(256, 16e9, "swap", n=64, out=256, slots=6)
    res = simulate(spec)
    assert len(res.finished) == 64, \
        f"swap mode deadlocked: {len(res.finished)}/64 finished"
    nb = res.mem_stats[0]["num_blocks"]
    peak = max(s.used_blocks for s in res.worker_mem[0])
    assert peak / nb >= 0.9, f"pressure too low to be a gate: {peak}/{nb}"
    m = res.memory_summary()
    assert m["swap_preempts"] > 0, "no swaps exercised"
    print(f"no-deadlock OK: 64/64 finished at "
          f"{100 * peak / nb:.0f}% peak pressure, "
          f"{m['swap_preempts']} swap preemptions")


def smoke_sharing_noop() -> None:
    """With no overlapping prefixes, prefix sharing must change nothing:
    per-request timings byte-identical to a non-sharing run."""
    wl = WorkloadSpec(num_requests=100, qps=20.0, seed=11,
                      shared_prefix_len=256,
                      shared_prefix_groups=1_000_000)
    ids = [r.prefix_id for r in generate(wl)]
    assert len(set(ids)) == len(ids), "seed 11 produced overlapping prefixes"
    outs = []
    for share in (False, True):
        res = simulate(SimSpec(
            arch="llama2-7b",
            workers=[WorkerSpec(hw="A100", gpu_mem_util=0.3)],
            workload=wl, prefix_sharing=share))
        outs.append([(r.id, r.t_first_token, r.t_finish)
                     for r in res.requests])
    assert outs[0] == outs[1], "sharing changed a non-overlapping workload"
    print("sharing-noop OK: 100 disjoint-prefix requests byte-identical")


def run(quick: bool = False) -> dict:
    """Driver entry point (benchmarks/run.py): crossover sweep +
    capacity study; ``quick`` restricts the sweep to the asserted
    corner configurations."""
    b = Bench("kv_hierarchy")
    grid = run_crossover(b, ctxs=QUICK_CTXS if quick else CTXS,
                         pcies=QUICK_PCIE if quick else PCIE)
    best = max(grid.values())
    worst = min(grid.values())
    b.finish(derived=f"swap_best={best:.3f}x_recompute_best="
                     f"{1 / worst:.3f}x")
    gain = run_capacity()
    return {"grid": grid, "capacity_gain": gain}


def main(argv) -> int:
    if "--smoke" in argv:
        smoke_no_deadlock()
        smoke_sharing_noop()
        return 0
    run(quick="--quick" in argv)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
