"""Paper Fig. 12 / Finding 4: substituting decode devices (V100, GDDR6-AiM
PIM, low-FLOPS A100) in a disaggregated 8-slot node; cost-efficiency."""
from __future__ import annotations

from repro.core.costmodel.hardware import HARDWARE
from repro.core.simulator import SimSpec, WorkerSpec, simulate
from repro.core.workload import WorkloadSpec

from benchmarks.common import Bench, fmt

TTFT_SLO, MTPOT_SLO = 15.0, 0.3


def max_goodput(workers, n_req, rates):
    peak = 0.0
    for qps in rates:
        spec = SimSpec(
            arch="llama2-7b", workers=workers, global_policy="disagg",
            workload=WorkloadSpec(num_requests=n_req, qps=qps, seed=0,
                                  lengths="fixed", prompt_len=128,
                                  output_len=256),
            local_policy="continuous", max_batch=256,
            max_batched_tokens=8192)
        res = simulate(spec)
        peak = max(peak, res.slo_goodput(ttft_slo=TTFT_SLO,
                                         mtpot_slo=MTPOT_SLO))
    return peak


def run(n_req: int = 500):
    b = Bench("hardware_sub_fig12")
    rates = (4.0, 8.0, 16.0)
    results = {}
    for n_prefill in (1, 2):
        n_dec = 8 - n_prefill
        for dec_hw in ("A100", "V100", "G6-AiM", "A100-low"):
            workers = [WorkerSpec(hw="A100", role="prefill")
                       for _ in range(n_prefill)] + \
                      [WorkerSpec(hw=dec_hw, role="decode")
                       for _ in range(n_dec)]
            gp = max_goodput(workers, n_req, rates)
            cost = n_prefill * 1.0 + n_dec * HARDWARE[dec_hw].price
            results[(n_prefill, dec_hw)] = (gp, cost)
            b.add(prefill=n_prefill, decode=n_dec, decode_hw=dec_hw,
                  goodput=fmt(gp), cost_a100=fmt(cost, 2),
                  goodput_per_cost=fmt(gp / cost))
    # Finding 4: PIM decode ~ A100 decode at roughly half the cost
    a = results[(1, "A100")]
    g = results[(1, "G6-AiM")]
    ratio = g[0] / a[0]
    b.finish(derived=f"finding4_pim_vs_a100_goodput={ratio:.2f}"
                     f"_cost={g[1] / a[1]:.2f}")
    return results


if __name__ == "__main__":
    run()
