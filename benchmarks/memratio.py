"""Paper Fig. 10 / Finding 2: capping the GPU-memory ratio available to
*new* requests reduces preemptions and lifts SLO goodput."""
from __future__ import annotations

from repro.core.simulator import SimSpec, WorkerSpec, simulate
from repro.core.workload import WorkloadSpec

from benchmarks.common import Bench, fmt

RATIOS = (1.0, 0.95, 0.9, 0.85, 0.8, 0.7)
RATES = (10.0, 14.0, 18.0, 22.0)
TTFT_SLO, MTPOT_SLO = 15.0, 0.3


def run(n_req: int = 2000):
    b = Bench("memratio_fig10")
    best = {}
    for ratio in RATIOS:
        for qps in RATES:
            spec = SimSpec(
                arch="llama2-7b",
                # constrain memory so the knob binds (paper uses longer
                # outputs; we shrink the pool instead of 50k requests)
                workers=[WorkerSpec(hw="A100", gpu_mem_util=0.45,
                                    max_mem_ratio=ratio)],
                workload=WorkloadSpec(num_requests=n_req, qps=qps, seed=0),
                local_policy="continuous", max_batch=512,
                max_batched_tokens=4096)
            res = simulate(spec)
            decode_gp = res.slo_goodput(mtpot_slo=MTPOT_SLO)
            both_gp = res.slo_goodput(ttft_slo=TTFT_SLO,
                                      mtpot_slo=MTPOT_SLO)
            b.add(ratio=ratio, qps=qps,
                  decode_slo_goodput=fmt(decode_gp),
                  both_slo_goodput=fmt(both_gp),
                  preempt_rate=fmt(res.preemption_rate()),
                  throughput=fmt(res.throughput()))
            best.setdefault(qps, []).append((both_gp, ratio))
    # Finding 2: at high load the best ratio is < 1.0
    top = {q: max(v)[1] for q, v in best.items()}
    hi = RATES[-1]
    b.finish(derived=f"finding2_best_ratio_at_{hi}qps={top[hi]}")
    return top


if __name__ == "__main__":
    run()
