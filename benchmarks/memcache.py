"""Paper Fig. 14 / Finding 6: multi-round KV memory pool vs recompute,
P99 latency across input/output lengths and request rates."""
from __future__ import annotations

from repro.core.mem.memory_pool import PoolConfig
from repro.core.simulator import SimSpec, WorkerSpec, simulate
from repro.core.workload import WorkloadSpec

from benchmarks.common import Bench, fmt

LENGTHS = ((32, 32), (64, 64), (128, 64), (128, 128))
RATES = (4.0, 8.0, 12.0, 16.0)


def run(n_req: int = 1200):
    b = Bench("memcache_fig14")
    gains = {}
    for in_len, out_len in LENGTHS:
        for pool_on in (False, True):
            for qps in RATES:
                wl = WorkloadSpec(
                    num_requests=n_req, qps=qps, seed=0, lengths="fixed",
                    prompt_len=in_len, output_len=out_len,
                    multi_round_frac=0.5, rounds_min=2, rounds_max=7)
                spec = SimSpec(
                    arch="llama2-7b", workers=[WorkerSpec(hw="A100")],
                    workload=wl, local_policy="continuous",
                    max_batch=256, max_batched_tokens=4096,
                    pool=PoolConfig() if pool_on else None)
                res = simulate(spec)
                p99 = res.latency_stats()["p99"]
                b.add(in_len=in_len, out_len=out_len,
                      pool=int(pool_on), qps=qps, p99=fmt(p99),
                      throughput=fmt(res.throughput()),
                      hit_rate=fmt(res.pool_stats["hit_rate"])
                      if res.pool_stats else 0.0)
                gains[(in_len, out_len, pool_on, qps)] = p99
    # Finding 6: cache helps most around out=64; always >= parity
    q = RATES[-1]
    r64 = gains[(64, 64, False, q)] / gains[(64, 64, True, q)]
    r32 = gains[(32, 32, False, q)] / gains[(32, 32, True, q)]
    b.finish(derived=f"finding6_p99_speedup_out64={r64:.2f}x_out32={r32:.2f}x")
    return gains


if __name__ == "__main__":
    run()
