#!/usr/bin/env bash
# CI gate: docs check + benchmark-registry check + tier-1 tests
# (collection errors fail fast) + smokes, so "suite no longer collects",
# "docs link rotted", "gate silently unwired" and "demo broke" all
# surface before merge.
#
#   bash scripts/ci.sh            # full gate (what .github/workflows runs)
#   bash scripts/ci.sh --quick    # docs + registry + pytest only
#                                 # (fast local pre-commit loop)
#
# Prints a per-stage timing summary at the end.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

QUICK=0
[[ "${1:-}" == "--quick" ]] && QUICK=1

STAGE_NAMES=()
STAGE_SECS=()

stage() {
    local name="$1"; shift
    echo "== ${name} =="
    local t0=$SECONDS
    "$@"
    STAGE_NAMES+=("$name")
    STAGE_SECS+=($((SECONDS - t0)))
    echo "${name} OK"
}

summary() {
    echo
    echo "== stage timing summary =="
    local i total=0
    for i in "${!STAGE_NAMES[@]}"; do
        printf '  %-42s %4ds\n' "${STAGE_NAMES[$i]}" "${STAGE_SECS[$i]}"
        total=$((total + STAGE_SECS[$i]))
    done
    printf '  %-42s %4ds\n' "total" "$total"
}
trap summary EXIT

stage "docs: links + module docstrings" \
    python scripts/check_docs.py

stage "benchmarks: registry + smoke-gate wiring" \
    env PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/run.py --check-registry

if [[ "$QUICK" == "1" ]]; then
    # the slow marker (pytest.ini) drops the multi-second JAX model
    # tests from the local pre-commit loop; the full gate runs them all
    stage "tier-1: pytest (-m 'not slow')" \
        python -m pytest -x -q -m "not slow"
    echo "(--quick: skipping smokes)"
    exit 0
fi

stage "tier-1: pytest" \
    python -m pytest -x -q

# the example output (not the stage banner) goes to /dev/null, so the
# redirect lives inside the staged command
stage "smoke: examples/multi_tenant.py (<30s)" \
    bash -c 'timeout 30 python examples/multi_tenant.py > /dev/null'

stage "smoke: examples/speculative.py (<30s)" \
    bash -c 'timeout 30 python examples/speculative.py > /dev/null'

# outer timeout covers the exact-mode baseline + the streaming run +
# the observability overhead gate (interleaved timed rounds, with a
# retry); the benchmark's internal 60s wall budget covers the
# streaming run only
stage "smoke: sim_speed streaming scale + obs overhead gates" \
    env PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    timeout 420 python benchmarks/sim_speed.py --smoke

# (a) swap preemption must drain a 95%-memory-pressure workload without
# deadlocking; (b) prefix sharing must be byte-identical to non-shared
# when no prefixes overlap (docs/MEMORY.md)
stage "smoke: kv_hierarchy memory gates" \
    env PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    timeout 120 python benchmarks/kv_hierarchy.py --smoke

# parallelism gates (docs/PARALLELISM.md): TP2/NVLink beats single GPU,
# pipeline bubble fraction matches (pp-1)/(m+pp-1) within 2%,
# ParallelSpec(1,1,1) is byte-identical to the pre-parallelism model,
# and the TP-vs-PP crossover corners hold
stage "smoke: parallelism crossover + bubble gates" \
    env PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    timeout 300 python benchmarks/parallelism.py --smoke

# chaos/availability gates (docs/RELIABILITY.md): zero-fault chaos is
# byte-identical to the baseline, no request is lost or duplicated
# under stochastic failures, availability improves monotonically with
# replicas, host-surviving KV beats re-prefill on TTFT, and the same
# seed reproduces identical availability numbers
stage "smoke: chaos availability + no-loss gates" \
    env PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    timeout 300 python benchmarks/chaos_sweep.py --smoke

# heterogeneity gates (docs/HETEROGENEITY.md): the split A100-prefill +
# L4-decode fleet beats homogeneous 4xA100 on $/1M generated tokens at
# equal SLO attainment, and model routing never cross-dispatches on a
# two-model fleet (per-model summaries populated)
stage "smoke: hetero fleet economics + routing gates" \
    env PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    timeout 120 python benchmarks/hetero_fleet.py --smoke

# autoscaling gates (docs/AUTOSCALING.md): the closed-loop controller
# adds capacity under a diurnal burst, scale-down drains retire
# without losing a request, and a disabled autoscaler is byte-inert
# (identical timelines to a spec with no autoscaler at all)
stage "smoke: autoscale burst + drain + inertness gates" \
    env PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    timeout 300 python benchmarks/autoscale.py --smoke

# observability gates (docs/OBSERVABILITY.md): exported Chrome trace
# validates (spans nest, durations sum to latency within 1e-6),
# attribution conserves in exact and streaming drop-mode, time series
# stays bounded; leaves results/obs/trace.json for the CI artifact
stage "smoke: observability trace + attribution gates" \
    env PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    timeout 120 python benchmarks/observability.py --smoke
