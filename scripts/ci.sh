#!/usr/bin/env bash
# CI gate: docs check + benchmark-registry check + lint + tier-1 tests
# (collection errors fail fast) + smokes, so "suite no longer collects",
# "docs link rotted", "gate silently unwired" and "demo broke" all
# surface before merge.
#
#   bash scripts/ci.sh            # full gate, serial (all lanes)
#   bash scripts/ci.sh --quick    # docs + registry + lint + fast pytest
#                                 # (fast local pre-commit loop)
#   bash scripts/ci.sh core      # lane: docs + registry + lint + pytest
#   bash scripts/ci.sh smokes-1  # lane: examples + sim_speed + kv mem
#   bash scripts/ci.sh smokes-2  # lane: parallelism + chaos + routing
#   bash scripts/ci.sh smokes-3  # lane: hetero + autoscale + obs
#
# The lanes partition the full gate with no overlap (core runs the
# whole test suite once; each smoke runs in exactly one lane), so the
# .github/workflows/ci.yml job matrix fans them out in parallel and
# the wall-clock cost is the slowest lane, not the serial sum.
#
# Prints a per-stage timing summary at the end (and appends it to
# $GITHUB_STEP_SUMMARY as markdown when running under GitHub Actions).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

QUICK=0
LANE="all"
case "${1:-}" in
    --quick) QUICK=1 ;;
    core|smokes-1|smokes-2|smokes-3) LANE="$1" ;;
    "") ;;
    *) echo "usage: ci.sh [--quick|core|smokes-1|smokes-2|smokes-3]" >&2
       exit 2 ;;
esac

want() { [[ "$LANE" == "all" || "$LANE" == "$1" ]]; }

STAGE_NAMES=()
STAGE_SECS=()

stage() {
    local name="$1"; shift
    echo "== ${name} =="
    local t0=$SECONDS
    "$@"
    STAGE_NAMES+=("$name")
    STAGE_SECS+=($((SECONDS - t0)))
    echo "${name} OK"
}

summary() {
    echo
    echo "== stage timing summary (lane: ${LANE}) =="
    local i total=0
    for i in "${!STAGE_NAMES[@]}"; do
        printf '  %-42s %4ds\n' "${STAGE_NAMES[$i]}" "${STAGE_SECS[$i]}"
        total=$((total + STAGE_SECS[$i]))
    done
    printf '  %-42s %4ds\n' "total" "$total"
    if [[ -n "${GITHUB_STEP_SUMMARY:-}" ]]; then
        {
            echo "### ci.sh stage timing (lane: ${LANE})"
            echo
            echo "| stage | seconds |"
            echo "| --- | ---: |"
            for i in "${!STAGE_NAMES[@]}"; do
                echo "| ${STAGE_NAMES[$i]} | ${STAGE_SECS[$i]} |"
            done
            echo "| **total** | **${total}** |"
        } >> "$GITHUB_STEP_SUMMARY"
    fi
}
trap summary EXIT

# ---- core lane: static checks + the full test suite -----------------------
if want core; then
    stage "docs: links + module docstrings" \
        python scripts/check_docs.py

    stage "benchmarks: registry + smoke-gate wiring" \
        env PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
        python benchmarks/run.py --check-registry

    # lint config lives in pyproject.toml; CI installs ruff via
    # requirements.txt, local environments without it skip gracefully
    # (the GitHub gate still enforces it)
    if python -m ruff --version > /dev/null 2>&1; then
        stage "lint: ruff check" \
            python -m ruff check .
    else
        echo "== lint: ruff check =="
        echo "(ruff not installed locally: skipped; CI enforces it)"
    fi

    if [[ "$QUICK" == "1" ]]; then
        # the slow marker (pytest.ini) drops the multi-second JAX model
        # tests from the local pre-commit loop; the full gate runs them
        stage "tier-1: pytest (-m 'not slow')" \
            python -m pytest -x -q -m "not slow"
        echo "(--quick: skipping smokes)"
        exit 0
    fi

    stage "tier-1: pytest" \
        python -m pytest -x -q
fi

# ---- smokes-1: examples + simulator-speed + memory-hierarchy gates --------
if want smokes-1; then
    # the example output (not the stage banner) goes to /dev/null, so
    # the redirect lives inside the staged command
    stage "smoke: examples/multi_tenant.py (<30s)" \
        bash -c 'timeout 30 python examples/multi_tenant.py > /dev/null'

    stage "smoke: examples/speculative.py (<30s)" \
        bash -c 'timeout 30 python examples/speculative.py > /dev/null'

    # outer timeout covers the exact-mode baseline + the streaming run +
    # the observability overhead gate (interleaved timed rounds, with a
    # retry); the benchmark's internal 60s wall budget covers the
    # streaming run only
    stage "smoke: sim_speed streaming scale + obs overhead gates" \
        env PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
        timeout 420 python benchmarks/sim_speed.py --smoke

    # (a) swap preemption must drain a 95%-memory-pressure workload
    # without deadlocking; (b) prefix sharing must be byte-identical to
    # non-shared when no prefixes overlap (docs/MEMORY.md)
    stage "smoke: kv_hierarchy memory gates" \
        env PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
        timeout 120 python benchmarks/kv_hierarchy.py --smoke
fi

# ---- smokes-2: parallelism + chaos + cache-aware routing gates ------------
if want smokes-2; then
    # parallelism gates (docs/PARALLELISM.md): TP2/NVLink beats single
    # GPU, pipeline bubble fraction matches (pp-1)/(m+pp-1) within 2%,
    # ParallelSpec(1,1,1) is byte-identical to the pre-parallelism
    # model, and the TP-vs-PP crossover corners hold
    stage "smoke: parallelism crossover + bubble gates" \
        env PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
        timeout 300 python benchmarks/parallelism.py --smoke

    # chaos/availability gates (docs/RELIABILITY.md): zero-fault chaos
    # is byte-identical to the baseline, no request is lost or
    # duplicated under stochastic failures, availability improves
    # monotonically with replicas, host-surviving KV beats re-prefill
    # on TTFT, and the same seed reproduces identical availability
    stage "smoke: chaos availability + no-loss gates" \
        env PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
        timeout 300 python benchmarks/chaos_sweep.py --smoke

    # cache-aware routing gates (docs/ROUTING.md): prefix_affinity
    # strictly beats prefix-blind round_robin on P50 TTFT at equal
    # load, the wrapper is byte-inert on prefix-free workloads, worker
    # death invalidates registry claims without losing requests, and
    # fetch time attributes as its own conserved component
    stage "smoke: prefix routing TTFT + registry gates" \
        env PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
        timeout 300 python benchmarks/prefix_routing.py --smoke
fi

# ---- smokes-3: heterogeneity + autoscaling + observability gates ----------
if want smokes-3; then
    # heterogeneity gates (docs/HETEROGENEITY.md): the split
    # A100-prefill + L4-decode fleet beats homogeneous 4xA100 on $/1M
    # generated tokens at equal SLO attainment, and model routing never
    # cross-dispatches on a two-model fleet
    stage "smoke: hetero fleet economics + routing gates" \
        env PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
        timeout 120 python benchmarks/hetero_fleet.py --smoke

    # autoscaling gates (docs/AUTOSCALING.md): the closed-loop
    # controller adds capacity under a diurnal burst, scale-down drains
    # retire without losing a request, and a disabled autoscaler is
    # byte-inert
    stage "smoke: autoscale burst + drain + inertness gates" \
        env PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
        timeout 300 python benchmarks/autoscale.py --smoke

    # observability gates (docs/OBSERVABILITY.md): exported Chrome
    # trace validates (spans nest, durations sum to latency within
    # 1e-6), attribution conserves in exact and streaming drop-mode,
    # time series stays bounded; leaves results/obs/trace.json for the
    # CI artifact
    stage "smoke: observability trace + attribution gates" \
        env PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
        timeout 120 python benchmarks/observability.py --smoke
fi
