#!/usr/bin/env bash
# CI gate: tier-1 tests (collection errors fail fast) + a multi-tenant
# smoke, so "suite no longer collects" and "tenancy demo broke" both
# surface before merge.
#
#   bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== smoke: examples/multi_tenant.py (<30s) =="
timeout 30 python examples/multi_tenant.py > /dev/null
echo "multi-tenant smoke OK"
