#!/usr/bin/env bash
# CI gate: docs check + tier-1 tests (collection errors fail fast) +
# smokes, so "suite no longer collects", "docs link rotted" and "demo
# broke" all surface before merge.
#
#   bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== docs: links + module docstrings =="
python scripts/check_docs.py

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== smoke: examples/multi_tenant.py (<30s) =="
timeout 30 python examples/multi_tenant.py > /dev/null
echo "multi-tenant smoke OK"

echo "== smoke: examples/speculative.py (<30s) =="
timeout 30 python examples/speculative.py > /dev/null
echo "speculative-decoding smoke OK"

# outer timeout covers the exact-mode baseline + the streaming run;
# the benchmark's internal 60s wall budget covers the streaming run only
echo "== smoke: sim_speed streaming scale gate (10k requests) =="
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    timeout 240 python benchmarks/sim_speed.py --smoke
echo "sim-speed streaming smoke OK"

# (a) swap preemption must drain a 95%-memory-pressure workload without
# deadlocking; (b) prefix sharing must be byte-identical to non-shared
# when no prefixes overlap (docs/MEMORY.md)
echo "== smoke: kv_hierarchy memory gates =="
PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}" \
    timeout 120 python benchmarks/kv_hierarchy.py --smoke
echo "kv-hierarchy smoke OK"
