"""Docs CI gate: internal markdown links must resolve, every
benchmark/example module must carry a docstring, and every registered
policy / workload kind must be documented.

Checks:
  1. every relative link in docs/*.md and README.md points at an
     existing file/directory; ``#anchor`` fragments must match a
     heading slug (GitHub-style) in the target file,
  2. every ``benchmarks/*.py`` and ``examples/*.py`` has a module
     docstring (they are the runnable documentation of the repo),
  3. every alias accepted by ``make_global_scheduler`` /
     ``make_local_scheduler`` and every ``WorkloadSpec.lengths`` /
     ``WorkloadSpec.arrival`` kind appears as a code-span in
     docs/POLICIES.md or docs/WORKLOADS.md — new registry entries
     without docs fail CI (doc-drift guard),
  4. every ``SimSpec.preemption_mode``, every pool eviction policy and
     every ``HARDWARE`` entry appears as a code-span in docs/MEMORY.md
     (same doc-drift guard for the memory subsystem),
  5. every ``ParallelSpec`` field and every ``CLUSTERS`` / ``LINKS``
     hardware entry appears as a code-span in docs/PARALLELISM.md —
     new parallelism knobs or topology presets without docs fail CI,
  6. every ``HOOK_POINTS`` breakpoint, attribution ``COMPONENTS``
     name, trace ``SPAN_PHASES`` name and time-series ``TS_FIELDS``
     column appears as a code-span in docs/OBSERVABILITY.md — new
     observability surface without docs fails CI,
  7. every fault kind (``FAULT_KINDS``) and every
     ``Results.availability_summary()`` field
     (``AVAILABILITY_FIELDS``) appears as a code-span in
     docs/RELIABILITY.md — new chaos surface without docs fails CI,
  8. the ``model_routed`` policy and every
     ``Results.model_summary()`` key (``MODEL_SUMMARY_FIELDS``)
     appears as a code-span in docs/HETEROGENEITY.md — new
     multi-model surface without docs fails CI,
  9. every autoscaling policy (``AUTOSCALE_POLICIES``), scale action
     (``SCALE_ACTIONS``) and ``Results.scaling_summary()`` field
     (``SCALING_SUMMARY_FIELDS``) appears as a code-span in
     docs/AUTOSCALING.md — new autoscaler surface without docs
     fails CI,
  10. the ``prefix_affinity`` policy and every
     ``Results.routing_summary()`` field (``ROUTING_SUMMARY_FIELDS``)
     appears as a code-span in docs/ROUTING.md — new cache-aware
     routing surface without docs fails CI.

Run:  python scripts/check_docs.py        (exits non-zero on failure)
"""
from __future__ import annotations

import ast
import glob
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

# [text](target) — excluding images and in-code spans is overkill here;
# fenced code blocks are stripped before matching
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def strip_code_blocks(text: str) -> str:
    return re.sub(r"```.*?```", "", text, flags=re.DOTALL)


def heading_slugs(path: str) -> set:
    """GitHub-style anchor slugs for every heading in a markdown file."""
    slugs = set()
    with open(path) as f:
        text = strip_code_blocks(f.read())
    for h in HEADING_RE.findall(text):
        h = re.sub(r"`([^`]*)`", r"\1", h)           # unwrap code spans
        slug = re.sub(r"[^\w\- ]", "", h.lower()).strip()
        slugs.add(re.sub(r"\s+", "-", slug))
    return slugs


def check_links(md_path: str) -> list:
    errors = []
    with open(md_path) as f:
        text = strip_code_blocks(f.read())
    base = os.path.dirname(md_path)
    for target in LINK_RE.findall(text):
        if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, mailto:, …
            continue
        target, _, frag = target.partition("#")
        dest = md_path if not target else \
            os.path.normpath(os.path.join(base, target))
        if target and not os.path.exists(dest):
            errors.append(f"{os.path.relpath(md_path, ROOT)}: broken link "
                          f"-> {target}")
            continue
        if frag and dest.endswith(".md"):
            if frag not in heading_slugs(dest):
                errors.append(f"{os.path.relpath(md_path, ROOT)}: anchor "
                              f"#{frag} not found in "
                              f"{os.path.relpath(dest, ROOT)}")
    return errors


def check_module_docstrings(pattern: str) -> list:
    errors = []
    for py in sorted(glob.glob(os.path.join(ROOT, pattern))):
        with open(py) as f:
            tree = ast.parse(f.read(), filename=py)
        if not ast.get_docstring(tree):
            errors.append(f"{os.path.relpath(py, ROOT)}: missing module "
                          f"docstring")
    return errors


def check_registry_docs() -> list:
    """Every policy alias and workload kind must be documented as a
    `code span` in docs/POLICIES.md or docs/WORKLOADS.md."""
    from repro.core.sched.global_sched import GLOBAL_POLICIES
    from repro.core.sched.local import LOCAL_POLICIES
    from repro.core.workload import ARRIVAL_KINDS, LENGTH_KINDS

    errors = []
    text = ""
    for name in ("POLICIES.md", "WORKLOADS.md"):
        path = os.path.join(ROOT, "docs", name)
        if not os.path.exists(path):
            errors.append(f"docs/{name}: missing (registry doc coverage "
                          f"needs it)")
            continue
        with open(path) as f:
            text += f.read()
    groups = [("global policy", sorted(GLOBAL_POLICIES)),
              ("local policy", sorted(LOCAL_POLICIES)),
              ("length model", LENGTH_KINDS),
              ("arrival kind", ARRIVAL_KINDS)]
    for what, names in groups:
        for n in names:
            # accept `name` and the quoted-literal form `"name"`
            if f"`{n}`" not in text and f'`"{n}"`' not in text:
                errors.append(f"{what} `{n}` not documented in "
                              f"docs/POLICIES.md or docs/WORKLOADS.md")
    return errors


def check_memory_docs() -> list:
    """Every preemption mode, pool eviction policy and HARDWARE entry
    must be documented as a `code span` in docs/MEMORY.md."""
    from repro.core.costmodel.hardware import HARDWARE
    from repro.core.mem.memory_pool import EVICTION_KINDS
    from repro.core.mem.swap import PREEMPTION_MODES

    errors = []
    path = os.path.join(ROOT, "docs", "MEMORY.md")
    if not os.path.exists(path):
        return ["docs/MEMORY.md: missing (memory-registry doc coverage "
                "needs it)"]
    with open(path) as f:
        text = f.read()
    groups = [("preemption mode", sorted(PREEMPTION_MODES)),
              ("pool eviction policy", sorted(EVICTION_KINDS)),
              ("HARDWARE entry", sorted(HARDWARE))]
    for what, names in groups:
        for n in names:
            if f"`{n}`" not in text and f'`"{n}"`' not in text:
                errors.append(f"{what} `{n}` not documented in "
                              f"docs/MEMORY.md")
    return errors


def check_parallelism_docs() -> list:
    """Every ParallelSpec knob and every cluster/link topology preset
    must be documented as a `code span` in docs/PARALLELISM.md."""
    import dataclasses

    from repro.core.comm import LINKS
    from repro.core.costmodel.hardware import CLUSTERS, ParallelSpec

    errors = []
    path = os.path.join(ROOT, "docs", "PARALLELISM.md")
    if not os.path.exists(path):
        return ["docs/PARALLELISM.md: missing (parallelism doc coverage "
                "needs it)"]
    with open(path) as f:
        text = f.read()
    fields = [f.name for f in dataclasses.fields(ParallelSpec)]
    groups = [("ParallelSpec field", fields),
              ("CLUSTERS entry", sorted(CLUSTERS)),
              ("LINKS entry", sorted(LINKS))]
    for what, names in groups:
        for n in names:
            if f"`{n}`" not in text and f'`"{n}"`' not in text:
                errors.append(f"{what} `{n}` not documented in "
                              f"docs/PARALLELISM.md")
    return errors


def check_observability_docs() -> list:
    """Every hook point, attribution component, trace span phase and
    time-series field must be documented as a `code span` in
    docs/OBSERVABILITY.md."""
    from repro.core.breakpoints import HOOK_POINTS
    from repro.obs import COMPONENTS, SPAN_PHASES, TS_FIELDS

    errors = []
    path = os.path.join(ROOT, "docs", "OBSERVABILITY.md")
    if not os.path.exists(path):
        return ["docs/OBSERVABILITY.md: missing (observability doc "
                "coverage needs it)"]
    with open(path) as f:
        text = f.read()
    groups = [("hook point", HOOK_POINTS),
              ("attribution component", COMPONENTS),
              ("trace span phase", SPAN_PHASES),
              ("time-series field", TS_FIELDS)]
    for what, names in groups:
        for n in names:
            if f"`{n}`" not in text and f'`"{n}"`' not in text:
                errors.append(f"{what} `{n}` not documented in "
                              f"docs/OBSERVABILITY.md")
    return errors


def check_reliability_docs() -> list:
    """Every fault kind and every availability-summary field must be
    documented as a `code span` in docs/RELIABILITY.md."""
    from repro.core.faults import FAULT_KINDS
    from repro.core.metrics import AVAILABILITY_FIELDS

    errors = []
    path = os.path.join(ROOT, "docs", "RELIABILITY.md")
    if not os.path.exists(path):
        return ["docs/RELIABILITY.md: missing (reliability doc coverage "
                "needs it)"]
    with open(path) as f:
        text = f.read()
    groups = [("fault kind", FAULT_KINDS),
              ("availability field", AVAILABILITY_FIELDS)]
    for what, names in groups:
        for n in names:
            if f"`{n}`" not in text and f'`"{n}"`' not in text:
                errors.append(f"{what} `{n}` not documented in "
                              f"docs/RELIABILITY.md")
    return errors


def check_heterogeneity_docs() -> list:
    """The model-routing policy and every per-model summary key must be
    documented as a `code span` in docs/HETEROGENEITY.md."""
    from repro.core.metrics import MODEL_SUMMARY_FIELDS

    errors = []
    path = os.path.join(ROOT, "docs", "HETEROGENEITY.md")
    if not os.path.exists(path):
        return ["docs/HETEROGENEITY.md: missing (multi-model doc "
                "coverage needs it)"]
    with open(path) as f:
        text = f.read()
    groups = [("routing policy", ["model_routed"]),
              ("model_summary field", MODEL_SUMMARY_FIELDS)]
    for what, names in groups:
        for n in names:
            if f"`{n}`" not in text and f'`"{n}"`' not in text:
                errors.append(f"{what} `{n}` not documented in "
                              f"docs/HETEROGENEITY.md")
    return errors


def check_autoscaling_docs() -> list:
    """Every autoscaling policy, scale action and scaling-summary
    field must be documented as a `code span` in docs/AUTOSCALING.md."""
    from repro.core.autoscale import AUTOSCALE_POLICIES, SCALE_ACTIONS
    from repro.core.metrics import SCALING_SUMMARY_FIELDS

    errors = []
    path = os.path.join(ROOT, "docs", "AUTOSCALING.md")
    if not os.path.exists(path):
        return ["docs/AUTOSCALING.md: missing (autoscaling doc "
                "coverage needs it)"]
    with open(path) as f:
        text = f.read()
    groups = [("autoscaling policy", AUTOSCALE_POLICIES),
              ("scale action", SCALE_ACTIONS),
              ("scaling_summary field", SCALING_SUMMARY_FIELDS)]
    for what, names in groups:
        for n in names:
            if f"`{n}`" not in text and f'`"{n}"`' not in text:
                errors.append(f"{what} `{n}` not documented in "
                              f"docs/AUTOSCALING.md")
    return errors


def check_routing_docs() -> list:
    """The prefix-affinity policy and every routing-summary field must
    be documented as a `code span` in docs/ROUTING.md."""
    from repro.core.metrics import ROUTING_SUMMARY_FIELDS

    errors = []
    path = os.path.join(ROOT, "docs", "ROUTING.md")
    if not os.path.exists(path):
        return ["docs/ROUTING.md: missing (cache-aware routing doc "
                "coverage needs it)"]
    with open(path) as f:
        text = f.read()
    groups = [("routing policy", ["prefix_affinity"]),
              ("routing_summary field", ROUTING_SUMMARY_FIELDS)]
    for what, names in groups:
        for n in names:
            if f"`{n}`" not in text and f'`"{n}"`' not in text:
                errors.append(f"{what} `{n}` not documented in "
                              f"docs/ROUTING.md")
    return errors


def main() -> int:
    errors = []
    docs = sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))
    if not docs:
        errors.append("docs/: no markdown files found")
    for md in docs + [os.path.join(ROOT, "README.md")]:
        errors.extend(check_links(md))
    errors.extend(check_module_docstrings("benchmarks/*.py"))
    errors.extend(check_module_docstrings("examples/*.py"))
    errors.extend(check_registry_docs())
    errors.extend(check_memory_docs())
    errors.extend(check_parallelism_docs())
    errors.extend(check_observability_docs())
    errors.extend(check_reliability_docs())
    errors.extend(check_heterogeneity_docs())
    errors.extend(check_autoscaling_docs())
    errors.extend(check_routing_docs())
    for e in errors:
        print(f"docs-check FAIL: {e}")
    if not errors:
        n = len(docs) + 1
        print(f"docs-check OK: {n} markdown files, links + anchors resolve, "
              f"all benchmarks/examples have module docstrings, all "
              f"policies/workload kinds and memory/parallelism/"
              f"observability/reliability/heterogeneity/autoscaling/"
              f"routing registries documented")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
