"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

    PYTHONPATH=src python examples/train_100m.py --steps 300

Checkpoints land in /tmp/repro_100m; re-running resumes automatically
(fault-tolerant restart path).
"""
import argparse

import jax

from repro.configs.base import ArchConfig, DENSE
from repro.models import model_zoo as zoo
from repro.training.data import DataConfig
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import TrainConfig, Trainer

CFG_100M = ArchConfig(
    name="lm-100m", family=DENSE, num_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab_size=32000,
    tie_embeddings=True, norm="rmsnorm", act="silu")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_100m")
    args = ap.parse_args()

    model = zoo.build(CFG_100M)
    trainer = Trainer(
        model,
        TrainConfig(opt=AdamWConfig(lr=6e-4, warmup_steps=30,
                                    total_steps=args.steps),
                    microbatches=2, checkpoint_dir=args.ckpt,
                    checkpoint_every=50, log_every=10),
        DataConfig(vocab_size=CFG_100M.vocab_size, seq_len=args.seq_len,
                   global_batch=args.batch, seed=0),
        init_key=jax.random.key(0))
    print(f"params: {zoo.param_count(trainer.params):,} "
          f"(~{zoo.param_count(trainer.params) / 1e6:.0f}M), "
          f"resuming from step {trainer.step}")
    trainer.run(args.steps - trainer.step)
    print("done:", trainer.history[-1])


if __name__ == "__main__":
    main()
