"""Multi-tenant QoS demo: three API tiers sharing one A100 cluster.

A free tier (rate-limited, sheddable), a pro tier and an enterprise
tier share two workers under weighted-fair queuing; the gateway
enforces each tier's token bucket and inflight cap, and the report
shows per-tenant latency, SLO attainment, goodput and fairness.

    PYTHONPATH=src python examples/multi_tenant.py
"""
from repro.core import SimSpec, TenantSpec, WorkerSpec, simulate
from repro.core.tenancy import ENTERPRISE, FREE, PRO
from repro.core.workload import WorkloadSpec


def main():
    tenants = [
        TenantSpec("free", FREE,
                   WorkloadSpec(num_requests=300, qps=30.0, seed=0)),
        TenantSpec("pro", PRO,
                   WorkloadSpec(num_requests=200, qps=10.0, seed=1)),
        TenantSpec("enterprise", ENTERPRISE,
                   WorkloadSpec(num_requests=100, qps=4.0, seed=2)),
    ]
    spec = SimSpec(
        arch="llama2-7b",
        workers=[WorkerSpec(hw="A100") for _ in range(2)],
        global_policy="wfq",
        local_policy="continuous",
        max_batch=128, max_batched_tokens=4096,
        tenants=tenants)
    res = simulate(spec)

    print(f"simulated {len(res.requests)} requests from "
          f"{len(tenants)} tenants in {res.wall_time:.2f}s wall "
          f"({res.sim_time:.1f}s simulated)")
    cols = ("n_finished", "n_rejected", "token_tps", "ttft_p50",
            "ttft_p99", "latency_p99", "queue_delay_mean",
            "slo_attainment", "goodput_rps")
    print(f"\n{'tenant':12s} " + " ".join(f"{c:>16s}" for c in cols))
    for tid, row in res.tenant_summary().items():
        print(f"{tid:12s} " + " ".join(f"{row[c]:16.3f}" for c in cols))

    s = res.summary()
    print(f"\naggregate: {s['throughput_rps']:.2f} req/s, "
          f"{s['n_rejected']} rejected at the gateway")
    print(f"fairness (Jain): raw={s['fairness_jain']:.3f}  "
          f"weight-normalized={s['fairness_jain_weighted']:.3f}")
    print("admission:", res.admission_stats)


if __name__ == "__main__":
    main()
