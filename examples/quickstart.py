"""Quickstart: simulate an 8xA100 vLLM-style cluster in seconds.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import SimSpec, WorkerSpec, simulate
from repro.core.workload import WorkloadSpec


def main():
    spec = SimSpec(
        arch="llama2-7b",
        workers=[WorkerSpec(hw="A100") for _ in range(8)],
        workload=WorkloadSpec(num_requests=5000, qps=60.0, seed=0),
        global_policy="least_loaded",
        local_policy="continuous",
        max_batch=256, max_batched_tokens=4096)
    res = simulate(spec)

    s = res.summary(ttft_slo=15.0, mtpot_slo=0.3)
    print("simulated", len(res.finished), "requests in",
          f"{res.wall_time:.2f}s wall ({res.sim_time:.1f}s simulated)")
    for k in ("throughput_rps", "latency_p50", "latency_p99",
              "goodput_rps", "preempt_rate"):
        print(f"  {k:16s} = {s[k]:.4f}")

    print("\nlatency CDF (P, seconds):")
    for lat, p in res.latency_cdf(10):
        print(f"  {p:4.1f}  {lat:8.3f}")


if __name__ == "__main__":
    main()
