"""Observability demo: trace a memory-pressured TP=2 cluster.

Runs a mixed prefill/decode workload on two tensor-parallel A100
workers whose KV pool is deliberately undersized, so decode growth
forces swap preemptions (host offload) alongside normal batching.
With ``ObsSpec.full()`` enabled the run exports:

* ``results/obs/example_trace.json`` — Chrome trace-event JSON; open
  it in https://ui.perfetto.dev or ``chrome://tracing`` to see
  per-request lifecycle spans and per-worker iteration slices.
* ``results/obs/example_timeseries.csv`` — queue depth, batch size,
  KV utilization, tokens/s ... sampled at a fixed sim-time interval.

and prints the latency-attribution table (``Results.explain()``)
decomposing TTFT and TPOT into components.

    PYTHONPATH=src python examples/observability.py
"""
import json
import os

from repro.configs import get_config
from repro.core import SimSpec, WorkerSpec, simulate
from repro.core.costmodel.operators import kv_bytes_per_token, param_bytes
from repro.core.workload import WorkloadSpec
from repro.obs import ObsSpec, validate_chrome_trace

OUT_DIR = os.path.join("results", "obs")


def build_spec() -> SimSpec:
    # KV pool sized for ~10 prompts plus a little decode headroom:
    # admission over-commits and decode growth swaps requests to host
    # (the benchmarks/kv_hierarchy.py pressure recipe, on 2 workers).
    # Both params and KV shard across tp=2, so size the cap from the
    # per-shard byte counts or the pool comes out 2x too roomy.
    cfg, tp = get_config("llama2-7b"), 2
    kvt = kv_bytes_per_token(cfg, 2, tp)
    ctx, out = 1024, 192
    kv_budget = (10 * ctx + 4 * out) * kvt
    cap = (param_bytes(cfg, 2, tp) + kv_budget) / 0.9
    wl = WorkloadSpec(num_requests=64, qps=0.0, seed=0, lengths="fixed",
                      prompt_len=ctx, output_len=out)
    return SimSpec(
        arch="llama2-7b",
        workers=[WorkerSpec(hw="A100", tp=tp, mem_cap_override=cap)
                 for _ in range(2)],
        workload=wl,
        local_policy="continuous",
        preemption_mode="swap",
        obs=ObsSpec.full(sample_interval=0.5))


def main():
    res = simulate(build_spec())
    os.makedirs(OUT_DIR, exist_ok=True)

    trace_path = os.path.join(OUT_DIR, "example_trace.json")
    ts_path = os.path.join(OUT_DIR, "example_timeseries.csv")
    res.export_trace(trace_path)
    res.export_timeseries(ts_path)

    with open(trace_path) as f:
        data = json.load(f)
    errors = validate_chrome_trace(data)
    assert not errors, errors

    mem = res.memory_summary()
    print(f"simulated {len(res.finished)} requests in "
          f"{res.wall_time:.2f}s wall ({res.sim_time:.1f}s simulated), "
          f"{mem['swap_preempts']} swap preemptions")
    print(f"trace:      {trace_path}  "
          f"({len(data['traceEvents'])} events, validated)")
    print(f"timeseries: {ts_path}  "
          f"({len(res.timeseries.rows())} rows)")
    print("\nlatency attribution (Results.explain()):\n")
    print(res.explain())


if __name__ == "__main__":
    main()
