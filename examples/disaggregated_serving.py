"""Disaggregated prefill/decode exploration (paper §IV-C).

Sweeps the P:D split of an 8-accelerator node and picks the best split
for a workload — then swaps the decode fleet to GDDR6-AiM PIM devices to
reproduce the cost-efficiency observation (Finding 4).

    PYTHONPATH=src python examples/disaggregated_serving.py
"""
from repro.core import SimSpec, WorkerSpec, simulate
from repro.core.costmodel.hardware import HARDWARE
from repro.core.workload import WorkloadSpec


def goodput(workers, qps=20.0):
    spec = SimSpec(
        arch="llama2-7b", workers=workers, global_policy="disagg",
        workload=WorkloadSpec(num_requests=2000, qps=qps, seed=0,
                              lengths="fixed", prompt_len=256,
                              output_len=128),
        local_policy="continuous", max_batch=256, max_batched_tokens=8192)
    return simulate(spec).slo_goodput(ttft_slo=15.0, mtpot_slo=0.3)


def main():
    print("P:D split sweep (8x A100):")
    best = (0, None)
    for p in (1, 2, 3, 4):
        ws = [WorkerSpec(hw="A100", role="prefill")] * p + \
             [WorkerSpec(hw="A100", role="decode")] * (8 - p)
        gp = goodput(ws)
        print(f"  P{p}-D{8 - p}: goodput {gp:.2f} req/s")
        if gp > best[0]:
            best = (gp, p)
    gp_a100, p = best
    print(f"best split: P{p}-D{8 - p}")

    ws_pim = [WorkerSpec(hw="A100", role="prefill")] * p + \
             [WorkerSpec(hw="G6-AiM", role="decode")] * (8 - p)
    gp_pim = goodput(ws_pim)
    cost_a = p + (8 - p) * HARDWARE["A100"].price
    cost_p = p + (8 - p) * HARDWARE["G6-AiM"].price
    print(f"A100 decode fleet : {gp_a100:.2f} req/s at cost {cost_a:.1f}")
    print(f"PIM  decode fleet : {gp_pim:.2f} req/s at cost {cost_p:.1f}")
    print(f"-> {gp_pim / gp_a100:.2f}x goodput at "
          f"{cost_p / cost_a:.2f}x cost (Finding 4)")


if __name__ == "__main__":
    main()
