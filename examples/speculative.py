"""Speculative decoding demo: a 0.5B draft proposing for a 7B target.

Runs the same low-occupancy workload with and without speculation and
prints the acceptance/effective-tokens metrics next to latency.

    PYTHONPATH=src python examples/speculative.py
"""
from repro.core import (AcceptanceModel, SimSpec, SpecDecodeSpec, WorkerSpec,
                        simulate)
from repro.core.workload import WorkloadSpec


def main():
    wl = WorkloadSpec(num_requests=64, qps=0.0, seed=0,
                      lengths="fixed", prompt_len=256, output_len=128)
    base = dict(arch="llama2-7b", workers=[WorkerSpec(hw="A100")],
                workload=wl, max_batch=4, max_batched_tokens=4096)

    off = simulate(SimSpec(**base))
    on = simulate(SimSpec(**base, spec_decode=SpecDecodeSpec(
        draft_arch="qwen2-0.5b", lookahead=4,
        acceptance=AcceptanceModel(kind="geometric", rate=0.85, decay=0.95))))

    for name, res in (("baseline", off), ("speculative", on)):
        s = res.summary()
        line = (f"{name:12s} tok/s={s['throughput_tps']:8.1f} "
                f"latency_p50={s['latency_p50']:.3f}s "
                f"latency_p99={s['latency_p99']:.3f}s")
        if "spec_steps" in s:
            line += (f"  acceptance={s['spec_acceptance_rate']:.2f} "
                     f"tokens/step={s['spec_eff_tokens_per_step']:.2f}")
        print(line)
    print(f"\nspeedup: {on.token_throughput() / off.token_throughput():.2f}x "
          f"token throughput at low batch occupancy")


if __name__ == "__main__":
    main()
