"""Serve a small model with batched requests on the REAL engine
(paged KV + continuous batching), then verify the simulator predicts the
engine's behavior — the paper's core loop, end to end.

    PYTHONPATH=src python examples/serve_smoke.py
"""
import jax

from repro.configs import get_smoke_config
from repro.core.mem.block_manager import BlockManager, MemoryConfig
from repro.core.metrics import Results
from repro.core.simulator import SimSpec, Simulation, WorkerSpec
from repro.core.workload import WorkloadSpec, generate
from repro.models import model_zoo as zoo
from repro.serving.engine import EngineConfig, ServingEngine


def main():
    cfg = get_smoke_config("llama2-7b")
    model = zoo.build(cfg)
    params = zoo.init_params(model, jax.random.key(0))

    wl = WorkloadSpec(num_requests=24, qps=0.0, seed=0,
                      max_prompt_len=48, max_output_len=16)
    reqs = generate(wl)

    eng = ServingEngine(model, params, EngineConfig(
        num_blocks=160, block_size=8, max_batch=6, max_pages_per_seq=16))
    for r in reqs:
        eng.add_request(r)
    eng.run()
    real = Results(requests=reqs, sim_time=eng.clock)
    print(f"real engine: {len(eng.finished)} requests, "
          f"{len(eng.records)} iterations, "
          f"{real.throughput():.2f} req/s (virtual)")
    sample = reqs[0]
    print(f"  e.g. request 0: {sample.prompt_len} prompt tokens -> "
          f"{eng.tokens_by_req[0][:8]}... ({sample.output_len} tokens)")

    # simulator with the engine-calibrated cost model
    spec = SimSpec(arch=cfg, workers=[WorkerSpec(hw="CPU")], workload=wl,
                   local_policy="continuous", max_batch=6,
                   backend="tabular",
                   backend_samples=[(r.mix, r.wall) for r in eng.records],
                   block_size=8)
    sim = Simulation(spec)
    sim.workers[0].mem = BlockManager(MemoryConfig(
        num_blocks=160, block_size=8, kv_bytes_per_token=1.0))
    res = sim.run()
    print(f"simulator  : {len(res.finished)} requests, "
          f"{sim.workers[0].iterations} iterations, "
          f"{res.throughput():.2f} req/s")
    err = abs(res.throughput() - real.throughput()) / real.throughput()
    print(f"throughput error: {err * 100:.2f}%")


if __name__ == "__main__":
    main()
