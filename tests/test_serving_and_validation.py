"""Real engine correctness + the paper's validation protocol in miniature:
the simulator (same scheduler/memory classes, tabular-calibrated cost)
must match the real engine structurally (exact batch traces) and
temporally (small error on throughput/latency)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.core.metrics import Results
from repro.core.simulator import SimSpec, Simulation, WorkerSpec
from repro.core.workload import WorkloadSpec, generate
from repro.models import model_zoo as zoo
from repro.serving.engine import EngineConfig, ServingEngine


@pytest.fixture(scope="module")
def small_engine():
    cfg = get_smoke_config("llama2-7b")
    model = zoo.build(cfg)
    params = zoo.init_params(model, jax.random.key(0))
    return model, params


def run_engine(model, params, reqs, **ec_kw):
    ec = EngineConfig(num_blocks=96, block_size=8, max_batch=4,
                      max_pages_per_seq=12, **ec_kw)
    eng = ServingEngine(model, params, ec)
    for r in reqs:
        eng.add_request(r)
    eng.run()
    return eng


def mk_reqs(n=8, seed=0):
    wl = WorkloadSpec(num_requests=n, qps=0.0, seed=seed, lengths="fixed",
                      prompt_len=20, output_len=8)
    return generate(wl)


def test_engine_finishes_and_counts(small_engine):
    model, params = small_engine
    reqs = mk_reqs(8)
    eng = run_engine(model, params, reqs)
    assert len(eng.finished) == 8
    for r in reqs:
        assert r.tokens_generated == 8
        assert len(eng.tokens_by_req[r.id]) == 8


def test_engine_paged_equals_contiguous_tokens(small_engine):
    """Greedy tokens from the paged engine == contiguous-cache oracle."""
    model, params = small_engine
    reqs = mk_reqs(3, seed=1)
    eng = run_engine(model, params, reqs)
    for r in reqs:
        prompt = jnp.asarray(eng.prompt_tokens[r.id][None])
        cache = zoo.init_cache(model, 1, 64)
        logits, cache = jax.jit(zoo.prefill, static_argnums=0)(
            model, params, {"tokens": prompt}, cache)
        tok = int(jnp.argmax(logits[0, -1, :model.plan.vocab_logical]))
        want = [tok]
        for _ in range(r.output_len - 1):
            lg, cache = jax.jit(zoo.decode_step, static_argnums=0)(
                model, params, cache, jnp.asarray([tok], jnp.int32))
            tok = int(jnp.argmax(lg[0, :model.plan.vocab_logical]))
            want.append(tok)
        assert eng.tokens_by_req[r.id] == want


def test_engine_preemption_recovers(small_engine):
    """Tiny memory forces preemption; all requests still finish."""
    model, params = small_engine
    reqs = mk_reqs(6, seed=2)
    ec = EngineConfig(num_blocks=20, block_size=8, max_batch=4,
                      max_pages_per_seq=12)
    eng = ServingEngine(model, params, ec)
    for r in reqs:
        eng.add_request(r)
    eng.run()
    assert len(eng.finished) == 6
    assert all(r.tokens_generated == 8 for r in reqs)


# ---------------------------------------------------------------------------
# Validation protocol (paper §III-C, in miniature)
# ---------------------------------------------------------------------------
def sim_with_tabular(reqs_spec, samples, *, num_blocks, block_size,
                     max_batch):
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("llama2-7b")
    spec = SimSpec(
        arch=cfg, workers=[WorkerSpec(hw="CPU")],
        workload=reqs_spec, local_policy="continuous",
        max_batch=max_batch, backend="tabular", backend_samples=samples,
        block_size=block_size)
    sim = Simulation(spec)
    # force identical memory geometry to the engine
    from repro.core.mem.block_manager import BlockManager, MemoryConfig
    sim.workers[0].mem = BlockManager(MemoryConfig(
        num_blocks=num_blocks, block_size=block_size,
        kv_bytes_per_token=1.0))
    return sim.run()


@pytest.mark.slow
def test_structural_validation_batch_traces_match(small_engine):
    """With the same scheduler, memory geometry and workload, the DES
    simulator reproduces the engine's iteration-by-iteration batch
    composition exactly."""
    model, params = small_engine
    wl = WorkloadSpec(num_requests=10, qps=0.0, seed=3, lengths="fixed",
                      prompt_len=20, output_len=8)
    reqs = generate(wl)
    eng = run_engine(model, params, reqs)
    engine_trace = [(rec.kind, rec.batch_ids) for rec in eng.records]

    samples = [(r.mix, r.wall) for r in eng.records]
    res = sim_with_tabular(wl, samples, num_blocks=96, block_size=8,
                           max_batch=4)
    # rebuild the simulator's iteration trace from its memory timeline:
    # instead, re-run a fresh sim capturing plans via hook
    from repro.core.simulator import Simulation
    spec = SimSpec(arch=get_smoke_config("llama2-7b"),
                   workers=[WorkerSpec(hw="CPU")],
                   workload=wl, local_policy="continuous", max_batch=4,
                   backend="tabular", backend_samples=samples,
                   block_size=8)
    sim = Simulation(spec)
    from repro.core.mem.block_manager import BlockManager, MemoryConfig
    sim.workers[0].mem = BlockManager(MemoryConfig(
        num_blocks=96, block_size=8, kv_bytes_per_token=1.0))
    trace = []
    sim.workers[0].hooks.on(
        "after_iteration",
        lambda w, plan, t: trace.append(
            ("prefill" if plan.prefill else "decode",
             tuple(r.id for r, _, _ in plan.prefill) or
             tuple(r.id for r in plan.decode))))
    sim.run()
    assert trace == engine_trace


def test_temporal_validation_throughput_close(small_engine):
    """Calibrated sim throughput within 15% of the real engine (the
    paper gets <1% with far more calibration data; this is the same
    protocol at smoke scale)."""
    model, params = small_engine
    wl = WorkloadSpec(num_requests=12, qps=0.0, seed=4, lengths="fixed",
                      prompt_len=20, output_len=8)
    reqs = generate(wl)
    eng = run_engine(model, params, reqs)
    res_eng = Results(requests=reqs, sim_time=eng.clock)
    thr_eng = res_eng.throughput()

    samples = [(r.mix, r.wall) for r in eng.records]
    res_sim = sim_with_tabular(wl, samples, num_blocks=96, block_size=8,
                               max_batch=4)
    thr_sim = res_sim.throughput()
    err = abs(thr_sim - thr_eng) / thr_eng
    assert err < 0.15, (thr_sim, thr_eng, err)
