"""Operator-graph cost model: physics sanity + paper's phase claims."""
import pytest

from repro.configs import ASSIGNED, get_config
from repro.core.costmodel.backends import RooflineBackend, TabularBackend
from repro.core.costmodel.hardware import A100, G6_AIM, V100
from repro.core.costmodel.operators import (BatchMix, OperatorGraph,
                                            kv_bytes_per_token, param_bytes,
                                            state_bytes_per_seq)


def test_flops_close_to_6nd():
    """Graph FLOPs for a decode-free prefill ~= 2*N*D fwd."""
    cfg = get_config("llama2-7b")
    g = OperatorGraph.from_config(cfg)
    tokens = 2048
    mix = BatchMix.from_batch([(tokens, 0)], [])
    f, _ = g.totals(mix)
    n = cfg.param_count() - cfg.vocab_size * cfg.d_model  # non-embed
    lower, upper = 2 * n * tokens * 0.9, 2 * n * tokens * 1.35
    assert lower < f < upper, (f, 2 * n * tokens)


def test_prefill_compute_bound_decode_memory_bound():
    """Paper background: prefill is compute-bound, decode memory-bound."""
    cfg = get_config("llama2-7b")
    g = OperatorGraph.from_config(cfg)
    hw = A100
    pre = BatchMix.from_batch([(1024, 0)], [])
    dec = BatchMix.from_batch([], [1024] * 8)

    def bound(mix):
        comp = sum(op.flops(mix) for op in g.ops) / (hw.flops * hw.flops_eff)
        memb = sum(op.bytes(mix) for op in g.ops) / (hw.mem_bw * hw.bw_eff)
        return comp, memb

    c_pre, m_pre = bound(pre)
    c_dec, m_dec = bound(dec)
    assert c_pre > m_pre, "prefill should be compute-bound"
    assert m_dec > c_dec, "decode should be memory-bound"


def test_decode_iteration_time_plausible():
    """llama2-7b bs=8 decode on A100 ~ 15-60 ms/token-iteration."""
    cfg = get_config("llama2-7b")
    be = RooflineBackend.for_model(cfg, A100)
    t = be.iteration_time(BatchMix.from_batch([], [512] * 8))
    assert 5e-3 < t < 0.1, t


def test_hardware_ordering_for_decode():
    """Decode favors bandwidth: A100 > G6-AiM ~ > V100."""
    cfg = get_config("llama2-7b")
    mix = BatchMix.from_batch([], [1024] * 16)
    times = {hw.name: RooflineBackend.for_model(cfg, hw).iteration_time(mix)
             for hw in (A100, V100, G6_AIM)}
    assert times["A100"] < times["V100"]
    assert times["G6-AiM"] < times["V100"]


def test_low_flops_a100_fine_for_decode_bad_for_prefill():
    """Paper Fig. 12/15: computing matters for prefill, not decode."""
    from repro.core.costmodel.hardware import A100_LOW
    cfg = get_config("llama2-7b")
    dec = BatchMix.from_batch([], [1024] * 16)
    pre = BatchMix.from_batch([(2048, 0)], [])
    t_dec = (RooflineBackend.for_model(cfg, A100_LOW).iteration_time(dec) /
             RooflineBackend.for_model(cfg, A100).iteration_time(dec))
    t_pre = (RooflineBackend.for_model(cfg, A100_LOW).iteration_time(pre) /
             RooflineBackend.for_model(cfg, A100).iteration_time(pre))
    assert t_dec < 1.5          # decode barely slower
    assert t_pre > 2.0          # prefill much slower


@pytest.mark.parametrize("name", ASSIGNED)
def test_graph_builds_for_every_arch(name):
    cfg = get_config(name)
    g = OperatorGraph.from_config(cfg, tp=16)
    mix = BatchMix.from_batch([(256, 0)], [512] * 4,
                              enc_tokens=cfg.enc_seq_len
                              if cfg.family in ("audio", "encdec") else 0)
    f, b = g.totals(mix)
    assert f > 0 and b > 0


def test_kv_sizing():
    cfg = get_config("llama2-7b")
    # 2 * 32 layers * 32 heads * 128 dim * 2 bytes = 524288 B/token
    assert kv_bytes_per_token(cfg) == pytest.approx(524288)
    assert state_bytes_per_seq(cfg) == 0
    m = get_config("mamba2-130m")
    assert kv_bytes_per_token(m) == 0
    assert state_bytes_per_seq(m) > 0
    assert param_bytes(cfg) == pytest.approx(cfg.param_count() * 2)


def test_tabular_backend_fits_affine():
    samples = []
    for nt in (1, 8, 64, 256):
        for kv in (0, 1000, 10000):
            mix = BatchMix(new_tokens=nt, attn_units=kv * nt,
                           kv_read_tokens=kv, n_seqs=max(1, nt // 4))
            t = 1e-3 + 2e-6 * nt + 1e-9 * kv * nt + 3e-8 * kv
            samples.append((mix, t))
    be = TabularBackend.fit(samples)
    for mix, t in samples:
        assert abs(be.iteration_time(mix) - t) / t < 0.15


def test_moe_flops_scale_with_topk_not_experts():
    cfg = get_config("granite-moe-1b-a400m")
    g = OperatorGraph.from_config(cfg)
    mix = BatchMix.from_batch([(1024, 0)], [])
    f, _ = g.totals(mix)
    n_active = cfg.active_param_count() - cfg.vocab_size * cfg.d_model
    assert f < 2 * n_active * 1024 * 1.5, \
        "MoE FLOPs must follow active params"
