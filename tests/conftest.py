import os

# Tests run on the single real CPU device (the dry-run subprocesses set
# their own XLA_FLAGS; never set host-device-count globally here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_threefry_partitionable", True)
