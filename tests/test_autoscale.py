"""Autoscaling layer (repro.core.autoscale, docs/AUTOSCALING.md):
property-based invariants over randomized policies x faults x
preemption x streaming, the frozen static-fleet golden pin, drain-based
scale-down losslessness, time-weighted billing, and the time-varying
availability accounting regression."""
import json
import os
import sys

import pytest

from repro.core.autoscale import AUTOSCALE_POLICIES, AutoscaleSpec
from repro.core.faults import ChaosSpec, FaultEvent, FaultSpec
from repro.core.metrics import Results, SCALING_SUMMARY_FIELDS
from repro.core.simulator import SimSpec, WorkerSpec, simulate
from repro.core.workload import WorkloadSpec
from repro.explore.sweep import spec_price, uptime_weighted_price
from repro.obs import ObsSpec

from _hypothesis_compat import given, settings, st

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "golden")


# ---------------------------------------------------------------------------
# helpers (shared idiom with tests/test_chaos.py)
# ---------------------------------------------------------------------------
def _sig(res):
    """Byte-level signature of a run: per-request ids and timestamps."""
    return [(r.id, r.t_first_token, r.t_finish, tuple(r.token_times))
            for r in sorted(res.requests, key=lambda r: r.id)]


def _assert_exactly_once(res, n_expected):
    fin = [r for r in res.requests if r.t_finish is not None]
    assert len(fin) == n_expected, \
        f"lost requests: {n_expected - len(fin)}"
    ids = [r.id for r in res.requests]
    assert len(ids) == len(set(ids)), "duplicated request objects"
    for r in fin:
        assert r.tokens_generated == r.output_len, r.id
        assert len(r.token_times) == r.output_len, r.id


def _assert_attribution_conserved(res, tol=1e-6):
    for r in res.requests:
        if r.t_finish is None or r.obs is None or r.obs.final is None:
            continue
        f = r.obs.final
        ttft = r.t_first_token - r.arrival_time
        assert abs(sum(f["ttft"].values()) - ttft) < tol, r.id
        dec = r.t_finish - r.t_first_token
        assert abs(sum(f["decode"].values()) - dec) < tol, r.id


def _spec(policy, *, with_faults=False, mode="recompute",
          streaming=False, n_req=60, qps=25.0, seed=9,
          min_replicas=1, max_replicas=4, interval=1.0, cooldown=2.0,
          n_workers=2, **as_kw):
    faults = [FaultSpec(time=3.0, worker=1, kind="fail", duration=1.0),
              FaultSpec(time=6.0, worker=0, kind="degrade", factor=3.0,
                        duration=2.0)] if with_faults else []
    return SimSpec(
        workers=[WorkerSpec(gpu_mem_util=0.25)
                 for _ in range(n_workers)],
        workload=WorkloadSpec(num_requests=n_req, qps=qps, seed=seed,
                              arrival="diurnal", diurnal_period=15.0,
                              diurnal_amplitude=0.9),
        preemption_mode=mode,
        streaming=streaming,
        faults=faults,
        chaos=ChaosSpec(reload_time=0.5, warmup_iters=1,
                        warmup_factor=2.0),
        autoscale=AutoscaleSpec(
            policy=policy, min_replicas=min_replicas,
            max_replicas=max_replicas, interval=interval,
            cooldown=cooldown, reload_time=0.5, warmup_iters=1,
            warmup_factor=2.0, **as_kw),
        obs=ObsSpec(attribution=True))


# ---------------------------------------------------------------------------
# property suite: randomized policies x faults x preemption x streaming
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(policy=st.sampled_from(list(AUTOSCALE_POLICIES)),
       with_faults=st.sampled_from([False, True]),
       mode=st.sampled_from(["recompute", "swap"]),
       streaming=st.sampled_from([False, True]),
       seed=st.integers(0, 40))
def test_autoscale_invariants(policy, with_faults, mode, streaming,
                              seed):
    """The chaos invariant suite holds while the fleet is scaling:
    every request finishes exactly once (scale-down drains lose
    nothing), latency attribution stays conserved, and the same seed
    reproduces the run byte-for-byte *including* the scale-event
    log."""
    spec = _spec(policy, with_faults=with_faults, mode=mode,
                 streaming=streaming, seed=seed)
    r1 = simulate(spec)
    _assert_exactly_once(r1, spec.workload.num_requests)
    _assert_attribution_conserved(r1)
    sc = r1.scaling_summary()
    assert set(SCALING_SUMMARY_FIELDS) <= set(sc)
    a = spec.autoscale
    assert a.min_replicas <= sc["fleet_size_max"] <= a.max_replicas
    # min_replicas holds at every instant, including while earlier
    # victims are still draining (the n_leaving bound in _tick)
    assert sc["fleet_size_min"] >= a.min_replicas
    for e in r1.scale_events:
        assert a.min_replicas <= e.fleet_size <= a.max_replicas, e
    r2 = simulate(spec)
    assert _sig(r1) == _sig(r2), "same seed must be byte-identical"
    assert r1.scale_events == r2.scale_events, \
        "scale-event log must be deterministic"
    assert r1.sim_time == r2.sim_time


# ---------------------------------------------------------------------------
# golden backward-compat pin: the dynamic-registry refactor must not
# move a single byte of a pre-refactor static-fleet run
# ---------------------------------------------------------------------------
def _load_pin_module():
    sys.path.insert(0, GOLDEN_DIR)
    try:
        from gen_autoscale_pin import pinned_spec, snapshot
        from pin_io import load_pin
    finally:
        sys.path.pop(0)
    return pinned_spec, snapshot, load_pin


def test_golden_static_fleet_pin():
    pinned_spec, snapshot, load_pin = _load_pin_module()
    want = load_pin(os.path.join(GOLDEN_DIR, "autoscale_pin.json"))
    got = json.loads(json.dumps(snapshot(simulate(pinned_spec()))))
    assert got == want, \
        "static-fleet run diverged from the pre-refactor golden pin"


def test_golden_pin_with_disabled_autoscaler():
    """AutoscaleSpec(enabled=False) must be byte-inert: same pin."""
    pinned_spec, snapshot, load_pin = _load_pin_module()
    spec = pinned_spec()
    spec.autoscale = AutoscaleSpec(enabled=False)
    res = simulate(spec)
    want = load_pin(os.path.join(GOLDEN_DIR, "autoscale_pin.json"))
    got = json.loads(json.dumps(snapshot(res)))
    assert got == want, "disabled autoscaler perturbed the run"
    assert res.scale_events is None


# ---------------------------------------------------------------------------
# scale-up / scale-down mechanics
# ---------------------------------------------------------------------------
def test_scale_up_pays_provisioning_lag():
    """A cloned worker becomes dispatch-eligible only after
    reload_time: every up_request -> up_ready pair is separated by
    exactly the configured lag (warm-up slowdown is paid after)."""
    spec = _spec("threshold", n_workers=1, qps=40.0, n_req=120,
                 queue_high=1.0)
    res = simulate(spec)
    sc = res.scaling_summary()
    assert sc["n_scale_up"] >= 1, "burst never triggered a scale-up"
    req_t = {}
    lags = []
    for e in res.scale_events:
        if e.action == "up_request":
            req_t[e.worker] = e.time
        elif e.action == "up_ready":
            lags.append(e.time - req_t.pop(e.worker))
    assert lags and all(abs(lag - 0.5) < 1e-9 for lag in lags), lags
    assert abs(sc["scale_up_lag_s"] - 0.5) < 1e-9


def test_scale_down_drains_without_loss():
    """Over-provisioned fleet under light load retires workers; no
    request is lost and retirements land only on empty workers."""
    spec = _spec("threshold", n_workers=4, qps=2.0, n_req=40,
                 queue_low=2.0, util_low=0.9)
    res = simulate(spec)
    _assert_exactly_once(res, spec.workload.num_requests)
    sc = res.scaling_summary()
    assert sc["n_scale_down"] >= 1
    assert any(e.action == "down_retired" for e in res.scale_events)
    drains = {e.worker: e.time for e in res.scale_events
              if e.action == "down_drain"}
    for e in res.scale_events:
        if e.action == "down_retired":
            assert e.time >= drains[e.worker]


def test_fleet_respects_bounds_and_cooldown():
    spec = _spec("threshold", n_workers=1, qps=40.0, n_req=150,
                 cooldown=3.0, queue_high=1.0)
    res = simulate(spec)
    sc = res.scaling_summary()
    assert 1 <= sc["fleet_size_min"] <= sc["fleet_size_max"] <= 4
    actions = sorted(e.time for e in res.scale_events
                     if e.action in ("up_request", "down_drain"))
    for a, b in zip(actions, actions[1:]):
        assert b - a >= 3.0 - 1e-9, \
            f"cooldown violated: actions at {a} and {b}"


def test_fleet_size_series_matches_events():
    spec = _spec("threshold", n_workers=1, qps=40.0, n_req=120,
                 queue_high=1.0)
    res = simulate(spec)
    sc = res.scaling_summary()
    series = sc["fleet_size_series"]
    assert series and series[0][1] >= 1
    assert all(t2 >= t1 for (t1, _), (t2, _) in zip(series, series[1:]))
    assert sc["fleet_size_final"] == series[-1][1]
    # time-weighted average consistent with worker_seconds
    assert sc["fleet_size_avg"] == pytest.approx(
        sc["worker_seconds"] / res.sim_time)


def test_validation_errors():
    for bad in (dict(policy="bogus"),
                dict(min_replicas=3, max_replicas=2),
                dict(min_replicas=0),
                dict(interval=0.0),
                dict(scale_step=0)):
        with pytest.raises(ValueError):
            AutoscaleSpec(**bad).validate()
    # surfaced through simulate() too
    with pytest.raises(ValueError):
        simulate(_spec("nope"))


# ---------------------------------------------------------------------------
# billing: time-weighted pricing (satellite: explore.spec_price tests)
# ---------------------------------------------------------------------------
def test_uptime_weighted_price_static_equals_spec_price():
    spec = SimSpec(
        workers=[WorkerSpec(hw="A100"), WorkerSpec(hw="L4")],
        workload=WorkloadSpec(num_requests=20, qps=10.0, seed=1))
    res = simulate(spec)
    assert uptime_weighted_price(spec, res) == \
        pytest.approx(spec_price(spec))


def test_uptime_weighted_price_half_span_bills_half():
    """A worker alive for half the horizon bills half its rate."""
    spec = SimSpec(workers=[WorkerSpec(hw="A100")])
    res = Results(requests=[], sim_time=10.0,
                  worker_spans={0: (0.0, None), 1: (0.0, 5.0)},
                  worker_prices={0: 1.0, 1: 1.0})
    assert uptime_weighted_price(spec, res) == pytest.approx(1.5)
    sc = res.scaling_summary()
    assert sc["billed_cost"] == pytest.approx(15.0)
    assert sc["worker_seconds"] == pytest.approx(15.0)
    assert sc["fleet_size_avg"] == pytest.approx(1.5)


def test_uptime_weighted_price_falls_back_without_spans():
    spec = SimSpec(workers=[WorkerSpec(hw="A100")] * 3)
    res = Results(requests=[], sim_time=10.0)
    assert uptime_weighted_price(spec, res) == \
        pytest.approx(spec_price(spec))
    assert uptime_weighted_price(spec, None) == \
        pytest.approx(spec_price(spec))


def test_autoscaled_run_bills_less_than_peak_fleet():
    """Billing integrates the actual fleet-size curve: an autoscaled
    run that only briefly touches max_replicas bills strictly less
    than a static max-size fleet over the same horizon."""
    spec = _spec("threshold", n_workers=1, qps=40.0, n_req=150,
                 queue_high=1.0)
    res = simulate(spec)
    sc = res.scaling_summary()
    assert sc["fleet_size_max"] >= 2, "test needs an actual scale-up"
    rate = uptime_weighted_price(spec, res)
    assert rate < sc["fleet_size_max"] * max(
        res.worker_prices.values())
    assert sc["billed_cost"] == pytest.approx(rate * res.sim_time)


def test_phase_cost_split_sums_to_billed_cost():
    """prefill + decode cost allocation re-composes the billed cost of
    every worker that did any work (idle-only workers excluded)."""
    spec = _spec("threshold", n_workers=2, qps=30.0, n_req=100)
    res = simulate(spec)
    sc = res.scaling_summary()
    p = sc["cost_per_1m_prefill_tokens"]
    d = sc["cost_per_1m_decode_tokens"]
    assert p > 0 and d > 0
    ph = res.phase_stats
    active_cost = 0.0
    for wid, stats in ph.items():
        if stats["busy_time"] <= 0:
            continue
        s, e = res.worker_spans[wid]
        span = (e if e is not None else res.sim_time) - s
        active_cost += res.worker_prices[wid] * span
    split_total = (p * sum(x["prefill_tokens"] for x in ph.values())
                   + d * sum(x["decode_tokens"] for x in ph.values()))
    assert split_total / 1e6 == pytest.approx(active_cost, rel=1e-6)


# ---------------------------------------------------------------------------
# availability accounting regression (satellite: time-varying fleet)
# ---------------------------------------------------------------------------
def test_availability_capacity_uses_provisioned_span():
    """A 1s outage is charged against provisioned worker-seconds, not
    n_workers * sim_time: with worker 1 provisioned for only half the
    run, capacity availability is 1 - 1/15, not 1 - 1/20."""
    ev = [FaultEvent(time=2.0, worker=0, kind="fail"),
          FaultEvent(time=3.0, worker=0, kind="recover")]
    res = Results(requests=[], sim_time=10.0, n_workers=2,
                  fault_events=ev,
                  worker_spans={0: (0.0, None), 1: (5.0, None)})
    av = res.availability_summary()
    assert av["capacity_availability"] == pytest.approx(1 - 1 / 15)
    legacy = Results(requests=[], sim_time=10.0, n_workers=2,
                     fault_events=ev)
    assert legacy.availability_summary()["capacity_availability"] \
        == pytest.approx(1 - 1 / 20)


def test_availability_absent_span_is_service_downtime():
    """Before a scale-up lands (and after retirement) a replica is
    absent: a single-worker fleet provisioned for [0, 5) of a 10s run
    leaves the service down for the other 5s — but absent time is NOT
    charged as per-worker failure downtime."""
    res = Results(requests=[], sim_time=10.0, n_workers=1,
                  fault_events=[],
                  worker_spans={0: (0.0, 5.0)})
    av = res.availability_summary()
    assert av["service_downtime_s"] == pytest.approx(5.0)
    assert av["availability_per_worker"][0] == pytest.approx(1.0)
    assert av["capacity_availability"] == pytest.approx(1.0)


def test_availability_static_fleet_identical_to_legacy():
    """Simulated static fleets carry worker_spans now; the numbers must
    match the historical fixed-n_workers accounting exactly."""
    spec = SimSpec(
        workers=[WorkerSpec(gpu_mem_util=0.3)] * 2,
        workload=WorkloadSpec(num_requests=50, qps=20.0, seed=4),
        faults=[FaultSpec(time=1.0, worker=0, kind="fail",
                          duration=1.0)],
        chaos=ChaosSpec(reload_time=0.2))
    res = simulate(spec)
    assert res.worker_spans == {0: (0.0, None), 1: (0.0, None)}
    with_spans = res.availability_summary()
    res.worker_spans = None
    legacy = res.availability_summary()
    for k in ("service_availability", "capacity_availability",
              "service_downtime_s", "mtbf_observed_s"):
        assert with_spans[k] == pytest.approx(legacy[k]), k


# ---------------------------------------------------------------------------
# full diurnal economics (slow: mirrors benchmarks/autoscale.py --quick)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_diurnal_autoscale_cheaper_than_static_peak():
    """End-to-end economics at reduced scale: on a diurnal workload an
    adaptive fleet bills fewer worker-seconds than the static fleet
    sized for its own observed peak, while finishing everything."""
    import benchmarks  # noqa: F401 - ensure package importable
    from benchmarks.autoscale import _autoscale, _workload
    n_req = 3000
    wl = _workload(n_req)
    adaptive = SimSpec(
        arch="llama2-7b", workers=[WorkerSpec(hw="A100")],
        global_policy="least_loaded", workload=wl,
        retain_requests=False, streaming_slo=(5.0, 0.5),
        autoscale=_autoscale("threshold", n_req))
    res = simulate(adaptive)
    sc = res.scaling_summary()
    assert res.stats.n_finished == n_req
    peak = sc["fleet_size_max"]
    assert peak >= 2, "diurnal peak never triggered a scale-up"
    static = SimSpec(
        arch="llama2-7b",
        workers=[WorkerSpec(hw="A100")] * peak,
        global_policy="least_loaded", workload=wl,
        retain_requests=False, streaming_slo=(5.0, 0.5))
    res_s = simulate(static)
    sc_s = res_s.scaling_summary()
    assert sc["billed_cost"] < sc_s["billed_cost"], \
        (sc["billed_cost"], sc_s["billed_cost"])
