"""Chaos layer (repro.core.faults, docs/RELIABILITY.md): property-based
invariants under randomized fault schedules, KV-aware failover,
costly-recovery semantics, availability accounting, and the
fail-during-migration / fail-mid-swap-out regressions."""

import pytest

from repro.core import comm as comm_mod
from repro.core.faults import (ChaosSpec, FaultEvent, FaultProcess,
                               FaultSpec, FAULT_KINDS, load_fault_trace)
from repro.core.metrics import AVAILABILITY_FIELDS
from repro.core.simulator import SimSpec, Simulation, WorkerSpec, simulate
from repro.core.workload import WorkloadSpec
from repro.obs import ObsSpec

from _hypothesis_compat import given, settings, st


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _sig(res):
    """Byte-level signature of a run: per-request ids and timestamps."""
    return [(r.id, r.t_first_token, r.t_finish, tuple(r.token_times))
            for r in sorted(res.requests, key=lambda r: r.id)]


def _assert_exactly_once(res, n_expected):
    """Every admitted request finishes exactly once: none lost, none
    duplicated (a duplicated request double-emits tokens)."""
    fin = [r for r in res.requests if r.t_finish is not None]
    assert len(fin) == n_expected, \
        f"lost requests: {n_expected - len(fin)}"
    ids = [r.id for r in res.requests]
    assert len(ids) == len(set(ids)), "duplicated request objects"
    for r in fin:
        assert r.tokens_generated == r.output_len, r.id
        assert len(r.token_times) == r.output_len, r.id
        assert all(b >= a for a, b in zip(r.token_times,
                                          r.token_times[1:])), r.id


def _assert_attribution_conserved(res, tol=1e-6):
    for r in res.requests:
        if r.t_finish is None or r.obs is None or r.obs.final is None:
            continue
        f = r.obs.final
        ttft = r.t_first_token - r.arrival_time
        assert abs(sum(f["ttft"].values()) - ttft) < tol, r.id
        dec = r.t_finish - r.t_first_token
        assert abs(sum(f["decode"].values()) - dec) < tol, r.id


# ---------------------------------------------------------------------------
# property suite: randomized fault schedules x preemption x accounting
# ---------------------------------------------------------------------------
_SCHEDULE = st.lists(
    st.tuples(st.integers(0, 1),          # worker
              st.integers(5, 50),         # fault time, deciseconds
              st.integers(5, 25),         # duration, deciseconds
              st.sampled_from(["fail", "degrade", "drain"])),
    max_size=3)


def _build(schedule, mode, streaming):
    faults = [FaultSpec(time=t / 10.0, worker=w, kind=kind,
                        factor=3.0 if kind == "degrade" else 1.0,
                        duration=d / 10.0)
              for w, t, d, kind in schedule]
    return SimSpec(
        workers=[WorkerSpec(gpu_mem_util=0.25),
                 WorkerSpec(gpu_mem_util=0.25)],
        workload=WorkloadSpec(num_requests=60, qps=25.0, seed=9),
        preemption_mode=mode,
        streaming=streaming,
        faults=faults,
        chaos=ChaosSpec(reload_time=0.5, warmup_iters=1,
                        warmup_factor=2.0),
        obs=ObsSpec(attribution=True))


@settings(max_examples=10)
@given(schedule=_SCHEDULE,
       mode=st.sampled_from(["recompute", "swap"]),
       streaming=st.sampled_from([False, True]))
def test_chaos_invariants(schedule, mode, streaming):
    """Under any fault schedule, in either preemption mode and either
    arrival mode: every request finishes exactly once, latency
    attribution still sums to the measured TTFT/decode spans, and the
    same seed reproduces the run byte-for-byte."""
    r1 = simulate(_build(schedule, mode, streaming))
    _assert_exactly_once(r1, 60)
    _assert_attribution_conserved(r1)
    r2 = simulate(_build(schedule, mode, streaming))
    assert _sig(r1) == _sig(r2)
    assert (r1.fault_events or []) == (r2.fault_events or [])


# ---------------------------------------------------------------------------
# zero-fault chaos is byte-identical to the baseline
# ---------------------------------------------------------------------------
def test_zero_fault_chaos_byte_identical():
    base = dict(workers=[WorkerSpec(), WorkerSpec()],
                workload=WorkloadSpec(num_requests=100, qps=10.0, seed=3))
    r0 = simulate(SimSpec(**base))
    r1 = simulate(SimSpec(**base, chaos=ChaosSpec()))
    assert _sig(r0) == _sig(r1)
    assert r0.sim_time == r1.sim_time


# ---------------------------------------------------------------------------
# stochastic processes
# ---------------------------------------------------------------------------
def _stochastic_spec(seed=7):
    return SimSpec(
        workers=[WorkerSpec(), WorkerSpec()],
        workload=WorkloadSpec(num_requests=120, qps=8.0, seed=3),
        chaos=ChaosSpec(
            processes=(FaultProcess(worker=0, mtbf=6.0, mttr=1.0,
                                    seed=seed),
                       FaultProcess(worker=1, mtbf=9.0, mttr=1.0,
                                    seed=seed)),
            reload_time=2.0))


def test_stochastic_failures_no_loss_and_reproducible():
    r1 = simulate(_stochastic_spec())
    _assert_exactly_once(r1, 120)
    assert r1.fault_events, "MTBF of 6-9s must fire within the run"
    av1 = r1.availability_summary()
    av2 = simulate(_stochastic_spec()).availability_summary()
    assert av1 == av2, "same seed must reproduce availability exactly"
    # a different seed draws a different fault timeline
    r3 = simulate(_stochastic_spec(seed=8))
    assert r3.fault_events != r1.fault_events


def test_availability_summary_accounting():
    r = simulate(_stochastic_spec())
    av = r.availability_summary(target=0.995)
    assert set(av) == set(AVAILABILITY_FIELDS)
    assert 0.0 <= av["service_availability"] <= 1.0
    assert 0.0 <= av["capacity_availability"] <= 1.0
    # capacity counts every lost replica, service only total outages
    assert av["capacity_availability"] <= av["service_availability"]
    assert av["n_failures"] > 0 and av["capacity_downtime_s"] > 0
    # recovery cost (mttr draw + 2s reload) counts as downtime
    assert av["mttr_observed_s"] > 2.0
    assert av["request_success_rate"] == 1.0
    # error budget: 30-day window at 99.5% = 0.005 * window seconds,
    # consumed scaled by observed downtime rate
    month = 30 * 86400.0
    avm = r.availability_summary(target=0.995, window=month)
    assert avm["error_budget_s"] == pytest.approx(0.005 * month)
    assert avm["budget_consumed_s"] == pytest.approx(
        av["service_downtime_s"] * month / r.sim_time)
    assert avm["burn_rate"] == pytest.approx(
        (1.0 - av["service_availability"]) / 0.005)
    assert avm["burn_rate"] == pytest.approx(av["burn_rate"])


def test_oom_crash_loop_fires_consecutive_failures():
    r = simulate(SimSpec(
        workers=[WorkerSpec(), WorkerSpec()],
        workload=WorkloadSpec(num_requests=100, qps=8.0, seed=3),
        chaos=ChaosSpec(
            processes=(FaultProcess(worker=0, kind="oom_crash_loop",
                                    mtbf=5.0, mttr=0.5, seed=1,
                                    max_events=1, crash_loops=3),),
            reload_time=0.2)))
    _assert_exactly_once(r, 100)
    av = r.availability_summary()
    assert av["n_failures"] == 3
    kinds = [e.kind for e in r.fault_events]
    assert kinds == ["fail", "recover"] * 3


def test_degrade_process_slows_then_restores():
    spec = SimSpec(
        workers=[WorkerSpec(), WorkerSpec()],
        workload=WorkloadSpec(num_requests=100, qps=8.0, seed=3),
        chaos=ChaosSpec(
            processes=(FaultProcess(worker=0, kind="degrade", mtbf=4.0,
                                    mttr=2.0, seed=2, max_events=2),)))
    sim = Simulation(spec)
    r = sim.run()
    _assert_exactly_once(r, 100)
    assert sim.workers[0].slowdown == 1.0, "degrade must auto-restore"
    av = r.availability_summary()
    assert av["degraded_s"] > 0.0
    assert av["n_failures"] == 0, "a straggler serves, slowly"
    assert av["service_availability"] == 1.0


# ---------------------------------------------------------------------------
# scheduled kinds: drain, duration auto-recover, costly recovery
# ---------------------------------------------------------------------------
def test_drain_stops_new_dispatches_until_restored():
    spec = SimSpec(
        workers=[WorkerSpec(), WorkerSpec()],
        workload=WorkloadSpec(num_requests=80, qps=20.0, seed=5),
        faults=[FaultSpec(time=0.0, worker=0, kind="drain",
                          duration=1000.0)])
    sim = Simulation(spec)
    r = sim.run()
    _assert_exactly_once(r, 80)
    assert sim.workers[1].tokens_emitted == sum(
        q.output_len for q in r.requests), \
        "a draining worker must receive no new dispatches"


def test_scheduled_fail_duration_auto_recovers():
    r = simulate(SimSpec(
        workers=[WorkerSpec(), WorkerSpec()],
        workload=WorkloadSpec(num_requests=80, qps=8.0, seed=3),
        faults=[FaultSpec(time=2.0, worker=0, kind="fail",
                          duration=1.0)],
        chaos=ChaosSpec(reload_time=0.5)))
    _assert_exactly_once(r, 80)
    assert [(e.time, e.kind) for e in r.fault_events] == \
        [(2.0, "fail"), (3.5, "recover")]
    av = r.availability_summary()
    assert av["downtime_per_worker"][0] == pytest.approx(1.5)
    assert av["downtime_per_worker"][1] == 0.0


def test_recovery_cost_reduces_availability():
    def run(reload):
        return simulate(SimSpec(
            workers=[WorkerSpec()],
            workload=WorkloadSpec(num_requests=60, qps=6.0, seed=3),
            faults=[FaultSpec(time=2.0, worker=0, kind="fail",
                              duration=1.0)],
            chaos=ChaosSpec(reload_time=reload, warmup_iters=0)))
    cheap = run(0.0)
    costly = run(5.0)
    _assert_exactly_once(cheap, 60)
    _assert_exactly_once(costly, 60)
    assert costly.availability_summary()["service_downtime_s"] == \
        pytest.approx(6.0)
    assert cheap.availability_summary()["service_downtime_s"] == \
        pytest.approx(1.0)
    assert costly.availability_summary()["service_availability"] < \
        cheap.availability_summary()["service_availability"]


def test_warmup_iterations_cost_extra_time():
    def run(warmup_iters):
        return simulate(SimSpec(
            workers=[WorkerSpec()],
            workload=WorkloadSpec(num_requests=60, qps=6.0, seed=3),
            faults=[FaultSpec(time=2.0, worker=0, kind="fail",
                              duration=1.0)],
            chaos=ChaosSpec(reload_time=0.0, warmup_iters=warmup_iters,
                            warmup_factor=3.0)))
    cold = run(200)
    warm = run(0)
    _assert_exactly_once(cold, 60)
    assert cold.sim_time > warm.sim_time


def test_all_workers_down_parks_arrivals():
    """A cluster-wide outage must hold arrivals at the dispatcher and
    serve them after recovery instead of crashing the scheduler."""
    r = simulate(SimSpec(
        workers=[WorkerSpec()],
        workload=WorkloadSpec(num_requests=60, qps=20.0, seed=5),
        faults=[FaultSpec(time=1.0, worker=0, kind="fail",
                          duration=2.0)],
        chaos=ChaosSpec(reload_time=0.5)))
    _assert_exactly_once(r, 60)
    assert r.availability_summary()["service_availability"] < 1.0


# ---------------------------------------------------------------------------
# KV-aware failover (composes with preemption_mode="swap")
# ---------------------------------------------------------------------------
def _swap_pressure_spec(survive):
    return SimSpec(
        workers=[WorkerSpec(gpu_mem_util=0.19),
                 WorkerSpec(gpu_mem_util=0.19)],
        workload=WorkloadSpec(num_requests=80, qps=40.0, seed=4,
                              lengths="fixed", prompt_len=512,
                              output_len=64),
        preemption_mode="swap",
        faults=[FaultSpec(time=3.0, worker=0, kind="fail")],
        chaos=ChaosSpec(reload_time=1.0, host_kv_survives=survive))


def test_host_kv_survives_failover_and_beats_recompute():
    """A victim whose KV sits in host DRAM when its worker dies resumes
    from swap on the new worker (the host tier outlives the worker
    process): adoption must happen, nothing may leak, and mean TTFT
    must beat the full-recompute policy."""
    surv = simulate(_swap_pressure_spec(True))
    reco = simulate(_swap_pressure_spec(False))
    _assert_exactly_once(surv, 80)
    _assert_exactly_once(reco, 80)
    assert sum(s["adopted"] for s in surv.swap_stats.values()) > 0
    assert sum(s["adopted"] for s in reco.swap_stats.values()) == 0
    for res in (surv, reco):
        # no host-DRAM leak: every byte accounted on either tier drains
        assert all(s["used_bytes"] == 0.0
                   for s in res.swap_stats.values())
    mean_ttft = lambda res: sum(  # noqa: E731
        q.ttft for q in res.finished) / len(res.finished)
    assert mean_ttft(surv) < mean_ttft(reco)


def test_fail_mid_swap_out_no_host_leak_under_chaos():
    """Killing a worker whose in-flight iteration bills a swap-out must
    release the host bytes (or hand them to the adopting tier) — no
    stranded victims, no leaked capacity, repeatedly."""
    r = simulate(SimSpec(
        workers=[WorkerSpec(gpu_mem_util=0.19),
                 WorkerSpec(gpu_mem_util=0.19)],
        workload=WorkloadSpec(num_requests=80, qps=40.0, seed=4,
                              lengths="fixed", prompt_len=512,
                              output_len=64),
        preemption_mode="swap",
        chaos=ChaosSpec(
            processes=(FaultProcess(worker=0, mtbf=2.0, mttr=0.5,
                                    seed=11),
                       FaultProcess(worker=1, mtbf=3.0, mttr=0.5,
                                    seed=11)),
            reload_time=0.5)))
    _assert_exactly_once(r, 80)
    assert all(s["used_bytes"] == 0.0 for s in r.swap_stats.values())
    assert all(s["used_bytes"] >= 0.0 for s in r.swap_stats.values())


# ---------------------------------------------------------------------------
# fail during migration (disagg_pd)
# ---------------------------------------------------------------------------
def test_fail_during_migration_no_duplication():
    """The source worker dying while a request's KV is on the wire must
    not deliver the migration: fail() already re-dispatched the request,
    and a late receive_migrated() would run it on two workers at once.
    A slow kv_link stretches every transfer so scheduled failures land
    inside migration windows."""
    for t_fail in (1.0, 2.0, 3.0, 5.0):
        r = simulate(SimSpec(
            workers=[WorkerSpec(role="prefill"),
                     WorkerSpec(role="decode")],
            global_policy="disagg",
            workload=WorkloadSpec(num_requests=40, qps=10.0, seed=2),
            kv_link=comm_mod.LinkSpec("slow", bandwidth=2e9,
                                      latency=1e-3),
            faults=[FaultSpec(time=t_fail, worker=0, kind="fail",
                              duration=1.5)],
            chaos=ChaosSpec(reload_time=0.5)))
        _assert_exactly_once(r, 40)


def test_fail_migration_target_reprefills():
    """The decode-side worker dying mid-transfer loses the arriving KV
    with the device: the request must re-prefill elsewhere, exactly
    once."""
    for t_fail in (1.0, 2.5, 4.0):
        r = simulate(SimSpec(
            workers=[WorkerSpec(role="prefill"),
                     WorkerSpec(role="decode")],
            global_policy="disagg",
            workload=WorkloadSpec(num_requests=40, qps=10.0, seed=2),
            kv_link=comm_mod.LinkSpec("slow", bandwidth=2e9,
                                      latency=1e-3),
            faults=[FaultSpec(time=t_fail, worker=1, kind="fail",
                              duration=1.5)],
            chaos=ChaosSpec(reload_time=0.5)))
        _assert_exactly_once(r, 40)


# ---------------------------------------------------------------------------
# observability integration
# ---------------------------------------------------------------------------
def test_fault_instants_and_n_alive_gauge():
    from repro.obs import validate_chrome_trace

    r = simulate(SimSpec(
        workers=[WorkerSpec(), WorkerSpec()],
        workload=WorkloadSpec(num_requests=80, qps=8.0, seed=3),
        faults=[FaultSpec(time=2.0, worker=0, kind="fail",
                          duration=2.0)],
        chaos=ChaosSpec(reload_time=1.0),
        obs=ObsSpec(trace=True, timeseries=True,
                    sample_interval=0.5)))
    _assert_exactly_once(r, 80)
    names = [e["name"] for e in r.trace.events]
    assert "fault.fail" in names and "fault.recover" in names
    assert validate_chrome_trace(r.trace.to_json()) == []
    cluster = r.timeseries.rows("cluster")
    alive = {row["n_alive"] for row in cluster}
    assert 2 in alive and 1 in alive, \
        "n_alive must dip during the outage"


# ---------------------------------------------------------------------------
# misc surface: trace loading, validation, registry
# ---------------------------------------------------------------------------
def test_load_fault_trace_jsonl(tmp_path):
    p = tmp_path / "faults.jsonl"
    p.write_text('{"time": 1.5, "worker": 0, "kind": "fail", '
                 '"duration": 2.0}\n'
                 '\n'
                 '{"time": 4.0, "worker": 1, "kind": "degrade", '
                 '"factor": 3.0}\n')
    faults = load_fault_trace(str(p))
    assert faults == [
        FaultSpec(time=1.5, worker=0, kind="fail", duration=2.0),
        FaultSpec(time=4.0, worker=1, kind="degrade", factor=3.0)]
    r = simulate(SimSpec(
        workers=[WorkerSpec(), WorkerSpec()],
        workload=WorkloadSpec(num_requests=60, qps=8.0, seed=3),
        faults=faults, chaos=ChaosSpec(reload_time=0.5)))
    _assert_exactly_once(r, 60)


def test_fault_validation_errors():
    base = dict(workers=[WorkerSpec()],
                workload=WorkloadSpec(num_requests=5, qps=5.0, seed=0))
    with pytest.raises(ValueError):
        simulate(SimSpec(**base,
                         faults=[FaultSpec(1.0, 3, "fail")]))
    with pytest.raises(ValueError):
        simulate(SimSpec(**base, chaos=ChaosSpec(
            processes=(FaultProcess(worker=0, kind="meteor"),))))
    assert set(FAULT_KINDS) >= {"fail", "recover", "slowdown",
                                "degrade", "drain", "oom_crash_loop"}


def test_fault_event_log_is_frozen_records():
    ev = FaultEvent(1.0, 0, "fail")
    with pytest.raises(Exception):
        ev.time = 2.0
