"""Streaming workloads (RequestSource), sketch metrics (StreamingStats)
and the retain_requests=False data path — the million-request scale
contract (docs/PERFORMANCE.md, docs/WORKLOADS.md)."""
import math
import random

import pytest

from repro.core.metrics import QuantileSketch, percentile
from repro.core.simulator import SimSpec, WorkerSpec, simulate
from repro.core.tenancy import TenantSpec, TenantTier
from repro.core.workload import (ARRIVAL_KINDS, WorkloadSpec, generate,
                                 generate_multi, make_source,
                                 make_tenant_source)


def _key(r):
    return (r.id, r.arrival_time, r.prompt_len, r.output_len,
            r.session_id, r.round_idx, r.history_len)


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arrival", [k for k in ARRIVAL_KINDS
                                     if k != "trace"])
def test_sources_deterministic_and_sorted(arrival):
    spec = WorkloadSpec(num_requests=1500, qps=20.0, seed=5,
                        arrival=arrival)
    a = [_key(r) for r in make_source(spec)]
    b = [_key(r) for r in make_source(spec)]
    assert a == b
    times = [k[1] for k in a]
    assert times == sorted(times)
    assert [k[0] for k in a] == list(range(len(a)))   # dense stable ids


def test_stream_matches_seed_golden_sample():
    """Backward-compat pin: these tuples were produced by the
    pre-streaming list-based generate() (verified against git history),
    so stream/generate regressions cannot cancel out — the comparison
    is against frozen data, not against the same code path."""
    golden = [
        (0, 0.204012, 35, 38, 1, 0),
        (1, 0.64947, 32, 216, 2, 0),
        (2, 0.859368, 121, 461, 3, 0),
        (3, 1.187161, 184, 160, 4, 0),
        (4, 1.563272, 276, 481, 5, 0),
        (5, 1.658407, 185, 869, 6, 0),
    ]
    spec = WorkloadSpec(num_requests=6, qps=5.0, seed=42)
    got = [(r.id, round(r.arrival_time, 6), r.prompt_len, r.output_len,
            r.session_id, r.round_idx) for r in make_source(spec)]
    assert got == golden
    assert [(r.id, round(r.arrival_time, 6), r.prompt_len, r.output_len,
             r.session_id, r.round_idx) for r in generate(spec)] == golden


def test_stream_matches_generate():
    """The lazy source and the materializing wrapper are the same
    stream, including multi-round sessions re-entering via the pending
    heap and the qps=0 all-at-once corner."""
    for spec in (WorkloadSpec(num_requests=400, qps=6.0, seed=11,
                              multi_round_frac=0.4),
                 WorkloadSpec(num_requests=200, qps=0.0, seed=1,
                              multi_round_frac=0.3),
                 WorkloadSpec(num_requests=300, qps=9.0, seed=2,
                              lengths="fixed", prompt_len=32,
                              output_len=8)):
        assert [_key(r) for r in make_source(spec)] == \
            [_key(r) for r in generate(spec)]


def test_bursty_is_burstier_than_poisson():
    """MMPP on-off should fatten the interarrival dispersion (CV > 1)
    relative to Poisson (CV ~ 1) at the same mean rate."""
    def cv(arrival):
        spec = WorkloadSpec(num_requests=6000, qps=50.0, seed=3,
                            arrival=arrival, burst_on_scale=4.0,
                            burst_off_scale=0.1)
        ts = [r.arrival_time for r in make_source(spec)]
        gaps = [b - a for a, b in zip(ts, ts[1:])]
        mean = sum(gaps) / len(gaps)
        var = sum((g - mean) ** 2 for g in gaps) / len(gaps)
        return math.sqrt(var) / mean
    assert cv("bursty") > 1.3 * cv("poisson")


def test_diurnal_rate_modulation():
    """Arrivals concentrate in the sinusoid's high-rate half-period."""
    spec = WorkloadSpec(num_requests=8000, qps=50.0, seed=4,
                        arrival="diurnal", diurnal_period=100.0,
                        diurnal_amplitude=0.9)
    reqs = list(make_source(spec))
    # phase in [0, 1): first half-period is the high-rate half
    high = sum(1 for r in reqs
               if (r.arrival_time % 100.0) < 50.0)
    assert high / len(reqs) > 0.6


def test_trace_streaming_rejects_unsorted(tmp_path):
    p = tmp_path / "t.jsonl"
    p.write_text('{"arrival": 5.0, "prompt_len": 8, "output_len": 2}\n'
                 '{"arrival": 1.0, "prompt_len": 8, "output_len": 2}\n')
    spec = WorkloadSpec(num_requests=10, lengths="trace",
                        trace_path=str(p))
    with pytest.raises(ValueError):
        list(make_source(spec))
    assert len(generate(spec)) == 2          # list mode sorts instead


def test_tenant_merge_accepts_unsorted_trace(tmp_path):
    """Trace-backed tenants are materialized-and-sorted inside the
    merge (pre-streaming generate_multi behaviour), so unsorted traces
    on disk keep working in multi-tenant mode."""
    p = tmp_path / "t.jsonl"
    p.write_text('{"arrival": 2.0, "prompt_len": 8, "output_len": 2}\n'
                 '{"arrival": 1.0, "prompt_len": 4, "output_len": 2}\n'
                 '{"arrival": 3.0, "prompt_len": 2, "output_len": 2}\n')
    tenants = [TenantSpec("t0", TenantTier(),
                          WorkloadSpec(num_requests=10, lengths="trace",
                                       trace_path=str(p)))]
    merged = generate_multi(tenants)
    assert [r.arrival_time for r in merged] == [1.0, 2.0, 3.0]
    assert [r.prompt_len for r in merged] == [4, 8, 2]
    assert [_key(r) for r in merged] == \
        [_key(r) for r in make_tenant_source(tenants)]


# ---------------------------------------------------------------------------
# multi-tenant heap-merge
# ---------------------------------------------------------------------------
def _tenants():
    return [
        TenantSpec("acme", TenantTier(name="pro", priority=5, weight=4.0),
                   WorkloadSpec(num_requests=300, qps=5.0, seed=2,
                                multi_round_frac=0.3)),
        TenantSpec("beta", TenantTier(name="free"),
                   WorkloadSpec(num_requests=200, qps=3.0, seed=2,
                                arrival="bursty")),
    ]


def test_tenant_merge_matches_generate_multi():
    a = [(_key(r), r.tenant_id, r.priority, r.weight)
         for r in make_tenant_source(_tenants())]
    b = [(_key(r), r.tenant_id, r.priority, r.weight)
         for r in generate_multi(_tenants())]
    assert a == b


def test_tenant_merge_preserves_per_tenant_order_and_ids():
    merged = list(make_tenant_source(_tenants()))
    assert [r.id for r in merged] == list(range(len(merged)))
    times = [r.arrival_time for r in merged]
    assert times == sorted(times)
    for tid in ("acme", "beta"):
        sub = [r for r in merged if r.tenant_id == tid]
        # per-tenant arrival order survives the merge, and so does the
        # per-tenant stream itself (same requests as solo generation)
        solo = make_tenant_source([t for t in _tenants()
                                   if t.tenant_id == tid])
        assert [(r.arrival_time, r.prompt_len, r.output_len)
                for r in sub] == \
            [(r.arrival_time, r.prompt_len, r.output_len) for r in solo]


# ---------------------------------------------------------------------------
# sketches
# ---------------------------------------------------------------------------
def test_sketch_within_1pct_on_lognormal():
    rng = random.Random(0)
    xs = [rng.lognormvariate(0.0, 1.0) for _ in range(10_000)]
    sk = QuantileSketch()
    for x in xs:
        sk.add(x)
    for p in (1, 10, 25, 50, 75, 90, 99, 99.9):
        exact = percentile(xs, p)
        assert abs(sk.percentile(p) - exact) / exact < 0.01, p
    assert sk.count == len(xs)
    assert sk.max == max(xs) and sk.min == min(xs)
    assert abs(sk.mean - sum(xs) / len(xs)) < 1e-9


def test_sketch_edge_cases():
    sk = QuantileSketch()
    assert math.isnan(sk.percentile(50))
    sk.add(0.0)
    sk.add(5.0)
    assert sk.percentile(0) == 0.0
    assert sk.percentile(100) == 5.0
    cdf = sk.cdf_points(4)
    assert cdf[0][1] == 0.0 and cdf[-1][1] == 1.0


def test_sketch_cdf_matches_percentiles():
    """The single-pass CDF equals evaluating percentile() pointwise."""
    rng = random.Random(1)
    sk = QuantileSketch()
    for _ in range(5000):
        sk.add(rng.lognormvariate(0.0, 1.0))
    assert sk.cdf_points(50) == \
        [(sk.percentile(100.0 * i / 50), i / 50) for i in range(51)]


# ---------------------------------------------------------------------------
# retain_requests=False end-to-end
# ---------------------------------------------------------------------------
def _base(streaming, retain, **kw):
    return SimSpec(
        arch="llama2-7b", workers=[WorkerSpec(), WorkerSpec()],
        workload=WorkloadSpec(num_requests=400, qps=30.0, seed=4),
        max_batch=64, streaming=streaming, retain_requests=retain, **kw)


def test_streaming_mode_identical_to_materialized():
    r1 = simulate(_base(False, True))
    r2 = simulate(_base(True, True))
    assert [x.t_finish for x in r1.requests] == \
        [x.t_finish for x in r2.requests]


def test_drop_mode_matches_exact_summary():
    exact = simulate(_base(False, True)).summary()
    drop_res = simulate(_base(True, False))
    drop = drop_res.summary()
    assert not drop_res.requests                 # everything retired
    assert drop_res.stats is not None
    assert drop["n_finished"] == exact["n_finished"]
    for k, v in exact.items():
        if isinstance(v, float) and v == v and v != 0.0:
            assert abs(drop[k] - v) / abs(v) < 0.011, (k, v, drop[k])


def test_drop_mode_bounds_live_requests():
    res = simulate(_base(True, False))
    assert 0 < res.max_live < 400


def test_drop_mode_tenant_breakdown():
    tenants = [
        TenantSpec("acme", TenantTier(name="pro", weight=4.0,
                                      ttft_slo=5.0, tpot_slo=1.0),
                   WorkloadSpec(num_requests=150, qps=10.0, seed=1)),
        TenantSpec("beta", TenantTier(name="free"),
                   WorkloadSpec(num_requests=100, qps=6.0, seed=1)),
    ]
    def spec(streaming, retain):
        return SimSpec(arch="llama2-7b",
                       workers=[WorkerSpec(), WorkerSpec()],
                       tenants=tenants, global_policy="wfq",
                       streaming=streaming, retain_requests=retain)
    exact = simulate(spec(False, True))
    drop = simulate(spec(True, False))
    es, ds = exact.tenant_summary(), drop.tenant_summary()
    assert set(es) == set(ds) == {"acme", "beta"}
    for t in es:
        for k in ("n_requests", "n_finished", "n_rejected", "tokens"):
            assert ds[t][k] == es[t][k], (t, k)
        for k in ("latency_p50", "latency_p99", "token_tps"):
            assert abs(ds[t][k] - es[t][k]) / max(es[t][k], 1e-12) < 0.011
    assert abs(drop.fairness_index() - exact.fairness_index()) < 0.01
    # per-tenant folds sum to the aggregate
    st = drop.stats
    assert sum(s.n_folded for s in st.tenants.values()) == st.n_folded


def test_streaming_goodput_with_configured_slo():
    slo = (0.5, 0.5)
    exact = simulate(_base(False, True))
    drop = simulate(_base(True, False, streaming_slo=slo))
    g_exact = exact.slo_goodput(ttft_slo=slo[0], mtpot_slo=slo[1])
    g_drop = drop.slo_goodput(ttft_slo=slo[0], mtpot_slo=slo[1])
    assert abs(g_drop - g_exact) / max(g_exact, 1e-12) < 1e-6
    # unmatched thresholds cannot be answered post-hoc in drop mode
    assert math.isnan(drop.slo_goodput(ttft_slo=9.9))


# ---------------------------------------------------------------------------
# Results caching regression (the repeated-full-sort fix)
# ---------------------------------------------------------------------------
def test_results_summary_unchanged_by_sort_cache():
    res = simulate(_base(False, True))
    s = res.summary()
    lats = res.latencies()
    tt = res.ttfts()
    assert s["latency_p50"] == percentile(lats, 50)
    assert s["latency_p90"] == percentile(lats, 90)
    assert s["latency_p99"] == percentile(lats, 99)
    assert s["ttft_p50"] == percentile(tt, 50)
    assert s["ttft_p99"] == percentile(tt, 99)
    assert s["latency_max"] == max(lats)
    # repeated calls hit the cache and stay identical
    assert res.summary() == s
    assert res.latency_cdf(10) == res.latency_cdf(10)


def test_mem_timeline_stays_bounded():
    from repro.core.worker import MEM_TIMELINE_CAP
    res = simulate(SimSpec(
        arch="llama2-7b", workers=[WorkerSpec()],
        workload=WorkloadSpec(num_requests=200, qps=0.0, seed=0,
                              lengths="fixed", prompt_len=4,
                              output_len=64),
        max_batch=4))
    for tl in res.worker_mem.values():
        assert len(tl) <= MEM_TIMELINE_CAP


# ---------------------------------------------------------------------------
# observability in drop mode (repro.obs, docs/OBSERVABILITY.md)
# ---------------------------------------------------------------------------
def test_drop_mode_summary_unchanged_by_obs():
    """Full observability only records — every summary metric of the
    golden drop-mode run stays bit-identical with it enabled."""
    from repro.obs import ObsSpec
    plain = simulate(_base(True, False)).summary()
    obs = simulate(_base(True, False, obs=ObsSpec.full())).summary()
    assert obs == plain


def test_drop_mode_attribution_conserves_means():
    """retain_requests=False keeps per-component sums in StreamingStats;
    the folded means must equal the exact-mode means (same sim, retained
    requests) and sum to the measured mean latency within 1e-6."""
    from repro.obs import ObsSpec
    exact = simulate(_base(False, True, obs=ObsSpec(attribution=True)))
    drop = simulate(_base(True, False, obs=ObsSpec(attribution=True)))
    assert not drop.requests
    eb, db = exact.time_breakdown(), drop.time_breakdown()
    assert db["n"] == eb["n"] == drop.stats.n_finished
    for section in ("ttft_mean", "decode_mean", "tpot_mean"):
        for k, v in eb[section].items():
            assert abs(db[section][k] - v) < 1e-9, (section, k)
    mean_ttft = sum(r.ttft for r in exact.finished) / len(exact.finished)
    assert abs(sum(db["ttft_mean"].values()) - mean_ttft) < 1e-6
