"""Deep observability (repro.obs, docs/OBSERVABILITY.md): request
lifecycle tracing, Chrome trace export, bounded time series, latency
attribution — plus the breakpoint-registry fast path and the engine's
daemon-event semantics the obs sampler rides on."""
import json

import pytest

from repro.core.breakpoints import HOOK_POINTS, Hooks
from repro.core.engine import Environment
from repro.core.simulator import SimSpec, Simulation, WorkerSpec, simulate
from repro.core.tenancy import TenantSpec, TenantTier
from repro.core.workload import WorkloadSpec
from repro.obs import (COMPONENTS, BoundedSeries, ObsSpec, TS_FIELDS,
                       validate_chrome_trace)

EPS = 1e-6


def _small(n=40, obs=None, **kw):
    kw.setdefault("local_policy", "continuous")
    return SimSpec(
        arch="llama2-7b", workers=[WorkerSpec(), WorkerSpec()],
        workload=WorkloadSpec(num_requests=n, qps=20.0, seed=3),
        max_batch=32, obs=obs, **kw)


def _pressure(n=48, obs=None, **kw):
    """Undersized KV pool (benchmarks/kv_hierarchy.py recipe): decode
    growth forces swap preemptions."""
    from repro.configs import get_config
    from repro.core.costmodel.operators import (kv_bytes_per_token,
                                                param_bytes)
    cfg = get_config("llama2-7b")
    kvt = kv_bytes_per_token(cfg, 2)
    cap = (param_bytes(cfg, 2) + (10 * 1024 + 4 * 192) * kvt) / 0.9
    return SimSpec(
        arch="llama2-7b",
        workers=[WorkerSpec(hw="A100", mem_cap_override=cap)],
        workload=WorkloadSpec(num_requests=n, qps=0.0, seed=0,
                              lengths="fixed", prompt_len=1024,
                              output_len=192),
        local_policy="continuous", preemption_mode="swap",
        obs=obs, **kw)


# ---------------------------------------------------------------------------
# breakpoint registry: O(1) empty fast path + no defaultdict pollution
# ---------------------------------------------------------------------------
def test_fire_on_unregistered_point_does_not_mutate():
    h = Hooks()
    for p in HOOK_POINTS:
        h.fire(p, object())
    assert h._hooks == {}          # no defaultdict-miss allocation


def test_hooks_register_and_fire():
    h = Hooks()
    seen = []
    h.on("on_admit", lambda *a: seen.append(a))
    h.on("on_admit", lambda *a: seen.append(a))
    h.fire("on_admit", "w", "r")
    assert seen == [("w", "r"), ("w", "r")]
    assert set(h._hooks) == {"on_admit"}    # only the registered point


def test_hooks_reject_unknown_point():
    h = Hooks()
    with pytest.raises(KeyError):
        h.on("no_such_point", lambda: None)


def test_all_seven_hook_points_fire_in_small_sim():
    """Every point in HOOK_POINTS fires at least once in a sim that
    prefills, decodes, batches and finishes — the registry audit."""
    assert len(HOOK_POINTS) == 7
    counts = {p: 0 for p in HOOK_POINTS}
    sim = Simulation(_small())

    def bump(point):
        return lambda *a, **kw: counts.__setitem__(
            point, counts[point] + 1)

    for w in sim.workers:
        for p in HOOK_POINTS:
            w.hooks.on(p, bump(p))
    sim.run()
    missing = [p for p, c in counts.items() if c == 0]
    assert not missing, f"hook points never fired: {missing}"


# ---------------------------------------------------------------------------
# engine daemon events (the time-series sampler's substrate)
# ---------------------------------------------------------------------------
def test_daemon_only_heap_ends_run():
    env = Environment()

    def ticker():
        while True:
            yield env.timeout(1.0, daemon=True)

    env.process(ticker(), name="tick", daemon=True)
    env.run()
    assert env.now == 0.0          # nothing non-daemon ever scheduled


def test_daemon_does_not_extend_sim_past_real_work():
    env = Environment()
    ticks = []

    def ticker():
        while True:
            yield env.timeout(1.0, daemon=True)
            ticks.append(env.now)

    def work():
        yield env.timeout(3.5)

    env.process(ticker(), name="tick", daemon=True)
    env.process(work(), name="work")
    env.run()
    assert env.now == 3.5          # run ends with the last real event
    assert ticks == [1.0, 2.0, 3.0]


# ---------------------------------------------------------------------------
# BoundedSeries: stride-doubling decimation
# ---------------------------------------------------------------------------
def test_bounded_series_caps_and_decimates():
    s = BoundedSeries(cap=8)
    for i in range(1000):
        if s.should_record():
            s.append(i)
    assert len(s) <= 8
    rows = list(s)
    assert rows[0] == 0            # the t~0 anchor survives decimation
    assert rows == sorted(rows)
    assert s.stride > 1            # decimation actually kicked in


def test_bounded_series_no_decimation_below_cap():
    s = BoundedSeries(cap=100)
    for i in range(50):
        if s.should_record():
            s.append(i)
    assert list(s) == list(range(50))
    assert s.stride == 1


# ---------------------------------------------------------------------------
# trace recorder + validator
# ---------------------------------------------------------------------------
def test_trace_exports_valid_chrome_json(tmp_path):
    res = simulate(_small(obs=ObsSpec(trace=True)))
    path = str(tmp_path / "trace.json")
    res.export_trace(path)
    with open(path) as f:
        data = json.load(f)
    assert validate_chrome_trace(data) == []
    names = {e["name"] for e in data["traceEvents"]}
    assert "iteration" in names
    cats = {e.get("cat") for e in data["traceEvents"]}
    assert "request.total" in cats and "request" in cats
    assert data["otherData"]["dropped_events"] == 0


def test_trace_span_durations_sum_to_latency():
    """Acceptance criterion: per-request phase spans are contiguous and
    sum to the measured arrival->finish latency within 1e-6 s."""
    res = simulate(_small(obs=ObsSpec(trace=True)))
    by_req = {}
    for ev in res.trace.events:
        if ev.get("cat") == "request":
            by_req.setdefault(ev["tid"], []).append(ev)
    lat = {r.id: (r.t_finish - r.arrival_time) for r in res.finished}
    assert by_req and set(lat) == set(by_req)
    for rid, evs in by_req.items():
        total = sum(e["dur"] for e in evs) / 1e6
        assert abs(total - lat[rid]) < EPS, (rid, total, lat[rid])


def test_validator_flags_corrupt_traces():
    res = simulate(_small(n=10, obs=ObsSpec(trace=True)))
    good = res.trace.to_json()
    assert validate_chrome_trace(good) == []

    bad = json.loads(json.dumps(good))
    for ev in bad["traceEvents"]:
        if ev.get("cat") == "request":
            ev["dur"] = ev["dur"] + 5e5      # open a gap
            break
    assert validate_chrome_trace(bad)

    bad2 = json.loads(json.dumps(good))
    for ev in bad2["traceEvents"]:
        if ev["ph"] == "X":
            ev["dur"] = -1.0
            break
    assert validate_chrome_trace(bad2)

    assert validate_chrome_trace({}) != []
    assert validate_chrome_trace({"traceEvents": [{}]}) != []


def test_trace_event_cap_drops_not_grows():
    res = simulate(_small(obs=ObsSpec(trace=True, max_trace_events=50)))
    assert len(res.trace) <= 50
    assert res.trace.dropped > 0
    assert res.trace.to_json()["otherData"]["dropped_events"] > 0


def test_trace_records_swaps_and_preempts():
    res = simulate(_pressure(obs=ObsSpec(trace=True)))
    assert res.memory_summary()["swap_preempts"] > 0
    names = {e["name"] for e in res.trace.events}
    assert "swap_out" in names and "swap_in" in names
    assert "preempted" in names
    assert validate_chrome_trace(res.trace.to_json()) == []


def test_trace_rejected_and_inflight_outcomes():
    tenants = [TenantSpec(
        "t0", TenantTier(name="free", rate_tokens_per_s=500.0,
                         burst_tokens=600.0, admission_policy="reject"),
        WorkloadSpec(num_requests=60, qps=50.0, seed=2))]
    res = simulate(SimSpec(
        arch="llama2-7b", workers=[WorkerSpec()], tenants=tenants,
        obs=ObsSpec(trace=True)))
    outcomes = {e["args"]["outcome"] for e in res.trace.events
                if e.get("cat") == "request.total"}
    assert "rejected" in outcomes and "finished" in outcomes
    assert validate_chrome_trace(res.trace.to_json()) == []


def test_trace_migrate_phase_in_disagg():
    ws = [WorkerSpec(role="prefill"), WorkerSpec(role="decode")]
    res = simulate(SimSpec(
        arch="llama2-7b", workers=ws, global_policy="disagg",
        workload=WorkloadSpec(num_requests=30, qps=10.0, seed=1),
        obs=ObsSpec(trace=True)))
    names = {e["name"] for e in res.trace.events
             if e.get("cat") == "request"}
    assert "migrate" in names
    assert validate_chrome_trace(res.trace.to_json()) == []


# ---------------------------------------------------------------------------
# latency attribution: conservation + components
# ---------------------------------------------------------------------------
def _check_conserved(res):
    worst = 0.0
    for r in res.finished:
        f = r.obs.final
        ttft = r.t_first_token - r.arrival_time
        worst = max(worst, abs(sum(f["ttft"].values()) - ttft))
        dec = r.t_finish - r.t_first_token
        worst = max(worst, abs(sum(f["decode"].values()) - dec))
    return worst


def test_attribution_conserves_exactly():
    res = simulate(_small(obs=ObsSpec(attribution=True)))
    assert _check_conserved(res) < EPS
    bd = res.time_breakdown()
    assert bd["mode"] == "exact" and bd["n"] == len(res.finished)
    # mean components sum to the mean measured latency
    mean_ttft = sum(r.ttft for r in res.finished) / len(res.finished)
    assert abs(sum(bd["ttft_mean"].values()) - mean_ttft) < EPS
    assert set(bd["ttft_mean"]) <= set(COMPONENTS)
    assert set(bd["decode_mean"]) <= set(COMPONENTS)


def test_attribution_conserves_under_swap_preemption():
    res = simulate(_pressure(obs=ObsSpec(attribution=True)))
    assert res.memory_summary()["swap_preempts"] > 0
    assert _check_conserved(res) < EPS
    bd = res.time_breakdown()
    assert "swap" in {**bd["ttft_mean"], **bd["decode_mean"]}


def test_attribution_gateway_component_with_admission():
    tenants = [TenantSpec(
        "t0", TenantTier(name="free", rate_tokens_per_s=2000.0,
                         burst_tokens=2000.0),
        WorkloadSpec(num_requests=50, qps=40.0, seed=2))]
    res = simulate(SimSpec(
        arch="llama2-7b", workers=[WorkerSpec()], tenants=tenants,
        obs=ObsSpec(attribution=True)))
    assert _check_conserved(res) < EPS
    assert res.time_breakdown()["ttft_mean"].get("gateway", 0.0) > 0.0


def test_attribution_comm_bubble_with_pipeline():
    from repro.core.simulator import ParallelSpec
    res = simulate(SimSpec(
        arch="llama2-7b", backend="roofline",
        workers=[WorkerSpec(hw="A100")],
        parallel=ParallelSpec(pp=2, microbatches=4),
        workload=WorkloadSpec(num_requests=16, qps=4.0, seed=1,
                              lengths="fixed", prompt_len=512,
                              output_len=32),
        obs=ObsSpec(attribution=True)))
    assert _check_conserved(res) < EPS
    bd = res.time_breakdown()
    assert "comm" in bd["decode_mean"] and "bubble" in bd["decode_mean"]


def test_explain_renders_all_sections():
    res = simulate(_small(obs=ObsSpec(attribution=True)))
    text = res.explain()
    for frag in ("TTFT", "decode phase", "TPOT", "total", "queue"):
        assert frag in text, frag


def test_time_breakdown_requires_attribution():
    res = simulate(_small())
    with pytest.raises(ValueError, match="attribution"):
        res.time_breakdown()
    with pytest.raises(ValueError, match="tracing"):
        res.export_trace("/dev/null")


# ---------------------------------------------------------------------------
# streaming drop-mode attribution
# ---------------------------------------------------------------------------
def test_streaming_attribution_matches_exact_means():
    exact = simulate(_small(n=120, obs=ObsSpec(attribution=True)))
    drop = simulate(_small(n=120, obs=ObsSpec(attribution=True),
                           streaming=True, retain_requests=False))
    assert not drop.requests                    # really dropped
    eb, db = exact.time_breakdown(), drop.time_breakdown()
    assert db["mode"] == "streaming" and db["n"] == eb["n"]
    for section in ("ttft_mean", "decode_mean", "tpot_mean"):
        assert set(db[section]) == set(eb[section]), section
        for k, v in eb[section].items():
            assert abs(db[section][k] - v) < 1e-9, (section, k)
    assert db["ttft_p99"] is None               # no tails in drop mode
    assert "exact mode" in drop.explain()       # the p99 footnote


# ---------------------------------------------------------------------------
# time series recorder
# ---------------------------------------------------------------------------
def test_timeseries_rows_bounded_and_typed(tmp_path):
    res = simulate(_small(
        n=150, obs=ObsSpec(timeseries=True, sample_interval=0.01,
                           timeseries_cap=32)))
    ts = res.timeseries
    cluster = ts.rows("cluster")
    assert 0 < len(cluster) <= 32
    times = [row["t"] for row in cluster]
    assert times == sorted(times)
    for row in cluster:
        assert set(row) <= set(TS_FIELDS)
    # per-worker rows exist and sum into the cluster row
    w0 = ts.rows("worker0")
    assert w0 and all(r["scope"] == "worker0" for r in w0)
    last = cluster[-1]
    assert last["n_finished"] == len(res.finished)

    csv_path = str(tmp_path / "ts.csv")
    json_path = str(tmp_path / "ts.json")
    res.export_timeseries(csv_path)
    res.export_timeseries(json_path)
    with open(csv_path) as f:
        header = f.readline().strip().split(",")
    assert header == list(TS_FIELDS)
    with open(json_path) as f:
        data = json.load(f)
    assert data["fields"] == list(TS_FIELDS)
    scopes = {r["scope"] for r in data["samples"]}
    assert scopes >= {"cluster", "worker0"}


def test_timeseries_final_sample_covers_short_sims():
    res = simulate(_small(n=5, obs=ObsSpec(timeseries=True,
                                           sample_interval=1e9)))
    rows = res.timeseries.rows("cluster")
    assert rows and rows[-1]["n_finished"] == len(res.finished)


# ---------------------------------------------------------------------------
# zero-cost when disabled
# ---------------------------------------------------------------------------
def test_disabled_obs_is_inert_and_identical():
    plain = simulate(_small())
    off = simulate(_small(obs=ObsSpec()))
    full = simulate(_small(obs=ObsSpec.full()))
    assert off.trace is None and off.timeseries is None
    assert plain.summary() == off.summary()
    # enabling obs never changes simulated behavior, only records it
    s_full = full.summary()
    s_plain = plain.summary()
    for k, v in s_plain.items():
        assert s_full[k] == v, k


def test_obsspec_enabled_semantics():
    assert not ObsSpec().enabled
    assert ObsSpec(trace=True).enabled
    assert ObsSpec(timeseries=True).enabled
    assert ObsSpec(attribution=True).enabled
    full = ObsSpec.full(sample_interval=0.25)
    assert full.trace and full.timeseries and full.attribution
    assert full.sample_interval == 0.25
