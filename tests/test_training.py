"""Training: convergence, checkpoint restart, fault supervision,
gradient compression, data determinism."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.distributed.fault import (InjectedFailure, StragglerDetector,
                                     run_with_restarts)
from repro.models import model_zoo as zoo
from repro.training.data import DataConfig, DataPipeline
from repro.training.grad_compress import compress_grads, ef_init, quantize, \
    dequantize
from repro.training.optimizer import AdamWConfig, schedule
from repro.training.trainer import TrainConfig, Trainer


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_smoke_config("llama2-7b")
    return zoo.build(cfg)


def mk_dc(cfg, batch=8, seq=32):
    return DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                      global_batch=batch, seed=0)


def test_loss_decreases(smoke_model):
    tc = TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=5,
                                     total_steps=100))
    tr = Trainer(smoke_model, tc, mk_dc(smoke_model.cfg))
    tr.run(25, log=None)
    assert tr.history[-1]["loss"] < tr.history[0]["loss"] - 0.2


def test_microbatching_equivalence(smoke_model):
    """grad accumulation over 4 microbatches == single big batch."""
    dc = mk_dc(smoke_model.cfg, batch=8)
    t1 = Trainer(smoke_model, TrainConfig(microbatches=1), dc,
                 init_key=jax.random.key(7))
    t4 = Trainer(smoke_model, TrainConfig(microbatches=4), dc,
                 init_key=jax.random.key(7))
    t1.run(3, log=None)
    t4.run(3, log=None)
    for a, b in zip(jax.tree.leaves(t1.params), jax.tree.leaves(t4.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_checkpoint_restart_exact(smoke_model):
    with tempfile.TemporaryDirectory() as d:
        tc = TrainConfig(checkpoint_dir=d, checkpoint_every=10,
                         async_checkpoint=False)
        tr = Trainer(smoke_model, tc, mk_dc(smoke_model.cfg))
        tr.run(10, log=None)
        ref_params = jax.tree.map(np.asarray, tr.params)
        tr.run(5, log=None)          # drift past the step-10 checkpoint
        tr2 = Trainer(smoke_model, tc, mk_dc(smoke_model.cfg))
        assert tr2.step == 10
        for a, b in zip(jax.tree.leaves(ref_params),
                        jax.tree.leaves(tr2.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # continuing from restore matches continuing without crash
        tr2.run(5, log=None)
        for a, b in zip(jax.tree.leaves(tr.params),
                        jax.tree.leaves(tr2.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)


def test_checkpoint_atomicity_tmp_ignored(smoke_model):
    with tempfile.TemporaryDirectory() as d:
        tc = TrainConfig(checkpoint_dir=d, checkpoint_every=5,
                         async_checkpoint=False)
        tr = Trainer(smoke_model, tc, mk_dc(smoke_model.cfg))
        tr.run(5, log=None)
        # simulate a crash mid-write: stray tmp dir must be ignored
        os.makedirs(os.path.join(d, "step_99.tmp"))
        tr2 = Trainer(smoke_model, tc, mk_dc(smoke_model.cfg))
        assert tr2.step == 5


@pytest.mark.slow
def test_run_with_restarts(smoke_model):
    """Supervisor resumes from checkpoints through injected failures."""
    with tempfile.TemporaryDirectory() as d:
        tc = TrainConfig(checkpoint_dir=d, checkpoint_every=2,
                         async_checkpoint=False)
        crashes = {"left": 2}

        class CrashyTrainer(Trainer):
            def run(self, n, log=None):
                for _ in range(n):
                    super().run(1, log=None)
                    # two failures at different points in the run
                    if self.step in (6, 9) and crashes["left"] > 0:
                        crashes["left"] -= 1
                        raise InjectedFailure("node lost")
                return self.history[-1] if self.history else {}

        tr = run_with_restarts(
            lambda: CrashyTrainer(smoke_model, tc, mk_dc(smoke_model.cfg)),
            num_steps=10, log=None)
        assert tr.step == 10
        assert crashes["left"] == 0


def test_straggler_detector():
    sd = StragglerDetector(factor=3.0)
    for _ in range(10):
        assert not sd.record(0.1)
    assert sd.record(1.0)
    assert not sd.record(0.11)


def test_quantize_roundtrip_small_error():
    x = jnp.asarray(np.random.RandomState(0).randn(256) * 0.01)
    q, s = quantize(x)
    err = np.abs(np.asarray(dequantize(q, s) - x)).max()
    assert err <= float(s) / 2 + 1e-9


def test_error_feedback_unbiased_over_time(smoke_model):
    """With EF, the *cumulative* compressed gradient tracks the true one."""
    g = {"w": jnp.asarray(np.random.RandomState(1).randn(64) * 1e-3)}
    ef = ef_init(g)
    total = np.zeros(64)
    for i in range(50):
        deq, ef = compress_grads(g, ef)
        total += np.asarray(deq["w"])
    want = np.asarray(g["w"]) * 50
    assert np.abs(total - want).max() < np.abs(want).max() * 0.05


def test_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(schedule(cfg, 0)) < float(schedule(cfg, 9))
    assert float(schedule(cfg, 9)) == pytest.approx(1e-3, rel=0.01)
    assert float(schedule(cfg, 99)) == pytest.approx(1e-4, rel=0.05)


def test_data_determinism_and_learnability():
    dc = DataConfig(vocab_size=128, seq_len=16, global_batch=4, seed=3)
    p1, p2 = DataPipeline(dc), DataPipeline(dc)
    b1, b2 = p1.batch_at(5), p2.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # markov structure: successor matches the table most of the time
    succ = p1._succ
    hits = (succ[b1["tokens"]] == b1["labels"]).mean()
    assert hits > 0.5
