"""Parallelism cost model (docs/PARALLELISM.md): stage splitting,
topology-aware collectives, pipeline bubbles, and SimSpec wiring."""
import pytest

from repro.configs import get_config
from repro.core.comm import LinkSpec
from repro.core.costmodel.backends import (PipelineBackend,
                                           RooflineBackend, make_backend)
from repro.core.costmodel.hardware import (CLUSTERS, ClusterSpec,
                                           DGX_A100, HARDWARE,
                                           ParallelSpec)
from repro.core.costmodel.operators import BatchMix, OperatorGraph
from repro.core.simulator import SimSpec, Simulation, WorkerSpec, simulate
from repro.core.workload import WorkloadSpec

CFG = get_config("llama2-7b")
A100 = HARDWARE["A100"]
MIX = BatchMix.from_batch([(128, 0)], [100, 200, 300])


def _fixed_wl(n=16, prompt=128, out=16):
    return WorkloadSpec(num_requests=n, qps=0.0, seed=0, lengths="fixed",
                        prompt_len=prompt, output_len=out)


# ---------------------------------------------------------------------------
# ParallelSpec / stage splitting
# ---------------------------------------------------------------------------
def test_parallel_spec_validates():
    with pytest.raises(ValueError):
        ParallelSpec(tp=0)
    with pytest.raises(ValueError):
        ParallelSpec(pp=1, microbatches=0)
    assert ParallelSpec(tp=2, pp=4).devices == 8


@pytest.mark.parametrize("pp", [2, 3, 4, 8])
def test_split_stages_conserves_work(pp):
    g = OperatorGraph.from_config(CFG, tp=2)
    stages = g.split_stages(pp)
    assert len(stages) == pp
    f_full, b_full = g.totals(MIX)
    f_sum = sum(s.totals(MIX)[0] for s in stages)
    b_sum = sum(s.totals(MIX)[1] for s in stages)
    assert f_sum == pytest.approx(f_full, rel=1e-12)
    assert b_sum == pytest.approx(b_full, rel=1e-12)
    assert sum(s.allreduce_count for s in stages) == g.allreduce_count


def test_split_stages_pins_ends():
    g = OperatorGraph.from_config(CFG, tp=1)
    stages = g.split_stages(4)
    names = [[op.name for op in s.ops] for s in stages]
    assert "embed" in names[0]
    assert "lm_head" in names[-1]
    for mid in names[1:-1]:
        assert "embed" not in mid and "lm_head" not in mid


def test_split_stages_identity_for_pp1():
    g = OperatorGraph.from_config(CFG, tp=1)
    assert g.split_stages(1) == [g]


def test_split_stages_every_family():
    for name in ("qwen3-14b", "mamba2-130m", "zamba2-2.7b",
                 "granite-moe-1b-a400m", "whisper-base"):
        cfg = get_config(name)
        g = OperatorGraph.from_config(cfg, tp=1)
        stages = g.split_stages(2)
        f_full, b_full = g.totals(MIX)
        assert sum(s.totals(MIX)[0] for s in stages) == \
            pytest.approx(f_full, rel=1e-12)
        assert sum(s.totals(MIX)[1] for s in stages) == \
            pytest.approx(b_full, rel=1e-12)


# ---------------------------------------------------------------------------
# topology-aware TP collectives
# ---------------------------------------------------------------------------
def test_legacy_flat_term_unchanged_without_cluster():
    g = OperatorGraph.from_config(CFG, tp=4)
    backend = RooflineBackend(hw=A100, graph=g)
    flat = g.collective_bytes_per_token * MIX.new_tokens / A100.link_bw
    assert backend.collective_time(MIX) == pytest.approx(flat)


def test_topology_matches_legacy_on_zero_latency_link():
    """A zero-latency intra link at hw.link_bw bandwidth reproduces the
    legacy flat term exactly — the volume formulas agree."""
    g = OperatorGraph.from_config(CFG, tp=4)
    legacy = RooflineBackend(hw=A100, graph=g)
    cl = ClusterSpec("eq", gpus_per_node=8,
                     intra_link=LinkSpec("x", A100.link_bw, 0.0))
    topo = RooflineBackend(hw=A100, graph=g, cluster=cl)
    assert topo.iteration_time(MIX) == \
        pytest.approx(legacy.iteration_time(MIX), rel=1e-12)


def test_tp_pays_latency_and_inter_node_links():
    g = OperatorGraph.from_config(CFG, tp=4)
    intra = RooflineBackend(hw=A100, graph=g,
                            cluster=CLUSTERS["dgx-a100"])
    inter = RooflineBackend(hw=A100, graph=g,
                            cluster=CLUSTERS["cross-node-100g"])
    legacy = RooflineBackend(hw=A100, graph=g)
    assert intra.iteration_time(MIX) > legacy.iteration_time(MIX)
    assert inter.iteration_time(MIX) > 1.5 * intra.iteration_time(MIX)


def test_cluster_with_legacy_only_graph_keeps_flat_term():
    """A hand-built graph carrying only the flat collective volume (no
    allreduce metadata) must not become communication-free when a
    cluster is set."""
    g = OperatorGraph(cfg=CFG, tp=4, dtype_bytes=2)
    g.collective_bytes_per_token = 1e6
    backend = RooflineBackend(hw=A100, graph=g,
                              cluster=CLUSTERS["dgx-a100"])
    flat = 1e6 * MIX.new_tokens / A100.link_bw
    assert backend.collective_time(MIX) == pytest.approx(flat)


def test_tp1_has_no_collective_cost():
    g = OperatorGraph.from_config(CFG, tp=1)
    backend = RooflineBackend(hw=A100, graph=g,
                              cluster=CLUSTERS["cross-node-100g"])
    assert backend.collective_time(MIX) == 0.0


# ---------------------------------------------------------------------------
# PipelineBackend
# ---------------------------------------------------------------------------
def test_pipeline_bubble_closed_form():
    for pp, m in [(2, 2), (4, 8), (8, 4)]:
        backend = PipelineBackend.for_model(
            CFG, A100, ParallelSpec(pp=pp, microbatches=m), DGX_A100)
        backend.iteration_time(BatchMix.from_batch([], [256] * 64))
        bubble, comm, span = backend.last_breakdown
        assert bubble / span == pytest.approx((pp - 1) / (m + pp - 1))
        assert comm > 0.0


def test_pipeline_microbatches_capped_by_tokens():
    backend = PipelineBackend.for_model(
        CFG, A100, ParallelSpec(pp=2, microbatches=16), DGX_A100)
    backend.iteration_time(BatchMix.from_batch([], [64] * 3))  # 3 tokens
    bubble, _, span = backend.last_breakdown
    assert bubble / span == pytest.approx(1 / 4)   # m=3, pp=2


def test_pipeline_empty_mix_free():
    backend = PipelineBackend.for_model(
        CFG, A100, ParallelSpec(pp=4), DGX_A100)
    assert backend.iteration_time(BatchMix()) == 0.0
    assert backend.last_breakdown == (0.0, 0.0, 0.0)


def test_pipeline_charges_overhead_once():
    """pp=1, m=1 pipeline equals the plain roofline: same work, same
    single iteration overhead."""
    backend = PipelineBackend.for_model(
        CFG, A100, ParallelSpec(pp=1, microbatches=1), DGX_A100)
    plain = RooflineBackend.for_model(CFG, A100, tp=1,
                                      cluster=DGX_A100)
    assert backend.iteration_time(MIX) == \
        pytest.approx(plain.iteration_time(MIX), rel=1e-12)


def test_make_backend_builds_pipeline():
    b = make_backend("roofline", CFG, A100,
                     parallel=ParallelSpec(tp=2, pp=2),
                     cluster=DGX_A100)
    assert isinstance(b, PipelineBackend)
    assert b.pp == 2
    assert all(s.graph.tp == 2 for s in b.stages)
    assert [s.stage for s in b.stages] == [0, 1]
    b2 = make_backend("roofline", CFG, A100, parallel=ParallelSpec(tp=2),
                      cluster=DGX_A100)
    assert isinstance(b2, RooflineBackend)
    assert b2.graph.tp == 2


def test_make_backend_tp_arg_wins_in_pipeline_branch():
    """An explicit tp argument must not be dropped when pp > 1 (same
    precedence as the pp == 1 branch)."""
    b = make_backend("roofline", CFG, A100, tp=4,
                     parallel=ParallelSpec(pp=2), cluster=DGX_A100)
    assert isinstance(b, PipelineBackend)
    assert all(s.graph.tp == 4 for s in b.stages)


def test_replicated_workers_share_custom_backend():
    """backends_by_worker is keyed by original worker index: replicas
    must clone the backend assignment, not fall back to the default."""
    custom = RooflineBackend.for_model(CFG, A100.with_(flops=A100.flops
                                                       * 2))
    sim = Simulation(SimSpec(
        workload=_fixed_wl(4), workers=[WorkerSpec()],
        backends_by_worker={0: custom},
        parallel=ParallelSpec(replicas=2)))
    assert sim.workers[0].backend is custom
    assert sim.workers[1].backend is custom


# ---------------------------------------------------------------------------
# SimSpec wiring
# ---------------------------------------------------------------------------
def test_default_parallel_spec_byte_identical():
    wl = WorkloadSpec(num_requests=40, qps=10.0, seed=7)
    base = simulate(SimSpec(workload=wl))
    par = simulate(SimSpec(workload=wl, parallel=ParallelSpec(),
                           cluster="dgx-a100"))
    assert [(r.id, r.t_first_token, r.t_finish) for r in base.requests] \
        == [(r.id, r.t_first_token, r.t_finish) for r in par.requests]


def test_unknown_cluster_name_raises():
    with pytest.raises(ValueError, match="unknown cluster"):
        Simulation(SimSpec(workload=_fixed_wl(2), cluster="nope"))


def test_pp_sim_finishes_and_accounts():
    spec = SimSpec(workload=_fixed_wl(24),
                   parallel=ParallelSpec(pp=4, microbatches=8),
                   cluster="dgx-a100")
    res = simulate(spec)
    assert len(res.finished) == 24
    summ = res.parallel_summary()
    assert summ["pp_bubble_time"] > 0.0
    assert summ["pp_comm_time"] > 0.0
    assert summ["bubble_fraction"] == pytest.approx(3 / 11, rel=0.02)


def test_pp_rejects_non_roofline_backend():
    with pytest.raises(ValueError, match="roofline"):
        Simulation(SimSpec(workload=_fixed_wl(2), backend="tabular",
                           backend_samples=[],
                           parallel=ParallelSpec(pp=2)))


def test_pipeline_backend_by_worker_still_accounted():
    """A PipelineBackend supplied via backends_by_worker (pp left at 1
    on the spec) must still surface its bubble/comm accounting."""
    pb = PipelineBackend.for_model(CFG, A100,
                                   ParallelSpec(pp=2, microbatches=2),
                                   DGX_A100)
    res = simulate(SimSpec(workload=_fixed_wl(8),
                           backends_by_worker={0: pb}))
    assert res.parallel_stats is not None
    assert res.parallel_summary()["bubble_fraction"] > 0.0


def test_split_stages_keeps_flat_only_collective_volume():
    """A hand-built flat-volume graph keeps its collective cost across
    a stage split (mirrors the collective_time legacy fallback)."""
    g = OperatorGraph(cfg=CFG, tp=4, dtype_bytes=2)
    g.collective_bytes_per_token = 1e6
    stages = g.split_stages(4)
    assert sum(s.collective_bytes_per_token for s in stages) == \
        pytest.approx(1e6)


def test_pp_accounting_scales_with_slowdown():
    """Bubble/comm/span share busy_time's time base: a slowed worker
    scales them all, leaving the bubble fraction unchanged."""
    def run_with(slowdown):
        return simulate(SimSpec(
            workload=_fixed_wl(16),
            workers=[WorkerSpec(slowdown=slowdown)],
            parallel=ParallelSpec(pp=4, microbatches=8),
            cluster="dgx-a100"))

    base, slow = run_with(1.0), run_with(2.0)
    sb, ss = base.parallel_stats[0], slow.parallel_stats[0]
    assert ss["pp_span_time"] == pytest.approx(2 * sb["pp_span_time"])
    assert ss["pp_span_time"] <= ss["busy_time"]
    assert slow.parallel_summary()["bubble_fraction"] == \
        pytest.approx(base.parallel_summary()["bubble_fraction"])


def test_parallel_stats_absent_without_pp():
    res = simulate(SimSpec(workload=_fixed_wl(4)))
    assert res.parallel_stats is None
    assert res.parallel_summary()["bubble_fraction"] == 0.0


def test_replicas_clone_worker_set():
    sim = Simulation(SimSpec(workload=_fixed_wl(8),
                             workers=[WorkerSpec(), WorkerSpec()],
                             parallel=ParallelSpec(replicas=3)))
    assert len(sim.workers) == 6
    res = sim.run()
    assert len(res.finished) == 8
    assert len({r.worker_id for r in res.finished}) > 1


def test_replicas_scale_throughput():
    wl = WorkloadSpec(num_requests=64, qps=0.0, seed=0,
                      lengths="fixed", prompt_len=128, output_len=32)
    one = simulate(SimSpec(workload=wl))
    four = simulate(SimSpec(workload=wl,
                            parallel=ParallelSpec(replicas=4)))
    assert four.throughput() > 1.5 * one.throughput()


def test_pp_scales_kv_capacity():
    base = Simulation(SimSpec(workload=_fixed_wl(2)))
    pp = Simulation(SimSpec(workload=_fixed_wl(2),
                            parallel=ParallelSpec(pp=4),
                            cluster="dgx-a100"))
    nb_base = base.workers[0].mem.mc.num_blocks
    nb_pp = pp.workers[0].mem.mc.num_blocks
    # 4 devices' HBM minus one weight copy > 4x the single-device pool
    assert nb_pp > 4 * nb_base


def test_worker_tp_override_wins():
    sim = Simulation(SimSpec(
        workload=_fixed_wl(2),
        workers=[WorkerSpec(tp=8), WorkerSpec()],
        parallel=ParallelSpec(tp=2), cluster="dgx-a100"))
    assert sim.workers[0].backend.graph.tp == 8
    assert sim.workers[1].backend.graph.tp == 2


def test_tp_composes_with_swap_and_prefix_sharing():
    """Parallelism must not disturb the memory subsystems: a TP+PP sim
    with swap preemption and prefix sharing still drains."""
    wl = WorkloadSpec(num_requests=12, qps=0.0, seed=0, lengths="fixed",
                      prompt_len=96, output_len=24,
                      shared_prefix_len=64, shared_prefix_groups=2)
    res = simulate(SimSpec(
        workload=wl, parallel=ParallelSpec(tp=2, pp=2, microbatches=2),
        cluster="dgx-a100", preemption_mode="swap", prefix_sharing=True))
    assert len(res.finished) == 12
    assert res.memory_summary()["shared_tokens"] > 0
