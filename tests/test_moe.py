"""MoE: dense-onehot vs sort (ragged_dot) paths agree; padding masked."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import moe_init, moe_apply


@pytest.mark.slow
@pytest.mark.parametrize("e,k", [(4, 2), (8, 2), (5, 3)])
def test_dense_vs_sort(e, k):
    key = jax.random.key(0)
    d, dx, t = 32, 16, 24
    p = moe_init(key, d, e, dx, "silu", jnp.float32)
    x = jax.random.normal(jax.random.key(1), (t, d))
    y1, a1 = moe_apply(p, x, top_k=k, n_experts_logical=e,
                       impl="dense_onehot", compute_dtype=jnp.float32)
    y2, a2 = moe_apply(p, x, top_k=k, n_experts_logical=e, impl="sort",
                       compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(a1["aux"]), float(a2["aux"]),
                               rtol=1e-5)


@pytest.mark.slow
def test_padded_experts_get_no_traffic():
    """Experts >= n_experts_logical must receive zero routing weight."""
    key = jax.random.key(2)
    d, dx, t = 16, 8, 40
    e_phys, e_log = 6, 4
    p = moe_init(key, d, e_phys, dx, "silu", jnp.float32)
    x = jax.random.normal(jax.random.key(3), (t, d))
    _, ids, _ = __import__(
        "repro.models.moe", fromlist=["_router"])._router(
        p, x, 2, e_log, jnp.float32)
    assert int(jnp.max(ids)) < e_log
    # output must equal the same model truncated to logical experts
    y_pad, _ = moe_apply(p, x, top_k=2, n_experts_logical=e_log,
                         impl="dense_onehot", compute_dtype=jnp.float32)
    p_log = {kk: (v[:e_log] if kk != "router" else v[:, :e_log])
             for kk, v in p.items()}
    y_log, _ = moe_apply(p_log, x, top_k=2, n_experts_logical=e_log,
                         impl="dense_onehot", compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_pad), np.asarray(y_log),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_grad_flows_both_impls():
    key = jax.random.key(4)
    p = moe_init(key, 16, 4, 8, "silu", jnp.float32)
    x = jax.random.normal(jax.random.key(5), (12, 16))

    for impl in ("dense_onehot", "sort"):
        def loss(p):
            y, aux = moe_apply(p, x, top_k=2, n_experts_logical=4,
                               impl=impl, compute_dtype=jnp.float32)
            return jnp.sum(y ** 2) + 0.01 * aux["aux"]
        g = jax.grad(loss)(p)
        assert all(np.isfinite(np.asarray(v)).all()
                   for v in jax.tree.leaves(g)), impl
