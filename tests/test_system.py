"""End-to-end behaviour tests for the whole system: simulator predictions
about real-engine behaviour hold, and the layered stack composes."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.request import Request
from repro.core.simulator import SimSpec, WorkerSpec
from repro.core.workload import WorkloadSpec, generate
from repro.models import model_zoo as zoo
from repro.serving.engine import EngineConfig, ServingEngine


@pytest.mark.slow
def test_sim_predicts_engine_iteration_count():
    """Continuous batching iteration count is a structural property: the
    simulator and the real engine must agree exactly (same scheduler)."""
    cfg = get_smoke_config("llama2-7b")
    model = zoo.build(cfg)
    params = zoo.init_params(model, jax.random.key(0))
    wl = WorkloadSpec(num_requests=6, qps=0.0, seed=9, lengths="fixed",
                      prompt_len=16, output_len=5)

    reqs = generate(wl)
    eng = ServingEngine(model, params, EngineConfig(
        num_blocks=96, block_size=8, max_batch=4, max_pages_per_seq=8))
    for r in reqs:
        eng.add_request(r)
    eng.run()

    spec = SimSpec(arch=cfg, workers=[WorkerSpec(hw="CPU")], workload=wl,
                   local_policy="continuous", max_batch=4, block_size=8)
    from repro.core.simulator import Simulation
    from repro.core.mem.block_manager import BlockManager, MemoryConfig
    sim = Simulation(spec)
    sim.workers[0].mem = BlockManager(MemoryConfig(
        num_blocks=96, block_size=8, kv_bytes_per_token=1.0))
    sim.run()
    assert sim.workers[0].iterations == len(eng.records)


def test_pallas_attention_inside_model():
    """RunSettings(attn_impl='pallas') routes through the Pallas kernel
    and matches the default path."""
    cfg = get_smoke_config("llama2-7b")
    m_ref = zoo.build(cfg)
    m_pal = m_ref.with_settings(attn_impl="pallas", attn_block_q=32,
                                attn_block_kv=32)
    params = zoo.init_params(m_ref, jax.random.key(1))
    batch = {"tokens": jax.random.randint(jax.random.key(2), (2, 64), 0,
                                          cfg.vocab_size)}
    l_ref, _ = zoo.forward(m_ref, params, batch)
    l_pal, _ = zoo.forward(m_pal, params, batch)
    np.testing.assert_allclose(np.asarray(l_pal, np.float32),
                               np.asarray(l_ref, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_serving_engine_pallas_paged_path():
    cfg = get_smoke_config("llama2-7b")
    model = zoo.build(cfg)
    params = zoo.init_params(model, jax.random.key(3))
    outs = {}
    for path in ("gather", "pallas"):
        eng = ServingEngine(model, params, EngineConfig(
            num_blocks=64, block_size=8, max_batch=2,
            max_pages_per_seq=8, attn_path=path))
        r = Request(id=0, arrival_time=0.0, prompt_len=12, output_len=6)
        eng.add_request(r)
        eng.run()
        outs[path] = list(eng.tokens_by_req[0])
    assert outs["gather"] == outs["pallas"]


def test_hundredM_scale_param_count():
    """examples/train_100m uses a ~100M config; verify the calc here."""
    from repro.configs.base import ArchConfig, DENSE
    cfg = ArchConfig(name="lm-100m", family=DENSE, num_layers=12,
                     d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
                     vocab_size=32000, tie_embeddings=True)
    n = cfg.param_count()
    assert 0.9e8 < n < 1.6e8, n
