"""Mamba2/SSD: chunked scan == recurrence == per-token decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SSMConfig
from repro.models.ssm import (causal_conv1d, conv_step, mamba2_apply,
                              mamba2_decode, mamba2_init, ssd_chunked,
                              ssd_recurrent, ssd_step)


def rand_inputs(key, b, s, h, p, g, n):
    ks = jax.random.split(key, 4)
    xbar = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    dA_log = -dt * jnp.exp(jax.random.uniform(ks[1], (1, 1, h)))
    Bm = jax.random.normal(ks[2], (b, s, g, n))
    Cm = jax.random.normal(ks[3], (b, s, g, n))
    return xbar, dA_log, Bm, Cm


@pytest.mark.parametrize("chunk", [16, 32, 64])
@pytest.mark.parametrize("g", [1, 2])
def test_chunked_equals_recurrent(chunk, g):
    xbar, da, Bm, Cm = rand_inputs(jax.random.key(0), 2, 64, 4, 16, g, 24)
    y1, s1 = ssd_recurrent(xbar, da, Bm, Cm)
    y2, s2 = ssd_chunked(xbar, da, Bm, Cm, chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


def test_step_continues_scan():
    """Decode steps after a prefill match one long scan."""
    xbar, da, Bm, Cm = rand_inputs(jax.random.key(1), 1, 48, 2, 8, 1, 16)
    y_full, _ = ssd_recurrent(xbar, da, Bm, Cm)
    y_pre, state = ssd_chunked(xbar[:, :32], da[:, :32], Bm[:, :32],
                               Cm[:, :32], 16)
    ys = []
    for t in range(32, 48):
        y_t, state = ssd_step(state, xbar[:, t], da[:, t], Bm[:, t],
                              Cm[:, t])
        ys.append(y_t)
    got = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y_full[:, 32:]),
                               rtol=1e-4, atol=1e-4)


def test_conv_step_matches_full():
    key = jax.random.key(2)
    x = jax.random.normal(key, (2, 20, 6))
    w = jax.random.normal(jax.random.key(3), (4, 6))
    b = jax.random.normal(jax.random.key(4), (6,))
    y_full, _ = causal_conv1d(x, w, b)
    state = jnp.zeros((2, 3, 6))
    ys = []
    for t in range(20):
        y_t, state = conv_step(x[:, t], w, b, state)
        ys.append(y_t)
    got = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y_full),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_block_prefill_then_decode_matches_forward():
    cfg = SSMConfig(d_state=16, head_dim=8, expand=2, conv_width=4,
                    chunk_size=16, n_groups=1)
    d_model = 32
    p = mamba2_init(jax.random.key(5), d_model, cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(6), (2, 33, d_model))
    y_full, _ = mamba2_apply(p, x, cfg, compute_dtype=jnp.float32,
                             impl="recurrent")
    y_pre, (cs, ss) = mamba2_apply(p, x[:, :32], cfg,
                                   compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_pre), np.asarray(y_full[:, :32]),
                               rtol=1e-4, atol=1e-4)
    y_t, _ = mamba2_decode(p, x[:, 32], cfg, compute_dtype=jnp.float32,
                           conv_state=cs, ssd_state=ss)
    np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_full[:, 32]),
                               rtol=1e-4, atol=1e-4)


def test_head_mask_zeroes_padded_heads():
    cfg = SSMConfig(d_state=8, head_dim=8, expand=2, conv_width=4,
                    chunk_size=16, n_groups=1)
    d_model = 16                       # 4 logical heads
    p = mamba2_init(jax.random.key(7), d_model, cfg, jnp.float32,
                    n_heads_phys=6)   # 2 padded
    x = jax.random.normal(jax.random.key(8), (1, 16, d_model))
    mask = jnp.array([1, 1, 1, 1, 0, 0], jnp.float32)
    y, _ = mamba2_apply(p, x, cfg, compute_dtype=jnp.float32,
                        head_mask=mask)
    assert np.isfinite(np.asarray(y)).all()
