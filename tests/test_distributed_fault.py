"""Unit tests for repro.distributed.fault: StragglerDetector window and
warm-up semantics, and the run_with_restarts supervisor loop."""
import pytest

from repro.distributed.fault import (InjectedFailure, StragglerDetector,
                                     run_with_restarts)


# ---------------------------------------------------------------------------
# StragglerDetector
# ---------------------------------------------------------------------------
def test_straggler_window_eviction():
    det = StragglerDetector(window=8)
    for i in range(10):
        det.record(float(i))
    assert len(det._times) == 8
    assert det._times == [float(i) for i in range(2, 10)]


def test_straggler_warmup_under_eight_samples():
    det = StragglerDetector()
    for _ in range(6):
        assert det.record(1.0) is False
    # 7th sample is huge but the detector is still warming up
    assert det.record(1000.0) is False
    # 8th sample crosses the warm-up threshold and may flag
    assert det.record(1000.0) is True


def test_straggler_exact_factor_boundary_is_not_flagged():
    det = StragglerDetector(factor=3.0)
    for _ in range(8):
        det.record(1.0)
    # median including the new sample stays 1.0; 3.0 == factor * med is
    # a strict comparison, so the boundary itself is not a straggler
    assert det.record(3.0) is False
    assert det.record(3.0001) is True


def test_straggler_median_tracks_drift():
    det = StragglerDetector(factor=3.0, window=8)
    for _ in range(8):
        det.record(1.0)
    # after the window fills with slower iterations, the old baseline
    # is evicted and the same absolute time stops being a straggler
    for _ in range(8):
        det.record(2.0)
    assert det.record(4.0) is False


# ---------------------------------------------------------------------------
# run_with_restarts
# ---------------------------------------------------------------------------
class _Trainer:
    """Checkpoint-restoring trainer stub: ``step`` persists across
    rebuilds (the checkpoint), ``fail_at`` raises once per listed step."""

    def __init__(self, state, fail_at=None):
        self.state = state
        self.step = state["step"]
        # shared across rebuilds so a consumed failure stays consumed
        self._fail_at = fail_at if fail_at is not None else set()
        state["builds"] = state.get("builds", 0) + 1

    def run(self, remaining, log=None):
        for _ in range(remaining):
            if self.step in self._fail_at:
                self._fail_at.discard(self.step)
                raise InjectedFailure(f"node lost at step {self.step}")
            self.step += 1
            self.state["step"] = self.step


def test_restarts_resume_from_checkpoint_and_finish():
    state = {"step": 0}
    fail_at = {3, 7}
    tr = run_with_restarts(lambda: _Trainer(state, fail_at),
                           num_steps=10, max_restarts=3, log=None)
    assert tr.step == 10
    assert state["builds"] == 3          # initial + one per failure


def test_returns_early_when_checkpoint_already_complete():
    state = {"step": 10}

    class _NeverRun(_Trainer):
        def run(self, remaining, log=None):
            raise AssertionError("run() must not be called")

    tr = run_with_restarts(lambda: _NeverRun(state), num_steps=10,
                           log=None)
    assert tr.step == 10


def test_reraises_after_max_restarts():
    state = {"step": 0}

    def make():
        t = _Trainer(state)
        t._fail_at = {t.step}            # always fails immediately
        return t

    with pytest.raises(InjectedFailure):
        run_with_restarts(make, num_steps=10, max_restarts=2, log=None)
    assert state["builds"] == 3          # initial try + 2 restarts


def test_restart_log_messages_emitted():
    state = {"step": 0}
    fail_at = {2}
    lines = []
    run_with_restarts(lambda: _Trainer(state, fail_at), num_steps=5,
                      max_restarts=3, log=lines.append)
    assert any("restart 1/3" in ln for ln in lines)
