"""Hypothesis shim: use the real library when installed, otherwise run
property tests over a deterministic pseudo-random sample of the same
strategy space so the suite still collects and exercises the invariants
(a pure-pytest fallback; the container has no ``hypothesis``).

Only the strategy combinators the test-suite actually uses are
implemented: ``integers``, ``sampled_from``, ``lists``, ``tuples``.
"""
from __future__ import annotations

import functools
import random

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 - mimics `strategies` module
        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda rng: rng.randint(lo, hi))

        @staticmethod
        def sampled_from(xs):
            xs = list(xs)
            return _Strategy(lambda rng: xs[rng.randrange(len(xs))])

        @staticmethod
        def lists(elem, max_size=10, min_size=0):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elem.draw(rng) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def tuples(*elems):
            return _Strategy(lambda rng: tuple(e.draw(rng) for e in elems))

    def settings(max_examples=50, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*arg_strats, **kw_strats):
        def deco(fn):
            inner = getattr(fn, "__wrapped__", fn)

            @functools.wraps(inner)
            def runner():
                # @settings sits above @given, so it stamps the runner
                n = getattr(runner, "_max_examples", 50)
                rng = random.Random(0xC0FFEE)
                for _ in range(n):
                    args = [s.draw(rng) for s in arg_strats]
                    kwargs = {k: s.draw(rng) for k, s in kw_strats.items()}
                    inner(*args, **kwargs)
            # pytest must see a zero-arg test, not the inner signature
            del runner.__wrapped__
            return runner
        return deco
