"""Hierarchical KV memory: SwapManager, shared-prefix copy-on-write
blocks, and the preemption-mode plumbing (docs/MEMORY.md)."""

import pytest

from repro.core.mem.block_manager import BlockManager, MemoryConfig
from repro.core.mem.swap import PREEMPTION_MODES, SwapConfig, SwapManager
from repro.core.request import Request
from repro.core.simulator import FaultSpec, SimSpec, WorkerSpec, simulate
from repro.core.workload import WorkloadSpec, generate


def mk_req(i, prompt=10, out=5, prefix_id=None, prefix_len=0):
    return Request(id=i, arrival_time=0.0, prompt_len=prompt,
                   output_len=out, prefix_id=prefix_id,
                   prefix_len=prefix_len)


def mk_bm(num_blocks=32, block_size=4, sharing=True):
    return BlockManager(MemoryConfig(num_blocks=num_blocks,
                                     block_size=block_size,
                                     kv_bytes_per_token=1.0,
                                     prefix_sharing=sharing))


# ---------------------------------------------------------------------------
# SwapManager unit behaviour
# ---------------------------------------------------------------------------
def test_swap_latency_formula():
    sc = SwapConfig(pcie_bw=10e9, kv_bytes_per_token=1e6, block_size=16,
                    setup_latency=1e-3, per_block_latency=1e-4)
    sm = SwapManager(sc)
    # 32 tokens -> 2 blocks: setup + 2*per_block + bytes/bw
    expect = 1e-3 + 2 * 1e-4 + 32 * 1e6 / 10e9
    assert sm.transfer_time(32) == pytest.approx(expect)
    r = mk_req(0)
    lat = sm.swap_out(r, 32)
    assert lat == pytest.approx(expect)
    assert sm.used_bytes == 32e6 and sm.holds(r)
    assert sm.swap_in(r) == pytest.approx(expect)
    assert sm.used_bytes == 0 and not sm.holds(r)
    assert sm.bytes_out == sm.bytes_in == 32e6


def test_swap_host_capacity_bound_and_drop_idempotent():
    sm = SwapManager(SwapConfig(host_capacity_bytes=100.0,
                                kv_bytes_per_token=1.0))
    r1, r2 = mk_req(1), mk_req(2)
    assert sm.can_swap_out(60)
    sm.swap_out(r1, 60)
    assert not sm.can_swap_out(60)       # 120 > 100
    assert sm.can_swap_out(40)
    sm.swap_out(r2, 40)
    assert sm.drop(r1) == 60
    assert sm.drop(r1) == 0              # idempotent
    assert sm.used_bytes == 40.0
    sm.drop(r2)
    assert sm.used_bytes == 0.0


def test_free_of_partially_swapped_request():
    """Device blocks and a host copy can coexist mid-swap; releasing
    both tiers restores all capacity exactly once."""
    bm = mk_bm(num_blocks=16, block_size=4, sharing=False)
    sm = SwapManager(SwapConfig(kv_bytes_per_token=1.0))
    r = mk_req(0, prompt=40)
    bm.allocate(r, 40)                   # 10 device blocks
    sm.swap_out(r, 16)                   # 4 blocks' worth parked in host
    assert bm.num_free == 6 and sm.used_bytes == 16.0
    assert bm.free(r) == 10
    assert sm.drop(r) == 16
    assert bm.num_free == 16 and sm.used_bytes == 0.0
    # double free of both tiers: no-ops, no underflow
    assert bm.free(r) == 0
    assert sm.drop(r) == 0
    assert bm.num_free == 16 and sm.used_bytes == 0.0


# ---------------------------------------------------------------------------
# shared-prefix copy-on-write blocks
# ---------------------------------------------------------------------------
def test_prefix_sharing_full_blocks():
    bm = mk_bm()
    a = mk_req(0, prompt=10, prefix_id=7, prefix_len=8)
    b = mk_req(1, prompt=10, prefix_id=7, prefix_len=8)
    bm.allocate(a, 10)                   # 3 blocks, 2 prefix registered
    assert bm.num_used == 3 and a.shared_tokens == 0
    bm.allocate(b, 10)                   # shares the 2 full prefix blocks
    assert bm.num_used == 4              # only b's tail is fresh
    assert b.shared_tokens == 8 and b.cached_len == 8
    assert bm.block_table(a)[:2] == bm.block_table(b)[:2]
    assert bm.ref[bm.block_table(a)[0]] == 2
    # freeing the registrant keeps the sharer's blocks resident
    assert bm.free(a) == 1               # only a's private tail freed
    assert bm.num_used == 3
    assert bm.free(b) == 3
    assert bm.num_free == 32


def test_prefix_partial_tail_not_shared_when_written_past():
    """A request whose tokens extend past the partial tail block must
    not take it by reference (it writes its own tokens there)."""
    bm = mk_bm()
    a = mk_req(0, prompt=10, prefix_id=3, prefix_len=6)   # tail valid=2
    b = mk_req(1, prompt=10, prefix_id=3, prefix_len=6)
    bm.allocate(a, 10)
    bm.allocate(b, 10)
    # only block 0 (full) is shared; both write into their own block 1
    assert bm.block_table(a)[0] == bm.block_table(b)[0]
    assert bm.block_table(a)[1] != bm.block_table(b)[1]
    assert b.shared_tokens == 4


def test_copy_on_write_append_and_rollback_across_boundary():
    """The satellite edge case: a request sharing the partial tail block
    appends (copy-on-write), grows past a boundary, then rolls back
    across that boundary onto the CoW block."""
    bm = mk_bm()
    a = mk_req(0, prompt=6, prefix_id=1, prefix_len=6)
    b = mk_req(1, prompt=6, prefix_id=1, prefix_len=6)
    bm.allocate(a, 6)                    # blocks [f0, p1(valid=2)]
    bm.allocate(b, 6)                    # shares both: prompt == prefix
    assert bm.num_used == 2 and b.shared_tokens == 6
    shared_tail = bm.block_table(b)[1]
    assert bm.ref[shared_tail] == 2
    assert bm.growth_blocks(b, 1) == 1   # CoW copy needed, no boundary
    bm.append_tokens(b, 1)               # CoW fires
    assert b.cow_copies == 1 and bm.cow_copies == 1
    cow_block = bm.block_table(b)[1]
    assert cow_block != shared_tail
    assert bm.ref[shared_tail] == 1 and bm.ref[cow_block] == 1
    assert bm.block_table(a)[1] == shared_tail     # a untouched
    bm.append_tokens(b, 4)               # 7 -> 11 tokens: crosses into b2
    assert len(bm.block_table(b)) == 3
    # rollback across the block boundary back onto the CoW block
    released = bm.rollback_tokens(b, 5)  # 11 -> 6 tokens
    assert released == 1                 # b2 freed; CoW block retained
    assert bm.block_table(b) == [bm.block_table(a)[0], cow_block]
    assert bm.resident_tokens(b) == 6
    # second append after rollback: block is already private, no CoW
    bm.append_tokens(b, 1)
    assert b.cow_copies == 1
    bm.free(a)
    bm.free(b)
    assert bm.num_free == 32


def test_refcount_double_free_protection():
    """Freeing shared-block holders in any order (and repeatedly) never
    double-frees a block or leaks one."""
    bm = mk_bm(num_blocks=16)
    reqs = [mk_req(i, prompt=9, prefix_id=5, prefix_len=8)
            for i in range(3)]
    for r in reqs:
        bm.allocate(r, 9)
    # 2 shared + 3 private tails
    assert bm.num_used == 5
    assert bm.ref[bm.block_table(reqs[0])[0]] == 3
    for r in reqs:
        bm.free(r)
        bm.free(r)                       # double free: no-op
    assert bm.num_free == 16
    assert not bm.ref and not bm.tables
    # the shared index forgot the blocks too: a new allocation re-registers
    c = mk_req(9, prompt=9, prefix_id=5, prefix_len=8)
    bm.allocate(c, 9)
    assert c.shared_tokens == 0          # nothing resident to share


def test_rollback_releases_shared_reference_only():
    bm = mk_bm()
    a = mk_req(0, prompt=8, prefix_id=2, prefix_len=8)
    b = mk_req(1, prompt=8, prefix_id=2, prefix_len=8)
    bm.allocate(a, 8)
    bm.allocate(b, 8)
    assert bm.num_used == 2
    # roll b back into the shared region: drops b's reference on the
    # second shared block (a still holds it), frees nothing
    assert bm.rollback_tokens(b, 5) == 0
    assert bm.num_used == 2 and bm.ref[bm.block_table(a)[1]] == 1
    assert bm.free(a) == 1               # block 0 still held by b
    assert bm.num_used == 1
    assert bm.free(b) == 1
    assert bm.num_free == 32


def test_trie_keeps_block_zero_registration():
    """Regression: physical block id 0 is a live trie payload — pruning
    a sibling registration must not drop it (falsy-payload bug)."""
    bm = mk_bm(num_blocks=8, block_size=4)
    a = mk_req(0, prompt=8, prefix_id=1, prefix_len=8)
    bm.allocate(a, 8)                    # registers blocks 0 and 1
    assert bm.block_table(a)[0] == 0
    b = mk_req(1, prompt=4, prefix_id=1, prefix_len=4)
    bm.allocate(b, 4)                    # shares only block 0
    assert bm.block_table(b) == [0] and bm.ref[0] == 2
    bm.free(a)                           # releases block 1; prunes its node
    # block 0 must still be registered: a third request re-shares it
    c = mk_req(2, prompt=4, prefix_id=1, prefix_len=4)
    bm.allocate(c, 4)
    assert bm.block_table(c) == [0] and c.shared_tokens == 4
    bm.free(b)
    bm.free(c)
    assert bm.num_free == 8


def test_partial_tail_not_shared_with_reserve():
    """Regression: static batching pre-books the whole output
    (reserve), so a request that will write past the partial tail must
    neither count it in can_allocate nor take it at allocation —
    otherwise the reserved append later OOMs on an unbudgeted CoW."""
    bm = mk_bm(num_blocks=2, block_size=4)
    a = mk_req(0, prompt=6, out=4, prefix_id=1, prefix_len=6)
    bm.allocate(a, 6)                    # full block + partial tail
    b = mk_req(1, prompt=6, out=4, prefix_id=1, prefix_len=6)
    # nominal need for 6+4 tokens = 3 blocks; only the full block may
    # resolve via sharing (tail excluded: 10 > 6), so 2 fresh > 0 free
    assert not bm.can_allocate(6, headroom_tokens=4, req=b)
    bm2 = mk_bm(num_blocks=8, block_size=4)
    bm2.allocate(a, 6)
    assert bm2.can_allocate(6, headroom_tokens=4, req=b)
    bm2.allocate(b, 6, reserve=4)
    assert b.shared_tokens == 4          # full block only, tail private
    assert bm2.block_table(a)[1] != bm2.block_table(b)[1]
    # the reserved append proceeds in place with no copy-on-write
    bm2.append_tokens(b, 4)
    assert b.cow_copies == 0


def test_static_batching_with_prefix_sharing_end_to_end():
    wl = WorkloadSpec(num_requests=40, qps=0.0, seed=5, lengths="fixed",
                      prompt_len=32, output_len=16,
                      shared_prefix_len=500, shared_prefix_groups=1)
    res = simulate(SimSpec(
        arch="llama2-7b",
        workers=[WorkerSpec(hw="A100", gpu_mem_util=0.25)],
        workload=wl, local_policy="static", max_batch=16,
        prefix_sharing=True))
    assert len(res.finished) == 40
    assert res.memory_summary()["shared_tokens"] > 0


def test_trace_roundtrip_preserves_prefix_fields(tmp_path):
    from repro.core.workload import save_trace
    wl = WorkloadSpec(num_requests=40, qps=5.0, seed=6,
                      shared_prefix_len=128, shared_prefix_groups=3)
    reqs = generate(wl)
    p = str(tmp_path / "trace.jsonl")
    save_trace(reqs, p)
    reqs2 = generate(WorkloadSpec(num_requests=40, lengths="trace",
                                  trace_path=p))
    assert [(r.prefix_id, r.prefix_len) for r in reqs] == \
        [(r.prefix_id, r.prefix_len) for r in reqs2]
    # double round-trip is a fixed point
    p2 = str(tmp_path / "trace2.jsonl")
    save_trace(reqs2, p2)
    assert open(p).read() == open(p2).read()


def test_can_allocate_accounts_for_shared_blocks():
    bm = mk_bm(num_blocks=6, block_size=4)
    a = mk_req(0, prompt=16, prefix_id=1, prefix_len=16)
    bm.allocate(a, 16)                   # 4 blocks, all registered
    b = mk_req(1, prompt=20, prefix_id=1, prefix_len=16)
    # nominal need = 5 blocks > 2 free, but 4 resolve via sharing
    assert not bm.can_allocate(20)
    assert bm.can_allocate(20, req=b)
    bm.allocate(b, 20)
    assert bm.num_used == 5


# ---------------------------------------------------------------------------
# workload plumbing
# ---------------------------------------------------------------------------
def test_workload_shared_prefix_fields():
    wl = WorkloadSpec(num_requests=60, qps=5.0, seed=0, lengths="fixed",
                      prompt_len=32, output_len=8, shared_prefix_len=100,
                      shared_prefix_groups=2, multi_round_frac=0.5)
    reqs = generate(wl)
    assert all(r.prefix_id in (0, 1) and r.prefix_len == 100
               for r in reqs)
    by_sess = {}
    for r in reqs:
        by_sess.setdefault(r.session_id, []).append(r)
    for rounds in by_sess.values():
        rounds.sort(key=lambda r: r.round_idx)
        # the system prompt rides in the first round's prompt only
        assert rounds[0].prompt_len == 132
        assert len({r.prefix_id for r in rounds}) == 1


def test_workload_without_prefix_unchanged():
    base = WorkloadSpec(num_requests=50, qps=5.0, seed=3)
    a = generate(base)
    b = generate(WorkloadSpec(num_requests=50, qps=5.0, seed=3))
    assert [(r.arrival_time, r.prompt_len) for r in a] == \
        [(r.arrival_time, r.prompt_len) for r in b]
    assert all(r.prefix_id is None and r.prefix_len == 0 for r in a)


# ---------------------------------------------------------------------------
# end-to-end simulation
# ---------------------------------------------------------------------------
def _pressure(mode, **kw):
    d = dict(arch="llama2-7b",
             workers=[WorkerSpec(hw="A100", gpu_mem_util=0.25)],
             workload=WorkloadSpec(num_requests=100, qps=25.0, seed=1),
             preemption_mode=mode)
    d.update(kw)
    return SimSpec(**d)


def test_swap_mode_end_to_end_and_deterministic():
    r1 = simulate(_pressure("swap"))
    r2 = simulate(_pressure("swap"))
    assert len(r1.finished) == 100
    m = r1.memory_summary()
    assert m["swap_preempts"] > 0
    assert m["swap_ins"] == m["swap_preempts"]
    assert m["recompute_preempts"] == 0
    assert m["swap_bytes_out"] > 0
    assert [x.t_finish for x in r1.requests] == \
        [x.t_finish for x in r2.requests]
    # swap counters surface in summary()
    assert r1.summary()["swap_preempts"] == m["swap_preempts"]


def test_swap_differs_from_recompute_under_preemption():
    sw = simulate(_pressure("swap"))
    rec = simulate(_pressure("recompute"))
    assert rec.memory_summary()["swap_preempts"] == 0
    assert rec.memory_summary()["preempts"] > 0
    assert [x.t_finish for x in sw.requests] != \
        [x.t_finish for x in rec.requests]


def test_unknown_preemption_mode_rejected():
    assert "recompute" in PREEMPTION_MODES and "swap" in PREEMPTION_MODES
    with pytest.raises(ValueError):
        simulate(_pressure("hibernate"))


def test_swap_counters_fold_into_streaming_stats():
    """retain_requests=False drops Request objects, so swap/prefix
    counters must survive in StreamingStats (docs/PERFORMANCE.md)."""
    exact = simulate(_pressure("swap"))
    drop = simulate(_pressure("swap", streaming=True,
                              retain_requests=False))
    assert drop.stats is not None
    me, md = exact.memory_summary(), drop.memory_summary()
    for k in ("preempts", "swap_preempts", "swap_ins",
              "shared_tokens", "cow_copies"):
        assert me[k] == md[k], (k, me[k], md[k])
    assert drop.stats.swap_outs == me["swap_preempts"]


def test_prefix_sharing_raises_capacity_end_to_end():
    wl = WorkloadSpec(num_requests=80, qps=0.0, seed=2, lengths="fixed",
                      prompt_len=64, output_len=32,
                      shared_prefix_len=1000, shared_prefix_groups=1)
    def run(share):
        return simulate(SimSpec(
            arch="llama2-7b",
            workers=[WorkerSpec(hw="A100", gpu_mem_util=0.25)],
            workload=wl, prefix_sharing=share))
    on, off = run(True), run(False)
    assert len(on.finished) == len(off.finished) == 80
    mx_on = max(s.n_running for s in on.worker_mem[0])
    mx_off = max(s.n_running for s in off.worker_mem[0])
    assert mx_on >= 1.5 * mx_off, (mx_on, mx_off)
    m = on.memory_summary()
    assert m["prefix_hit_rate"] > 0.5 and m["shared_tokens"] > 0
    assert on.summary()["prefix_hit_rate"] == m["prefix_hit_rate"]
    assert off.memory_summary()["shared_tokens"] == 0


def test_swap_mode_with_worker_failure_no_leak():
    """Killing a worker holding swapped-out requests drops their host
    copies; everything still finishes after re-dispatch."""
    spec = SimSpec(
        arch="llama2-7b",
        workers=[WorkerSpec(hw="A100", gpu_mem_util=0.25),
                 WorkerSpec(hw="A100", gpu_mem_util=0.25)],
        workload=WorkloadSpec(num_requests=80, qps=30.0, seed=4),
        preemption_mode="swap",
        faults=[FaultSpec(time=3.0, worker=0, kind="fail")])
    res = simulate(spec)
    assert len(res.finished) == 80
    # worker 0's host tier drained with it
    assert res.swap_stats[0]["used_bytes"] == 0.0


def test_full_eviction_cascade_plan_not_empty():
    """Regression: when sharing makes every eviction free 0 blocks, the
    loop can preempt every survivor — the resulting plan must not
    report empty, or the worker never applies the evictions and the
    victims strand in ``running`` with freed KV."""
    from collections import deque
    from repro.core.sched.local import ContinuousBatching

    class W:
        pass

    w = W()
    w.mem = BlockManager(MemoryConfig(num_blocks=2, block_size=16,
                                      kv_bytes_per_token=1.0,
                                      prefix_sharing=True))
    w.pool = None
    w.waiting = deque()
    w.running = []
    # two decodes whose whole 32-token context is a shared prefix:
    # freeing either releases no blocks, so both get evicted
    for i in range(2):
        r = mk_req(i, prompt=32, prefix_id=1, prefix_len=32)
        w.mem.allocate(r, 32)
        r.prefill_done_len = 32
        r.tokens_generated = 1
        w.running.append(r)
    assert w.mem.num_free == 0
    plan = ContinuousBatching(max_batch=8, max_batched_tokens=64).plan(w)
    assert len(plan.preempted) == 2 and not plan.decode
    assert not plan.empty, "preemption-only plan must be applied"


def test_block_manager_invariants_with_sharing_random_ops():
    """The property-test invariants, extended for refcounts: free+used
    == total, ref equals table multiplicity, coverage holds."""
    import random
    rng = random.Random(0)
    bm = mk_bm(num_blocks=24, block_size=4)
    reqs = {i: mk_req(i, prompt=12, prefix_id=i % 2, prefix_len=8)
            for i in range(6)}
    for _ in range(400):
        i = rng.randrange(6)
        r = reqs[i]
        op = rng.choice(["alloc", "append", "rollback", "free"])
        try:
            if op == "alloc" and not bm.resident(r):
                bm.allocate(r, rng.randint(8, 20))
            elif op == "append" and bm.resident(r):
                bm.append_tokens(r, rng.randint(1, 6))
            elif op == "rollback" and bm.resident(r):
                n = rng.randint(1, bm.resident_tokens(r))
                bm.rollback_tokens(r, n)
            elif op == "free" and bm.resident(r):
                bm.free(r)
        except MemoryError:
            pass
        assert bm.num_free + bm.num_used == 24
        mult = {}
        for t in bm.tables.values():
            for blk in t:
                mult[blk] = mult.get(blk, 0) + 1
        assert mult == bm.ref, "refcount drift"
        assert set(mult).isdisjoint(bm.free_blocks)
        for rid, table in bm.tables.items():
            assert len(table) * 4 >= bm.token_counts[rid]
    for r in reqs.values():
        if bm.resident(r):
            bm.free(r)
    assert bm.num_free == 24
