"""Property tests for the PagedAttention block manager."""

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.mem.block_manager import BlockManager, MemoryConfig
from repro.core.request import Request


def mk_req(i, prompt=10, out=5):
    return Request(id=i, arrival_time=0.0, prompt_len=prompt, output_len=out)


def test_basic_alloc_free():
    bm = BlockManager(MemoryConfig(num_blocks=10, block_size=4,
                                   kv_bytes_per_token=2.0))
    r = mk_req(0, prompt=9)
    blocks = bm.allocate(r, 9)
    assert len(blocks) == 3              # ceil(9/4)
    assert bm.num_free == 7
    bm.append_tokens(r, 3)               # 12 tokens -> still 3 blocks
    assert bm.num_used == 3
    bm.append_tokens(r, 1)               # 13 -> 4 blocks
    assert bm.num_used == 4
    assert bm.free(r) == 4
    assert bm.num_free == 10


def test_oom_raises():
    bm = BlockManager(MemoryConfig(num_blocks=2, block_size=4))
    r = mk_req(0)
    with pytest.raises(MemoryError):
        bm.allocate(r, 100)


def test_watermark_blocks_admission_only():
    mc = MemoryConfig(num_blocks=10, block_size=4, watermark=0.5)
    bm = BlockManager(mc)
    assert bm.can_allocate(4 * 5, respect_watermark=False)
    assert not bm.can_allocate(4 * 6, respect_watermark=True)
    assert bm.can_allocate(4 * 5, respect_watermark=True)


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "append", "free"]),
                          st.integers(0, 7), st.integers(1, 40)),
                max_size=60),
       st.integers(2, 32), st.integers(8, 64))
def test_invariants_random_ops(ops, block_size, num_blocks):
    """free + used == total; tables disjoint; coverage sufficient."""
    bm = BlockManager(MemoryConfig(num_blocks=num_blocks,
                                   block_size=block_size,
                                   kv_bytes_per_token=1.0))
    reqs = {i: mk_req(i) for i in range(8)}
    for op, rid, n in ops:
        r = reqs[rid]
        try:
            if op == "alloc" and not bm.resident(r):
                bm.allocate(r, n)
            elif op == "append" and bm.resident(r):
                bm.append_tokens(r, n)
            elif op == "free" and bm.resident(r):
                bm.free(r)
        except MemoryError:
            pass
        # --- invariants ---
        assert bm.num_free + bm.num_used == num_blocks
        all_blocks = [b for t in bm.tables.values() for b in t]
        assert len(all_blocks) == len(set(all_blocks)), "block shared!"
        assert set(all_blocks).isdisjoint(set(bm.free_blocks))
        for rid2, table in bm.tables.items():
            toks = bm.token_counts[rid2]
            assert len(table) * block_size >= toks, "coverage violated"


def test_from_model_sizing():
    from repro.configs import get_config
    cfg = get_config("llama2-7b")
    mc = MemoryConfig.from_model(cfg, 80e9, block_size=16, gpu_mem_util=0.9)
    # (0.9*80G - 13.5G params) / (0.5MB/token * 16) ~= 7k blocks, which
    # matches what vLLM logs for llama2-7b fp16 on A100-80G
    assert 5000 < mc.num_blocks < 9000, mc.num_blocks
    kv_gb_per_1k_tokens = mc.kv_bytes_per_token * 1000 / 1e9
    assert 0.3 < kv_gb_per_1k_tokens < 0.7   # ~0.5 GB per 1k tokens


def test_ssm_state_slots():
    from repro.configs import get_config
    cfg = get_config("mamba2-130m")
    mc = MemoryConfig.from_model(cfg, 80e9)
    bm = BlockManager(mc)
    r = mk_req(0, prompt=100000)
    bm.allocate(r, 100000)
    assert bm.num_used == 1              # constant state per seq
    bm.append_tokens(r, 5000)
    assert bm.num_used == 1
