"""Per-arch smoke tests (assignment requirement): reduced same-family
config, one forward/train step on CPU, output shapes + no NaNs; plus
prefill/decode consistency against the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, CONFIGS, get_smoke_config
from repro.models import model_zoo as zoo

ALL_ARCHS = ASSIGNED + ["llama2-7b", "opt-13b"]


def make_batch(cfg, key, b=2, s=32):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size),
             "labels": jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size)}
    if cfg.family in ("audio", "encdec"):
        batch["embeds"] = jax.random.normal(
            ks[2], (b, cfg.enc_seq_len, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_and_train_step(name):
    cfg = get_smoke_config(name)
    model = zoo.build(cfg)
    params = zoo.init_params(model, jax.random.key(0))
    batch = make_batch(cfg, jax.random.key(1))
    logits, aux = jax.jit(zoo.forward, static_argnums=0)(model, params,
                                                         batch)
    b, s = batch["tokens"].shape
    assert logits.shape == (b, s, model.plan.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    loss, metrics = jax.jit(zoo.loss_fn, static_argnums=0)(model, params,
                                                           batch)
    assert np.isfinite(float(loss))
    g = jax.jit(jax.grad(lambda p: zoo.loss_fn(model, p, batch)[0]))(params)
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(g))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_consistency(name):
    cfg = get_smoke_config(name)
    model = zoo.build(cfg)
    params = zoo.init_params(model, jax.random.key(2))
    batch = make_batch(cfg, jax.random.key(3))
    b, s = batch["tokens"].shape

    cache = zoo.init_cache(model, b, s + 4)
    logits_pf, cache = jax.jit(zoo.prefill, static_argnums=0)(
        model, params, batch, cache)
    logits_fw, _ = jax.jit(zoo.forward, static_argnums=0)(model, params,
                                                          batch)
    np.testing.assert_allclose(np.asarray(logits_pf, np.float32),
                               np.asarray(logits_fw, np.float32),
                               rtol=2e-2, atol=2e-2)

    tok = jnp.argmax(logits_pf[:, -1, :model.plan.vocab_logical],
                     -1).astype(jnp.int32)
    logits_d, cache = jax.jit(zoo.decode_step, static_argnums=0)(
        model, params, cache, tok)
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], tok[:, None]], 1)
    batch2.pop("labels")
    logits_fw2, _ = jax.jit(zoo.forward, static_argnums=0)(model, params,
                                                           batch2)
    np.testing.assert_allclose(np.asarray(logits_d, np.float32),
                               np.asarray(logits_fw2[:, -1], np.float32),
                               rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_scan_vs_unrolled(name):
    """scan-over-layers and the unrolled loop are the same function."""
    cfg = get_smoke_config(name)
    m_scan = zoo.build(cfg)
    m_unroll = m_scan.with_settings(scan_layers=False)
    params = zoo.init_params(m_scan, jax.random.key(4))
    batch = make_batch(cfg, jax.random.key(5))
    l1, _ = jax.jit(zoo.forward, static_argnums=0)(m_scan, params, batch)
    l2, _ = jax.jit(zoo.forward, static_argnums=0)(m_unroll, params, batch)
    # bf16 compute fuses differently between lowerings, and MoE top-k can
    # flip on router-logit near-ties for isolated tokens — require 99.5%
    # of logits to agree instead of exact allclose.
    a = np.asarray(l1, np.float32)
    b = np.asarray(l2, np.float32)
    close = np.isclose(a, b, rtol=5e-2, atol=5e-2)
    assert close.mean() > 0.995, f"only {close.mean():.4f} close"


def test_full_configs_match_assignment():
    """The full-size configs carry the exact assigned hyperparameters."""
    spec = {
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "mamba2-130m": (24, 768, None, None, 0, 50280),
        "whisper-base": (12, 512, 8, 8, 2048, 51865),
    }
    for name, (L, d, h, kv, ff, v) in spec.items():
        cfg = CONFIGS[name]
        assert cfg.num_layers == L, name
        assert cfg.d_model == d, name
        if h is not None:
            assert cfg.n_heads == h and cfg.n_kv_heads == kv, name
        assert cfg.d_ff == ff, name
        assert cfg.vocab_size == v, name
    assert CONFIGS["mamba2-130m"].ssm.d_state == 128
    assert CONFIGS["zamba2-2.7b"].ssm.d_state == 64
    assert CONFIGS["granite-moe-1b-a400m"].moe.num_experts == 32
    assert CONFIGS["granite-moe-1b-a400m"].moe.top_k == 8
    assert CONFIGS["granite-moe-3b-a800m"].moe.top_k == 8


def test_param_counts_plausible():
    """Full configs land near their nameplate sizes."""
    approx = {"qwen3-14b": 14e9, "stablelm-3b": 2.8e9,
              "internlm2-1.8b": 1.8e9, "qwen2-0.5b": 0.5e9,
              "chameleon-34b": 34e9, "mamba2-130m": 0.13e9,
              "zamba2-2.7b": 2.7e9, "whisper-base": 0.072e9,
              "granite-moe-1b-a400m": 1.3e9, "granite-moe-3b-a800m": 3.4e9}
    for name, want in approx.items():
        got = CONFIGS[name].param_count()
        assert 0.55 * want < got < 1.8 * want, \
            (name, f"{got:.2e}", f"{want:.2e}")
