"""Heterogeneous multi-model fleet serving (docs/HETEROGENEITY.md):
property-based cross-feature matrix — randomized per-worker (arch, hw,
role, tp) fleets x {recompute, swap} x {exact, streaming} x {faults
on/off} — plus the golden single-model backward-compat pin, the
spec_price/worker-builder agreement regression, model-aware routing
semantics, and per-model Results breakdowns."""
import json
import os

import pytest

from repro.core.costmodel.hardware import ParallelSpec
from repro.core.faults import ChaosSpec, FaultProcess, FaultSpec
from repro.core.metrics import MODEL_SUMMARY_FIELDS
from repro.core.sched.global_sched import (GLOBAL_POLICIES, LeastLoaded,
                                           ModelRouted,
                                           make_global_scheduler)
from repro.core.simulator import (SimSpec, Simulation, WorkerSpec,
                                  effective_tp, simulate)
from repro.core.tenancy import TenantSpec
from repro.core.tenancy.spec import TenantTier
from repro.core.workload import WorkloadSpec, generate, save_trace
from repro.explore.sweep import spec_price, worker_price
from repro.obs import ObsSpec

from _hypothesis_compat import given, settings, st

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "golden", "hetero_pin.json")

BIG, SMALL = "llama2-7b", "qwen2-0.5b"


# ---------------------------------------------------------------------------
# helpers (shared idiom with tests/test_chaos.py)
# ---------------------------------------------------------------------------
def _sig(res):
    """Byte-level signature of a run: per-request ids and timestamps."""
    return [(r.id, r.t_first_token, r.t_finish, tuple(r.token_times))
            for r in sorted(res.requests, key=lambda r: r.id)]


def _assert_exactly_once(res, n_expected):
    fin = [r for r in res.requests if r.t_finish is not None]
    assert len(fin) == n_expected, \
        f"lost requests: {n_expected - len(fin)}"
    ids = [r.id for r in res.requests]
    assert len(ids) == len(set(ids)), "duplicated request objects"
    for r in fin:
        assert r.tokens_generated == r.output_len, r.id
        assert len(r.token_times) == r.output_len, r.id
        assert all(b >= a for a, b in zip(r.token_times,
                                          r.token_times[1:])), r.id


def _assert_attribution_conserved(res, tol=1e-6):
    for r in res.requests:
        if r.t_finish is None or r.obs is None or r.obs.final is None:
            continue
        f = r.obs.final
        ttft = r.t_first_token - r.arrival_time
        assert abs(sum(f["ttft"].values()) - ttft) < tol, r.id
        dec = r.t_finish - r.t_first_token
        assert abs(sum(f["decode"].values()) - dec) < tol, r.id


def _assert_no_cross_model_dispatch(sim):
    """Every worker only ever saw requests for the model it hosts."""
    for w in sim.workers:
        assert w.served_models <= {w.model}, \
            f"worker {w.wid} ({w.model}) served {w.served_models}"


def _two_model_tenants(n_each, *, seed):
    return [
        TenantSpec(tenant_id="big", tier=TenantTier(),
                   workload=WorkloadSpec(num_requests=n_each, qps=10.0,
                                         seed=seed, model=BIG)),
        TenantSpec(tenant_id="small", tier=TenantTier(),
                   workload=WorkloadSpec(num_requests=n_each, qps=10.0,
                                         seed=seed + 1, model=SMALL)),
    ]


# ---------------------------------------------------------------------------
# property suite: random fleets x preemption x arrival mode x faults
# ---------------------------------------------------------------------------
#: extra workers beyond the two per-model "both" anchors: (model index,
#: hardware index, role, tp) — roles exercise disagg routing inside a
#: model's host subset, tp the per-worker override.  Hardware and
#: memory budget are resolved per model by ``_ws`` so every drawn
#: worker can actually fit its model's weights (a worker whose budget
#: cannot hold the weights admits nothing and stalls its requests —
#: true for homogeneous fleets too, not what this suite probes)
_EXTRA = st.lists(
    st.tuples(st.integers(0, 1),
              st.integers(0, 2),
              st.sampled_from(["both", "prefill", "decode"]),
              st.integers(1, 2)),
    max_size=2)

#: per-model feasible (hardware, gpu_mem_util) pools: the 7B model
#: needs headroom for ~13.5 GB of fp16 weights, the 0.5B one fits
#: anywhere (utils kept low for preemption pressure)
_POOLS = {BIG: [("A100", 0.2), ("V100", 0.5), ("A100", 0.35)],
          SMALL: [("L4", 0.12), ("V100", 0.12), ("A100", 0.1)]}


def _ws(model, hw_i, role="both", tp=1):
    hw, util = _POOLS[model][hw_i]
    return WorkerSpec(hw=hw, arch=model, role=role, tp=tp,
                      gpu_mem_util=util)


def _fleet_spec(extra, mode, streaming, faulty):
    models = (BIG, SMALL)
    workers = [_ws(BIG, 0), _ws(SMALL, 0)]
    for mi, hw_i, role, tp in extra:
        workers.append(_ws(models[mi], hw_i, role, tp))
    faults = [FaultSpec(time=2.0, worker=0, kind="fail", duration=1.0),
              FaultSpec(time=3.0, worker=1, kind="degrade", factor=3.0,
                        duration=1.0)] if faulty else []
    return SimSpec(
        arch=BIG,
        workers=workers,
        global_policy="model_routed",
        tenants=_two_model_tenants(30, seed=11),
        preemption_mode=mode,
        streaming=streaming,
        faults=faults,
        chaos=ChaosSpec(reload_time=0.5, warmup_iters=1,
                        warmup_factor=2.0) if faulty else None,
        obs=ObsSpec(attribution=True))


@settings(max_examples=10)
@given(extra=_EXTRA,
       mode=st.sampled_from(["recompute", "swap"]),
       streaming=st.sampled_from([False, True]),
       faulty=st.sampled_from([False, True]))
def test_hetero_fleet_invariants(extra, mode, streaming, faulty):
    """Under any random heterogeneous fleet, either preemption mode,
    either arrival mode, with or without faults: every request finishes
    exactly once, no worker ever receives a request for a model it does
    not host, latency attribution still sums to the measured spans, and
    the same seed reproduces the run byte-for-byte."""
    sim = Simulation(_fleet_spec(extra, mode, streaming, faulty))
    r1 = sim.run()
    _assert_exactly_once(r1, 60)
    _assert_no_cross_model_dispatch(sim)
    _assert_attribution_conserved(r1)
    assert set(r1.model_ids()) >= {BIG, SMALL}
    r2 = simulate(_fleet_spec(extra, mode, streaming, faulty))
    assert _sig(r1) == _sig(r2)
    assert (r1.fault_events or []) == (r2.fault_events or [])


# ---------------------------------------------------------------------------
# golden backward-compat pin: the worker-construction refactor must not
# move a single byte of a pre-hetero single-model run
# ---------------------------------------------------------------------------
def test_golden_single_model_pin():
    import sys
    sys.path.insert(0, os.path.dirname(GOLDEN))
    try:
        from gen_hetero_pin import pinned_spec, snapshot
        from pin_io import load_pin
    finally:
        sys.path.pop(0)
    want = load_pin(GOLDEN)
    got = json.loads(json.dumps(snapshot(simulate(pinned_spec()))))
    assert got == want, \
        "single-model run diverged from the pre-refactor golden pin"


# ---------------------------------------------------------------------------
# spec_price agreement with the worker builder
# ---------------------------------------------------------------------------
def test_spec_price_matches_built_fleet():
    """The price model and the worker builder resolve tp through the
    same ``effective_tp``: pricing the *built* fleet device-by-device
    must equal ``spec_price`` of the spec."""
    spec = SimSpec(
        workers=[WorkerSpec(hw="A100", tp=2),
                 WorkerSpec(hw="L4", arch=SMALL),
                 WorkerSpec(hw="V100", hw_overrides={"price": 0.3})],
        global_policy="model_routed",
        parallel=ParallelSpec(tp=2, replicas=2),
        workload=WorkloadSpec(num_requests=1, qps=1.0, seed=0))
    sim = Simulation(spec)
    pp = spec.parallel.pp
    built = sum(w.hw.price * w.tp * pp for w in sim.workers)
    assert built == pytest.approx(spec_price(spec))
    # and per-worker: builder tp == price-model tp, price matches
    for i, w in enumerate(sim.workers):
        ws = spec.workers[i % len(spec.workers)]
        assert w.tp == effective_tp(ws, spec.parallel)
        assert worker_price(ws, spec.parallel) == \
            pytest.approx(w.hw.price * w.tp * pp)


# ---------------------------------------------------------------------------
# routing semantics
# ---------------------------------------------------------------------------
def test_model_routed_registry_and_hetero_alias():
    assert "model_routed" in GLOBAL_POLICIES
    sched = make_global_scheduler("model_routed")
    assert isinstance(sched, ModelRouted)
    assert isinstance(sched.inner, LeastLoaded)
    for alias in ("hetero", "heterogeneity_aware"):
        s = make_global_scheduler(alias)
        assert isinstance(s, ModelRouted), \
            f"{alias} must be upgraded to model routing"
    with pytest.raises(ValueError):
        ModelRouted(inner=LeastLoaded(), aging_rate=1.0)


def test_model_routed_passthrough_byte_identical():
    """On a single-model fleet the wrapper must be inert: same dispatch
    sequence, same bytes, as its inner policy run bare."""
    base = dict(workers=[WorkerSpec(), WorkerSpec()],
                workload=WorkloadSpec(num_requests=80, qps=12.0, seed=5))
    bare = simulate(SimSpec(**base, global_policy="least_loaded"))
    wrapped = simulate(SimSpec(**base, global_policy="model_routed"))
    assert _sig(bare) == _sig(wrapped)
    assert bare.sim_time == wrapped.sim_time


def test_multi_model_fleet_rejects_model_blind_policy():
    spec = SimSpec(workers=[WorkerSpec(arch=BIG),
                            WorkerSpec(hw="L4", arch=SMALL)],
                   global_policy="least_loaded",
                   tenants=_two_model_tenants(5, seed=1))
    with pytest.raises(ValueError, match="model-blind"):
        Simulation(spec)


def test_workload_model_must_be_hosted():
    spec = SimSpec(workers=[WorkerSpec(arch=BIG)],
                   global_policy="model_routed",
                   workload=WorkloadSpec(num_requests=5, qps=5.0, seed=0,
                                         model=SMALL))
    with pytest.raises(ValueError, match="hosts only"):
        Simulation(spec)


def test_disagg_roles_respected_within_model_subset():
    """Prefill/decode split inside one model's host subset: requests
    migrate between that model's workers only, roles honored."""
    sim = Simulation(SimSpec(
        arch=BIG,
        workers=[WorkerSpec(arch=BIG, role="prefill"),
                 WorkerSpec(arch=BIG, role="decode"),
                 WorkerSpec(hw="L4", arch=SMALL)],
        global_policy="model_routed",
        tenants=_two_model_tenants(20, seed=3)))
    r = sim.run()
    _assert_exactly_once(r, 40)
    _assert_no_cross_model_dispatch(sim)
    # the decode worker of the BIG subset actually decoded
    assert sim.workers[1].tokens_emitted > 0


# ---------------------------------------------------------------------------
# per-model Results breakdowns
# ---------------------------------------------------------------------------
def _hetero_run(**kw):
    spec = SimSpec(arch=BIG,
                   workers=[WorkerSpec(arch=BIG, gpu_mem_util=0.3),
                            WorkerSpec(hw="L4", arch=SMALL,
                                       gpu_mem_util=0.3)],
                   global_policy="model_routed",
                   tenants=_two_model_tenants(30, seed=7), **kw)
    return simulate(spec)


def test_model_summary_fields_and_conservation():
    r = _hetero_run()
    ms = r.model_summary()
    assert sorted(ms) == sorted([BIG, SMALL])
    for row in ms.values():
        assert set(row) == set(MODEL_SUMMARY_FIELDS)
    # per-model counters sum to the aggregate
    assert sum(row["n_finished"] for row in ms.values()) == \
        len(r.finished)
    assert sum(row["tokens"] for row in ms.values()) == \
        sum(q.tokens_generated for q in r.finished)
    assert all(row["n_workers"] == 1 for row in ms.values())
    assert r.default_model == BIG
    assert sorted(set(r.worker_models.values())) == [BIG, SMALL]


def test_model_summary_streaming_matches_exact_counts():
    exact = _hetero_run()
    stream = _hetero_run(retain_requests=False,
                         streaming_slo=(0.5, 0.1))
    me, ms = exact.model_summary(), stream.model_summary(
        ttft_slo=0.5, mtpot_slo=0.1)
    assert sorted(me) == sorted(ms)
    for m in me:
        assert set(ms[m]) == set(MODEL_SUMMARY_FIELDS)
        assert ms[m]["n_finished"] == me[m]["n_finished"]
        assert ms[m]["tokens"] == me[m]["tokens"]
        # sketch quantiles track the exact ones within a few percent
        assert ms[m]["latency_p50"] == pytest.approx(
            me[m]["latency_p50"], rel=0.05)
        assert 0.0 <= ms[m]["slo_attainment"] <= 1.0


def test_model_targeted_fault_process_and_availability():
    """FaultProcess(worker=-1, model=...) expands to every hosting
    worker; per-model availability only dips for the targeted model."""
    spec = SimSpec(
        arch=BIG,
        workers=[WorkerSpec(arch=BIG, gpu_mem_util=0.3),
                 WorkerSpec(hw="L4", arch=SMALL, gpu_mem_util=0.3),
                 WorkerSpec(hw="L4", arch=SMALL, gpu_mem_util=0.3)],
        global_policy="model_routed",
        tenants=_two_model_tenants(40, seed=5),
        chaos=ChaosSpec(
            processes=(FaultProcess(worker=-1, model=SMALL, mtbf=4.0,
                                    mttr=0.5, seed=3, max_events=2),),
            reload_time=0.5))
    sim = Simulation(spec)
    r = sim.run()
    _assert_exactly_once(r, 80)
    _assert_no_cross_model_dispatch(sim)
    small_wids = {w.wid for w in sim.workers if w.model == SMALL}
    assert {e.worker for e in r.fault_events} <= small_wids
    av = r.availability_summary()["models"]
    assert av[BIG]["capacity_availability"] == 1.0
    assert av[SMALL]["capacity_availability"] < 1.0
    assert av[SMALL]["n_workers"] == 2
    # a model-targeted process naming an unhosted model fails fast
    bad = SimSpec(
        workers=[WorkerSpec()],
        workload=WorkloadSpec(num_requests=2, qps=5.0, seed=0),
        chaos=ChaosSpec(processes=(
            FaultProcess(worker=-1, model="nope", mtbf=5.0),)))
    with pytest.raises(ValueError, match="matches no"):
        simulate(bad)


def test_trace_round_trips_model(tmp_path):
    """save_trace keeps per-request model tags; replaying the trace
    reproduces them (and untagged traces stay tag-free)."""
    reqs = generate(WorkloadSpec(num_requests=10, qps=5.0, seed=2,
                                 model=SMALL))
    assert all(q.model == SMALL for q in reqs)
    p = tmp_path / "trace.jsonl"
    save_trace(reqs, str(p))
    back = generate(WorkloadSpec(lengths="trace", arrival="trace",
                                 trace_path=str(p)))
    assert [q.model for q in back] == [SMALL] * 10
    plain = generate(WorkloadSpec(num_requests=3, qps=5.0, seed=2))
    assert all(q.model is None for q in plain)
    p2 = tmp_path / "plain.jsonl"
    save_trace(plain, str(p2))
    with open(p2) as f:
        assert all("model" not in json.loads(line) for line in f)
