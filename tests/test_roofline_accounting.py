"""Validates the roofline HLO accounting (benchmarks/roofline_report):
counting-mode (unrolled layers) + analytic attention-loop correction must
match a fully-counted compile (naive attention, no loops) at small scale.
"""
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, DENSE
from repro.models import model_zoo as zoo
from benchmarks.roofline_report import (_tri_pairs, attn_correction,
                                        cost_analysis_dict)


def _flops(model, batch):
    params_s = zoo.param_specs(model)

    def fwd(p, b):
        return zoo.forward(model, p, b)[0]

    lowered = jax.jit(fwd).lower(params_s, batch)
    return cost_analysis_dict(lowered.compile().cost_analysis())["flops"]


def test_unrolled_plus_correction_matches_loopfree():
    cfg = ArchConfig(name="t", family=DENSE, num_layers=3, d_model=64,
                     n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
                     vocab_size=512)
    batch = {"tokens": jax.ShapeDtypeStruct((2, 256), jnp.int32)}

    # ground truth: unrolled layers + naive attention (no loops at all)
    m_true = zoo.build(cfg).with_settings(scan_layers=False,
                                          attn_impl="naive")
    f_true = _flops(m_true, batch)

    # counting mode: unrolled layers + blocked attention (inner loop)
    m_count = zoo.build(cfg).with_settings(scan_layers=False,
                                           attn_impl="blocked",
                                           attn_block_q=64,
                                           attn_block_kv=64)
    f_count = _flops(m_count, batch)
    assert f_count < f_true          # inner loop undercounts

    # the analytic correction: (pairs-1) per layer
    pairs = (256 // 64) * (256 // 64)
    f_pair = 4.0 * 2 * 64 * 64 * 4 * 16        # 4*B*bq*bk*Hq*hd
    corrected = f_count + (pairs - 1) * 3 * f_pair
    # The correction counts matmul FLOPs only; naive attention's softmax
    # elementwise ops (5*B*H*S^2) sit outside it. At this toy size
    # (hd=16) that's ~5% of attention; at production head dims (128) it
    # is <1%, so the matmul-only correction is the right accounting.
    assert abs(corrected - f_true) / f_true < 0.08, \
        (corrected, f_true, f_count)


def test_tri_pairs():
    assert _tri_pairs(4, 4, 64, 64) == 10       # lower triangle of 4x4
    assert _tri_pairs(4, 8, 128, 64) == 2 + 4 + 6 + 8
    assert _tri_pairs(1, 1, 64, 64) == 1


def test_attn_correction_zero_for_decode_and_ssm():
    f, b = attn_correction("qwen3-14b", "decode_32k", {}, 256)
    assert f == 0.0 and b == 0.0
    f, b = attn_correction("mamba2-130m", "train_4k",
                           {"attn_impl": "blocked"}, 256)
    assert f == 0.0 and b == 0.0


def test_attn_correction_positive_for_long_prefill():
    f, b = attn_correction(
        "qwen3-14b", "prefill_32k",
        {"attn_impl": "blocked", "attn_block_q": 1024,
         "attn_block_kv": 1024, "remat": "full"}, 256)
    assert f > 0 and b > 0
    # causal variant must be about half the rectangle
    f2, _ = attn_correction(
        "qwen3-14b", "prefill_32k",
        {"attn_impl": "blocked_causal", "attn_block_q": 1024,
         "attn_block_kv": 1024}, 256)
    assert 0.4 < f2 / f < 0.6
