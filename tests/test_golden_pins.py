"""Golden-pin hygiene: pins stay compressed and loadable."""
import glob
import os
import sys

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "golden")
MAX_UNCOMPRESSED = 1 << 20        # 1MB


def test_no_large_uncompressed_pins():
    """Pins are ~1MB of JSON each and belong in git as .json.gz; a
    regen script writing a large plain .json again should fail CI, not
    bloat the repo."""
    offenders = [
        os.path.basename(p)
        for p in glob.glob(os.path.join(GOLDEN_DIR, "*.json"))
        if os.path.getsize(p) > MAX_UNCOMPRESSED]
    assert not offenders, \
        (f"uncompressed pins over 1MB in tests/golden/: {offenders} — "
         f"store them gzipped via pin_io.save_pin (regen scripts do "
         f"this already)")


def test_every_gz_pin_loads_via_logical_path():
    """load_pin resolves the logical *.json name to its .gz sibling."""
    sys.path.insert(0, GOLDEN_DIR)
    try:
        from pin_io import load_pin
    finally:
        sys.path.pop(0)
    pins = glob.glob(os.path.join(GOLDEN_DIR, "*.json.gz"))
    assert pins, "no golden pins found"
    for gz in pins:
        pin = load_pin(gz[:-len(".gz")])
        assert isinstance(pin, dict) and "sim_time" in pin, \
            f"{os.path.basename(gz)} did not load as a pin snapshot"
