"""Exploration harness (repro.explore): grid expansion, the resumable
per-point cache, Pareto extraction, and multiprocessing fan-out."""
import json
import os

import pytest

from repro.core.costmodel.hardware import ParallelSpec
from repro.core.simulator import SimSpec, WorkerSpec
from repro.core.workload import WorkloadSpec
from repro.explore import (DEFAULT_OBJECTIVES, SweepSpec, dominates,
                           grid_points, pareto_frontier, point_key,
                           run_sweep, spec_price)


def _tiny_builder(point):
    """Module-level so the multiprocessing pool can pickle it."""
    return SimSpec(
        arch="llama2-7b",
        workload=WorkloadSpec(num_requests=4, qps=0.0, seed=0,
                              lengths="fixed", prompt_len=point["prompt"],
                              output_len=4),
        parallel=ParallelSpec(tp=point["tp"]),
        cluster="dgx-a100")


TINY_AXES = {"prompt": [32, 64], "tp": [1, 2]}


# ---------------------------------------------------------------------------
# grid + keys
# ---------------------------------------------------------------------------
def test_grid_points_product_and_order():
    pts = grid_points(TINY_AXES)
    assert len(pts) == 4
    assert pts[0] == {"prompt": 32, "tp": 1}
    assert pts == grid_points(TINY_AXES)        # stable


def test_point_key_stable_and_distinct():
    pts = grid_points(TINY_AXES)
    keys = [point_key(p) for p in pts]
    assert len(set(keys)) == len(keys)
    assert keys == [point_key(p) for p in grid_points(TINY_AXES)]
    # key order inside the dict must not matter
    assert point_key({"a": 1, "b": 2}) == point_key({"b": 2, "a": 1})


def test_spec_price_counts_devices():
    spec = SimSpec(workers=[WorkerSpec(hw="A100")],
                   parallel=ParallelSpec(tp=2, pp=2, replicas=3))
    assert spec_price(spec) == pytest.approx(12.0)   # 2*2*3 A100s
    spec2 = SimSpec(workers=[WorkerSpec(hw="V100", tp=4)],
                    parallel=ParallelSpec(tp=2))
    assert spec2.workers[0].tp == 4
    assert spec_price(spec2) == pytest.approx(0.25 * 4)
    # hw_overrides reach the price model, matching the simulated worker
    spec3 = SimSpec(workers=[WorkerSpec(hw="A100",
                                        hw_overrides={"price": 2.5})])
    assert spec_price(spec3) == pytest.approx(2.5)


# ---------------------------------------------------------------------------
# resumable sweep cache
# ---------------------------------------------------------------------------
def test_sweep_runs_and_resumes(tmp_path):
    sweep = SweepSpec(name="t", builder=_tiny_builder, axes=TINY_AXES)
    out = str(tmp_path / "sweep")
    r1 = run_sweep(sweep, out)
    assert r1.n_simulated == 4 and r1.n_cached == 0
    assert len(r1.rows) == 4
    assert os.path.exists(r1.csv_path)
    assert os.path.exists(r1.pareto_path)
    assert all(row["throughput"] > 0 for row in r1.rows)

    # full re-run: everything cached, nothing simulated
    r2 = run_sweep(sweep, out)
    assert r2.n_simulated == 0 and r2.n_cached == 4
    assert r2.rows == r1.rows

    # kill two points ("sweep died half way"): only they re-simulate
    pts = grid_points(TINY_AXES)
    for p in pts[:2]:
        os.remove(os.path.join(out, "points", f"{point_key(p)}.json"))
    r3 = run_sweep(sweep, out)
    assert r3.n_simulated == 2 and r3.n_cached == 2
    assert r3.rows == r1.rows                  # deterministic sim


def test_default_metrics_reads_streaming_sketches():
    """Drop-mode specs must not produce NaN objectives: the metrics row
    falls back to the StreamingStats sketches."""
    from repro.core.simulator import simulate
    from repro.explore import default_metrics
    spec = SimSpec(
        arch="llama2-7b",
        workload=WorkloadSpec(num_requests=64, qps=50.0, seed=0,
                              lengths="fixed", prompt_len=32,
                              output_len=8),
        streaming=True, retain_requests=False)
    row = default_metrics(spec, simulate(spec))
    assert row["throughput"] > 0
    assert row["p99_ttft"] == row["p99_ttft"]          # not NaN
    assert row["cost_per_1k_tokens"] == row["cost_per_1k_tokens"]
    assert row["finished"] == 64


def test_sweep_version_salts_cache_and_force_resimulates(tmp_path):
    """A version bump (cost model changed) or force=True must ignore
    the existing cache instead of serving stale results."""
    out = str(tmp_path / "sweep")
    v1 = SweepSpec(name="t", builder=_tiny_builder, axes=TINY_AXES,
                   version="v1")
    assert run_sweep(v1, out).n_simulated == 4
    assert run_sweep(v1, out).n_simulated == 0
    v2 = SweepSpec(name="t", builder=_tiny_builder, axes=TINY_AXES,
                   version="v2")
    assert run_sweep(v2, out).n_simulated == 4       # keys differ
    assert point_key({"a": 1}, "v1") != point_key({"a": 1}, "v2")
    r = run_sweep(v2, out, force=True)
    assert r.n_simulated == 4 and r.n_cached == 0


def test_sweep_rejects_corrupt_and_mismatched_cache(tmp_path):
    sweep = SweepSpec(name="t", builder=_tiny_builder, axes=TINY_AXES)
    out = str(tmp_path / "sweep")
    run_sweep(sweep, out)
    pts = grid_points(TINY_AXES)
    p0 = os.path.join(out, "points", f"{point_key(pts[0])}.json")
    with open(p0, "w") as f:
        f.write("{ not json")                  # torn write
    p1 = os.path.join(out, "points", f"{point_key(pts[1])}.json")
    with open(p1, "w") as f:
        json.dump({"point": {"different": 1}, "metrics": {}}, f)
    r = run_sweep(sweep, out)
    assert r.n_simulated == 2 and r.n_cached == 2


def test_sweep_multiprocessing(tmp_path):
    sweep = SweepSpec(name="t", builder=_tiny_builder, axes=TINY_AXES)
    out = str(tmp_path / "mp")
    r = run_sweep(sweep, out, processes=2)
    assert r.n_simulated == 4
    # identical metrics to the inline run (deterministic DES)
    r_inline = run_sweep(sweep, str(tmp_path / "inline"))
    assert r.rows == r_inline.rows


# ---------------------------------------------------------------------------
# pareto
# ---------------------------------------------------------------------------
def test_dominates():
    assert dominates((2.0, 1.0), (1.0, 1.0))
    assert not dominates((1.0, 1.0), (1.0, 1.0))
    assert not dominates((2.0, 0.5), (1.0, 1.0))


def test_pareto_frontier_directions():
    rows = [
        {"throughput": 10.0, "p99_ttft": 1.0, "cost_per_1k_tokens": 1.0},
        {"throughput": 20.0, "p99_ttft": 2.0, "cost_per_1k_tokens": 2.0},
        {"throughput": 5.0, "p99_ttft": 2.0, "cost_per_1k_tokens": 2.0},
        {"throughput": 10.0, "p99_ttft": 1.0, "cost_per_1k_tokens": 0.5},
    ]
    front = pareto_frontier(rows, DEFAULT_OBJECTIVES)
    assert rows[0] not in front                # dominated by rows[3]
    assert rows[1] in front                    # best throughput
    assert rows[2] not in front
    assert rows[3] in front


def test_pareto_excludes_nan_and_missing():
    rows = [{"throughput": float("nan"), "p99_ttft": 0.0,
             "cost_per_1k_tokens": 0.0},
            {"throughput": 1.0, "p99_ttft": 1.0,
             "cost_per_1k_tokens": 1.0},
            {"p99_ttft": 0.0, "cost_per_1k_tokens": 0.0}]
    front = pareto_frontier(rows, DEFAULT_OBJECTIVES)
    assert front == [rows[1]]


def test_pareto_bad_direction_raises():
    with pytest.raises(ValueError, match="direction"):
        pareto_frontier([{"x": 1.0}], {"x": "upward"})


def test_sweep_csv_has_frontier_subset(tmp_path):
    sweep = SweepSpec(name="t", builder=_tiny_builder, axes=TINY_AXES)
    out = str(tmp_path / "sweep")
    r = run_sweep(sweep, out)
    assert 1 <= len(r.frontier) <= len(r.rows)
    with open(r.pareto_path) as f:
        lines = [ln for ln in f.read().splitlines() if ln.strip()]
    assert len(lines) == len(r.frontier) + 1   # header + rows
