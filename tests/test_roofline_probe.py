"""Depth-probe extrapolation correctness: linear reconstruction from two
reduced depths must equal the directly-compiled deeper model."""
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, DENSE
from repro.models import model_zoo as zoo
from benchmarks.roofline_report import cost_analysis_dict, extrapolate


def _cost(cfg, depth):
    c = cfg.with_overrides(num_layers=depth)
    model = zoo.build(c).with_settings(scan_layers=False,
                                       attn_impl="naive")
    params_s = zoo.param_specs(model)
    batch = {"tokens": jax.ShapeDtypeStruct((2, 128), jnp.int32)}
    comp = jax.jit(lambda p, b: zoo.forward(model, p, b)[0]) \
        .lower(params_s, batch).compile()
    return cost_analysis_dict(comp.cost_analysis())


BASE = ArchConfig(name="probe-test", family=DENSE, num_layers=6,
                  d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
                  d_ff=128, vocab_size=512)


def test_linear_in_depth_and_extrapolation(monkeypatch):
    c2, c4, c6 = (_cost(BASE, d) for d in (2, 4, 6))
    # affine in depth: f(6) == f(4) + (f(4) - f(2))
    want = c4["flops"] + (c4["flops"] - c2["flops"])
    assert abs(want - c6["flops"]) / c6["flops"] < 1e-6

    # the report's extrapolate() reproduces the full-depth numbers
    import benchmarks.roofline_report as rr
    monkeypatch.setattr(rr, "get_config", lambda name: BASE)
    ra = {"arch": "probe-test", "shape": "train_4k", "mesh": "16x16",
          "n_devices": 256, "depth_override": 2,
          "cost": {"flops": c2["flops"],
                   "bytes accessed": c2["bytes accessed"]},
          "collectives": {"total_bytes": 0.0}}
    rb = {**ra, "depth_override": 4,
          "cost": {"flops": c4["flops"],
                   "bytes accessed": c4["bytes accessed"]},
          "collectives": {"total_bytes": 0.0}}
    out = extrapolate(ra, rb)
    assert abs(out["cost"]["flops"] - c6["flops"]) / c6["flops"] < 1e-6
    assert abs(out["cost"]["bytes accessed"] - c6["bytes accessed"]) \
        / c6["bytes accessed"] < 0.02     # byte constants ~affine
