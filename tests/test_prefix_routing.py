"""Cache-aware prefix-affinity routing, the remote KV tier, and the
cross-worker fetch path (docs/ROUTING.md)."""
import pytest

from repro.core import comm as comm_mod
from repro.core.faults import FaultSpec
from repro.core.mem.remote_store import RemoteKVSpec, RemoteKVStore
from repro.core.mem.swap import SwapConfig, SwapManager
from repro.core.metrics import ROUTING_SUMMARY_FIELDS
from repro.core.request import Request
from repro.core.sched.global_sched import make_global_scheduler
from repro.core.sched.prefix_registry import PrefixRegistry
from repro.core.simulator import SimSpec, WorkerSpec, simulate
from repro.core.workload import WorkloadSpec


def mk_req(i, prompt=64, out=8, prefix_id=None, prefix_len=0):
    return Request(id=i, arrival_time=0.0, prompt_len=prompt,
                   output_len=out, prefix_id=prefix_id,
                   prefix_len=prefix_len)


# ---------------------------------------------------------------------------
# PrefixRegistry unit behaviour
# ---------------------------------------------------------------------------
class FakeClock:
    def __init__(self):
        self.now = 0.0


def test_registry_publish_holders_and_max_merge():
    reg = PrefixRegistry()
    reg.publish(7, wid=0, tokens=128)
    reg.publish(7, wid=1, tokens=64)
    reg.publish(7, wid=0, tokens=96)          # never shrinks a claim
    assert reg.holders(7) == {0: 128, 1: 64}
    assert reg.tokens_at(7, 0) == 128
    assert reg.tokens_at(7, 9) == 0
    assert reg.holders(8) == {}


def test_registry_ttl_expiry_and_touch_refresh():
    clk = FakeClock()
    reg = PrefixRegistry(clk, ttl=10.0)
    reg.publish(1, wid=0, tokens=32)
    reg.publish(1, wid=1, tokens=32)
    clk.now = 9.0
    reg.touch(1, 1)                           # refresh one claim
    clk.now = 15.0
    assert reg.holders(1) == {1: 32}          # wid 0 aged out
    assert reg.stats()["registry_expirations"] == 1
    clk.now = 30.0
    assert reg.holders(1) == {}
    assert reg.n_entries() == 0


def test_registry_lru_eviction_at_capacity():
    reg = PrefixRegistry(max_prefixes=2)
    reg.publish(1, 0, 10)
    reg.publish(2, 0, 10)
    reg.publish(1, 1, 10)                     # re-publish: 1 is now MRU
    reg.publish(3, 0, 10)                     # evicts pid 2 (oldest)
    assert reg.holders(2) == {}
    assert reg.holders(1) and reg.holders(3)
    assert reg.stats()["registry_evictions"] == 1


def test_registry_invalidate_worker():
    reg = PrefixRegistry()
    reg.publish(1, 0, 10)
    reg.publish(1, 1, 10)
    reg.publish(2, 0, 10)
    assert reg.invalidate_worker(0) == 2
    assert reg.holders(1) == {1: 10}
    assert reg.holders(2) == {}
    assert reg.invalidate_worker(0) == 0      # idempotent


# ---------------------------------------------------------------------------
# RemoteKVStore unit behaviour
# ---------------------------------------------------------------------------
def test_remote_store_lru_evicts_unpinned_only():
    st = RemoteKVStore(100.0)
    assert st.put(("prefix", 1), 10, 40.0)
    assert st.put(("prefix", 2), 10, 40.0)
    assert st.get(("prefix", 1)) == (10, 40.0)   # touch: 2 is now LRU
    assert st.put(("prefix", 3), 10, 40.0)       # evicts 2
    assert st.has(("prefix", 1)) and st.has(("prefix", 3))
    assert st.get(("prefix", 2)) is None
    s = st.stats()
    assert s["evictions"] == 1 and s["misses"] == 1
    assert s["used_bytes"] == 80.0


def test_remote_store_pinned_never_evicted_and_reject():
    st = RemoteKVStore(100.0)
    assert st.put(("swap", 1), 10, 80.0, pinned=True)
    assert st.put(("prefix", 1), 10, 20.0)
    # a pinned put that cannot fit even after evicting every unpinned
    # entry must be rejected, not evict live swap progress
    assert not st.put(("swap", 2), 10, 90.0, pinned=True)
    assert st.has(("swap", 1))
    assert st.stats()["rejects"] == 1
    # unpinned entries do make way for a fitting pinned put
    assert st.put(("swap", 3), 10, 15.0, pinned=True)
    assert not st.has(("prefix", 1))
    assert st.drop(("swap", 1)) == 10
    assert st.drop(("swap", 1)) == 0          # idempotent
    assert st.stats()["used_bytes"] == 15.0


# ---------------------------------------------------------------------------
# SwapManager with the remote tier
# ---------------------------------------------------------------------------
def _sm(host_cap=100.0, remote_cap=1000.0):
    remote = RemoteKVStore(remote_cap)
    sm = SwapManager(SwapConfig(host_capacity_bytes=host_cap,
                                kv_bytes_per_token=1.0,
                                remote_bw=10.0, remote_setup_latency=1.0),
                     remote=remote)
    return sm, remote


def test_swap_spills_to_remote_when_host_full():
    sm, remote = _sm(host_cap=100.0)
    r1, r2 = mk_req(1), mk_req(2)
    sm.swap_out(r1, 80)                       # host tier
    assert sm.can_swap_out(50)                # remote absorbs overflow
    lat = sm.swap_out(r2, 50)
    assert lat == pytest.approx(1.0 + 50 / 10.0)   # setup + bytes/bw
    assert remote.has(("swap", 2)) and sm.holds(r2)
    assert sm.tokens_held(r2) == 50
    # swap-in drains the remote copy and frees the object
    assert sm.swap_in(r2) == pytest.approx(1.0 + 50 / 10.0)
    assert not remote.has(("swap", 2)) and not sm.holds(r2)
    s = sm.stats()
    assert s["remote_out_events"] == 1 and s["remote_in_events"] == 1
    assert s["remote_bytes_out"] == s["remote_bytes_in"] == 50.0


def test_adopt_into_remote_tier_and_fallback():
    """adopt() lands in the remote tier when host is full; with both
    tiers full it reports failure (caller recomputes) without leaking
    partial state."""
    sm, remote = _sm(host_cap=100.0, remote_cap=60.0)
    filler = mk_req(9)
    sm.swap_out(filler, 100)                  # host now full
    r = mk_req(1)
    assert sm.adopt(r, 50)
    assert remote.has(("swap", 1)) and sm.tokens_held(r) == 50
    r2 = mk_req(2)
    assert not sm.adopt(r2, 50)               # remote full of pinned KV
    assert not sm.holds(r2) and not remote.has(("swap", 2))
    assert sm.stats()["fallbacks"] == 1
    # dropping the adopted request frees the remote object exactly once
    assert sm.drop(r) == 50
    assert sm.drop(r) == 0
    assert not remote.has(("swap", 1))


def test_swap_stats_keys_gated_on_remote():
    """Without a remote tier attached, stats() must keep the exact
    legacy key set — golden pins snapshot it."""
    legacy = SwapManager(SwapConfig()).stats()
    assert not any(k.startswith("remote_") for k in legacy)
    sm, _ = _sm()
    assert {"remote_out_events", "remote_in_events", "remote_bytes_out",
            "remote_bytes_in"} <= set(sm.stats())


# ---------------------------------------------------------------------------
# PrefixAffinity policy unit behaviour
# ---------------------------------------------------------------------------
class FakeWorker:
    run_prefill = True
    run_decode = True
    alive = True
    draining = False
    retired = False

    def __init__(self, wid, load=0):
        self.wid = wid
        self._load = load

    def load_tokens(self):
        return self._load


def _router(inner="round_robin", **kw):
    pol = make_global_scheduler("prefix_affinity", inner=inner, **kw)
    pol.registry = PrefixRegistry()
    return pol


def test_affinity_routes_to_longest_holder():
    pol = _router()
    ws = [FakeWorker(0), FakeWorker(1), FakeWorker(2)]
    pol.registry.publish(5, 1, 64)
    pol.registry.publish(5, 2, 128)           # longest prefix wins
    req = mk_req(0, prefix_id=5, prefix_len=128)
    assert pol.assign(req, ws) == 2
    assert pol.affinity_hits == 1 and req.fetch_src is None


def test_affinity_falls_through_without_prefix_or_holder():
    pol = _router()
    ws = [FakeWorker(0), FakeWorker(1)]
    # no prefix: inner round robin decides
    assert pol.assign(mk_req(0), ws) == 0
    # prefix nobody holds: miss, inner decides, claim published
    req = mk_req(1, prefix_id=5, prefix_len=64)
    wid = pol.assign(req, ws)
    assert pol.affinity_misses == 1
    assert pol.registry.holders(5) == {wid: 64}


def test_affinity_overload_diversion_stamps_fetch_hint():
    pol = _router(inner="least_loaded", overload_factor=2.0)
    ws = [FakeWorker(0, load=5000), FakeWorker(1, load=10)]
    pol.registry.publish(5, 0, 128)           # only the hot worker is warm
    req = mk_req(0, prefix_id=5, prefix_len=128)
    wid = pol.assign(req, ws)
    assert wid != 0                           # diverted off the hot holder
    assert pol.overload_diversions == 1 and pol.fetch_hints == 1
    assert req.fetch_src == 0 and req.fetch_tokens == 128


def test_affinity_skips_dead_holder():
    pol = _router()
    ws = [FakeWorker(0), FakeWorker(1)]
    pol.registry.publish(5, 0, 64)
    ws[0].alive = False
    req = mk_req(0, prefix_id=5, prefix_len=64)
    assert pol.assign(req, ws) == 1           # dead holder is not warm
    assert pol.affinity_misses == 1


# ---------------------------------------------------------------------------
# fetch pricing: break-even and failure handling (integration)
# ---------------------------------------------------------------------------
def _sim_spec(*, n_workers=3, link=comm_mod.NVLINK, remote=True,
              faults=(), n=90, qps=25.0, retain=True):
    wl = WorkloadSpec(num_requests=n, qps=qps, seed=5, lengths="fixed",
                      prompt_len=64, output_len=32,
                      shared_prefix_len=512, shared_prefix_groups=6)
    return SimSpec(
        arch="llama2-7b",
        workers=[WorkerSpec(hw="A100", gpu_mem_util=0.3)
                 for _ in range(n_workers)],
        workload=wl, prefix_sharing=True,
        global_policy="prefix_affinity",
        global_policy_kw={"overload_factor": 1.2}, kv_link=link,
        remote_kv=RemoteKVSpec() if remote else None,
        faults=faults, retain_requests=retain)


def test_break_even_declines_fetch_on_slow_link():
    """The same workload fetches over a fast link and recomputes over a
    pathologically slow one — the break-even works both ways."""
    slow = comm_mod.LinkSpec("glacial", bandwidth=1e3, latency=5.0)
    fast = simulate(_sim_spec(link=comm_mod.NVLINK,
                              remote=False)).routing_summary()
    slow_r = simulate(_sim_spec(link=slow, remote=False)).routing_summary()
    assert fast["fetch_hints"] > 0, "no diversions: gate is vacuous"
    assert fast["peer_fetches"] > 0
    assert slow_r["peer_fetches"] == 0
    assert slow_r["fetch_recomputes"] > 0


def test_fetch_hint_at_dead_peer_is_leak_free():
    """A worker dying between routing (hint stamped) and admission must
    not crash or leak: the fetch falls back to the remote tier or to a
    recorded miss, and every request still finishes."""
    faults = (FaultSpec(time=1.0, worker=0, kind="fail", duration=2.5),
              FaultSpec(time=4.0, worker=1, kind="fail", duration=2.5))
    res = simulate(_sim_spec(faults=faults, n=120, qps=30.0))
    assert len(res.finished) == 120
    ro = res.routing_summary()
    assert ro["registry_invalidations"] > 0
    # remote tier outlives the workers: fetches still happen post-fail
    assert ro["fetches"] > 0


def test_routing_summary_fields_exact_and_streaming():
    exact = simulate(_sim_spec()).routing_summary()
    assert set(exact) == set(ROUTING_SUMMARY_FIELDS)
    stream = simulate(_sim_spec(retain=False)).routing_summary()
    assert set(stream) == set(ROUTING_SUMMARY_FIELDS)
    # per-request fold keeps the fetch counters exact in drop mode
    assert stream["fetches"] == exact["fetches"]
    assert stream["fetched_tokens"] == exact["fetched_tokens"]
    assert stream["prefix_requests"] == exact["prefix_requests"]


def test_disabled_path_has_no_routing_surface():
    wl = WorkloadSpec(num_requests=30, qps=20.0, seed=1)
    res = simulate(SimSpec(workers=[WorkerSpec(), WorkerSpec()],
                           workload=wl))
    assert res.routing_stats is None and res.remote_stats is None
    ro = res.routing_summary()
    assert ro["fetches"] == 0 and ro["affinity_hit_rate"] == 0.0
