"""DES kernel: SimPy-subset semantics + deterministic tie-breaking."""
import pytest

from repro.core.engine import Environment, Store, all_of


def test_timeout_ordering():
    env = Environment()
    log = []

    def proc(name, delay):
        yield env.timeout(delay)
        log.append((env.now, name))

    env.process(proc("b", 2.0))
    env.process(proc("a", 1.0))
    env.process(proc("c", 3.0))
    env.run()
    assert log == [(1.0, "a"), (2.0, "b"), (3.0, "c")]
    assert env.now == 3.0


def test_same_time_deterministic_seq_order():
    """Events at identical timestamps fire in creation order."""
    for _ in range(3):
        env = Environment()
        log = []

        def proc(name):
            yield env.timeout(1.0)
            log.append(name)

        for name in "abcdef":
            env.process(proc(name))
        env.run()
        assert log == list("abcdef")


def test_event_chain_and_values():
    env = Environment()
    out = []

    def producer(ev):
        yield env.timeout(5.0)
        ev.succeed("payload")

    def consumer(ev):
        val = yield ev
        out.append((env.now, val))

    ev = env.event()
    env.process(producer(ev))
    env.process(consumer(ev))
    env.run()
    assert out == [(5.0, "payload")]


def test_wait_on_already_processed_event():
    env = Environment()
    ev = env.event()
    ev.succeed(42)
    out = []

    def late():
        yield env.timeout(1.0)
        val = yield ev          # ev processed long ago; must not hang
        out.append(val)

    env.process(late())
    env.run()
    assert out == [42]


def test_store_fifo_blocking():
    env = Environment()
    store = Store(env)
    got = []

    def consumer(i):
        item = yield store.get()
        got.append((env.now, i, item))

    def producer():
        yield env.timeout(1.0)
        store.put("x")
        yield env.timeout(1.0)
        store.put("y")

    env.process(consumer(0))
    env.process(consumer(1))
    env.process(producer())
    env.run()
    assert got == [(1.0, 0, "x"), (2.0, 1, "y")]


def test_all_of():
    env = Environment()
    done = []

    def waiter(events):
        yield all_of(env, events)
        done.append(env.now)

    evs = [env.timeout(t) for t in (1.0, 3.0, 2.0)]
    env.process(waiter(evs))
    env.run()
    assert done == [3.0]


def test_run_until():
    env = Environment()
    log = []

    def p():
        while True:
            yield env.timeout(1.0)
            log.append(env.now)

    env.process(p())
    env.run(until=5.5)
    assert log == [1.0, 2.0, 3.0, 4.0, 5.0]
    assert env.now == 5.5


def test_negative_delay_rejected():
    env = Environment()
    with pytest.raises(ValueError):
        env.timeout(-1.0)
