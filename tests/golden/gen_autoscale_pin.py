"""Regenerate the golden backward-compat pin for the autoscaling
refactor (tests/test_autoscale.py::test_golden_static_fleet_pin).

The pin freezes a *static-fleet* run — generated before the
fixed-list -> dynamic-worker-registry refactor landed — as JSON: a run
with ``SimSpec.autoscale`` left at its default (``None``) or set to a
disabled ``AutoscaleSpec`` must reproduce these bytes exactly.  Any
change to worker construction, dispatch order or the billing
bookkeeping that shifts this run is a backward-compat break.
Regenerate ONLY when an intentional cost-model change invalidates the
pin:

    PYTHONPATH=src python tests/golden/gen_autoscale_pin.py
"""
from __future__ import annotations

import os

from repro.core.faults import ChaosSpec, FaultSpec
from repro.core.simulator import SimSpec, WorkerSpec, simulate
from repro.core.workload import WorkloadSpec

HERE = os.path.dirname(os.path.abspath(__file__))
PIN_PATH = os.path.join(HERE, "autoscale_pin.json")


def pinned_spec() -> SimSpec:
    """The frozen run: three static workers, diurnal arrivals (the
    workload shape the autoscaler targets), swap preemption and one
    scheduled fault with costly recovery — every code path the
    dynamic-registry refactor rewires, with scaling itself off."""
    return SimSpec(
        arch="llama2-7b",
        workers=[WorkerSpec(hw="A100", gpu_mem_util=0.3)] * 3,
        workload=WorkloadSpec(num_requests=150, qps=12.0, seed=11,
                              arrival="diurnal", diurnal_period=20.0,
                              diurnal_amplitude=0.8),
        preemption_mode="swap",
        faults=[FaultSpec(time=4.0, worker=1, kind="fail", duration=1.0)],
        chaos=ChaosSpec(reload_time=0.5, warmup_iters=1,
                        warmup_factor=2.0))


def snapshot(res) -> dict:
    """Byte-exact observable surface of a run: floats round-trip via
    repr in JSON, so equality on the loaded dict is byte equality."""
    return {
        "sim_time": res.sim_time,
        "requests": [
            {"id": r.id, "t_first_token": r.t_first_token,
             "t_finish": r.t_finish, "token_times": r.token_times,
             "preempt_count": r.preempt_count,
             "swap_out_count": r.swap_out_count,
             "swap_in_count": r.swap_in_count}
            for r in sorted(res.requests, key=lambda q: q.id)],
        "mem_stats": {str(k): v for k, v in (res.mem_stats or {}).items()},
        "swap_stats": {str(k): v for k, v in (res.swap_stats or {}).items()},
        "fault_events": [
            {"time": e.time, "worker": e.worker, "kind": e.kind,
             "factor": e.factor}
            for e in (res.fault_events or [])],
    }


def main() -> None:
    from pin_io import save_pin
    res = simulate(pinned_spec())
    out = save_pin(snapshot(res), PIN_PATH)
    print(f"wrote {out}: {len(res.requests)} requests, "
          f"sim_time={res.sim_time}")


if __name__ == "__main__":
    main()
