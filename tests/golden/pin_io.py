"""Transparent gzip I/O for golden pins.

Golden pins are ~1MB of indent-formatted JSON each and compress ~20x;
storing them as ``.json.gz`` keeps the repo lean without giving up the
byte-exact compare (the *decompressed* JSON is what equality runs
over).  ``load_pin``/``save_pin`` take the logical ``*.json`` path and
resolve the ``.gz`` sibling transparently, so regen scripts and tests
share one naming convention.  tests/test_golden_pins.py gates that no
uncompressed pin over 1MB sneaks back into tests/golden/.
"""
from __future__ import annotations

import gzip
import json
import os


def load_pin(path: str):
    """Load a pin by its logical ``*.json`` path: the gzip sibling
    (``<path>.gz``) wins when present, the plain file is the
    fallback."""
    gz = path + ".gz"
    if os.path.exists(gz):
        with gzip.open(gz, "rt") as f:
            return json.load(f)
    with open(path) as f:
        return json.load(f)


def save_pin(obj, path: str) -> str:
    """Write ``obj`` as ``<path>.gz``, removing a stale uncompressed
    sibling.  ``mtime=0`` keeps the archive byte-stable: regenerating
    an unchanged pin produces an identical file, so git sees no
    spurious diff."""
    data = json.dumps(obj, indent=1, sort_keys=True).encode()
    gz = path + ".gz"
    with open(gz, "wb") as raw:
        with gzip.GzipFile(fileobj=raw, mode="wb", mtime=0) as f:
            f.write(data)
    if os.path.exists(path):
        os.remove(path)
    return gz
