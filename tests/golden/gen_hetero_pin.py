"""Regenerate the golden backward-compat pin for the heterogeneity
refactor (tests/test_hetero_fleet.py::test_golden_single_model_pin).

The pin freezes a single-model, pre-refactor ``SimSpec`` run — request
timestamps, token times, mem/swap stats and the fault log — as JSON.
Any ``WorkerSpec``/worker-construction refactor that changes this run's
bytes is a backward-compat break.  Regenerate ONLY when an intentional
cost-model change invalidates the pin:

    PYTHONPATH=src python tests/golden/gen_hetero_pin.py
"""
from __future__ import annotations

import os

from repro.core.faults import ChaosSpec, FaultSpec
from repro.core.simulator import SimSpec, WorkerSpec, simulate
from repro.core.workload import WorkloadSpec

HERE = os.path.dirname(os.path.abspath(__file__))
PIN_PATH = os.path.join(HERE, "hetero_pin.json")


def pinned_spec() -> SimSpec:
    """The frozen run: two workers, swap preemption, prefix sharing,
    one scheduled fault with costly recovery — every pre-hetero
    subsystem the worker-construction refactor touches."""
    return SimSpec(
        arch="llama2-7b",
        workers=[WorkerSpec(hw="A100", gpu_mem_util=0.25),
                 WorkerSpec(hw="V100", gpu_mem_util=0.5, tp=2)],
        workload=WorkloadSpec(num_requests=120, qps=10.0, seed=7,
                              shared_prefix_len=64,
                              shared_prefix_groups=2),
        preemption_mode="swap",
        prefix_sharing=True,
        faults=[FaultSpec(time=3.0, worker=0, kind="fail", duration=1.0)],
        chaos=ChaosSpec(reload_time=0.5, warmup_iters=1,
                        warmup_factor=2.0))


def snapshot(res) -> dict:
    """Byte-exact observable surface of a run: floats round-trip via
    repr in JSON, so equality on the loaded dict is byte equality."""
    return {
        "sim_time": res.sim_time,
        "requests": [
            {"id": r.id, "t_first_token": r.t_first_token,
             "t_finish": r.t_finish, "token_times": r.token_times,
             "preempt_count": r.preempt_count,
             "swap_out_count": r.swap_out_count,
             "swap_in_count": r.swap_in_count,
             "shared_tokens": r.shared_tokens}
            for r in sorted(res.requests, key=lambda q: q.id)],
        "mem_stats": {str(k): v for k, v in (res.mem_stats or {}).items()},
        "swap_stats": {str(k): v for k, v in (res.swap_stats or {}).items()},
        "fault_events": [
            {"time": e.time, "worker": e.worker, "kind": e.kind,
             "factor": e.factor}
            for e in (res.fault_events or [])],
    }


def main() -> None:
    from pin_io import save_pin
    res = simulate(pinned_spec())
    out = save_pin(snapshot(res), PIN_PATH)
    print(f"wrote {out}: {len(res.requests)} requests, "
          f"sim_time={res.sim_time}")


if __name__ == "__main__":
    main()
