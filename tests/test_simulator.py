"""TokenSim end-to-end behaviour: determinism, the paper's directional
findings, disaggregation, memory pool, faults and stragglers."""

from repro.core.mem.memory_pool import PoolConfig
from repro.core.simulator import FaultSpec, SimSpec, WorkerSpec, simulate
from repro.core.workload import WorkloadSpec


def base_spec(**kw):
    d = dict(arch="llama2-7b", workers=[WorkerSpec(hw="A100")],
             workload=WorkloadSpec(num_requests=150, qps=8.0, seed=0),
             local_policy="continuous", max_batch=64)
    d.update(kw)
    return SimSpec(**d)


def test_all_requests_finish():
    res = simulate(base_spec())
    assert len(res.finished) == 150
    assert res.throughput() > 0


def test_determinism():
    r1 = simulate(base_spec())
    r2 = simulate(base_spec())
    assert [x.t_finish for x in r1.requests] == \
        [x.t_finish for x in r2.requests]
    assert r1.sim_time == r2.sim_time


def test_finding1_continuous_beats_static():
    """Paper Finding 1: continuous batching reduces latency."""
    cont = simulate(base_spec(local_policy="continuous", max_batch=16))
    stat = simulate(base_spec(local_policy="static", max_batch=16))
    assert cont.latency_stats()["p99"] < stat.latency_stats()["p99"]
    assert cont.latency_stats()["mean"] < stat.latency_stats()["mean"]


def test_finding2_mem_ratio_tradeoff_runs():
    """Admission cap changes behavior (preemptions drop)."""
    hot = simulate(base_spec(
        workers=[WorkerSpec(hw="A100", gpu_mem_util=0.35, max_mem_ratio=1.0)],
        workload=WorkloadSpec(num_requests=150, qps=20.0, seed=1)))
    capped = simulate(base_spec(
        workers=[WorkerSpec(hw="A100", gpu_mem_util=0.35,
                            max_mem_ratio=0.8)],
        workload=WorkloadSpec(num_requests=150, qps=20.0, seed=1)))
    assert len(hot.finished) == len(capped.finished) == 150
    assert capped.preemption_rate() <= hot.preemption_rate()


def test_disaggregation_first_token_on_prefill_worker():
    spec = base_spec(
        workers=[WorkerSpec(role="prefill"), WorkerSpec(role="decode")],
        global_policy="disagg",
        workload=WorkloadSpec(num_requests=60, qps=4.0, seed=2))
    res = simulate(spec)
    assert len(res.finished) == 60
    # decode tokens must exist and migration cost shows in token gaps
    for r in res.finished:
        assert r.tokens_generated == r.output_len


def test_memory_pool_multiround_reduces_latency():
    """Paper Finding 6 direction: pool helps multi-round workloads."""
    wl = WorkloadSpec(num_requests=200, qps=10.0, seed=3,
                      lengths="fixed", prompt_len=256, output_len=64,
                      multi_round_frac=0.5)
    off = simulate(base_spec(workload=wl, pool=None))
    on = simulate(base_spec(workload=wl, pool=PoolConfig()))
    assert len(on.finished) == len(off.finished) == 200
    assert on.pool_stats["hits"] > 0
    assert on.latency_stats()["p99"] <= off.latency_stats()["p99"] * 1.05


def test_worker_failure_requests_redispatched():
    spec = base_spec(
        workers=[WorkerSpec(), WorkerSpec()],
        workload=WorkloadSpec(num_requests=120, qps=10.0, seed=4),
        faults=[FaultSpec(time=3.0, worker=0, kind="fail")])
    res = simulate(spec)
    assert len(res.finished) == 120          # nothing lost
    # all finishing work happened on worker 1 after the failure
    assert all(r.worker_id == 1 for r in res.requests
               if r.t_finish and r.t_finish > 3.5)


def test_straggler_mitigation_least_loaded():
    """A slowed worker receives less work under least-loaded dispatch."""
    spec = base_spec(
        workers=[WorkerSpec(), WorkerSpec(slowdown=8.0)],
        global_policy="least_loaded",
        workload=WorkloadSpec(num_requests=200, qps=15.0, seed=5))
    res = simulate(spec)
    assert len(res.finished) == 200
    on_fast = sum(1 for r in res.requests if r.worker_id == 0)
    on_slow = sum(1 for r in res.requests if r.worker_id == 1)
    assert on_fast > on_slow * 1.5


def test_recovery_restores_capacity():
    spec = base_spec(
        workers=[WorkerSpec(), WorkerSpec()],
        workload=WorkloadSpec(num_requests=150, qps=12.0, seed=6),
        faults=[FaultSpec(time=2.0, worker=0, kind="fail"),
                FaultSpec(time=6.0, worker=0, kind="recover")])
    res = simulate(spec)
    assert len(res.finished) == 150
    late_on_0 = [r for r in res.requests
                 if r.worker_id == 0 and r.arrival_time > 6.5]
    assert late_on_0, "recovered worker never used"


def test_mtpot_slo_catches_preemption_gaps():
    wl = WorkloadSpec(num_requests=100, qps=25.0, seed=7)
    res = simulate(base_spec(
        workers=[WorkerSpec(gpu_mem_util=0.3)], workload=wl))
    s = res.summary(ttft_slo=15.0, mtpot_slo=0.3)
    assert s["goodput_rps"] <= s["throughput_rps"] + 1e-9


def test_simulation_speed():
    """The sim must stay lightweight: >10k tokens/s of simulated decode."""
    import time
    spec = base_spec(workload=WorkloadSpec(num_requests=500, qps=16.0,
                                           seed=8))
    t0 = time.perf_counter()
    res = simulate(spec)
    wall = time.perf_counter() - t0
    tokens = sum(r.tokens_generated for r in res.finished)
    assert tokens / wall > 10_000, f"{tokens/wall:.0f} tok/s too slow"
