"""Speculative decoding: acceptance models, KV accept/rollback, scheduler
budgeting, end-to-end speedup/crossover, and no-leak guarantees."""
from collections import deque

import pytest

from repro.core import (AcceptanceModel, SimSpec, SpecDecodeSpec, WorkerSpec,
                        simulate)
from repro.core.mem.block_manager import BlockManager, MemoryConfig
from repro.core.request import Request
from repro.core.sched.local import ContinuousBatching
from repro.core.simulator import Simulation
from repro.core.workload import WorkloadSpec


def spec_sim(*, batch=1, k=4, acc=0.8, num_requests=8, output_len=64,
             spec=True, **kw):
    wl = WorkloadSpec(num_requests=num_requests, qps=0.0, lengths="fixed",
                      prompt_len=128, output_len=output_len, seed=0)
    sd = SpecDecodeSpec(draft_arch="qwen2-0.5b", lookahead=k,
                        acceptance=AcceptanceModel(rate=acc)) if spec \
        else None
    d = dict(arch="llama2-7b", workers=[WorkerSpec(hw="A100")], workload=wl,
             max_batch=batch, max_batched_tokens=4096, spec_decode=sd)
    d.update(kw)
    return SimSpec(**d)


# ---------------------------------------------------------------------------
# acceptance models
# ---------------------------------------------------------------------------
def test_acceptance_constant_expectation():
    m = AcceptanceModel(rate=0.8)
    # E[accepted] = sum_{i=1..K} p^i
    assert m.expected_accepted(4) == pytest.approx(
        sum(0.8 ** i for i in range(1, 5)))
    import random
    rng = random.Random(0)
    samples = [m.sample_accepted(rng, 4) for _ in range(20000)]
    assert all(0 <= s <= 4 for s in samples)
    mean = sum(samples) / len(samples)
    assert mean == pytest.approx(m.expected_accepted(4), rel=0.05)


def test_acceptance_geometric_decays():
    m = AcceptanceModel(kind="geometric", rate=0.9, decay=0.8)
    assert m.prob(0) == pytest.approx(0.9)
    assert m.prob(3) == pytest.approx(0.9 * 0.8 ** 3)
    assert m.expected_accepted(8) < AcceptanceModel(
        rate=0.9).expected_accepted(8)


def test_acceptance_trace_per_position():
    m = AcceptanceModel(kind="trace", per_position=(1.0, 0.5, 0.0))
    assert m.prob(0) == 1.0 and m.prob(1) == 0.5
    assert m.prob(10) == 0.0               # past the trace: last entry
    import random
    assert m.sample_accepted(random.Random(0), 5) <= 2  # pos 2 never accepts


def test_acceptance_validation():
    with pytest.raises(ValueError):
        AcceptanceModel(kind="bogus")
    with pytest.raises(ValueError):
        AcceptanceModel(kind="trace")      # needs per_position
    with pytest.raises(ValueError):
        AcceptanceModel(rate=1.5)
    with pytest.raises(ValueError):
        SpecDecodeSpec(lookahead=0)


# ---------------------------------------------------------------------------
# block manager accept/rollback
# ---------------------------------------------------------------------------
def test_rollback_releases_blocks_deterministically():
    mem = BlockManager(MemoryConfig(num_blocks=16, block_size=4,
                                    kv_bytes_per_token=1.0))
    r = Request(id=0, arrival_time=0.0, prompt_len=6, output_len=10)
    mem.allocate(r, 6)                     # 2 blocks
    mem.append_tokens(r, 5)                # 11 tokens -> 3 blocks
    assert len(mem.block_table(r)) == 3
    taken = list(mem.block_table(r))
    released = mem.rollback_tokens(r, 4)   # back to 7 tokens -> 2 blocks
    assert released == 1
    assert mem.resident_tokens(r) == 7
    assert len(mem.block_table(r)) == 2
    # invariant: free + allocated == total; released block reusable next
    assert mem.num_free + len(mem.block_table(r)) == 16
    r2 = Request(id=1, arrival_time=0.0, prompt_len=4, output_len=1)
    assert mem.allocate(r2, 4) == [taken[-1]]   # LIFO reuse: deterministic


def test_rollback_noop_and_bounds():
    mem = BlockManager(MemoryConfig(num_blocks=8, block_size=4,
                                    kv_bytes_per_token=1.0))
    r = Request(id=0, arrival_time=0.0, prompt_len=4, output_len=2)
    mem.allocate(r, 4)
    assert mem.rollback_tokens(r, 0) == 0
    with pytest.raises(AssertionError):
        mem.rollback_tokens(r, 5)          # more than resident


def test_rollback_ssm_constant_state():
    mem = BlockManager(MemoryConfig(num_blocks=4, block_size=1,
                                    kv_bytes_per_token=0.0,
                                    state_bytes_per_seq=100.0))
    r = Request(id=0, arrival_time=0.0, prompt_len=4, output_len=8)
    mem.allocate(r, 4)
    mem.append_tokens(r, 5)
    assert mem.rollback_tokens(r, 3) == 0  # no paged blocks to release
    assert mem.resident_tokens(r) == 6


# ---------------------------------------------------------------------------
# scheduler budgeting: mixed spec/non-spec batches
# ---------------------------------------------------------------------------
class _StubWorker:
    def __init__(self, num_blocks=1000, spec=None):
        self.mem = BlockManager(MemoryConfig(num_blocks=num_blocks,
                                             block_size=16,
                                             kv_bytes_per_token=1.0))
        self.pool = None
        self.waiting = deque()
        self.running = []
        self.spec_decode = spec


def _decode_req(w, rid, ctx=32):
    r = Request(id=rid, arrival_time=float(rid), prompt_len=ctx,
                output_len=64)
    w.mem.allocate(r, ctx)
    r.prefill_done_len = ctx
    r.tokens_generated = 1
    w.running.append(r)
    return r


def test_verify_tokens_bill_the_budget():
    """4 decodes, budget 8, K=4: only one fits at K+1 tokens; the rest
    stay on the normal decode path (mixed batch)."""
    sd = SpecDecodeSpec(lookahead=4)
    w = _StubWorker(spec=sd)
    for i in range(4):
        _decode_req(w, i)
    sched = ContinuousBatching(max_batch=8, max_batched_tokens=8)
    plan = sched.plan(w)
    assert len(plan.spec_decode) == 1
    assert len(plan.decode) == 3
    assert not set(r.id for r in plan.spec_decode) & \
        set(r.id for r in plan.decode)


def test_spec_disabled_without_config():
    w = _StubWorker(spec=None)
    _decode_req(w, 0)
    plan = ContinuousBatching(max_batch=8, max_batched_tokens=64).plan(w)
    assert plan.decode and not plan.spec_decode


def test_spec_degrades_on_memory_pressure_without_preempting():
    """Free blocks cover every decode's +1 growth but not the draft
    windows: speculation must back off rather than preempt."""
    sd = SpecDecodeSpec(lookahead=16)      # window larger than one block
    w = _StubWorker(num_blocks=5, spec=sd)
    a = _decode_req(w, 0, ctx=32)          # 2 blocks, full
    b = _decode_req(w, 1, ctx=32)          # 2 blocks, full
    # 1 free block: both +1 growths fit in-block (32 -> 33 needs a 3rd
    # block each... use ctx=31 so growth stays in-block)
    w.running.clear()
    w.mem.free(a)
    w.mem.free(b)
    a = _decode_req(w, 2, ctx=30)
    b = _decode_req(w, 3, ctx=30)
    plan = ContinuousBatching(max_batch=8, max_batched_tokens=4096).plan(w)
    assert not plan.preempted
    assert len(plan.spec_decode) + len(plan.decode) == 2
    # K+1=17 tokens from ctx 30 needs 3 blocks vs 2 -> 1 extra each, only
    # 1 free: exactly one request may speculate
    assert len(plan.spec_decode) <= 1


# ---------------------------------------------------------------------------
# end-to-end
# ---------------------------------------------------------------------------
def test_effective_tokens_per_step_and_speedup_batch1():
    on = simulate(spec_sim(batch=1, k=4, acc=0.8))
    off = simulate(spec_sim(batch=1, spec=False))
    s = on.spec_summary()
    assert s["eff_tokens_per_step"] >= 1.5
    assert 0.0 < s["acceptance_rate"] <= 1.0
    assert on.token_throughput() > off.token_throughput()
    assert "spec_eff_tokens_per_step" in on.summary()


def test_throughput_crossover_at_high_occupancy():
    on = simulate(spec_sim(batch=64, k=4, acc=0.8, num_requests=128))
    off = simulate(spec_sim(batch=64, num_requests=128, spec=False))
    assert on.token_throughput() < off.token_throughput()


def test_no_kv_leak_after_spec_run():
    """Rejected draft tokens must never leak blocks: after the run every
    worker's free list covers the whole pool again."""
    for output_len in (3, 64):             # 3 < K+1 exercises the cap
        sim = Simulation(spec_sim(batch=4, k=4, acc=0.5,
                                  output_len=output_len))
        res = sim.run()
        assert len(res.finished) == len(res.requests)
        for w in sim.workers:
            assert not w.mem.tables, "requests left resident"
            assert w.mem.num_free == w.mem.mc.num_blocks, "leaked blocks"
        for r in res.requests:
            assert r.tokens_generated == r.output_len


def test_spec_with_disaggregation_no_leak():
    """A MIGRATING request's KV is released mid-iteration by the
    transfer; it must never be planned for (speculative) decode on the
    source worker.  Regression: this used to roll back a freed table."""
    wl = WorkloadSpec(num_requests=40, qps=4.0, seed=2)
    sim = Simulation(SimSpec(
        arch="llama2-7b",
        workers=[WorkerSpec(role="prefill"), WorkerSpec(role="decode")],
        global_policy="disagg_pd",           # long-form alias
        workload=wl,
        spec_decode=SpecDecodeSpec(lookahead=4)))
    res = sim.run()
    assert len(res.finished) == 40
    for r in res.finished:
        assert r.tokens_generated == r.output_len
    for w in sim.workers:
        assert not w.mem.tables and w.mem.num_free == w.mem.mc.num_blocks


def test_spec_determinism():
    r1 = simulate(spec_sim(batch=4, num_requests=16))
    r2 = simulate(spec_sim(batch=4, num_requests=16))
    assert [x.t_finish for x in r1.requests] == \
        [x.t_finish for x in r2.requests]
    assert r1.spec_summary() == r2.spec_summary()


def test_spec_counters_consistent():
    res = simulate(spec_sim(batch=2, num_requests=8))
    for r in res.requests:
        assert r.draft_accepted <= r.draft_proposed
        assert r.spec_tokens <= r.spec_steps * 5      # <= K+1 per step
        assert r.spec_tokens >= r.spec_steps          # >= 1 per step
        assert r.spec_tokens <= r.tokens_generated
        if r.draft_proposed:
            assert r.acceptance_rate == \
                r.draft_accepted / r.draft_proposed
