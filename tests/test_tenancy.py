"""Multi-tenant QoS subsystem: admission control, fair-share scheduling,
priority preemption, and per-tenant metrics (repro.core.tenancy)."""
import pytest

from repro.core import SimSpec, TenantSpec, TenantTier, WorkerSpec, simulate
from repro.core.metrics import jain_index
from repro.core.tenancy import TokenBucket
from repro.core.workload import WorkloadSpec, generate_multi


def fixed_wl(n, qps, seed, prompt=128, out=64):
    return WorkloadSpec(num_requests=n, qps=qps, seed=seed,
                        lengths="fixed", prompt_len=prompt, output_len=out)


def tenant(tid, *, n=60, qps=8.0, seed=0, prompt=128, out=64, **tier_kw):
    return TenantSpec(tid, TenantTier(name=tid, **tier_kw),
                      fixed_wl(n, qps, seed, prompt, out))


def sim(tenants, *, policy="wfq", until=None, **kw):
    d = dict(arch="llama2-7b", workers=[WorkerSpec(hw="A100")],
             global_policy=policy, local_policy="continuous",
             max_batch=64, tenants=tenants, until=until)
    d.update(kw)
    return simulate(SimSpec(**d))


# ---------------------------------------------------------------------------
# token bucket
# ---------------------------------------------------------------------------
def test_token_bucket_math():
    b = TokenBucket(rate=100.0, burst=500.0)
    assert b.wait_time(0.0, 500.0) == 0.0
    b.consume(0.0, 500.0)
    assert b.available(0.0) == 0.0
    # 200 tokens refill after 2 s
    assert b.wait_time(0.0, 200.0) == pytest.approx(2.0)
    assert b.wait_time(1.0, 200.0) == pytest.approx(1.0)
    # oversized requests wait for a full bucket, not forever
    assert b.wait_time(5.0, 9999.0) == pytest.approx(0.0)
    b.consume(5.0, 9999.0)                 # runs the bucket into debt
    assert b.available(5.0) < 0.0


def test_rate_limit_rejects_at_configured_rate():
    """REJECT tier: admitted token rate ~= burst + rate * horizon."""
    rate, burst, cost = 2000.0, 4000.0, 128 + 64
    t = tenant("free", n=400, qps=50.0, seed=1,
               rate_tokens_per_s=rate, burst_tokens=burst,
               admission_policy="reject")
    res = sim([t])
    fin = [r for r in res.requests if not r.rejected]
    rej = [r for r in res.requests if r.rejected]
    assert rej, "over-limit traffic must be rejected"
    assert res.admission_stats["free"]["rejected"] == len(rej)
    horizon = max(r.arrival_time for r in res.requests)
    allowed = burst + rate * horizon
    admitted_tokens = len(fin) * cost
    assert admitted_tokens <= allowed + cost            # never over
    assert admitted_tokens >= 0.8 * min(allowed, 400 * cost)


def test_queue_policy_delays_instead_of_rejecting():
    t = tenant("slow", n=40, qps=50.0, seed=2,
               rate_tokens_per_s=1000.0, burst_tokens=1000.0,
               admission_policy="queue")
    res = sim([t])
    assert len(res.finished) == 40                      # nothing dropped
    delays = [r.queue_delay for r in res.requests]
    assert max(delays) > 1.0                            # gateway queueing


def test_shed_policy_bounds_queue_delay():
    t = tenant("shed", n=200, qps=100.0, seed=3,
               rate_tokens_per_s=2000.0, burst_tokens=2000.0,
               admission_policy="shed", shed_timeout=2.0)
    res = sim([t])
    n_rej = sum(1 for r in res.requests if r.rejected)
    assert n_rej > 0
    for r in res.requests:
        if r.queue_delay is not None:
            assert r.queue_delay <= 2.0 + 1e-6


def test_shed_bounds_delay_behind_inflight_cap():
    """The shed deadline must hold even when the stall comes from the
    inflight cap rather than the bucket (delivery-time check)."""
    t = tenant("shed", n=120, qps=0.0, seed=5,
               rate_tokens_per_s=5000.0, burst_tokens=5000.0,
               admission_policy="shed", shed_timeout=2.0, max_inflight=2)
    res = sim([t])
    assert sum(1 for r in res.requests if r.rejected) > 0
    for r in res.requests:
        if r.queue_delay is not None:
            assert r.queue_delay <= 2.0 + 1e-6


def test_max_inflight_caps_concurrency():
    t = tenant("capped", n=30, qps=0.0, seed=4, max_inflight=2)
    res = sim([t])
    assert len(res.finished) == 30
    # with 2 inflight, request k can only be released after k-2 finished
    releases = sorted(r.t_admitted for r in res.requests)
    finishes = sorted(r.t_finish for r in res.requests)
    for k in range(2, 30):
        assert releases[k] >= finishes[k - 2] - 1e-9


# ---------------------------------------------------------------------------
# fair-share scheduling
# ---------------------------------------------------------------------------
def test_wfq_equal_weights_is_fair():
    ts = [tenant("a", n=200, qps=0.0, seed=10, weight=1.0),
          tenant("b", n=200, qps=0.0, seed=11, weight=1.0)]
    res = sim(ts, policy="wfq", max_batch=8, until=30.0)
    tps = res.tenant_token_throughputs()
    assert all(v > 0 for v in tps.values())
    assert jain_index(list(tps.values())) > 0.99
    assert res.fairness_index() > 0.99


def test_wfq_shares_follow_weights():
    """Backlogged tenants get token service proportional to weight."""
    ts = [tenant("small", n=300, qps=0.0, seed=12, weight=1.0),
          tenant("big", n=300, qps=0.0, seed=13, weight=3.0)]
    res = sim(ts, policy="wfq", max_batch=8, until=30.0)
    tps = res.tenant_token_throughputs()
    ratio = tps["big"] / tps["small"]
    assert 3.0 * 0.9 <= ratio <= 3.0 * 1.1, ratio
    # normalizing by weight restores fairness
    assert res.fairness_index(weighted=True) > 0.99


def test_priority_tier_served_first():
    ts = [tenant("low", n=150, qps=0.0, seed=14, priority=0),
          tenant("high", n=150, qps=0.0, seed=15, priority=10)]
    res = sim(ts, policy="priority", max_batch=8, until=20.0)
    s = res.tenant_summary()
    # the high tier's backlog drains strictly first
    assert s["high"]["n_finished"] > s["low"]["n_finished"]
    assert s["high"]["ttft_p99"] < s["low"]["ttft_p99"]


def test_priority_preempts_low_tier_kv():
    """Under memory pressure the preemption path evicts low-tier KV."""
    wl = lambda seed: WorkloadSpec(num_requests=100, qps=25.0, seed=seed)
    ts = [TenantSpec("low", TenantTier(name="low", priority=0), wl(16)),
          TenantSpec("high", TenantTier(name="high", priority=10), wl(17))]
    res = sim(ts, policy="priority",
              workers=[WorkerSpec(hw="A100", gpu_mem_util=0.3)],
              max_batch=64)
    s = res.tenant_summary()
    total_preempts = sum(r.preempt_count for r in res.requests)
    assert total_preempts > 0, "scenario must create memory pressure"
    low_p = sum(r.preempt_count for r in res.requests
                if r.tenant_id == "low")
    high_p = total_preempts - low_p
    assert low_p > high_p
    assert s["high"]["latency_p99"] <= s["low"]["latency_p99"]


def test_aging_prevents_starvation():
    """With aging, a saturating high tier cannot starve the low tier.

    The low tier's backlog arrives at t=0; the high tier keeps arriving
    above the service rate.  Without aging every fresh high request
    outranks the stuck low ones forever; with aging the low tier's wait
    time eventually dominates the 10-point tier gap."""
    ts = [tenant("low", n=40, qps=0.0, seed=18, priority=0),
          tenant("high", n=400, qps=40.0, seed=19, priority=10)]
    starved = sim(ts, policy="priority", max_batch=8, until=25.0)
    aged = sim(ts, policy="priority", max_batch=8, until=25.0,
               global_policy_kw={"aging_rate": 100.0})
    low_starved = starved.tenant_summary()["low"]["n_finished"]
    low_aged = aged.tenant_summary()["low"]["n_finished"]
    assert low_starved < 40          # strict priority starves the low tier
    assert low_aged > low_starved    # aging restores service


def test_wfq_assign_idempotent_on_redispatch():
    """Failure redispatch re-enters assign(); the tenant's virtual
    clock must not be charged twice for the same request."""
    from repro.core.request import Request
    from repro.core.sched.global_sched import make_global_scheduler

    class W:
        wid, alive, run_prefill, run_decode = 0, True, True, True

        def load_tokens(self):
            return 0

    sched = make_global_scheduler("wfq")
    r = Request(id=0, arrival_time=0.0, prompt_len=10, output_len=5,
                tenant_id="t", weight=1.0)
    sched.assign(r, [W()])
    vft, book = r.vft, dict(sched._last_vft)
    sched.assign(r, [W()])               # orphan re-dispatch after a fail
    assert r.vft == vft and sched._last_vft == book


# ---------------------------------------------------------------------------
# workload composition + determinism + metric consistency
# ---------------------------------------------------------------------------
def test_generate_multi_deterministic_and_stamped():
    ts = [tenant("a", n=50, qps=5.0, seed=0, weight=2.0, priority=3),
          tenant("b", n=50, qps=5.0, seed=0)]
    r1, r2 = generate_multi(ts), generate_multi(ts)
    key = lambda rs: [(r.id, r.tenant_id, r.arrival_time, r.prompt_len,
                       r.output_len, r.priority, r.weight) for r in rs]
    assert key(r1) == key(r2)
    assert [r.id for r in r1] == list(range(100))
    assert all(r.tenant_id in ("a", "b") for r in r1)
    # same seed, different tenants => decorrelated streams
    a = [r.prompt_len for r in r1 if r.tenant_id == "a"]
    b = [r.prompt_len for r in r1 if r.tenant_id == "b"]
    assert a == [128] * 50 and b == [128] * 50   # fixed lengths here


def test_generate_multi_decorrelates_seeds():
    wl = WorkloadSpec(num_requests=50, qps=5.0, seed=7)
    ts = [TenantSpec("a", TenantTier(), wl), TenantSpec("b", TenantTier(), wl)]
    reqs = generate_multi(ts)
    a = [r.arrival_time for r in reqs if r.tenant_id == "a"]
    b = [r.arrival_time for r in reqs if r.tenant_id == "b"]
    assert a != b


def test_generate_multi_rejects_duplicate_ids():
    wl = WorkloadSpec(num_requests=5)
    with pytest.raises(ValueError):
        generate_multi([TenantSpec("a", TenantTier(), wl),
                        TenantSpec("a", TenantTier(), wl)])


def test_tenant_sim_deterministic():
    """Identical SimSpec (incl. tenants) => identical per-tenant metrics."""
    ts = [tenant("free", n=60, qps=15.0, seed=20,
                 rate_tokens_per_s=3000.0, burst_tokens=3000.0,
                 admission_policy="shed", shed_timeout=3.0),
          tenant("pro", n=60, qps=8.0, seed=21, weight=4.0, priority=5)]
    r1 = sim(ts, policy="wfq")
    r2 = sim(ts, policy="wfq")
    assert r1.tenant_summary() == r2.tenant_summary()
    assert [x.t_finish for x in r1.requests] == \
        [x.t_finish for x in r2.requests]


def test_tenant_metrics_sum_to_aggregate():
    ts = [tenant("a", n=70, qps=10.0, seed=22),
          tenant("b", n=50, qps=6.0, seed=23, weight=2.0),
          tenant("c", n=30, qps=40.0, seed=24,
                 rate_tokens_per_s=2000.0, burst_tokens=2000.0,
                 admission_policy="reject")]
    res = sim(ts, policy="wfq")
    s = res.tenant_summary()
    assert sum(row["n_requests"] for row in s.values()) == len(res.requests)
    assert sum(row["n_finished"] for row in s.values()) == len(res.finished)
    assert sum(row["n_rejected"] for row in s.values()) == \
        sum(1 for r in res.requests if r.rejected)
    assert sum(row["tokens"] for row in s.values()) == \
        sum(r.tokens_generated for r in res.finished)


def test_no_tenants_path_unchanged():
    """tenants=() keeps the single-stream behaviour and summary keys."""
    spec = SimSpec(arch="llama2-7b", workers=[WorkerSpec(hw="A100")],
                   workload=WorkloadSpec(num_requests=50, qps=8.0, seed=0),
                   max_batch=64)
    res = simulate(spec)
    assert len(res.finished) == 50
    assert res.tenant_specs is None and res.admission_stats is None
    assert "fairness_jain" not in res.summary()
