"""Workload generation, memory pool, comm model, schedulers."""

import pytest

from repro.core.comm import Link, LinkSpec
from repro.core.engine import Environment
from repro.core.mem.memory_pool import MemoryPool, PoolConfig, PrefixTrie
from repro.core.request import Request
from repro.core.workload import WorkloadSpec, generate, save_trace


def test_workload_deterministic():
    spec = WorkloadSpec(num_requests=100, qps=5.0, seed=42)
    a, b = generate(spec), generate(spec)
    assert [(r.arrival_time, r.prompt_len, r.output_len) for r in a] == \
        [(r.arrival_time, r.prompt_len, r.output_len) for r in b]


def test_sharegpt_moments():
    spec = WorkloadSpec(num_requests=5000, qps=0.0, seed=0)
    reqs = generate(spec)
    mean_p = sum(r.prompt_len for r in reqs) / len(reqs)
    mean_o = sum(r.output_len for r in reqs) / len(reqs)
    # calibrated lognormal targets (clipped): prompt ~170, output ~300
    assert 120 < mean_p < 260, mean_p
    assert 200 < mean_o < 420, mean_o
    assert max(r.prompt_len for r in reqs) <= spec.max_prompt_len


def test_poisson_rate():
    spec = WorkloadSpec(num_requests=4000, qps=10.0, seed=1)
    reqs = generate(spec)
    span = reqs[-1].arrival_time - reqs[0].arrival_time
    rate = len(reqs) / span
    assert 8.5 < rate < 11.5, rate


def test_multiround_sessions():
    spec = WorkloadSpec(num_requests=300, qps=2.0, seed=2,
                        multi_round_frac=1.0, rounds_min=2, rounds_max=4)
    reqs = generate(spec)
    by_sess = {}
    for r in reqs:
        by_sess.setdefault(r.session_id, []).append(r)
    multi = [v for v in by_sess.values() if len(v) > 1]
    assert multi
    for rounds in multi:
        rounds.sort(key=lambda r: r.round_idx)
        for prev, cur in zip(rounds, rounds[1:]):
            assert cur.history_len >= prev.prompt_len + prev.output_len
            assert cur.prompt_len > cur.history_len  # includes new turn
            assert cur.arrival_time >= prev.arrival_time


def test_trace_roundtrip(tmp_path):
    spec = WorkloadSpec(num_requests=50, qps=3.0, seed=3)
    reqs = generate(spec)
    p = str(tmp_path / "trace.jsonl")
    save_trace(reqs, p)
    spec2 = WorkloadSpec(num_requests=50, lengths="trace", trace_path=p)
    reqs2 = generate(spec2)
    assert [(r.prompt_len, r.output_len) for r in reqs] == \
        [(r.prompt_len, r.output_len) for r in reqs2]


def test_trace_roundtrip_preserves_all_fields(tmp_path):
    """save_trace/load keeps arrivals, sessions and round indices for a
    multi-round workload, and a double round-trip is a fixed point."""
    spec = WorkloadSpec(num_requests=80, qps=5.0, seed=9,
                        multi_round_frac=0.6)
    reqs = generate(spec)
    p = str(tmp_path / "trace.jsonl")
    save_trace(reqs, p)
    reqs2 = generate(WorkloadSpec(num_requests=80, lengths="trace",
                                  trace_path=p))
    assert [(r.arrival_time, r.prompt_len, r.output_len, r.session_id,
             r.round_idx) for r in reqs] == \
        [(r.arrival_time, r.prompt_len, r.output_len, r.session_id,
          r.round_idx) for r in reqs2]
    p2 = str(tmp_path / "trace2.jsonl")
    save_trace(reqs2, p2)
    assert open(p).read() == open(p2).read()


# ---------------------------------------------------------------------------
def test_memory_pool_hit_miss_lru():
    pool = MemoryPool(PoolConfig(capacity_tokens=100, block_size=16))
    pool.store(1, 60)
    pool.store(2, 40)
    r = Request(id=0, arrival_time=0, prompt_len=80, output_len=4,
                session_id=1, round_idx=1, history_len=60)
    reuse, lat = pool.lookup(r)
    assert reuse == 60
    assert lat == pytest.approx(4 * 800e-9)
    # storing session 3 must evict LRU (session 2, since 1 was touched)
    pool.store(3, 50)
    r2 = Request(id=1, arrival_time=0, prompt_len=50, output_len=4,
                 session_id=2, round_idx=1, history_len=40)
    assert pool.lookup(r2)[0] == 0
    assert pool.evictions >= 1


def test_memory_pool_disabled():
    pool = MemoryPool(PoolConfig(enabled=False))
    assert pool.store(1, 100) == 0.0
    r = Request(id=0, arrival_time=0, prompt_len=10, output_len=1,
                session_id=1, history_len=5)
    assert pool.lookup(r) == (0, 0.0)


def test_memory_pool_eviction_under_capacity_pressure():
    """LRU evicts in insertion/touch order and an oversized entry is
    dropped entirely rather than thrashing the pool."""
    pool = MemoryPool(PoolConfig(capacity_tokens=100, block_size=16))
    pool.store(1, 40)
    pool.store(2, 40)
    pool.store(3, 40)                      # evicts session 1
    assert pool.evictions == 1
    r1 = Request(id=0, arrival_time=0, prompt_len=50, output_len=1,
                 session_id=1, history_len=40)
    assert pool.lookup(r1) == (0, 0.0)     # evicted: miss
    # an entry larger than the whole pool evicts everything, then still
    # fails to fit; the pool must stay consistent (empty, no phantom use)
    assert pool.store(9, 1000) == 0.0
    assert pool.used_tokens == 0
    r9 = Request(id=1, arrival_time=0, prompt_len=1000, output_len=1,
                 session_id=9, history_len=900)
    assert pool.lookup(r9) == (0, 0.0)


def test_memory_pool_lookup_caps_at_prompt_and_history():
    """Reuse never exceeds min(cached, history_len, prompt_len)."""
    pool = MemoryPool(PoolConfig(capacity_tokens=1000))
    pool.store(1, 500)
    r = Request(id=0, arrival_time=0, prompt_len=64, output_len=1,
                session_id=1, history_len=300)
    assert pool.lookup(r)[0] == 64         # prompt bound
    r2 = Request(id=1, arrival_time=0, prompt_len=400, output_len=1,
                 session_id=1, history_len=100)
    assert pool.lookup(r2)[0] == 100       # history bound
    r3 = Request(id=2, arrival_time=0, prompt_len=400, output_len=1,
                 session_id=1, history_len=0)
    assert pool.lookup(r3) == (0, 0.0)     # no shared history: miss


def test_prefix_trie_empty():
    t = PrefixTrie()
    assert t.best_worker((1, 2, 3)) == (None, 0)
    assert t.best_worker(()) == (None, 0)


def test_prefix_trie_exact_match():
    t = PrefixTrie()
    t.insert((5, 6, 7), worker_id=3)
    assert t.best_worker((5, 6, 7)) == (3, 3)     # exact, full depth
    assert t.best_worker((5, 6)) == (3, 2)        # proper prefix
    assert t.best_worker((5, 6, 7, 8)) == (3, 3)  # longer query


def test_prefix_trie():
    t = PrefixTrie()
    t.insert((1, 2, 3), worker_id=0)
    t.insert((1, 2, 9), worker_id=1)
    w, depth = t.best_worker((1, 2, 3, 4))
    assert w == 0 and depth == 3
    w, depth = t.best_worker((1, 2, 9))
    assert depth == 3 and w == 1
    assert t.best_worker((7,)) == (None, 0)


# ---------------------------------------------------------------------------
def test_link_serialization():
    env = Environment()
    link = Link(env, LinkSpec("t", bandwidth=1e9, latency=1e-3,
                              serialize=True))
    done = []

    def p(i):
        ev = link.transfer(1e6)      # 1 MB -> 1ms + 1ms latency
        yield ev
        done.append((i, env.now))

    for i in range(3):
        env.process(p(i))
    env.run()
    # serialized: each waits for the previous
    times = [t for _, t in sorted(done)]
    assert times[1] >= times[0] + 0.0019
    assert times[2] >= times[1] + 0.0019


def test_link_pipelining_faster():
    env = Environment()
    slow = Link(env, LinkSpec("s", bandwidth=1e9, latency=5e-3,
                              buffer_chunks=1, chunk_bytes=1e6))
    fast = Link(env, LinkSpec("f", bandwidth=1e9, latency=5e-3,
                              buffer_chunks=8, chunk_bytes=1e6))
    assert fast.transfer_time(32e6) < slow.transfer_time(32e6) + 5e-3 * 31


def test_scheduler_chunked_prefill_mixes():
    """Chunked prefill runs decode+prefill in one iteration."""
    from collections import deque
    from repro.core.mem.block_manager import BlockManager, MemoryConfig
    from repro.core.sched.local import ContinuousBatching

    class W:
        pass

    w = W()
    w.mem = BlockManager(MemoryConfig(num_blocks=1000, block_size=16,
                                      kv_bytes_per_token=1.0))
    w.pool = None
    w.waiting = deque()
    w.running = []
    sched = ContinuousBatching(max_batch=8, max_batched_tokens=256,
                               chunked_prefill=True, prefill_chunk=64)
    # one running decode + one long waiting prefill
    r_dec = Request(id=0, arrival_time=0.0, prompt_len=10, output_len=50)
    w.mem.allocate(r_dec, 10)
    r_dec.prefill_done_len = 10
    r_dec.tokens_generated = 1
    w.running.append(r_dec)
    r_new = Request(id=1, arrival_time=1.0, prompt_len=500, output_len=5)
    w.waiting.append(r_new)
    plan = sched.plan(w)
    assert plan.decode and plan.prefill
    assert plan.prefill[0][1] == 64      # one chunk only
