"""Pad-to-shard planning properties."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import ASSIGNED, get_config
from repro.configs.base import ArchConfig, DENSE
from repro.distributed.padding import make_pad_plan


def test_identity_at_tp1():
    for name in ASSIGNED:
        cfg = get_config(name)
        plan = make_pad_plan(cfg, tp=1)
        assert plan.n_q == cfg.n_heads
        assert plan.n_kv == cfg.n_kv_heads
        assert plan.kv_rep == 1
        if cfg.moe:
            assert plan.n_experts == cfg.moe.num_experts


@pytest.mark.parametrize("name", ASSIGNED)
def test_assigned_archs_shard_at_tp16(name):
    cfg = get_config(name)
    plan = make_pad_plan(cfg, tp=16)
    if cfg.n_heads:
        assert plan.n_q % 16 == 0, (name, plan.n_q)
        assert plan.n_kv % 16 == 0 or plan.n_kv == 0
        assert plan.n_q >= cfg.n_heads
        # every device's q heads use that device's kv head
        assert plan.n_q == plan.n_kv * plan.group
        mask = plan.q_head_mask()
        assert mask.sum() == cfg.n_heads
    assert plan.vocab % 256 == 0 and plan.vocab >= cfg.vocab_size
    if cfg.moe:
        assert plan.n_experts % 16 == 0
        assert plan.n_experts >= cfg.moe.num_experts
    if cfg.ssm:
        assert plan.ssm_heads % 16 == 0


@settings(max_examples=100, deadline=None)
@given(hkv=st.sampled_from([1, 2, 4, 8, 16, 32]),
       group=st.integers(1, 8),
       tp=st.sampled_from([1, 2, 4, 8, 16]))
def test_pad_plan_properties(hkv, group, tp):
    hq = hkv * group
    if hkv < tp and tp % hkv:
        return                           # unsupported combo, raises
    cfg = ArchConfig(name="t", family=DENSE, num_layers=1, d_model=64,
                     n_heads=hq, n_kv_heads=hkv, head_dim=8, d_ff=64,
                     vocab_size=1000)
    plan = make_pad_plan(cfg, tp=tp)
    # devices hold whole numbers of q heads and kv heads
    assert plan.n_q % tp == 0
    assert plan.n_kv % tp == 0
    # logical heads all present exactly once
    mask = plan.q_head_mask()
    assert mask.sum() == hq
    # padded fraction is bounded (never more than double)
    assert plan.n_q <= max(2 * hq, tp)
    # physical q head i uses physical kv head i // group; check the
    # logical mapping is consistent: each logical kv head's group of
    # logical q heads lands on copies of that kv head
    qs_per_kv = plan.group
    for phys_q in range(plan.n_q):
        phys_kv = phys_q // qs_per_kv
        orig_kv = phys_kv // plan.kv_rep
        assert orig_kv < hkv
