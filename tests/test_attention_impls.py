"""All attention implementations agree numerically."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention_impl import (attend, blocked_attention,
                                         blocked_causal_attention,
                                         decode_attention, naive_attention)


def rand_qkv(key, b, sq, skv, h, hkv, d, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), dtype)
    k = jax.random.normal(ks[1], (b, skv, hkv, d), dtype)
    v = jax.random.normal(ks[2], (b, skv, hkv, d), dtype)
    return q, k, v


@pytest.mark.parametrize("h,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("s", [64, 128, 256])
def test_blocked_matches_naive(h, hkv, s):
    q, k, v = rand_qkv(jax.random.key(0), 2, s, s, h, hkv, 32)
    want = naive_attention(q, k, v, causal=True)
    got = blocked_attention(q, k, v, causal=True, block_q=64, block_kv=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("blocks", [(32, 32), (64, 32), (32, 64)])
def test_blocked_causal_matches_naive(blocks):
    bq, bk = blocks
    q, k, v = rand_qkv(jax.random.key(1), 2, 128, 128, 4, 2, 32)
    want = naive_attention(q, k, v, causal=True)
    got = blocked_causal_attention(q, k, v, block_q=bq, block_kv=bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_non_causal_cross_attention():
    q, k, v = rand_qkv(jax.random.key(2), 2, 32, 96, 4, 4, 16)
    want = naive_attention(q, k, v, causal=False)
    got = blocked_attention(q, k, v, causal=False, block_q=32, block_kv=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_softcap():
    q, k, v = rand_qkv(jax.random.key(3), 1, 64, 64, 2, 2, 16)
    want = naive_attention(q, k, v, causal=True, logit_softcap=30.0)
    got = blocked_attention(q, k, v, causal=True, block_q=32, block_kv=32,
                            logit_softcap=30.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # softcap must change the result (guard against silent no-op)
    plain = naive_attention(q, k, v, causal=True)
    assert not np.allclose(np.asarray(want), np.asarray(plain))


def test_decode_matches_naive_last_row():
    """Decode with a cache == last row of full causal attention."""
    b, s, h, hkv, d = 2, 48, 4, 2, 16
    q, k, v = rand_qkv(jax.random.key(4), b, s, s, h, hkv, d)
    full = naive_attention(q, k, v, causal=True)
    cache_len = jnp.full((b,), s, jnp.int32)
    got = decode_attention(q[:, -1:], k, v, cache_len)
    np.testing.assert_allclose(np.asarray(got[:, 0]),
                               np.asarray(full[:, -1]),
                               rtol=2e-5, atol=2e-5)


def test_decode_ragged_lengths():
    b, s, h, d = 3, 32, 2, 16
    q, k, v = rand_qkv(jax.random.key(5), b, 1, s, h, h, d)
    lens = jnp.array([5, 17, 32], jnp.int32)
    got = decode_attention(q, k, v, lens)
    for i, L in enumerate([5, 17, 32]):
        want = naive_attention(q[i:i+1], k[i:i+1, :L], v[i:i+1, :L],
                               causal=False)
        np.testing.assert_allclose(np.asarray(got[i]), np.asarray(want[0]),
                                   rtol=2e-5, atol=2e-5)


def test_dispatch_paths():
    q, k, v = rand_qkv(jax.random.key(6), 1, 64, 64, 2, 2, 16)
    outs = [attend(q, k, v, causal=True, impl=i, block_q=32, block_kv=32)
            for i in ("naive", "blocked", "blocked_causal", "pallas")]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   rtol=2e-4, atol=2e-4)
