"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as flash_ops
from repro.kernels.flash_attention import ref as flash_ref
from repro.kernels.paged_attention import ops as paged_ops
from repro.kernels.paged_attention import ref as paged_ref
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.kernels.ssd_scan import ref as ssd_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
FLASH_CASES = [
    # b, sq, skv, h, hkv, d, causal, dtype
    (2, 128, 128, 4, 2, 64, True, jnp.float32),
    (1, 256, 256, 4, 4, 64, True, jnp.bfloat16),
    (2, 64, 192, 4, 1, 32, False, jnp.float32),    # cross-attn ragged
    (1, 100, 100, 2, 2, 16, True, jnp.float32),    # pad both dims
    (1, 128, 128, 8, 8, 128, True, jnp.float32),   # MHA, mxu-sized head
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_vs_ref(case):
    b, sq, skv, h, hkv, d, causal, dt = case
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), dt)
    k = jax.random.normal(ks[1], (b, skv, hkv, d), dt)
    v = jax.random.normal(ks[2], (b, skv, hkv, d), dt)
    out = flash_ops.flash_attention(q, k, v, causal=causal,
                                    block_q=64, block_kv=64)
    qh, kh, vh = (jnp.moveaxis(x, 2, 1) for x in (q, k, v))
    want = jnp.moveaxis(
        flash_ref.attention_ref(qh, kh, vh, causal=causal), 1, 2)
    tol = 2.5e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_softcap():
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 32))
    k = jax.random.normal(ks[1], (1, 64, 2, 32))
    v = jax.random.normal(ks[2], (1, 64, 2, 32))
    out = flash_ops.flash_attention(q, k, v, causal=True, softcap=20.0,
                                    block_q=32, block_kv=32)
    qh, kh, vh = (jnp.moveaxis(x, 2, 1) for x in (q, k, v))
    want = jnp.moveaxis(flash_ref.attention_ref(
        qh, kh, vh, causal=True, softcap=20.0), 1, 2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# paged attention
# ---------------------------------------------------------------------------
PAGED_CASES = [
    (2, 4, 2, 64, 16, 16, 4, jnp.float32),
    (3, 8, 1, 32, 32, 8, 6, jnp.float32),
    (2, 4, 4, 64, 16, 16, 3, jnp.bfloat16),
    (1, 16, 2, 128, 8, 32, 2, jnp.float32),
]


@pytest.mark.parametrize("case", PAGED_CASES)
def test_paged_attention_vs_ref(case):
    b, hq, hkv, d, npages, page, mp, dt = case
    ks = jax.random.split(jax.random.key(2), 5)
    q = jax.random.normal(ks[0], (b, hq, d), dt)
    kp = jax.random.normal(ks[1], (npages, page, hkv, d), dt)
    vp = jax.random.normal(ks[2], (npages, page, hkv, d), dt)
    bt = jax.random.randint(ks[3], (b, mp), 0, npages)
    cl = jax.random.randint(ks[4], (b,), 1, mp * page + 1)
    out = paged_ops.paged_attention(q, kp, vp, bt, cl)
    kh = jnp.transpose(kp, (2, 0, 1, 3))
    vh = jnp.transpose(vp, (2, 0, 1, 3))
    want = paged_ref.paged_attention_ref(
        q, kh, vh, bt.astype(jnp.int32), cl.astype(jnp.int32))
    tol = 3e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_paged_attention_respects_block_table_permutation():
    """Same KV content through permuted page tables -> same output."""
    b, hq, hkv, d, npages, page, mp = 1, 2, 1, 16, 8, 4, 4
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (b, hq, d))
    kseq = jax.random.normal(ks[1], (mp * page, hkv, d))
    vseq = jax.random.normal(ks[2], (mp * page, hkv, d))
    outs = []
    for perm in ([0, 1, 2, 3], [3, 1, 0, 2]):
        kp = jnp.zeros((npages, page, hkv, d))
        vp = jnp.zeros((npages, page, hkv, d))
        for logical, phys in enumerate(perm):
            kp = kp.at[phys].set(kseq[logical * page:(logical + 1) * page])
            vp = vp.at[phys].set(vseq[logical * page:(logical + 1) * page])
        bt = jnp.asarray([perm], jnp.int32)
        cl = jnp.asarray([mp * page], jnp.int32)
        outs.append(paged_ops.paged_attention(q, kp, vp, bt, cl))
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------
#: (b, s, h, p, g, n, chunk, tol) — case3's larger tile accumulates
#: fp32 rounding differences between the chunked scan and the
#: sequential reference (1/32768 elements at 1.1e-4), so its bound is
#: 2e-4; the smaller cases keep the tight 1e-4 sensitivity.
SSD_CASES = [
    (2, 128, 4, 32, 1, 64, 32, 1e-4),
    (1, 96, 4, 16, 2, 32, 32, 1e-4),
    (2, 100, 2, 16, 1, 16, 32, 1e-4),     # ragged -> pad path
    (1, 64, 8, 64, 1, 128, 64, 2e-4),     # mamba2-130m-like tile
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_scan_vs_ref(case):
    b, s, h, p, g, n, chunk, tol = case
    ks = jax.random.split(jax.random.key(4), 4)
    xbar = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    dA_log = -dt * jnp.exp(jax.random.uniform(ks[1], (1, 1, h)))
    Bm = jax.random.normal(ks[2], (b, s, g, n))
    Cm = jax.random.normal(ks[3], (b, s, g, n))
    y, fs = ssd_ops.ssd_scan(xbar, dA_log, Bm, Cm, chunk=chunk)
    yw, fsw = ssd_ref.ssd_scan_ref(xbar, dA_log, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yw),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(fsw),
                               rtol=tol, atol=tol)
