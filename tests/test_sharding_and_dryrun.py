"""Sharding plans + a real (small-mesh) dry run in a subprocess.

The production 512-device dry-run is exercised by
``python -m repro.launch.dryrun`` (results in results/dryrun/); here we
check the plan trees are coherent and that lower+compile works on an
8-device host mesh from a clean subprocess (device count must be set
before jax initializes).
"""
import os
import subprocess
import sys

import jax
import pytest

from repro.configs import ASSIGNED, get_config
from repro.distributed import shard_plan
from repro.models import model_zoo as zoo

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("name", ASSIGNED)
def test_param_pspecs_match_tree(name):
    cfg = get_config(name)
    model = zoo.build(cfg, tp=16)
    specs = zoo.param_specs(model)
    pspecs = shard_plan.param_pspecs(model)
    flat_s, tdef_s = jax.tree_util.tree_flatten(specs)
    flat_p = tdef_s.flatten_up_to(pspecs)
    assert len(flat_s) == len(flat_p)
    for leaf, spec in zip(flat_s, flat_p):
        # spec rank must not exceed tensor rank, and every sharded dim
        # must divide by the mesh axis size it is mapped to
        assert len(spec) <= len(leaf.shape), (spec, leaf.shape)
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                continue
            size = {"data": 16, "model": 16, "pod": 2}[ax] \
                if isinstance(ax, str) else 16
            assert dim % size == 0, (name, spec, leaf.shape, ax)


def test_rules_spec():
    r = shard_plan.default_rules(multi_pod=True)
    assert r.spec("batch", "seq") == jax.sharding.PartitionSpec(
        ("pod", "data"), None)
    r2 = shard_plan.default_rules(seq_parallel=True)
    assert r2.spec("batch", "kv_seq") == jax.sharding.PartitionSpec(
        None, ("data",))


def test_shard_noop_without_mesh():
    from repro.distributed.api import shard
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert shard(x, "batch", None) is x


SMALL_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.distributed import shard_plan
from repro.distributed.api import use_rules, make_rules
from repro.models import model_zoo as zoo
from repro.training.trainer import TrainConfig, make_train_step

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_smoke_config("qwen3-14b")
model = zoo.build(cfg, tp=2)
rules = make_rules(batch=("data",), heads="model", kv_heads="model",
                   ff="model", vocab="model", experts="model")
params = zoo.init_params(model, jax.random.key(0))
pspecs = shard_plan.param_pspecs(model)
N = lambda t: shard_plan.named(mesh, t)
params = jax.device_put(params, N(pspecs))

step = make_train_step(model, TrainConfig())
from repro.training.optimizer import adamw_init
opt = adamw_init(params)
ef = {"_": jnp.zeros(())}
batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
         "labels": jnp.zeros((8, 32), jnp.int32)}
batch = jax.device_put(batch, N({"tokens": jax.sharding.PartitionSpec(("data",), None),
                                 "labels": jax.sharding.PartitionSpec(("data",), None)}))

def wrapped(p, o, e, b):
    with use_rules(mesh, rules):
        return step(p, o, e, b)

out = jax.jit(wrapped)(params, opt, ef, batch)
loss = float(out[3]["loss"])
assert loss == loss and loss > 0, loss

# compare with single-device result
model1 = zoo.build(cfg, tp=2)
params1 = jax.device_put(jax.tree.map(lambda x: jax.numpy.asarray(x), params))
out1 = jax.jit(step)(params1, adamw_init(params1), {"_": jnp.zeros(())},
                     {k: jax.numpy.asarray(v) for k, v in batch.items()})
import numpy as np
np.testing.assert_allclose(loss, float(out1[3]["loss"]), rtol=5e-3)
print("SMALL-MESH-OK", loss)
"""


@pytest.mark.slow
def test_small_mesh_train_step_subprocess():
    """8 host devices, (4 data x 2 model) mesh: the sharded train step
    compiles, runs, and matches the unsharded loss."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SMALL_MESH_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "SMALL-MESH-OK" in out.stdout


def test_dryrun_results_exist_and_clean():
    """The production dry-run artifacts (512 devices, both meshes) must
    exist for every non-skipped cell and contain no failures."""
    import glob
    import json
    d = os.path.join(REPO, "results", "dryrun")
    files = glob.glob(os.path.join(d, "*_baseline.json"))
    if not files:
        pytest.skip("dry-run artifacts not generated yet")
    n_ok = n_skip = 0
    for f in files:
        r = json.load(open(f))
        assert "error" not in r, (f, r.get("error"))
        if "skipped" in r:
            n_skip += 1
        else:
            n_ok += 1
            assert r["cost"].get("flops", 0) > 0
    assert n_ok >= 64, (n_ok, n_skip)
