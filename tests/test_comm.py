"""comm edge cases (zero-byte / latency-dominated / contention),
collective cost primitives, and DisaggPD.reassign fallback."""
import pytest

from repro.core.comm import (DCN, ETH100G, Link, LinkSpec, NVLINK,
                             p2p_time, ring_allreduce_time,
                             stage_boundary_link, tp_group_link)
from repro.core.costmodel.hardware import (CLUSTERS, ClusterSpec,
                                           CROSS_NODE_100G, DGX_A100)
from repro.core.engine import Environment
from repro.core.sched.global_sched import DisaggPD, make_global_scheduler


# ---------------------------------------------------------------------------
# Link edge cases
# ---------------------------------------------------------------------------
def test_zero_byte_transfer_costs_only_latency():
    spec = LinkSpec("t", bandwidth=1e9, latency=5e-6)
    env = Environment()
    link = Link(env, spec)
    assert link.transfer_time(0) == pytest.approx(5e-6)
    link.transfer(0)
    env.run()
    assert env.now == pytest.approx(5e-6)
    assert link.bytes_moved == 0.0
    assert link.transfers == 1


def test_small_message_latency_dominated():
    """For messages far below bandwidth*latency, the wire time is the
    latency floor — and stays monotone in size."""
    spec = LinkSpec("t", bandwidth=100e9, latency=30e-6)
    env = Environment()
    link = Link(env, spec)
    t_small = link.transfer_time(64)          # 0.64 ns of bandwidth
    assert t_small == pytest.approx(30e-6, rel=1e-3)
    sizes = [0, 64, 4096, 2 ** 20, 2 ** 30]
    times = [link.transfer_time(s) for s in sizes]
    assert times == sorted(times)
    # the large transfer is bandwidth-dominated instead
    assert times[-1] > 100 * t_small
    assert times[-1] == pytest.approx(2 ** 30 / 100e9 + 30e-6)


def test_link_contention_serializes():
    """A serializing link runs back-to-back transfers sequentially; a
    non-serializing link overlaps them."""
    env = Environment()
    link = Link(env, LinkSpec("ser", bandwidth=1e9, latency=0.0,
                              serialize=True))
    done = []
    link.transfer(1e9).wait(lambda ev: done.append(env.now))
    link.transfer(1e9).wait(lambda ev: done.append(env.now))
    env.run()
    assert done == pytest.approx([1.0, 2.0])

    env2 = Environment()
    link2 = Link(env2, LinkSpec("par", bandwidth=1e9, latency=0.0,
                                serialize=False))
    done2 = []
    link2.transfer(1e9).wait(lambda ev: done2.append(env2.now))
    link2.transfer(1e9).wait(lambda ev: done2.append(env2.now))
    env2.run()
    assert done2 == pytest.approx([1.0, 1.0])


def test_contention_respects_in_flight_transfer():
    """A transfer issued while the link is busy queues behind the
    remaining busy time, not behind a fresh full transfer."""
    env = Environment()
    link = Link(env, LinkSpec("ser", bandwidth=1e9, latency=0.0))

    def proc():
        link.transfer(1e9)                   # busy until t=1
        yield env.timeout(0.5)
        ev = link.transfer(1e9)              # starts at t=1, done t=2
        yield ev
        assert env.now == pytest.approx(2.0)

    env.process(proc())
    env.run()


# ---------------------------------------------------------------------------
# collective primitives
# ---------------------------------------------------------------------------
def test_p2p_time_zero_bytes_free():
    assert p2p_time(0, NVLINK) == 0.0
    assert p2p_time(-1, NVLINK) == 0.0
    assert p2p_time(1e9, NVLINK) == pytest.approx(
        NVLINK.latency + 1e9 / NVLINK.bandwidth)


def test_ring_allreduce_degenerate_and_formula():
    assert ring_allreduce_time(1e6, 1, NVLINK) == 0.0
    assert ring_allreduce_time(0, 8, NVLINK) == 0.0
    n, nbytes = 4, 1e6
    expect = 2 * (n - 1) * (NVLINK.latency
                            + nbytes / n / NVLINK.bandwidth)
    assert ring_allreduce_time(nbytes, n, NVLINK) == pytest.approx(expect)


def test_ring_allreduce_latency_floor_grows_with_ranks():
    """Tiny messages are pure latency: 2(n-1) hops each."""
    t8 = ring_allreduce_time(8, 8, ETH100G)
    t2 = ring_allreduce_time(8, 2, ETH100G)
    assert t8 > t2 * 3
    assert t2 == pytest.approx(2 * ETH100G.latency, rel=1e-2)


def test_topology_link_selection():
    assert tp_group_link(DGX_A100, 4) is DGX_A100.intra_link
    assert tp_group_link(DGX_A100, 16) is DGX_A100.inter_link
    assert tp_group_link(CROSS_NODE_100G, 2) is CROSS_NODE_100G.inter_link
    # aligned stages each fit their own node: tp == gpus_per_node means
    # stage 1 owns devices 8..15 entirely on node 1
    assert tp_group_link(DGX_A100, 8, stage=1) is DGX_A100.intra_link
    # mis-aligned group: tp=6 stage 1 owns devices 6..11, straddling
    # the node boundary at device 8 -> pays the inter-node link
    assert tp_group_link(DGX_A100, 6, stage=0) is DGX_A100.intra_link
    assert tp_group_link(DGX_A100, 6, stage=1) is DGX_A100.inter_link
    # tp=2, 8 gpus/node: stages 0..3 on node 0 -> boundary 3 crosses
    assert stage_boundary_link(DGX_A100, 2, 0) is DGX_A100.intra_link
    assert stage_boundary_link(DGX_A100, 2, 3) is DGX_A100.inter_link
    # tp == gpus_per_node: every stage boundary crosses nodes
    assert stage_boundary_link(DGX_A100, 8, 0) is DGX_A100.inter_link
    # one gpu per node: everything crosses
    assert stage_boundary_link(CROSS_NODE_100G, 1, 0) \
        is CROSS_NODE_100G.inter_link
    # mis-aligned stages: gpn=4, tp=3 -> stage1 ends at device 5 and
    # stage2 starts at device 6, both on node 1: the hand-off itself is
    # intra-node even though the stages' lead devices are not
    c4 = ClusterSpec("c4", gpus_per_node=4)
    assert stage_boundary_link(c4, 3, 1) is c4.intra_link
    # ...while stage0 -> stage1 (device 2 -> 3) stays on node 0
    assert stage_boundary_link(c4, 3, 0) is c4.intra_link
    # and gpn=4, tp=2, stage1 -> stage2 is device 3 -> 4: crosses
    assert stage_boundary_link(c4, 2, 1) is c4.inter_link


def test_cluster_registry_consistent():
    for name, c in CLUSTERS.items():
        assert c.name == name
        assert c.gpus_per_node >= 1
    assert isinstance(DGX_A100.with_(gpus_per_node=4), ClusterSpec)
    assert DCN.bandwidth < ETH100G.bandwidth < NVLINK.bandwidth


# ---------------------------------------------------------------------------
# DisaggPD.reassign with no eligible decode workers
# ---------------------------------------------------------------------------
class _StubWorker:
    def __init__(self, wid, *, alive=True, run_prefill=True,
                 run_decode=True, load=0):
        self.wid = wid
        self.alive = alive
        self.run_prefill = run_prefill
        self.run_decode = run_decode
        self._load = load

    def load_tokens(self):
        return self._load


class _StubReq:
    worker_id = 0


def test_disagg_reassign_no_decode_workers_falls_back():
    """A prefill-only cluster (no run_decode worker) must still return
    an alive worker instead of crashing — the request decodes where its
    prefill ran."""
    sched = make_global_scheduler("disagg_pd")
    assert isinstance(sched, DisaggPD)
    workers = [_StubWorker(0, run_decode=False, load=5),
               _StubWorker(1, run_decode=False, load=2)]
    wid = sched.reassign(_StubReq(), workers)
    assert wid == 1                        # least-loaded alive fallback


def test_disagg_reassign_skips_dead_decode_workers():
    workers = [_StubWorker(0, run_prefill=False, alive=False),
               _StubWorker(1, run_prefill=False, load=9),
               _StubWorker(2, run_decode=False, load=0)]
    wid = DisaggPD().reassign(_StubReq(), workers)
    assert wid == 1                        # only alive decode worker


def test_disagg_assign_round_robins_prefill_only():
    sched = DisaggPD()
    workers = [_StubWorker(0, run_decode=False),
               _StubWorker(1, run_prefill=False),
               _StubWorker(2, run_decode=False)]
    picks = [sched.assign(_StubReq(), workers) for _ in range(4)]
    assert picks == [0, 2, 0, 2]
