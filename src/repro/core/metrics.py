"""Metrics: the dynamic outputs the paper argues single-shot simulators
cannot produce — latency distributions, CDFs, SLO goodput, memory-over-
time — computed from the per-request records.

Two accounting modes share one ``Results`` surface:

* **exact** (default): ``Results.requests`` holds every ``Request`` and
  percentiles/CDFs are computed from the full latency lists (sorted once
  and cached per ``Results``);
* **streaming** (``Results.stats`` set, produced by
  ``SimSpec(retain_requests=False)``): finished requests are folded into
  a :class:`StreamingStats` sketch as they retire and then dropped, so
  memory stays O(1) in the number of requests.  Quantiles come from a
  log-binned sketch with bounded relative error (default 0.3%, see
  docs/PERFORMANCE.md for the accuracy model).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.request import Request

#: every key of ``Results.availability_summary()``;
#: scripts/check_docs.py asserts each is documented in
#: docs/RELIABILITY.md
AVAILABILITY_FIELDS = (
    "service_availability", "capacity_availability",
    "availability_per_worker", "downtime_per_worker",
    "service_downtime_s", "capacity_downtime_s", "degraded_s",
    "n_failures", "mtbf_observed_s", "mttr_observed_s", "target",
    "window_s", "error_budget_s", "budget_consumed_s",
    "budget_remaining_frac", "burn_rate", "request_success_rate",
    "tenants", "models")

#: every key of a ``Results.model_summary()`` row (heterogeneous
#: multi-model fleets); scripts/check_docs.py asserts each is
#: documented in docs/HETEROGENEITY.md
MODEL_SUMMARY_FIELDS = (
    "n_requests", "n_finished", "tokens", "token_tps",
    "latency_p50", "latency_p99", "ttft_p50", "ttft_p99",
    "slo_attainment", "goodput_rps", "preempt_rate", "n_workers")

#: every key of ``Results.scaling_summary()`` (closed-loop autoscaling
#: and cost economics); scripts/check_docs.py asserts each is
#: documented in docs/AUTOSCALING.md
SCALING_SUMMARY_FIELDS = (
    "n_scale_up", "n_scale_down", "fleet_size_min", "fleet_size_max",
    "fleet_size_avg", "fleet_size_final", "fleet_size_series",
    "worker_seconds", "scale_up_lag_s", "billed_cost",
    "cost_per_1m_tokens", "cost_per_1m_prefill_tokens",
    "cost_per_1m_decode_tokens", "events")

#: every key of ``Results.routing_summary()`` (cache-aware prefix
#: routing + remote KV tier); scripts/check_docs.py asserts each is
#: documented in docs/ROUTING.md
ROUTING_SUMMARY_FIELDS = (
    "prefix_requests", "fetches", "fetched_tokens",
    "affinity_hits", "affinity_misses", "affinity_hit_rate",
    "overload_diversions", "fetch_hints",
    "peer_fetches", "remote_fetches", "fetch_bytes", "fetch_time_s",
    "fetch_misses", "fetch_recomputes",
    "registry_prefixes", "registry_entries", "registry_publishes",
    "registry_invalidations", "registry_expirations",
    "registry_evictions",
    "remote_capacity_bytes", "remote_used_bytes",
    "remote_peak_used_bytes", "remote_entries", "remote_stores",
    "remote_hits", "remote_misses", "remote_evictions",
    "remote_rejects")


def _interp_percentile(s: Sequence[float], p: float) -> float:
    """Linear-interpolated percentile of an already-sorted sequence."""
    if not s:
        return float("nan")
    k = (len(s) - 1) * p / 100.0
    lo, hi = int(math.floor(k)), int(math.ceil(k))
    if lo == hi:
        return s[lo]
    return s[lo] + (s[hi] - s[lo]) * (k - lo)


def percentile(xs: Sequence[float], p: float) -> float:
    return _interp_percentile(sorted(xs), p)


def _cdf_points_sorted(s: Sequence[float],
                       n: int) -> List[Tuple[float, float]]:
    """CDF sampled at n+1 evenly spaced fractions of a sorted sequence."""
    if not s:
        return []
    return [(s[min(len(s) - 1, int(i * len(s) / n))], i / n)
            for i in range(n + 1)]


def cdf_points(xs: Sequence[float], n: int = 100) -> List[Tuple[float, float]]:
    return _cdf_points_sorted(sorted(xs), n)


def jain_index(xs: Sequence[float]) -> float:
    """Jain's fairness index: (Σx)² / (n·Σx²) ∈ (0, 1], 1 = equal."""
    xs = [x for x in xs if x == x]        # drop NaNs
    if not xs:
        return float("nan")
    sq = sum(x * x for x in xs)
    if sq == 0.0:
        return 1.0
    return sum(xs) ** 2 / (len(xs) * sq)


# ---------------------------------------------------------------------------
# streaming sketches
# ---------------------------------------------------------------------------
class QuantileSketch:
    """Log-binned quantile sketch (DDSketch-style) with bounded relative
    error: every reported quantile q satisfies |q - q*| <= alpha * q*
    for the true quantile q*.  Positive values map to geometric buckets
    ``ceil(log_gamma(x))`` with gamma = (1+alpha)/(1-alpha); bucket
    count is O(log(max/min)/alpha), independent of sample count."""

    __slots__ = ("gamma", "_lg", "bins", "n_zero", "count",
                 "sum", "min", "max")

    def __init__(self, alpha: float = 0.003):
        self.gamma = (1.0 + alpha) / (1.0 - alpha)
        self._lg = math.log(self.gamma)
        self.bins: Dict[int, int] = {}
        self.n_zero = 0                  # values <= 0 collapse to one bin
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def add(self, x: float) -> None:
        self.count += 1
        self.sum += x
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x
        if x <= 0.0:
            self.n_zero += 1
            return
        i = math.ceil(math.log(x) / self._lg)
        self.bins[i] = self.bins.get(i, 0) + 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else float("nan")

    def percentile(self, p: float) -> float:
        if self.count == 0:
            return float("nan")
        if p <= 0.0:
            return self.min
        if p >= 100.0:
            return self.max
        # nearest-rank target: empirically the closest convention to the
        # interpolating exact percentile() (floor/ceil bias a half order
        # statistic, which at the distribution tails costs more than the
        # sketch's own alpha)
        rank = round(p / 100.0 * (self.count - 1))
        if rank < self.n_zero:
            return min(self.min, 0.0)
        seen = self.n_zero
        for i in sorted(self.bins):
            seen += self.bins[i]
            if seen > rank:
                # bucket midpoint in log space: 2γ^i/(γ+1)
                v = 2.0 * self.gamma ** i / (self.gamma + 1.0)
                return min(max(v, self.min), self.max)
        return self.max

    def cdf_points(self, n: int = 100) -> List[Tuple[float, float]]:
        """Approximate CDF sampled at n+1 evenly spaced fractions —
        drop-in for ``cdf_points`` on the folded values.  One pass over
        the sorted bins serves every fraction (percentile() per point
        would re-sort and re-scan n+1 times)."""
        if self.count == 0:
            return []
        mids = [(seen, 2.0 * self.gamma ** i / (self.gamma + 1.0))
                for seen, i in self._cumulative_bins()]
        out: List[Tuple[float, float]] = []
        j = 0
        for k in range(n + 1):
            p = 100.0 * k / n
            if p <= 0.0:
                out.append((self.min, 0.0))
                continue
            if p >= 100.0:
                out.append((self.max, 1.0))
                continue
            rank = round(p / 100.0 * (self.count - 1))
            if rank < self.n_zero:
                out.append((min(self.min, 0.0), k / n))
                continue
            while j < len(mids) and mids[j][0] <= rank:
                j += 1
            v = mids[j][1] if j < len(mids) else self.max
            out.append((min(max(v, self.min), self.max), k / n))
        return out

    def _cumulative_bins(self) -> List[Tuple[int, int]]:
        """(cumulative count, bin index) in value order, zeros included
        in the running count."""
        out = []
        seen = self.n_zero
        for i in sorted(self.bins):
            seen += self.bins[i]
            out.append((seen, i))
        return out

    def stats(self) -> Dict[str, float]:
        return {"p50": self.percentile(50), "p90": self.percentile(90),
                "p99": self.percentile(99),
                "max": self.max if self.count else float("nan"),
                "mean": self.mean}


class StreamingStats:
    """Constant-memory aggregate of retired requests.

    ``Simulation`` folds every finished (or rejected) request in as it
    retires; ``Results`` reads summaries from here when the request list
    was not retained.  Counters/min/max/mean are exact; quantiles carry
    the sketch's bounded relative error.  ``tenant_slos`` maps tenant_id
    to its (ttft_slo, tpot_slo) so per-tenant SLO attainment can be
    counted at fold time (once a request is dropped, SLOs cannot be
    re-evaluated against new thresholds).
    """

    def __init__(self, alpha: float = 0.003,
                 slo: Optional[Tuple[float, float]] = None,
                 tenant_slos: Optional[Dict[str, Tuple[float, float]]] = None):
        self.alpha = alpha
        self.slo = slo
        self.latency = QuantileSketch(alpha)
        self.norm_latency = QuantileSketch(alpha)
        self.ttft = QuantileSketch(alpha)
        self.queue_delay = QuantileSketch(alpha)
        self.n_finished = 0
        self.n_rejected = 0
        self.n_folded = 0
        self.tokens = 0
        self.preempts = 0
        self.n_slo_ok = 0
        self.first_arrival = math.inf
        self.last_finish = -math.inf
        # speculative decoding counters
        self.spec_steps = 0
        self.spec_tokens = 0
        self.draft_proposed = 0
        self.draft_accepted = 0
        # hierarchical KV memory counters (docs/MEMORY.md): folded here
        # so retain_requests=False keeps swap/prefix accounting exact
        self.swap_outs = 0
        self.swap_ins = 0
        self.shared_tokens = 0
        self.cow_copies = 0
        # cache-aware routing counters (docs/ROUTING.md)
        self.fetches = 0
        self.fetched_tokens = 0
        self.prefix_requests = 0
        #: latency-attribution sums (docs/OBSERVABILITY.md): per-
        #: component totals of the finalized TTFT / decode / per-token
        #: breakdowns, folded at retire time so drop-mode keeps the
        #: conserved decomposition without retaining requests
        self.attrib = {"n": 0, "ttft": {}, "decode": {}, "tpot": {}}
        self._tenant_slos = tenant_slos or {}
        self.tenants: Dict[str, "StreamingStats"] = {}
        #: per-model sub-sketches (docs/HETEROGENEITY.md), keyed by the
        #: concrete model name the dispatcher stamped; each inherits the
        #: global streaming SLO so per-model goodput works in drop mode
        self.models: Dict[str, "StreamingStats"] = {}

    # ------------------------------------------------------------------
    def _tenant(self, tid: str) -> "StreamingStats":
        sub = self.tenants.get(tid)
        if sub is None:
            sub = StreamingStats(self.alpha,
                                 slo=self._tenant_slos.get(tid))
            self.tenants[tid] = sub
        return sub

    def _model(self, model: str) -> "StreamingStats":
        sub = self.models.get(model)
        if sub is None:
            sub = StreamingStats(self.alpha, slo=self.slo)
            self.models[model] = sub
        return sub

    def fold(self, req: Request, *, _recurse: bool = True) -> None:
        """Fold one retired request (finished or rejected) and forget it."""
        if _recurse and req.tenant_id is not None:
            self._tenant(req.tenant_id).fold(req, _recurse=False)
        if _recurse and req.model is not None:
            self._model(req.model).fold(req, _recurse=False)
        self.n_folded += 1
        self.preempts += req.preempt_count
        self.spec_steps += req.spec_steps
        self.spec_tokens += req.spec_tokens
        self.draft_proposed += req.draft_proposed
        self.draft_accepted += req.draft_accepted
        self.swap_outs += req.swap_out_count
        self.swap_ins += req.swap_in_count
        self.shared_tokens += req.shared_tokens
        self.cow_copies += req.cow_copies
        self.fetches += req.fetch_count
        self.fetched_tokens += req.fetched_tokens
        if req.prefix_id is not None:
            self.prefix_requests += 1
        ro = getattr(req, "obs", None)
        if ro is not None and ro.final is not None:
            a = self.attrib
            a["n"] += 1
            f = ro.final
            t = a["ttft"]
            for k, v in f["ttft"].items():
                t[k] = t.get(k, 0.0) + v
            d, tp = a["decode"], a["tpot"]
            scale = 1.0 / max(1, f["tokens"] - 1)
            for k, v in f["decode"].items():
                d[k] = d.get(k, 0.0) + v
                tp[k] = tp.get(k, 0.0) + v * scale
        if req.rejected or req.t_finish is None:
            self.n_rejected += 1
            return
        self.n_finished += 1
        self.tokens += req.tokens_generated
        if req.arrival_time < self.first_arrival:
            self.first_arrival = req.arrival_time
        if req.t_finish > self.last_finish:
            self.last_finish = req.t_finish
        self.latency.add(req.latency)
        self.norm_latency.add(req.normalized_latency)
        if req.ttft is not None:
            self.ttft.add(req.ttft)
        if req.queue_delay is not None:
            self.queue_delay.add(req.queue_delay)
        if self.slo is not None and req.meets_slo(*self.slo):
            self.n_slo_ok += 1

    # ------------------------------------------------------------------
    @property
    def span(self) -> float:
        if self.n_finished == 0:
            return 0.0
        return self.last_finish - self.first_arrival

    def throughput(self) -> float:
        return self.n_finished / max(self.span, 1e-9) \
            if self.n_finished else 0.0

    def token_throughput(self) -> float:
        return self.tokens / max(self.span, 1e-9) if self.n_finished else 0.0

    def goodput(self) -> float:
        """Requests/s that met the configured SLO (needs ``slo`` set at
        construction: SLOs are evaluated at fold time)."""
        if self.slo is None or self.n_finished == 0:
            return float("nan") if self.slo is None else 0.0
        return self.n_slo_ok / max(self.span, 1e-9)


@dataclass
class Results:
    requests: List[Request]
    sim_time: float
    worker_mem: Dict[int, list] = field(default_factory=dict)
    pool_stats: Optional[dict] = None
    #: per-worker BlockManager.stats() (prefix sharing / occupancy)
    mem_stats: Optional[Dict[int, dict]] = None
    #: per-worker SwapManager.stats() when preemption_mode="swap"
    swap_stats: Optional[Dict[int, dict]] = None
    wall_time: float = 0.0
    events: int = 0
    #: tenant_id -> TenantSpec when the sim ran with tenants (tenancy)
    tenant_specs: Optional[Dict[str, object]] = None
    #: AdmissionController.stats() snapshot at end of sim
    admission_stats: Optional[Dict[str, Dict[str, float]]] = None
    #: per-worker pipeline-parallel accounting (docs/PARALLELISM.md):
    #: {wid: {"pp_bubble_time", "pp_comm_time", "pp_span_time",
    #: "busy_time", "iterations"}} when the sim ran with pp > 1
    parallel_stats: Optional[Dict[int, Dict[str, float]]] = None
    #: streaming aggregates when the sim ran with retain_requests=False;
    #: ``requests`` then holds only the (few) never-finished leftovers
    stats: Optional[StreamingStats] = None
    #: peak simultaneously-live Request objects (streaming memory model)
    max_live: int = 0
    #: repro.obs.TraceRecorder when the sim ran with ObsSpec(trace=True)
    trace: Optional[object] = field(default=None, repr=False)
    #: repro.obs.TimeSeriesRecorder when ObsSpec(timeseries=True)
    timeseries: Optional[object] = field(default=None, repr=False)
    #: injected-fault log (repro.core.faults.FaultEvent) when the sim
    #: ran with faults or a chaos spec; availability_summary() derives
    #: all availability accounting from it
    fault_events: Optional[list] = None
    #: worker count (after replica expansion) for capacity availability
    n_workers: int = 0
    #: wid -> hosted model name when the sim ran heterogeneous fleets
    #: (docs/HETEROGENEITY.md); drives per-model availability and
    #: ``model_summary`` worker counts
    worker_models: Optional[Dict[int, str]] = None
    #: the arch requests defaulted to when they arrived unstamped
    default_model: Optional[str] = None
    #: autoscaler action log (repro.core.autoscale.ScaleEvent) when the
    #: sim ran with SimSpec.autoscale enabled; scaling_summary() and
    #: the byte-identity tests derive everything from it
    scale_events: Optional[list] = None
    #: wid -> (t_provisioned, t_retired-or-None): the span each worker
    #: actually existed for.  Filled by every simulate() run (static
    #: fleets get (0.0, None)); drives time-weighted billing and the
    #: time-varying capacity accounting in availability_summary()
    worker_spans: Optional[Dict[int, Tuple[float, Optional[float]]]] = None
    #: wid -> device price (A100-relative $/s units, matching
    #: explore.worker_price) for uptime-weighted cost
    worker_prices: Optional[Dict[int, float]] = None
    #: wid -> {"prefill_time", "decode_time", "prefill_tokens",
    #: "decode_tokens", "busy_time"}: busy time split by phase, the
    #: basis of the prefill/decode $/1M-tokens split
    phase_stats: Optional[Dict[int, Dict[str, float]]] = None
    #: cluster-wide cache-aware routing counters (docs/ROUTING.md):
    #: Simulation.fetch_prefix fetch accounting merged with the
    #: prefix_affinity policy's and PrefixRegistry's stats(); None when
    #: neither prefix routing nor a remote KV tier was active
    routing_stats: Optional[Dict[str, float]] = None
    #: RemoteKVStore.stats() snapshot when SimSpec.remote_kv was set
    remote_stats: Optional[Dict[str, float]] = None
    #: per-Results caches: finished list and sorted metric lists are
    #: computed once (the repeated-full-sort fix); safe because Results
    #: is read after the simulation has finished mutating requests
    _cache: Dict[str, list] = field(default_factory=dict, repr=False,
                                    compare=False)

    # ------------------------------------------------------------------
    @property
    def finished(self) -> List[Request]:
        fin = self._cache.get("finished")
        if fin is None:
            fin = [r for r in self.requests if r.t_finish is not None]
            self._cache["finished"] = fin
        return fin

    def _sorted(self, name: str, values) -> List[float]:
        s = self._cache.get(name)
        if s is None:
            s = sorted(values)
            self._cache[name] = s
        return s

    def throughput(self) -> float:
        """Finished requests per second of simulated time."""
        if self.stats is not None:
            return self.stats.throughput()
        f = self.finished
        if not f:
            return 0.0
        span = max(r.t_finish for r in f) - min(r.arrival_time for r in f)
        return len(f) / max(span, 1e-9)

    def token_throughput(self) -> float:
        if self.stats is not None:
            return self.stats.token_throughput()
        f = self.finished
        if not f:
            return 0.0
        span = max(r.t_finish for r in f) - min(r.arrival_time for r in f)
        return sum(r.tokens_generated for r in f) / max(span, 1e-9)

    def latencies(self) -> List[float]:
        return [r.latency for r in self.finished]

    def normalized_latencies(self) -> List[float]:
        return [r.normalized_latency for r in self.finished]

    def ttfts(self) -> List[float]:
        return [r.ttft for r in self.finished if r.ttft is not None]

    def latency_stats(self) -> Dict[str, float]:
        if self.stats is not None:
            return self.stats.latency.stats()
        lats = self._sorted("latencies", self.latencies())
        return {"p50": _interp_percentile(lats, 50),
                "p90": _interp_percentile(lats, 90),
                "p99": _interp_percentile(lats, 99),
                "max": lats[-1] if lats else float("nan"),
                "mean": sum(lats) / len(lats) if lats else float("nan")}

    def latency_cdf(self, n: int = 100):
        if self.stats is not None:
            return self.stats.latency.cdf_points(n)
        return _cdf_points_sorted(
            self._sorted("latencies", self.latencies()), n)

    def slo_goodput(self, *, ttft_slo: float = 0.0,
                    mtpot_slo: float = 0.0) -> float:
        """Requests/s that met their SLOs (paper's goodput metric).  In
        streaming mode SLOs are evaluated at fold time, so this requires
        the thresholds configured up front (StreamingStats.slo)."""
        if self.stats is not None:
            if self.stats.slo == (ttft_slo, mtpot_slo):
                return self.stats.goodput()
            return float("nan")
        ok = [r for r in self.finished
              if r.meets_slo(ttft_slo, mtpot_slo)]
        if not ok:
            return 0.0
        span = max(r.t_finish for r in self.finished) - \
            min(r.arrival_time for r in self.finished)
        return len(ok) / max(span, 1e-9)

    # ---- observability (repro.obs, docs/OBSERVABILITY.md) -------------
    def export_trace(self, path: str) -> str:
        """Write the Chrome trace-event JSON (Perfetto-loadable)."""
        if self.trace is None:
            raise ValueError("tracing was not enabled: run with "
                             "SimSpec(obs=ObsSpec(trace=True))")
        return self.trace.export(path)

    def export_timeseries(self, path: str) -> str:
        """Write the sampled time series; ``.json`` suffix selects JSON,
        anything else CSV."""
        if self.timeseries is None:
            raise ValueError("time series was not enabled: run with "
                             "SimSpec(obs=ObsSpec(timeseries=True))")
        if path.endswith(".json"):
            return self.timeseries.export_json(path)
        return self.timeseries.export_csv(path)

    def time_breakdown(self) -> dict:
        """Mean (and, in exact mode, P99-tail) decomposition of TTFT,
        decode-phase and per-token latency into attribution components
        (repro.obs.attribution.COMPONENTS).  Requires the sim to have
        run with ``ObsSpec(attribution=True)``; works in streaming
        drop-mode via the sums folded into ``StreamingStats``."""
        from repro.obs.attribution import (aggregate_exact,
                                           aggregate_streaming)
        if self.stats is not None and self.stats.attrib["n"]:
            return aggregate_streaming(self.stats.attrib)
        return aggregate_exact(self.finished)

    def explain(self) -> str:
        """``time_breakdown()`` rendered as a table."""
        from repro.obs.attribution import format_breakdown
        return format_breakdown(self.time_breakdown())

    def preemption_rate(self) -> float:
        if self.stats is not None:
            n = self.stats.n_folded + len(self.requests)
            pre = self.stats.preempts + sum(r.preempt_count
                                            for r in self.requests)
            return pre / max(1, n)
        n = len(self.requests)
        return sum(r.preempt_count for r in self.requests) / max(1, n)

    # ---- hierarchical KV memory (repro.core.mem) ----------------------
    def memory_summary(self) -> Dict[str, float]:
        """Hierarchical-memory accounting (docs/MEMORY.md): the
        preemption-mode breakdown (how many evictions swapped vs
        recomputed), PCIe swap volume, and prefix-sharing/copy-on-write
        activity.  Works in both exact and streaming modes — leftover
        in-flight requests are added to the folded counters."""
        if self.stats is not None:
            preempts = self.stats.preempts + sum(
                r.preempt_count for r in self.requests)
            swap_outs = self.stats.swap_outs + sum(
                r.swap_out_count for r in self.requests)
            swap_ins = self.stats.swap_ins + sum(
                r.swap_in_count for r in self.requests)
            shared_tokens = self.stats.shared_tokens + sum(
                r.shared_tokens for r in self.requests)
            cow = self.stats.cow_copies + sum(
                r.cow_copies for r in self.requests)
        else:
            preempts = sum(r.preempt_count for r in self.requests)
            swap_outs = sum(r.swap_out_count for r in self.requests)
            swap_ins = sum(r.swap_in_count for r in self.requests)
            shared_tokens = sum(r.shared_tokens for r in self.requests)
            cow = sum(r.cow_copies for r in self.requests)
        out = {"preempts": preempts,
               "swap_preempts": swap_outs,
               "recompute_preempts": preempts - swap_outs,
               "swap_ins": swap_ins,
               "shared_tokens": shared_tokens,
               "cow_copies": cow}
        if self.swap_stats:
            vals = self.swap_stats.values()
            out["swap_bytes_out"] = sum(s["bytes_out"] for s in vals)
            out["swap_bytes_in"] = sum(s["bytes_in"] for s in vals)
            out["host_peak_bytes"] = max(
                s["peak_used_bytes"] for s in vals)
            out["swap_fallbacks"] = sum(s["fallbacks"] for s in vals)
        if self.mem_stats:
            hits = sum(s["shared_hits"] for s in self.mem_stats.values())
            misses = sum(s["shared_misses"]
                         for s in self.mem_stats.values())
            out["prefix_hit_rate"] = hits / (hits + misses) \
                if hits + misses else 0.0
        return out

    # ---- cache-aware routing (docs/ROUTING.md) ------------------------
    def routing_summary(self) -> Dict[str, float]:
        """Cache-aware prefix-routing and remote-KV-tier accounting:
        affinity hit rate at the global scheduler, cross-worker /
        remote-tier KV fetch volume and pricing, registry churn, and
        remote-store occupancy.  ``ROUTING_SUMMARY_FIELDS`` lists every
        returned key.  Works in both exact and streaming modes —
        per-request fetch counters are folded at retire time, cluster
        counters come from ``routing_stats``/``remote_stats``."""
        if self.stats is not None:
            prefix_requests = self.stats.prefix_requests + sum(
                1 for r in self.requests if r.prefix_id is not None)
            fetches = self.stats.fetches + sum(
                r.fetch_count for r in self.requests)
            fetched_tokens = self.stats.fetched_tokens + sum(
                r.fetched_tokens for r in self.requests)
        else:
            prefix_requests = sum(1 for r in self.requests
                                  if r.prefix_id is not None)
            fetches = sum(r.fetch_count for r in self.requests)
            fetched_tokens = sum(r.fetched_tokens for r in self.requests)
        out: Dict[str, float] = {
            "prefix_requests": prefix_requests,
            "fetches": fetches,
            "fetched_tokens": fetched_tokens,
        }
        rs = self.routing_stats or {}
        for k in ("affinity_hits", "affinity_misses",
                  "overload_diversions", "fetch_hints",
                  "peer_fetches", "remote_fetches", "fetch_bytes",
                  "fetch_time_s", "fetch_misses", "fetch_recomputes",
                  "registry_prefixes", "registry_entries",
                  "registry_publishes", "registry_invalidations",
                  "registry_expirations", "registry_evictions"):
            out[k] = rs.get(k, 0)
        routed = out["affinity_hits"] + out["affinity_misses"]
        out["affinity_hit_rate"] = out["affinity_hits"] / routed \
            if routed else 0.0
        rem = self.remote_stats or {}
        out["remote_capacity_bytes"] = rem.get("capacity_bytes", 0.0)
        out["remote_used_bytes"] = rem.get("used_bytes", 0.0)
        out["remote_peak_used_bytes"] = rem.get("peak_used_bytes", 0.0)
        out["remote_entries"] = rem.get("n_entries", 0)
        out["remote_stores"] = rem.get("stores", 0)
        out["remote_hits"] = rem.get("hits", 0)
        out["remote_misses"] = rem.get("misses", 0)
        out["remote_evictions"] = rem.get("evictions", 0)
        out["remote_rejects"] = rem.get("rejects", 0)
        return out

    # ---- parallelism (docs/PARALLELISM.md) ----------------------------
    def parallel_summary(self) -> Dict[str, float]:
        """Pipeline-parallel accounting across workers: total fill/drain
        bubble and stage-boundary comm time, and their fractions of the
        pipeline span (step time x steps, framework overhead excluded).
        ``bubble_fraction`` matches the closed form
        ``(pp-1)/(microbatches+pp-1)`` when every iteration fills its
        configured micro-batch count (tail iterations shrink it)."""
        if not self.parallel_stats:
            return {"pp_bubble_time": 0.0, "pp_comm_time": 0.0,
                    "pp_span_time": 0.0, "bubble_fraction": 0.0,
                    "comm_fraction": 0.0}
        vals = self.parallel_stats.values()
        bubble = sum(s["pp_bubble_time"] for s in vals)
        comm = sum(s["pp_comm_time"] for s in vals)
        span = sum(s["pp_span_time"] for s in vals)
        return {"pp_bubble_time": bubble, "pp_comm_time": comm,
                "pp_span_time": span,
                "bubble_fraction": bubble / span if span else 0.0,
                "comm_fraction": comm / span if span else 0.0}

    # ---- speculative decoding (repro.core.specdecode) -----------------
    def spec_summary(self) -> Dict[str, float]:
        """Aggregate speculative-decoding counters: acceptance rate of
        draft tokens, effective tokens emitted per verify step (the
        speedup lever: 1.0 means speculation bought nothing), and the
        fraction of tokens produced speculatively."""
        if self.stats is not None:
            steps, proposed = self.stats.spec_steps, self.stats.draft_proposed
            accepted, spec_tokens = self.stats.draft_accepted, \
                self.stats.spec_tokens
            total_tokens = self.stats.tokens
        else:
            steps = sum(r.spec_steps for r in self.requests)
            proposed = sum(r.draft_proposed for r in self.requests)
            accepted = sum(r.draft_accepted for r in self.requests)
            spec_tokens = sum(r.spec_tokens for r in self.requests)
            total_tokens = sum(r.tokens_generated for r in self.requests)
        return {
            "spec_steps": steps,
            "acceptance_rate": accepted / proposed if proposed
            else float("nan"),
            "eff_tokens_per_step": spec_tokens / steps if steps
            else float("nan"),
            "spec_token_frac": spec_tokens / total_tokens if total_tokens
            else 0.0,
        }

    # ---- multi-tenant breakdowns (repro.core.tenancy) -----------------
    def tenant_ids(self) -> List[str]:
        if self.tenant_specs:
            return sorted(self.tenant_specs)
        if self.stats is not None:
            return sorted(self.stats.tenants)
        return sorted({r.tenant_id for r in self.requests
                       if r.tenant_id is not None})

    def for_tenant(self, tenant_id: str) -> "Results":
        """A Results view restricted to one tenant's requests (shares the
        simulation span, so rates remain comparable across tenants)."""
        return Results(
            requests=[r for r in self.requests if r.tenant_id == tenant_id],
            sim_time=self.sim_time,
            tenant_specs=self.tenant_specs,
            stats=self.stats.tenants.get(tenant_id)
            if self.stats is not None else None)

    def tenant_token_throughputs(self) -> Dict[str, float]:
        """Generated tokens/s per tenant over the shared finished-span —
        the quantity WFQ shares by weight."""
        if self.stats is not None:
            span = self.stats.span
            return {t: self.stats.tenants[t].tokens / max(span, 1e-9)
                    if t in self.stats.tenants else 0.0
                    for t in self.tenant_ids()}
        f = self.finished
        if not f:
            return {t: 0.0 for t in self.tenant_ids()}
        span = max(r.t_finish for r in f) - min(r.arrival_time for r in f)
        out = {}
        for t in self.tenant_ids():
            toks = sum(r.tokens_generated for r in f if r.tenant_id == t)
            out[t] = toks / max(span, 1e-9)
        return out

    def fairness_index(self, *, weighted: bool = False) -> float:
        """Jain index over per-tenant token throughput; ``weighted``
        normalizes each tenant by its tier weight first, so 1.0 means
        throughput shares match configured weights exactly."""
        tps = self.tenant_token_throughputs()
        xs = []
        for t, v in sorted(tps.items()):
            w = 1.0
            if weighted and self.tenant_specs and t in self.tenant_specs:
                w = max(getattr(self.tenant_specs[t].tier, "weight", 1.0),
                        1e-9)
            xs.append(v / w)
        return jain_index(xs)

    def tenant_summary(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant latency/TTFT percentiles, SLO attainment, goodput,
        rejects and gateway queueing delay.  Per-tenant counters sum to
        the aggregate (property-tested in tests/test_tenancy.py)."""
        if self.stats is not None:
            return self._tenant_summary_streaming()
        out: Dict[str, Dict[str, float]] = {}
        tps = self.tenant_token_throughputs()
        for t in self.tenant_ids():
            sub = self.for_tenant(t)
            spec = (self.tenant_specs or {}).get(t)
            ttft_slo = getattr(getattr(spec, "tier", None), "ttft_slo", 0.0)
            tpot_slo = getattr(getattr(spec, "tier", None), "tpot_slo", 0.0)
            fin = sub.finished
            n_ok = sum(1 for r in fin if r.meets_slo(ttft_slo, tpot_slo))
            qd = [r.queue_delay for r in sub.requests
                  if r.queue_delay is not None]
            lats = sub._sorted("latencies", sub.latencies())
            tt = sub._sorted("ttfts", sub.ttfts())
            row = {
                "n_requests": len(sub.requests),
                "n_finished": len(fin),
                "n_rejected": sum(1 for r in sub.requests if r.rejected),
                "tokens": sum(r.tokens_generated for r in fin),
                "token_tps": tps.get(t, 0.0),
                "latency_p50": _interp_percentile(lats, 50),
                "latency_p99": _interp_percentile(lats, 99),
                "ttft_p50": _interp_percentile(tt, 50),
                "ttft_p99": _interp_percentile(tt, 99),
                "queue_delay_mean": sum(qd) / len(qd) if qd
                else 0.0,
                "slo_attainment": n_ok / len(sub.requests)
                if sub.requests else float("nan"),
                "goodput_rps": sub.slo_goodput(
                    ttft_slo=ttft_slo, mtpot_slo=tpot_slo),
                "preempt_rate": sub.preemption_rate(),
            }
            out[t] = row
        return out

    def _tenant_summary_streaming(self) -> Dict[str, Dict[str, float]]:
        """tenant_summary from folded per-tenant sketches (drop mode):
        same keys, span shared with the aggregate so rates compare."""
        out: Dict[str, Dict[str, float]] = {}
        span = self.stats.span
        for t in self.tenant_ids():
            s = self.stats.tenants.get(t)
            if s is None:
                s = StreamingStats(self.stats.alpha)
            out[t] = {
                "n_requests": s.n_folded,
                "n_finished": s.n_finished,
                "n_rejected": s.n_rejected,
                "tokens": s.tokens,
                "token_tps": s.tokens / max(span, 1e-9),
                "latency_p50": s.latency.percentile(50),
                "latency_p99": s.latency.percentile(99),
                "ttft_p50": s.ttft.percentile(50),
                "ttft_p99": s.ttft.percentile(99),
                "queue_delay_mean": s.queue_delay.mean
                if s.queue_delay.count else 0.0,
                "slo_attainment": s.n_slo_ok / s.n_folded
                if s.slo is not None and s.n_folded else float("nan"),
                "goodput_rps": s.n_slo_ok / max(span, 1e-9)
                if s.slo is not None else float("nan"),
                "preempt_rate": s.preempts / max(1, s.n_folded),
            }
        return out

    # ---- heterogeneous multi-model fleets (docs/HETEROGENEITY.md) -----
    def model_ids(self) -> List[str]:
        """Every model served or hosted, in sorted order."""
        out = set()
        if self.worker_models:
            out.update(m for m in self.worker_models.values()
                       if m is not None)
        if self.stats is not None:
            out.update(self.stats.models)
        out.update(r.model for r in self.requests if r.model is not None)
        return sorted(out)

    def for_model(self, model: str) -> "Results":
        """A Results view restricted to one model's requests (shares the
        simulation span, so rates remain comparable across models)."""
        return Results(
            requests=[r for r in self.requests if r.model == model],
            sim_time=self.sim_time,
            tenant_specs=self.tenant_specs,
            stats=self.stats.models.get(model)
            if self.stats is not None else None,
            worker_models={wid: m for wid, m
                           in (self.worker_models or {}).items()
                           if m == model} or None,
            default_model=self.default_model)

    def model_summary(self, *, ttft_slo: float = 0.0,
                      mtpot_slo: float = 0.0
                      ) -> Dict[str, Dict[str, float]]:
        """Per-model latency/TTFT percentiles, SLO attainment (fraction
        of *finished* requests meeting the SLO), goodput and hosting
        worker count — the multi-model mirror of ``tenant_summary``.
        ``MODEL_SUMMARY_FIELDS`` lists every row key.  In streaming mode
        SLO columns require the thresholds configured up front
        (``SimSpec.streaming_slo``), like ``slo_goodput``."""
        if self.stats is not None:
            return self._model_summary_streaming(ttft_slo, mtpot_slo)
        out: Dict[str, Dict[str, float]] = {}
        f = self.finished
        span = (max(r.t_finish for r in f)
                - min(r.arrival_time for r in f)) if f else 0.0
        hosts = self.worker_models or {}
        for m in self.model_ids():
            sub = self.for_model(m)
            fin = sub.finished
            n_ok = sum(1 for r in fin if r.meets_slo(ttft_slo, mtpot_slo))
            lats = sub._sorted("latencies", sub.latencies())
            tt = sub._sorted("ttfts", sub.ttfts())
            out[m] = {
                "n_requests": len(sub.requests),
                "n_finished": len(fin),
                "tokens": sum(r.tokens_generated for r in fin),
                "token_tps": sum(r.tokens_generated for r in fin)
                / max(span, 1e-9) if fin else 0.0,
                "latency_p50": _interp_percentile(lats, 50),
                "latency_p99": _interp_percentile(lats, 99),
                "ttft_p50": _interp_percentile(tt, 50),
                "ttft_p99": _interp_percentile(tt, 99),
                "slo_attainment": n_ok / len(fin) if fin
                else float("nan"),
                "goodput_rps": n_ok / max(span, 1e-9) if fin else 0.0,
                "preempt_rate": sub.preemption_rate(),
                "n_workers": sum(1 for v in hosts.values() if v == m),
            }
        return out

    def _model_summary_streaming(self, ttft_slo: float, mtpot_slo: float
                                 ) -> Dict[str, Dict[str, float]]:
        """model_summary from folded per-model sketches (drop mode):
        same keys, span shared with the aggregate so rates compare."""
        out: Dict[str, Dict[str, float]] = {}
        span = self.stats.span
        hosts = self.worker_models or {}
        for m in self.model_ids():
            s = self.stats.models.get(m)
            if s is None:
                s = StreamingStats(self.stats.alpha)
            slo_match = s.slo == (ttft_slo, mtpot_slo) \
                and s.slo is not None
            out[m] = {
                "n_requests": s.n_folded,
                "n_finished": s.n_finished,
                "tokens": s.tokens,
                "token_tps": s.tokens / max(span, 1e-9),
                "latency_p50": s.latency.percentile(50),
                "latency_p99": s.latency.percentile(99),
                "ttft_p50": s.ttft.percentile(50),
                "ttft_p99": s.ttft.percentile(99),
                "slo_attainment": s.n_slo_ok / s.n_finished
                if slo_match and s.n_finished else float("nan"),
                "goodput_rps": s.n_slo_ok / max(span, 1e-9)
                if slo_match else float("nan"),
                "preempt_rate": s.preempts / max(1, s.n_folded),
                "n_workers": sum(1 for v in hosts.values() if v == m),
            }
        return out

    # ---- closed-loop autoscaling (docs/AUTOSCALING.md) ----------------
    def scaling_summary(self) -> dict:
        """Scale-event and cost-economics accounting for a (possibly)
        time-varying fleet.  ``SCALING_SUMMARY_FIELDS`` lists every
        returned key.

        Billing is time-weighted: each worker bills its price over its
        provisioned-to-retired span (``worker_spans``), so
        ``billed_cost`` equals ``spec_price * sim_time`` only for
        static fleets.  ``cost_per_1m_*_tokens`` splits the billed
        cost by each worker's prefill/decode busy-time share (idle
        time allocated pro rata; workers that never ran are excluded
        from the split but still appear in ``billed_cost``)."""
        T = max(self.sim_time, 1e-12)
        spans = self.worker_spans or {
            wid: (0.0, None)
            for wid in range(self.n_workers or len(self.worker_mem)
                             or 1)}
        prices = self.worker_prices or {}
        span_s = {wid: max(0.0, min(e if e is not None else T, T) - s)
                  for wid, (s, e) in spans.items()}
        worker_seconds = sum(span_s.values())
        billed = sum(prices.get(wid, 0.0) * sp
                     for wid, sp in span_s.items())
        # fleet size as a step series over provision/retire breakpoints
        deltas: List[Tuple[float, int]] = []
        for wid, (s, e) in sorted(spans.items()):
            deltas.append((min(s, T), 1))
            if e is not None:
                deltas.append((min(e, T), -1))
        deltas.sort()
        series: List[Tuple[float, int]] = []
        size = 0
        for t, d in deltas:
            size += d
            if series and series[-1][0] == t:
                series[-1] = (t, size)
            else:
                series.append((t, size))
        sizes = [s for _, s in series] or [0]
        ph = self.phase_stats or {}
        p_tok = sum(d["prefill_tokens"] for d in ph.values())
        d_tok = sum(d["decode_tokens"] for d in ph.values())
        if self.stats is not None:
            tokens = self.stats.tokens
        else:
            tokens = sum(r.tokens_generated for r in self.finished)
        p_cost = d_cost = 0.0
        for wid, d in ph.items():
            busy = d.get("busy_time", 0.0)
            if busy <= 0:
                continue
            c = prices.get(wid, 0.0) * span_s.get(wid, 0.0)
            p_cost += c * d["prefill_time"] / busy
            d_cost += c * d["decode_time"] / busy
        events = self.scale_events or []
        n_up = sum(1 for e in events if e.action == "up_request")
        n_down = sum(1 for e in events if e.action == "down_drain")
        req_t: Dict[int, float] = {}
        lags: List[float] = []
        for e in events:
            if e.action == "up_request":
                req_t[e.worker] = e.time
            elif e.action == "up_ready" and e.worker in req_t:
                lags.append(e.time - req_t.pop(e.worker))
        return {
            "n_scale_up": n_up,
            "n_scale_down": n_down,
            "fleet_size_min": min(sizes),
            "fleet_size_max": max(sizes),
            "fleet_size_avg": worker_seconds / T,
            "fleet_size_final": sizes[-1],
            "fleet_size_series": series,
            "worker_seconds": worker_seconds,
            "scale_up_lag_s": sum(lags) / len(lags) if lags else 0.0,
            "billed_cost": billed,
            "cost_per_1m_tokens": billed / tokens * 1e6
            if tokens else float("nan"),
            "cost_per_1m_prefill_tokens": p_cost / p_tok * 1e6
            if p_tok else float("nan"),
            "cost_per_1m_decode_tokens": d_cost / d_tok * 1e6
            if d_tok else float("nan"),
            "events": list(events),
        }

    # ------------------------------------------------------------------
    def availability_summary(self, *, target: float = 0.995,
                             window: Optional[float] = None) -> dict:
        """Availability and error-budget accounting derived from the
        injected-fault log (docs/RELIABILITY.md).

        Definitions (``AVAILABILITY_FIELDS`` lists every returned key):

        * **service availability** — fraction of the observation span
          with at least one worker alive (the cluster could serve);
          ``service_downtime_s`` is the complementary all-down time,
        * **capacity availability** — mean per-worker uptime fraction,
          i.e. ``1 - sum(worker downtime) / (n_workers * span)``; it
          penalizes every lost replica, not just total outages,
        * **error budget** — ``(1 - target) * window_s`` seconds of
          allowed service downtime; the observed all-down time is
          rate-extrapolated from the simulated span to the window
          (pass e.g. ``window=30 * 86400`` for a 30-day budget), and
          ``burn_rate`` is observed unavailability over allowed
          unavailability (1.0 = exactly on budget).

        Downtime intervals open at a ``fail`` event and close at the
        matching ``recover`` (which lands *after* the repair draw and
        the model reload, so recovery cost counts as downtime); an
        interval still open at the end of the run is clipped to
        ``sim_time``.  Degraded (slowdown != 1) spans are tracked
        separately — a straggler serves, slowly.

        With a time-varying fleet (autoscaling), capacity accounting
        is over each worker's *provisioned* span (``worker_spans``),
        not ``n_workers * sim_time``: a replica that existed for half
        the run contributes half a worker-run of capacity, and its
        not-yet-provisioned / already-retired time counts as absent
        for service availability but is not charged as downtime.
        Static fleets reduce to the historical fixed-``n_workers``
        formulas exactly."""
        T = max(self.sim_time, 1e-12)
        spans = self.worker_spans
        if spans:
            wids = sorted(spans)
            span_of = {
                wid: max(0.0, min(e if e is not None else T, T) - s)
                for wid, (s, e) in spans.items()}
        else:
            # legacy surface (hand-built Results): fixed fleet, every
            # worker provisioned for the whole run
            wids = list(range(self.n_workers or len(self.worker_mem)
                              or 1))
            span_of = {wid: T for wid in wids}
        n = len(wids)
        provisioned_s = sum(span_of.values()) or T
        events = sorted(self.fault_events or [],
                        key=lambda e: (e.time, e.worker))
        down: Dict[int, List[Tuple[float, float]]] = {}
        open_down: Dict[int, float] = {}
        deg_open: Dict[int, float] = {}
        degraded = 0.0
        n_failures = 0
        for ev in events:
            if ev.kind == "fail":
                if ev.worker not in open_down:
                    open_down[ev.worker] = ev.time
                    n_failures += 1
            elif ev.kind == "recover":
                t0 = open_down.pop(ev.worker, None)
                if t0 is not None:
                    down.setdefault(ev.worker, []).append(
                        (t0, min(ev.time, T)))
            elif ev.kind == "slowdown":
                if ev.factor != 1.0:
                    deg_open.setdefault(ev.worker, ev.time)
                else:
                    t0 = deg_open.pop(ev.worker, None)
                    if t0 is not None:
                        degraded += max(0.0, min(ev.time, T) - t0)
            # "drain" is not downtime: the worker serves its queue
        for wid, t0 in open_down.items():
            down.setdefault(wid, []).append((t0, T))
        for t0 in deg_open.values():
            degraded += max(0.0, T - t0)
        downtime_per_worker = {
            wid: sum(b - a for a, b in down.get(wid, ()))
            for wid in wids}
        capacity_down = sum(downtime_per_worker.values())
        # service downtime: sweep the interval deltas, accumulate the
        # spans where every one of the nn workers is down at once
        def _all_down(iv_lists, nn: int) -> float:
            deltas: List[Tuple[float, int]] = []
            for ivs in iv_lists:
                for a, b in ivs:
                    deltas.append((a, 1))
                    deltas.append((b, -1))
            deltas.sort()
            total = 0.0
            cnt = 0
            t_all: Optional[float] = None
            for t, d in deltas:
                was_all = cnt == nn
                cnt += d
                if not was_all and cnt == nn:
                    t_all = t
                elif was_all and cnt < nn and t_all is not None:
                    total += t - t_all
                    t_all = None
            return total

        # for service availability a worker is also "absent" outside
        # its provisioned span: before a scale-up lands and after a
        # retirement the replica cannot serve (static fleets add no
        # intervals here, preserving the historical numbers)
        service_iv = {wid: list(down.get(wid, ())) for wid in wids}
        if spans:
            for wid, (s, e) in spans.items():
                if s > 0:
                    service_iv[wid].append((0.0, min(s, T)))
                if e is not None and e < T:
                    service_iv[wid].append((e, T))
        service_down = _all_down(service_iv.values(), n)
        window_s = window if window is not None else T
        scale = window_s / T
        error_budget_s = (1.0 - target) * window_s
        budget_consumed_s = service_down * scale
        if self.stats is not None:
            n_total = self.stats.n_folded + len(self.requests)
            n_fin = self.stats.n_finished
        else:
            n_total = len(self.requests)
            n_fin = len(self.finished)
        tenants: Dict[str, dict] = {}
        if self.tenant_specs:
            for tid, row in self.tenant_summary().items():
                nreq = row.get("n_requests", 0) or 0
                tenants[tid] = {
                    "success_rate": row.get("n_finished", 0) / nreq
                    if nreq else 1.0,
                    "slo_attainment": row.get("slo_attainment",
                                              float("nan"))}
        # per-model availability over each model's hosting workers
        # (docs/HETEROGENEITY.md): a model is serviceable while at least
        # one of its hosts is up, regardless of the rest of the fleet
        models: Dict[str, dict] = {}
        if self.worker_models:
            for m in sorted(set(self.worker_models.values())):
                wids = [wid for wid, name in self.worker_models.items()
                        if name == m]
                m_down = _all_down([down.get(wid, ()) for wid in wids],
                                   len(wids))
                m_cap = sum(downtime_per_worker.get(wid, 0.0)
                            for wid in wids)
                m_span = sum(span_of.get(wid, T) for wid in wids)
                models[m] = {
                    "service_availability": 1.0 - m_down / T,
                    "capacity_availability":
                        1.0 - m_cap / max(m_span, 1e-12),
                    "n_workers": len(wids)}
        return {
            "service_availability": 1.0 - service_down / T,
            "capacity_availability":
                1.0 - capacity_down / provisioned_s,
            "availability_per_worker": {
                wid: 1.0 - dt / max(span_of.get(wid, T), 1e-12)
                for wid, dt in downtime_per_worker.items()},
            "downtime_per_worker": downtime_per_worker,
            "service_downtime_s": service_down,
            "capacity_downtime_s": capacity_down,
            "degraded_s": degraded,
            "n_failures": n_failures,
            "mtbf_observed_s": (provisioned_s - capacity_down)
            / n_failures if n_failures else None,
            "mttr_observed_s": capacity_down / n_failures
            if n_failures else None,
            "target": target,
            "window_s": window_s,
            "error_budget_s": error_budget_s,
            "budget_consumed_s": budget_consumed_s,
            "budget_remaining_frac":
                1.0 - budget_consumed_s / error_budget_s
                if error_budget_s > 0 else float("nan"),
            "burn_rate": (service_down / T) / (1.0 - target)
            if target < 1.0 else float("nan"),
            "request_success_rate": n_fin / n_total if n_total else 1.0,
            "tenants": tenants,
            "models": models,
        }

    def summary(self, *, ttft_slo: float = 0.0,
                mtpot_slo: float = 0.0) -> Dict[str, float]:
        stats = self.stats
        n_finished = stats.n_finished if stats is not None \
            else len(self.finished)
        out = {"throughput_rps": self.throughput(),
               "throughput_tps": self.token_throughput(),
               "n_finished": n_finished,
               "preempt_rate": self.preemption_rate(),
               "sim_time": self.sim_time}
        out.update({f"latency_{k}": v
                    for k, v in self.latency_stats().items()})
        if stats is not None:
            out["ttft_p50"] = stats.ttft.percentile(50)
            out["ttft_p99"] = stats.ttft.percentile(99)
        else:
            tt = self._sorted("ttfts", self.ttfts())
            out["ttft_p50"] = _interp_percentile(tt, 50)
            out["ttft_p99"] = _interp_percentile(tt, 99)
        if ttft_slo or mtpot_slo:
            out["goodput_rps"] = self.slo_goodput(
                ttft_slo=ttft_slo, mtpot_slo=mtpot_slo)
        if self.pool_stats:
            out.update({f"pool_{k}": v for k, v in self.pool_stats.items()})
        if self.swap_stats or (self.mem_stats and any(
                s["shared_hits"] + s["shared_misses"]
                for s in self.mem_stats.values())):
            mem = self.memory_summary()
            for k in ("swap_preempts", "recompute_preempts",
                      "swap_bytes_out", "prefix_hit_rate", "cow_copies"):
                if k in mem:
                    out[k] = mem[k]
        has_spec = stats.spec_steps if stats is not None \
            else any(r.spec_steps for r in self.requests)
        if has_spec:
            out.update({f"spec_{k}" if not k.startswith("spec_") else k: v
                        for k, v in self.spec_summary().items()})
        if self.tenant_specs:
            out["n_rejected"] = stats.n_rejected if stats is not None \
                else sum(1 for r in self.requests if r.rejected)
            out["fairness_jain"] = self.fairness_index()
            out["fairness_jain_weighted"] = self.fairness_index(
                weighted=True)
        return out
