"""Metrics: the dynamic outputs the paper argues single-shot simulators
cannot produce — latency distributions, CDFs, SLO goodput, memory-over-
time — computed from the per-request records."""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.request import Request


def percentile(xs: Sequence[float], p: float) -> float:
    if not xs:
        return float("nan")
    s = sorted(xs)
    k = (len(s) - 1) * p / 100.0
    lo, hi = int(math.floor(k)), int(math.ceil(k))
    if lo == hi:
        return s[lo]
    return s[lo] + (s[hi] - s[lo]) * (k - lo)


def cdf_points(xs: Sequence[float], n: int = 100) -> List[Tuple[float, float]]:
    if not xs:
        return []
    s = sorted(xs)
    return [(s[min(len(s) - 1, int(i * len(s) / n))], i / n)
            for i in range(n + 1)]


@dataclass
class Results:
    requests: List[Request]
    sim_time: float
    worker_mem: Dict[int, list] = field(default_factory=dict)
    pool_stats: Optional[dict] = None
    wall_time: float = 0.0
    events: int = 0

    # ------------------------------------------------------------------
    @property
    def finished(self) -> List[Request]:
        return [r for r in self.requests if r.t_finish is not None]

    def throughput(self) -> float:
        """Finished requests per second of simulated time."""
        f = self.finished
        if not f:
            return 0.0
        span = max(r.t_finish for r in f) - min(r.arrival_time for r in f)
        return len(f) / max(span, 1e-9)

    def token_throughput(self) -> float:
        f = self.finished
        if not f:
            return 0.0
        span = max(r.t_finish for r in f) - min(r.arrival_time for r in f)
        return sum(r.tokens_generated for r in f) / max(span, 1e-9)

    def latencies(self) -> List[float]:
        return [r.latency for r in self.finished]

    def normalized_latencies(self) -> List[float]:
        return [r.normalized_latency for r in self.finished]

    def ttfts(self) -> List[float]:
        return [r.ttft for r in self.finished if r.ttft is not None]

    def latency_stats(self) -> Dict[str, float]:
        lats = self.latencies()
        return {"p50": percentile(lats, 50), "p90": percentile(lats, 90),
                "p99": percentile(lats, 99),
                "max": max(lats) if lats else float("nan"),
                "mean": sum(lats) / len(lats) if lats else float("nan")}

    def latency_cdf(self, n: int = 100):
        return cdf_points(self.latencies(), n)

    def slo_goodput(self, *, ttft_slo: float = 0.0,
                    mtpot_slo: float = 0.0) -> float:
        """Requests/s that met their SLOs (paper's goodput metric)."""
        ok = [r for r in self.finished
              if r.meets_slo(ttft_slo, mtpot_slo)]
        if not ok:
            return 0.0
        span = max(r.t_finish for r in self.finished) - \
            min(r.arrival_time for r in self.finished)
        return len(ok) / max(span, 1e-9)

    def preemption_rate(self) -> float:
        n = len(self.requests)
        return sum(r.preempt_count for r in self.requests) / max(1, n)

    def summary(self, *, ttft_slo: float = 0.0,
                mtpot_slo: float = 0.0) -> Dict[str, float]:
        out = {"throughput_rps": self.throughput(),
               "throughput_tps": self.token_throughput(),
               "n_finished": len(self.finished),
               "preempt_rate": self.preemption_rate(),
               "sim_time": self.sim_time}
        out.update({f"latency_{k}": v
                    for k, v in self.latency_stats().items()})
        tt = self.ttfts()
        out["ttft_p50"] = percentile(tt, 50)
        out["ttft_p99"] = percentile(tt, 99)
        if ttft_slo or mtpot_slo:
            out["goodput_rps"] = self.slo_goodput(
                ttft_slo=ttft_slo, mtpot_slo=mtpot_slo)
        if self.pool_stats:
            out.update({f"pool_{k}": v for k, v in self.pool_stats.items()})
        return out
