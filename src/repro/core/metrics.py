"""Metrics: the dynamic outputs the paper argues single-shot simulators
cannot produce — latency distributions, CDFs, SLO goodput, memory-over-
time — computed from the per-request records."""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.request import Request


def percentile(xs: Sequence[float], p: float) -> float:
    if not xs:
        return float("nan")
    s = sorted(xs)
    k = (len(s) - 1) * p / 100.0
    lo, hi = int(math.floor(k)), int(math.ceil(k))
    if lo == hi:
        return s[lo]
    return s[lo] + (s[hi] - s[lo]) * (k - lo)


def cdf_points(xs: Sequence[float], n: int = 100) -> List[Tuple[float, float]]:
    if not xs:
        return []
    s = sorted(xs)
    return [(s[min(len(s) - 1, int(i * len(s) / n))], i / n)
            for i in range(n + 1)]


def jain_index(xs: Sequence[float]) -> float:
    """Jain's fairness index: (Σx)² / (n·Σx²) ∈ (0, 1], 1 = equal."""
    xs = [x for x in xs if x == x]        # drop NaNs
    if not xs:
        return float("nan")
    sq = sum(x * x for x in xs)
    if sq == 0.0:
        return 1.0
    return sum(xs) ** 2 / (len(xs) * sq)


@dataclass
class Results:
    requests: List[Request]
    sim_time: float
    worker_mem: Dict[int, list] = field(default_factory=dict)
    pool_stats: Optional[dict] = None
    wall_time: float = 0.0
    events: int = 0
    #: tenant_id -> TenantSpec when the sim ran with tenants (tenancy)
    tenant_specs: Optional[Dict[str, object]] = None
    #: AdmissionController.stats() snapshot at end of sim
    admission_stats: Optional[Dict[str, Dict[str, float]]] = None

    # ------------------------------------------------------------------
    @property
    def finished(self) -> List[Request]:
        return [r for r in self.requests if r.t_finish is not None]

    def throughput(self) -> float:
        """Finished requests per second of simulated time."""
        f = self.finished
        if not f:
            return 0.0
        span = max(r.t_finish for r in f) - min(r.arrival_time for r in f)
        return len(f) / max(span, 1e-9)

    def token_throughput(self) -> float:
        f = self.finished
        if not f:
            return 0.0
        span = max(r.t_finish for r in f) - min(r.arrival_time for r in f)
        return sum(r.tokens_generated for r in f) / max(span, 1e-9)

    def latencies(self) -> List[float]:
        return [r.latency for r in self.finished]

    def normalized_latencies(self) -> List[float]:
        return [r.normalized_latency for r in self.finished]

    def ttfts(self) -> List[float]:
        return [r.ttft for r in self.finished if r.ttft is not None]

    def latency_stats(self) -> Dict[str, float]:
        lats = self.latencies()
        return {"p50": percentile(lats, 50), "p90": percentile(lats, 90),
                "p99": percentile(lats, 99),
                "max": max(lats) if lats else float("nan"),
                "mean": sum(lats) / len(lats) if lats else float("nan")}

    def latency_cdf(self, n: int = 100):
        return cdf_points(self.latencies(), n)

    def slo_goodput(self, *, ttft_slo: float = 0.0,
                    mtpot_slo: float = 0.0) -> float:
        """Requests/s that met their SLOs (paper's goodput metric)."""
        ok = [r for r in self.finished
              if r.meets_slo(ttft_slo, mtpot_slo)]
        if not ok:
            return 0.0
        span = max(r.t_finish for r in self.finished) - \
            min(r.arrival_time for r in self.finished)
        return len(ok) / max(span, 1e-9)

    def preemption_rate(self) -> float:
        n = len(self.requests)
        return sum(r.preempt_count for r in self.requests) / max(1, n)

    # ---- speculative decoding (repro.core.specdecode) -----------------
    def spec_summary(self) -> Dict[str, float]:
        """Aggregate speculative-decoding counters: acceptance rate of
        draft tokens, effective tokens emitted per verify step (the
        speedup lever: 1.0 means speculation bought nothing), and the
        fraction of tokens produced speculatively."""
        steps = sum(r.spec_steps for r in self.requests)
        proposed = sum(r.draft_proposed for r in self.requests)
        accepted = sum(r.draft_accepted for r in self.requests)
        spec_tokens = sum(r.spec_tokens for r in self.requests)
        total_tokens = sum(r.tokens_generated for r in self.requests)
        return {
            "spec_steps": steps,
            "acceptance_rate": accepted / proposed if proposed
            else float("nan"),
            "eff_tokens_per_step": spec_tokens / steps if steps
            else float("nan"),
            "spec_token_frac": spec_tokens / total_tokens if total_tokens
            else 0.0,
        }

    # ---- multi-tenant breakdowns (repro.core.tenancy) -----------------
    def tenant_ids(self) -> List[str]:
        if self.tenant_specs:
            return sorted(self.tenant_specs)
        return sorted({r.tenant_id for r in self.requests
                       if r.tenant_id is not None})

    def for_tenant(self, tenant_id: str) -> "Results":
        """A Results view restricted to one tenant's requests (shares the
        simulation span, so rates remain comparable across tenants)."""
        return Results(
            requests=[r for r in self.requests if r.tenant_id == tenant_id],
            sim_time=self.sim_time,
            tenant_specs=self.tenant_specs)

    def tenant_token_throughputs(self) -> Dict[str, float]:
        """Generated tokens/s per tenant over the shared finished-span —
        the quantity WFQ shares by weight."""
        f = self.finished
        if not f:
            return {t: 0.0 for t in self.tenant_ids()}
        span = max(r.t_finish for r in f) - min(r.arrival_time for r in f)
        out = {}
        for t in self.tenant_ids():
            toks = sum(r.tokens_generated for r in f if r.tenant_id == t)
            out[t] = toks / max(span, 1e-9)
        return out

    def fairness_index(self, *, weighted: bool = False) -> float:
        """Jain index over per-tenant token throughput; ``weighted``
        normalizes each tenant by its tier weight first, so 1.0 means
        throughput shares match configured weights exactly."""
        tps = self.tenant_token_throughputs()
        xs = []
        for t, v in sorted(tps.items()):
            w = 1.0
            if weighted and self.tenant_specs and t in self.tenant_specs:
                w = max(getattr(self.tenant_specs[t].tier, "weight", 1.0),
                        1e-9)
            xs.append(v / w)
        return jain_index(xs)

    def tenant_summary(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant latency/TTFT percentiles, SLO attainment, goodput,
        rejects and gateway queueing delay.  Per-tenant counters sum to
        the aggregate (property-tested in tests/test_tenancy.py)."""
        out: Dict[str, Dict[str, float]] = {}
        tps = self.tenant_token_throughputs()
        for t in self.tenant_ids():
            sub = self.for_tenant(t)
            spec = (self.tenant_specs or {}).get(t)
            ttft_slo = getattr(getattr(spec, "tier", None), "ttft_slo", 0.0)
            tpot_slo = getattr(getattr(spec, "tier", None), "tpot_slo", 0.0)
            fin = sub.finished
            n_ok = sum(1 for r in fin if r.meets_slo(ttft_slo, tpot_slo))
            qd = [r.queue_delay for r in sub.requests
                  if r.queue_delay is not None]
            row = {
                "n_requests": len(sub.requests),
                "n_finished": len(fin),
                "n_rejected": sum(1 for r in sub.requests if r.rejected),
                "tokens": sum(r.tokens_generated for r in fin),
                "token_tps": tps.get(t, 0.0),
                "latency_p50": percentile(sub.latencies(), 50),
                "latency_p99": percentile(sub.latencies(), 99),
                "ttft_p50": percentile(sub.ttfts(), 50),
                "ttft_p99": percentile(sub.ttfts(), 99),
                "queue_delay_mean": sum(qd) / len(qd) if qd
                else 0.0,
                "slo_attainment": n_ok / len(sub.requests)
                if sub.requests else float("nan"),
                "goodput_rps": sub.slo_goodput(
                    ttft_slo=ttft_slo, mtpot_slo=tpot_slo),
                "preempt_rate": sub.preemption_rate(),
            }
            out[t] = row
        return out

    def summary(self, *, ttft_slo: float = 0.0,
                mtpot_slo: float = 0.0) -> Dict[str, float]:
        out = {"throughput_rps": self.throughput(),
               "throughput_tps": self.token_throughput(),
               "n_finished": len(self.finished),
               "preempt_rate": self.preemption_rate(),
               "sim_time": self.sim_time}
        out.update({f"latency_{k}": v
                    for k, v in self.latency_stats().items()})
        tt = self.ttfts()
        out["ttft_p50"] = percentile(tt, 50)
        out["ttft_p99"] = percentile(tt, 99)
        if ttft_slo or mtpot_slo:
            out["goodput_rps"] = self.slo_goodput(
                ttft_slo=ttft_slo, mtpot_slo=mtpot_slo)
        if self.pool_stats:
            out.update({f"pool_{k}": v for k, v in self.pool_stats.items()})
        if any(r.spec_steps for r in self.requests):
            out.update({f"spec_{k}" if not k.startswith("spec_") else k: v
                        for k, v in self.spec_summary().items()})
        if self.tenant_specs:
            out["n_rejected"] = sum(1 for r in self.requests if r.rejected)
            out["fairness_jain"] = self.fairness_index()
            out["fairness_jain_weighted"] = self.fairness_index(
                weighted=True)
        return out
