"""Speculative decoding for the DES core (Leviathan et al. 2023; vLLM).

A draft model proposes ``lookahead`` (K) tokens per step; the target
model verifies them in one batched forward over K+1 query positions and
keeps the accepted prefix plus one bonus/correction token, so a decode
step emits between 1 and K+1 tokens.  The simulator models this as:

  * **draft cost** — K sequential decode iterations of a second,
    ``HardwareSpec``-costed roofline model built from the draft
    architecture (same chip as the worker, smaller weights),
  * **verify cost** — the K+1 draft tokens enter the target iteration's
    ``BatchMix`` as a prefill-like chunk (causal attention over the
    live context), so verify tokens bill the same operator-granular
    roofline as everything else and count against the local scheduler's
    ``max_batched_tokens`` budget,
  * **accept/rollback** — the number of accepted tokens is sampled from
    an ``AcceptanceModel``; KV blocks of rejected draft tokens are
    released via ``BlockManager.rollback_tokens`` in the same iteration
    (no leaked blocks, property-tested in tests/test_spec_decode.py).

This reproduces the known batch-occupancy crossover: at batch 1 decode
is weight-bandwidth-bound, verifying K+1 tokens costs about the same as
one, and speculation multiplies tokens/step; at high occupancy verify
work is compute-bound and the rejected fraction plus draft overhead
makes speculation net-negative (see benchmarks/spec_decode.py).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, Tuple, Union

#: acceptance-probability model kinds
CONSTANT = "constant"      # same probability at every draft position
GEOMETRIC = "geometric"    # decaying: p_i = rate * decay**i
TRACE = "trace"            # per-position probabilities fitted offline
ACCEPTANCE_KINDS = (CONSTANT, GEOMETRIC, TRACE)


@dataclass(frozen=True)
class AcceptanceModel:
    """Per-position probability that the target accepts draft token i.

    ``constant`` uses ``rate`` everywhere; ``geometric`` decays it by
    ``decay`` per position (later draft tokens condition on earlier
    unverified ones, so real acceptance falls with depth); ``trace``
    takes explicit ``per_position`` probabilities fitted from a measured
    acceptance trace (positions past the tuple reuse its last entry).
    """

    kind: str = CONSTANT
    rate: float = 0.8
    decay: float = 0.9
    per_position: Tuple[float, ...] = ()

    def __post_init__(self):
        if self.kind not in ACCEPTANCE_KINDS:
            raise ValueError(
                f"acceptance kind {self.kind!r} not in {ACCEPTANCE_KINDS}")
        if self.kind == TRACE and not self.per_position:
            raise ValueError("trace acceptance model needs per_position")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate {self.rate} outside [0, 1]")

    def prob(self, position: int) -> float:
        """Acceptance probability at draft position ``position`` (0-based)."""
        if self.kind == CONSTANT:
            return self.rate
        if self.kind == GEOMETRIC:
            return self.rate * self.decay ** position
        idx = min(position, len(self.per_position) - 1)
        return self.per_position[idx]

    def sample_accepted(self, rng: random.Random, k: int) -> int:
        """Accepted draft tokens in one verify step: the draft prefix up
        to (excluding) the first rejection, capped at ``k``."""
        for i in range(k):
            if rng.random() >= self.prob(i):
                return i
        return k

    def expected_accepted(self, k: int) -> float:
        """E[accepted] for a K-token draft (closed form over prefixes)."""
        exp, live = 0.0, 1.0
        for i in range(k):
            live *= self.prob(i)
            exp += live
        return exp


@dataclass(frozen=True)
class SpecDecodeSpec:
    """Speculative-decoding configuration attached to ``SimSpec``.

    ``draft_arch`` names the proposer (any registry config or an
    ``ArchConfig``); it is costed on the *same* ``HardwareSpec`` as the
    worker it runs on, with optional ``draft_hw_overrides`` (e.g. a
    dedicated draft accelerator's FLOPs).  ``lookahead`` is K, the draft
    tokens proposed per step.  The acceptance model decides how many
    survive verification; ``seed`` decorrelates acceptance sampling
    while keeping the simulation a pure function of its spec.
    """

    draft_arch: Union[str, object] = "qwen2-0.5b"
    lookahead: int = 4
    acceptance: AcceptanceModel = field(default_factory=AcceptanceModel)
    seed: int = 0
    draft_hw_overrides: Dict[str, float] = field(default_factory=dict)

    def __post_init__(self):
        if self.lookahead < 1:
            raise ValueError(f"lookahead must be >= 1, got {self.lookahead}")

    @property
    def verify_tokens(self) -> int:
        """Query positions per verify step (K drafts + 1 bonus)."""
        return self.lookahead + 1

    def rng_for_worker(self, wid: int) -> random.Random:
        """Deterministic per-worker acceptance RNG (event order inside a
        worker is deterministic, so this keeps runs reproducible)."""
        return random.Random((self.seed + 1) * 0x9E3779B1 + wid)
