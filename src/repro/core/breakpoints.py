"""Operator/iteration-level breakpoints (paper §III-A).

A ``Hooks`` registry maps breakpoint names to user callables.  Workers
invoke them at the documented points; the disaggregation behavior ships
as a two-hook definition (``disagg_hooks``), mirroring the paper's claim
that PD-separation is "two lines of code" on top of the breakpoint API.

Hook points (args):
  before_sched(worker)                 — before each scheduling decision
  on_admit(worker, req)                — request admitted to the batch
  after_prefill(worker, req)           — prompt KV complete (before token)
  on_first_token(worker, req)          — first output token emitted
  after_token(worker, req)             — every generated token
  after_iteration(worker, plan, t)     — iteration retired (t = duration)
  on_finish(worker, req)               — request completed
"""
from __future__ import annotations

from typing import Callable, Dict, List

#: the seven breakpoints; each is fired by the worker loop (see the
#: module docstring for arguments) and audited ≥1-fire by
#: tests/test_observability.py.  scripts/check_docs.py asserts each
#: name is documented in docs/OBSERVABILITY.md
HOOK_POINTS = ("before_sched", "on_admit", "after_prefill",
               "on_first_token", "after_token", "after_iteration",
               "on_finish")


class Hooks:
    """Breakpoint registry with an O(1) empty fast path: ``fire`` on a
    point with no callbacks is a plain dict miss — no list is allocated
    or inserted (the previous defaultdict grew one empty list per
    distinct miss), so the worker's per-token hot loop pays nothing
    when observability is off."""

    __slots__ = ("_hooks",)

    def __init__(self):
        self._hooks: Dict[str, List[Callable]] = {}

    def on(self, point: str, fn: Callable) -> "Hooks":
        if point not in HOOK_POINTS:
            raise KeyError(f"unknown breakpoint {point!r}; "
                           f"have {HOOK_POINTS}")
        self._hooks.setdefault(point, []).append(fn)
        return self

    def fire(self, point: str, *args) -> None:
        fns = self._hooks.get(point)
        if fns is None:
            return
        for fn in fns:
            fn(*args)


def disagg_hooks() -> Hooks:
    """PD disaggregation in two hooks: after the first token on a
    prefill-only worker, hand the request back to the global scheduler
    (which sends it to a decode worker, moving the KV over the link)."""
    hooks = Hooks()

    def submit_back(worker, req):
        if worker.run_prefill and not worker.run_decode and not req.finished:
            worker.cluster.migrate(req, worker)

    hooks.on("on_first_token", submit_back)
    return hooks
