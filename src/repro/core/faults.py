"""Failure injection and availability simulation (docs/RELIABILITY.md).

TokenSim's exploration claim extends to *unhealthy* clusters: this
module turns the original one-shot scheduled ``FaultSpec`` into a
family of fault processes so availability questions ("how much
redundancy buys how many nines at what $/token" — see
benchmarks/chaos_sweep.py) become simulable.

Three injection styles, freely mixable:

* **scheduled** — a list of ``FaultSpec(time, worker, kind)`` entries,
  exactly the pre-existing surface (plus an optional ``duration`` that
  auto-restores the worker),
* **stochastic** — ``FaultProcess`` draws exponential uptime (MTBF) and
  repair (MTTR) times from a private deterministic RNG, so fault
  timelines are reproducible and *independent of simulation content*
  (the property the replica-monotonicity CI gate relies on),
* **trace-driven** — ``load_fault_trace`` reads a JSONL failure log
  into a scheduled list.

Recovery is costly when a ``ChaosSpec`` is active: a revived worker
first pays the model-reload latency (``HardwareSpec.reload_time`` or
the spec override) and then runs its first ``warmup_iters`` iterations
at ``warmup_factor``x cost (cold caches / recompiled kernels).  The
legacy path — ``SimSpec.faults`` with ``chaos=None`` — keeps the
historical free-and-instant recovery, byte-identical.

Degrade faults reuse the straggler semantics of
``repro.distributed.fault.StragglerDetector``: a degraded worker runs
at ``factor``x iteration time, which is exactly the signal the
detector flags (``seconds > factor * median``) and the ``least_loaded``
dispatch policy drains around.
"""
from __future__ import annotations

import json
import random
from dataclasses import dataclass, replace
from typing import List, Optional, Sequence

from repro.distributed.fault import StragglerDetector

#: every fault kind the injector understands — scheduled ``FaultSpec``
#: entries use the first five, ``FaultProcess`` uses ``fail`` /
#: ``degrade`` / ``oom_crash_loop``; scripts/check_docs.py asserts each
#: is documented in docs/RELIABILITY.md
FAULT_KINDS = ("fail", "recover", "slowdown", "degrade", "drain",
               "oom_crash_loop")

#: scheduled kinds accepted by ``FaultSpec.kind``
SCHEDULED_KINDS = ("fail", "recover", "slowdown", "degrade", "drain")

#: stochastic kinds accepted by ``FaultProcess.kind``
PROCESS_KINDS = ("fail", "degrade", "oom_crash_loop")

#: default degrade slowdown: the multiplicative threshold
#: ``StragglerDetector`` fires at, so an injected straggler is exactly
#: what the mitigation layer is tuned to catch
DEFAULT_DEGRADE_FACTOR = StragglerDetector.factor


@dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.  ``kind`` is one of ``SCHEDULED_KINDS``:
    ``fail`` kills the worker (device KV lost, queue re-dispatched),
    ``recover`` revives it (paying the reload/warm-up cost when a
    ``ChaosSpec`` is active; free and instant otherwise — the legacy
    contract), ``slowdown``/``degrade`` multiply iteration time by
    ``factor``, and ``drain`` stops new dispatches while running work
    completes.  A positive ``duration`` auto-restores the worker that
    many seconds later without needing an explicit ``recover`` entry."""
    time: float
    worker: int
    kind: str                     # see SCHEDULED_KINDS
    factor: float = 1.0
    duration: float = 0.0         # 0 = until an explicit recover


@dataclass(frozen=True)
class FaultProcess:
    """A stochastic fault stream for one worker.

    Uptime and repair times are exponential draws (classic
    MTBF / MTTR renewal model) from ``random.Random`` seeded by
    ``(seed, worker, kind)`` only — never by simulation state — so the
    same process produces the same fault timeline regardless of
    workload, replica count, or scheduler (reproducibility and the
    monotone-replicas gate both depend on this).

    ``oom_crash_loop`` models the pathology where a worker comes back
    only to OOM again: each triggering draws ``crash_loops``
    consecutive fail/repair cycles separated by ``loop_uptime`` seconds
    of apparent health before the loop clears."""
    worker: int
    kind: str = "fail"            # see PROCESS_KINDS
    mtbf: float = 300.0           # mean seconds between failures
    mttr: float = 10.0            # mean seconds to repair (pre-reload)
    seed: int = 0
    factor: float = DEFAULT_DEGRADE_FACTOR   # degrade slowdown
    start: float = 0.0            # injection holdoff from t=0
    max_events: int = 0           # 0 = unbounded
    crash_loops: int = 3          # fail/repair cycles per oom trigger
    loop_uptime: float = 1.0      # healthy gap inside a crash loop
    #: target a model instead of a worker id (docs/HETEROGENEITY.md):
    #: with ``worker=-1`` the injector expands this process into one
    #: per worker hosting ``model`` (each with its own timeline, since
    #: the RNG is seeded per worker); with ``worker >= 0`` it validates
    #: that the worker actually hosts the model
    model: Optional[str] = None


@dataclass(frozen=True)
class ChaosSpec:
    """Chaos configuration for a simulation (``SimSpec.chaos``).

    Setting it (even empty) opts the run into the *costly recovery*
    model: revived workers pay ``reload_time`` (``None`` = the worker's
    ``HardwareSpec.reload_time``) and run ``warmup_iters`` iterations
    at ``warmup_factor``x.  ``host_kv_survives`` makes worker failure
    KV-aware: device KV is always lost, but victims whose KV sits in
    the host-DRAM swap tier (``preemption_mode="swap"``) keep it — the
    re-dispatch adopts the host copy into the new worker's tier and the
    request resumes from swap instead of re-prefilling.

    ``ChaosSpec()`` with no processes and no scheduled faults changes
    nothing: the zero-fault run is byte-identical to ``chaos=None``
    (a chaos_sweep --smoke CI gate)."""
    processes: Sequence[FaultProcess] = ()
    reload_time: Optional[float] = None
    warmup_iters: int = 2
    warmup_factor: float = 2.0
    host_kv_survives: bool = True


@dataclass(frozen=True)
class FaultEvent:
    """One injected-fault record in ``Results.fault_events`` — the
    availability accounting in ``Results.availability_summary`` is
    derived entirely from these."""
    time: float
    worker: int
    kind: str                     # "fail" | "recover" | "slowdown" | "drain"
    factor: float = 1.0


def load_fault_trace(path: str) -> List[FaultSpec]:
    """Read a JSONL failure trace into a scheduled fault list.  Each
    line is an object with ``time``, ``worker``, ``kind`` and optional
    ``factor`` / ``duration`` — the format ``chaos_sweep`` can replay
    real incident logs through."""
    out: List[FaultSpec] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            out.append(FaultSpec(
                time=float(d["time"]), worker=int(d["worker"]),
                kind=str(d["kind"]), factor=float(d.get("factor", 1.0)),
                duration=float(d.get("duration", 0.0))))
    return out


class FaultInjector:
    """DES process(es) applying scheduled and stochastic faults to a
    ``Simulation``.

    The scheduled generator reproduces the legacy ``_fault_injector``
    yield-for-yield when ``chaos`` is ``None`` (no extra engine events,
    so pre-chaos runs stay byte-identical).  Stochastic processes use
    *daemon* timeouts for their healthy-uptime waits — an unbounded
    fault stream must not keep the simulation alive — but plain
    timeouts for the repair/reload chain, so a cluster that is entirely
    down still advances time toward the recovery that un-parks the
    waiting requests."""

    def __init__(self, sim, chaos: Optional[ChaosSpec],
                 faults: Sequence[FaultSpec]):
        self.sim = sim
        self.env = sim.env
        self.chaos = chaos
        self.faults = tuple(faults)
        self.events: List[FaultEvent] = []

    # ------------------------------------------------------------------
    def start(self) -> None:
        n = len(self.sim.workers)
        for f in self.faults:
            if not 0 <= f.worker < n:
                raise ValueError(f"FaultSpec.worker {f.worker} out of "
                                 f"range for {n} workers")
        if self.faults:
            self.env.process(self._scheduled(), name="faults")
        if self.chaos is not None:
            for p in self.chaos.processes:
                for q in self._expand(p, n):
                    if not 0 <= q.worker < n:
                        raise ValueError(f"FaultProcess.worker {q.worker} "
                                         f"out of range for {n} workers")
                    if q.kind not in PROCESS_KINDS:
                        raise ValueError(f"unknown FaultProcess.kind "
                                         f"{q.kind!r}; have {PROCESS_KINDS}")
                    self.env.process(self._stochastic(q),
                                     name=f"chaos-w{q.worker}-{q.kind}")

    def _expand(self, p: FaultProcess, n: int) -> List[FaultProcess]:
        """Resolve model-targeted processes (docs/HETEROGENEITY.md) into
        per-worker ones; worker-targeted processes pass through."""
        if p.model is None:
            return [p]
        hosts = [w.wid for w in self.sim.workers
                 if getattr(w, "model", None) == p.model]
        if not hosts:
            raise ValueError(f"FaultProcess.model {p.model!r} matches no "
                             f"worker in this fleet")
        if p.worker >= 0:
            if p.worker not in hosts:
                raise ValueError(f"FaultProcess.worker {p.worker} does "
                                 f"not host model {p.model!r}")
            return [p]
        return [replace(p, worker=wid) for wid in hosts]

    # ------------------------------------------------------------------
    def _log(self, wid: int, kind: str, factor: float = 1.0) -> None:
        now = self.env.now
        self.events.append(FaultEvent(now, wid, kind, factor))
        obs = self.sim.obs
        if obs is not None:
            obs.on_fault(wid, kind, now,
                         {"factor": factor} if factor != 1.0 else None)

    def _reload_time(self, w) -> float:
        if self.chaos is None:
            return 0.0            # legacy contract: recovery is free
        if self.chaos.reload_time is not None:
            return self.chaos.reload_time
        return w.hw.reload_time

    # ---- primitive fault actions -------------------------------------
    def _fail(self, w) -> bool:
        if not w.alive:
            return False          # idempotent: already down
        kv = self.chaos.host_kv_survives if self.chaos is not None \
            else False
        orphans = w.fail(kv_survives=kv)
        self._log(w.wid, "fail")
        # cache-aware routing (docs/ROUTING.md): the dead worker's KV is
        # gone, so its prefix-registry claims must die with it — stale
        # entries would route requests at a cold (or still-down) worker.
        # The remote object store deliberately survives: it is off-host.
        reg = getattr(self.sim, "prefix_registry", None)
        if reg is not None:
            reg.invalidate_worker(w.wid)
        self.sim.redispatch(orphans, from_worker=w)
        return True

    def _slowdown(self, w, factor: float) -> None:
        w.slowdown = factor
        self._log(w.wid, "slowdown", factor)

    def _drain(self, w) -> None:
        w.draining = True
        self._log(w.wid, "drain")

    def _undrain(self, w) -> None:
        if getattr(w, "retiring", False):
            return                # retirement drains are not fault drains
        w.draining = False
        if w.alive:
            # a dead worker's drain ending is not a recovery: logging
            # one would spuriously close its open downtime interval
            self._log(w.wid, "recover")
            w._wakeup()

    def _finish_recover(self, w) -> None:
        w.slowdown = 1.0
        w.draining = False
        if self.chaos is not None:
            w.recover(warmup_iters=self.chaos.warmup_iters,
                      warmup_factor=self.chaos.warmup_factor)
        else:
            w.recover()
        self._log(w.wid, "recover")
        self.sim.on_worker_recovered(w)

    def _revive(self, w):
        """Repair completed: pay the model reload, then serve warm-up
        iterations.  Downtime (fail -> recover in the event log) thus
        includes the reload — recovery is not free."""
        rt = self._reload_time(w)
        if rt > 0:
            yield self.env.timeout(rt)
        self._finish_recover(w)

    # ---- scheduled faults --------------------------------------------
    def _scheduled(self):
        env = self.env
        for f in sorted(self.faults, key=lambda f: f.time):
            delay = f.time - env.now
            if delay > 0:
                yield env.timeout(delay)
            w = self.sim.workers[f.worker]
            if f.kind in ("slowdown", "degrade"):
                self._slowdown(w, f.factor)
                if f.duration > 0:
                    env.process(self._after(f.duration, self._slowdown,
                                            w, 1.0))
            elif f.kind == "drain":
                self._drain(w)
                if f.duration > 0:
                    env.process(self._after(f.duration, self._undrain, w))
            elif f.kind == "fail":
                if self._fail(w) and f.duration > 0:
                    env.process(self._after(f.duration,
                                            self._start_revive, w))
            elif f.kind == "recover":
                if self._reload_time(w) > 0:
                    env.process(self._revive(w))
                else:
                    self._finish_recover(w)
            else:
                raise ValueError(f.kind)

    def _after(self, delay: float, fn, *args):
        yield self.env.timeout(delay)
        fn(*args)

    def _start_revive(self, w) -> None:
        self.env.process(self._revive(w))

    # ---- stochastic processes ----------------------------------------
    def _stochastic(self, p: FaultProcess):
        env = self.env
        w = self.sim.workers[p.worker]
        rng = random.Random(f"chaos:{p.seed}:{p.worker}:{p.kind}")
        if p.start > 0:
            yield env.timeout(p.start, daemon=True)
        n = 0
        while p.max_events <= 0 or n < p.max_events:
            yield env.timeout(rng.expovariate(1.0 / p.mtbf), daemon=True)
            n += 1
            if p.kind == "degrade":
                self._slowdown(w, p.factor)
                yield env.timeout(rng.expovariate(1.0 / p.mttr))
                self._slowdown(w, 1.0)
            elif p.kind == "fail":
                if not self._fail(w):
                    continue      # raced another process: skip the cycle
                yield env.timeout(rng.expovariate(1.0 / p.mttr))
                yield from self._revive(w)
            else:                 # oom_crash_loop
                loops = max(1, p.crash_loops)
                for i in range(loops):
                    if self._fail(w):
                        yield env.timeout(rng.expovariate(1.0 / p.mttr))
                        yield from self._revive(w)
                    if i + 1 < loops:
                        yield env.timeout(p.loop_uptime, daemon=True)
