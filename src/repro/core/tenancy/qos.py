"""Tenant-aware queue disciplines for the worker's waiting queue.

Citations: start-time fair queuing (Goyal et al. 1996) for WFQ tags;
priority aging is the classic starvation guard from OS schedulers.

The local schedulers consult a ``QueueDiscipline`` to pick which waiting
request to admit next and which running request to evict first under
memory pressure.  The default (None) keeps the seed's FIFO / newest-
victim behaviour; the tenant-aware global schedulers in
``repro.core.sched.global_sched`` hand every worker a shared discipline
so ordering is consistent cluster-wide.
"""
from __future__ import annotations

from typing import Sequence

from repro.core.request import Request


class QueueDiscipline:
    """FIFO baseline; subclasses reorder by QoS tags."""

    def select(self, waiting: Sequence[Request], now: float) -> Request:
        """The next waiting request to consider for admission."""
        return min(waiting, key=self.admit_key(now))

    def admit_key(self, now: float):
        return lambda r: (r.arrival_time, r.id)

    def victim_key(self, now: float):
        """Sort ascending by this key; evict from the END of the list
        (default: newest arrival — the seed's recompute-preemption)."""
        return lambda r: (r.arrival_time, r.id)

    def on_service_start(self, req: Request, now: float) -> None:
        """Hook fired when a request first enters a batch."""


class WFQDiscipline(QueueDiscipline):
    """Order by the virtual finish time stamped by the WFQ global
    scheduler; evict the least-entitled (largest tag) request first."""

    def __init__(self, sched) -> None:
        self.sched = sched           # WeightedFairQueuing record book

    def admit_key(self, now: float):
        return lambda r: (r.vft, r.arrival_time, r.id)

    def victim_key(self, now: float):
        # ascending => smallest tag first; pop() evicts the largest vft
        return lambda r: (-r.priority, r.vft, r.id)

    def on_service_start(self, req: Request, now: float) -> None:
        self.sched.on_service_start(req)


class PriorityAgingDiscipline(QueueDiscipline):
    """Strict priority with linear aging: effective priority grows with
    queue wait so low tiers cannot starve.  ``aging_rate`` is priority
    points gained per second of waiting."""

    def __init__(self, aging_rate: float = 0.0) -> None:
        self.aging_rate = aging_rate

    def admit_key(self, now: float):
        def key(r: Request):
            eff = r.priority + self.aging_rate * max(
                0.0, now - r.arrival_time)
            return (-eff, r.arrival_time, r.id)
        return key

    def victim_key(self, now: float):
        # highest tier first => pop() evicts the lowest tier, newest
        return lambda r: (-r.priority, r.arrival_time, r.id)
