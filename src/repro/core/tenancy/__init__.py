"""Multi-tenant QoS: tenant specs, admission control, fair scheduling.

Citations: beyond-paper subsystem; see spec.py, admission.py and qos.py
for the per-technique references (Limitador, SFQ, priority aging).

The subsystem threads tenant identity through the whole stack:

    TenantSpec (tier + workload)                 [spec.py]
      -> merged arrival stream                   [core.workload]
      -> AdmissionController (token bucket)      [admission.py]
      -> tenant-aware GlobalScheduler            [core.sched.global_sched]
      -> QueueDiscipline on each worker          [qos.py]
      -> per-tenant Results breakdowns           [core.metrics]
"""
from repro.core.tenancy.admission import (AdmissionController,  # noqa: F401
                                          TokenBucket)
from repro.core.tenancy.qos import (PriorityAgingDiscipline,  # noqa: F401
                                    QueueDiscipline, WFQDiscipline)
from repro.core.tenancy.spec import (ADMISSION_POLICIES, ENTERPRISE,  # noqa: F401
                                     FREE, PRO, QUEUE, REJECT, SHED,
                                     TIERS, TenantSpec, TenantTier)
