"""Admission control: simulated API-gateway rate limiting.

Citations: token-bucket limiting as in Limitador/Kuadrant and cloud LLM
gateways; reject/queue/shed mirror RFC 6585 (429) semantics.

One DES process per tenant sits between the dispatcher and the global
scheduler (the Limitador/Kuadrant position in a production stack).  Each
tenant has a token bucket over ``prompt+output`` tokens and an optional
in-flight cap; over-limit traffic is rejected, queued, or shed according
to the tier's ``admission_policy``.

Everything is deterministic: buckets are pure functions of (arrival
times, costs), tenant processes are created in sorted tenant order, and
ties resolve through the engine's (time, priority, seq) ordering.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, Optional, Sequence

from repro.core.engine import Environment, Event
from repro.core.request import Request, State
from repro.core.tenancy.spec import REJECT, SHED, TenantSpec


@dataclass
class TokenBucket:
    """Classic token bucket; refilled lazily at observation times."""

    rate: float                      # tokens per second; 0 = unlimited
    burst: float                     # capacity
    tokens: float = field(default=0.0)
    t_last: float = 0.0

    def __post_init__(self):
        self.tokens = self.burst     # start full

    def _refill(self, now: float) -> None:
        if now > self.t_last:
            self.tokens = min(self.burst,
                              self.tokens + (now - self.t_last) * self.rate)
            self.t_last = now

    def available(self, now: float) -> float:
        self._refill(now)
        return self.tokens

    def wait_time(self, now: float, cost: float) -> float:
        """Seconds until ``cost`` tokens can be consumed.  Requests larger
        than the burst wait for a full bucket and run the balance into
        debt (classic borrowing), so they are delayed, never deadlocked."""
        if self.rate <= 0:
            return 0.0
        self._refill(now)
        need = min(cost, self.burst)
        if self.tokens >= need:
            return 0.0
        return (need - self.tokens) / self.rate

    def consume(self, now: float, cost: float) -> None:
        self._refill(now)
        self.tokens -= cost          # may go negative (burst debt)


class AdmissionController:
    """Per-tenant gateway queues feeding the cluster's global scheduler."""

    def __init__(self, env: Environment, tenants: Sequence[TenantSpec],
                 cluster) -> None:
        self.env = env
        self.cluster = cluster
        self.tenants: Dict[str, TenantSpec] = {
            t.tenant_id: t for t in tenants}
        self.buckets: Dict[str, TokenBucket] = {}
        self.queues: Dict[str, Deque[Request]] = {}
        self.inflight: Dict[str, int] = {}
        self.rejected: Dict[str, int] = {}
        self._wake: Dict[str, Optional[Event]] = {}
        for tid in sorted(self.tenants):
            tier = self.tenants[tid].tier
            self.buckets[tid] = TokenBucket(tier.rate_tokens_per_s,
                                            tier.burst_tokens)
            self.queues[tid] = deque()
            self.inflight[tid] = 0
            self.rejected[tid] = 0
            self._wake[tid] = None
            env.process(self._gateway(tid), name=f"admission:{tid}")

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Dispatcher entry point; called at the request's arrival time."""
        tid = req.tenant_id
        spec = self.tenants.get(tid)
        if spec is None:             # unknown tenant: pass through
            self._release(req)
            return
        tier = spec.tier
        cost = spec.request_cost(req)
        if tier.admission_policy == REJECT:
            # reject iff the bucket cannot cover the queued backlog plus
            # this request right now (simultaneous arrivals within the
            # burst are all admitted), or the inflight cap is exhausted
            over_rate = self._projected_wait(tid, cost) > 0.0
            over_cap = bool(tier.max_inflight and self.inflight[tid]
                            + len(self.queues[tid]) >= tier.max_inflight)
            if over_rate or over_cap:
                self._reject(req)
                return
        elif tier.admission_policy == SHED:
            if self._projected_wait(tid, cost) > tier.shed_timeout:
                self._reject(req)
                return
        self.queues[tid].append(req)
        self._wakeup(tid)

    def on_finish(self, req: Request) -> None:
        tid = req.tenant_id
        if tid in self.inflight:
            self.inflight[tid] = max(0, self.inflight[tid] - 1)
            self._wakeup(tid)

    def stats(self) -> Dict[str, Dict[str, float]]:
        return {tid: {"rejected": self.rejected[tid],
                      "queued": len(self.queues[tid]),
                      "inflight": self.inflight[tid]}
                for tid in sorted(self.tenants)}

    # ------------------------------------------------------------------
    def _projected_wait(self, tid: str, cost: float) -> float:
        """Bucket-refill time for the backlog ahead of (and including) a
        candidate request — the shed decision signal."""
        bucket = self.buckets[tid]
        if bucket.rate <= 0:
            return 0.0
        spec = self.tenants[tid]
        backlog = sum(spec.request_cost(r) for r in self.queues[tid])
        need = backlog + min(cost, bucket.burst)
        avail = bucket.available(self.env.now)
        if avail >= need:
            return 0.0
        return (need - avail) / bucket.rate

    def _reject(self, req: Request) -> None:
        req.state = State.REJECTED
        self.rejected[req.tenant_id] += 1
        on_rejected = getattr(self.cluster, "on_request_rejected", None)
        if on_rejected is not None:
            on_rejected(req)

    def _release(self, req: Request) -> None:
        req.t_admitted = self.env.now
        obs = getattr(self.cluster, "obs", None)
        if obs is not None:
            # gateway span ends here; the request enters a worker queue
            obs.on_release(req, self.env.now)
        place = getattr(self.cluster, "_place", None)
        if place is not None:
            # the cluster's placement path: same assign/observe/submit
            # sequence, plus outage parking — a request released while
            # every eligible worker is down waits at the dispatcher
            # instead of crashing the scheduler
            place(req)
            return
        wid = self.cluster.global_sched.assign(req, self.cluster.workers)
        if obs is not None:
            self.cluster.global_sched.observe_assign(req, wid)
        self.cluster.workers[wid].submit(req)

    def _wakeup(self, tid: str) -> None:
        ev = self._wake[tid]
        if ev is not None and not ev.triggered:
            ev.succeed()

    def _gateway(self, tid: str):
        env = self.env
        spec = self.tenants[tid]
        tier = spec.tier
        bucket = self.buckets[tid]
        q = self.queues[tid]
        while True:
            if not q or (tier.max_inflight
                         and self.inflight[tid] >= tier.max_inflight):
                self._wake[tid] = env.event()
                yield self._wake[tid]
                continue
            req = q[0]
            cost = spec.request_cost(req)
            wait = bucket.wait_time(env.now, cost)
            if tier.admission_policy == SHED and env.now + wait \
                    - req.arrival_time > tier.shed_timeout:
                # would be delivered past its deadline (stalled behind
                # the inflight cap and/or bucket debt): shed instead of
                # releasing a stale request
                q.popleft()
                self._reject(req)
                continue
            if wait > 0:
                # safe to consume right after the wait without re-checking:
                # this process is the bucket's only consumer, the head is
                # stable (submit appends), and inflight only drops while
                # we sleep.  Re-checking would spin on float residue.
                yield env.timeout(wait)
            bucket.consume(env.now, cost)
            q.popleft()
            self.inflight[tid] += 1
            self._release(req)
