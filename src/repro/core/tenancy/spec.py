"""Tenant tiers and per-tenant QoS contracts.

Citations: token-bucket gateway limiting follows Limitador/Kuadrant;
tiered SLO contracts follow production LLM API pricing tiers.

A ``TenantTier`` is the QoS contract an operator sells: scheduling
weight/priority, a token-bucket rate limit (tokens/s + burst, the
Limitador/Kuadrant role in production gateways), per-tenant latency SLOs
and an in-flight cap.  A ``TenantSpec`` binds one tenant to a tier and a
traffic mix (its own ``WorkloadSpec``); the simulator merges all tenant
streams into one deterministic arrival sequence.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.workload import WorkloadSpec

#: admission policies when a tenant exceeds its rate limit / inflight cap
REJECT = "reject"      # 429 immediately: request never enters the system
QUEUE = "queue"        # hold at the gateway until the bucket refills
SHED = "shed"          # queue, but reject if projected wait > shed_timeout
ADMISSION_POLICIES = (REJECT, QUEUE, SHED)


@dataclass(frozen=True)
class TenantTier:
    """QoS contract parameters for one tier of service."""

    name: str = "standard"
    #: weighted-fair-queuing share (relative; used by global_policy="wfq")
    weight: float = 1.0
    #: strict priority, larger = more important (global_policy="priority")
    priority: int = 0
    #: token-bucket rate limit in tokens/s over prompt+output tokens;
    #: 0 disables rate limiting for the tier
    rate_tokens_per_s: float = 0.0
    #: bucket capacity in tokens (max burst admitted at line rate)
    burst_tokens: float = 0.0
    #: what the gateway does with over-limit traffic
    admission_policy: str = QUEUE
    #: max projected gateway wait before a SHED tier drops a request
    shed_timeout: float = 10.0
    #: concurrent requests allowed past the gateway; 0 = unlimited
    max_inflight: int = 0
    #: per-tenant SLOs (seconds); 0 disables the bound
    ttft_slo: float = 0.0
    tpot_slo: float = 0.0

    def __post_init__(self):
        if self.admission_policy not in ADMISSION_POLICIES:
            raise ValueError(
                f"admission_policy {self.admission_policy!r} not in "
                f"{ADMISSION_POLICIES}")
        if self.rate_tokens_per_s > 0 and self.burst_tokens <= 0:
            # a zero-capacity bucket would deadlock QUEUE tenants; default
            # the burst to one second of line rate
            object.__setattr__(self, "burst_tokens",
                               float(self.rate_tokens_per_s))


#: common API-gateway shapes, usable directly or via ``TIERS[name]``
FREE = TenantTier(name="free", weight=1.0, priority=0,
                  rate_tokens_per_s=2_000.0, burst_tokens=8_000.0,
                  admission_policy=SHED, shed_timeout=5.0,
                  max_inflight=8, ttft_slo=10.0, tpot_slo=1.0)
PRO = TenantTier(name="pro", weight=4.0, priority=5,
                 rate_tokens_per_s=20_000.0, burst_tokens=60_000.0,
                 admission_policy=QUEUE,
                 max_inflight=64, ttft_slo=3.0, tpot_slo=0.3)
ENTERPRISE = TenantTier(name="enterprise", weight=16.0, priority=10,
                        admission_policy=QUEUE,
                        ttft_slo=1.0, tpot_slo=0.2)
TIERS = {t.name: t for t in (FREE, PRO, ENTERPRISE)}


@dataclass(frozen=True)
class TenantSpec:
    """One tenant: an id, its QoS tier, and its traffic."""

    tenant_id: str
    tier: TenantTier = field(default_factory=TenantTier)
    workload: WorkloadSpec = field(default_factory=WorkloadSpec)

    def request_cost(self, req) -> float:
        """Tokens a request charges against the bucket (prompt+output,
        token-based limiting as in production LLM gateways)."""
        return float(req.prompt_len + req.output_len)
