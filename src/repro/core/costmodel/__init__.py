from repro.core.costmodel.hardware import HardwareSpec, HARDWARE  # noqa: F401
from repro.core.costmodel.operators import BatchMix, OperatorGraph  # noqa: F401
from repro.core.costmodel.backends import (  # noqa: F401
    CostBackend, RooflineBackend, TabularBackend, XLACalibratedBackend,
    make_backend)
