from repro.core.costmodel.hardware import (  # noqa: F401
    CLUSTERS, ClusterSpec, HARDWARE, HardwareSpec, ParallelSpec)
from repro.core.costmodel.operators import BatchMix, OperatorGraph  # noqa: F401
from repro.core.costmodel.backends import (  # noqa: F401
    CostBackend, PipelineBackend, RooflineBackend, TabularBackend,
    XLACalibratedBackend, make_backend)
