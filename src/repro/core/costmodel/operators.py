"""Transformer-oriented operator graph, generated from ``ArchConfig``.

This is the piece the paper credits its accuracy to: instead of a single
"model FLOPs" number, every iteration is costed per operator with its own
FLOPs *and* bytes, so MLP tiles are compute-bound while decode attention
is bandwidth-bound within the same iteration (no coarse-grained MLP
approximation).

The same ``ArchConfig`` that builds the real JAX model builds this graph,
so the simulator cannot drift from the runtime.

``BatchMix`` carries the iteration's aggregate workload:
  * ``new_tokens``      — tokens computed this iteration (prefill chunks +
                          one per decode request),
  * ``attn_units``      — Σ (q-token × kv-token) pairs actually attended,
  * ``kv_read_tokens``  — Σ context tokens whose K/V is read,
  * ``n_seqs``          — sequences in the batch,
  * ``enc_tokens``      — encoder tokens (enc-dec archs only).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import List, Tuple

from repro.configs.base import (ArchConfig, AUDIO, DENSE, ENCDEC, HYBRID,
                                MOE, SSM, VLM)


def _bucket8(n: int) -> int:
    """Power-of-two padding bucket (>=8) — mirrors the real engine's
    prefill shape bucketing so calibrated backends see the same shapes."""
    return max(8, 1 << (int(n) - 1).bit_length()) if n > 0 else 0


@dataclass(frozen=True)
class BatchMix:
    new_tokens: int = 0
    attn_units: float = 0.0
    kv_read_tokens: float = 0.0
    n_seqs: int = 0
    enc_tokens: int = 0
    padded_tokens: float = 0.0     # Σ bucket(prefill chunk) + decodes

    @staticmethod
    def from_batch(prefill: List[Tuple[int, int]],
                   decode_ctx: List[int],
                   enc_tokens: int = 0) -> "BatchMix":
        """prefill: [(chunk_len, ctx_before)], decode_ctx: [context_len]."""
        new_tokens = sum(c for c, _ in prefill) + len(decode_ctx)
        attn_units = sum(c * (b + (c + 1) / 2.0) for c, b in prefill) \
            + float(sum(decode_ctx))
        kv_read = sum(b + c for c, b in prefill) + float(sum(decode_ctx))
        padded = float(sum(_bucket8(c) for c, _ in prefill)) \
            + len(decode_ctx)
        return BatchMix(new_tokens=new_tokens, attn_units=attn_units,
                        kv_read_tokens=kv_read,
                        n_seqs=len(prefill) + len(decode_ctx),
                        enc_tokens=enc_tokens, padded_tokens=padded)


@dataclass(frozen=True)
class Operator:
    """One op's cost in coefficient form.

    flops(mix) / bytes(mix) are affine in the mix aggregates:
      flops = f_tok*new_tokens + f_attn*attn_units + f_seq*n_seqs + f_enc*enc_tokens
      bytes = b_fixed + b_tok*new_tokens + b_kv*kv_read_tokens + b_seq*n_seqs
              + b_enc*enc_tokens
    b_fixed is the weight traffic (paid once per iteration, batch-amortized).
    """
    name: str
    f_tok: float = 0.0
    f_attn: float = 0.0
    f_seq: float = 0.0
    f_enc: float = 0.0
    b_fixed: float = 0.0
    b_tok: float = 0.0
    b_kv: float = 0.0
    b_seq: float = 0.0
    b_enc: float = 0.0
    count: int = 1          # layers this op repeats over

    def flops(self, m: BatchMix) -> float:
        return self.count * (self.f_tok * m.new_tokens
                             + self.f_attn * m.attn_units
                             + self.f_seq * m.n_seqs
                             + self.f_enc * m.enc_tokens)

    def bytes(self, m: BatchMix) -> float:
        active = (m.new_tokens + m.enc_tokens) > 0
        return self.count * ((self.b_fixed if active else 0.0)
                             + self.b_tok * m.new_tokens
                             + self.b_kv * m.kv_read_tokens
                             + self.b_seq * m.n_seqs
                             + self.b_enc * m.enc_tokens)


@dataclass
class OperatorGraph:
    cfg: ArchConfig
    tp: int
    dtype_bytes: int
    ops: List[Operator] = field(default_factory=list)
    collective_bytes_per_token: float = 0.0   # TP all-reduce traffic
    #: per-layer collective structure (docs/PARALLELISM.md): number of
    #: all-reduces per token-pass and the full activation bytes each one
    #: reduces; the topology-aware backend prices these per ring step
    #: while ``collective_bytes_per_token`` keeps the legacy flat volume
    allreduce_count: int = 0
    allreduce_bytes_per_token: float = 0.0
    #: activation bytes one token carries across a pipeline-stage
    #: boundary (hidden state, d_model * dtype_bytes)
    act_bytes_per_token: float = 0.0

    # ------------------------------------------------------------------
    @staticmethod
    def from_config(cfg: ArchConfig, tp: int = 1,
                    dtype_bytes: int = 2) -> "OperatorGraph":
        g = OperatorGraph(cfg=cfg, tp=tp, dtype_bytes=dtype_bytes)
        d = cfg.d_model
        dt = dtype_bytes
        L = cfg.num_layers

        def linear(name, d_in, d_out, count, tok_attr="tok"):
            w_bytes = d_in * d_out * dt / tp
            op = Operator(
                name=name, count=count,
                **{f"f_{tok_attr}": 2.0 * d_in * d_out / tp},
                b_fixed=w_bytes,
                **({"b_tok": (d_in + d_out) * dt / tp}
                   if tok_attr == "tok" else
                   {"b_enc": (d_in + d_out) * dt / tp}))
            g.ops.append(op)

        def attention(count, n_q, n_kv, hd, tok_attr="tok", self_sq=True):
            """Score + PV flops per attn unit; KV read bytes."""
            # per (q,kv) pair: 2 flops × hd × n_q (QK^T) + same for PV
            f = 4.0 * n_q * hd / tp
            kv_b = 2.0 * n_kv * hd * dt / tp       # K+V read per ctx token
            if self_sq:
                g.ops.append(Operator(name=f"attn_core_x{count}",
                                      count=count, f_attn=f, b_kv=kv_b))
            else:  # encoder self-attention: units = enc_tokens^2 folded
                g.ops.append(Operator(name=f"enc_attn_x{count}", count=count,
                                      f_enc=f * 1.0, b_enc=kv_b))

        hd = cfg.head_dim
        nq, nkv = cfg.n_heads, cfg.n_kv_heads

        if cfg.family in (DENSE, VLM, MOE):
            linear("qkv", d, (nq + 2 * nkv) * hd, L)
            linear("attn_out", nq * hd, d, L)
            attention(L, nq, nkv, hd)
            if cfg.family == MOE:
                m = cfg.moe
                gated = 3 if cfg.act == "silu" else 2
                # router
                linear("router", d, m.num_experts, L)
                # top-k expert FFN: flops scale with top_k; weight bytes
                # stream the touched experts (≈ all of them at batch>=E)
                f_ffn = 2.0 * gated * d * m.d_expert * m.top_k / tp
                w_all = m.num_experts * gated * d * m.d_expert * dt / tp
                g.ops.append(Operator(
                    name="moe_ffn", count=L, f_tok=f_ffn, b_fixed=w_all,
                    b_tok=(gated * m.top_k * (d + m.d_expert)) * dt / tp))
            else:
                gated = 3 if cfg.act == "silu" else 2
                # gate+up fused as one (d -> 2*d_ff) matmul when gated
                linear("mlp_up", d, cfg.d_ff * (2 if gated == 3 else 1), L)
                linear("mlp_down", cfg.d_ff, d, L)
            g.ops.append(Operator(name="norms", count=L, f_tok=8.0 * d,
                                  b_tok=4.0 * d * dt))

        elif cfg.family in (SSM, HYBRID):
            s = cfg.ssm
            d_in = s.d_inner(d)
            nh = s.n_heads(d)
            gn = s.n_groups * s.d_state
            linear("ssm_in_proj", d, 2 * d_in + 2 * gn + nh, L)
            linear("ssm_out_proj", d_in, d, L)
            g.ops.append(Operator(                     # conv + dt + gating
                name="ssm_elementwise", count=L,
                f_tok=2.0 * s.conv_width * (d_in + 2 * gn) + 10.0 * d_in,
                b_tok=4.0 * d_in * dt))
            # SSD core: per token 2*(N*P read+write state) flops ~ 4*H*N*P
            # bytes: fp32 state read+write per seq per iteration (decode)
            state_b = nh * s.d_state * s.head_dim * 4.0
            g.ops.append(Operator(
                name="ssd_core", count=L,
                f_tok=6.0 * nh * s.d_state * s.head_dim / tp,
                b_tok=2.0 * d_in * dt / tp,
                b_seq=2.0 * state_b / tp))
            if cfg.family == HYBRID:
                napp = (cfg.num_layers // cfg.attn_period
                        if cfg.attn_period else 0)
                if napp:
                    linear("shared_qkv", d, (nq + 2 * nkv) * hd, napp)
                    linear("shared_attn_out", nq * hd, d, napp)
                    attention(napp, nq, nkv, hd)
                    gated = 3 if cfg.act == "silu" else 2
                    linear("shared_mlp_up", d,
                           cfg.d_ff * (2 if gated == 3 else 1), napp)
                    linear("shared_mlp_down", cfg.d_ff, d, napp)
            g.ops.append(Operator(name="norms", count=L, f_tok=8.0 * d,
                                  b_tok=4.0 * d * dt))

        elif cfg.family in (ENCDEC, AUDIO):
            Le, Ld = cfg.n_enc_layers, cfg.n_dec_layers
            # encoder (runs on enc_tokens)
            linear("enc_qkv", d, (nq + 2 * nkv) * hd, Le, tok_attr="enc")
            linear("enc_out", nq * hd, d, Le, tok_attr="enc")
            g.ops.append(Operator(                      # enc self-attn
                name="enc_attn", count=Le,
                f_enc=4.0 * nq * hd * cfg.enc_seq_len / tp,
                b_enc=2.0 * nkv * hd * dt / tp))
            linear("enc_mlp_up", d, cfg.d_ff, Le, tok_attr="enc")
            linear("enc_mlp_down", cfg.d_ff, d, Le, tok_attr="enc")
            # decoder
            linear("dec_qkv", d, (nq + 2 * nkv) * hd, Ld)
            linear("dec_out", nq * hd, d, Ld)
            attention(Ld, nq, nkv, hd)
            # cross attention reads the fixed encoder KV
            g.ops.append(Operator(
                name="cross_attn", count=Ld,
                f_tok=4.0 * nq * hd * cfg.enc_seq_len / tp,
                b_tok=0.0,
                b_seq=2.0 * nkv * hd * cfg.enc_seq_len * dt / tp))
            linear("dec_mlp_up", d, cfg.d_ff, Ld)
            linear("dec_mlp_down", cfg.d_ff, d, Ld)
            g.ops.append(Operator(name="norms", count=Le + Ld,
                                  f_tok=8.0 * d, b_tok=4.0 * d * dt))
        else:
            raise ValueError(cfg.family)

        # embedding + lm head (all LM families)
        if cfg.vocab_size:
            g.ops.append(Operator(name="embed", count=1,
                                  b_tok=d * dt))
            linear("lm_head", d, cfg.vocab_size, 1)

        # TP all-reduce traffic: 2 per layer (attn out + mlp out),
        # ring: 2*(tp-1)/tp of the activation bytes each.
        g.act_bytes_per_token = float(d * dt)
        if tp > 1:
            g.collective_bytes_per_token = \
                2 * L * 2 * (tp - 1) / tp * d * dt
            g.allreduce_count = 2 * L
            g.allreduce_bytes_per_token = float(d * dt)
        return g

    # ------------------------------------------------------------------
    def split_stages(self, pp: int) -> List["OperatorGraph"]:
        """Partition the graph into ``pp`` pipeline stages
        (docs/PARALLELISM.md).

        Layer-repeated ops (``count > 1``) spread their repeat counts as
        evenly as integer division allows; once-per-model ops pin to the
        pipeline ends (``embed`` on stage 0, the lm head and any other
        singleton on the last stage).  Per-layer collective metadata
        splits proportionally, so each stage's TP all-reduces match its
        layer share.  Invariant (tested): summing any op count, flops or
        bytes over the stages reproduces the unsplit graph exactly.
        """
        if pp <= 1:
            return [self]
        stages = []
        for s in range(pp):
            g = OperatorGraph(cfg=self.cfg, tp=self.tp,
                              dtype_bytes=self.dtype_bytes)
            g.act_bytes_per_token = self.act_bytes_per_token
            for op in self.ops:
                if op.count > 1:
                    c = op.count * (s + 1) // pp - op.count * s // pp
                    if c:
                        g.ops.append(replace(op, count=c))
                elif op.name == "embed":
                    if s == 0:
                        g.ops.append(op)
                elif s == pp - 1:
                    g.ops.append(op)
            if self.allreduce_count:
                n_ar = self.allreduce_count * (s + 1) // pp \
                    - self.allreduce_count * s // pp
                g.allreduce_count = n_ar
                g.allreduce_bytes_per_token = self.allreduce_bytes_per_token
                g.collective_bytes_per_token = \
                    self.collective_bytes_per_token * n_ar \
                    / self.allreduce_count
            elif self.collective_bytes_per_token:
                # hand-built graph carrying only the flat volume: split
                # it evenly so the collective cost survives stage-wise
                # (mirrors the legacy fallback in collective_time)
                g.collective_bytes_per_token = \
                    self.collective_bytes_per_token / pp
            stages.append(g)
        return stages

    # ------------------------------------------------------------------
    def totals(self, m: BatchMix) -> Tuple[float, float]:
        f = sum(op.flops(m) for op in self.ops)
        b = sum(op.bytes(m) for op in self.ops)
        return f, b


# ---------------------------------------------------------------------------
# Derived sizing helpers (shared with mem managers / comm)
# ---------------------------------------------------------------------------
def kv_bytes_per_token(cfg: ArchConfig, dtype_bytes: int = 2,
                       tp: int = 1) -> float:
    """Bytes of KV cache one context token occupies (per device shard)."""
    if cfg.family == SSM:
        return 0.0                        # constant state, no per-token KV
    if cfg.family == HYBRID:
        napp = cfg.num_layers // cfg.attn_period if cfg.attn_period else 0
        return 2.0 * napp * cfg.n_kv_heads * cfg.head_dim * dtype_bytes / tp
    layers = cfg.n_dec_layers if cfg.family in (ENCDEC, AUDIO) \
        else cfg.num_layers
    return 2.0 * layers * cfg.n_kv_heads * cfg.head_dim * dtype_bytes / tp


def state_bytes_per_seq(cfg: ArchConfig, dtype_bytes: int = 2,
                        tp: int = 1) -> float:
    """Per-request constant state bytes (SSM/hybrid; 0 otherwise)."""
    if cfg.family not in (SSM, HYBRID):
        return 0.0
    s = cfg.ssm
    nh = s.n_heads(cfg.d_model)
    ssd = cfg.num_layers * nh * s.d_state * s.head_dim * 4.0  # fp32
    conv = cfg.num_layers * (s.conv_width - 1) * \
        (s.d_inner(cfg.d_model) + 2 * s.n_groups * s.d_state) * dtype_bytes
    return (ssd + conv) / tp


def param_bytes(cfg: ArchConfig, dtype_bytes: int = 2, tp: int = 1) -> float:
    return cfg.param_count() * dtype_bytes / tp
