"""Compute-cost backends: pluggable "compute simulators" (paper §III).

* ``RooflineBackend``       — GenZ-style operator-granular roofline over
                              the ``OperatorGraph``; the default.
* ``TabularBackend``        — calibrated from measured iterations of the
                              *real* JAX engine (repro.serving): piecewise
                              linear in the mix aggregates.  This is how
                              the validation studies hold the simulator to
                              the <1% bar without A100s.
* ``XLACalibratedBackend``  — roofline with per-op FLOPs/bytes replaced by
                              ``compiled.cost_analysis()`` totals from the
                              multi-pod dry-run (beyond paper: ties the
                              simulator to the compiled HLO).
* ``PipelineBackend``       — wraps per-stage rooflines into an
                              iteration-synchronous pipeline: micro-batch
                              fill/drain bubbles and stage-boundary p2p
                              activation hand-off (docs/PARALLELISM.md).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from repro.configs.base import ArchConfig
from repro.core.comm import (LinkSpec, p2p_time, ring_allreduce_time,
                             stage_boundary_link, tp_group_link)
from repro.core.costmodel.hardware import (ClusterSpec, HardwareSpec,
                                           ParallelSpec)
from repro.core.costmodel.operators import BatchMix, OperatorGraph


class CostBackend:
    """iteration_time(mix) -> seconds on one worker."""

    def iteration_time(self, mix: BatchMix) -> float:
        raise NotImplementedError


@dataclass
class RooflineBackend(CostBackend):
    hw: HardwareSpec
    graph: OperatorGraph
    #: interconnect topology (docs/PARALLELISM.md).  ``None`` keeps the
    #: legacy flat TP term (collective volume / hw.link_bw, latency-free)
    #: byte-identical to the pre-topology cost model; a ``ClusterSpec``
    #: prices each per-layer all-reduce as a ring over the link the TP
    #: group actually occupies, so TP stops being free at high degree
    #: and across node boundaries.
    cluster: Optional[ClusterSpec] = None
    #: pipeline-stage index of this backend under the consecutive
    #: placement model (stage s owns devices [s*tp, (s+1)*tp)) — decides
    #: whether this stage's TP ring straddles a node boundary
    stage: int = 0

    @staticmethod
    def for_model(cfg: ArchConfig, hw: HardwareSpec, tp: int = 1,
                  dtype_bytes: int = 2,
                  cluster: Optional[ClusterSpec] = None
                  ) -> "RooflineBackend":
        return RooflineBackend(
            hw=hw, graph=OperatorGraph.from_config(cfg, tp, dtype_bytes),
            cluster=cluster)

    def iteration_time(self, mix: BatchMix) -> float:
        if mix.new_tokens == 0 and mix.enc_tokens == 0:
            return 0.0
        hw = self.hw
        t = hw.iter_overhead
        fpeak = hw.flops * hw.flops_eff
        bpeak = hw.mem_bw * hw.bw_eff
        for op in self.graph.ops:
            f = op.flops(mix)
            b = op.bytes(mix)
            if f or b:
                t += max(f / fpeak, b / bpeak)
        t += self.collective_time(mix)
        return t

    def collective_time(self, mix: BatchMix) -> float:
        """TP all-reduce cost for one iteration's token batch."""
        g = self.graph
        if not g.collective_bytes_per_token:
            return 0.0
        # legacy flat term: no topology given, or a hand-built graph
        # that only carries the flat volume (allreduce metadata unset) —
        # the latter must not become free just because a cluster is set
        if self.cluster is None or not g.allreduce_count:
            return g.collective_bytes_per_token * mix.new_tokens \
                / self.hw.link_bw
        link = tp_group_link(self.cluster, g.tp, self.stage)
        nbytes = g.allreduce_bytes_per_token * mix.new_tokens
        return g.allreduce_count * ring_allreduce_time(nbytes, g.tp, link)


@dataclass
class TabularBackend(CostBackend):
    """Least-squares affine fit  t ≈ c0 + c1·padded_tokens + c2·attn_units
    + c3·kv_read_tokens + c4·n_seqs  over calibration samples.

    ``padded_tokens`` (bucketed prefill chunks) rather than raw tokens:
    the real engine pads prompts to power-of-two shape buckets, so that
    is the feature its wall-clock actually follows."""

    coef: Tuple[float, float, float, float, float]
    samples: List[Tuple[BatchMix, float]] = field(default_factory=list)

    @staticmethod
    def _features(m: BatchMix):
        padded = m.padded_tokens or m.new_tokens
        return [1.0, padded, m.attn_units, m.kv_read_tokens, m.n_seqs]

    @staticmethod
    def fit(samples: List[Tuple[BatchMix, float]]) -> "TabularBackend":
        import numpy as np
        X = np.array([TabularBackend._features(m) for m, _ in samples])
        y = np.array([t for _, t in samples])
        coef, *_ = np.linalg.lstsq(X, y, rcond=None)
        return TabularBackend(coef=tuple(float(c) for c in coef),
                              samples=list(samples))

    def iteration_time(self, mix: BatchMix) -> float:
        if mix.new_tokens == 0 and mix.enc_tokens == 0:
            return 0.0
        f = self._features(mix)
        t = sum(c * x for c, x in zip(self.coef, f))
        return max(t, 1e-6)


def cost_analysis_dict(cost) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across JAX versions: older
    releases return a one-element list of dicts (one per program), newer
    ones the dict itself.  Every producer/consumer of cost records
    (launch.dryrun, benchmarks.roofline_report, the calibration tests)
    goes through this so the artifact schema stays a flat dict."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


@dataclass
class XLACalibratedBackend(CostBackend):
    """Roofline on dry-run HLO totals.

    ``flops_per_token`` / ``bytes_per_token`` come from
    ``compiled.cost_analysis()`` of the real lowered step divided by the
    step's token count; attention terms are added from the graph (HLO
    numbers are shape-specific, attention scales quadratically)."""

    hw: HardwareSpec
    flops_per_token: float
    bytes_fixed: float
    bytes_per_token: float
    graph: Optional[OperatorGraph] = None

    def iteration_time(self, mix: BatchMix) -> float:
        if mix.new_tokens == 0 and mix.enc_tokens == 0:
            return 0.0
        hw = self.hw
        f = self.flops_per_token * mix.new_tokens
        b = self.bytes_fixed + self.bytes_per_token * mix.new_tokens
        if self.graph is not None:
            for op in self.graph.ops:
                if op.f_attn or op.b_kv:
                    f += op.f_attn * mix.attn_units * op.count
                    b += op.b_kv * mix.kv_read_tokens * op.count
        return hw.iter_overhead + max(f / (hw.flops * hw.flops_eff),
                                      b / (hw.mem_bw * hw.bw_eff))


@dataclass
class PipelineBackend(CostBackend):
    """Iteration-synchronous pipeline parallelism over per-stage
    backends (docs/PARALLELISM.md).

    One iteration's batch splits into ``microbatches`` equal micro-
    batches that flow through the ``pp`` stages; the step period is the
    slowest stage's micro-batch time plus the slowest stage-boundary
    activation hand-off, so

        span   = (m + pp - 1) * step         (fill + steady + drain)
        bubble = (pp - 1) * step             -> bubble/span = the
                                                closed-form fraction
                                                (pp-1)/(m+pp-1)

    Framework/launch overhead (``overhead``) is charged once per
    iteration — stages run as persistent workers, not per-step
    relaunches — and excluded from the bubble-fraction denominator.
    The wrapped stage backends keep their own TP collective terms, so
    TP x PP composes.  ``last_breakdown`` holds the most recent
    iteration's ``(bubble, comm, span)`` for the worker to account into
    its ``IterationPlan``.
    """

    stages: List[CostBackend]
    boundary_links: List[LinkSpec]       # len == pp - 1
    act_bytes_per_token: float           # hidden state across a boundary
    microbatches: int = 2
    overhead: float = 0.0                # once-per-iteration framework cost
    last_breakdown: Tuple[float, float, float] = (0.0, 0.0, 0.0)

    @staticmethod
    def for_model(cfg: ArchConfig, hw: HardwareSpec,
                  parallel: ParallelSpec, cluster: ClusterSpec,
                  dtype_bytes: int = 2) -> "PipelineBackend":
        graph = OperatorGraph.from_config(cfg, parallel.tp, dtype_bytes)
        stage_hw = hw.with_(iter_overhead=0.0)
        stages = [RooflineBackend(hw=stage_hw, graph=g, cluster=cluster,
                                  stage=s)
                  for s, g in enumerate(graph.split_stages(parallel.pp))]
        links = [stage_boundary_link(cluster, parallel.tp, s)
                 for s in range(parallel.pp - 1)]
        return PipelineBackend(
            stages=stages, boundary_links=links,
            act_bytes_per_token=graph.act_bytes_per_token,
            microbatches=parallel.microbatches,
            overhead=hw.iter_overhead)

    @property
    def pp(self) -> int:
        return len(self.stages)

    def iteration_time(self, mix: BatchMix) -> float:
        self.last_breakdown = (0.0, 0.0, 0.0)
        if mix.new_tokens == 0 and mix.enc_tokens == 0:
            return 0.0
        pp = self.pp
        # a micro-batch needs at least one token; tail iterations with
        # fewer tokens than configured micro-batches shrink m
        m = max(1, min(self.microbatches, int(mix.new_tokens)))
        s = 1.0 / m
        micro = BatchMix(new_tokens=mix.new_tokens * s,
                         attn_units=mix.attn_units * s,
                         kv_read_tokens=mix.kv_read_tokens * s,
                         n_seqs=mix.n_seqs * s,
                         enc_tokens=mix.enc_tokens * s,
                         padded_tokens=mix.padded_tokens * s)
        t_stage = max(b.iteration_time(micro) for b in self.stages)
        act = self.act_bytes_per_token * micro.new_tokens
        t_comm = max((p2p_time(act, link) for link in self.boundary_links),
                     default=0.0)
        step = t_stage + t_comm
        span = (m + pp - 1) * step
        self.last_breakdown = ((pp - 1) * step, (m + pp - 1) * t_comm, span)
        return self.overhead + span


def make_backend(kind: str, cfg: ArchConfig, hw: HardwareSpec,
                 tp: int = 1, *, cluster: Optional[ClusterSpec] = None,
                 parallel: Optional[ParallelSpec] = None,
                 **kw) -> CostBackend:
    if kind == "roofline":
        if parallel is not None and parallel.pp > 1:
            from dataclasses import replace as _replace

            from repro.core.costmodel.hardware import DGX_A100
            # explicit tp argument wins over parallel.tp (same
            # precedence as the pp == 1 branch / simulator wiring)
            eff = parallel if tp == 1 else _replace(parallel, tp=tp)
            return PipelineBackend.for_model(
                cfg, hw, eff, cluster or DGX_A100, **kw)
        eff_tp = parallel.tp if parallel is not None and tp == 1 else tp
        return RooflineBackend.for_model(cfg, hw, tp=eff_tp,
                                         cluster=cluster, **kw)
    if kind == "tabular":
        return TabularBackend.fit(kw["samples"])
    if kind == "xla":
        return XLACalibratedBackend(hw=hw, **kw)
    raise ValueError(f"unknown backend {kind!r}")
