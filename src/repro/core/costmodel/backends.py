"""Compute-cost backends: pluggable "compute simulators" (paper §III).

* ``RooflineBackend``       — GenZ-style operator-granular roofline over
                              the ``OperatorGraph``; the default.
* ``TabularBackend``        — calibrated from measured iterations of the
                              *real* JAX engine (repro.serving): piecewise
                              linear in the mix aggregates.  This is how
                              the validation studies hold the simulator to
                              the <1% bar without A100s.
* ``XLACalibratedBackend``  — roofline with per-op FLOPs/bytes replaced by
                              ``compiled.cost_analysis()`` totals from the
                              multi-pod dry-run (beyond paper: ties the
                              simulator to the compiled HLO).
"""
from __future__ import annotations

import bisect
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ArchConfig
from repro.core.costmodel.hardware import HardwareSpec
from repro.core.costmodel.operators import BatchMix, OperatorGraph


class CostBackend:
    """iteration_time(mix) -> seconds on one worker."""

    def iteration_time(self, mix: BatchMix) -> float:
        raise NotImplementedError


@dataclass
class RooflineBackend(CostBackend):
    hw: HardwareSpec
    graph: OperatorGraph

    @staticmethod
    def for_model(cfg: ArchConfig, hw: HardwareSpec, tp: int = 1,
                  dtype_bytes: int = 2) -> "RooflineBackend":
        return RooflineBackend(
            hw=hw, graph=OperatorGraph.from_config(cfg, tp, dtype_bytes))

    def iteration_time(self, mix: BatchMix) -> float:
        if mix.new_tokens == 0 and mix.enc_tokens == 0:
            return 0.0
        hw = self.hw
        t = hw.iter_overhead
        fpeak = hw.flops * hw.flops_eff
        bpeak = hw.mem_bw * hw.bw_eff
        for op in self.graph.ops:
            f = op.flops(mix)
            b = op.bytes(mix)
            if f or b:
                t += max(f / fpeak, b / bpeak)
        if self.graph.collective_bytes_per_token:
            t += self.graph.collective_bytes_per_token * mix.new_tokens \
                / self.hw.link_bw
        return t


@dataclass
class TabularBackend(CostBackend):
    """Least-squares affine fit  t ≈ c0 + c1·padded_tokens + c2·attn_units
    + c3·kv_read_tokens + c4·n_seqs  over calibration samples.

    ``padded_tokens`` (bucketed prefill chunks) rather than raw tokens:
    the real engine pads prompts to power-of-two shape buckets, so that
    is the feature its wall-clock actually follows."""

    coef: Tuple[float, float, float, float, float]
    samples: List[Tuple[BatchMix, float]] = field(default_factory=list)

    @staticmethod
    def _features(m: BatchMix):
        padded = m.padded_tokens or m.new_tokens
        return [1.0, padded, m.attn_units, m.kv_read_tokens, m.n_seqs]

    @staticmethod
    def fit(samples: List[Tuple[BatchMix, float]]) -> "TabularBackend":
        import numpy as np
        X = np.array([TabularBackend._features(m) for m, _ in samples])
        y = np.array([t for _, t in samples])
        coef, *_ = np.linalg.lstsq(X, y, rcond=None)
        return TabularBackend(coef=tuple(float(c) for c in coef),
                              samples=list(samples))

    def iteration_time(self, mix: BatchMix) -> float:
        if mix.new_tokens == 0 and mix.enc_tokens == 0:
            return 0.0
        f = self._features(mix)
        t = sum(c * x for c, x in zip(self.coef, f))
        return max(t, 1e-6)


def cost_analysis_dict(cost) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across JAX versions: older
    releases return a one-element list of dicts (one per program), newer
    ones the dict itself.  Every producer/consumer of cost records
    (launch.dryrun, benchmarks.roofline_report, the calibration tests)
    goes through this so the artifact schema stays a flat dict."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


@dataclass
class XLACalibratedBackend(CostBackend):
    """Roofline on dry-run HLO totals.

    ``flops_per_token`` / ``bytes_per_token`` come from
    ``compiled.cost_analysis()`` of the real lowered step divided by the
    step's token count; attention terms are added from the graph (HLO
    numbers are shape-specific, attention scales quadratically)."""

    hw: HardwareSpec
    flops_per_token: float
    bytes_fixed: float
    bytes_per_token: float
    graph: Optional[OperatorGraph] = None

    def iteration_time(self, mix: BatchMix) -> float:
        if mix.new_tokens == 0 and mix.enc_tokens == 0:
            return 0.0
        hw = self.hw
        f = self.flops_per_token * mix.new_tokens
        b = self.bytes_fixed + self.bytes_per_token * mix.new_tokens
        if self.graph is not None:
            for op in self.graph.ops:
                if op.f_attn or op.b_kv:
                    f += op.f_attn * mix.attn_units * op.count
                    b += op.b_kv * mix.kv_read_tokens * op.count
        return hw.iter_overhead + max(f / (hw.flops * hw.flops_eff),
                                      b / (hw.mem_bw * hw.bw_eff))


def make_backend(kind: str, cfg: ArchConfig, hw: HardwareSpec,
                 tp: int = 1, **kw) -> CostBackend:
    if kind == "roofline":
        return RooflineBackend.for_model(cfg, hw, tp=tp, **kw)
    if kind == "tabular":
        return TabularBackend.fit(kw["samples"])
    if kind == "xla":
        return XLACalibratedBackend(hw=hw, **kw)
    raise ValueError(f"unknown backend {kind!r}")
