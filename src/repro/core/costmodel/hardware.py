"""Hardware models.

Chips are modeled by peak dense FLOP/s, HBM bandwidth/capacity and link
bandwidths — the same abstraction the paper (and GenZ) uses.  GPU entries
reproduce the paper's case studies; TPU v5e is the real deployment target
of this repo, and the PIM entry follows the paper's GDDR6-AiM setting
(Fig. 12): a memory-centric part whose effective bandwidth, not FLOPs, is
the selling point.  ``price`` is relative to A100 = 1.0 (used by the
Fig. 12 budget analysis).

``ClusterSpec`` adds the interconnect topology between chips (GPUs per
node, intra-node vs inter-node ``LinkSpec``) and ``ParallelSpec`` the
parallelism strategy mapped onto it (tensor/pipeline degree, data
replicas) — together the hardware axes the exploration harness sweeps
(docs/PARALLELISM.md).
"""
from __future__ import annotations

from dataclasses import dataclass, replace

from repro.core.comm import DCN, ETH100G, ICI, LinkSpec, NVLINK


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    flops: float            # peak dense FLOP/s (fp16/bf16 tensor)
    mem_bw: float           # HBM bytes/s
    mem_cap: float          # HBM bytes
    link_bw: float          # inter-device bytes/s (NVLink / ICI per link)
    pcie_bw: float = 32e9   # host link bytes/s
    #: host DRAM bytes available to park swapped-out KV (the SwapManager
    #: tier, docs/MEMORY.md); the per-accelerator share of the host box
    host_mem_cap: float = 256e9
    price: float = 1.0      # relative to A100
    # achievable fractions (empirical efficiency of dense kernels):
    flops_eff: float = 0.62
    bw_eff: float = 0.82
    # fixed per-iteration overhead (framework + launch), seconds
    iter_overhead: float = 4.0e-3
    #: model-reload latency after a worker restart, seconds
    #: (docs/RELIABILITY.md): weights back onto the device plus server
    #: re-init — the dominant recovery cost, and the same scale-up lag
    #: an autoscaler would pay.  Consumed only when ``SimSpec.chaos``
    #: is set; the legacy fault path keeps recovery free.
    reload_time: float = 30.0
    #: remote KV tier link (docs/ROUTING.md): effective bytes/s this
    #: host sees from the cluster object store (LMCache-class; a 50 GbE
    #: NIC share by default).  Consumed only when ``SimSpec.remote_kv``
    #: is set — per-tier retrieve cost = remote_setup + bytes/remote_bw.
    remote_bw: float = 6.25e9
    #: per-object remote-store round-trip setup latency, seconds
    #: (metadata lookup + connection + first byte)
    remote_setup: float = 2e-3

    def with_(self, **kw) -> "HardwareSpec":
        return replace(self, **kw)


A100 = HardwareSpec("A100", flops=312e12, mem_bw=2.039e12, mem_cap=80e9,
                    link_bw=300e9, price=1.0)
A100_40G = A100.with_(name="A100-40G", mem_cap=40e9)
#: the paper's "AL" — A100 with 1/4 peak FLOPS (Fig. 12)
A100_LOW = A100.with_(name="A100-low", flops=312e12 / 4, price=0.9)
V100 = HardwareSpec("V100", flops=125e12, mem_bw=0.9e12, mem_cap=32e9,
                    link_bw=150e9, pcie_bw=16e9, host_mem_cap=96e9,
                    price=0.25)
#: SK Hynix GDDR6-AiM accelerator card (paper's "G"): near-bank compute
#: gives GDDR6 an effective ~16x internal bandwidth for GEMV-like decode
#: ops. Modeled from the Hot Chips '34 figures at card level; the paper
#: prices it at ~1/2 an A100.
G6_AIM = HardwareSpec("G6-AiM", flops=26e12, mem_bw=2.0e12, mem_cap=32e9,
                      link_bw=32e9, pcie_bw=16e9, host_mem_cap=64e9,
                      price=0.5)
#: TPU v5e — the deployment target for the real runtime in this repo.
TPU_V5E = HardwareSpec("TPUv5e", flops=197e12, mem_bw=819e9, mem_cap=16e9,
                       link_bw=50e9, pcie_bw=16e9, host_mem_cap=128e9,
                       price=0.35)
#: NVIDIA L4 — the cheap inference card (Ada, 24 GB GDDR6): weak on
#: prefill FLOPs but plenty of bandwidth-per-dollar for small-model
#: decode, which is what makes the mixed A100-prefill + L4-decode
#: fleets in benchmarks/hetero_fleet.py win on $/token
L4 = HardwareSpec("L4", flops=121e12, mem_bw=300e9, mem_cap=24e9,
                  link_bw=64e9, pcie_bw=16e9, host_mem_cap=64e9,
                  price=0.2)
#: CPU host executing the real JAX engine in this container; calibrated
#: via TabularBackend, the static numbers are only a seed.  KV "swap"
#: target is its own DRAM, so pcie_bw degrades to a memcpy.
CPU_HOST = HardwareSpec("CPU", flops=2e11, mem_bw=40e9, mem_cap=32e9,
                        link_bw=10e9, pcie_bw=20e9, host_mem_cap=32e9,
                        price=0.02, flops_eff=0.5, bw_eff=0.5,
                        iter_overhead=1e-3)

HARDWARE = {h.name: h for h in
            [A100, A100_40G, A100_LOW, V100, G6_AIM, TPU_V5E, L4,
             CPU_HOST]}


# ---------------------------------------------------------------------------
# Interconnect topology + parallelism strategy (docs/PARALLELISM.md)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ClusterSpec:
    """Interconnect topology of one serving replica's devices.

    Devices are numbered consecutively; nodes hold ``gpus_per_node`` of
    them, wired internally by ``intra_link`` (NVLink / ICI class) and to
    each other by ``inter_link`` (NIC class).  The collective cost model
    (repro.core.comm.collectives) uses this to decide which link a TP
    ring or a PP stage boundary traverses, so parallelism cost depends
    on *where* the ranks land, not just how many there are.
    """
    name: str
    gpus_per_node: int = 8
    intra_link: LinkSpec = NVLINK
    inter_link: LinkSpec = ETH100G

    def with_(self, **kw) -> "ClusterSpec":
        return replace(self, **kw)


#: DGX-class box: 8 NVLinked GPUs per node, 100 GbE between nodes.
DGX_A100 = ClusterSpec("dgx-a100", gpus_per_node=8,
                       intra_link=NVLINK, inter_link=ETH100G)
#: one GPU per host — every device-to-device hop crosses the 100 GbE NIC
#: (the "slow inter-node links" corner of the TP-vs-PP crossover).
CROSS_NODE_100G = ClusterSpec("cross-node-100g", gpus_per_node=1,
                              intra_link=NVLINK, inter_link=ETH100G)
#: one GPU per host behind data-center network links (50 Gbps class).
CROSS_NODE_DCN = ClusterSpec("cross-node-dcn", gpus_per_node=1,
                             intra_link=NVLINK, inter_link=DCN)
#: TPU v5e topology: 4-chip ICI-connected trays, DCN between trays.
TPU_V5E_POD = ClusterSpec("tpuv5e-pod", gpus_per_node=4,
                          intra_link=ICI, inter_link=DCN)

CLUSTERS = {c.name: c for c in
            [DGX_A100, CROSS_NODE_100G, CROSS_NODE_DCN, TPU_V5E_POD]}


@dataclass(frozen=True)
class ParallelSpec:
    """Parallelism strategy of one logical worker (docs/PARALLELISM.md).

    ``tp`` tensor-shards every layer (all-reduce per layer pair), ``pp``
    splits the layer stack into pipeline stages fed ``microbatches``
    micro-batches per iteration, and ``replicas`` data-parallel-clones
    the whole worker set behind the global scheduler.  One worker spec
    with ``ParallelSpec(tp, pp)`` therefore occupies ``tp * pp``
    devices; the defaults are exactly the pre-parallelism single-device
    cost model.
    """
    tp: int = 1            # tensor-parallel degree (devices per stage)
    pp: int = 1            # pipeline stages
    replicas: int = 1      # data-parallel copies of the worker set
    #: micro-batches per pipeline iteration; the bubble fraction is
    #: (pp - 1) / (microbatches + pp - 1)
    microbatches: int = 2

    def __post_init__(self):
        if self.tp < 1 or self.pp < 1 or self.replicas < 1 \
                or self.microbatches < 1:
            raise ValueError(f"ParallelSpec degrees must be >= 1: {self}")

    @property
    def devices(self) -> int:
        """Accelerators one replica of this strategy occupies."""
        return self.tp * self.pp
