"""Hardware models.

Chips are modeled by peak dense FLOP/s, HBM bandwidth/capacity and link
bandwidths — the same abstraction the paper (and GenZ) uses.  GPU entries
reproduce the paper's case studies; TPU v5e is the real deployment target
of this repo, and the PIM entry follows the paper's GDDR6-AiM setting
(Fig. 12): a memory-centric part whose effective bandwidth, not FLOPs, is
the selling point.  ``price`` is relative to A100 = 1.0 (used by the
Fig. 12 budget analysis).
"""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    flops: float            # peak dense FLOP/s (fp16/bf16 tensor)
    mem_bw: float           # HBM bytes/s
    mem_cap: float          # HBM bytes
    link_bw: float          # inter-device bytes/s (NVLink / ICI per link)
    pcie_bw: float = 32e9   # host link bytes/s
    #: host DRAM bytes available to park swapped-out KV (the SwapManager
    #: tier, docs/MEMORY.md); the per-accelerator share of the host box
    host_mem_cap: float = 256e9
    price: float = 1.0      # relative to A100
    # achievable fractions (empirical efficiency of dense kernels):
    flops_eff: float = 0.62
    bw_eff: float = 0.82
    # fixed per-iteration overhead (framework + launch), seconds
    iter_overhead: float = 4.0e-3

    def with_(self, **kw) -> "HardwareSpec":
        return replace(self, **kw)


A100 = HardwareSpec("A100", flops=312e12, mem_bw=2.039e12, mem_cap=80e9,
                    link_bw=300e9, price=1.0)
A100_40G = A100.with_(name="A100-40G", mem_cap=40e9)
#: the paper's "AL" — A100 with 1/4 peak FLOPS (Fig. 12)
A100_LOW = A100.with_(name="A100-low", flops=312e12 / 4, price=0.9)
V100 = HardwareSpec("V100", flops=125e12, mem_bw=0.9e12, mem_cap=32e9,
                    link_bw=150e9, pcie_bw=16e9, host_mem_cap=96e9,
                    price=0.25)
#: SK Hynix GDDR6-AiM accelerator card (paper's "G"): near-bank compute
#: gives GDDR6 an effective ~16x internal bandwidth for GEMV-like decode
#: ops. Modeled from the Hot Chips '34 figures at card level; the paper
#: prices it at ~1/2 an A100.
G6_AIM = HardwareSpec("G6-AiM", flops=26e12, mem_bw=2.0e12, mem_cap=32e9,
                      link_bw=32e9, pcie_bw=16e9, host_mem_cap=64e9,
                      price=0.5)
#: TPU v5e — the deployment target for the real runtime in this repo.
TPU_V5E = HardwareSpec("TPUv5e", flops=197e12, mem_bw=819e9, mem_cap=16e9,
                       link_bw=50e9, pcie_bw=16e9, host_mem_cap=128e9,
                       price=0.35)
#: CPU host executing the real JAX engine in this container; calibrated
#: via TabularBackend, the static numbers are only a seed.  KV "swap"
#: target is its own DRAM, so pcie_bw degrades to a memcpy.
CPU_HOST = HardwareSpec("CPU", flops=2e11, mem_bw=40e9, mem_cap=32e9,
                        link_bw=10e9, pcie_bw=20e9, host_mem_cap=32e9,
                        price=0.02, flops_eff=0.5, bw_eff=0.5,
                        iter_overhead=1e-3)

HARDWARE = {h.name: h for h in
            [A100, A100_40G, A100_LOW, V100, G6_AIM, TPU_V5E, CPU_HOST]}
