"""Hierarchical KV memory management (paper §III-B / §IV-E,
docs/MEMORY.md).

Three tiers, device-out: ``BlockManager`` — paged device KV with
refcounted shared-prefix copy-on-write blocks; ``SwapManager`` — host
DRAM holding preempted requests' KV over a PCIe-costed channel
(``SimSpec.preemption_mode="swap"``); ``MemoryPool`` + ``PrefixTrie`` —
the cross-request/session cache serving multi-round conversations and
prefix locality.
"""
from repro.core.mem.block_manager import (BlockManager,  # noqa: F401
                                          MemoryConfig)
from repro.core.mem.memory_pool import (EVICTION_KINDS,  # noqa: F401
                                        MemoryPool, PoolConfig,
                                        PrefixTrie)
from repro.core.mem.swap import (PREEMPTION_MODES,  # noqa: F401
                                 SwapConfig, SwapManager)
