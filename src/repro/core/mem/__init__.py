from repro.core.mem.block_manager import BlockManager, MemoryConfig  # noqa: F401
from repro.core.mem.memory_pool import MemoryPool, PoolConfig  # noqa: F401
