"""Multi-round KV memory pool and prompt-prefix trie (docs/MEMORY.md).

Citations: CachedAttention / MemServe (paper §IV-E, Fig. 14).

Finished conversations park their KV in a tiered pool (host DRAM or a
disaggregated memory pool); a follow-up round of the same session reuses
the cached prefix instead of recomputing prefill.  A prompt-prefix trie
gives MemServe-style cross-request locality for identical prefixes —
both for global-scheduler routing (worker payloads) and inside the
``BlockManager`` allocation path (physical-block payloads backing
shared-prefix copy-on-write caching).

Costs: retrieval latency per block (MemServe quotes ~800 ns/block for
pooled memory) plus optional bandwidth-limited transfer handled by the
simulator's comm model.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.request import Request

#: every accepted ``PoolConfig.eviction`` policy; scripts/check_docs.py
#: asserts each entry is documented in docs/MEMORY.md
EVICTION_KINDS = ("lru",)


@dataclass(frozen=True)
class PoolConfig:
    capacity_tokens: int = 4_000_000
    block_size: int = 16
    retrieve_latency_per_block: float = 800e-9   # MemServe figure
    store_latency_per_block: float = 800e-9
    eviction: str = "lru"                # see EVICTION_KINDS
    enabled: bool = True


class MemoryPool:
    """LRU pool of per-session KV prefixes (token granularity)."""

    def __init__(self, pc: PoolConfig):
        if pc.eviction not in EVICTION_KINDS:
            raise ValueError(f"unknown pool eviction policy "
                             f"{pc.eviction!r}; have {EVICTION_KINDS}")
        self.pc = pc
        self._entries: "OrderedDict[int, int]" = OrderedDict()
        self.used_tokens = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # -- store / lookup ---------------------------------------------------
    def store(self, session_id: Optional[int], context_len: int) -> float:
        """Park `context_len` tokens of KV for the session; returns the
        simulated store latency."""
        if not self.pc.enabled or session_id is None:
            return 0.0
        prev = self._entries.pop(session_id, 0)
        self.used_tokens -= prev
        keep = max(prev, context_len)
        while self.used_tokens + keep > self.pc.capacity_tokens \
                and self._entries:
            _, ev = self._entries.popitem(last=False)
            self.used_tokens -= ev
            self.evictions += 1
        if self.used_tokens + keep > self.pc.capacity_tokens:
            return 0.0                    # doesn't fit at all
        self._entries[session_id] = keep
        self.used_tokens += keep
        blocks = -(-keep // self.pc.block_size)
        return blocks * self.pc.store_latency_per_block

    def lookup(self, req: Request) -> Tuple[int, float]:
        """Returns (reusable_prefix_tokens, retrieve_latency)."""
        if not self.pc.enabled or req.session_id is None:
            return 0, 0.0
        cached = self._entries.get(req.session_id, 0)
        if cached <= 0:
            self.misses += 1
            return 0, 0.0
        self._entries.move_to_end(req.session_id)   # LRU touch
        reuse = min(cached, req.history_len, req.prompt_len)
        if reuse <= 0:
            self.misses += 1
            return 0, 0.0
        self.hits += 1
        blocks = -(-reuse // self.pc.block_size)
        return reuse, blocks * self.pc.retrieve_latency_per_block

    def stats(self) -> Dict[str, float]:
        total = self.hits + self.misses
        return {"hits": self.hits, "misses": self.misses,
                "hit_rate": self.hits / total if total else 0.0,
                "used_tokens": self.used_tokens,
                "evictions": self.evictions}


class PrefixTrie:
    """MemServe-style global prompt tree at block granularity.

    Keys are per-block content hashes (here: the workload's deterministic
    pseudo-token block keys).  Two payload kinds share the node
    structure, serving the two prefix-locality layers of the stack:

    * ``_workers`` sets — the session-affinity global scheduler routes
      requests to the worker most likely to hold their prefix
      (``insert`` / ``best_worker``);
    * ``_block`` physical-block ids — the ``BlockManager`` allocation
      path resolves a request's shared-prefix keys to resident device
      blocks for refcounted copy-on-write sharing (``insert_block`` /
      ``match_blocks`` / ``remove_block``).
    """

    #: node payload keys (everything else in a node dict is a child edge)
    _META = ("_workers", "_block")

    def __init__(self, block_size: int = 16):
        self.block_size = block_size
        self.root: Dict = {}

    # -- worker-routing payloads (global scheduler) ----------------------
    def insert(self, key_blocks: Tuple[int, ...], worker_id: int) -> None:
        node = self.root
        for kb in key_blocks:
            node = node.setdefault(kb, {})
            node.setdefault("_workers", set()).add(worker_id)

    def best_worker(self, key_blocks: Tuple[int, ...]) -> Tuple[Optional[int], int]:
        """(worker with longest shared prefix, matched blocks)."""
        node = self.root
        last_workers, depth = None, 0
        for kb in key_blocks:
            if kb not in node:
                break
            node = node[kb]
            last_workers = node.get("_workers")
            depth += 1
        if not last_workers:
            return None, 0
        return min(last_workers), depth

    # -- physical-block payloads (BlockManager allocation path) ----------
    def insert_block(self, key_path: Sequence, block_id: int) -> None:
        """Register a resident device block under its content-key path."""
        node = self.root
        for k in key_path:
            node = node.setdefault(k, {})
        node["_block"] = block_id

    def match_blocks(self, key_path: Sequence) -> List[int]:
        """Physical blocks of the longest registered prefix of
        ``key_path`` (contiguous from the root; stops at the first key
        without a resident block)."""
        node = self.root
        out: List[int] = []
        for k in key_path:
            node = node.get(k)
            if node is None or "_block" not in node:
                break
            out.append(node["_block"])
        return out

    def remove_block(self, key_path: Sequence) -> None:
        """Unregister the block at ``key_path``, pruning nodes that hold
        no live payload and no children afterwards."""
        path = [self.root]
        for k in key_path:
            nxt = path[-1].get(k)
            if nxt is None:
                return
            path.append(nxt)
        path[-1].pop("_block", None)
        for i in range(len(key_path), 0, -1):
            node = path[i]
            # presence checks, not truthiness: block id 0 and physical
            # worker id 0 are live payloads too
            alive = any(k not in self._META for k in node) \
                or "_block" in node or node.get("_workers")
            if alive:                    # child edges or live payloads
                break
            del path[i - 1][key_path[i - 1]]
