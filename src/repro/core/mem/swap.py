"""Host-DRAM swap tier for preempted KV (docs/MEMORY.md).

Citations: vLLM's swap preemption mode and the LLMServingSim /
Miao et al. serving-survey treatment of KV offload across a memory
hierarchy.  When a local scheduler preempts a request in
``preemption_mode="swap"``, the victim's resident KV moves to host DRAM
over a PCIe-bandwidth-costed channel instead of being discarded; on
re-admission it moves back and decoding resumes without re-prefill.

Cost model (billed into the worker's iteration time by the event loop):

    transfer_time(tokens) = setup_latency
                          + blocks * per_block_latency
                          + tokens * kv_bytes_per_token / pcie_bw

The per-block term models the scattered per-layer DMA descriptors a
paged KV layout forces (small non-contiguous copies run far below peak
PCIe bandwidth), which is why recompute beats swap for short contexts
while swap wins for long ones — the crossover
``benchmarks/kv_hierarchy.py`` sweeps.  Host capacity is bounded by
``HardwareSpec.host_mem_cap``; when the host tier is full the scheduler
falls back to recompute preemption for that victim.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict

from repro.core.request import Request

#: every accepted ``SimSpec.preemption_mode``; scripts/check_docs.py
#: asserts each entry is documented in docs/MEMORY.md
PREEMPTION_MODES = ("recompute", "swap")


@dataclass(frozen=True)
class SwapConfig:
    pcie_bw: float = 32e9               # host link bytes/s
    host_capacity_bytes: float = 256e9  # DRAM reserved for swapped KV
    kv_bytes_per_token: float = 1.0     # 0 => SSM constant per-seq state
    state_bytes_per_seq: float = 0.0
    block_size: int = 16
    #: fixed DMA/driver setup per transfer, seconds
    setup_latency: float = 50e-6
    #: per-block descriptor cost of scattered paged-KV copies, seconds
    per_block_latency: float = 50e-6


class SwapManager:
    """Accounting for KV parked in host DRAM, one instance per worker.

    Holds (req id -> tokens) for swapped-out requests, bounds host
    usage, and prices each direction of the transfer.  Pure accounting:
    the local scheduler decides *when* to swap, the worker bills the
    returned latencies into simulated time.
    """

    def __init__(self, sc: SwapConfig):
        self.sc = sc
        self.host: Dict[int, int] = {}   # req id -> tokens held in DRAM
        self.used_bytes = 0.0
        self.peak_used_bytes = 0.0
        self.swap_out_events = 0
        self.swap_in_events = 0
        self.bytes_out = 0.0
        self.bytes_in = 0.0
        self.fallbacks = 0               # host full: recompute instead
        self.adopted = 0                 # failover entries taken over
        #: observability tap (repro.obs): when set, called as
        #: on_event(kind, req_id, tokens, nbytes) for every swap_out /
        #: swap_in so the trace can mark transfers on the worker lane
        self.on_event = None

    # -- cost model -------------------------------------------------------
    def bytes_for(self, tokens: int) -> float:
        if self.sc.kv_bytes_per_token > 0:
            return tokens * self.sc.kv_bytes_per_token
        return self.sc.state_bytes_per_seq

    def transfer_time(self, tokens: int) -> float:
        """One direction (swap-out or swap-in) of ``tokens`` of KV."""
        blocks = max(1, math.ceil(max(1, tokens) / self.sc.block_size))
        return self.sc.setup_latency \
            + blocks * self.sc.per_block_latency \
            + self.bytes_for(tokens) / max(self.sc.pcie_bw, 1.0)

    # -- state ------------------------------------------------------------
    def can_swap_out(self, tokens: int) -> bool:
        return self.used_bytes + self.bytes_for(tokens) \
            <= self.sc.host_capacity_bytes

    def holds(self, req: Request) -> bool:
        return req.id in self.host

    def tokens_held(self, req: Request) -> int:
        return self.host.get(req.id, 0)

    def swap_out(self, req: Request, tokens: int) -> float:
        """Park ``tokens`` of req's KV in host DRAM; returns latency."""
        assert req.id not in self.host, f"req {req.id} already swapped"
        assert tokens > 0
        nbytes = self.bytes_for(tokens)
        assert self.used_bytes + nbytes <= self.sc.host_capacity_bytes, \
            "host tier full (call can_swap_out first)"
        self.host[req.id] = tokens
        self.used_bytes += nbytes
        self.peak_used_bytes = max(self.peak_used_bytes, self.used_bytes)
        self.swap_out_events += 1
        self.bytes_out += nbytes
        if self.on_event is not None:
            self.on_event("swap_out", req.id, tokens, nbytes)
        return self.transfer_time(tokens)

    def swap_in(self, req: Request) -> float:
        """Restore req's KV to the device; returns latency."""
        tokens = self.host.pop(req.id)
        nbytes = self.bytes_for(tokens)
        self.used_bytes -= nbytes
        self.swap_in_events += 1
        self.bytes_in += nbytes
        if self.on_event is not None:
            self.on_event("swap_in", req.id, tokens, nbytes)
        return self.transfer_time(tokens)

    def adopt(self, req: Request, tokens: int) -> bool:
        """Take ownership of a KV entry that already lives in host DRAM
        (failover re-dispatch, docs/RELIABILITY.md): no PCIe transfer —
        the bytes never moved — just capacity accounting in the
        adopting worker's tier.  Returns False (and counts a fallback)
        when this tier has no room; the caller then re-prefills."""
        if tokens <= 0 or req.id in self.host:
            return False
        nbytes = self.bytes_for(tokens)
        if self.used_bytes + nbytes > self.sc.host_capacity_bytes:
            self.fallbacks += 1
            return False
        self.host[req.id] = tokens
        self.used_bytes += nbytes
        self.peak_used_bytes = max(self.peak_used_bytes, self.used_bytes)
        self.adopted += 1
        if self.on_event is not None:
            self.on_event("adopt", req.id, tokens, nbytes)
        return True

    def drop(self, req: Request) -> int:
        """Discard req's host copy without a transfer (finish, failure,
        migration); idempotent.  Returns tokens released."""
        tokens = self.host.pop(req.id, 0)
        if tokens:
            self.used_bytes -= self.bytes_for(tokens)
        return tokens

    def stats(self) -> Dict[str, float]:
        return {"swap_out_events": self.swap_out_events,
                "swap_in_events": self.swap_in_events,
                "bytes_out": self.bytes_out,
                "bytes_in": self.bytes_in,
                "used_bytes": self.used_bytes,
                "peak_used_bytes": self.peak_used_bytes,
                "fallbacks": self.fallbacks,
                "adopted": self.adopted}
