"""Host-DRAM swap tier for preempted KV (docs/MEMORY.md).

Citations: vLLM's swap preemption mode and the LLMServingSim /
Miao et al. serving-survey treatment of KV offload across a memory
hierarchy.  When a local scheduler preempts a request in
``preemption_mode="swap"``, the victim's resident KV moves to host DRAM
over a PCIe-bandwidth-costed channel instead of being discarded; on
re-admission it moves back and decoding resumes without re-prefill.

Cost model (billed into the worker's iteration time by the event loop):

    transfer_time(tokens) = setup_latency
                          + blocks * per_block_latency
                          + tokens * kv_bytes_per_token / pcie_bw

The per-block term models the scattered per-layer DMA descriptors a
paged KV layout forces (small non-contiguous copies run far below peak
PCIe bandwidth), which is why recompute beats swap for short contexts
while swap wins for long ones — the crossover
``benchmarks/kv_hierarchy.py`` sweeps.  Host capacity is bounded by
``HardwareSpec.host_mem_cap``; when the host tier is full the scheduler
falls back to recompute preemption for that victim — unless a third,
cluster-wide remote/object tier is attached (``SimSpec.remote_kv``,
docs/ROUTING.md), in which case the victim *spills* there first:

    remote transfer_time(tokens) = remote_setup_latency
                                 + bytes / remote_bw

(one GET/PUT per object — the store is not block-granular, so no
per-block descriptor term).  Spilled entries are pinned in the store
(they hold the only copy of live progress) and freed on swap-in /
release; only when neither tier fits does the scheduler fall back to
recompute.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional

from repro.core.mem.remote_store import RemoteKVStore
from repro.core.request import Request

#: every accepted ``SimSpec.preemption_mode``; scripts/check_docs.py
#: asserts each entry is documented in docs/MEMORY.md
PREEMPTION_MODES = ("recompute", "swap")


@dataclass(frozen=True)
class SwapConfig:
    pcie_bw: float = 32e9               # host link bytes/s
    host_capacity_bytes: float = 256e9  # DRAM reserved for swapped KV
    kv_bytes_per_token: float = 1.0     # 0 => SSM constant per-seq state
    state_bytes_per_seq: float = 0.0
    block_size: int = 16
    #: fixed DMA/driver setup per transfer, seconds
    setup_latency: float = 50e-6
    #: per-block descriptor cost of scattered paged-KV copies, seconds
    per_block_latency: float = 50e-6
    #: remote-tier link (docs/ROUTING.md), from HardwareSpec.remote_bw /
    #: remote_setup; consumed only when a RemoteKVStore is attached
    remote_bw: float = 6.25e9
    remote_setup_latency: float = 2e-3


class SwapManager:
    """Accounting for KV parked in host DRAM, one instance per worker.

    Holds (req id -> tokens) for swapped-out requests, bounds host
    usage, and prices each direction of the transfer.  Pure accounting:
    the local scheduler decides *when* to swap, the worker bills the
    returned latencies into simulated time.
    """

    def __init__(self, sc: SwapConfig,
                 remote: Optional[RemoteKVStore] = None):
        self.sc = sc
        self.remote = remote             # shared cluster tier (or None)
        self.host: Dict[int, int] = {}   # req id -> tokens held in DRAM
        self._remote: Dict[int, int] = {}  # req id -> tokens spilled
        self.used_bytes = 0.0
        self.peak_used_bytes = 0.0
        self.swap_out_events = 0
        self.swap_in_events = 0
        self.bytes_out = 0.0
        self.bytes_in = 0.0
        self.remote_out_events = 0
        self.remote_in_events = 0
        self.remote_bytes_out = 0.0
        self.remote_bytes_in = 0.0
        self.fallbacks = 0               # no tier fits: recompute instead
        self.adopted = 0                 # failover entries taken over
        #: observability tap (repro.obs): when set, called as
        #: on_event(kind, req_id, tokens, nbytes) for every swap_out /
        #: swap_in so the trace can mark transfers on the worker lane
        self.on_event = None

    # -- cost model -------------------------------------------------------
    def bytes_for(self, tokens: int) -> float:
        if self.sc.kv_bytes_per_token > 0:
            return tokens * self.sc.kv_bytes_per_token
        return self.sc.state_bytes_per_seq

    def transfer_time(self, tokens: int, tier: str = "host") -> float:
        """One direction (swap-out or swap-in) of ``tokens`` of KV."""
        if tier == "remote":
            return self.sc.remote_setup_latency \
                + self.bytes_for(tokens) / max(self.sc.remote_bw, 1.0)
        blocks = max(1, math.ceil(max(1, tokens) / self.sc.block_size))
        return self.sc.setup_latency \
            + blocks * self.sc.per_block_latency \
            + self.bytes_for(tokens) / max(self.sc.pcie_bw, 1.0)

    # -- state ------------------------------------------------------------
    def _host_fits(self, nbytes: float) -> bool:
        return self.used_bytes + nbytes <= self.sc.host_capacity_bytes

    def can_swap_out(self, tokens: int) -> bool:
        nbytes = self.bytes_for(tokens)
        if self._host_fits(nbytes):
            return True
        return self.remote is not None and self.remote.can_fit(nbytes)

    def holds(self, req: Request) -> bool:
        if req.id in self.host:
            return True
        if req.id in self._remote:
            # pinned spill entries are never LRU-evicted, but a drop by
            # another owner (adoption churn) invalidates the binding
            if self.remote is not None \
                    and self.remote.has(("swap", req.id)):
                return True
            del self._remote[req.id]
        return False

    def tokens_held(self, req: Request) -> int:
        return self.host.get(req.id, 0) or self._remote.get(req.id, 0)

    def swap_out(self, req: Request, tokens: int) -> float:
        """Park ``tokens`` of req's KV in host DRAM (or spill to the
        remote tier when the host is full); returns latency."""
        assert req.id not in self.host and req.id not in self._remote, \
            f"req {req.id} already swapped"
        assert tokens > 0
        nbytes = self.bytes_for(tokens)
        if not self._host_fits(nbytes):
            assert self.remote is not None \
                and self.remote.put(("swap", req.id), tokens, nbytes,
                                    pinned=True), \
                "no tier fits (call can_swap_out first)"
            self._remote[req.id] = tokens
            self.remote_out_events += 1
            self.remote_bytes_out += nbytes
            if self.on_event is not None:
                self.on_event("remote_out", req.id, tokens, nbytes)
            return self.transfer_time(tokens, tier="remote")
        self.host[req.id] = tokens
        self.used_bytes += nbytes
        self.peak_used_bytes = max(self.peak_used_bytes, self.used_bytes)
        self.swap_out_events += 1
        self.bytes_out += nbytes
        if self.on_event is not None:
            self.on_event("swap_out", req.id, tokens, nbytes)
        return self.transfer_time(tokens)

    def swap_in(self, req: Request) -> float:
        """Restore req's KV to the device; returns latency."""
        if req.id in self._remote:
            tokens = self._remote.pop(req.id)
            self.remote.drop(("swap", req.id))
            nbytes = self.bytes_for(tokens)
            self.remote_in_events += 1
            self.remote_bytes_in += nbytes
            if self.on_event is not None:
                self.on_event("remote_in", req.id, tokens, nbytes)
            return self.transfer_time(tokens, tier="remote")
        tokens = self.host.pop(req.id)
        nbytes = self.bytes_for(tokens)
        self.used_bytes -= nbytes
        self.swap_in_events += 1
        self.bytes_in += nbytes
        if self.on_event is not None:
            self.on_event("swap_in", req.id, tokens, nbytes)
        return self.transfer_time(tokens)

    def adopt(self, req: Request, tokens: int) -> bool:
        """Take ownership of a KV entry that already lives off-device
        (failover re-dispatch, docs/RELIABILITY.md): no transfer — the
        bytes never moved — just capacity accounting in the adopting
        worker's tiers (host DRAM first, remote spill second).  Returns
        False (and counts a fallback) when no tier has room; the caller
        then re-prefills."""
        if tokens <= 0 or req.id in self.host or req.id in self._remote:
            return False
        nbytes = self.bytes_for(tokens)
        if not self._host_fits(nbytes):
            if self.remote is not None \
                    and self.remote.put(("swap", req.id), tokens, nbytes,
                                        pinned=True):
                self._remote[req.id] = tokens
                self.adopted += 1
                if self.on_event is not None:
                    self.on_event("adopt", req.id, tokens, nbytes)
                return True
            self.fallbacks += 1
            return False
        self.host[req.id] = tokens
        self.used_bytes += nbytes
        self.peak_used_bytes = max(self.peak_used_bytes, self.used_bytes)
        self.adopted += 1
        if self.on_event is not None:
            self.on_event("adopt", req.id, tokens, nbytes)
        return True

    def drop(self, req: Request) -> int:
        """Discard req's off-device copy without a transfer (finish,
        failure, migration); idempotent.  Frees the remote object too —
        spill entries are pinned, so this is their only exit.  Returns
        tokens released."""
        tokens = self.host.pop(req.id, 0)
        if tokens:
            self.used_bytes -= self.bytes_for(tokens)
            return tokens
        tokens = self._remote.pop(req.id, 0)
        if tokens and self.remote is not None:
            self.remote.drop(("swap", req.id))
        return tokens

    def stats(self) -> Dict[str, float]:
        out = {"swap_out_events": self.swap_out_events,
               "swap_in_events": self.swap_in_events,
               "bytes_out": self.bytes_out,
               "bytes_in": self.bytes_in,
               "used_bytes": self.used_bytes,
               "peak_used_bytes": self.peak_used_bytes,
               "fallbacks": self.fallbacks,
               "adopted": self.adopted}
        if self.remote is not None:
            # keys appear only with the tier attached, so two-tier runs
            # (and their golden pins) stay byte-identical
            out.update(remote_out_events=self.remote_out_events,
                       remote_in_events=self.remote_in_events,
                       remote_bytes_out=self.remote_bytes_out,
                       remote_bytes_in=self.remote_bytes_in)
        return out
