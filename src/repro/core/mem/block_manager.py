"""PagedAttention-style block-granular KV memory manager (paper §III-B).

Tracks device memory at block / token / byte granularity.  The *same
class* backs both the simulator's worker memory model and the real JAX
serving engine's page allocator (repro.serving.engine) — one
implementation, structurally validated against itself.

Invariants (property-tested in tests/test_block_manager.py):
  * a block belongs to at most one request (no sharing at this layer;
    prefix sharing is the MemoryPool's job),
  * free + Σ allocated == total,
  * a request's blocks always cover ceil(context_len / block_size).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.request import Request


@dataclass(frozen=True)
class MemoryConfig:
    num_blocks: int
    block_size: int = 16                # tokens per block
    #: bytes per KV token (byte-granularity reporting). 0 => attention-free
    #: arch: one constant state slot per sequence instead of paged KV.
    kv_bytes_per_token: float = 1.0
    state_bytes_per_seq: float = 0.0    # SSM/hybrid constant per-seq state
    watermark: float = 0.0              # reserve fraction for running reqs

    @staticmethod
    def from_model(cfg, hw_mem_bytes: float, *, block_size: int = 16,
                   dtype_bytes: int = 2, tp: int = 1,
                   gpu_mem_util: float = 0.9, watermark: float = 0.0,
                   reserve_bytes: float = 0.0) -> "MemoryConfig":
        """Size the KV pool like vLLM: (mem_util × capacity − params −
        reserve) / block bytes."""
        from repro.core.costmodel.operators import (kv_bytes_per_token,
                                                    param_bytes,
                                                    state_bytes_per_seq)
        kvt = kv_bytes_per_token(cfg, dtype_bytes, tp)
        sps = state_bytes_per_seq(cfg, dtype_bytes, tp)
        budget = hw_mem_bytes * gpu_mem_util - param_bytes(
            cfg, dtype_bytes, tp) - reserve_bytes
        if kvt <= 0:                     # pure SSM: budget counts states
            n = max(1, int(budget / max(sps, 1.0)))
            return MemoryConfig(num_blocks=n, block_size=1,
                                kv_bytes_per_token=0.0,
                                state_bytes_per_seq=sps,
                                watermark=watermark)
        n = max(1, int(budget / (kvt * block_size)))
        return MemoryConfig(num_blocks=n, block_size=block_size,
                            kv_bytes_per_token=kvt,
                            state_bytes_per_seq=sps, watermark=watermark)


class BlockManager:
    def __init__(self, mc: MemoryConfig):
        self.mc = mc
        self.free_blocks: List[int] = list(range(mc.num_blocks))
        self.free_blocks.reverse()       # pop() yields 0,1,2,... order
        self.tables: Dict[int, List[int]] = {}   # req id -> physical blocks
        self.token_counts: Dict[int, int] = {}   # req id -> resident tokens
        self.peak_used = 0

    # -- capacity queries -------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self.free_blocks)

    @property
    def num_used(self) -> int:
        return self.mc.num_blocks - self.num_free

    def usage(self) -> float:
        return self.num_used / max(1, self.mc.num_blocks)

    def used_bytes(self) -> float:
        if self.mc.kv_bytes_per_token:
            return self.num_used * self.mc.block_size * \
                self.mc.kv_bytes_per_token
        return self.num_used * self.mc.state_bytes_per_seq

    def blocks_needed(self, tokens: int) -> int:
        if self.mc.kv_bytes_per_token <= 0:      # SSM: 1 slot per seq
            return 1
        return math.ceil(max(1, tokens) / self.mc.block_size)

    def can_allocate(self, tokens: int, *, respect_watermark: bool = False,
                     headroom_tokens: int = 0) -> bool:
        need = self.blocks_needed(tokens + headroom_tokens)
        avail = self.num_free
        if respect_watermark and self.mc.watermark > 0:
            avail -= int(self.mc.watermark * self.mc.num_blocks)
        return need <= avail

    # -- allocation -------------------------------------------------------
    def allocate(self, req: Request, tokens: int,
                 reserve: int = 0) -> List[int]:
        """Allocate blocks covering ``tokens`` (+ ``reserve`` headroom
        tokens, used by static batching to pre-book the whole output)."""
        assert req.id not in self.tables, f"req {req.id} already allocated"
        need = self.blocks_needed(tokens + reserve)
        if need > self.num_free:
            raise MemoryError(f"OOM: need {need}, free {self.num_free}")
        blocks = [self.free_blocks.pop() for _ in range(need)]
        self.tables[req.id] = blocks
        self.token_counts[req.id] = tokens
        self.peak_used = max(self.peak_used, self.num_used)
        return blocks

    def can_append(self, req: Request, n: int = 1) -> bool:
        cur = self.token_counts.get(req.id, 0)
        have = len(self.tables.get(req.id, ())) * self.mc.block_size
        if self.mc.kv_bytes_per_token <= 0:
            return True                           # constant state
        need = self.blocks_needed(cur + n) - self.blocks_needed(cur) \
            if cur + n > have else 0
        return need <= self.num_free

    def append_tokens(self, req: Request, n: int = 1) -> None:
        """Grow req's context by n tokens, taking new blocks as needed.

        Speculative decoding appends the full draft window (K+1 tokens)
        before verify and pairs it with ``rollback_tokens`` for the
        rejected suffix, so accept/rollback is two symmetric calls and
        the coverage invariant holds between iterations."""
        assert req.id in self.tables, f"req {req.id} not resident"
        if self.mc.kv_bytes_per_token <= 0:
            self.token_counts[req.id] += n
            return
        cur = self.token_counts[req.id]
        blocks = self.tables[req.id]
        need = self.blocks_needed(cur + n) - len(blocks)
        if need > self.num_free:
            raise MemoryError(f"OOM appending: need {need}")
        for _ in range(max(0, need)):
            blocks.append(self.free_blocks.pop())
        self.token_counts[req.id] = cur + n
        self.peak_used = max(self.peak_used, self.num_used)

    def rollback_tokens(self, req: Request, n: int = 1) -> int:
        """Shrink req's context by n tokens (rejected speculative drafts),
        releasing blocks that no longer cover any token.  Blocks return
        to the free list in reverse allocation order — the same
        discipline ``free`` uses — so allocation patterns stay
        deterministic.  Returns #blocks released."""
        if n <= 0:
            return 0
        assert req.id in self.tables, f"req {req.id} not resident"
        cur = self.token_counts[req.id]
        assert n <= cur, f"rollback {n} exceeds resident {cur}"
        self.token_counts[req.id] = cur - n
        if self.mc.kv_bytes_per_token <= 0:
            return 0                      # constant state: nothing paged
        blocks = self.tables[req.id]
        keep = self.blocks_needed(cur - n) if cur - n > 0 else 0
        released = 0
        while len(blocks) > keep:
            self.free_blocks.append(blocks.pop())
            released += 1
        return released

    def free(self, req: Request) -> int:
        """Release all blocks of req; returns #blocks released."""
        blocks = self.tables.pop(req.id, [])
        self.token_counts.pop(req.id, None)
        self.free_blocks.extend(reversed(blocks))
        return len(blocks)

    def resident(self, req: Request) -> bool:
        return req.id in self.tables

    def block_table(self, req: Request) -> List[int]:
        return self.tables[req.id]

    def resident_tokens(self, req: Request) -> int:
        return self.token_counts.get(req.id, 0)
