"""PagedAttention-style block-granular KV memory manager (paper §III-B,
docs/MEMORY.md).

Tracks device memory at block / token / byte granularity.  The *same
class* backs both the simulator's worker memory model and the real JAX
serving engine's page allocator (repro.serving.engine) — one
implementation, structurally validated against itself.

With ``MemoryConfig(prefix_sharing=True)`` the manager adds a
shared-prefix tier: requests declaring a common prefix
(``Request.prefix_id`` / ``prefix_len``) resolve their prefix blocks
through a content-keyed :class:`~repro.core.mem.memory_pool.PrefixTrie`
and share resident physical blocks under refcounts, with copy-on-write
on append into a shared block.  Blocks are append-only, so a registered
content range is immutable; sharing is between concurrently resident
requests (the cross-time cache is the MemoryPool's job).

Invariants (property-tested in tests/test_block_manager.py and
tests/test_kv_hierarchy.py):
  * without sharing, a block belongs to at most one request; with
    sharing, a block's refcount equals the number of tables holding it,
  * free + Σ unique allocated == total,
  * a request's blocks always cover ceil(context_len / block_size).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.mem.memory_pool import PrefixTrie
from repro.core.request import Request


@dataclass(frozen=True)
class MemoryConfig:
    num_blocks: int
    block_size: int = 16                # tokens per block
    #: bytes per KV token (byte-granularity reporting). 0 => attention-free
    #: arch: one constant state slot per sequence instead of paged KV.
    kv_bytes_per_token: float = 1.0
    state_bytes_per_seq: float = 0.0    # SSM/hybrid constant per-seq state
    watermark: float = 0.0              # reserve fraction for running reqs
    #: shared-prefix copy-on-write caching (docs/MEMORY.md): requests
    #: with equal (prefix_id, prefix_len) share resident prefix blocks
    prefix_sharing: bool = False

    @staticmethod
    def from_model(cfg, hw_mem_bytes: float, *, block_size: int = 16,
                   dtype_bytes: int = 2, tp: int = 1,
                   gpu_mem_util: float = 0.9, watermark: float = 0.0,
                   reserve_bytes: float = 0.0,
                   prefix_sharing: bool = False) -> "MemoryConfig":
        """Size the KV pool like vLLM: (mem_util × capacity − params −
        reserve) / block bytes."""
        from repro.core.costmodel.operators import (kv_bytes_per_token,
                                                    param_bytes,
                                                    state_bytes_per_seq)
        kvt = kv_bytes_per_token(cfg, dtype_bytes, tp)
        sps = state_bytes_per_seq(cfg, dtype_bytes, tp)
        budget = hw_mem_bytes * gpu_mem_util - param_bytes(
            cfg, dtype_bytes, tp) - reserve_bytes
        if kvt <= 0:                     # pure SSM: budget counts states
            n = max(1, int(budget / max(sps, 1.0)))
            return MemoryConfig(num_blocks=n, block_size=1,
                                kv_bytes_per_token=0.0,
                                state_bytes_per_seq=sps,
                                watermark=watermark,
                                prefix_sharing=prefix_sharing)
        n = max(1, int(budget / (kvt * block_size)))
        return MemoryConfig(num_blocks=n, block_size=block_size,
                            kv_bytes_per_token=kvt,
                            state_bytes_per_seq=sps, watermark=watermark,
                            prefix_sharing=prefix_sharing)


class BlockManager:
    def __init__(self, mc: MemoryConfig):
        self.mc = mc
        self.free_blocks: List[int] = list(range(mc.num_blocks))
        self.free_blocks.reverse()       # pop() yields 0,1,2,... order
        self.tables: Dict[int, List[int]] = {}   # req id -> physical blocks
        self.token_counts: Dict[int, int] = {}   # req id -> resident tokens
        self.peak_used = 0
        #: physical block -> number of tables holding it (1 = private)
        self.ref: Dict[int, int] = {}
        #: content-keyed index of resident shareable prefix blocks
        self.shared_index: Optional[PrefixTrie] = \
            PrefixTrie(mc.block_size) if mc.prefix_sharing else None
        self._shared_path: Dict[int, Tuple] = {}  # block -> trie key path
        # prefix-sharing counters (Results.memory_summary)
        self.shared_hits = 0             # prefix blocks reused via index
        self.shared_misses = 0           # prefix blocks allocated fresh
        self.shared_tokens = 0           # tokens covered by reused blocks
        self.cow_copies = 0              # copy-on-write block copies

    # -- capacity queries -------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self.free_blocks)

    @property
    def num_used(self) -> int:
        return self.mc.num_blocks - self.num_free

    def usage(self) -> float:
        return self.num_used / max(1, self.mc.num_blocks)

    def used_bytes(self) -> float:
        if self.mc.kv_bytes_per_token:
            return self.num_used * self.mc.block_size * \
                self.mc.kv_bytes_per_token
        return self.num_used * self.mc.state_bytes_per_seq

    def blocks_needed(self, tokens: int) -> int:
        if self.mc.kv_bytes_per_token <= 0:      # SSM: 1 slot per seq
            return 1
        return math.ceil(max(1, tokens) / self.mc.block_size)

    def can_allocate(self, tokens: int, *, respect_watermark: bool = False,
                     headroom_tokens: int = 0,
                     req: Optional[Request] = None) -> bool:
        """Whether an allocation of ``tokens`` would fit.  With ``req``
        given and prefix sharing enabled, blocks resolvable through the
        shared index are not charged against the free list (swap-aware
        admission passes the request so shared-prefix requests admit at
        their effective, not nominal, footprint)."""
        need = self.blocks_needed(tokens + headroom_tokens)
        if req is not None and self._sharing_active(req):
            need -= len(self.shared_index.match_blocks(
                self._prefix_keys(req, tokens, headroom_tokens)))
        avail = self.num_free
        if respect_watermark and self.mc.watermark > 0:
            avail -= int(self.mc.watermark * self.mc.num_blocks)
        return need <= avail

    # -- prefix sharing ---------------------------------------------------
    def _sharing_active(self, req: Request) -> bool:
        return self.shared_index is not None \
            and self.mc.kv_bytes_per_token > 0 \
            and getattr(req, "prefix_id", None) is not None \
            and req.prefix_len > 0

    def _prefix_keys(self, req: Request, tokens: int,
                     reserve: int = 0) -> List[tuple]:
        """Deterministic content keys for req's shareable prefix blocks.

        Stands in for per-block content hashes: the workload layer
        guarantees requests with equal ``prefix_id`` carry identical
        prefix tokens.  A full block is always shareable; the partial
        tail block is keyed by its valid-token count and only taken by
        requests whose tokens — including any pre-booked ``reserve``
        (static batching writes its whole output into the reservation,
        no copy-on-write possible) — end inside it.  Anyone writing
        past it recomputes the tail privately, vLLM-style, or triggers
        copy-on-write on a later append."""
        bs = self.mc.block_size
        plen = min(req.prefix_len, tokens)
        if plen <= 0:
            return []
        keys = []
        for i in range(math.ceil(plen / bs)):
            valid = min(bs, plen - i * bs)
            if valid < bs and tokens + reserve > i * bs + valid:
                break                    # req writes past the partial tail
            keys.append((req.prefix_id, i, valid))
        return keys

    def _release_block(self, b: int) -> bool:
        """Drop one table's reference; frees the block when the last
        holder releases it.  Returns True if it went back on the free
        list."""
        r = self.ref[b] - 1
        assert r >= 0, f"refcount underflow on block {b}"
        if r > 0:
            self.ref[b] = r
            return False
        del self.ref[b]
        path = self._shared_path.pop(b, None)
        if path is not None:
            self.shared_index.remove_block(path)
        self.free_blocks.append(b)
        return True

    # -- allocation -------------------------------------------------------
    def allocate(self, req: Request, tokens: int,
                 reserve: int = 0) -> List[int]:
        """Allocate blocks covering ``tokens`` (+ ``reserve`` headroom
        tokens, used by static batching to pre-book the whole output).
        With prefix sharing, resolvable prefix blocks are taken by
        reference from the shared index instead of the free list, and
        freshly written prefix blocks are registered for later reuse."""
        assert req.id not in self.tables, f"req {req.id} already allocated"
        need = self.blocks_needed(tokens + reserve)
        shared: List[int] = []
        keys: List[tuple] = []
        if self._sharing_active(req):
            keys = self._prefix_keys(req, tokens, reserve)
            shared = self.shared_index.match_blocks(keys)
        if need - len(shared) > self.num_free:
            raise MemoryError(f"OOM: need {need - len(shared)}, "
                              f"free {self.num_free}")
        for b in shared:
            self.ref[b] += 1
        fresh = [self.free_blocks.pop() for _ in range(need - len(shared))]
        for b in fresh:
            self.ref[b] = 1
        blocks = shared + fresh
        if keys:
            # register this request's freshly written prefix blocks
            for i in range(len(shared), len(keys)):
                self.shared_index.insert_block(keys[:i + 1], blocks[i])
                self._shared_path[blocks[i]] = tuple(keys[:i + 1])
            self.shared_hits += len(shared)
            self.shared_misses += len(keys) - len(shared)
            if shared:
                # tokens covered by reused blocks: full blocks, plus the
                # partial tail's valid count when it was taken
                toks = min(req.prefix_len, tokens,
                           len(shared) * self.mc.block_size)
                self.shared_tokens += toks
                req.shared_tokens += toks
                # skip prefill for the shared tokens; when the writer's
                # own prefill is still in flight this models coalesced
                # prefix computation (optimistic in-flight dedup — the
                # documented assumption in docs/MEMORY.md)
                if toks > req.cached_len:
                    req.cached_len = toks
        self.tables[req.id] = blocks
        self.token_counts[req.id] = tokens
        self.peak_used = max(self.peak_used, self.num_used)
        return blocks

    def growth_blocks(self, req: Request, n: int = 1) -> int:
        """Free blocks required to append ``n`` tokens: boundary growth
        plus one copy-on-write block when the first new token lands in
        a block shared with another request.  Schedulers budget decode
        feasibility with this (see ContinuousBatching)."""
        if self.mc.kv_bytes_per_token <= 0:
            return 0
        cur = self.token_counts[req.id]
        blocks = self.tables[req.id]
        need = max(0, self.blocks_needed(cur + n) - len(blocks))
        if cur % self.mc.block_size != 0:
            b = blocks[cur // self.mc.block_size]
            if self.ref.get(b, 1) > 1:
                need += 1                # CoW copy of the shared block
        return need

    def can_append(self, req: Request, n: int = 1) -> bool:
        if self.mc.kv_bytes_per_token <= 0:
            return True                           # constant state
        if req.id not in self.tables:
            return False
        return self.growth_blocks(req, n) <= self.num_free

    def append_tokens(self, req: Request, n: int = 1) -> None:
        """Grow req's context by n tokens, taking new blocks as needed.

        Speculative decoding appends the full draft window (K+1 tokens)
        before verify and pairs it with ``rollback_tokens`` for the
        rejected suffix, so accept/rollback is two symmetric calls and
        the coverage invariant holds between iterations.  An append
        landing in a block with refcount > 1 copies it first
        (copy-on-write), so shared prefix content is never mutated."""
        assert req.id in self.tables, f"req {req.id} not resident"
        if self.mc.kv_bytes_per_token <= 0:
            self.token_counts[req.id] += n
            return
        cur = self.token_counts[req.id]
        blocks = self.tables[req.id]
        bs = self.mc.block_size
        grow = max(0, self.blocks_needed(cur + n) - len(blocks))
        cow_idx = -1
        if cur % bs != 0:
            idx = cur // bs
            if self.ref.get(blocks[idx], 1) > 1:
                cow_idx = idx
        if grow + (1 if cow_idx >= 0 else 0) > self.num_free:
            raise MemoryError(f"OOM appending: need "
                              f"{grow + (1 if cow_idx >= 0 else 0)}")
        if cow_idx >= 0:
            old = blocks[cow_idx]
            nb = self.free_blocks.pop()
            self.ref[nb] = 1
            blocks[cow_idx] = nb
            released = self._release_block(old)
            assert not released, "CoW source had a single holder"
            self.cow_copies += 1
            req.cow_copies += 1
        for _ in range(grow):
            nb = self.free_blocks.pop()
            self.ref[nb] = 1
            blocks.append(nb)
        self.token_counts[req.id] = cur + n
        self.peak_used = max(self.peak_used, self.num_used)

    def rollback_tokens(self, req: Request, n: int = 1) -> int:
        """Shrink req's context by n tokens (rejected speculative drafts),
        releasing blocks that no longer cover any token.  Private blocks
        return to the free list in reverse allocation order — the same
        discipline ``free`` uses — so allocation patterns stay
        deterministic; shared blocks only drop this request's reference.
        Returns #blocks actually freed."""
        if n <= 0:
            return 0
        assert req.id in self.tables, f"req {req.id} not resident"
        cur = self.token_counts[req.id]
        assert n <= cur, f"rollback {n} exceeds resident {cur}"
        self.token_counts[req.id] = cur - n
        if self.mc.kv_bytes_per_token <= 0:
            return 0                      # constant state: nothing paged
        blocks = self.tables[req.id]
        keep = self.blocks_needed(cur - n) if cur - n > 0 else 0
        released = 0
        while len(blocks) > keep:
            if self._release_block(blocks.pop()):
                released += 1
        return released

    def free(self, req: Request) -> int:
        """Release req's references on all its blocks; blocks with no
        remaining holder return to the free list.  Idempotent (a second
        free is a no-op), so double frees cannot underflow refcounts.
        Returns #blocks actually freed."""
        blocks = self.tables.pop(req.id, [])
        self.token_counts.pop(req.id, None)
        released = 0
        for b in reversed(blocks):
            if self._release_block(b):
                released += 1
        return released

    def resident(self, req: Request) -> bool:
        return req.id in self.tables

    def block_table(self, req: Request) -> List[int]:
        return self.tables[req.id]

    def resident_tokens(self, req: Request) -> int:
        return self.token_counts.get(req.id, 0)

    def stats(self) -> Dict[str, float]:
        """Prefix-sharing and occupancy counters (docs/MEMORY.md)."""
        lookups = self.shared_hits + self.shared_misses
        return {"num_blocks": self.mc.num_blocks,
                "peak_used": self.peak_used,
                "shared_hits": self.shared_hits,
                "shared_misses": self.shared_misses,
                "prefix_hit_rate": self.shared_hits / lookups
                if lookups else 0.0,
                "shared_tokens": self.shared_tokens,
                "cow_copies": self.cow_copies}
