"""Cluster-wide remote/object KV store — the third cache tier
(docs/ROUTING.md).

Sits under host DRAM in the hierarchy device HBM -> host DRAM
(``SwapManager``) -> remote store: a capacity-bounded LRU shared by
every worker, in the LMCache / Mooncake mold.  Two kinds of entries
live here:

* **prefix publications** (``("prefix", prefix_id)``) — shared-prefix
  KV that disagg prefill workers (and peer-fetch write-through)
  publish so other workers retrieve instead of re-prefilling.  These
  are cache entries: evictable under LRU pressure, and the prefix
  registry / fetch path must tolerate a miss.
* **swap spill** (``("swap", request_id)``) — preemption victims that
  overflowed a worker's host tier.  These hold the only copy of live
  prefill progress, so they are *pinned*: LRU never evicts them; they
  are freed explicitly via :meth:`drop` on swap-in / release.  If a
  pinned entry does not fit even after evicting every unpinned entry,
  the put fails and the caller falls back to recompute — the same
  no-lost-progress contract as the host tier.

Retrieve cost is priced per accessing worker as
``remote_setup + bytes / remote_bw`` from its ``HardwareSpec`` (the
object store is bandwidth- not block-granular: one GET per object), so
the store itself only does byte accounting.  Unlike worker state, the
store survives worker death — that is what makes the disagg
publish-then-fetch path serviceable after the prefill worker fails.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class RemoteKVSpec:
    """Enables the remote tier when set on ``SimSpec.remote_kv``
    (``None`` keeps the simulator byte-identical to the two-tier
    model)."""
    #: object-store capacity shared by the whole cluster
    capacity_bytes: float = 1e12
    #: override ``HardwareSpec.remote_bw`` for every worker (None =
    #: per-worker hardware value)
    bw: Optional[float] = None
    #: override ``HardwareSpec.remote_setup`` likewise
    setup_latency: Optional[float] = None
    #: disagg prefill hand-off (``Simulation.migrate``) and peer-fetch
    #: write-through publish shared prefixes into the store
    publish_prefixes: bool = True


class RemoteKVStore:
    """Capacity-bounded LRU object store keyed by opaque tuples."""

    def __init__(self, capacity_bytes: float):
        self.capacity_bytes = float(capacity_bytes)
        # key -> (tokens, nbytes, pinned); dict order is LRU order
        # (oldest first) maintained by re-insertion on touch
        self._entries: Dict[Tuple, Tuple[int, float, bool]] = {}
        self.used_bytes = 0.0
        self.peak_used_bytes = 0.0
        self.stores = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.rejects = 0

    # -- capacity -----------------------------------------------------
    def _evictable_bytes(self) -> float:
        return sum(nb for _, nb, pinned in self._entries.values()
                   if not pinned)

    def can_fit(self, nbytes: float) -> bool:
        """Would a put of ``nbytes`` succeed (evicting unpinned LRU
        entries if needed)?"""
        free = self.capacity_bytes - self.used_bytes
        return nbytes <= free + self._evictable_bytes()

    def _make_room(self, nbytes: float) -> bool:
        if nbytes > self.capacity_bytes:
            return False
        while self.used_bytes + nbytes > self.capacity_bytes:
            victim = next((k for k, (_, _, pinned) in
                           self._entries.items() if not pinned), None)
            if victim is None:
                return False
            _, nb, _ = self._entries.pop(victim)
            self.used_bytes -= nb
            self.evictions += 1
        return True

    # -- object API ---------------------------------------------------
    def put(self, key: Tuple, tokens: int, nbytes: float, *,
            pinned: bool = False) -> bool:
        """Store (or refresh) an object; returns False when it cannot
        fit without evicting a pinned entry."""
        old = self._entries.pop(key, None)
        if old is not None:
            self.used_bytes -= old[1]
        if not self._make_room(nbytes):
            self.rejects += 1
            return False
        self._entries[key] = (tokens, nbytes, pinned)
        self.used_bytes += nbytes
        self.peak_used_bytes = max(self.peak_used_bytes, self.used_bytes)
        self.stores += 1
        return True

    def get(self, key: Tuple) -> Optional[Tuple[int, float]]:
        """(tokens, nbytes) on hit — touches LRU order — else None."""
        ent = self._entries.pop(key, None)
        if ent is None:
            self.misses += 1
            return None
        self._entries[key] = ent            # re-insert = most recent
        self.hits += 1
        return ent[0], ent[1]

    def has(self, key: Tuple) -> bool:
        return key in self._entries

    def drop(self, key: Tuple) -> int:
        """Free an object (idempotent); returns the tokens it held."""
        ent = self._entries.pop(key, None)
        if ent is None:
            return 0
        self.used_bytes -= ent[1]
        return ent[0]

    # -- reporting ----------------------------------------------------
    def stats(self) -> dict:
        return {"capacity_bytes": self.capacity_bytes,
                "used_bytes": self.used_bytes,
                "peak_used_bytes": self.peak_used_bytes,
                "n_entries": len(self._entries),
                "stores": self.stores,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "rejects": self.rejects}
