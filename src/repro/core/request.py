"""Request objects and lifecycle states."""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import List, Optional


class State(enum.Enum):
    QUEUED = "queued"          # at global scheduler
    WAITING = "waiting"        # in a worker's local queue
    PREFILL = "prefill"
    MIGRATING = "migrating"    # KV in flight between workers (disagg)
    DECODE = "decode"
    PREEMPTED = "preempted"    # swapped out / pending recompute
    FINISHED = "finished"
    REJECTED = "rejected"      # dropped by admission control (429)


@dataclass(eq=False)
class Request:
    # eq=False: identity semantics, so hot-path ``in``/``remove`` on
    # worker queues are pointer comparisons instead of a 25-field
    # structural compare (which also mis-identifies distinct requests
    # that happen to share every field value)
    id: int
    arrival_time: float
    prompt_len: int
    output_len: int                      # target new tokens (incl. first)

    # multi-round conversation support
    session_id: Optional[int] = None
    round_idx: int = 0
    history_len: int = 0                 # tokens of prior rounds (KV reusable)

    # heterogeneous fleet serving (docs/HETEROGENEITY.md): the model this
    # request must run on.  None means "the simulation's default arch";
    # the dispatcher stamps the concrete name at arrival so routing and
    # per-model metrics never see the sentinel
    model: Optional[str] = None

    # multi-tenant QoS (repro.core.tenancy)
    tenant_id: Optional[str] = None
    priority: int = 0                    # tier priority (larger = higher)
    weight: float = 1.0                  # WFQ share
    vft: float = 0.0                     # virtual finish time (WFQ tag)

    # hierarchical KV memory (repro.core.mem, docs/MEMORY.md): requests
    # with the same prefix_id share their first prefix_len prompt tokens
    # (a system prompt); the BlockManager content-keys those blocks
    prefix_id: Optional[int] = None
    prefix_len: int = 0

    # runtime state
    state: State = State.QUEUED
    tokens_generated: int = 0
    cached_len: int = 0                  # prefix KV reused from a pool
    prefill_done_len: int = 0            # chunked prefill progress
    worker_id: Optional[int] = None
    preempt_count: int = 0

    # speculative decoding (repro.core.specdecode)
    spec_steps: int = 0                  # verify steps taken
    spec_tokens: int = 0                 # tokens emitted by spec steps
    draft_proposed: int = 0              # draft tokens proposed (Σ K)
    draft_accepted: int = 0              # draft tokens accepted by target

    # hierarchical KV memory counters (docs/MEMORY.md)
    shared_tokens: int = 0               # tokens backed by shared blocks
    cow_copies: int = 0                  # copy-on-write block copies
    swapped_tokens: int = 0              # KV tokens parked in host DRAM
    swap_out_count: int = 0              # preemptions taken in swap mode
    swap_in_count: int = 0               # host->device restores

    # cache-aware routing (docs/ROUTING.md): the prefix_affinity router
    # stamps a one-shot hint — "worker fetch_src holds fetch_tokens of
    # your prefix" — that the target worker's admission consumes via
    # Simulation.fetch_prefix; the counters record consummated fetches
    fetch_src: Optional[int] = field(default=None, repr=False)
    fetch_tokens: int = field(default=0, repr=False)
    fetch_count: int = 0                 # peer/remote KV fetches taken
    fetched_tokens: int = 0              # prefix tokens obtained by fetch

    #: latency-attribution banks (repro.obs.attribution.RequestObs),
    #: attached lazily by the observability layer when
    #: SimSpec(obs=ObsSpec(attribution=True)); None otherwise
    obs: Optional[object] = field(default=None, repr=False)

    # incremental worker-load accounting (core.worker): the exact amount
    # this request last charged against its worker's waiting/running
    # load, so dequeue/finish can reverse it in O(1)
    _load_charge: int = field(default=0, repr=False)
    _run_charge: int = field(default=0, repr=False)

    # timestamps
    t_admitted: Optional[float] = None   # released by admission control
    t_first_token: Optional[float] = None
    t_finish: Optional[float] = None
    token_times: List[float] = field(default_factory=list)

    @property
    def context_len(self) -> int:
        """Tokens whose KV must be resident to decode the next token."""
        return self.prompt_len + self.tokens_generated

    @property
    def prefill_target(self) -> int:
        """Tokens that must be prefilled before decode: the prompt, plus
        previously generated tokens after a recompute-preemption (vLLM
        recompute mode re-prefills them as part of the context)."""
        base = self.prompt_len
        if self.prefill_done_len < self.prompt_len and self.tokens_generated:
            base += self.tokens_generated
        return base

    @property
    def remaining_prefill(self) -> int:
        # open-coded prefill_target minus max(cached, done): this is the
        # hottest property in the scheduler loop (called once per running
        # request per iteration)
        done = self.prefill_done_len
        base = self.prompt_len
        if done < base and self.tokens_generated:
            base += self.tokens_generated
        if self.cached_len > done:
            done = self.cached_len
        rem = base - done
        return rem if rem > 0 else 0

    @property
    def finished(self) -> bool:
        return self.tokens_generated >= self.output_len

    @property
    def rejected(self) -> bool:
        return self.state == State.REJECTED

    # -- metrics ---------------------------------------------------------
    @property
    def latency(self) -> Optional[float]:
        return None if self.t_finish is None \
            else self.t_finish - self.arrival_time

    @property
    def normalized_latency(self) -> Optional[float]:
        """vLLM's metric: end-to-end latency / output length."""
        lat = self.latency
        return None if lat is None else lat / max(1, self.output_len)

    @property
    def ttft(self) -> Optional[float]:
        return None if self.t_first_token is None \
            else self.t_first_token - self.arrival_time

    @property
    def queue_delay(self) -> Optional[float]:
        """Time held at the admission gateway (rate limit / inflight cap)."""
        return None if self.t_admitted is None \
            else self.t_admitted - self.arrival_time

    @property
    def acceptance_rate(self) -> Optional[float]:
        """Fraction of draft tokens the target accepted (spec decode)."""
        if self.draft_proposed == 0:
            return None
        return self.draft_accepted / self.draft_proposed

    @property
    def max_tpot(self) -> Optional[float]:
        """Max inter-token interval (mTPOT) over the decode phase."""
        if len(self.token_times) < 2:
            return 0.0 if self.token_times else None
        return max(b - a for a, b in zip(self.token_times,
                                         self.token_times[1:]))

    def meets_slo(self, ttft_slo: float, mtpot_slo: float) -> bool:
        if self.t_finish is None:
            return False
        if self.ttft is not None and ttft_slo and self.ttft > ttft_slo:
            return False
        if mtpot_slo:
            mt = self.max_tpot
            if mt is not None and mt > mtpot_slo:
                return False
        return True
