"""Local (per-worker) schedulers: batching policies for one accelerator.

Citations: static vs continuous batching follows Orca/vLLM (paper
Fig. 8); chunked prefill is Sarathi-style; speculative-decode budgeting
follows Leviathan et al. 2023 (see repro.core.specdecode).

A policy builds an ``IterationPlan`` from the worker's waiting queue,
running set and memory manager — the full system state, per the paper's
"scheduler function API provides all system information".
"""
from __future__ import annotations

from dataclasses import dataclass, field, fields
from typing import List, Optional, Tuple

from repro.core.request import Request, State


@dataclass
class IterationPlan:
    #: (req, chunk_len, ctx_before) — prompt tokens computed this iteration
    prefill: List[Tuple[Request, int, int]] = field(default_factory=list)
    decode: List[Request] = field(default_factory=list)
    #: requests decoding speculatively this iteration (draft + verify);
    #: disjoint from ``decode``
    spec_decode: List[Request] = field(default_factory=list)
    admitted: List[Request] = field(default_factory=list)
    preempted: List[Request] = field(default_factory=list)
    retrieve_latency: float = 0.0        # memory-pool fetches this iter
    #: PCIe time of this iteration's KV swap-outs/-ins (docs/MEMORY.md);
    #: billed serially into the iteration by the worker
    swap_latency: float = 0.0
    #: cross-worker / remote-tier prefix-KV fetch time this iteration
    #: (docs/ROUTING.md); billed serially like swap_latency
    fetch_latency: float = 0.0
    #: pipeline-parallel accounting (docs/PARALLELISM.md), filled by the
    #: worker after costing: fill/drain bubble time and stage-boundary
    #: p2p activation-transfer time of this iteration
    pp_bubble: float = 0.0
    comm_latency: float = 0.0
    #: speculative-decode draft-model time of this iteration, filled by
    #: the worker after costing (slowdown-scaled like the billed time) —
    #: the draft/verify split the attribution layer reports
    draft_latency: float = 0.0

    @property
    def empty(self) -> bool:
        # preempted counts as work: the worker must still apply the
        # eviction side effects (state change, re-enqueue) even when a
        # full-eviction cascade leaves nothing to compute — otherwise
        # victims strand in ``running`` with their KV already freed
        return not (self.prefill or self.decode or self.spec_decode
                    or self.preempted)


class LocalScheduler:
    """Override ``plan``.  Subclasses may keep state (paper: "stateful")."""

    def plan(self, worker) -> IterationPlan:   # worker: core.worker.Worker
        raise NotImplementedError


# Waiting-queue protocol with FIFO fallback: full workers (core.worker,
# serving.engine) expose tenant-aware ordering; minimal stub workers
# (tests, user schedulers) only need ``waiting``/``running``/``mem``.
def _next_waiting(worker) -> Optional[Request]:
    get = getattr(worker, "next_waiting", None)
    if get is not None:
        return get()
    return worker.waiting[0] if worker.waiting else None


def _pop_waiting(worker, req: Request) -> None:
    pop = getattr(worker, "pop_waiting", None)
    if pop is not None:
        pop(req)
    else:
        worker.waiting.remove(req)


def _victim_sort_key(worker):
    f = getattr(worker, "victim_sort_key", None)
    return f() if f is not None else (lambda r: (r.arrival_time, r.id))


def _preempt(worker, victim: Request, plan: IterationPlan) -> None:
    """Evict ``victim``'s KV from the device.  In swap mode
    (``worker.swap`` set) the KV parks in host DRAM over the PCIe
    channel and prefill progress survives; when the host tier is full —
    or in recompute mode — the KV is discarded and the victim
    re-prefills on re-admission.  The swap covers the full resident
    context, vLLM-style (no dedup against prefix blocks other holders
    keep resident — see docs/MEMORY.md), so a swapped victim can always
    be restored regardless of what its prefix sharers do meanwhile."""
    mem = worker.mem
    tokens = mem.resident_tokens(victim)
    mem.free(victim)
    swap = getattr(worker, "swap", None)
    if swap is not None and tokens > 0 and swap.can_swap_out(tokens):
        plan.swap_latency += swap.swap_out(victim, tokens)
        victim.swapped_tokens = tokens
        victim.swap_out_count += 1
    else:
        if swap is not None:
            swap.fallbacks += 1
        victim.prefill_done_len = 0
        victim.cached_len = 0
    victim.preempt_count += 1
    plan.preempted.append(victim)


def _prefill_sort_key(worker):
    """Order competing prefills inside one iteration: FIFO by default,
    discipline order (priority / virtual finish time) when the worker
    has a tenant-aware queue discipline."""
    disc = getattr(worker, "discipline", None)
    if disc is None:
        return lambda r: (r.arrival_time, r.id)
    return disc.admit_key(worker.env.now if hasattr(worker, "env")
                          else getattr(worker, "clock", 0.0))


@dataclass
class StaticBatching(LocalScheduler):
    """Classic static batching: fill a batch, run it to completion, only
    then admit the next batch (the paper's Fig. 8 upper timeline)."""

    max_batch: int = 32

    def plan(self, worker) -> IterationPlan:
        plan = IterationPlan()
        running = [r for r in worker.running if not r.finished]
        if not running:
            # batch finished: admit a fresh one (reserving room for each
            # request's full output — static batching predates paging)
            while worker.waiting and len(plan.admitted) < self.max_batch:
                req = _next_waiting(worker)
                ctx = max(1, req.context_len)
                if not worker.mem.can_allocate(
                        ctx, headroom_tokens=req.output_len, req=req):
                    break
                _pop_waiting(worker, req)
                worker.mem.allocate(req, ctx, reserve=req.output_len)
                plan.admitted.append(req)
            running = plan.admitted
        for r in running:
            if r.remaining_prefill > 0:
                plan.prefill.append((r, r.remaining_prefill,
                                     max(r.cached_len, r.prefill_done_len)))
            else:
                plan.decode.append(r)
        # static batching: prefill everything first, then pure decode
        if plan.prefill:
            plan.decode = []
        return plan


@dataclass
class ContinuousBatching(LocalScheduler):
    """vLLM-style continuous batching with optional chunked prefill.

    * admits new requests whenever batch slots + memory allow, respecting
      the ``max_mem_ratio`` admission cap (Fig. 10's knob: the watermark
      lives in the worker's MemoryConfig),
    * prefill-prioritized iterations (vLLM v0) unless ``chunked_prefill``
      mixes one prefill chunk with running decodes (Sarathi-style —
      beyond-paper option),
    * preempts the newest running request on decode OOM — discarding its
      KV (recompute mode) or parking it in host DRAM when the worker
      carries a ``SwapManager`` (swap mode, docs/MEMORY.md).
    """

    max_batch: int = 256
    max_batched_tokens: int = 2048
    chunked_prefill: bool = False
    prefill_chunk: int = 512

    def plan(self, worker) -> IterationPlan:
        plan = IterationPlan()
        mem = worker.mem

        # ---- admission (swap-aware: see docs/MEMORY.md) ----------------
        swap = getattr(worker, "swap", None)
        n_running = len(worker.running)
        while worker.waiting and n_running + len(plan.admitted) < self.max_batch:
            req = _next_waiting(worker)
            need = max(1, req.context_len)
            swapped = swap is not None and swap.holds(req)
            if req.cached_len == 0 and not swapped \
                    and worker.pool is not None and req.history_len > 0:
                reuse, lat = worker.pool.lookup(req)
                req.cached_len = reuse
                plan.retrieve_latency = max(plan.retrieve_latency, lat)
            if not mem.can_allocate(need, respect_watermark=True, req=req):
                break
            _pop_waiting(worker, req)
            mem.allocate(req, need)
            if swapped:
                # restore the parked KV before the step; decode resumes
                # where it left off (no re-prefill)
                plan.swap_latency += swap.swap_in(req)
                req.swap_in_count += 1
                req.swapped_tokens = 0
            elif req.fetch_src is not None:
                # cache-aware routing stamped a fetch hint (docs/
                # ROUTING.md): pull the shared prefix from the peer (or
                # the remote tier) instead of re-prefilling; the cluster
                # prices it and may decline at the break-even point
                cluster = getattr(worker, "cluster", None)
                if cluster is not None:
                    plan.fetch_latency += cluster.fetch_prefix(worker, req)
                req.fetch_src = None
            plan.admitted.append(req)

        # MIGRATING requests' KV is in flight to another worker: they
        # stay in ``running`` until the transfer completes but must not
        # be planned (their blocks are released mid-iteration)
        # single pass: ``remaining_prefill`` is a non-trivial property,
        # evaluate it once per request per iteration
        prefills = []
        decodes = []
        for r in worker.running:
            if r.finished or r.state is State.MIGRATING:
                continue
            (prefills if r.remaining_prefill > 0 else decodes).append(r)
        for r in plan.admitted:
            (prefills if r.remaining_prefill > 0 else decodes).append(r)

        # ---- build the iteration ---------------------------------------
        budget = self.max_batched_tokens
        if prefills and not self.chunked_prefill:
            # prefill-prioritized iteration (no decodes mixed in)
            for r in sorted(prefills, key=_prefill_sort_key(worker)):
                chunk = min(r.remaining_prefill, budget)
                if chunk <= 0:
                    break
                plan.prefill.append(
                    (r, chunk, max(r.cached_len, r.prefill_done_len)))
                budget -= chunk
            return plan

        if self.chunked_prefill and prefills:
            budget -= len(decodes)        # decodes cost 1 token each
            r = min(prefills, key=_prefill_sort_key(worker))
            chunk = min(r.remaining_prefill, self.prefill_chunk,
                        max(0, budget))
            if chunk > 0:
                plan.prefill.append(
                    (r, chunk, max(r.cached_len, r.prefill_done_len)))

        # ---- decodes, preempting on OOM -------------------------------
        # Victim order comes from the worker's queue discipline: FIFO
        # evicts the newest arrival (seed behaviour); tenant-aware
        # disciplines evict the lowest tier / least-entitled first, so
        # low-tier requests yield KV blocks to high-tier ones.  The
        # eviction itself follows the worker's preemption mode: swap to
        # host DRAM when a SwapManager is attached (falling back to
        # recompute if the host tier is full), discard otherwise.
        decodes.sort(key=_victim_sort_key(worker))
        survivors: List[Request] = list(decodes)

        # check appends feasible (incl. copy-on-write copies of shared
        # prefix blocks); evict newest until they are
        def total_new_blocks(reqs):
            return sum(mem.growth_blocks(r, 1) for r in reqs
                       if mem.resident(r))

        while survivors and total_new_blocks(survivors) > mem.num_free:
            victim = survivors.pop()       # newest arrival
            if victim in plan.admitted:
                plan.admitted.remove(victim)
            _preempt(worker, victim, plan)
        plan.decode = survivors
        self._assign_speculative(worker, plan)
        return plan

    def _assign_speculative(self, worker, plan: IterationPlan) -> None:
        """Upgrade planned decodes to speculative mode where they fit.

        Each speculative request bills K+1 verify tokens against
        ``max_batched_tokens`` (a normal decode bills 1) and may need
        extra KV blocks for its draft window.  Requests that don't fit
        the token budget or the remaining free blocks stay on the normal
        decode path, so mixed spec/non-spec batches schedule correctly
        and speculation never triggers a preemption by itself."""
        spec_cfg = getattr(worker, "spec_decode", None)
        if spec_cfg is None or not plan.decode:
            return
        mem = worker.mem
        k1 = spec_cfg.verify_tokens
        budget = self.max_batched_tokens \
            - sum(c for _, c, _ in plan.prefill) - len(plan.decode)
        # blocks already committed to the +1 growth of every planned
        # decode (growth_blocks includes any copy-on-write copy)
        committed = sum(mem.growth_blocks(r, 1)
                        for r in plan.decode if mem.resident(r))
        free = mem.num_free - committed
        chosen = []
        for r in plan.decode:              # already in discipline order
            if budget < k1 - 1:
                break
            if not mem.resident(r):
                continue
            extra = mem.growth_blocks(r, k1) - mem.growth_blocks(r, 1)
            if extra > free:
                continue
            free -= extra
            budget -= k1 - 1
            chosen.append(r)
        if chosen:
            ids = {r.id for r in chosen}
            plan.spec_decode = chosen
            plan.decode = [r for r in plan.decode if r.id not in ids]


#: every accepted ``SimSpec.local_policy`` name; scripts/check_docs.py
#: asserts each key is documented in docs/POLICIES.md
LOCAL_POLICIES = {"static": StaticBatching, "continuous": ContinuousBatching}


def make_local_scheduler(kind: str, **kw) -> LocalScheduler:
    try:
        cls = LOCAL_POLICIES[kind]
    except KeyError:
        raise ValueError(f"unknown local scheduler {kind!r}; "
                         f"have {sorted(LOCAL_POLICIES)}")
    # each policy takes the subset of SimSpec batching knobs it declares
    allowed = {f.name for f in fields(cls)}
    return cls(**{k: v for k, v in kw.items() if k in allowed})
