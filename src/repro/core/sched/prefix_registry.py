"""Cluster-wide prefix registry for cache-aware routing
(docs/ROUTING.md).

Tracks which workers hold KV for which shared ``prefix_id``s — the
record book the ``prefix_affinity`` global policy consults before
dispatch, in the llm-d ext_proc mold: the *router* records where each
prefix was sent (publication happens at assign time, off the worker
hot loop), and two mechanisms keep it honest about cache mortality:

* **staleness (TTL)** — an entry not re-touched within ``ttl``
  simulated seconds is treated as evicted and pruned lazily at lookup;
  a worker that stopped seeing a prefix has almost certainly recycled
  its blocks.
* **invalidation** — ``FaultInjector`` calls
  :meth:`invalidate_worker` when a worker dies, so registry entries
  die with the worker instead of routing traffic at a ghost.

Entries are hints, never guarantees: a stale-but-fresh-looking entry
just means the request re-prefills at the target (exactly what a
prefix-blind router would have done), so correctness never depends on
the registry being right.  A bounded LRU over prefix ids
(``max_prefixes``) keeps the registry itself from growing without
bound on million-prefix workloads.
"""
from __future__ import annotations

from typing import Dict, Tuple


class PrefixRegistry:
    """prefix_id -> {worker id -> (tokens held, last-touch time)}."""

    def __init__(self, env=None, *, ttl: float = 30.0,
                 max_prefixes: int = 65536):
        self.env = env                  # sim clock source (None in tests)
        self.ttl = float(ttl)
        self.max_prefixes = int(max_prefixes)
        # dict order over prefix ids is LRU order (oldest first),
        # maintained by re-insertion on publish/lookup
        self._entries: Dict[int, Dict[int, Tuple[int, float]]] = {}
        self.publishes = 0
        self.invalidations = 0
        self.expirations = 0
        self.evictions = 0

    @property
    def now(self) -> float:
        return self.env.now if self.env is not None else 0.0

    def publish(self, prefix_id: int, wid: int, tokens: int) -> None:
        """Record that worker ``wid`` (now) holds ``tokens`` of KV for
        ``prefix_id``."""
        holders = self._entries.pop(prefix_id, None)
        if holders is None:
            holders = {}
            while len(self._entries) >= self.max_prefixes:
                self._entries.pop(next(iter(self._entries)))
                self.evictions += 1
        holders[wid] = (max(tokens, holders.get(wid, (0, 0.0))[0]),
                        self.now)
        self._entries[prefix_id] = holders
        self.publishes += 1

    def holders(self, prefix_id: int) -> Dict[int, int]:
        """Fresh holders of ``prefix_id`` as {wid: tokens}; prunes
        TTL-expired entries as a side effect."""
        holders = self._entries.get(prefix_id)
        if not holders:
            return {}
        cutoff = self.now - self.ttl
        stale = [w for w, (_, t) in holders.items() if t < cutoff]
        for w in stale:
            del holders[w]
            self.expirations += 1
        if not holders:
            del self._entries[prefix_id]
            return {}
        return {w: tok for w, (tok, _) in holders.items()}

    def tokens_at(self, prefix_id: int, wid: int) -> int:
        """Fresh token count ``wid`` holds for ``prefix_id`` (0 if
        absent or expired)."""
        return self.holders(prefix_id).get(wid, 0)

    def touch(self, prefix_id: int, wid: int) -> None:
        """Refresh the TTL of an entry that just served a hit."""
        holders = self._entries.get(prefix_id)
        if holders and wid in holders:
            holders[wid] = (holders[wid][0], self.now)

    def invalidate_worker(self, wid: int) -> int:
        """Drop every entry held by ``wid`` (worker death); returns the
        number of prefixes invalidated."""
        n = 0
        dead = []
        for pid, holders in self._entries.items():
            if holders.pop(wid, None) is not None:
                n += 1
                if not holders:
                    dead.append(pid)
        for pid in dead:
            del self._entries[pid]
        self.invalidations += n
        return n

    def n_entries(self) -> int:
        return sum(len(h) for h in self._entries.values())

    def stats(self) -> dict:
        return {"registry_prefixes": len(self._entries),
                "registry_entries": self.n_entries(),
                "registry_publishes": self.publishes,
                "registry_invalidations": self.invalidations,
                "registry_expirations": self.expirations,
                "registry_evictions": self.evictions}
