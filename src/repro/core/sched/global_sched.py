"""Global (cluster) schedulers: request -> worker dispatch (paper §III-A).

Policies receive the full worker list (hardware type, role flags, queue
and memory state — "all system information") and may keep their own state
(the record-book pattern from the paper).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

from repro.core.request import Request


class GlobalScheduler:
    """Base class for cluster-level dispatch policies.

    Subclasses override ``assign`` (and optionally ``reassign`` for the
    disaggregated prefill→decode hand-off and ``discipline`` for
    worker-queue ordering); they may keep internal state — the paper's
    "record book" pattern.
    """

    def assign(self, req: Request, workers: List) -> int:
        """Pick the worker for a new request (prefill side)."""
        raise NotImplementedError

    def reassign(self, req: Request, workers: List) -> int:
        """Pick the decode worker after prefill hand-off (disagg). The
        default keeps the request where it is."""
        return req.worker_id

    def discipline(self):
        """Queue discipline the workers should order their waiting queues
        by, or None for FIFO.  Tenant-aware policies override this so the
        cluster-wide ordering (repro.core.tenancy.qos) stays consistent
        with the dispatch-side record book."""
        return None

    def eligible_for(self, req: Request, workers: List) -> List:
        """Workers this policy would ever consider for ``req`` — the
        dispatcher parks a request when none of them is alive.  The
        default (every worker) keeps model-blind policies exactly as
        they were; model-aware policies narrow it to the request's
        hosts (docs/HETEROGENEITY.md)."""
        return workers

    # ---- observability (repro.obs) -----------------------------------
    def observe_assign(self, req: Request, wid: int) -> None:
        """Record one dispatch decision in a per-worker record book the
        time-series recorder samples (load-balance observability).  The
        Simulation calls this only when observability is enabled, so
        the default dispatch path stays untouched."""
        book = getattr(self, "_assign_book", None)
        if book is None:
            book = self._assign_book = {}
        book[wid] = book.get(wid, 0) + 1

    def assign_counts(self) -> Dict[int, int]:
        """Cumulative dispatches per worker id (empty when observability
        never recorded any)."""
        return dict(getattr(self, "_assign_book", None) or {})


def _eligible(workers, *, prefill=None, decode=None):
    out = []
    for w in workers:
        if not w.alive or getattr(w, "draining", False):
            # draining (repro.core.faults): finishes its queue but
            # takes no new dispatches — like dead for placement
            continue
        if prefill is not None and w.run_prefill != prefill:
            continue
        if decode is not None and w.run_decode != decode:
            continue
        out.append(w)
    # role/drain fallback: with nothing eligible, any live worker beats
    # dropping the request (a fully-draining cluster still serves)
    return out or [w for w in workers if w.alive]


@dataclass
class RoundRobin(GlobalScheduler):
    """Cycle new requests over prefill-capable workers in worker order —
    the stateless baseline every study compares against."""

    _next: int = 0

    def assign(self, req, workers):
        ws = _eligible(workers, prefill=True)
        w = ws[self._next % len(ws)]
        self._next += 1
        return w.wid


@dataclass
class LeastLoaded(GlobalScheduler):
    """Dispatch to the worker with the fewest queued+running tokens —
    also the straggler mitigation policy: a slowed worker drains and
    stops receiving new work."""

    def assign(self, req, workers):
        ws = _eligible(workers, prefill=True)
        return min(ws, key=lambda w: (w.load_tokens(), w.wid)).wid

    def reassign(self, req, workers):
        ws = _eligible(workers, decode=True)
        return min(ws, key=lambda w: (w.load_tokens(), w.wid)).wid


@dataclass
class DisaggPD(GlobalScheduler):
    """Disaggregated prefill/decode: new requests round-robin over
    prefill workers; after the first token they move to the least-loaded
    decode worker (the paper's Fig. 3 user-defined example)."""

    _next_p: int = 0

    def assign(self, req, workers):
        ws = _eligible(workers, prefill=True)
        w = ws[self._next_p % len(ws)]
        self._next_p += 1
        return w.wid

    def reassign(self, req, workers):
        ws = _eligible(workers, decode=True)
        return min(ws, key=lambda w: (w.load_tokens(), w.wid)).wid


@dataclass
class SessionAffinity(GlobalScheduler):
    """Multi-round conversations stick to the worker that holds their KV
    in the pool tier (locality-aware, MemServe-style)."""

    fallback: GlobalScheduler = field(default_factory=LeastLoaded)
    _session_map: Dict[int, int] = field(default_factory=dict)

    def assign(self, req, workers):
        if req.session_id is not None and req.session_id in self._session_map:
            wid = self._session_map[req.session_id]
            if any(w.wid == wid and w.alive for w in workers):
                return wid
        wid = self.fallback.assign(req, workers)
        if req.session_id is not None:
            self._session_map[req.session_id] = wid
        return wid

    def reassign(self, req, workers):
        return self.fallback.reassign(req, workers)


@dataclass
class HeterogeneityAware(GlobalScheduler):
    """Weights prefill dispatch by FLOPs and decode dispatch by memory
    bandwidth — the cross-stack policy the paper motivates for clusters
    of mixed accelerators (A100 + PIM, Fig. 12)."""

    def assign(self, req, workers):
        ws = _eligible(workers, prefill=True)
        return min(ws, key=lambda w:
                   (w.load_tokens() / max(w.hw.flops, 1.0), w.wid)).wid

    def reassign(self, req, workers):
        ws = _eligible(workers, decode=True)
        return min(ws, key=lambda w:
                   (w.load_tokens() / max(w.hw.mem_bw, 1.0), w.wid)).wid


@dataclass
class WeightedFairQueuing(GlobalScheduler):
    """Weighted fair queuing over tenants via virtual finish times
    (start-time fair queuing variant).

    Each request is tagged ``vft = max(V, last_vft[tenant]) +
    cost/weight`` at dispatch; workers admit waiting requests in vft
    order (WFQDiscipline), so backlogged tenants receive token service
    proportional to their weights.  The virtual clock ``V`` advances to
    the start tag of each request entering service, which denies idle
    tenants retroactive credit (a returning tenant resumes at the
    current frontier instead of monopolizing the cluster)."""

    fallback: GlobalScheduler = field(default_factory=LeastLoaded)
    _v: float = 0.0
    _last_vft: Dict[str, float] = field(default_factory=dict)

    def assign(self, req, workers):
        if req.vft == 0.0:
            # stamp exactly once: failure redispatch sends orphans back
            # through assign(), which must not re-charge the tenant's
            # virtual clock for work it was already billed for
            tid = req.tenant_id or "_default"
            cost = float(req.prompt_len + req.output_len)
            start = max(self._v, self._last_vft.get(tid, 0.0))
            req.vft = start + cost / max(req.weight, 1e-9)
            self._last_vft[tid] = req.vft
        return self.fallback.assign(req, workers)

    def reassign(self, req, workers):
        return self.fallback.reassign(req, workers)

    def on_service_start(self, req) -> None:
        cost = float(req.prompt_len + req.output_len)
        self._v = max(self._v, req.vft - cost / max(req.weight, 1e-9))

    def discipline(self):
        from repro.core.tenancy.qos import WFQDiscipline
        return WFQDiscipline(self)


@dataclass
class PriorityAging(GlobalScheduler):
    """Strict priority across tenant tiers with linear aging: workers
    admit the highest effective priority first, where effective priority
    grows by ``aging_rate`` points per second of queueing (starvation
    guard).  Under memory pressure the preemption path evicts the lowest
    tier first, so low-tier requests yield KV blocks to high-tier ones."""

    aging_rate: float = 0.0
    fallback: GlobalScheduler = field(default_factory=LeastLoaded)

    def assign(self, req, workers):
        return self.fallback.assign(req, workers)

    def reassign(self, req, workers):
        return self.fallback.reassign(req, workers)

    def discipline(self):
        from repro.core.tenancy.qos import PriorityAgingDiscipline
        return PriorityAgingDiscipline(self.aging_rate)


class ModelRouted(GlobalScheduler):
    """Model-aware routing for heterogeneous multi-model fleets
    (docs/HETEROGENEITY.md): restrict dispatch to the workers hosting
    the request's model, then delegate the choice among them to any
    inner policy.

    ``inner`` is a policy name (``make_global_scheduler`` spelling) or
    instance; it sees only the host subset, so the role/drain fallback
    in ``_eligible`` can never leak a request onto a worker serving a
    different model.  A worker whose ``model`` attribute is unset hosts
    everything (homogeneous fleets — where this wrapper is a byte-exact
    pass-through of its inner policy)."""

    def __init__(self, inner="least_loaded", **inner_kw):
        if isinstance(inner, str):
            inner = make_global_scheduler(inner, **inner_kw)
        elif inner_kw:
            raise ValueError("inner_kw only applies when inner is a name")
        self.inner = inner

    @staticmethod
    def _hosts(req, workers):
        model = getattr(req, "model", None)
        if model is None:
            return workers
        out = [w for w in workers
               if getattr(w, "model", None) in (None, model)]
        if not out:
            raise ValueError(
                f"no worker hosts model {model!r} (request {req.id})")
        return out

    def eligible_for(self, req, workers):
        return self._hosts(req, workers)

    def assign(self, req, workers):
        return self.inner.assign(req, self._hosts(req, workers))

    def reassign(self, req, workers):
        return self.inner.reassign(req, self._hosts(req, workers))

    def discipline(self):
        return self.inner.discipline()

    def on_service_start(self, req) -> None:
        hook = getattr(self.inner, "on_service_start", None)
        if hook is not None:
            hook(req)


class PrefixAffinity(GlobalScheduler):
    """Cache-aware routing (docs/ROUTING.md): send a request to the
    worker already holding its longest shared prefix, llm-d style.

    Consults a cluster-wide :class:`~repro.core.sched.prefix_registry.
    PrefixRegistry` (attached by the ``Simulation``, which also ages and
    invalidates entries so the router never assumes immortal cache).
    Requests without a ``prefix_id`` — and prefixes nobody holds — fall
    through to the inner policy untouched; among equally-warm holders
    the inner policy breaks the tie, so ``least_loaded`` inside gives a
    load-aware tiebreak for free.  When every warm worker is overloaded
    (``overload_factor`` x the lightest eligible worker), the request
    routes by the inner policy instead and — if ``fetch_on_overload`` —
    carries a fetch hint so the target worker pulls the prefix from the
    warm peer over the ``SimSpec.kv_link`` rather than re-prefilling
    (the cross-worker KV transfer, priced by ``Simulation.
    fetch_prefix`` with a fetch-vs-recompute break-even).

    Wrappable like ``model_routed`` and composes with it in either
    direction: ``inner`` is a policy name or instance."""

    def __init__(self, inner="least_loaded", *, registry=None,
                 registry_ttl: float = 30.0, overload_factor: float = 3.0,
                 fetch_on_overload: bool = True, **inner_kw):
        if isinstance(inner, str):
            inner = make_global_scheduler(inner, **inner_kw)
        elif inner_kw:
            raise ValueError("inner_kw only applies when inner is a name")
        self.inner = inner
        self.registry = registry        # attached by the Simulation
        self.registry_ttl = registry_ttl
        self.overload_factor = overload_factor
        self.fetch_on_overload = fetch_on_overload
        self.affinity_hits = 0          # routed to a warm holder
        self.affinity_misses = 0        # no fresh holder: inner decided
        self.overload_diversions = 0    # warm but too hot: inner decided
        self.fetch_hints = 0            # diversions stamped with a hint

    def assign(self, req, workers):
        reg = self.registry
        pid = getattr(req, "prefix_id", None)
        if reg is None or pid is None or req.prefix_len <= 0:
            return self.inner.assign(req, workers)
        held = reg.holders(pid)
        ws = _eligible(workers, prefill=True)
        warm = [w for w in ws if held.get(w.wid, 0) > 0]
        if not warm:
            self.affinity_misses += 1
            wid = self.inner.assign(req, workers)
            reg.publish(pid, wid, req.prefix_len)
            return wid
        best = max(held[w.wid] for w in warm)
        warm = [w for w in warm if held[w.wid] == best]
        light = min(w.load_tokens() for w in ws)
        warm_load = min(w.load_tokens() for w in warm)
        if warm_load > self.overload_factor * max(light, 1.0):
            # every warm holder is hot: dispatch by load, but tell the
            # target where the prefix lives so it can fetch, not recompute
            self.overload_diversions += 1
            wid = self.inner.assign(req, workers)
            if self.fetch_on_overload and wid not in held:
                src = min((w for w in warm), key=lambda w:
                          (w.load_tokens(), w.wid))
                req.fetch_src = src.wid
                req.fetch_tokens = min(best, req.prefix_len)
                self.fetch_hints += 1
            reg.publish(pid, wid, req.prefix_len)
            return wid
        self.affinity_hits += 1
        wid = self.inner.assign(req, warm)
        reg.touch(pid, wid)
        reg.publish(pid, wid, req.prefix_len)
        return wid

    def eligible_for(self, req, workers):
        return self.inner.eligible_for(req, workers)

    def reassign(self, req, workers):
        return self.inner.reassign(req, workers)

    def discipline(self):
        return self.inner.discipline()

    def on_service_start(self, req) -> None:
        hook = getattr(self.inner, "on_service_start", None)
        if hook is not None:
            hook(req)

    def stats(self) -> Dict[str, int]:
        return {"affinity_hits": self.affinity_hits,
                "affinity_misses": self.affinity_misses,
                "overload_diversions": self.overload_diversions,
                "fetch_hints": self.fetch_hints}


def _hetero_routed(**kw):
    """The ``hetero`` policy upgraded for multi-model fleets: model
    routing wrapped around the FLOPs/bandwidth-weighted chooser.  For a
    single-model fleet the wrapper is inert, so existing runs keep
    their exact dispatch sequence."""
    return ModelRouted(inner=HeterogeneityAware(**kw))


#: every accepted ``SimSpec.global_policy`` name (aliases included);
#: scripts/check_docs.py asserts each key is documented in docs/POLICIES.md
GLOBAL_POLICIES = {"round_robin": RoundRobin, "least_loaded": LeastLoaded,
                   "disagg": DisaggPD, "disagg_pd": DisaggPD,
                   "session_affinity": SessionAffinity,
                   "hetero": _hetero_routed,
                   "heterogeneity_aware": _hetero_routed,
                   "wfq": WeightedFairQueuing, "priority": PriorityAging,
                   "model_routed": ModelRouted,
                   "prefix_affinity": PrefixAffinity}


def make_global_scheduler(kind: str, **kw) -> GlobalScheduler:
    """Build a global policy by name (see docs/POLICIES.md for the full
    reference table).  ``disagg_pd`` and ``heterogeneity_aware`` are
    long-form aliases of ``disagg`` / ``hetero``."""
    try:
        cls = GLOBAL_POLICIES[kind]
    except KeyError:
        raise ValueError(f"unknown global scheduler {kind!r}; "
                         f"have {sorted(GLOBAL_POLICIES)}")
    return cls(**kw)
