from repro.core.sched.local import (  # noqa: F401
    IterationPlan, LocalScheduler, StaticBatching, ContinuousBatching,
    make_local_scheduler)
from repro.core.sched.global_sched import (  # noqa: F401
    GlobalScheduler, RoundRobin, LeastLoaded, DisaggPD, SessionAffinity,
    make_global_scheduler)
