"""Closed-loop autoscaling inside the DES (docs/AUTOSCALING.md).

The fleet the dispatcher sees is no longer fixed: an :class:`Autoscaler`
daemon process samples queue depth, per-worker utilization and (when an
SLO is configured) windowed TTFT attainment every
``AutoscaleSpec.interval`` simulated seconds and grows or shrinks the
replica set between ``min_replicas`` and ``max_replicas``.

Scale-up is not free: a new worker clones the template ``WorkerSpec``
and pays the same recovery cost model as a fault revival
(docs/RELIABILITY.md) — model-reload latency
(``HardwareSpec.reload_time`` or the spec override) followed by
``warmup_iters`` iterations at ``warmup_factor``x — before it becomes
dispatch-eligible.  Scale-down reuses the drain path: the victim stops
taking new dispatches, finishes (or swaps out and re-admits) the work
it holds, and only then retires, so no request is ever lost to a
scaling decision.  Retired workers stay in the registry with their
stats frozen; billing stops at retirement
(``explore.uptime_weighted_price``).

Policies (``AUTOSCALE_POLICIES``):

* ``threshold`` — scale up when mean queue depth per serving worker
  exceeds ``queue_high`` (or windowed SLO attainment drops below
  ``slo_target``); scale down when the queue is below ``queue_low``
  *and* utilization below ``util_low``,
* ``target_utilization`` — track ``ceil(n * util / target_util)``
  serving replicas, the classic CPU-style target tracker,
* ``predictive_ema`` — linear trend extrapolation of an exponentially
  weighted queue-depth average: scale on where the queue is *heading*,
  buying back the provisioning lag that reactive policies eat.

Every decision is a pure function of sampled simulation state and the
spec, so autoscaled runs remain deterministic: the scale-event log is
part of the byte-identity contract (tests/test_autoscale.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from math import ceil, inf
from typing import List, Optional

#: every pluggable scaling policy ``AutoscaleSpec.policy`` accepts;
#: scripts/check_docs.py asserts each is documented in
#: docs/AUTOSCALING.md
AUTOSCALE_POLICIES = ("threshold", "target_utilization", "predictive_ema")

#: every ``ScaleEvent.action`` the autoscaler logs
SCALE_ACTIONS = ("up_request", "up_ready", "down_drain", "down_retired")


@dataclass(frozen=True)
class AutoscaleSpec:
    """Configuration for the closed-loop autoscaler (``SimSpec.autoscale``).

    ``template`` is the ``WorkerSpec`` scale-up clones; ``None`` uses
    the first entry of ``SimSpec.workers``.  The autoscaler *manages*
    exactly the workers built from a spec equal to the template (other
    entries — e.g. other models in a heterogeneous fleet — are never
    scaled), and ``min_replicas``/``max_replicas`` bound the managed
    count including replicas still provisioning.

    ``enabled=False`` makes the spec inert: no daemon process is
    created and the run is byte-identical to ``autoscale=None``
    (golden-pinned in tests/golden/autoscale_pin.json)."""
    enabled: bool = True
    policy: str = "threshold"
    #: sampling period of the control loop, simulated seconds
    interval: float = 2.0
    min_replicas: int = 1
    max_replicas: int = 4
    #: minimum seconds between consecutive scale actions (hysteresis)
    cooldown: float = 10.0
    #: managed replicas added/retired per control decision
    scale_step: int = 1
    #: mean waiting requests per serving worker that triggers scale-up
    queue_high: float = 4.0
    #: queue level below which scale-down becomes permissible
    queue_low: float = 0.5
    #: utilization below which ``threshold``/``predictive_ema`` shrink
    util_low: float = 0.35
    #: utilization the ``target_utilization`` policy tracks
    target_util: float = 0.7
    #: in-flight requests (running + queued) one worker is considered
    #: full at — the denominator of the sampled utilization.  Busy-time
    #: fraction is useless under continuous batching (a single decoding
    #: request keeps the iteration loop 100% busy while throughput can
    #: still grow an order of magnitude with batching), so utilization
    #: here is *occupancy*: ``min(1, in_flight / capacity_concurrency)``
    #: averaged over serving workers
    capacity_concurrency: int = 64
    #: EMA smoothing for ``predictive_ema`` (1.0 = no smoothing)
    ema_alpha: float = 0.5
    #: when set, windowed TTFT attainment below ``slo_target`` is a
    #: scale-up signal for the ``threshold`` policy
    ttft_slo: Optional[float] = None
    slo_target: float = 0.99
    #: WorkerSpec to clone on scale-up; None = SimSpec.workers[0]
    template: Optional[object] = None
    #: provisioning lag before a new worker serves; None = the
    #: template hardware's ``HardwareSpec.reload_time``
    reload_time: Optional[float] = None
    #: post-provisioning warm-up, same model as fault recovery
    warmup_iters: int = 2
    warmup_factor: float = 2.0

    def validate(self) -> None:
        if self.policy not in AUTOSCALE_POLICIES:
            raise ValueError(f"unknown autoscale policy {self.policy!r}; "
                             f"have {AUTOSCALE_POLICIES}")
        if not 1 <= self.min_replicas <= self.max_replicas:
            raise ValueError(
                f"need 1 <= min_replicas <= max_replicas, got "
                f"[{self.min_replicas}, {self.max_replicas}]")
        if self.interval <= 0:
            raise ValueError("AutoscaleSpec.interval must be > 0")
        if self.scale_step < 1:
            raise ValueError("AutoscaleSpec.scale_step must be >= 1")


@dataclass(frozen=True)
class ScaleEvent:
    """One autoscaler action in ``Results.scale_events`` — the scaling
    summary and the byte-identity tests derive everything from these.
    ``fleet_size`` is the managed provisioned count (serving +
    provisioning, retired excluded) *after* the action; ``signal`` is
    the policy input that triggered it (queue depth or utilization)."""
    time: float
    worker: int
    action: str                   # see SCALE_ACTIONS
    fleet_size: int
    signal: float = 0.0


class Autoscaler:
    """DES daemon scaling a ``Simulation``'s fleet at runtime.

    Follows the ``FaultInjector``/``TimeSeriesRecorder`` pattern: the
    control loop runs on *daemon* timeouts, so an idle autoscaler never
    keeps the simulation alive nor extends ``sim_time`` — but a
    provisioning worker's reload wait is a plain timeout, so capacity
    that was paid for always comes up (and can un-park requests even
    if every other worker died meanwhile)."""

    def __init__(self, sim, spec: AutoscaleSpec):
        spec.validate()
        self.sim = sim
        self.env = sim.env
        self.spec = spec
        self.template = spec.template if spec.template is not None \
            else sim.spec.workers[0]
        #: backends_by_worker keys follow the original spec position;
        #: clones inherit the template's slot
        try:
            self.template_base_i = list(sim.spec.workers).index(
                self.template)
        except ValueError:
            self.template_base_i = 0
        self.managed: List = [w for w in sim.workers
                              if w.spec_ws == self.template]
        if not self.managed:
            raise ValueError(
                "AutoscaleSpec.template matches no worker in the fleet; "
                "scale-up would add a worker the workload never targets")
        if len(self.managed) < spec.min_replicas:
            raise ValueError(
                f"fleet starts with {len(self.managed)} managed "
                f"worker(s) but min_replicas={spec.min_replicas}")
        self.events: List[ScaleEvent] = []
        self.n_scale_up = 0
        self.n_scale_down = 0
        self._last_action_t = -inf
        self._ema: Optional[float] = None
        self._prev_ema: Optional[float] = None
        #: windowed SLO attainment counters, reset every tick
        self._win_finished = 0
        self._win_slo_ok = 0

    # ------------------------------------------------------------------
    def start(self) -> None:
        self.env.process(self._loop(), name="autoscaler", daemon=True)

    def on_finish(self, req) -> None:
        """Simulation tap: count windowed TTFT attainment at retire
        time (works in streaming drop-mode — the request may be garbage
        one call later)."""
        if self.spec.ttft_slo is None:
            return
        ttft = req.ttft
        if ttft is None:
            return
        self._win_finished += 1
        if ttft <= self.spec.ttft_slo:
            self._win_slo_ok += 1

    # ------------------------------------------------------------------
    def _loop(self):
        env = self.env
        while True:
            yield env.timeout(self.spec.interval, daemon=True)
            self._tick()

    def _provisioned(self) -> List:
        return [w for w in self.managed if not w.retired]

    def _serving(self) -> List:
        return [w for w in self.managed
                if w.alive and not w.draining and not w.provisioning]

    def _tick(self) -> None:
        now = self.env.now
        self._finalize_retirements(now)
        serving = self._serving()
        provisioned = self._provisioned()
        n_prov = len(provisioned)
        # ---- sample control signals ----------------------------------
        cap = max(1, self.spec.capacity_concurrency)
        if serving:
            q = sum(len(w.waiting) for w in serving) / len(serving)
            util = sum(
                min(1.0, (len(w.running) + len(w.waiting)) / cap)
                for w in serving) / len(serving)
        else:
            # nothing serving (all provisioning or down): pressure is
            # whatever queued on the managed set; treat util as high
            q = float(sum(len(w.waiting) for w in provisioned) or 0)
            util = 1.0
        slo_att = None
        if self.spec.ttft_slo is not None and self._win_finished:
            slo_att = self._win_slo_ok / self._win_finished
        self._win_finished = self._win_slo_ok = 0
        delta = self._decide(q, util, slo_att, len(serving), n_prov)
        # ---- apply, under cooldown and the replica bounds ------------
        if delta == 0 or now - self._last_action_t < self.spec.cooldown:
            return
        if delta > 0:
            k = min(delta, self.spec.scale_step,
                    self.spec.max_replicas - n_prov)
            if k <= 0:
                return
            self._last_action_t = now
            for _ in range(k):
                self._scale_up(now, signal=q)
        else:
            # already-retiring workers still count as provisioned but
            # are guaranteed to leave: bound the step by the fleet that
            # will remain, or min_replicas can be transiently violated
            n_leaving = sum(1 for w in provisioned if w.retiring)
            k = min(-delta, self.spec.scale_step,
                    n_prov - n_leaving - self.spec.min_replicas)
            victims = self._pick_victims(k)
            if not victims:
                return
            self._last_action_t = now
            for w in victims:
                self._scale_down(w, now, signal=util)

    # ------------------------------------------------------------------
    def _decide(self, q: float, util: float, slo_att: Optional[float],
                n_serving: int, n_prov: int) -> int:
        """Desired change to the managed provisioned count.  Pure in
        (sampled state, spec): determinism of the scale-event log —
        and thus same-seed byte-identity — rests here."""
        s = self.spec
        if s.policy == "threshold":
            if q > s.queue_high or (slo_att is not None
                                    and slo_att < s.slo_target):
                return 1
            if q < s.queue_low and util < s.util_low:
                return -1
            return 0
        if s.policy == "target_utilization":
            if n_serving == 0:
                return 1 if q > 0 else 0
            desired = ceil(n_serving * util / s.target_util)
            if desired > n_prov:
                return desired - n_prov
            if desired < n_prov and q <= s.queue_low:
                return desired - n_prov
            return 0
        # predictive_ema: first-order trend on the smoothed queue depth
        a = s.ema_alpha
        ema = q if self._ema is None else a * q + (1.0 - a) * self._ema
        prev = self._ema if self._ema is not None else ema
        self._prev_ema, self._ema = prev, ema
        predicted = ema + (ema - prev)
        if predicted > s.queue_high:
            return 1
        if predicted < s.queue_low and util < s.util_low:
            return -1
        return 0

    # ------------------------------------------------------------------
    def _scale_up(self, now: float, *, signal: float) -> None:
        w = self.sim.add_worker(self.template,
                                base_i=self.template_base_i,
                                provisioning=True)
        self.managed.append(w)
        self.n_scale_up += 1
        self._log(w.wid, "up_request", signal)
        self.env.process(self._provision(w), name=f"provision-w{w.wid}")

    def _provision(self, w):
        """Pay the model-load lag, then join the serving set warm —
        the same recovery cost model a fault revival uses."""
        s = self.spec
        rt = s.reload_time if s.reload_time is not None \
            else w.hw.reload_time
        if rt > 0:
            yield self.env.timeout(rt)
        w.provisioning = False
        w.recover(warmup_iters=s.warmup_iters,
                  warmup_factor=s.warmup_factor)
        self._log(w.wid, "up_ready")
        self.sim.on_worker_recovered(w)

    def _pick_victims(self, k: int) -> List:
        """Least-loaded serving workers first (ties: youngest wid), so
        draining finishes fastest and the original fleet is the last
        to go."""
        if k <= 0:
            return []
        cands = sorted(self._serving(),
                       key=lambda w: (w.load_tokens(), -w.wid))
        return cands[:k]

    def _scale_down(self, w, now: float, *, signal: float) -> None:
        w.begin_retire()
        self.n_scale_down += 1
        self._log(w.wid, "down_drain", signal)
        self._finalize_retirements(now)   # an idle victim retires now

    def _finalize_retirements(self, now: float) -> None:
        for w in self.managed:
            if w.retiring and not w.retired and not w.waiting \
                    and not w.running:
                w.finish_retire(now)
                self._log(w.wid, "down_retired")

    def _log(self, wid: int, action: str, signal: float = 0.0) -> None:
        ev = ScaleEvent(self.env.now, wid, action,
                        len(self._provisioned()), signal)
        self.events.append(ev)
        obs = self.sim.obs
        if obs is not None:
            obs.on_scale(wid, action, self.env.now)
