"""TokenSim facade: configure a cluster, run a workload, get Results.

Mirrors the paper's Fig. 1/2: a dispatcher feeds a global scheduler that
assigns requests to concurrently running workers; local schedulers batch
between iterations; memory managers track device memory; a communication
model prices inter-worker KV movement (disaggregation, Fig. 7); an
optional memory pool serves multi-round conversations (Fig. 14); fault /
straggler injection exercises the mitigation policies.

Hierarchical KV memory (docs/MEMORY.md): ``preemption_mode="swap"``
attaches a per-worker host-DRAM ``SwapManager`` so preemption parks
victim KV over PCIe instead of recomputing it, and
``prefix_sharing=True`` makes the ``BlockManager`` share content-keyed
prefix blocks between concurrent requests with refcounted
copy-on-write — both costed against ``HardwareSpec.pcie_bw`` /
``host_mem_cap``.

Scale (docs/PERFORMANCE.md): ``SimSpec(streaming=True)`` makes the
dispatcher pull arrivals lazily from a ``workload.RequestSource``
instead of materializing the request list, and
``retain_requests=False`` folds finished requests into constant-memory
``StreamingStats`` sketches — together they bound live ``Request``
objects by the in-flight population, enabling million-request runs.

Parallelism & topology (docs/PARALLELISM.md): ``SimSpec.parallel``
(``ParallelSpec(tp, pp, replicas)``) maps each worker onto ``tp * pp``
devices of a ``SimSpec.cluster`` topology — tensor-parallel all-reduces
priced per ring step over the link the TP group occupies, pipeline
stages fed micro-batches with explicit bubble + p2p activation
accounting, and data-parallel replicas of the whole worker set.  The
defaults (tp=pp=replicas=1, cluster=None) are byte-identical to the
pre-parallelism cost model.

Multi-tenant QoS layer (repro.core.tenancy, beyond paper): when
``SimSpec.tenants`` is set, per-tenant workloads are merged into one
deterministic arrival stream and an ``AdmissionController`` — a
simulated API gateway with per-tenant token buckets and in-flight caps —
sits between the dispatcher and the global scheduler.  Tenant-aware
global policies ("wfq", "priority") hand every worker a shared queue
discipline, so weighted-fair / strict-priority ordering applies both at
dispatch and inside each worker's waiting queue, and the preemption path
evicts low-tier KV first.  ``Results`` then offers per-tenant latency /
SLO-attainment / goodput breakdowns and Jain's fairness index.
"""
from __future__ import annotations

import time as _time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Union

from repro.configs import get_config
from repro.configs.base import ArchConfig
from repro.core import comm as comm_mod
from repro.core.autoscale import Autoscaler, AutoscaleSpec
from repro.core.breakpoints import Hooks, disagg_hooks
from repro.core.costmodel.backends import (CostBackend, PipelineBackend,
                                           RooflineBackend, TabularBackend)
from repro.core.costmodel.hardware import (CLUSTERS, ClusterSpec, DGX_A100,
                                           HARDWARE, HardwareSpec,
                                           ParallelSpec)
from repro.core.costmodel.operators import kv_bytes_per_token, \
    state_bytes_per_seq
from repro.core.engine import Environment
from repro.core.faults import (ChaosSpec, FaultEvent, FaultInjector,
                               FaultProcess, FaultSpec, load_fault_trace)
from repro.core.mem.block_manager import MemoryConfig
from repro.core.mem.memory_pool import MemoryPool, PoolConfig
from repro.core.mem.remote_store import RemoteKVSpec, RemoteKVStore
from repro.core.mem.swap import PREEMPTION_MODES, SwapConfig, SwapManager
from repro.core.metrics import Results, StreamingStats
from repro.core.request import Request, State
from repro.core.sched.global_sched import (GlobalScheduler, PrefixAffinity,
                                           make_global_scheduler)
from repro.core.sched.local import make_local_scheduler
from repro.core.sched.prefix_registry import PrefixRegistry
from repro.core.specdecode import SpecDecodeSpec
from repro.core.tenancy import AdmissionController, TenantSpec
from repro.core.worker import Worker
from repro.core.workload import (WorkloadSpec, generate, generate_multi,
                                 make_source, make_tenant_source)
from repro.obs import ObsRecorder, ObsSpec


@dataclass(frozen=True)
class WorkerSpec:
    hw: str = "A100"
    role: str = "both"                  # both | prefill | decode
    tp: int = 1
    gpu_mem_util: float = 0.9
    max_mem_ratio: float = 1.0          # admission cap (Fig. 10)
    mem_cap_override: Optional[float] = None  # bytes (Fig. 13/15 sweeps)
    hw_overrides: Dict[str, float] = field(default_factory=dict)
    slowdown: float = 1.0
    #: model this worker hosts (docs/HETEROGENEITY.md): a config name or
    #: ArchConfig; None inherits ``SimSpec.arch``.  The worker's memory
    #: sizing, cost backend and KV-transfer pricing all resolve against
    #: this arch, so one fleet can serve several models at once (pair
    #: with the ``model_routed`` global policy)
    arch: Optional[Union[str, ArchConfig]] = None


def effective_tp(ws: WorkerSpec, parallel: ParallelSpec) -> int:
    """Tensor degree a worker actually runs at: the per-worker
    ``WorkerSpec.tp`` override (Fig. 12-style heterogeneous setups)
    wins over the cluster-wide ``ParallelSpec.tp``.  Shared by the
    worker builder and the exploration harness's price model so the
    two can never disagree."""
    return ws.tp if ws.tp != 1 else parallel.tp


# FaultSpec grew into a family of fault processes and moved to
# repro.core.faults (docs/RELIABILITY.md); re-exported here so the
# original import path keeps working
__all_faults__ = (ChaosSpec, FaultEvent, FaultInjector, FaultProcess,
                  FaultSpec, load_fault_trace)


@dataclass
class SimSpec:
    arch: Union[str, ArchConfig] = "llama2-7b"
    workers: Sequence[WorkerSpec] = (WorkerSpec(),)
    workload: WorkloadSpec = field(default_factory=WorkloadSpec)
    global_policy: str = "least_loaded"
    #: extra kwargs for make_global_scheduler (e.g. {"aging_rate": 2.0})
    global_policy_kw: Dict[str, object] = field(default_factory=dict)
    local_policy: str = "continuous"
    max_batch: int = 256
    max_batched_tokens: int = 2048
    chunked_prefill: bool = False
    prefill_chunk: int = 512
    block_size: int = 16
    dtype_bytes: int = 2
    #: preemption mode (docs/MEMORY.md): "recompute" discards a victim's
    #: KV and re-prefills it on re-admission; "swap" parks it in host
    #: DRAM over the worker's PCIe link and restores it later
    preemption_mode: str = "recompute"
    #: shared-prefix copy-on-write caching in the BlockManager: requests
    #: with equal (prefix_id, prefix_len) share resident prefix blocks
    prefix_sharing: bool = False
    #: host DRAM bytes available for swapped KV; None = the worker
    #: hardware's ``HardwareSpec.host_mem_cap``
    host_mem_cap: Optional[float] = None
    #: third cache tier (docs/ROUTING.md): a cluster-wide capacity-
    #: bounded remote/object KV store under host DRAM.  Prefill
    #: hand-offs publish shared prefixes into it, the swap tier spills
    #: victims when host DRAM fills, and workers fetch at
    #: ``remote_setup + bytes / remote_bw`` (per-worker HardwareSpec
    #: fields, overridable on the spec).  None — the default — is
    #: byte-identical to the two-tier model
    remote_kv: Optional[RemoteKVSpec] = None
    #: parallelism strategy applied to every worker (docs/PARALLELISM.md):
    #: tensor degree (per-worker ``WorkerSpec.tp`` != 1 still wins),
    #: pipeline stages with micro-batched iterations, and data-parallel
    #: replicas of the whole worker set.  The default is the pre-existing
    #: single-device cost model, byte-identical.
    parallel: ParallelSpec = field(default_factory=ParallelSpec)
    #: interconnect topology for collective costing: a ClusterSpec, a
    #: name from ``CLUSTERS``, or None for the legacy flat TP term
    #: (volume / hw.link_bw, latency-free).  Pipeline parallelism needs
    #: a topology; pp > 1 with ``cluster=None`` assumes ``dgx-a100``.
    cluster: Optional[Union[str, ClusterSpec]] = None
    pool: Optional[PoolConfig] = None
    kv_link: comm_mod.LinkSpec = comm_mod.NVLINK
    faults: Sequence[FaultSpec] = ()
    #: chaos layer (docs/RELIABILITY.md): stochastic MTBF/MTTR fault
    #: processes plus the costly-recovery model (model reload, warm-up
    #: iterations, host-KV survival).  None keeps the legacy contract:
    #: scheduled ``faults`` with free, instant recovery
    chaos: Optional[ChaosSpec] = None
    backend: str = "roofline"
    backend_samples: Optional[list] = None   # for tabular
    backends_by_worker: Optional[Dict[int, CostBackend]] = None
    until: Optional[float] = None
    #: multi-tenant QoS: when set, each tenant's workload is merged into
    #: one stream and admission control gates the dispatcher
    #: (``workload`` above is then ignored)
    tenants: Sequence[TenantSpec] = ()
    #: speculative decoding (repro.core.specdecode): when set, decode
    #: iterations draft ``lookahead`` tokens with the draft model and
    #: verify them in one target forward (continuous batching only)
    spec_decode: Optional[SpecDecodeSpec] = None
    #: streaming mode (docs/PERFORMANCE.md): the dispatcher pulls
    #: arrivals lazily from a RequestSource instead of materializing the
    #: whole request list up front — required for million-request runs.
    #: With a finite ``until`` horizon, Results.requests covers only the
    #: requests actually dispatched before the cut (exact mode lists all
    #: num_requests), so count-normalized metrics can differ there
    streaming: bool = False
    #: False folds finished requests into a StreamingStats sketch and
    #: drops them (Results then reads summaries from ``Results.stats``);
    #: True (default) keeps the exact per-request list
    retain_requests: bool = True
    #: relative quantile error of the streaming sketches
    sketch_alpha: float = 0.003
    #: (ttft_slo, tpot_slo) evaluated at fold time so ``slo_goodput``
    #: works with retain_requests=False (per-tenant SLOs come from the
    #: tenant tiers automatically)
    streaming_slo: Optional[tuple] = None
    #: observability (docs/OBSERVABILITY.md): request-lifecycle tracing
    #: (Chrome trace-event export), bounded time-series sampling, and
    #: latency attribution.  None (default) is the zero-cost path: no
    #: recorder objects exist and every tap is a single is-None check
    obs: Optional[ObsSpec] = None
    #: closed-loop autoscaling (docs/AUTOSCALING.md): a daemon process
    #: samples queue depth / utilization / SLO attainment and scales the
    #: fleet between min_replicas and max_replicas at runtime, paying
    #: model-reload + warm-up lag on the way up and draining on the way
    #: down.  None (or a disabled spec) keeps the fleet static,
    #: byte-identical to the pre-autoscaling simulator
    autoscale: Optional[AutoscaleSpec] = None


class WorkerRegistry(list):
    """The fleet as a *dynamic* worker registry (docs/AUTOSCALING.md).

    A list subclass, so every pre-autoscaling consumer — global
    schedulers iterating the fleet, ``workers[wid]`` indexing, the
    fault injector, the obs sampler — keeps working unchanged, while
    the autoscaler can grow it at runtime through ``add``.  The
    registry is append-only: wids are dense list positions (asserted
    on add), and scale-down retires a worker *in place*
    (``Worker.retired``) instead of removing it, so wid indexing and
    per-worker stats stay stable for the whole run."""

    def add(self, worker) -> None:
        if worker.wid != len(self):
            raise ValueError(f"worker wid {worker.wid} breaks dense "
                             f"indexing (registry holds {len(self)})")
        self.append(worker)

    def n_serving(self) -> int:
        """Workers currently accepting dispatches."""
        return sum(1 for w in self if w.alive and not w.draining)


class Simulation:
    def __init__(self, spec: SimSpec):
        self.spec = spec
        self.cfg = spec.arch if isinstance(spec.arch, ArchConfig) \
            else get_config(spec.arch)
        #: concrete name stamped on requests arriving with model=None,
        #: so routing and per-model metrics never see the sentinel
        self.default_model: str = self.cfg.name
        #: model name -> ArchConfig for every arch hosted by the fleet
        #: (filled by _build_workers; the default arch is always present)
        self._model_cfgs: Dict[str, ArchConfig] = {self.cfg.name: self.cfg}
        self.env = Environment()
        self.link = comm_mod.Link(self.env, spec.kv_link)
        self.pool = MemoryPool(spec.pool) if spec.pool else None
        if spec.streaming:
            # lazy arrival stream: the dispatcher pulls one request at a
            # time; ``requests`` fills as requests are dispatched (and
            # stays empty of retired ones when retain_requests=False)
            self.source = iter(make_tenant_source(spec.tenants)
                               if spec.tenants
                               else make_source(spec.workload))
            self.requests: List[Request] = []
        else:
            self.source = None
            self.requests = generate_multi(spec.tenants) \
                if spec.tenants else generate(spec.workload)
        self.stats: Optional[StreamingStats] = None
        if not spec.retain_requests:
            tenant_slos = {t.tenant_id: (t.tier.ttft_slo, t.tier.tpot_slo)
                           for t in spec.tenants}
            self.stats = StreamingStats(
                spec.sketch_alpha, slo=spec.streaming_slo,
                tenant_slos=tenant_slos)
        self._n_live = 0
        self.max_live = 0
        #: observability hub; built before the workers so install() can
        #: register its breakpoint hooks on each one
        self.obs: Optional[ObsRecorder] = \
            ObsRecorder(spec.obs) \
            if spec.obs is not None and spec.obs.enabled else None
        self.global_sched: GlobalScheduler = make_global_scheduler(
            spec.global_policy, **spec.global_policy_kw)
        #: cache-aware routing (docs/ROUTING.md): attach a cluster-wide
        #: prefix registry to any PrefixAffinity router in the policy
        #: chain (wrappers expose ``.inner``, fallbacks ``.fallback``);
        #: both stay None for prefix-blind policies — zero extra state
        self.prefix_registry: Optional[PrefixRegistry] = None
        self._prefix_router: Optional[PrefixAffinity] = None
        node = self.global_sched
        while node is not None:
            if isinstance(node, PrefixAffinity):
                if node.registry is None:
                    node.registry = PrefixRegistry(
                        self.env, ttl=node.registry_ttl)
                self.prefix_registry = node.registry
                self._prefix_router = node
                break
            node = getattr(node, "inner", None) \
                or getattr(node, "fallback", None)
        #: remote/object KV tier shared by the whole cluster
        #: (docs/ROUTING.md); built before the workers so their swap
        #: managers can spill into it
        self.remote_store: Optional[RemoteKVStore] = \
            RemoteKVStore(spec.remote_kv.capacity_bytes) \
            if spec.remote_kv is not None else None
        #: cluster-level fetch counters (Results.routing_summary)
        self.fetch_stats: Dict[str, float] = {
            "fetches": 0, "peer_fetches": 0, "remote_fetches": 0,
            "fetch_bytes": 0.0, "fetch_time_s": 0.0,
            "fetch_misses": 0, "fetch_recomputes": 0}
        self.admission: Optional[AdmissionController] = \
            AdmissionController(self.env, spec.tenants, self) \
            if spec.tenants else None
        self.workers: WorkerRegistry = WorkerRegistry()
        self._build_workers()
        self._validate_models()
        #: requests held at the dispatcher during a cluster-wide outage
        #: (every worker dead), re-placed on the first recovery; each
        #: entry is (request, source SwapManager or None)
        self._parked: List[tuple] = []
        self.fault_injector: Optional[FaultInjector] = \
            FaultInjector(self, spec.chaos, spec.faults) \
            if spec.faults or (spec.chaos is not None
                               and spec.chaos.processes) else None
        self._n_finished = 0
        #: closed-loop autoscaler (docs/AUTOSCALING.md); None (or a
        #: disabled spec) keeps the fleet static — no daemon process,
        #: no extra events, byte-identical to the pre-autoscale path
        self.autoscaler: Optional[Autoscaler] = \
            Autoscaler(self, spec.autoscale) \
            if spec.autoscale is not None and spec.autoscale.enabled \
            else None
        #: model -> (kv_bytes_per_token, state_bytes_per_seq) so the
        #: migration path prices the KV transfer against the request's
        #: own arch, not the fleet default
        self._kv_by_model = {
            name: (kv_bytes_per_token(cfg, spec.dtype_bytes),
                   state_bytes_per_seq(cfg, spec.dtype_bytes))
            for name, cfg in self._model_cfgs.items()}

    # ------------------------------------------------------------------
    def _build_workers(self) -> None:
        spec = self.spec
        if spec.preemption_mode not in PREEMPTION_MODES:
            raise ValueError(f"unknown preemption_mode "
                             f"{spec.preemption_mode!r}; have "
                             f"{PREEMPTION_MODES}")
        disagg = any(w.role != "both" for w in spec.workers)
        draft_cfg = None
        if spec.spec_decode is not None:
            da = spec.spec_decode.draft_arch
            draft_cfg = da if isinstance(da, ArchConfig) else get_config(da)
        par = spec.parallel
        cluster = spec.cluster
        if isinstance(cluster, str):
            try:
                cluster = CLUSTERS[cluster]
            except KeyError:
                raise ValueError(f"unknown cluster {cluster!r}; "
                                 f"have {sorted(CLUSTERS)}")
        if cluster is None and par.pp > 1:
            cluster = DGX_A100         # pp needs a topology for p2p links
        if par.pp > 1 and spec.backend != "roofline":
            # only the roofline backend knows how to split into stages;
            # a tabular/xla model would silently cost a 4-device
            # pipeline as one device while the KV pool scales by pp
            raise ValueError(
                f"ParallelSpec(pp={par.pp}) requires backend='roofline' "
                f"(got {spec.backend!r}); supply a pipeline-aware "
                f"backend via backends_by_worker instead")
        # per-sim invariants reused by runtime worker additions
        # (add_worker): a scaled-up clone must be built exactly like an
        # initial worker
        self._disagg = disagg
        self._draft_cfg = draft_cfg
        self._cluster = cluster
        #: data parallelism: replicate the whole worker set, each copy a
        #: full tp x pp serving instance behind the global scheduler
        worker_specs = list(spec.workers) * par.replicas
        for i, ws in enumerate(worker_specs):
            #: replicas clone the original worker set, so per-worker
            #: config keyed by index (backends_by_worker) follows the
            #: original position, not the expanded one
            self._make_worker(ws, i, i % len(spec.workers))

    def _make_worker(self, ws: WorkerSpec, wid: int,
                     base_i: int) -> Worker:
        """Build one worker from its spec and register it — shared by
        the initial fleet construction and runtime scale-up
        (``add_worker``), so the two can never diverge."""
        spec = self.spec
        par = spec.parallel
        cluster = self._cluster
        tp = effective_tp(ws, par)
        # per-worker arch (docs/HETEROGENEITY.md): None inherits the
        # fleet default; everything below — memory sizing, cost
        # backend, encoder tokens — resolves against this config
        if ws.arch is None:
            wcfg = self.cfg
        elif isinstance(ws.arch, ArchConfig):
            wcfg = ws.arch
        else:
            wcfg = get_config(ws.arch)
        self._model_cfgs.setdefault(wcfg.name, wcfg)
        hw = HARDWARE[ws.hw]
        if ws.hw_overrides:
            hw = hw.with_(**ws.hw_overrides)
        price = hw.price * tp * par.pp   # mirrors explore.worker_price
        if ws.mem_cap_override is not None:
            hw = hw.with_(mem_cap=ws.mem_cap_override)
        # a pp-stage worker owns pp devices: its aggregate KV budget
        # is pp device capacities minus one full (tp-sharded) copy of
        # the weights, which the stages hold 1/pp each
        mem_cfg = MemoryConfig.from_model(
            wcfg, hw.mem_cap * par.pp, block_size=spec.block_size,
            dtype_bytes=spec.dtype_bytes, tp=tp,
            gpu_mem_util=ws.gpu_mem_util,
            watermark=max(0.0, 1.0 - ws.max_mem_ratio),
            prefix_sharing=spec.prefix_sharing)
        swap = None
        if spec.preemption_mode == "swap":
            rbw, rsetup = self._remote_cost(hw)
            swap = SwapManager(SwapConfig(
                pcie_bw=hw.pcie_bw,
                host_capacity_bytes=spec.host_mem_cap
                if spec.host_mem_cap is not None else hw.host_mem_cap,
                kv_bytes_per_token=mem_cfg.kv_bytes_per_token,
                state_bytes_per_seq=mem_cfg.state_bytes_per_seq,
                block_size=mem_cfg.block_size,
                remote_bw=rbw, remote_setup_latency=rsetup),
                remote=self.remote_store)
        if spec.backends_by_worker and base_i in spec.backends_by_worker:
            backend = spec.backends_by_worker[base_i]
        elif spec.backend == "tabular":
            backend = TabularBackend.fit(spec.backend_samples)
        elif par.pp > 1:
            backend = PipelineBackend.for_model(
                wcfg, hw,
                ParallelSpec(tp=tp, pp=par.pp,
                             microbatches=par.microbatches),
                cluster, dtype_bytes=spec.dtype_bytes)
        else:
            backend = RooflineBackend.for_model(
                wcfg, hw, tp=tp, dtype_bytes=spec.dtype_bytes,
                cluster=cluster)
        sched = make_local_scheduler(
            spec.local_policy, max_batch=spec.max_batch,
            max_batched_tokens=spec.max_batched_tokens,
            chunked_prefill=spec.chunked_prefill,
            prefill_chunk=spec.prefill_chunk)
        hooks = disagg_hooks() if self._disagg else Hooks()
        enc_tokens = wcfg.enc_seq_len \
            if wcfg.family in ("audio", "encdec") else 0
        draft_backend = None
        if self._draft_cfg is not None:
            # draft model runs on the same chip as its worker (with
            # optional overrides, e.g. a dedicated draft unit)
            dhw = hw.with_(**spec.spec_decode.draft_hw_overrides) \
                if spec.spec_decode.draft_hw_overrides else hw
            draft_backend = RooflineBackend.for_model(
                self._draft_cfg, dhw, tp=tp,
                dtype_bytes=spec.dtype_bytes, cluster=cluster)
        w = Worker(self.env, wid, hw, backend, mem_cfg, sched,
                   run_prefill=ws.role in ("both", "prefill"),
                   run_decode=ws.role in ("both", "decode"),
                   cluster=self, pool=self.pool, hooks=hooks,
                   enc_tokens_per_req=enc_tokens,
                   discipline=self.global_sched.discipline(),
                   spec_decode=spec.spec_decode,
                   draft_backend=draft_backend, swap=swap,
                   obs=self.obs, model=wcfg.name, tp=tp)
        w.slowdown = ws.slowdown
        w.spec_ws = ws
        w.price = price
        if self.obs is not None:
            self.obs.install(w)
        self.workers.add(w)
        return w

    def add_worker(self, ws: WorkerSpec, *, base_i: int = 0,
                   provisioning: bool = False) -> Worker:
        """Grow the fleet at runtime (autoscaler scale-up): build a
        worker from ``ws`` exactly as the initial fleet was built, at
        the next dense wid.  With ``provisioning=True`` it starts
        outside every dispatch path (``alive=False``, so even the
        eligibility fallback skips it) until the model load finishes
        and ``Worker.recover`` brings it up."""
        w = self._make_worker(ws, len(self.workers), base_i)
        if provisioning:
            w.alive = False
            w.provisioning = True
        if w.model not in self._kv_by_model:
            # a runtime-added model must be migration-priceable too
            cfg = self._model_cfgs[w.model]
            self._kv_by_model[w.model] = (
                kv_bytes_per_token(cfg, self.spec.dtype_bytes),
                state_bytes_per_seq(cfg, self.spec.dtype_bytes))
        return w

    def _validate_models(self) -> None:
        """Fail fast on fleet/workload model mismatches: every model the
        workload declares must be hosted by at least one worker, and a
        multi-model fleet needs a model-aware global policy (one that
        overrides ``eligible_for``) — a model-blind policy would happily
        dispatch a request onto a worker serving a different model."""
        spec = self.spec
        hosted = {w.model for w in self.workers}
        if spec.tenants:
            wanted = {t.workload.model or self.default_model
                      for t in spec.tenants}
        else:
            wanted = {spec.workload.model or self.default_model}
        missing = sorted(wanted - hosted)
        if missing:
            raise ValueError(
                f"workload targets model(s) {missing} but the fleet "
                f"hosts only {sorted(hosted)}; add a WorkerSpec with "
                f"arch=<model> (docs/HETEROGENEITY.md)")
        if len(hosted) > 1 and type(self.global_sched).eligible_for \
                is GlobalScheduler.eligible_for:
            raise ValueError(
                f"fleet hosts multiple models {sorted(hosted)} but "
                f"global_policy={spec.global_policy!r} is model-blind; "
                f"use 'model_routed' (wrapping it via "
                f"global_policy_kw={{'inner': {spec.global_policy!r}}}) "
                f"or 'hetero' (docs/HETEROGENEITY.md)")

    # ------------------------------------------------------------------
    # cluster callbacks (used by workers/hooks)
    def _remote_cost(self, hw: HardwareSpec) -> tuple:
        """(bw, setup) the remote tier charges this hardware
        (docs/ROUTING.md): spec-level overrides win over the per-worker
        HardwareSpec fields."""
        rk = self.spec.remote_kv
        if rk is None:
            return hw.remote_bw, hw.remote_setup
        return (rk.bw if rk.bw is not None else hw.remote_bw,
                rk.setup_latency if rk.setup_latency is not None
                else hw.remote_setup)

    def fetch_prefix(self, worker: Worker, req: Request) -> float:
        """Price pulling ``req``'s shared prefix from the peer named by
        its fetch hint — a ``p2p_time`` transfer over ``SimSpec.
        kv_link`` — or from the remote tier when the peer is gone
        (docs/ROUTING.md).  Applies a fetch-vs-recompute break-even
        mirroring the swap crossover: when re-prefilling the missing
        tokens is cheaper than the wire, the fetch is declined and the
        request prefills as routed.  Returns the latency to bill into
        ``IterationPlan.fetch_latency`` (0.0 when nothing fetched)."""
        want = req.fetch_tokens
        have = max(req.cached_len, req.prefill_done_len)
        if want <= have:
            return 0.0                      # local cache already covers it
        st = self.fetch_stats
        kvt, sbs = self._kv_by_model[req.model or self.default_model]
        cost = via = None
        tokens = want
        src_wid = req.fetch_src
        if src_wid is not None and 0 <= src_wid < len(self.workers):
            src = self.workers[src_wid]
            if src.alive and not src.retired and src is not worker:
                nbytes = (kvt * tokens) if kvt else sbs
                cost = comm_mod.p2p_time(nbytes, self.spec.kv_link)
                via = "peer"
        if cost is None and self.remote_store is not None \
                and req.prefix_id is not None:
            hit = self.remote_store.get(("prefix", req.prefix_id))
            if hit is not None and min(want, hit[0]) > have:
                tokens = min(want, hit[0])
                nbytes = (kvt * tokens) if kvt else sbs
                rbw, rsetup = self._remote_cost(worker.hw)
                cost = rsetup + nbytes / max(rbw, 1.0)
                via = "remote"
        if cost is None:
            st["fetch_misses"] += 1         # peer dead, remote cold
            return 0.0
        if cost >= worker.estimate_prefill_time(tokens - have):
            st["fetch_recomputes"] += 1     # recompute wins the break-even
            return 0.0
        req.cached_len = max(req.cached_len, tokens)
        req.fetch_count += 1
        req.fetched_tokens += tokens - have
        st["fetches"] += 1
        st["peer_fetches" if via == "peer" else "remote_fetches"] += 1
        st["fetch_bytes"] += nbytes
        st["fetch_time_s"] += cost
        if via == "peer":
            if self.prefix_registry is not None \
                    and req.prefix_id is not None:
                self.prefix_registry.touch(req.prefix_id, src_wid)
            if self.remote_store is not None and req.prefix_id is not None \
                    and self.spec.remote_kv.publish_prefixes:
                # write-through: a prefix worth moving between peers is
                # worth making cluster-visible
                self.remote_store.put(("prefix", req.prefix_id),
                                      tokens, nbytes)
        if self.obs is not None:
            self.obs.on_fetch(worker.wid, req, via, tokens, nbytes,
                              self.env.now)
        return cost

    def migrate(self, req: Request, from_worker: Worker) -> None:
        """Move a prefilled request to a decode worker (KV over the link)."""
        target_id = self.global_sched.reassign(req, self.workers)
        if self.remote_store is not None and req.prefix_id is not None \
                and req.prefix_len > 0 \
                and self.spec.remote_kv.publish_prefixes:
            # disagg publish (docs/ROUTING.md): the prefill worker has
            # the shared prefix computed at hand-off time; pushing it to
            # the object store is an async write-back off the serving
            # path, so no latency is billed here
            pkvt, psbs = self._kv_by_model[req.model or self.default_model]
            ptok = min(req.prefix_len, req.context_len)
            self.remote_store.put(("prefix", req.prefix_id), ptok,
                                  (pkvt * ptok) if pkvt else psbs)
        if target_id == from_worker.wid:
            return                          # stays: nothing to move
        req.state = State.MIGRATING
        kvt, sbs = self._kv_by_model[req.model or self.default_model]
        nbytes = kvt * max(1, req.context_len) if kvt else sbs
        done = self.link.transfer(nbytes)
        target = self.workers[target_id]
        obs = self.obs
        if obs is not None:
            self.global_sched.observe_assign(req, target_id)
        t_start = self.env.now

        def on_done(_ev, req=req, fw=from_worker, tw=target):
            if req.state is not State.MIGRATING:
                # the source worker died mid-transfer: fail() already
                # reset the request and re-dispatched it, so the partial
                # KV never arrived — delivering it now would duplicate
                # the request on two workers
                return
            fw.release(req)
            if obs is not None:
                obs.on_migrate_done(req, self.env.now,
                                    self.env.now - t_start)
            if not tw.alive:
                # target died while the KV was on the wire: the copy is
                # lost with the device, so re-prefill from scratch
                req.swapped_tokens = 0
                req.prefill_done_len = 0
                req.cached_len = 0
                req.state = State.QUEUED
                self.redispatch([req])
                return
            tw.receive_migrated(req)

        done.wait(on_done)

    def on_request_finished(self, req: Request) -> None:
        self._n_finished += 1
        self._n_live -= 1
        if self.autoscaler is not None:
            self.autoscaler.on_finish(req)
        if self.obs is not None:
            # derive the conserved component breakdown while the
            # timestamps are final, before any streaming fold drops it
            self.obs.finalize(req)
        if self.admission is not None:
            self.admission.on_finish(req)
        if self.stats is not None:
            # fold-and-forget: the request's numbers enter the sketches
            # and nothing else holds a reference (workers have already
            # released it), so it is garbage the moment we return
            self.stats.fold(req)

    def on_request_rejected(self, req: Request) -> None:
        """Admission control dropped the request (429): account for it
        so streaming mode can forget it."""
        self._n_live -= 1
        if self.obs is not None:
            self.obs.on_reject(req, self.env.now)
        if self.stats is not None:
            self.stats.fold(req)

    def redispatch(self, orphans: List[Request],
                   from_worker: Optional[Worker] = None) -> None:
        obs = self.obs
        src_swap = from_worker.swap if from_worker is not None else None
        for req in sorted(orphans, key=lambda r: r.id):
            if obs is not None:
                obs.on_requeue(req, self.env.now)
            self._place(req, src_swap)

    def _place(self, req: Request, src_swap=None) -> None:
        """Assign one request to a worker.  During a cluster-wide outage
        (no worker alive) the request parks at the dispatcher and is
        re-placed by the first recovery.  ``src_swap`` is a failed
        worker's host-DRAM tier: a surviving KV entry there follows the
        request into the new worker's tier (no PCIe transfer — the
        bytes never left host memory), falling back to re-prefill when
        the new tier has no room."""
        # park against the policy's eligible subset: a model whose hosts
        # are all down waits at the dispatcher even while workers of
        # other models keep serving (model-blind policies see the full
        # fleet here, exactly as before)
        hosts = self.global_sched.eligible_for(req, self.workers)
        if not any(w.alive for w in hosts):
            self._parked.append((req, src_swap))
            return
        wid = self.global_sched.assign(req, self.workers)
        if self.obs is not None:
            self.global_sched.observe_assign(req, wid)
        target = self.workers[wid]
        if self.remote_store is not None and req.fetch_src is None \
                and req.prefix_id is not None and req.prefix_len > 0 \
                and self.remote_store.has(("prefix", req.prefix_id)):
            # the cluster store holds this prefix (published by a disagg
            # prefill hand-off or a peer-fetch write-through): hint the
            # target to fetch instead of re-prefilling.  fetch_src=-1
            # means "no peer, remote tier only"; the local-cache check
            # and the break-even in fetch_prefix still apply
            req.fetch_src = -1
            req.fetch_tokens = req.prefix_len
        if src_swap is not None and src_swap.holds(req):
            tokens = src_swap.drop(req)
            tswap = target.swap
            if tswap is None or not tswap.adopt(req, tokens):
                # host copy has nowhere to live on the new worker:
                # fall back to re-prefilling from scratch
                req.swapped_tokens = 0
                req.prefill_done_len = 0
                req.cached_len = 0
        target.submit(req)

    def on_worker_recovered(self, worker: Worker) -> None:
        """Fault injector finished reviving ``worker``: re-place any
        requests parked during a cluster-wide outage."""
        if self._parked:
            parked, self._parked = self._parked, []
            for req, src_swap in parked:
                self._place(req, src_swap)

    # ------------------------------------------------------------------
    def _dispatcher(self):
        env = self.env
        streaming = self.source is not None
        retain = self.spec.retain_requests
        obs = self.obs
        it = self.source if streaming else self.requests
        default_model = self.default_model
        for req in it:
            if req.model is None:
                # stamp the concrete default so routing, per-model
                # metrics and the migration path never see the sentinel
                req.model = default_model
            if streaming and retain:
                self.requests.append(req)
            delay = req.arrival_time - env.now
            if delay > 0:
                yield env.timeout(delay)
            self._n_live += 1
            if self._n_live > self.max_live:
                self.max_live = self._n_live
            if obs is not None:
                obs.on_arrival(req, gated=self.admission is not None)
            if self.admission is not None:
                self.admission.submit(req)
            else:
                self._place(req)

    # ------------------------------------------------------------------
    def _sampler(self):
        """Periodic time-series tick.  A daemon process: its timeouts
        never keep the simulation alive, so sampling neither extends
        ``sim_time`` nor prevents ``env.run()`` from terminating."""
        env = self.env
        while True:
            yield env.timeout(self.obs.ts.interval, daemon=True)
            self._sample_obs(env.now)

    def _sample_obs(self, now: float) -> None:
        obs = self.obs
        extra = {"n_live": self._n_live, "n_finished": self._n_finished,
                 "n_rejected": sum(self.admission.rejected.values())
                 if self.admission is not None else 0,
                 "assigns": self.global_sched.assign_counts()}
        cluster = obs.ts.sample(now, self.workers, extra)
        if obs.trace is not None:
            obs.trace.counter("cluster", now, {
                "queue_depth": cluster["queue_depth"],
                "n_running": cluster["n_running"],
                "kv_used_blocks": cluster["kv_used_blocks"]})

    # ------------------------------------------------------------------
    def run(self) -> Results:
        t0 = _time.perf_counter()
        self.env.process(self._dispatcher(), name="dispatcher")
        if self.fault_injector is not None:
            self.fault_injector.start()
        if self.autoscaler is not None:
            self.autoscaler.start()
        if self.obs is not None and self.obs.ts is not None:
            self.env.process(self._sampler(), name="obs-sampler",
                             daemon=True)
        self.env.run(until=self.spec.until)
        wall = _time.perf_counter() - t0
        if self.autoscaler is not None:
            # a drained victim idle at the horizon retires now, so its
            # billing span closes at the time it stopped working
            self.autoscaler._finalize_retirements(self.env.now)
        if self.obs is not None:
            if self.obs.ts is not None:
                # closing frame at the horizon (also covers sims shorter
                # than one sampling interval)
                self._sample_obs(self.env.now)
            if self.obs.trace is not None:
                self.obs.trace.flush_open(self.env.now)
        requests = self.requests
        if self.stats is not None:
            # retired requests live only in the sketches; report the
            # (bounded) leftovers still in flight at the horizon
            leftovers = {id(r): r for w in self.workers
                         for r in list(w.waiting) + list(w.running)}
            if self.admission is not None:
                for q in self.admission.queues.values():
                    leftovers.update((id(r), r) for r in q)
            requests = sorted(leftovers.values(), key=lambda r: r.id)
        return Results(
            requests=requests,
            sim_time=self.env.now,
            worker_mem={w.wid: w.mem_timeline for w in self.workers},
            pool_stats=self.pool.stats() if self.pool else None,
            mem_stats={w.wid: w.mem.stats() for w in self.workers},
            swap_stats={w.wid: w.swap.stats() for w in self.workers
                        if w.swap is not None} or None,
            wall_time=wall,
            events=sum(w.iterations for w in self.workers),
            tenant_specs={t.tenant_id: t for t in self.spec.tenants}
            if self.spec.tenants else None,
            admission_stats=self.admission.stats()
            if self.admission else None,
            parallel_stats={
                w.wid: {"pp_bubble_time": w.pp_bubble_time,
                        "pp_comm_time": w.pp_comm_time,
                        "pp_span_time": w.pp_span_time,
                        "busy_time": w.busy_time,
                        "iterations": w.iterations}
                for w in self.workers}
            if self.spec.parallel.pp > 1
            or any(w.pp_span_time for w in self.workers) else None,
            stats=self.stats,
            max_live=self.max_live,
            worker_models={w.wid: w.model for w in self.workers},
            default_model=self.default_model,
            fault_events=self.fault_injector.events
            if self.fault_injector is not None else None,
            n_workers=len(self.workers),
            scale_events=self.autoscaler.events
            if self.autoscaler is not None else None,
            worker_spans={w.wid: (w.t_provisioned, w.t_retired)
                          for w in self.workers},
            worker_prices={w.wid: w.price for w in self.workers},
            phase_stats={
                w.wid: {"prefill_time": w.prefill_time,
                        "decode_time": w.decode_time,
                        "prefill_tokens": w.prefill_tokens,
                        "decode_tokens": w.decode_tokens,
                        "busy_time": w.busy_time}
                for w in self.workers},
            routing_stats=self._routing_stats(),
            remote_stats=self.remote_store.stats()
            if self.remote_store is not None else None,
            trace=self.obs.trace if self.obs is not None else None,
            timeseries=self.obs.ts if self.obs is not None else None)

    def _routing_stats(self) -> Optional[dict]:
        """Cluster-level cache-aware-routing counters (docs/ROUTING.md),
        None unless a prefix router or remote tier is active."""
        if self._prefix_router is None and self.remote_store is None:
            return None
        out = dict(self.fetch_stats)
        if self._prefix_router is not None:
            out.update(self._prefix_router.stats())
            out.update(self.prefix_registry.stats())
        return out


def simulate(spec: SimSpec) -> Results:
    return Simulation(spec).run()
