"""Deterministic discrete-event simulation kernel.

The paper builds TokenSim on SimPy; SimPy is unavailable offline, so this
is our own implementation of the same generator-process model.  It is
intentionally a strict subset of SimPy's API (``Environment``, ``process``,
``timeout``, ``event``, ``Store``) with one upgrade: **deterministic
tie-breaking**.  Events scheduled for the same simulated time fire in
``(time, priority, seq)`` order, where ``seq`` is a global monotonically
increasing counter — so a simulation is a pure function of its inputs,
which the validation tests (structural trace equality vs. the real engine)
rely on.

**Daemon events** (beyond the SimPy subset): events scheduled with
``daemon=True`` do not keep the simulation alive — ``run()`` exits once
only daemon events remain in the heap.  This is how the observability
sampler (repro.obs) ticks periodically without extending the
simulation: its wake-ups fire while real work is pending and evaporate
with it.  All pre-existing events are non-daemon, so simulations
without daemon users are untouched.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Any, Callable, Generator, List, Optional

NORMAL = 0
URGENT = -1  # fires before NORMAL events at the same timestamp


class Event:
    """A one-shot event; processes waiting on it resume when it succeeds."""

    __slots__ = ("env", "callbacks", "_value", "triggered", "processed", "ok")

    def __init__(self, env: "Environment"):
        self.env = env
        self.callbacks: List[Callable[["Event"], None]] = []
        self._value: Any = None
        self.triggered = False
        self.processed = False
        self.ok = True

    def wait(self, cb: Callable[["Event"], None]) -> None:
        """Attach a callback; fires immediately (rescheduled) if already
        processed — the SimPy semantics processes rely on.  Re-pushing
        ``self`` (the run loop swaps the callback list out on every pop)
        keeps the same (time, priority, seq) firing order as scheduling
        a fresh wrapper event, without allocating one — a measurable win
        on the million-wait hot path (see docs/PERFORMANCE.md)."""
        self.callbacks.append(cb)
        if self.processed:
            self.env._schedule(self, 0.0, NORMAL)

    @property
    def value(self):
        return self._value

    def succeed(self, value: Any = None, priority: int = NORMAL) -> "Event":
        if self.triggered:
            raise RuntimeError("event already triggered")
        self.triggered = True
        self._value = value
        self.env._schedule(self, 0.0, priority)
        return self

    def fail(self, exc: BaseException, priority: int = NORMAL) -> "Event":
        if self.triggered:
            raise RuntimeError("event already triggered")
        self.triggered = True
        self.ok = False
        self._value = exc
        self.env._schedule(self, 0.0, priority)
        return self


class Timeout(Event):
    def __init__(self, env: "Environment", delay: float, value: Any = None,
                 priority: int = NORMAL, *, daemon: bool = False):
        super().__init__(env)
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        self.triggered = True
        self._value = value
        env._schedule(self, delay, priority, daemon)


class Process(Event):
    """Drives a generator; the yielded events resume it."""

    __slots__ = ("gen", "name")

    def __init__(self, env: "Environment", gen: Generator, name: str = "",
                 daemon: bool = False):
        super().__init__(env)
        self.gen = gen
        self.name = name or getattr(gen, "__name__", "proc")
        init = Timeout(env, 0.0, priority=URGENT, daemon=daemon)
        init.callbacks.append(self._resume)

    def _resume(self, trigger: Event):
        try:
            if trigger.ok:
                target = self.gen.send(trigger.value)
            else:
                target = self.gen.throw(trigger.value)
        except StopIteration as stop:
            if not self.triggered:
                self.succeed(stop.value)
            return
        if not isinstance(target, Event):
            raise RuntimeError(
                f"process {self.name} yielded non-event {target!r}")
        target.wait(self._resume)


class Store:
    """FIFO store with blocking get, deterministic wakeup order."""

    __slots__ = ("env", "items", "_getters")

    def __init__(self, env: "Environment"):
        self.env = env
        self.items: List[Any] = []
        self._getters: List[Event] = []

    def put(self, item: Any) -> None:
        self.items.append(item)
        while self._getters and self.items:
            getter = self._getters.pop(0)
            getter.succeed(self.items.pop(0))

    def get(self) -> Event:
        ev = Event(self.env)
        if self.items:
            ev.succeed(self.items.pop(0))
        else:
            self._getters.append(ev)
        return ev

    def __len__(self):
        return len(self.items)


class Environment:
    def __init__(self):
        self.now: float = 0.0
        self._heap: List = []
        self._seq = itertools.count()
        #: pending non-daemon events; run() exits when this hits zero
        self._live = 0

    def _schedule(self, event: Event, delay: float, priority: int = NORMAL,
                  daemon: bool = False):
        if not daemon:
            self._live += 1
        # seq is globally unique, so the daemon flag is never compared
        heapq.heappush(self._heap,
                       (self.now + delay, priority, next(self._seq),
                        daemon, event))

    # -- SimPy-compatible surface ---------------------------------------
    def timeout(self, delay: float, value: Any = None, *,
                daemon: bool = False) -> Timeout:
        return Timeout(self, delay, value, daemon=daemon)

    def event(self) -> Event:
        return Event(self)

    def process(self, gen: Generator, name: str = "",
                daemon: bool = False) -> Process:
        return Process(self, gen, name, daemon)

    def run(self, until: Optional[float] = None) -> None:
        while self._heap and self._live:
            t, _, _, daemon, event = self._heap[0]
            if until is not None and t > until:
                self.now = until
                return
            heapq.heappop(self._heap)
            if not daemon:
                self._live -= 1
            self.now = t
            event.processed = True
            callbacks, event.callbacks = event.callbacks, []
            for cb in callbacks:
                cb(event)
        if until is not None:
            self.now = until


def all_of(env: Environment, events: List[Event]) -> Event:
    """Condition event that succeeds when every input event has."""
    done = env.event()
    remaining = [len(events)]
    if not events:
        return done.succeed([])

    def on_fire(_ev):
        remaining[0] -= 1
        if remaining[0] == 0:
            done.succeed([e.value for e in events])

    for e in events:
        if e.processed:
            remaining[0] -= 1
        else:
            e.wait(on_fire)
    if remaining[0] == 0 and not done.triggered:
        done.succeed([e.value for e in events])
    return done
