"""TokenSim core: the paper's contribution — a modular, extensible
discrete-event simulator for LLM inference systems.

Layers (bottom-up): engine (DES kernel) -> request/workload -> costmodel
(hardware + operator graph + backends) -> mem (block manager, memory
pool) -> comm -> sched (global/local) -> worker -> simulator facade.
"""
from repro.core.engine import Environment  # noqa: F401
from repro.core.mem import (BlockManager, MemoryConfig,  # noqa: F401
                            MemoryPool, PoolConfig, SwapConfig,
                            SwapManager)
from repro.core.request import Request, State  # noqa: F401
from repro.core.workload import (WorkloadSpec, generate,  # noqa: F401
                                 make_source, make_tenant_source)
from repro.core.metrics import (Results, StreamingStats,  # noqa: F401
                                jain_index)
from repro.core.faults import (ChaosSpec, FaultEvent,  # noqa: F401
                               FaultProcess, FaultSpec, FAULT_KINDS,
                               load_fault_trace)
from repro.core.simulator import (SimSpec, WorkerSpec,  # noqa: F401
                                  Simulation, simulate)
from repro.core.specdecode import (AcceptanceModel,  # noqa: F401
                                   SpecDecodeSpec)
from repro.core.tenancy import TenantSpec, TenantTier  # noqa: F401
