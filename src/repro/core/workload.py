"""Workload generation: dynamic request streams sampled from datasets.

The paper samples 2k–50k requests from ShareGPT.  ShareGPT itself is not
available offline, so the default workload is a **calibrated synthetic**:
log-normal prompt/output length marginals whose moments match the
published ShareGPT statistics used by the vLLM paper (mean prompt ≈ 161
tokens with a heavy tail clipped at 1024, mean output ≈ 338 — see
EXPERIMENTS.md for the exact calibration note), plus Poisson arrivals.
A JSONL trace loader with the identical interface covers users who do
have real traces, and fixed-length workloads reproduce the paper's
Table II / Fig. 7 setups.
"""
from __future__ import annotations

import json
import math
import random
import zlib
from dataclasses import dataclass, field, replace
from typing import Iterator, List, Optional, Sequence

from repro.core.request import Request

# Log-normal parameterization calibrated to ShareGPT moments:
#   prompt:  median exp(mu)=110, sigma=1.0  -> mean ~181, P99 ~1.1k
#   output:  median exp(mu)=215, sigma=0.95 -> mean ~338
SHAREGPT_PROMPT = (math.log(110.0), 1.0)
SHAREGPT_OUTPUT = (math.log(215.0), 0.95)


@dataclass
class WorkloadSpec:
    num_requests: int = 1000
    qps: float = 4.0                     # Poisson arrival rate; 0 => all at t=0
    seed: int = 0

    # length model: "sharegpt" | "fixed" | "lognormal" | "trace"
    lengths: str = "sharegpt"
    prompt_len: int = 128                # fixed mode
    output_len: int = 128
    prompt_lognormal: tuple = SHAREGPT_PROMPT
    output_lognormal: tuple = SHAREGPT_OUTPUT
    max_prompt_len: int = 1024
    max_output_len: int = 1024
    trace_path: Optional[str] = None

    # multi-round conversations (Fig. 14): fraction of sessions with >1
    # round; rounds ~ Uniform[min,max]; think time between rounds.
    multi_round_frac: float = 0.0
    rounds_min: int = 2
    rounds_max: int = 7
    think_time_mean: float = 2.0


def _sample_len(rng: random.Random, spec: WorkloadSpec, which: str) -> int:
    if spec.lengths == "fixed":
        return spec.prompt_len if which == "prompt" else spec.output_len
    mu, sigma = (spec.prompt_lognormal if which == "prompt"
                 else spec.output_lognormal)
    cap = spec.max_prompt_len if which == "prompt" else spec.max_output_len
    return max(1, min(cap, int(rng.lognormvariate(mu, sigma))))


def generate(spec: WorkloadSpec) -> List[Request]:
    """Materialize the full request list (sorted by arrival time)."""
    rng = random.Random(spec.seed)
    reqs: List[Request] = []

    if spec.lengths == "trace":
        assert spec.trace_path, "trace workload needs trace_path"
        with open(spec.trace_path) as f:
            for i, line in enumerate(f):
                if i >= spec.num_requests:
                    break
                rec = json.loads(line)
                reqs.append(Request(
                    id=i, arrival_time=float(rec.get("arrival", 0.0)),
                    prompt_len=int(rec["prompt_len"]),
                    output_len=int(rec["output_len"]),
                    session_id=rec.get("session_id"),
                    round_idx=int(rec.get("round", 0))))
        reqs.sort(key=lambda r: (r.arrival_time, r.id))
        return reqs

    t = 0.0
    rid = 0
    sid = 0
    n_emitted = 0
    while n_emitted < spec.num_requests:
        if spec.qps > 0:
            t += rng.expovariate(spec.qps)
        arrival = t

        n_rounds = 1
        if spec.multi_round_frac > 0 and rng.random() < spec.multi_round_frac:
            n_rounds = rng.randint(spec.rounds_min, spec.rounds_max)
        sid += 1
        history = 0
        rt = arrival
        for r in range(n_rounds):
            if n_emitted >= spec.num_requests:
                break
            p = _sample_len(rng, spec, "prompt")
            o = _sample_len(rng, spec, "output")
            reqs.append(Request(
                id=rid, arrival_time=rt, prompt_len=history + p,
                output_len=o, session_id=sid, round_idx=r,
                history_len=history))
            rid += 1
            n_emitted += 1
            history += p + o
            rt += rng.expovariate(1.0 / spec.think_time_mean) \
                if spec.think_time_mean > 0 else 0.0
    reqs.sort(key=lambda r: (r.arrival_time, r.id))
    for i, r in enumerate(reqs):
        r.id = i                          # stable ids in arrival order
    return reqs


def generate_multi(tenants: Sequence) -> List[Request]:
    """Merge per-tenant workloads into one deterministic arrival stream.

    ``tenants`` is a sequence of ``repro.core.tenancy.TenantSpec`` (held
    duck-typed here to keep the workload layer tenancy-agnostic).  Each
    tenant's stream is generated with a seed decorrelated by a stable
    hash of its id, stamped with the tenant's identity and QoS tags, and
    the union is re-sorted into a single arrival order with stable ids.
    """
    reqs: List[Request] = []
    order = {t.tenant_id: i for i, t in enumerate(tenants)}
    if len(order) != len(tenants):
        raise ValueError("duplicate tenant_id in tenant specs")
    for t in tenants:
        ws = t.workload
        sub = generate(replace(
            ws, seed=ws.seed ^ zlib.crc32(t.tenant_id.encode())))
        for r in sub:
            r.tenant_id = t.tenant_id
            r.priority = t.tier.priority
            r.weight = t.tier.weight
            if r.session_id is not None:
                # keep sessions distinct across tenants
                r.session_id = r.session_id * len(tenants) \
                    + order[t.tenant_id]
        reqs.extend(sub)
    reqs.sort(key=lambda r: (r.arrival_time, order[r.tenant_id], r.id))
    for i, r in enumerate(reqs):
        r.id = i
    return reqs


def save_trace(reqs: List[Request], path: str) -> None:
    with open(path, "w") as f:
        for r in reqs:
            f.write(json.dumps({
                "arrival": r.arrival_time, "prompt_len": r.prompt_len,
                "output_len": r.output_len, "session_id": r.session_id,
                "round": r.round_idx}) + "\n")
