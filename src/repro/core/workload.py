"""Workload generation: lazy request streams sampled from datasets.

The paper samples 2k–50k requests from ShareGPT.  ShareGPT itself is not
available offline, so the default workload is a **calibrated synthetic**:
log-normal prompt/output length marginals whose moments match the
published ShareGPT statistics used by the vLLM paper (mean prompt ≈ 161
tokens with a heavy tail clipped at 1024, mean output ≈ 338 — see
EXPERIMENTS.md for the exact calibration note).  A JSONL trace loader
with the identical interface covers users who do have real traces, and
fixed-length workloads reproduce the paper's Table II / Fig. 7 setups.

Arrival processes (``WorkloadSpec.arrival``, see docs/WORKLOADS.md)
cover the serving-survey taxonomy: plain Poisson, bursty MMPP on-off,
diurnal sinusoid (thinned Poisson), and trace replay.

The primary interface is the lazy :class:`RequestSource` iterator
protocol — ``make_source(spec)`` / ``make_tenant_source(tenants)``
yield ``Request`` objects in nondecreasing arrival order with O(live
sessions) memory, so million-request simulations never materialize the
full list.  ``generate()`` / ``generate_multi()`` remain as thin
materializing wrappers for callers that want the full sorted list.
"""
from __future__ import annotations

import heapq
import json
import math
import random
import zlib
from dataclasses import dataclass, replace
from typing import Iterator, List, Optional, Sequence

from repro.core.request import Request

# Log-normal parameterization calibrated to ShareGPT moments:
#   prompt:  median exp(mu)=110, sigma=1.0  -> mean ~181, P99 ~1.1k
#   output:  median exp(mu)=215, sigma=0.95 -> mean ~338
SHAREGPT_PROMPT = (math.log(110.0), 1.0)
SHAREGPT_OUTPUT = (math.log(215.0), 0.95)

#: length models accepted by ``WorkloadSpec.lengths`` (docs/WORKLOADS.md)
LENGTH_KINDS = ("sharegpt", "lognormal", "fixed", "trace")
#: arrival processes accepted by ``WorkloadSpec.arrival``
ARRIVAL_KINDS = ("poisson", "bursty", "diurnal", "trace")


@dataclass
class WorkloadSpec:
    num_requests: int = 1000
    qps: float = 4.0                     # mean arrival rate; 0 => all at t=0
    seed: int = 0

    #: model this traffic targets (docs/HETEROGENEITY.md): stamped on
    #: every generated request so a model-aware global policy only
    #: dispatches it to workers hosting that model.  None = the
    #: simulation's default arch.  Multi-model mixes merge per-model
    #: workloads through the tenant-source machinery
    #: (``make_tenant_source``), each tenant carrying its own ``model``
    model: Optional[str] = None

    # arrival process: "poisson" | "bursty" | "diurnal" | "trace"
    arrival: str = "poisson"
    # bursty (MMPP on-off): exponential phase durations; the arrival rate
    # is qps*burst_on_scale during ON phases, qps*burst_off_scale during
    # OFF phases (defaults keep the long-run mean rate at ~qps when
    # on/off phases have equal mean duration)
    burst_on_mean: float = 10.0
    burst_off_mean: float = 10.0
    burst_on_scale: float = 1.8
    burst_off_scale: float = 0.2
    # diurnal sinusoid: rate(t) = qps * (1 + amplitude*sin(2πt/period)),
    # sampled exactly via Lewis-Shedler thinning
    diurnal_period: float = 3600.0
    diurnal_amplitude: float = 0.8

    # length model: "sharegpt" | "fixed" | "lognormal" | "trace"
    lengths: str = "sharegpt"
    prompt_len: int = 128                # fixed mode
    output_len: int = 128
    prompt_lognormal: tuple = SHAREGPT_PROMPT
    output_lognormal: tuple = SHAREGPT_OUTPUT
    max_prompt_len: int = 1024
    max_output_len: int = 1024
    trace_path: Optional[str] = None

    # multi-round conversations (Fig. 14): fraction of sessions with >1
    # round; rounds ~ Uniform[min,max]; think time between rounds.
    multi_round_frac: float = 0.0
    rounds_min: int = 2
    rounds_max: int = 7
    think_time_mean: float = 2.0

    # shared-prefix workloads (docs/MEMORY.md): every session carries a
    # common system prompt of ``shared_prefix_len`` tokens (added to its
    # first-round prompt) drawn from one of ``shared_prefix_groups``
    # distinct prefixes; requests expose it as (prefix_id, prefix_len)
    # so a prefix-sharing BlockManager can share the resident blocks
    shared_prefix_len: int = 0
    shared_prefix_groups: int = 1


def _sample_len(rng: random.Random, spec: WorkloadSpec, which: str) -> int:
    if spec.lengths == "fixed":
        return spec.prompt_len if which == "prompt" else spec.output_len
    mu, sigma = (spec.prompt_lognormal if which == "prompt"
                 else spec.output_lognormal)
    cap = spec.max_prompt_len if which == "prompt" else spec.max_output_len
    return max(1, min(cap, int(rng.lognormvariate(mu, sigma))))


# ---------------------------------------------------------------------------
# arrival processes: iterators of absolute arrival times
# ---------------------------------------------------------------------------
def _poisson_times(rng: random.Random, spec: WorkloadSpec) -> Iterator[float]:
    t = 0.0
    while True:
        if spec.qps > 0:
            t += rng.expovariate(spec.qps)
        yield t


def _bursty_times(rng: random.Random, spec: WorkloadSpec) -> Iterator[float]:
    """MMPP on-off: Poisson arrivals whose rate switches between
    qps*burst_on_scale and qps*burst_off_scale at exponential phase
    boundaries.  Memorylessness makes redrawing the gap at each phase
    switch an exact simulation of the modulated process."""
    if spec.qps <= 0:
        while True:
            yield 0.0
    t = 0.0
    on = True
    phase_end = rng.expovariate(1.0 / max(spec.burst_on_mean, 1e-9))
    while True:
        rate = spec.qps * (spec.burst_on_scale if on
                           else spec.burst_off_scale)
        if rate <= 0:
            t = phase_end
        else:
            gap = rng.expovariate(rate)
            if t + gap <= phase_end:
                t += gap
                yield t
                continue
            t = phase_end
        on = not on
        mean = spec.burst_on_mean if on else spec.burst_off_mean
        phase_end = t + rng.expovariate(1.0 / max(mean, 1e-9))


def _diurnal_times(rng: random.Random, spec: WorkloadSpec) -> Iterator[float]:
    """Sinusoid-modulated Poisson via Lewis-Shedler thinning: propose at
    the peak rate, accept with probability rate(t)/peak."""
    if spec.qps <= 0:
        while True:
            yield 0.0
    amp = min(max(spec.diurnal_amplitude, 0.0), 0.999)
    peak = spec.qps * (1.0 + amp)
    omega = 2.0 * math.pi / max(spec.diurnal_period, 1e-9)
    t = 0.0
    while True:
        t += rng.expovariate(peak)
        rate = spec.qps * (1.0 + amp * math.sin(omega * t))
        if rng.random() * peak <= rate:
            yield t


_ARRIVAL_ITERS = {"poisson": _poisson_times, "bursty": _bursty_times,
                  "diurnal": _diurnal_times}


# ---------------------------------------------------------------------------
# RequestSource protocol: lazy, arrival-ordered request iterators
# ---------------------------------------------------------------------------
class RequestSource:
    """Iterable of ``Request`` objects in nondecreasing arrival order.

    Sources are lazy: the dispatcher pulls one request at a time, so
    memory stays O(live sessions) rather than O(num_requests).  Iterating
    a source twice restarts it from its seed (pure function of the spec).
    """

    def __iter__(self) -> Iterator[Request]:
        raise NotImplementedError


class SyntheticSource(RequestSource):
    """Sampled workload: a configured arrival process plus length model,
    with multi-round sessions held in a small pending heap (future
    rounds re-enter the stream at their think-time arrival)."""

    def __init__(self, spec: WorkloadSpec):
        if spec.arrival not in _ARRIVAL_ITERS:
            hint = " (trace replay is TraceSource; build via " \
                "make_source)" if spec.arrival == "trace" else ""
            raise ValueError(f"SyntheticSource cannot sample arrival "
                             f"kind {spec.arrival!r}{hint}; have "
                             f"{sorted(_ARRIVAL_ITERS)}")
        if spec.lengths not in LENGTH_KINDS or spec.lengths == "trace":
            raise ValueError(f"SyntheticSource cannot sample length "
                             f"model {spec.lengths!r}")
        self.spec = spec

    def __iter__(self) -> Iterator[Request]:
        spec = self.spec
        rng = random.Random(spec.seed)
        times = _ARRIVAL_ITERS[spec.arrival](rng, spec)
        # (arrival, generation order, request): sessions arrive at
        # nondecreasing base times, so once the next session's base
        # arrival is known every pending entry at or before it is final
        pending: List[tuple] = []
        rid = 0
        sid = 0
        out_id = 0
        n_emitted = 0
        while n_emitted < spec.num_requests:
            arrival = next(times)
            while pending and pending[0][0] <= arrival:
                _, _, req = heapq.heappop(pending)
                req.id = out_id
                out_id += 1
                yield req

            n_rounds = 1
            if spec.multi_round_frac > 0 \
                    and rng.random() < spec.multi_round_frac:
                n_rounds = rng.randint(spec.rounds_min, spec.rounds_max)
            sid += 1
            prefix_id = None
            if spec.shared_prefix_len > 0:
                # one system prompt per session; groups share content
                prefix_id = rng.randrange(
                    max(1, spec.shared_prefix_groups))
            history = 0
            rt = arrival
            for r in range(n_rounds):
                if n_emitted >= spec.num_requests:
                    break
                p = _sample_len(rng, spec, "prompt")
                if r == 0 and prefix_id is not None:
                    p += spec.shared_prefix_len   # system prompt up front
                o = _sample_len(rng, spec, "output")
                heapq.heappush(pending, (rt, rid, Request(
                    id=rid, arrival_time=rt, prompt_len=history + p,
                    output_len=o, session_id=sid, round_idx=r,
                    history_len=history, prefix_id=prefix_id,
                    prefix_len=spec.shared_prefix_len
                    if prefix_id is not None else 0,
                    model=spec.model)))
                rid += 1
                n_emitted += 1
                history += p + o
                rt += rng.expovariate(1.0 / spec.think_time_mean) \
                    if spec.think_time_mean > 0 else 0.0
        while pending:
            _, _, req = heapq.heappop(pending)
            req.id = out_id
            out_id += 1
            yield req


def _parse_trace_record(i: int, rec: dict,
                        model: Optional[str] = None) -> Request:
    """One JSONL trace line -> Request (the ``save_trace`` field set);
    shared by streaming replay and the materializing ``generate()`` so
    the two modes cannot drift on trace semantics.  A per-record
    ``model`` field wins over the workload-level default."""
    return Request(
        id=i, arrival_time=float(rec.get("arrival", 0.0)),
        prompt_len=int(rec["prompt_len"]),
        output_len=int(rec["output_len"]),
        session_id=rec.get("session_id"),
        round_idx=int(rec.get("round", 0)),
        prefix_id=rec.get("prefix_id"),
        prefix_len=int(rec.get("prefix_len", 0)),
        model=rec.get("model", model))


class TraceSource(RequestSource):
    """Replay a JSONL trace lazily (one line per request; fields
    ``arrival``, ``prompt_len``, ``output_len``, optional ``session_id``
    / ``round`` — the ``save_trace`` format).  Streaming replay requires
    nondecreasing arrivals; for unsorted traces use ``generate()``,
    which materializes and sorts."""

    def __init__(self, spec: WorkloadSpec):
        assert spec.trace_path, "trace workload needs trace_path"
        self.spec = spec

    def __iter__(self) -> Iterator[Request]:
        spec = self.spec
        last = -math.inf
        with open(spec.trace_path) as f:
            for i, line in enumerate(f):
                if i >= spec.num_requests:
                    break
                req = _parse_trace_record(i, json.loads(line), spec.model)
                if req.arrival_time < last:
                    raise ValueError(
                        f"{spec.trace_path}:{i + 1}: arrivals not sorted "
                        f"({req.arrival_time} after {last}); sort the "
                        f"trace or use workload.generate()")
                last = req.arrival_time
                yield req


class MergedSource(RequestSource):
    """Heap-merge of per-tenant sources into one arrival-ordered stream.

    Each tenant's sub-stream keeps its internal order (per-tenant ids
    are strictly increasing within a tenant); ties at equal arrival time
    break by tenant declaration order, then per-tenant id — the same
    total order ``generate_multi`` produces by sorting.  Global ids are
    reassigned sequentially in emission order, so ids are stable and
    dense regardless of how many requests are ultimately pulled.
    """

    def __init__(self, tenants: Sequence):
        order = {t.tenant_id: i for i, t in enumerate(tenants)}
        if len(order) != len(tenants):
            raise ValueError("duplicate tenant_id in tenant specs")
        self.tenants = list(tenants)
        self._order = order

    def _tenant_stream(self, t) -> Iterator[Request]:
        ws = t.workload
        sub_spec = replace(ws, seed=ws.seed ^ zlib.crc32(
            t.tenant_id.encode()))
        if ws.lengths == "trace" or ws.arrival == "trace":
            # traces may be unsorted on disk and the merge needs each
            # tenant stream arrival-ordered: materialize-and-sort this
            # tenant (the pre-streaming generate_multi behaviour); the
            # other tenants stay lazy
            sub = iter(generate(sub_spec))
        else:
            sub = make_source(sub_spec)
        n = len(self.tenants)
        for r in sub:
            r.tenant_id = t.tenant_id
            r.priority = t.tier.priority
            r.weight = t.tier.weight
            if r.session_id is not None:
                # keep sessions distinct across tenants
                r.session_id = r.session_id * n + self._order[t.tenant_id]
            if r.prefix_id is not None:
                # system prompts are tenant-private: never share across
                r.prefix_id = r.prefix_id * n + self._order[t.tenant_id]
            yield r

    def __iter__(self) -> Iterator[Request]:
        order = self._order
        merged = heapq.merge(
            *(self._tenant_stream(t) for t in self.tenants),
            key=lambda r: (r.arrival_time, order[r.tenant_id], r.id))
        for i, r in enumerate(merged):
            r.id = i
            yield r


def make_source(spec: WorkloadSpec) -> RequestSource:
    """Build the lazy request source for a workload spec."""
    if spec.lengths == "trace" or spec.arrival == "trace":
        return TraceSource(spec)
    return SyntheticSource(spec)


def make_tenant_source(tenants: Sequence) -> RequestSource:
    """Heap-merged lazy source over per-tenant workloads.

    ``tenants`` is a sequence of ``repro.core.tenancy.TenantSpec`` (held
    duck-typed here to keep the workload layer tenancy-agnostic).  Each
    tenant's stream is generated with a seed decorrelated by a stable
    hash of its id and stamped with the tenant's identity and QoS tags.
    """
    return MergedSource(tenants)


# ---------------------------------------------------------------------------
# materializing wrappers (backward-compatible list interface)
# ---------------------------------------------------------------------------
def generate(spec: WorkloadSpec) -> List[Request]:
    """Materialize the full request list (sorted by arrival time)."""
    if spec.lengths == "trace" or spec.arrival == "trace":
        # traces may be unsorted on disk: materialize then sort, keeping
        # line-index ids (the seed behaviour streaming replay forbids)
        assert spec.trace_path, "trace workload needs trace_path"
        reqs: List[Request] = []
        with open(spec.trace_path) as f:
            for i, line in enumerate(f):
                if i >= spec.num_requests:
                    break
                reqs.append(_parse_trace_record(i, json.loads(line),
                                                spec.model))
        reqs.sort(key=lambda r: (r.arrival_time, r.id))
        return reqs
    return list(SyntheticSource(spec))


def generate_multi(tenants: Sequence) -> List[Request]:
    """Materialize the merged multi-tenant stream (see MergedSource)."""
    return list(MergedSource(tenants))


def save_trace(reqs: List[Request], path: str) -> None:
    with open(path, "w") as f:
        for r in reqs:
            rec = {"arrival": r.arrival_time, "prompt_len": r.prompt_len,
                   "output_len": r.output_len, "session_id": r.session_id,
                   "round": r.round_idx}
            if r.prefix_id is not None:
                # shared-prefix tags round-trip (docs/MEMORY.md); plain
                # workloads keep the seed trace format byte-identical
                rec["prefix_id"] = r.prefix_id
                rec["prefix_len"] = r.prefix_len
            if r.model is not None:
                # model tags round-trip (docs/HETEROGENEITY.md); plain
                # workloads keep the seed trace format byte-identical
                rec["model"] = r.model
            f.write(json.dumps(rec) + "\n")
