"""Topology-aware collective cost model (docs/PARALLELISM.md).

Analytic alpha-beta costs for the collectives the parallelism layer
charges: ring all-reduce for tensor-parallel activation reduction and
point-to-point send/recv for pipeline-stage activation hand-off.  Each
primitive is costed against one :class:`~repro.core.comm.LinkSpec`
(latency + bytes/bandwidth per hop); *which* link applies is a topology
question answered by ``ClusterSpec`` placement helpers below, so tensor
parallelism stops being free at high degree: a TP group that spills past
``gpus_per_node`` pays inter-node latency and bandwidth on every hop.

Placement model (documented assumption): devices of one replica are
numbered consecutively, pipeline stage ``s`` of a ``tp x pp`` replica
owns devices ``[s*tp, (s+1)*tp)``, and nodes hold ``gpus_per_node``
consecutive devices — the standard "TP innermost, PP across" layout.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

from repro.core.comm import LinkSpec

if TYPE_CHECKING:                        # avoid cycle: hardware imports comm
    from repro.core.costmodel.hardware import ClusterSpec


def p2p_time(nbytes: float, link: LinkSpec) -> float:
    """One point-to-point message: latency + bytes/bandwidth.

    Zero-byte sends cost nothing (no message is posted) — the
    engine-level :class:`~repro.core.comm.Link` keeps its "latency even
    for empty payloads" semantics for explicit transfers; this analytic
    model is called per planned hand-off and must not charge for stages
    that exchange no activations."""
    if nbytes <= 0:
        return 0.0
    return link.latency + nbytes / link.bandwidth


def ring_allreduce_time(nbytes: float, n_ranks: int,
                        link: LinkSpec) -> float:
    """Ring all-reduce of ``nbytes`` (the full tensor) over ``n_ranks``.

    2*(n-1) pipelined steps (reduce-scatter + all-gather), each moving
    ``nbytes / n`` per rank over the slowest link in the ring:

        T = 2 * (n - 1) * (link.latency + nbytes / n / link.bandwidth)

    The bandwidth term equals the classic ``2*(n-1)/n * nbytes / bw``
    optimal-ring volume; the latency term is what makes high TP degree
    expensive on high-latency links."""
    if n_ranks <= 1 or nbytes <= 0:
        return 0.0
    return 2 * (n_ranks - 1) * (link.latency
                                + nbytes / n_ranks / link.bandwidth)


def tp_group_link(cluster: "ClusterSpec", tp: int,
                  stage: int = 0) -> LinkSpec:
    """Link the TP ring of pipeline stage ``stage`` traverses: under the
    consecutive-placement model the stage owns devices
    ``[stage*tp, (stage+1)*tp)``, and the ring pays the inter-node link
    as soon as that range straddles a node boundary (the slowest hop
    bounds every pipelined ring step) — which also covers mis-aligned
    groups where ``tp`` does not divide ``gpus_per_node``."""
    gpn = max(1, cluster.gpus_per_node)
    if (stage * tp) // gpn != ((stage + 1) * tp - 1) // gpn:
        return cluster.inter_link
    return cluster.intra_link


def stage_boundary_link(cluster: "ClusterSpec", tp: int,
                        stage: int) -> LinkSpec:
    """Link carrying activations from pipeline stage ``stage`` to
    ``stage + 1`` under the consecutive-placement model: the hand-off is
    from the last device of ``stage`` to the first device of
    ``stage + 1``, so it crosses nodes exactly when those two adjacent
    devices live on different nodes."""
    gpn = max(1, cluster.gpus_per_node)
    last_dev = (stage + 1) * tp - 1
    if last_dev // gpn != (last_dev + 1) // gpn:
        return cluster.inter_link
    return cluster.intra_link
