"""Communication model: inter-worker data movement (paper §III-B).

Models links by (bandwidth, latency); a transfer's duration is
``latency + bytes / bandwidth``.  Transfers run as engine processes, so
they naturally overlap with compute, and a link can be configured to
serialize (one transfer at a time, the paper's "default method") or to
pipeline through a bounded preload buffer (the paper's overlap study):
with ``buffer_chunks > 1`` up to that many chunks are in flight at once.

:mod:`repro.core.comm.collectives` builds on the same ``LinkSpec``
abstraction to price parallelism collectives analytically — ring
all-reduce for tensor parallelism and p2p send/recv for pipeline-stage
hand-off — with topology (intra-node vs inter-node link selection)
supplied by ``costmodel.hardware.ClusterSpec`` (docs/PARALLELISM.md).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core.engine import Environment, Event


@dataclass(frozen=True)
class LinkSpec:
    name: str
    bandwidth: float              # bytes/s
    latency: float = 5e-6         # per-message
    serialize: bool = True        # one transfer at a time (default)
    buffer_chunks: int = 1        # >1 enables preload-buffer pipelining
    chunk_bytes: float = 16 * 2 ** 20


NVLINK = LinkSpec("NVLink", 300e9, 3e-6)
PCIE4 = LinkSpec("PCIe4x16", 32e9, 8e-6)
ETH100G = LinkSpec("Eth100G", 12.5e9, 30e-6)
ICI = LinkSpec("ICI", 50e9, 2e-6)
DCN = LinkSpec("DCN", 6.25e9, 50e-6)

LINKS = {l.name: l for l in [NVLINK, PCIE4, ETH100G, ICI, DCN]}


class Link:
    """A shared link with optional serialization and chunk pipelining."""

    def __init__(self, env: Environment, spec: LinkSpec):
        self.env = env
        self.spec = spec
        self._busy_until = 0.0
        self.bytes_moved = 0.0
        self.transfers = 0

    def transfer_time(self, nbytes: float) -> float:
        s = self.spec
        if s.buffer_chunks <= 1 or nbytes <= s.chunk_bytes:
            return s.latency + nbytes / s.bandwidth
        # pipelined chunks: receiver-side store overlaps next load; with a
        # deep enough buffer the pipeline is bandwidth-bound + one fill.
        n_chunks = -(-nbytes // s.chunk_bytes)
        fill = min(n_chunks, s.buffer_chunks) * s.latency
        return fill + nbytes / s.bandwidth

    def transfer(self, nbytes: float) -> Event:
        """Schedule a transfer; returns the completion event."""
        t = self.transfer_time(nbytes)
        now = self.env.now
        if self.spec.serialize:
            start = max(now, self._busy_until)
            self._busy_until = start + t
            done_in = (start + t) - now
        else:
            done_in = t
        self.bytes_moved += nbytes
        self.transfers += 1
        return self.env.timeout(done_in)


# imported last: collectives pulls LinkSpec back out of this module
from repro.core.comm.collectives import (  # noqa: E402,F401
    p2p_time, ring_allreduce_time, stage_boundary_link, tp_group_link)
