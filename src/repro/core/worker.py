"""Simulated inference worker: one accelerator running an iteration loop.

The worker is a DES process: it asks its local scheduler for an
``IterationPlan``, charges the cost model for the batch, advances
simulated time, then applies the plan's effects (token emission, KV
growth, finishes, preemptions) and fires breakpoints.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, List, Optional

from repro.core.breakpoints import Hooks
from repro.core.costmodel.backends import CostBackend
from repro.core.costmodel.hardware import HardwareSpec
from repro.core.costmodel.operators import BatchMix
from repro.core.engine import Environment, Event
from repro.core.mem.block_manager import BlockManager, MemoryConfig
from repro.core.mem.memory_pool import MemoryPool
from repro.core.mem.swap import SwapManager
from repro.core.request import Request, State
from repro.core.sched.local import LocalScheduler
from repro.obs.timeseries import BoundedSeries


#: mem_timeline length at which the sampling stride doubles (bounds the
#: timeline's memory on long runs; sub-cap runs record every iteration)
MEM_TIMELINE_CAP = 8192


@dataclass
class MemSample:
    t: float
    used_blocks: int
    used_bytes: float
    n_running: int


class Worker:
    def __init__(self, env: Environment, wid: int, hw: HardwareSpec,
                 backend: CostBackend, mem_cfg: MemoryConfig,
                 sched: LocalScheduler, *, run_prefill: bool = True,
                 run_decode: bool = True, cluster=None,
                 pool: Optional[MemoryPool] = None,
                 hooks: Optional[Hooks] = None,
                 enc_tokens_per_req: int = 0,
                 discipline=None, spec_decode=None,
                 draft_backend: Optional[CostBackend] = None,
                 swap: Optional[SwapManager] = None,
                 obs=None, model: Optional[str] = None, tp: int = 1):
        self.env = env
        self.wid = wid
        self.hw = hw
        #: model this worker hosts (docs/HETEROGENEITY.md); None = hosts
        #: anything (homogeneous fleets and bare unit-test workers)
        self.model = model
        #: resolved tensor-parallel degree (per-worker override wins
        #: over the cluster ParallelSpec) — mirrored here so the price
        #: model can be pinned against the built fleet
        self.tp = tp
        self.backend = backend
        self.mem = BlockManager(mem_cfg)
        self.sched = sched
        self.run_prefill = run_prefill
        self.run_decode = run_decode
        self.cluster = cluster
        self.pool = pool
        self.hooks = hooks or Hooks()
        self.enc_tokens_per_req = enc_tokens_per_req
        #: tenant-aware queue ordering (repro.core.tenancy.qos); None=FIFO
        self.discipline = discipline
        #: speculative decoding (repro.core.specdecode); None = disabled
        self.spec_decode = spec_decode
        self.draft_backend = draft_backend
        #: host-DRAM KV tier (repro.core.mem.swap); when set, preemption
        #: swaps victims' KV out over PCIe instead of discarding it
        self.swap = swap
        #: observability hub (repro.obs.ObsRecorder); None = all taps
        #: collapse to one attribute load + is-None check per iteration
        self.obs = obs
        self._spec_rng = spec_decode.rng_for_worker(wid) \
            if spec_decode is not None else None

        self.waiting: Deque[Request] = deque()
        self.running: List[Request] = []
        #: every distinct request model ever submitted here — the
        #: no-cross-model-dispatch invariant in tests/test_hetero_fleet.py
        #: asserts this stays within {self.model}
        self.served_models: set = set()
        self.alive = True
        self.slowdown = 1.0
        #: draining (repro.core.faults): alive and finishing its queue,
        #: but skipped by the global scheduler for new dispatches
        self.draining = False
        #: autoscaler lifecycle (repro.core.autoscale): a provisioning
        #: worker was just added and is paying its model-load lag
        #: (alive=False keeps it out of every dispatch path, including
        #: the _eligible alive-fallback); retiring = draining toward
        #: permanent removal; retired = out of the serving set for
        #: good, stats frozen, billing stopped
        self.provisioning = False
        self.retiring = False
        self.retired = False
        #: billing surface (explore.uptime_weighted_price): per-device
        #: price x devices, stamped by the simulator's worker builder,
        #: and the provisioned-to-retired span actually billed
        self.price = 0.0
        self.t_provisioned = env.now
        self.t_retired: Optional[float] = None
        #: the WorkerSpec this worker was built from (None for bare
        #: unit-test workers); the autoscaler manages template-equal ones
        self.spec_ws = None
        #: post-recovery warm-up (docs/RELIABILITY.md): the next
        #: ``_warmup_left`` iterations cost ``_warmup_factor``x
        self._warmup_left = 0
        self._warmup_factor = 1.0
        #: bumped by fail(); an iteration in flight across a failure
        #: compares epochs after its timeout and discards its effects
        #: (the batch died with the device)
        self._fail_epoch = 0
        #: memory-over-time samples under stride-doubling decimation
        #: (repro.obs.timeseries.BoundedSeries): bounded on
        #: million-iteration runs, every iteration below the cap
        self._mem_series = BoundedSeries(MEM_TIMELINE_CAP)
        #: incrementally maintained load_tokens halves; each tracked
        #: request stores its charge so enqueue/dequeue stay O(1) even
        #: if its prefill/context state changes while tracked (e.g. a
        #: pool prefix hit before admission)
        self._waiting_load = 0
        self._running_load = 0
        self.iterations = 0
        self.busy_time = 0.0
        #: busy_time split by phase, each iteration's cost allocated
        #: proportionally to its prefill vs decode token counts — the
        #: basis of the $/1M-tokens prefill/decode split
        #: (Results.scaling_summary, docs/AUTOSCALING.md)
        self.prefill_time = 0.0
        self.decode_time = 0.0
        self.prefill_tokens = 0
        self.decode_tokens = 0
        #: cheap cumulative counters the time-series recorder samples
        self.tokens_emitted = 0
        self.preempt_events = 0
        #: pipeline-parallel accounting (docs/PARALLELISM.md): cumulative
        #: fill/drain bubble, stage-boundary p2p comm, and pipeline span
        #: (step time x steps, framework overhead excluded) — so
        #: bubble/span can be checked against the closed-form fraction
        self.pp_bubble_time = 0.0
        self.pp_comm_time = 0.0
        self.pp_span_time = 0.0
        self._wake: Optional[Event] = None
        self.proc = env.process(self._run(), name=f"worker{wid}")

    @property
    def mem_timeline(self) -> List[MemSample]:
        return self._mem_series.rows

    # ------------------------------------------------------------------
    def _enqueue(self, req: Request, *, front: bool = False) -> None:
        charge = max(1, req.remaining_prefill) + 1
        req._load_charge = charge
        self._waiting_load += charge
        if front:
            self.waiting.appendleft(req)
        else:
            self.waiting.append(req)

    def submit(self, req: Request) -> None:
        req.worker_id = self.wid
        req.state = State.WAITING
        if req.model is not None:
            self.served_models.add(req.model)
        self._enqueue(req)
        self._wakeup()

    def receive_migrated(self, req: Request) -> None:
        """Request arrives with its KV already computed elsewhere: blocks
        for the full context are allocated at admission; no prefill."""
        req.worker_id = self.wid
        req.state = State.WAITING
        if req.model is not None:
            self.served_models.add(req.model)
        req.prefill_done_len = req.prefill_target
        self._enqueue(req)
        self._wakeup()

    def next_waiting(self) -> Optional[Request]:
        """Head of the waiting queue under the active discipline."""
        if not self.waiting:
            return None
        if self.discipline is None:
            return self.waiting[0]
        return self.discipline.select(self.waiting, self.env.now)

    def pop_waiting(self, req: Request) -> None:
        self.waiting.remove(req)
        self._waiting_load -= req._load_charge
        req._load_charge = 0

    def victim_sort_key(self):
        """Ascending sort key such that the END of the sorted running
        list is the first preemption victim."""
        if self.discipline is None:
            return lambda r: (r.arrival_time, r.id)
        return self.discipline.victim_key(self.env.now)

    def load_tokens(self) -> int:
        """Dispatch-load heuristic: queued work plus running context
        pressure, both maintained incrementally so the global
        scheduler's per-request scan of all workers stays O(1) each."""
        return self._waiting_load + self._running_load

    def _charge_running(self, req: Request) -> None:
        c = 1 + req.context_len // 256
        req._run_charge = c
        self._running_load += c

    def _uncharge_running(self, req: Request) -> None:
        self._running_load -= req._run_charge
        req._run_charge = 0

    def _wakeup(self):
        if self._wake is not None and not self._wake.triggered:
            self._wake.succeed()

    # ------------------------------------------------------------------
    def _run(self):
        env = self.env
        while True:
            if not self.alive:
                self._wake = env.event()
                yield self._wake
                continue
            self.hooks.fire("before_sched", self)
            plan = self.sched.plan(self)
            if plan.empty:
                self._wake = env.event()
                yield self._wake
                continue

            for req in plan.admitted:
                req.state = State.PREFILL if req.remaining_prefill else \
                    State.DECODE
                if req not in self.running:
                    self.running.append(req)
                    self._charge_running(req)
                if self.discipline is not None:
                    self.discipline.on_service_start(req, env.now)
                self.hooks.fire("on_admit", self, req)
            obs = self.obs
            for req in plan.preempted:
                req.state = State.PREEMPTED
                self.preempt_events += 1
                if obs is not None:
                    obs.on_preempt(req, env.now)
                if req in self.running:
                    self.running.remove(req)
                    self._uncharge_running(req)
                self._enqueue(req, front=True)  # retry first (vLLM order)

            # KV must grow before the decode step executes; speculative
            # requests book the whole draft window, the rejected suffix
            # is rolled back after verification
            for req in plan.decode:
                self.mem.append_tokens(req, 1)
            verify = []
            if plan.spec_decode:
                k1 = self.spec_decode.verify_tokens
                for req in plan.spec_decode:
                    self.mem.append_tokens(req, k1)
                    # K+1 causal query positions over the live context:
                    # costed like a prefill chunk in the target's mix
                    verify.append((k1, req.context_len))

            mix = BatchMix.from_batch(
                [(c, b) for _, c, b in plan.prefill] + verify,
                [r.context_len for r in plan.decode],
                enc_tokens=self.enc_tokens_per_req * sum(
                    1 for r, c, b in plan.prefill
                    if b == 0))
            # swap transfers are PCIe-bound, not compute: they bill at
            # face value rather than scaling with the worker slowdown
            t_compute = self.backend.iteration_time(mix)
            if self._warmup_left > 0:
                # cold caches / recompiled kernels after a restart
                t_compute *= self._warmup_factor
                self._warmup_left -= 1
            breakdown = getattr(self.backend, "last_breakdown", None)
            if breakdown is not None:
                # scale by the worker slowdown like the billed time, so
                # bubble/comm/span share busy_time's time base
                sd = self.slowdown
                bubble, comm, span = breakdown
                plan.pp_bubble = bubble * sd
                plan.comm_latency = comm * sd
                self.pp_bubble_time += bubble * sd
                self.pp_comm_time += comm * sd
                self.pp_span_time += span * sd
            t = t_compute * self.slowdown \
                + plan.retrieve_latency + plan.swap_latency \
                + plan.fetch_latency
            if plan.spec_decode:
                plan.draft_latency = \
                    self._draft_time(plan.spec_decode) * self.slowdown
                t += plan.draft_latency
            epoch = self._fail_epoch
            yield env.timeout(t)
            if self._fail_epoch != epoch:
                # the worker failed while this iteration was in flight:
                # the batch is gone (orphans already re-dispatched), so
                # applying its effects would double-emit tokens for
                # requests now living on another worker
                continue
            now = env.now
            self.iterations += 1
            self.busy_time += t
            p_tok = sum(c for _r, c, _b in plan.prefill)
            d_tok = len(plan.decode)
            if plan.spec_decode:
                d_tok += len(plan.spec_decode) \
                    * self.spec_decode.verify_tokens
            tot = p_tok + d_tok
            if tot:
                self.prefill_time += t * (p_tok / tot)
                self.decode_time += t * (d_tok / tot)
                self.prefill_tokens += p_tok
                self.decode_tokens += d_tok
            if obs is not None and obs.attribution:
                # before token emission, so an iteration that produces
                # the first token still banks on the TTFT side
                obs.attribute(plan, t)

            # ---- apply effects ---------------------------------------
            for req, chunk, _ctx in plan.prefill:
                req.prefill_done_len = max(req.cached_len,
                                           req.prefill_done_len) + chunk
                if req.remaining_prefill == 0:
                    self.hooks.fire("after_prefill", self, req)
                    self._emit_token(req, now)
            for req in plan.decode:
                self._emit_token(req, now)
            for req in plan.spec_decode:
                self._apply_spec_step(req, now)

            ms = self._mem_series
            if ms.should_record():
                ms.append(MemSample(
                    now, self.mem.num_used, self.mem.used_bytes(),
                    len(self.running)))
            self.hooks.fire("after_iteration", self, plan, t)

    # ------------------------------------------------------------------
    def estimate_prefill_time(self, tokens: int) -> float:
        """Analytic cost of prefilling ``tokens`` from scratch as one
        chunk on this worker — the recompute side of the fetch-vs-
        recompute break-even (docs/ROUTING.md), mirroring the swap
        crossover's use of the cost model."""
        if tokens <= 0:
            return 0.0
        mix = BatchMix.from_batch([(tokens, 0)], [])
        return self.backend.iteration_time(mix) * self.slowdown

    def _draft_time(self, spec_reqs: List[Request]) -> float:
        """Cost of the draft model proposing K tokens: K sequential
        decode iterations of the draft backend over the speculative
        sub-batch (context grows by one per draft position)."""
        cfg = self.spec_decode
        t = 0.0
        for k in range(cfg.lookahead):
            mix = BatchMix.from_batch(
                [], [r.context_len + k for r in spec_reqs])
            t += self.draft_backend.iteration_time(mix)
        return t

    def _apply_spec_step(self, req: Request, now: float) -> None:
        """Sample the verify outcome: keep the accepted draft prefix plus
        the bonus token, roll rejected tokens' KV blocks back, emit."""
        cfg = self.spec_decode
        accepted = cfg.acceptance.sample_accepted(
            self._spec_rng, cfg.lookahead)
        emitted = min(accepted + 1, req.output_len - req.tokens_generated)
        req.spec_steps += 1
        req.spec_tokens += emitted
        req.draft_proposed += cfg.lookahead
        req.draft_accepted += accepted
        self.mem.rollback_tokens(req, cfg.verify_tokens - emitted)
        for _ in range(emitted):
            self._emit_token(req, now)

    def _emit_token(self, req: Request, now: float) -> None:
        first = req.tokens_generated == 0
        req.tokens_generated += 1
        self.tokens_emitted += 1
        req.token_times.append(now)
        c = 1 + req.context_len // 256
        if c != req._run_charge:
            self._running_load += c - req._run_charge
            req._run_charge = c
        if first:
            req.t_first_token = now
            self.hooks.fire("on_first_token", self, req)
            if req.state == State.MIGRATING:
                return                      # handed off to a decode worker
        req.state = State.DECODE
        self.hooks.fire("after_token", self, req)
        if req.finished:
            self._finish(req, now)

    def _finish(self, req: Request, now: float) -> None:
        req.state = State.FINISHED
        req.t_finish = now
        if req in self.running:
            self.running.remove(req)
            self._uncharge_running(req)
        self.mem.free(req)
        if self.pool is not None:
            self.pool.store(req.session_id, req.context_len)
        self.hooks.fire("on_finish", self, req)
        if self.cluster is not None:
            self.cluster.on_request_finished(req)

    # ------------------------------------------------------------------
    def release(self, req: Request) -> None:
        """Remove a request from this worker (migration/failure)."""
        if req in self.running:
            self.running.remove(req)
            self._uncharge_running(req)
        if req in self.waiting:
            self.pop_waiting(req)
        self.mem.free(req)
        if self.swap is not None and self.swap.drop(req):
            # host copy is gone with the worker binding: re-prefill
            req.swapped_tokens = 0
            req.prefill_done_len = 0
            req.cached_len = 0

    def fail(self, *, kv_survives: bool = False) -> List[Request]:
        """Kill the worker; returns requests needing re-dispatch.

        Device KV always dies with the worker.  With ``kv_survives``
        (``ChaosSpec.host_kv_survives``) victims whose KV is parked in
        the host-DRAM swap tier keep their entry and progress — the
        host memory outlives the worker process — so the re-dispatch
        can adopt the copy into the new worker's tier instead of
        re-prefilling (docs/RELIABILITY.md)."""
        self.alive = False
        self._fail_epoch += 1
        self._warmup_left = 0
        orphans = list(self.running) + list(self.waiting)
        for r in orphans:
            self.mem.free(r)
            if kv_survives and self.swap is not None \
                    and self.swap.holds(r):
                r.preempt_count += 1
                r.state = State.QUEUED
                continue
            if self.swap is not None:
                self.swap.drop(r)
            # restart from scratch (device and host KV lost)
            r.swapped_tokens = 0
            r.prefill_done_len = 0
            r.cached_len = 0
            r.preempt_count += 1
            r.state = State.QUEUED
        self.running.clear()
        self.waiting.clear()
        self._waiting_load = 0
        self._running_load = 0
        return orphans

    def recover(self, warmup_iters: int = 0,
                warmup_factor: float = 1.0) -> None:
        if self.retired:
            # a revival landing after the autoscaler retired the worker
            # must not resurrect it into the serving set
            return
        self.alive = True
        # a retiring worker stays out of dispatch even across a
        # fault-recovery cycle: retirement is not cancellable by repair
        self.draining = self.retiring
        self._warmup_left = warmup_iters
        self._warmup_factor = warmup_factor
        self._wakeup()

    # ---- autoscaler lifecycle (repro.core.autoscale) -----------------
    def begin_retire(self) -> None:
        """Scale-down: stop taking dispatches, finish what's queued."""
        self.retiring = True
        self.draining = True

    def finish_retire(self, now: float) -> None:
        """Queue drained: leave the serving set permanently.  The
        worker object stays in the registry so per-worker stats and
        wid indexing survive; billing stops here."""
        self.retired = True
        self.alive = False
        self.t_retired = now
