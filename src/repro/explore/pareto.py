"""Pareto-frontier extraction over sweep result rows.

An ``objectives`` map names the metric columns that matter and their
direction (``"max"`` / ``"min"``); a row is on the frontier iff no other
row is at least as good on every objective and strictly better on one.
Rows missing an objective (or carrying NaN) never dominate anything and
are excluded from the frontier — a failed metric must not look optimal.
"""
from __future__ import annotations

import csv
from typing import Dict, List, Sequence


def _objective_values(row: Dict, objectives: Dict[str, str]):
    """Per-objective values oriented so that larger is always better;
    None when any objective is missing or NaN."""
    vals = []
    for name, direction in objectives.items():
        v = row.get(name)
        if not isinstance(v, (int, float)) or v != v:
            return None
        if direction == "min":
            v = -v
        elif direction != "max":
            raise ValueError(f"objective {name!r}: direction must be "
                             f"'max' or 'min', got {direction!r}")
        vals.append(v)
    return tuple(vals)


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """True iff ``a`` is >= ``b`` everywhere and > somewhere (both
    already oriented larger-is-better)."""
    return all(x >= y for x, y in zip(a, b)) \
        and any(x > y for x, y in zip(a, b))


def pareto_frontier(rows: List[Dict],
                    objectives: Dict[str, str]) -> List[Dict]:
    """Non-dominated subset of ``rows`` under ``objectives``, in input
    order.  Duplicate objective vectors all stay on the frontier."""
    scored = [(r, _objective_values(r, objectives)) for r in rows]
    frontier = []
    for r, v in scored:
        if v is None:
            continue
        if not any(other is not None and dominates(other, v)
                   for _, other in scored):
            frontier.append(r)
    return frontier


def write_rows_csv(rows: List[Dict], path: str) -> None:
    """Write rows with a union-of-keys header (first-seen key order)."""
    keys: List[str] = []
    for r in rows:
        for k in r:
            if k not in keys:
                keys.append(k)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys)
        w.writeheader()
        for r in rows:
            w.writerow(r)
