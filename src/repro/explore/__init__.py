"""Hardware x software exploration harness (docs/PARALLELISM.md).

The paper's headline — *hardware and software exploration* — as a
first-class package: declare a grid over any SimSpec knobs (parallelism
strategy, cluster topology, chips, batching, workloads), fan it out over
a multiprocessing pool with a resumable per-point JSON cache, and
extract the Pareto frontier over (throughput, P99 TTFT/TBT, $/token).
``benchmarks/parallelism.py`` drives it to reproduce the TP-vs-PP
crossover.
"""
from repro.explore.pareto import (  # noqa: F401
    dominates, pareto_frontier, write_rows_csv)
from repro.explore.sweep import (  # noqa: F401
    DEFAULT_OBJECTIVES, SweepResult, SweepSpec, default_metrics,
    grid_points, point_key, run_sweep, spec_price,
    uptime_weighted_price)
