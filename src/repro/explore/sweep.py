"""Declarative hardware x software sweeps with a resumable result cache.

A :class:`SweepSpec` names the grid axes (``{"tp": [1, 2, 4], ...}``)
and a module-level ``builder(point) -> SimSpec``; :func:`run_sweep` fans
the grid out over a multiprocessing pool (``processes=0`` runs inline)
and extracts one metrics row per point — throughput, P99 TTFT/TBT, and
$/token from ``HardwareSpec.price`` times the devices the point's
``ParallelSpec`` occupies.

Every completed point persists as ``<out_dir>/points/<key>.json`` keyed
by a hash of the point's canonical JSON, written atomically.  Re-running
a half-finished sweep loads the cached points and simulates only the
missing ones (a killed sweep resumes where it died; corrupt or
mismatched cache files are re-simulated).  ``run_sweep`` also writes the
full grid to ``sweep.csv`` and the non-dominated subset to
``pareto.csv`` (see :mod:`repro.explore.pareto`).

The ``builder`` must be a module-level callable so worker processes can
unpickle it; with ``processes=0`` any callable works.  The pool uses
the ``spawn`` start method where possible (fork is unsafe under a
threaded JAX parent), so driver scripts must keep the standard
``if __name__ == "__main__":`` guard.
"""
from __future__ import annotations

import hashlib
import itertools
import json
import multiprocessing
import os
import sys
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.costmodel.hardware import HARDWARE
from repro.core.metrics import Results, percentile
from repro.core.simulator import SimSpec, effective_tp, simulate
from repro.explore.pareto import pareto_frontier, write_rows_csv

#: frontier directions for the default metrics row
DEFAULT_OBJECTIVES = {"throughput": "max", "p99_ttft": "min",
                      "cost_per_1k_tokens": "min"}


@dataclass(frozen=True)
class SweepSpec:
    """A declarative grid sweep: ``axes`` values are crossed into points
    (dicts) which ``builder`` turns into a ``SimSpec`` each."""
    name: str
    builder: Callable[[Dict], SimSpec]
    axes: Dict[str, Sequence]
    #: optional replacement for :func:`default_metrics`
    metrics: Optional[Callable[[SimSpec, Results], Dict]] = None
    #: cache-invalidation tag mixed into every point's cache key: bump
    #: it when the cost model or the builder changes meaning, so cached
    #: results from the old code stop validating (the cache knows
    #: nothing about code versions on its own; ``run_sweep(force=True)``
    #: is the blunt alternative)
    version: str = ""


@dataclass
class SweepResult:
    rows: List[Dict] = field(default_factory=list)
    frontier: List[Dict] = field(default_factory=list)
    n_cached: int = 0
    n_simulated: int = 0
    csv_path: str = ""
    pareto_path: str = ""


def grid_points(axes: Dict[str, Sequence]) -> List[Dict]:
    """Cross-product of the axes, key-sorted for a stable order."""
    names = sorted(axes)
    return [dict(zip(names, combo))
            for combo in itertools.product(*(axes[n] for n in names))]


def point_key(point: Dict, version: str = "") -> str:
    """Stable filename-safe cache key for one grid point (salted with
    the sweep's ``version`` tag)."""
    canon = json.dumps(point, sort_keys=True, default=str)
    if version:
        canon = f"{version}\n{canon}"
    return hashlib.sha1(canon.encode()).hexdigest()[:16]


def worker_price(ws, parallel) -> float:
    """A100-relative price of one worker's devices: its chip price
    (with ``hw_overrides`` applied, matching what the simulator builds)
    times the tp x pp devices it spans.  The tp resolution is the
    simulator's own ``effective_tp``, so the priced worker is the
    simulated one (pinned by tests/test_hetero_fleet.py)."""
    hw = HARDWARE[ws.hw]
    if ws.hw_overrides:
        hw = hw.with_(**ws.hw_overrides)
    return hw.price * effective_tp(ws, parallel) * parallel.pp


def spec_price(spec: SimSpec) -> float:
    """A100-relative price of the cluster a spec occupies: the sum of
    per-worker ``worker_price`` over the worker list, times replicas.
    This is the *static* (fleet-as-configured) rate; for runs where
    the autoscaler changed the fleet, bill with
    ``uptime_weighted_price`` instead."""
    par = spec.parallel
    return sum(worker_price(ws, par) for ws in spec.workers) \
        * par.replicas


def uptime_weighted_price(spec: SimSpec, res: Optional[Results] = None
                          ) -> float:
    """Time-weighted $-per-hour billing (docs/AUTOSCALING.md): the
    effective fleet price rate, with each worker billed only over its
    provisioned-to-retired span —
    ``sum_w price_w * span_w / sim_time``.  A worker alive for half
    the horizon bills half its rate; a static fleet bills exactly
    ``spec_price`` (unit-tested in tests/test_autoscale.py).  Falls
    back to ``spec_price`` when the run carries no span bookkeeping
    (hand-built Results, cached sweep rows)."""
    spans = getattr(res, "worker_spans", None) if res is not None \
        else None
    prices = getattr(res, "worker_prices", None) if res is not None \
        else None
    if not spans or not prices:
        return spec_price(spec)
    T = max(res.sim_time, 1e-12)
    return sum(prices.get(wid, 0.0)
               * (min(e if e is not None else T, T) - s)
               for wid, (s, e) in spans.items()) / T


def default_metrics(spec: SimSpec, res: Results) -> Dict:
    """The (throughput, tail latency, $/token) row the Pareto frontier
    is extracted over.  TBT is the inter-token gap over every finished
    request's decode phase; cost is price-units x sim-seconds per 1k
    generated tokens (relative dollars at A100 = 1.0).

    Streaming/drop-mode specs (``retain_requests=False``) are read from
    the ``StreamingStats`` sketches instead of the (empty) request
    list; per-gap TBT is not sketched, so ``p99_tbt`` is NaN there —
    exclude it from the objectives for streaming sweeps.

    ``price`` is uptime-weighted (docs/AUTOSCALING.md): identical to
    ``spec_price`` for static fleets, but an autoscaled run bills each
    worker only over its provisioned span."""
    price = uptime_weighted_price(spec, res)
    if res.stats is not None:
        st = res.stats
        tokens = st.tokens
        p50_ttft = st.ttft.percentile(50)
        p99_ttft = st.ttft.percentile(99)
        p99_tbt = float("nan")
        finished = st.n_finished
    else:
        gaps: List[float] = []
        for r in res.finished:
            ts = r.token_times
            gaps.extend(b - a for a, b in zip(ts, ts[1:]))
        tokens = sum(r.tokens_generated for r in res.finished)
        p50_ttft = percentile(res.ttfts(), 50)
        p99_ttft = percentile(res.ttfts(), 99)
        p99_tbt = percentile(gaps, 99) if gaps else float("nan")
        finished = len(res.finished)
    lat = res.latency_stats()
    row = {
        "throughput": res.throughput(),
        "token_throughput": res.token_throughput(),
        "p50_ttft": p50_ttft,
        "p99_ttft": p99_ttft,
        "p99_tbt": p99_tbt,
        "p99_latency": lat["p99"],
        "finished": finished,
        "price": price,
        "cost_per_1k_tokens": price * res.sim_time / tokens * 1e3
        if tokens else float("nan"),
    }
    if res.parallel_stats:
        row["bubble_fraction"] = res.parallel_summary()["bubble_fraction"]
    if res.routing_stats is not None:
        ro = res.routing_summary()
        row["affinity_hit_rate"] = ro["affinity_hit_rate"]
        row["kv_fetches"] = ro["fetches"]
        row["kv_fetch_time_s"] = ro["fetch_time_s"]
    return row


def _run_point(args) -> Dict:
    """Pool worker: simulate one grid point and persist its cache file
    atomically (tmp + rename), so a killed sweep never leaves a torn
    JSON behind."""
    builder, metrics_fn, point, path = args
    spec = builder(point)
    res = simulate(spec)
    metrics = (metrics_fn or default_metrics)(spec, res)
    payload = {"point": point, "metrics": metrics}
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    os.replace(tmp, path)
    return payload


def _mp_context():
    """Prefer ``spawn`` — callers may have JAX (multithreaded) loaded in
    the parent, and forking a threaded process risks deadlocked
    children.  Spawn re-imports the parent's ``__main__`` though, so
    when that module is not importable (stdin / REPL parents) fall back
    to ``fork`` — the sweep jobs themselves never touch JAX."""
    main = sys.modules.get("__main__")
    spawn_safe = main is None \
        or getattr(main, "__spec__", None) is not None \
        or os.path.exists(getattr(main, "__file__", ""))
    if spawn_safe or "fork" not in multiprocessing.get_all_start_methods():
        return multiprocessing.get_context("spawn")
    return multiprocessing.get_context("fork")


def _load_cached(path: str, point: Dict) -> Optional[Dict]:
    """Cached payload for ``point``, or None when missing / corrupt /
    written for a different point (hash collision or edited grid)."""
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, ValueError):
        return None
    if not isinstance(payload, dict) or "metrics" not in payload \
            or payload.get("point") != point:
        return None
    return payload


def run_sweep(sweep: SweepSpec, out_dir: str, *, processes: int = 0,
              objectives: Optional[Dict[str, str]] = None,
              force: bool = False, verbose: bool = False) -> SweepResult:
    """Run (or resume) a sweep; returns every row plus the frontier.

    ``processes=0`` simulates inline (deterministic order, picklability
    not required); ``processes=N`` fans the missing points out over a
    pool of N workers.  Only points without a valid cache file are
    simulated — ``SweepResult.n_simulated`` counts them, which the
    resumability test pins.  The cache is keyed by point +
    ``sweep.version`` only — it cannot see code changes, so after
    editing the cost model either bump the version tag or pass
    ``force=True`` to re-simulate everything."""
    points = grid_points(sweep.axes)
    points_dir = os.path.join(out_dir, "points")
    os.makedirs(points_dir, exist_ok=True)

    payloads: Dict[int, Dict] = {}
    missing = []
    for idx, point in enumerate(points):
        path = os.path.join(
            points_dir, f"{point_key(point, sweep.version)}.json")
        cached = None if force else _load_cached(path, point)
        if cached is not None:
            payloads[idx] = cached
        else:
            missing.append((idx, point, path))
    if verbose and missing:
        print(f"sweep {sweep.name}: {len(points)} points, "
              f"{len(payloads)} cached, {len(missing)} to simulate")

    jobs = [(sweep.builder, sweep.metrics, point, path)
            for _, point, path in missing]
    if jobs:
        if processes > 0:
            with _mp_context().Pool(processes) as pool:
                fresh = pool.map(_run_point, jobs)
        else:
            fresh = [_run_point(j) for j in jobs]
        for (idx, _, _), payload in zip(missing, fresh):
            payloads[idx] = payload

    rows = [{**payloads[i]["point"], **payloads[i]["metrics"]}
            for i in range(len(points))]
    result = SweepResult(rows=rows, n_cached=len(points) - len(missing),
                         n_simulated=len(missing))
    result.csv_path = os.path.join(out_dir, "sweep.csv")
    write_rows_csv(rows, result.csv_path)
    result.frontier = pareto_frontier(
        rows, objectives or DEFAULT_OBJECTIVES)
    result.pareto_path = os.path.join(out_dir, "pareto.csv")
    write_rows_csv(result.frontier, result.pareto_path)
    return result
