"""Chrome trace-event recording and validation (docs/OBSERVABILITY.md).

``TraceRecorder`` emits the subset of the Trace Event Format that
Perfetto and ``chrome://tracing`` render natively:

* ``ph="X"`` complete events — request lifecycle phase spans (one lane
  per request id under the ``requests`` process) and per-worker
  iteration slices with the ``IterationPlan`` cost breakdown in
  ``args``;
* ``ph="i"`` instant events — swap-out/swap-in markers on worker lanes;
* ``ph="C"`` counter events — cluster gauges mirrored from the time
  series (when both recorders are on);
* ``ph="M"`` metadata — process names, emitted at export time.

Timestamps are simulated seconds scaled to microseconds (the format's
unit).  Request phase spans are contiguous by construction: each
transition closes the previous span at the instant the next one opens,
and a whole-request umbrella span (``cat="request.total"``) runs from
arrival to finish — so the phase durations sum to the request's
measured latency, which :func:`validate_chrome_trace` checks to 1e-6 s.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Tuple

#: trace lane layout: one synthetic "process" per concern
REQUESTS_PID = 1
CLUSTER_PID = 2
WORKER_PID_BASE = 10

#: every request-lifecycle span name the recorder can emit;
#: scripts/check_docs.py asserts each is documented in
#: docs/OBSERVABILITY.md
SPAN_PHASES = ("gateway", "queue", "prefill", "decode", "preempted",
               "migrate")

_US = 1e6                                # seconds -> microseconds


class TraceRecorder:
    """Bounded in-memory Chrome trace; one instance per simulation.

    The hot path appends compact ``(ph, name, cat, ts, dur, pid, tid,
    args)`` tuples (times still in simulated seconds); :meth:`to_json`
    expands them to trace-event dicts once, at export — one tuple
    allocation per event beats an 8-key dict literal several-fold, and
    export cost is off the simulated clock."""

    def __init__(self, max_events: int = 100_000):
        self.max_events = max_events
        self._raw: List[tuple] = []
        self.dropped = 0
        #: req id -> (phase, start_time, request) for the open span;
        #: entries only outlive the request while it is in flight, so
        #: drop-mode memory stays bounded by the live population
        self._open: Dict[int, Tuple[str, float, object]] = {}
        self._workers: List[int] = []

    # ------------------------------------------------------------------
    def _emit(self, ev: tuple) -> None:
        if len(self._raw) >= self.max_events:
            self.dropped += 1
            return
        self._raw.append(ev)

    def __len__(self) -> int:
        return len(self._raw)

    @property
    def events(self) -> List[dict]:
        """Recorded events as trace-event dicts (metadata excluded)."""
        return [self._expand(ev) for ev in self._raw]

    def register_worker(self, wid: int) -> None:
        if wid not in self._workers:
            self._workers.append(wid)

    # ---- request lifecycle -------------------------------------------
    def req_phase(self, req, phase: str, now: float) -> None:
        """Transition ``req`` into ``phase``, closing the previous span.
        A transition into the current phase is a no-op (keeps the
        original span start)."""
        rid = req.id
        cur = self._open.get(rid)
        if cur is not None:
            prev, start, _ = cur
            if prev == phase:
                return
            self._emit(("X", prev, "request", start, now - start,
                        REQUESTS_PID, rid, None))
        self._open[rid] = (phase, now, req)

    def req_close(self, req, now: float,
                  outcome: str = "finished") -> None:
        """Close the open span and emit the whole-request umbrella."""
        rid = req.id
        cur = self._open.pop(rid, None)
        if cur is not None:
            prev, start, _ = cur
            self._emit(("X", prev, "request", start, now - start,
                        REQUESTS_PID, rid, None))
        self._emit(("X", f"req{rid}", "request.total", req.arrival_time,
                    now - req.arrival_time, REQUESTS_PID, rid,
                    {"prompt_len": req.prompt_len,
                     "output_len": req.output_len,
                     "preempts": req.preempt_count,
                     "outcome": outcome}))

    def flush_open(self, now: float) -> None:
        """Close spans of requests still in flight at the horizon."""
        for rid in sorted(self._open):
            _, _, req = self._open[rid]
            self.req_close(req, now, outcome="inflight")

    # ---- worker-side events ------------------------------------------
    def iteration(self, wid: int, start: float, dur: float,
                  args: dict) -> None:
        self._emit(("X", "iteration", "iteration", start, dur,
                    WORKER_PID_BASE + wid, 1, args))

    def instant(self, name: str, now: float, pid: int,
                args: dict) -> None:
        self._emit(("i", name, "event", now, 0.0, pid, 1, args))

    def swap_event(self, wid: int, kind: str, now: float,
                   args: dict) -> None:
        self.instant(kind, now, WORKER_PID_BASE + wid, args)

    def counter(self, name: str, now: float, values: dict) -> None:
        self._emit(("C", name, None, now, 0.0, CLUSTER_PID, 0, values))

    # ---- export -------------------------------------------------------
    @staticmethod
    def _expand(ev: tuple) -> dict:
        ph, name, cat, ts, dur, pid, tid, args = ev
        out = {"name": name, "ph": ph, "ts": ts * _US,
               "pid": pid, "tid": tid, "args": args if args is not None
               else {}}
        if cat is not None:
            out["cat"] = cat
        if ph == "X":
            out["dur"] = dur * _US
        elif ph == "i":
            out["s"] = "t"
        return out

    def to_json(self) -> dict:
        meta = [{"name": "process_name", "ph": "M", "ts": 0.0,
                 "pid": pid, "tid": 0, "args": {"name": pname}}
                for pid, pname in
                [(REQUESTS_PID, "requests"), (CLUSTER_PID, "cluster")]
                + [(WORKER_PID_BASE + w, f"worker{w}")
                   for w in sorted(self._workers)]]
        return {"traceEvents": meta + self.events,
                "displayTimeUnit": "ms",
                "otherData": {"generator": "repro.obs",
                              "dropped_events": self.dropped}}

    def export(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)
        return path


# ---------------------------------------------------------------------------
# validation (used by the CI smoke and tests)
# ---------------------------------------------------------------------------
_PHASES_OK = {"X", "M", "i", "C"}
#: tolerance for span arithmetic, microseconds (= the acceptance
#: criterion's 1e-6 seconds)
_EPS_US = 1.0


def validate_chrome_trace(data: dict) -> List[str]:
    """Structural checks on an exported trace.  Returns a list of error
    strings (empty = valid): well-formed trace-event JSON, phase spans
    per request contiguous and nested inside the umbrella span, and the
    phase durations summing to the umbrella (= measured latency) within
    1e-6 s."""
    errors: List[str] = []
    if not isinstance(data, dict) or not isinstance(
            data.get("traceEvents"), list):
        return ["top level must be a dict with a 'traceEvents' list"]
    events = data["traceEvents"]
    req_pid: Optional[int] = None
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not a dict")
            continue
        for key in ("name", "ph", "ts", "pid", "tid"):
            if key not in ev:
                errors.append(f"event {i} ({ev.get('name')!r}): "
                              f"missing {key!r}")
        ph = ev.get("ph")
        if ph not in _PHASES_OK:
            errors.append(f"event {i}: unknown ph {ph!r}")
        if ph == "X" and not (isinstance(ev.get("dur"), (int, float))
                              and ev["dur"] >= 0):
            errors.append(f"event {i} ({ev.get('name')!r}): X event "
                          f"needs dur >= 0, got {ev.get('dur')!r}")
        if ph == "M" and ev.get("name") == "process_name" \
                and ev.get("args", {}).get("name") == "requests":
            req_pid = ev.get("pid")
    if errors:
        return errors
    if req_pid is None:
        req_pid = REQUESTS_PID
    # per-request span tree
    by_tid: Dict[int, Dict[str, list]] = {}
    for ev in events:
        if ev.get("pid") != req_pid or ev.get("ph") != "X":
            continue
        slot = by_tid.setdefault(ev["tid"], {"total": [], "phases": []})
        slot["total" if ev.get("cat") == "request.total"
             else "phases"].append(ev)
    for tid in sorted(by_tid):
        slot = by_tid[tid]
        if len(slot["total"]) != 1:
            errors.append(f"request {tid}: expected exactly one umbrella "
                          f"span, got {len(slot['total'])}")
            continue
        u = slot["total"][0]
        u0, u1 = u["ts"], u["ts"] + u["dur"]
        phases = sorted(slot["phases"], key=lambda e: e["ts"])
        if not phases:
            errors.append(f"request {tid}: umbrella without phase spans")
            continue
        for ev in phases:
            if ev["ts"] < u0 - _EPS_US or \
                    ev["ts"] + ev["dur"] > u1 + _EPS_US:
                errors.append(f"request {tid}: phase {ev['name']!r} "
                              f"outside umbrella span")
        if abs(phases[0]["ts"] - u0) > _EPS_US:
            errors.append(f"request {tid}: first phase starts "
                          f"{abs(phases[0]['ts'] - u0):.3f}us after "
                          f"arrival")
        last = phases[-1]
        if abs(last["ts"] + last["dur"] - u1) > _EPS_US:
            errors.append(f"request {tid}: last phase does not end at "
                          f"the umbrella end")
        for a, b in zip(phases, phases[1:]):
            if abs(a["ts"] + a["dur"] - b["ts"]) > _EPS_US:
                errors.append(f"request {tid}: gap/overlap between "
                              f"{a['name']!r} and {b['name']!r}")
        total = sum(e["dur"] for e in phases)
        if abs(total - u["dur"]) > _EPS_US:
            errors.append(
                f"request {tid}: phase durations sum to {total:.3f}us, "
                f"umbrella is {u['dur']:.3f}us")
    return errors
