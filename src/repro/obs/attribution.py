"""Latency attribution: where did each request's time go?

Every iteration a request participates in is decomposed into the cost
components the worker already computes (``IterationPlan``): compute, TP
all-reduce / pipeline p2p ``comm``, pipeline ``bubble``, PCIe ``swap``,
memory-pool ``retrieve`` and speculative ``draft`` time.  Components are
banked per request on one of two accounts — before the first token
(feeds TTFT) or after it (feeds TPOT) — and two residuals are derived at
finish time:

* ``queue``  = TTFT - gateway - sum(pre-token components): time the
  request spent waiting (global + local queues, preemption gaps) before
  its first token;
* ``stall``  = decode span - sum(post-token components): decode-phase
  time the request was not in any iteration (preempted, swapped out,
  migrating, or batching gaps).

Because the residuals are defined by subtraction, the attributed
components sum to the measured latency *exactly* (to float addition
error), in both exact and streaming drop-mode — the conservation
property ``tests/test_observability.py`` pins at 1e-6.

Note: post-first-token compute is labeled ``decode`` even when it is
re-prefill work after a recompute-preemption — the time is real decode-
phase latency; the preemption itself is visible in ``stall`` and in the
trace's ``preempted`` span.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

#: every component name that can appear in ``Results.time_breakdown()``;
#: scripts/check_docs.py asserts each is documented in
#: docs/OBSERVABILITY.md
COMPONENTS = ("gateway", "queue", "prefill", "decode", "comm", "bubble",
              "swap", "retrieve", "fetch", "draft", "migrate", "stall")


class RequestObs:
    """Per-request component banks, attached lazily as ``Request.obs``.

    The dominant component by call volume — iteration ``compute`` time,
    banked once per participant per iteration — lives in two scalar
    slots (``pre_compute`` / ``post_compute``); a float in-place add is
    severalfold cheaper than a dict update and this is the single
    hottest line of the whole observability stack (see the
    ``run_obs_overhead`` gate in benchmarks/sim_speed.py).  The rare
    components (comm, bubble, swap, ...) go in the ``pre``/``post``
    dicts."""

    __slots__ = ("pre", "post", "pre_compute", "post_compute", "final")

    def __init__(self):
        self.pre: Dict[str, float] = {}    # before the first token
        self.post: Dict[str, float] = {}   # after the first token
        self.pre_compute = 0.0
        self.post_compute = 0.0
        #: set by finalize_request: {"ttft": {...}, "decode": {...},
        #: "tokens": n} — the conserved decomposition
        self.final: Optional[dict] = None


def charge(req, comps: Sequence[Tuple[str, float]]) -> None:
    """Bank one iteration's components on ``req`` (the caller builds
    ``comps`` once per iteration, shared by every participant)."""
    ro = req.obs
    if ro is None:
        ro = req.obs = RequestObs()
    pre = req.t_first_token is None
    bank = ro.pre if pre else ro.post
    for k, v in comps:
        if k == "compute":
            if pre:
                ro.pre_compute += v
            else:
                ro.post_compute += v
        else:
            bank[k] = bank.get(k, 0.0) + v


def add_component(req, name: str, value: float, *, post: bool = True) -> None:
    """Bank a single out-of-iteration component (e.g. migration time)."""
    ro = req.obs
    if ro is None:
        ro = req.obs = RequestObs()
    bank = ro.post if post else ro.pre
    bank[name] = bank.get(name, 0.0) + value


def finalize_request(req) -> None:
    """Turn the banks into the conserved TTFT/decode decomposition.
    Called once when the request finishes (before any streaming fold)."""
    if req.t_finish is None or req.t_first_token is None:
        return
    ro = req.obs
    if ro is None:
        ro = req.obs = RequestObs()
    if ro.final is not None:
        return
    ttft = req.t_first_token - req.arrival_time
    gateway = (req.t_admitted - req.arrival_time) \
        if req.t_admitted is not None else 0.0
    ttft_c: Dict[str, float] = {}
    if gateway:
        ttft_c["gateway"] = gateway
    if ro.pre_compute:
        ttft_c["prefill"] = ro.pre_compute
    ttft_c.update(ro.pre)
    # residual: waiting anywhere before the first token (not clamped,
    # so the decomposition sums to TTFT exactly)
    ttft_c["queue"] = ttft - gateway - ro.pre_compute \
        - sum(ro.pre.values())
    decode_span = req.t_finish - req.t_first_token
    dec_c: Dict[str, float] = {}
    if ro.post_compute:
        dec_c["decode"] = ro.post_compute
    dec_c.update(ro.post)
    dec_c["stall"] = decode_span - ro.post_compute \
        - sum(ro.post.values())
    ro.final = {"ttft": ttft_c, "decode": dec_c,
                "tokens": req.tokens_generated}


# ---------------------------------------------------------------------------
# aggregation (Results.time_breakdown / Results.explain)
# ---------------------------------------------------------------------------
def _acc(dst: Dict[str, float], src: Dict[str, float],
         scale: float = 1.0) -> None:
    for k, v in src.items():
        dst[k] = dst.get(k, 0.0) + v * scale


def _mean(sums: Dict[str, float], n: int) -> Dict[str, float]:
    return {k: v / n for k, v in sums.items()}


def aggregate_exact(requests) -> dict:
    """Mean and P99-tail breakdowns from retained finished requests."""
    recs = [r for r in requests
            if getattr(r, "obs", None) is not None
            and r.obs.final is not None]
    if not recs:
        raise ValueError(
            "no attribution data: run with "
            "SimSpec(obs=ObsSpec(attribution=True))")
    n = len(recs)
    ttft_s: Dict[str, float] = {}
    dec_s: Dict[str, float] = {}
    tpot_s: Dict[str, float] = {}
    for r in recs:
        f = r.obs.final
        _acc(ttft_s, f["ttft"])
        _acc(dec_s, f["decode"])
        _acc(tpot_s, f["decode"], 1.0 / max(1, f["tokens"] - 1))
    # P99 tail: the worst ~1% by the respective phase duration, so the
    # tail breakdown explains what makes the slow requests slow
    k = max(1, n // 100)
    tail_t = sorted(recs, key=lambda r: (r.ttft, r.id))[-k:]
    tail_d = sorted(recs, key=lambda r: (r.t_finish - r.t_first_token,
                                         r.id))[-k:]
    ttft_p99: Dict[str, float] = {}
    dec_p99: Dict[str, float] = {}
    tpot_p99: Dict[str, float] = {}
    for r in tail_t:
        _acc(ttft_p99, r.obs.final["ttft"])
    for r in tail_d:
        f = r.obs.final
        _acc(dec_p99, f["decode"])
        _acc(tpot_p99, f["decode"], 1.0 / max(1, f["tokens"] - 1))
    return {"n": n, "mode": "exact", "tail_n": k,
            "ttft_mean": _mean(ttft_s, n),
            "decode_mean": _mean(dec_s, n),
            "tpot_mean": _mean(tpot_s, n),
            "ttft_p99": _mean(ttft_p99, len(tail_t)),
            "decode_p99": _mean(dec_p99, len(tail_d)),
            "tpot_p99": _mean(tpot_p99, len(tail_d))}


def aggregate_streaming(attrib: dict) -> dict:
    """Mean breakdowns from the per-component sums folded into
    ``StreamingStats`` (drop-mode keeps no per-request tails, so the
    P99 breakdowns are ``None`` there)."""
    n = attrib["n"]
    if not n:
        raise ValueError(
            "no attribution data: run with "
            "SimSpec(obs=ObsSpec(attribution=True))")
    return {"n": n, "mode": "streaming", "tail_n": 0,
            "ttft_mean": _mean(attrib["ttft"], n),
            "decode_mean": _mean(attrib["decode"], n),
            "tpot_mean": _mean(attrib["tpot"], n),
            "ttft_p99": None, "decode_p99": None, "tpot_p99": None}


def format_breakdown(bd: dict) -> str:
    """Human-readable table for ``Results.explain()``."""
    lines: List[str] = [
        f"latency attribution ({bd['n']} finished requests, "
        f"{bd['mode']} mode)"]

    def section(title: str, mean: Dict[str, float],
                p99: Optional[Dict[str, float]]) -> None:
        lines.append(f"-- {title} --")
        hdr = f"  {'component':<10} {'mean (s)':>12}"
        if p99 is not None:
            hdr += f" {'p99-tail (s)':>13}"
        lines.append(hdr)
        keys = [k for k in COMPONENTS
                if k in mean or (p99 and k in p99)]
        for k in keys:
            row = f"  {k:<10} {mean.get(k, 0.0):>12.6f}"
            if p99 is not None:
                row += f" {p99.get(k, 0.0):>13.6f}"
            lines.append(row)
        row = f"  {'total':<10} {sum(mean.values()):>12.6f}"
        if p99 is not None:
            row += f" {sum(p99.values()):>13.6f}"
        lines.append(row)

    section("TTFT", bd["ttft_mean"], bd["ttft_p99"])
    section("decode phase", bd["decode_mean"], bd["decode_p99"])
    section("TPOT (per token)", bd["tpot_mean"], bd["tpot_p99"])
    if bd["ttft_p99"] is None:
        lines.append("  (p99-tail breakdowns need exact mode: "
                     "retain_requests=True)")
    return "\n".join(lines)
