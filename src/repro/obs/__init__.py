"""Deep observability for the simulator (docs/OBSERVABILITY.md).

Three recorders behind one ``SimSpec(obs=ObsSpec(...))`` knob:

* :class:`TraceRecorder` — request-lifecycle spans and per-worker
  iteration slices as Chrome trace-event JSON (Perfetto-loadable);
* :class:`TimeSeriesRecorder` — bounded-memory gauges/counters sampled
  over simulated time, CSV/JSON export;
* latency attribution — per-request component banks surfaced by
  ``Results.time_breakdown()`` / ``Results.explain()``, conserved to
  the measured latency in exact and streaming drop-mode.
"""
from repro.obs.attribution import (COMPONENTS, RequestObs, add_component,
                                   aggregate_exact, aggregate_streaming,
                                   charge, finalize_request,
                                   format_breakdown)
from repro.obs.recorder import ObsRecorder
from repro.obs.spec import ObsSpec
from repro.obs.timeseries import (BoundedSeries, TS_FIELDS,
                                  TimeSeriesRecorder)
from repro.obs.trace import (SPAN_PHASES, TraceRecorder,
                             validate_chrome_trace)

__all__ = ["COMPONENTS", "RequestObs", "add_component", "aggregate_exact",
           "aggregate_streaming", "charge", "finalize_request",
           "format_breakdown", "ObsRecorder", "ObsSpec", "BoundedSeries",
           "TS_FIELDS", "TimeSeriesRecorder", "SPAN_PHASES",
           "TraceRecorder", "validate_chrome_trace"]
