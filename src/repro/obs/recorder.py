"""ObsRecorder: one object tying the three recorders to the simulator.

The recorder rides the breakpoint registry (paper §III-A) for every
worker-side lifecycle event — ``on_admit``, ``on_first_token``,
``on_finish`` and ``after_iteration`` are ordinary hooks registered on
each worker's ``Hooks`` — and takes direct calls from the simulator for
the cluster-side events the registry does not cover (arrival, gateway
release, rejection, preemption, re-dispatch, migration).  With
``ObsSpec()`` all-off, the ``Simulation`` never constructs a recorder
at all and every tap collapses to one ``is None`` check.
"""
from __future__ import annotations

from typing import Optional

from repro.core.request import State
from repro.obs.attribution import (RequestObs, add_component, charge,
                                   finalize_request)
from repro.obs.spec import ObsSpec
from repro.obs.timeseries import TimeSeriesRecorder
from repro.obs.trace import TraceRecorder, WORKER_PID_BASE


class ObsRecorder:
    """Per-simulation observability hub (see docs/OBSERVABILITY.md)."""

    def __init__(self, spec: ObsSpec):
        self.spec = spec
        self.trace: Optional[TraceRecorder] = \
            TraceRecorder(spec.max_trace_events) if spec.trace else None
        self.ts: Optional[TimeSeriesRecorder] = \
            TimeSeriesRecorder(spec.sample_interval,
                               spec.timeseries_cap) \
            if spec.timeseries else None
        self.attribution = spec.attribution

    # ------------------------------------------------------------------
    def install(self, worker) -> None:
        """Attach to one worker: set its ``obs`` back-reference and, when
        tracing, register the lifecycle hooks on its breakpoint registry
        and tap its SwapManager."""
        worker.obs = self
        tr = self.trace
        if tr is None:
            return
        tr.register_worker(worker.wid)
        worker.hooks.on("on_admit", self._hook_admit)
        worker.hooks.on("on_first_token", self._hook_first_token)
        worker.hooks.on("on_finish", self._hook_finish)
        worker.hooks.on("after_iteration", self._hook_iteration)
        swap = getattr(worker, "swap", None)
        if swap is not None:
            env, wid = worker.env, worker.wid

            def on_event(kind, rid, tokens, nbytes,
                         _tr=tr, _env=env, _wid=wid):
                _tr.swap_event(_wid, kind, _env.now,
                               {"req": rid, "tokens": tokens,
                                "bytes": nbytes})

            swap.on_event = on_event

    # ---- hook callbacks (breakpoint registry) -------------------------
    def _hook_admit(self, worker, req) -> None:
        self.trace.req_phase(
            req, "prefill" if req.remaining_prefill else "decode",
            worker.env.now)

    def _hook_first_token(self, worker, req) -> None:
        # the disagg hand-off hook runs first (registered at worker
        # construction), so a migrating request is already MIGRATING here
        phase = "migrate" if req.state is State.MIGRATING else "decode"
        self.trace.req_phase(req, phase, worker.env.now)

    def _hook_finish(self, worker, req) -> None:
        self.trace.req_close(req, worker.env.now)

    def _hook_iteration(self, worker, plan, t) -> None:
        now = worker.env.now
        args = {"prefill": len(plan.prefill),
                "decode": len(plan.decode),
                "spec_decode": len(plan.spec_decode),
                "preempted": len(plan.preempted)}
        other = 0.0
        for key, val in (("comm", plan.comm_latency),
                         ("bubble", plan.pp_bubble),
                         ("swap", plan.swap_latency),
                         ("retrieve", plan.retrieve_latency),
                         ("fetch", plan.fetch_latency),
                         ("draft", plan.draft_latency)):
            if val:
                args[key] = val
                other += val
        args["compute"] = t - other
        self.trace.iteration(worker.wid, now - t, t, args)

    # ---- direct calls from the Simulation -----------------------------
    def on_arrival(self, req, gated: bool) -> None:
        if self.trace is not None:
            self.trace.req_phase(
                req, "gateway" if gated else "queue", req.arrival_time)

    def on_release(self, req, now: float) -> None:
        """Admission gateway released the request toward a worker."""
        if self.trace is not None:
            self.trace.req_phase(req, "queue", now)

    def on_reject(self, req, now: float) -> None:
        if self.trace is not None:
            self.trace.req_close(req, now, outcome="rejected")

    def on_preempt(self, req, now: float) -> None:
        if self.trace is not None:
            self.trace.req_phase(req, "preempted", now)

    def on_requeue(self, req, now: float) -> None:
        """Failure re-dispatch / migration landing: back to a queue."""
        if self.trace is not None:
            self.trace.req_phase(req, "queue", now)

    def on_fault(self, wid: int, kind: str, now: float,
                 args=None) -> None:
        """Fault-injection instant (repro.core.faults) on the worker's
        trace lane: ``fault.fail`` / ``fault.recover`` /
        ``fault.slowdown`` / ``fault.drain``."""
        if self.trace is not None:
            self.trace.instant(f"fault.{kind}", now,
                               WORKER_PID_BASE + wid, args or {})

    def on_scale(self, wid: int, action: str, now: float) -> None:
        """Autoscaler instant (repro.core.autoscale) on the worker's
        trace lane: ``scale.up_request`` / ``scale.up_ready`` /
        ``scale.down_drain`` / ``scale.down_retired``."""
        if self.trace is not None:
            self.trace.instant(f"scale.{action}", now,
                               WORKER_PID_BASE + wid, {})

    def on_fetch(self, wid: int, req, via: str, tokens: int,
                 nbytes: float, now: float) -> None:
        """Cross-worker / remote-tier KV fetch instant
        (docs/ROUTING.md) on the fetching worker's trace lane:
        ``fetch.peer`` / ``fetch.remote``."""
        if self.trace is not None:
            self.trace.instant(f"fetch.{via}", now,
                               WORKER_PID_BASE + wid,
                               {"req": req.id, "tokens": tokens,
                                "bytes": nbytes})

    def on_migrate_done(self, req, now: float, dur: float) -> None:
        if self.trace is not None:
            self.trace.req_phase(req, "queue", now)
        if self.attribution:
            add_component(req, "migrate", dur, post=True)

    # ---- attribution hot path (called by the worker per iteration) ----
    def attribute(self, plan, t: float) -> None:
        """Bank this iteration's cost components on every participant.
        Runs after the iteration's timeout but before token emission, so
        a prefill that produces the first token still banks pre-token.

        The overwhelmingly common iteration has no comm/bubble/swap/
        retrieve/draft time, so that case inlines the single "compute"
        bank update instead of paying a ``charge()`` call per request
        (the difference is a measurable share of total sim cost on
        token-light workloads — see benchmarks/sim_speed.py's
        ``run_obs_overhead`` gate)."""
        other = plan.comm_latency + plan.pp_bubble + plan.swap_latency \
            + plan.retrieve_latency + plan.fetch_latency \
            + plan.draft_latency
        if not other:
            for req in plan.decode:
                ro = req.obs
                if ro is None:
                    ro = req.obs = RequestObs()
                if req.t_first_token is None:
                    ro.pre_compute += t
                else:
                    ro.post_compute += t
            for req, _chunk, _ctx in plan.prefill:
                ro = req.obs
                if ro is None:
                    ro = req.obs = RequestObs()
                if req.t_first_token is None:
                    ro.pre_compute += t
                else:
                    ro.post_compute += t
            for req in plan.spec_decode:
                ro = req.obs
                if ro is None:
                    ro = req.obs = RequestObs()
                if req.t_first_token is None:
                    ro.pre_compute += t
                else:
                    ro.post_compute += t
            return
        comps = [("compute", t - other)]
        for key, val in (("comm", plan.comm_latency),
                         ("bubble", plan.pp_bubble),
                         ("swap", plan.swap_latency),
                         ("retrieve", plan.retrieve_latency),
                         ("fetch", plan.fetch_latency),
                         ("draft", plan.draft_latency)):
            if val:
                comps.append((key, val))
        for req, _chunk, _ctx in plan.prefill:
            charge(req, comps)
        for req in plan.decode:
            charge(req, comps)
        for req in plan.spec_decode:
            charge(req, comps)

    def finalize(self, req) -> None:
        if self.attribution:
            finalize_request(req)
