"""Observability configuration (docs/OBSERVABILITY.md).

``SimSpec(obs=ObsSpec(...))`` switches on any combination of the three
recorders; the default ``obs=None`` keeps the simulator on its original
zero-instrumentation path (no recorder objects exist, workers guard
every tap with one ``is None`` check, and the breakpoint registry's
empty fast path makes hook dispatch a dict miss).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ObsSpec:
    """What to record and how much memory recording may use.

    All three recorders are bounded: the trace caps its event list at
    ``max_trace_events`` (excess events are counted, not stored), and
    the time series doubles its sampling stride whenever it hits
    ``timeseries_cap`` frames, so memory stays O(cap) on arbitrarily
    long runs.
    """

    #: request-lifecycle spans + per-worker iteration slices, exported
    #: as Chrome trace-event JSON (Perfetto / chrome://tracing)
    trace: bool = False
    #: periodic gauges/counters (queue depth, batch size, KV blocks,
    #: tokens/s, preemptions, rejections), CSV/JSON export
    timeseries: bool = False
    #: per-request latency attribution feeding Results.time_breakdown()
    #: / Results.explain(); works in streaming drop-mode too
    attribution: bool = False
    #: simulated seconds between time-series samples (doubles on
    #: decimation)
    sample_interval: float = 1.0
    #: frame count at which the time series halves itself
    timeseries_cap: int = 4096
    #: hard cap on stored trace events; overflow increments
    #: ``TraceRecorder.dropped`` instead of growing the list
    max_trace_events: int = 100_000

    @property
    def enabled(self) -> bool:
        return self.trace or self.timeseries or self.attribution

    @classmethod
    def full(cls, **kw) -> "ObsSpec":
        """Everything on — the examples/benchmarks shorthand."""
        return cls(trace=True, timeseries=True, attribution=True, **kw)
