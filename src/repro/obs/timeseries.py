"""Bounded time-series metrics (docs/OBSERVABILITY.md).

``TimeSeriesRecorder`` samples cluster and per-worker gauges/counters at
a configurable simulated-time interval.  Memory is bounded by the same
stride-doubling decimation the worker's ``mem_timeline`` pioneered: when
the frame list hits its cap, every other frame is dropped (keeping the
t~0 anchor) and the sampling interval doubles, so a run of any length
stores at most ``cap`` frames at progressively coarser resolution.

:class:`BoundedSeries` is that decimation policy factored out as a
container; ``worker.py`` now uses it for ``mem_timeline`` instead of
carrying its own stride/tick fields.
"""
from __future__ import annotations

import csv
import json
from typing import Dict, List, Optional

#: every column in the exported time series; scripts/check_docs.py
#: asserts each is documented in docs/OBSERVABILITY.md.  Worker rows
#: leave the cluster-only tail columns (n_live, n_finished, n_rejected)
#: empty; ``n_alive`` is 0/1 per worker and the live-worker count on
#: the cluster row (the downtime gauge, docs/RELIABILITY.md)
TS_FIELDS = ("t", "scope", "queue_depth", "n_running", "kv_used_blocks",
             "kv_util", "swap_used_bytes", "tokens", "tokens_per_s",
             "preempts", "iterations", "assigns", "n_alive", "n_live",
             "n_finished", "n_rejected")


class BoundedSeries:
    """Append-only sample list with stride-doubling decimation: when
    ``rows`` reaches ``cap``, odd indices are dropped (the t~0 sample
    survives every halving) and the recording stride doubles, so
    sub-cap runs record every sample and long runs stay O(cap)."""

    __slots__ = ("rows", "cap", "stride", "_tick")

    def __init__(self, cap: int = 8192):
        self.rows: List = []
        self.cap = cap
        self.stride = 1
        self._tick = 0

    def should_record(self) -> bool:
        """One call per candidate sample; True every ``stride`` calls."""
        self._tick += 1
        return self._tick % self.stride == 0

    def append(self, row) -> None:
        self.rows.append(row)
        if len(self.rows) >= self.cap:
            del self.rows[1::2]
            self.stride *= 2

    def __len__(self) -> int:
        return len(self.rows)

    def __iter__(self):
        return iter(self.rows)


class TimeSeriesRecorder:
    """Periodic cluster/worker samples with bounded memory."""

    def __init__(self, interval: float = 1.0, cap: int = 4096):
        #: current simulated seconds between samples (doubles when the
        #: frame list is decimated)
        self.interval = interval
        self.cap = cap
        #: each frame is the list of row dicts for one sample time
        #: (one per worker + one cluster row)
        self.frames: List[List[dict]] = []
        self._last: Dict[str, tuple] = {}   # scope -> (t, tokens)

    # ------------------------------------------------------------------
    def _rate(self, scope: str, now: float, tokens: int) -> float:
        t0, tok0 = self._last.get(scope, (0.0, 0))
        self._last[scope] = (now, tokens)
        dt = now - t0
        return (tokens - tok0) / dt if dt > 0 else 0.0

    def sample(self, now: float, workers, extra: dict) -> dict:
        """Record one frame; returns the cluster row (for counters)."""
        assigns = extra.get("assigns") or {}
        rows: List[dict] = []
        tot = {"queue_depth": 0, "n_running": 0, "kv_used_blocks": 0,
               "kv_used": 0, "kv_total": 0, "swap_used_bytes": 0.0,
               "tokens": 0, "preempts": 0, "iterations": 0, "assigns": 0,
               "n_alive": 0}
        for w in workers:
            used, free = w.mem.num_used, w.mem.num_free
            row = {"t": now, "scope": f"worker{w.wid}",
                   "queue_depth": len(w.waiting),
                   "n_running": len(w.running),
                   "kv_used_blocks": used,
                   "kv_util": used / max(1, used + free),
                   "swap_used_bytes": w.swap.used_bytes
                   if w.swap is not None else 0.0,
                   "tokens": w.tokens_emitted,
                   "tokens_per_s": self._rate(
                       f"worker{w.wid}", now, w.tokens_emitted),
                   "preempts": w.preempt_events,
                   "iterations": w.iterations,
                   "assigns": assigns.get(w.wid, 0),
                   "n_alive": 1 if w.alive else 0}
            rows.append(row)
            tot["queue_depth"] += row["queue_depth"]
            tot["n_running"] += row["n_running"]
            tot["kv_used_blocks"] += used
            tot["kv_used"] += used
            tot["kv_total"] += used + free
            tot["swap_used_bytes"] += row["swap_used_bytes"]
            tot["tokens"] += row["tokens"]
            tot["preempts"] += row["preempts"]
            tot["iterations"] += row["iterations"]
            tot["assigns"] += row["assigns"]
            tot["n_alive"] += row["n_alive"]
        cluster = {"t": now, "scope": "cluster",
                   "queue_depth": tot["queue_depth"],
                   "n_running": tot["n_running"],
                   "kv_used_blocks": tot["kv_used_blocks"],
                   "kv_util": tot["kv_used"] / max(1, tot["kv_total"]),
                   "swap_used_bytes": tot["swap_used_bytes"],
                   "tokens": tot["tokens"],
                   "tokens_per_s": self._rate("cluster", now,
                                              tot["tokens"]),
                   "preempts": tot["preempts"],
                   "iterations": tot["iterations"],
                   "assigns": tot["assigns"],
                   "n_alive": tot["n_alive"],
                   "n_live": extra.get("n_live", 0),
                   "n_finished": extra.get("n_finished", 0),
                   "n_rejected": extra.get("n_rejected", 0)}
        rows.append(cluster)
        self.frames.append(rows)
        if len(self.frames) >= self.cap:
            del self.frames[1::2]
            self.interval *= 2
        return cluster

    # ------------------------------------------------------------------
    def rows(self, scope: Optional[str] = None) -> List[dict]:
        """Flat sample list, optionally filtered to one scope
        (``"cluster"``, ``"worker0"``, ...)."""
        out = [row for frame in self.frames for row in frame]
        if scope is not None:
            out = [r for r in out if r["scope"] == scope]
        return out

    def export_csv(self, path: str) -> str:
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(TS_FIELDS),
                               restval="")
            w.writeheader()
            for row in self.rows():
                w.writerow(row)
        return path

    def export_json(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump({"interval": self.interval, "fields":
                       list(TS_FIELDS), "samples": self.rows()}, f)
        return path
