"""Serving CLI: run the real paged-KV engine on a workload.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b --smoke \
      --requests 50 --qps 0 --max-batch 8
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config, get_smoke_config
from repro.core.metrics import Results
from repro.core.workload import WorkloadSpec, generate
from repro.models import model_zoo as zoo
from repro.serving.engine import EngineConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=50)
    ap.add_argument("--qps", type=float, default=0.0)
    ap.add_argument("--prompt-len", type=int, default=0,
                    help=">0 fixes the prompt length")
    ap.add_argument("--output-len", type=int, default=0)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--num-blocks", type=int, default=512)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--attn", default="gather", choices=("gather", "pallas"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = zoo.build(cfg)
    params = zoo.init_params(model, jax.random.key(args.seed))

    wl = WorkloadSpec(num_requests=args.requests, qps=args.qps,
                      seed=args.seed)
    if args.prompt_len:
        wl = WorkloadSpec(num_requests=args.requests, qps=args.qps,
                          seed=args.seed, lengths="fixed",
                          prompt_len=args.prompt_len,
                          output_len=args.output_len or 16)
    else:
        wl = WorkloadSpec(num_requests=args.requests, qps=args.qps,
                          seed=args.seed, max_prompt_len=96,
                          max_output_len=32)
    reqs = generate(wl)
    mp = args.num_blocks // max(4, args.max_batch)
    ec = EngineConfig(num_blocks=args.num_blocks, block_size=args.block_size,
                      max_batch=args.max_batch,
                      max_pages_per_seq=mp, attn_path=args.attn,
                      seed=args.seed)
    eng = ServingEngine(model, params, ec)
    for r in reqs:
        r.arrival_time = 0.0
        eng.add_request(r)
    eng.run()
    res = Results(requests=reqs, sim_time=eng.clock)
    summary = res.summary()
    summary["iterations"] = len(eng.records)
    print(json.dumps(summary, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(summary, f)


if __name__ == "__main__":
    main()
