"""TokenSim CLI: simulate an LLM serving cluster.

Examples:
  # 8xA100, continuous batching, ShareGPT-like workload at 12 QPS
  PYTHONPATH=src python -m repro.launch.simulate --arch llama2-7b \
      --workers 8 --qps 12 --requests 2000
  # disaggregated 2 prefill + 6 decode
  PYTHONPATH=src python -m repro.launch.simulate --arch llama2-7b \
      --prefill-workers 2 --decode-workers 6 --qps 12 --requests 2000
"""
from __future__ import annotations

import argparse
import json

from repro.core.mem.memory_pool import PoolConfig
from repro.core.simulator import (FaultSpec, SimSpec, Simulation,
                                  WorkerSpec)
from repro.core.workload import WorkloadSpec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--hw", default="A100")
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--prefill-workers", type=int, default=0)
    ap.add_argument("--decode-workers", type=int, default=0)
    ap.add_argument("--requests", type=int, default=1000)
    ap.add_argument("--qps", type=float, default=8.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--local", default="continuous",
                    choices=("continuous", "static"))
    ap.add_argument("--global-policy", default="least_loaded")
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--max-batched-tokens", type=int, default=2048)
    ap.add_argument("--max-mem-ratio", type=float, default=1.0)
    ap.add_argument("--gpu-mem-util", type=float, default=0.9)
    ap.add_argument("--memory-pool", action="store_true")
    ap.add_argument("--multi-round-frac", type=float, default=0.0)
    ap.add_argument("--ttft-slo", type=float, default=15.0)
    ap.add_argument("--mtpot-slo", type=float, default=0.3)
    ap.add_argument("--fail-worker", type=int, default=-1)
    ap.add_argument("--fail-time", type=float, default=30.0)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.prefill_workers or args.decode_workers:
        workers = [WorkerSpec(hw=args.hw, role="prefill",
                              gpu_mem_util=args.gpu_mem_util)
                   for _ in range(args.prefill_workers)] + \
                  [WorkerSpec(hw=args.hw, role="decode",
                              gpu_mem_util=args.gpu_mem_util,
                              max_mem_ratio=args.max_mem_ratio)
                   for _ in range(args.decode_workers)]
        gpolicy = "disagg"
    else:
        workers = [WorkerSpec(hw=args.hw, gpu_mem_util=args.gpu_mem_util,
                              max_mem_ratio=args.max_mem_ratio)
                   for _ in range(args.workers)]
        gpolicy = args.global_policy

    faults = []
    if args.fail_worker >= 0:
        faults.append(FaultSpec(time=args.fail_time, worker=args.fail_worker,
                                kind="fail"))

    spec = SimSpec(
        arch=args.arch, workers=workers,
        workload=WorkloadSpec(num_requests=args.requests, qps=args.qps,
                              seed=args.seed,
                              multi_round_frac=args.multi_round_frac),
        global_policy=gpolicy, local_policy=args.local,
        max_batch=args.max_batch,
        max_batched_tokens=args.max_batched_tokens,
        pool=PoolConfig() if args.memory_pool else None,
        faults=faults)
    res = Simulation(spec).run()
    summary = res.summary(ttft_slo=args.ttft_slo, mtpot_slo=args.mtpot_slo)
    summary["wall_time_s"] = res.wall_time
    print(json.dumps({k: (round(v, 5) if isinstance(v, float) else v)
                      for k, v in summary.items()}, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(summary, f)


if __name__ == "__main__":
    main()
