"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: builds the
production mesh from 512 host placeholder devices, lowers the real
train/prefill/decode step with the full-size model as ShapeDtypeStructs
(no allocation), compiles, and records memory_analysis / cost_analysis /
parsed per-device collective bytes for the roofline report.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k \
      --mesh single --settings baseline --out results/dryrun
  python -m repro.launch.dryrun --all --mesh both
"""
# The first two lines MUST run before any other import pulls in jax:
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"  # noqa: E402

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import (ASSIGNED, get_config, get_shape,  # noqa: E402
                           LM_SHAPES, shape_applicable)
from repro.configs.base import TRAIN, PREFILL  # noqa: E402
from repro.core.costmodel.backends import cost_analysis_dict  # noqa: E402
from repro.distributed import shard_plan  # noqa: E402
from repro.distributed.api import use_rules  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model_zoo as zoo  # noqa: E402
from repro.models.common import RunSettings  # noqa: E402
from repro.training.optimizer import AdamWConfig  # noqa: E402
from repro.training.trainer import TrainConfig, make_train_step  # noqa: E402

P = jax.sharding.PartitionSpec

SETTINGS_PRESETS = {
    # paper-faithful baseline: full-rectangle flash attention, dense
    # (every-expert) MoE, full remat — what a straightforward port does.
    "baseline": RunSettings(attn_impl="blocked", moe_impl="dense_onehot",
                            remat="full", scan_layers=True),
    # beyond-paper optimized (settings the §Perf hillclimb CONFIRMED):
    # causal-triangle attention (half the attention FLOPs) + matmul-
    # output-saving remat. moe_impl stays dense_onehot: the grouped-GEMM
    # "sort" path is numerically validated but GSPMD cannot partition
    # argsort/ragged_dot at 256 chips (§Perf A1, +587% compute) — a
    # shard_map expert-parallel dispatch is the recorded future path.
    "optimized": RunSettings(attn_impl="blocked_causal",
                             moe_impl="dense_onehot",
                             remat="dots_saveable", scan_layers=True),
    # serving variant: weights replicated over "data" (no ZeRO-3
    # all-gathers at inference)
    "optimized_serve": RunSettings(attn_impl="blocked_causal",
                                   remat="none", scan_layers=True,
                                   fsdp_params=False),
}

DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "s32": 4,
               "u64": 8, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "c64": 8, "c128": 16}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs — never allocated)
# ---------------------------------------------------------------------------
def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(model: zoo.Model, shape_name: str):
    """Returns {"batch"/"cache"/"tokens" ShapeDtypeStructs} per shape kind."""
    cfg = model.cfg
    shape = get_shape(shape_name)
    b, s = shape.global_batch, shape.seq_len
    out = {}
    if shape.kind == TRAIN:
        batch = {"tokens": sds((b, s), jnp.int32),
                 "labels": sds((b, s), jnp.int32)}
        if cfg.family in ("audio", "encdec"):
            batch["embeds"] = sds((b, cfg.enc_seq_len, cfg.d_model),
                                  jnp.float32)
        out["batch"] = batch
    elif shape.kind == PREFILL:
        batch = {"tokens": sds((b, s), jnp.int32)}
        if cfg.family in ("audio", "encdec"):
            batch["embeds"] = sds((b, cfg.enc_seq_len, cfg.d_model),
                                  jnp.float32)
        out["batch"] = batch
        out["cache"] = zoo.cache_specs(model, b, s)
    else:  # decode: one new token against a cache of seq_len
        out["tokens"] = sds((b,), jnp.int32)
        out["cache"] = zoo.cache_specs(model, b, s)
    return out


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of all shapes in a type string like
    'f32[8,128]' or '(bf16[2,4], u32[])'."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str):
    """Per-device bytes by collective kind, from post-SPMD HLO."""
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.+?) (all-reduce|all-gather|"
                     r"reduce-scatter|all-to-all|collective-permute)"
                     r"(?:-start|-done)?\(", ls)
        if not m:
            continue
        if "-done(" in ls:
            continue                      # counted at -start
        result_type, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(result_type)
        # reduce-scatter's result is 1/n of the data moved; use operand
        if kind == "reduce-scatter":
            args = ls.split("(", 1)[1]
            nbytes = max(nbytes, _shape_bytes(args.split(")")[0]))
        out[kind]["count"] += 1
        out[kind]["bytes"] += nbytes
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


# ---------------------------------------------------------------------------
# Depth probing (roofline counting mode)
# ---------------------------------------------------------------------------
def probe_depths(cfg):
    """Two reduced depths (a < b) per family for finite-difference layer
    accounting: per-layer cost = (f(b)-f(a))/(b-a), constant part =
    f(b) - b*layer, total = constant + L*layer.  Exact because every
    cost component is affine in depth (identical layers; the optimizer
    update scales with per-layer params)."""
    if cfg.family == "hybrid":
        p = cfg.attn_period or 1
        return p, 2 * p
    if cfg.family in ("audio", "encdec"):
        return 2, 4            # scales n_enc and n_dec together
    return 2, 4


def with_depth(cfg, n: int):
    kw = {"num_layers": n}
    if cfg.family in ("audio", "encdec"):
        frac_e = cfg.n_enc_layers / cfg.num_layers
        kw = {"num_layers": n,
              "n_enc_layers": max(1, round(n * frac_e)),
              "n_dec_layers": n - max(1, round(n * frac_e))}
    return cfg.with_overrides(**kw)


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------
def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             settings: RunSettings, grad_compression: bool = False,
             seq_parallel: bool | None = None,
             save_hlo: str | None = None,
             depth_override: int | None = None) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}
    if depth_override is not None:
        cfg = with_depth(cfg, depth_override)

    mesh = make_production_mesh(multi_pod=multi_pod)
    model = zoo.build(cfg, tp=16, settings=settings)
    if seq_parallel is None:
        seq_parallel = (shape_name == "long_500k")
    rules = shard_plan.default_rules(multi_pod=multi_pod,
                                     seq_parallel=seq_parallel)

    pparams = shard_plan.param_pspecs(model)
    specs = input_specs(model, shape_name)
    t0 = time.perf_counter()

    def N(tree):
        return shard_plan.named(mesh, tree)

    with mesh:
        if shape.kind == TRAIN:
            tc = TrainConfig(opt=AdamWConfig(),
                             grad_compression=grad_compression)
            step_fn = make_train_step(model, tc)
            params_s = zoo.param_specs(model)
            opt_s = jax.eval_shape(
                lambda p: {"mu": p, "nu": p,
                           "step": jnp.zeros((), jnp.int32)}, params_s)
            ef_s = jax.eval_shape(
                lambda p: jax.tree.map(
                    lambda x: jnp.zeros(x.shape, jnp.float32), p),
                params_s) if grad_compression else \
                {"_": sds((), jnp.float32)}
            in_shard = (pparams, shard_plan.opt_pspecs(model),
                        shard_plan.ef_pspecs(model, grad_compression),
                        shard_plan.batch_pspecs(specs["batch"], rules))
            out_shard = (pparams, shard_plan.opt_pspecs(model),
                         shard_plan.ef_pspecs(model, grad_compression),
                         None)

            def wrapped(params, opt, ef, batch):
                with use_rules(mesh, rules):
                    return step_fn(params, opt, ef, batch)

            jitted = jax.jit(wrapped, in_shardings=N(in_shard),
                             out_shardings=N(out_shard))
            lowered = jitted.lower(params_s, opt_s, ef_s, specs["batch"])

        elif shape.kind == PREFILL:
            params_s = zoo.param_specs(model)
            cache_sh = shard_plan.cache_pspecs(model, rules)
            in_shard = (pparams,
                        shard_plan.batch_pspecs(specs["batch"], rules),
                        cache_sh)
            out_shard = (rules.spec("batch", "vocab"), cache_sh)

            def prefill_step(params, batch, cache):
                with use_rules(mesh, rules):
                    logits, cache = zoo.prefill(model, params, batch,
                                                cache)
                    return logits[:, -1], cache   # serving: sample last

            jitted = jax.jit(prefill_step, in_shardings=N(in_shard),
                             out_shardings=N(out_shard))
            lowered = jitted.lower(params_s, specs["batch"],
                                   specs["cache"])

        else:  # DECODE
            params_s = zoo.param_specs(model)
            cache_sh = shard_plan.cache_pspecs(model, rules)
            in_shard = (pparams, cache_sh, rules.spec("batch"))
            out_shard = (rules.spec("batch", "vocab"), cache_sh)

            def serve_step(params, cache, tokens):
                with use_rules(mesh, rules):
                    return zoo.decode_step(model, params, cache, tokens)

            jitted = jax.jit(serve_step, in_shardings=N(in_shard),
                             out_shardings=N(out_shard))
            lowered = jitted.lower(params_s, specs["cache"],
                                   specs["tokens"])

        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled.cost_analysis())
    hlo = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    coll = parse_collectives(hlo)

    n_devices = 512 if multi_pod else 256
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_devices,
        "settings": dataclasses.asdict(settings),
        "seq_parallel": seq_parallel,
        "grad_compression": grad_compression,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            k: getattr(mem, k) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes",
             "alias_size_in_bytes")
            if hasattr(mem, k)},
        "cost": {k: cost.get(k) for k in
                 ("flops", "bytes accessed", "transcendentals")
                 if k in cost} if isinstance(cost, dict) else {},
        "collectives": coll,
    }
    return result


# ---------------------------------------------------------------------------
def all_cells():
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for shape in LM_SHAPES:
            yield arch, shape.name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--settings", default="baseline")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--seq-parallel", type=int, default=-1,
                    help="-1 auto (long_500k only), 0 off, 1 on")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--depth-probe", action="store_true",
                    help="roofline counting mode: lower each cell at two "
                         "reduced unrolled depths for finite-difference "
                         "layer accounting (see DESIGN.md §6)")
    args = ap.parse_args()

    settings = SETTINGS_PRESETS[args.settings] \
        if args.settings in SETTINGS_PRESETS else \
        RunSettings(**json.loads(args.settings))
    os.makedirs(args.out, exist_ok=True)

    cells = list(all_cells()) if args.all else [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            depths = [None]
            if args.depth_probe:
                from repro.configs import get_config as _gc
                a, b = probe_depths(_gc(arch))
                depths = [a, b]
            for depth in depths:
                tag = f"{arch}_{shape}_{'multi' if mp else 'single'}" \
                      f"_{args.settings if args.settings in SETTINGS_PRESETS else 'custom'}"
                if depth is not None:
                    tag += f"_d{depth}"
                try:
                    sp = None if args.seq_parallel < 0 \
                        else bool(args.seq_parallel)
                    res = run_cell(arch, shape, multi_pod=mp,
                                   settings=settings,
                                   grad_compression=args.grad_compression,
                                   seq_parallel=sp, save_hlo=args.save_hlo,
                                   depth_override=depth)
                    if depth is not None:
                        res["depth_override"] = depth
                    status = "SKIP" if "skipped" in res else "OK"
                except Exception as e:                     # noqa: BLE001
                    res = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()}
                    status = "FAIL"
                    failures += 1
                path = os.path.join(args.out, tag + ".json")
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                extra = ""
                if status == "OK":
                    extra = (f" compile={res['compile_s']}s "
                             f"flops={res['cost'].get('flops', 0):.3e} "
                             f"coll={res['collectives']['total_bytes']:.3e}B")
                print(f"[{status}] {tag}{extra}", flush=True)
                if status == "SKIP":
                    break                      # skip both depths
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
