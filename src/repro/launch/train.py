"""Training CLI.

Examples:
  # smoke-size 2-layer qwen3 on CPU
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --smoke \
      --steps 50 --seq-len 64 --batch 8
  # ~100M-param model for a few hundred steps (examples/train_100m.py)
"""
from __future__ import annotations

import argparse
import json

import jax

from repro.configs import get_config, get_smoke_config
from repro.models import model_zoo as zoo
from repro.models.common import RunSettings
from repro.training.data import DataConfig
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--remat", default="full",
                    choices=("none", "full", "dots_saveable"))
    ap.add_argument("--history-out", default=None)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    settings = RunSettings(remat=args.remat)
    model = zoo.build(cfg, settings=settings)
    tc = TrainConfig(
        opt=AdamWConfig(lr=args.lr, warmup_steps=max(10, args.steps // 20),
                        total_steps=args.steps),
        microbatches=args.microbatches,
        grad_compression=args.grad_compression,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                    global_batch=args.batch, seed=args.seed)
    trainer = Trainer(model, tc, dc, init_key=jax.random.key(args.seed))
    print(f"arch={cfg.name} params={zoo.param_count(trainer.params):,} "
          f"steps={args.steps}")
    trainer.run(args.steps)
    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump(trainer.history, f)
    print("final:", trainer.history[-1])


if __name__ == "__main__":
    main()
