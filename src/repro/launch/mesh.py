"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run driver sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import, and everything else sees the real single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, data: int = 1, model: int = 1, pod: int = 1):
    """Small meshes for CPU-device tests (requires enough host devices)."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
