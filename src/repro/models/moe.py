"""Token-choice top-k MoE (granite-style) with two interchangeable impls.

* ``dense_onehot`` — every expert computes every token; outputs are combined
  with the (renormalized) top-k router weights. Exact, simple, and the
  *paper-faithful baseline* for the dry-run: its HLO FLOPs are E/k× the
  active-parameter FLOPs, which the §Perf hillclimb then removes.
* ``sort`` — dropless grouped-GEMM: token→expert assignments are sorted by
  expert id and dispatched through ``jax.lax.ragged_dot`` (TPU grouped
  matmul). HLO FLOPs ≈ top_k × active FLOPs. This is the beyond-paper
  optimized path.

Both paths agree to float tolerance (tests assert allclose).

Expert weights are stacked with a leading expert axis so EP sharding is a
single PartitionSpec entry: gate/up: (E, d_model, d_expert), down:
(E, d_expert, d_model). Padded experts (pad plan) receive -inf router
logits and therefore zero routing weight.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import _normal


def moe_init(key, d_model: int, n_experts: int, d_expert: int, act: str,
             dtype, n_experts_logical: Optional[int] = None):
    ks = jax.random.split(key, 4)
    gated = act == "silu"
    p = {
        "router": _normal(ks[0], (d_model, n_experts), dtype, d_model ** -0.5),
        "up": _normal(ks[1], (n_experts, d_model, d_expert), dtype,
                      d_model ** -0.5),
        "down": _normal(ks[2], (n_experts, d_expert, d_model), dtype,
                        d_expert ** -0.5),
    }
    if gated:
        p["gate"] = _normal(ks[3], (n_experts, d_model, d_expert), dtype,
                            d_model ** -0.5)
    return p


def _router(p, x, top_k: int, n_experts_logical: int, compute_dtype):
    """Top-k routing. x: (T, d). Returns (probs (T,k), ids (T,k), aux)."""
    logits = (x.astype(jnp.float32)
              @ p["router"].astype(jnp.float32))            # (T, E)
    e = logits.shape[-1]
    if n_experts_logical < e:                                # padded experts
        pad_mask = jnp.arange(e) >= n_experts_logical
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    top_logits, top_ids = jax.lax.top_k(logits, top_k)       # (T, k)
    probs = jax.nn.softmax(top_logits, axis=-1)              # renormalized
    # Load-balance aux loss (Switch-style) + router z-loss, over real experts.
    full_probs = jax.nn.softmax(logits, axis=-1)
    me = full_probs.mean(axis=0)                             # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[top_ids.reshape(-1)].add(
        1.0 / top_ids.size)
    aux = n_experts_logical * jnp.sum(me * ce)
    zloss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return probs, top_ids, {"aux": aux, "zloss": zloss}


def _expert_ffn_dense(p, x, compute_dtype):
    """All experts on all tokens. x: (T, d) -> (E, T, d)."""
    up = jnp.einsum("td,edf->etf", x, p["up"].astype(compute_dtype))
    if "gate" in p:
        g = jnp.einsum("td,edf->etf", x, p["gate"].astype(compute_dtype))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(g.dtype) * up
    else:
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(up.dtype)
    return jnp.einsum("etf,efd->etd", h, p["down"].astype(compute_dtype))


def moe_apply_dense(p, x, *, top_k: int, n_experts_logical: int,
                    compute_dtype) -> Tuple[jnp.ndarray, dict]:
    """dense_onehot path. x: (..., d)."""
    shp = x.shape
    x2 = x.reshape(-1, shp[-1]).astype(compute_dtype)        # (T, d)
    probs, ids, aux = _router(p, x2, top_k, n_experts_logical, compute_dtype)
    e = p["router"].shape[-1]
    outs = _expert_ffn_dense(p, x2, compute_dtype)           # (E, T, d)
    onehot = jax.nn.one_hot(ids, e, dtype=jnp.float32)       # (T, k, E)
    weights = jnp.einsum("tk,tke->te", probs, onehot)        # (T, E)
    y = jnp.einsum("te,etd->td", weights.astype(compute_dtype), outs)
    return y.reshape(shp), aux


def moe_apply_sort(p, x, *, top_k: int, n_experts_logical: int,
                   compute_dtype) -> Tuple[jnp.ndarray, dict]:
    """Dropless grouped-GEMM path via ragged_dot. x: (..., d)."""
    shp = x.shape
    x2 = x.reshape(-1, shp[-1]).astype(compute_dtype)        # (T, d)
    t, d = x2.shape
    probs, ids, aux = _router(p, x2, top_k, n_experts_logical, compute_dtype)
    e = p["router"].shape[-1]

    flat_ids = ids.reshape(-1)                               # (T*k,)
    order = jnp.argsort(flat_ids)                            # stable
    inv = jnp.argsort(order)
    token_of = order // top_k                                # source token
    xs = x2[token_of]                                        # (T*k, d) sorted
    group_sizes = jnp.bincount(flat_ids, length=e).astype(jnp.int32)

    up = jax.lax.ragged_dot(xs, p["up"].astype(compute_dtype), group_sizes)
    if "gate" in p:
        g = jax.lax.ragged_dot(xs, p["gate"].astype(compute_dtype),
                               group_sizes)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(g.dtype) * up
    else:
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(up.dtype)
    ys = jax.lax.ragged_dot(h, p["down"].astype(compute_dtype), group_sizes)

    y_flat = ys[inv]                                         # (T*k, d) token order
    w = probs.reshape(-1)[:, None].astype(compute_dtype)
    y = jnp.sum((y_flat * w).reshape(t, top_k, d), axis=1)
    return y.reshape(shp), aux


def moe_apply(p, x, *, top_k: int, n_experts_logical: int, impl: str,
              compute_dtype):
    if impl == "dense_onehot":
        return moe_apply_dense(p, x, top_k=top_k,
                               n_experts_logical=n_experts_logical,
                               compute_dtype=compute_dtype)
    if impl == "sort":
        return moe_apply_sort(p, x, top_k=top_k,
                              n_experts_logical=n_experts_logical,
                              compute_dtype=compute_dtype)
    raise ValueError(f"unknown moe impl {impl!r}")
