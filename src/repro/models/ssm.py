"""Mamba2 / SSD (state-space duality) block — arXiv:2405.21060.

Pure-functional, three entry points that agree numerically (tests):

* ``ssd_chunked``   — chunked "attention-like" scan used for train/prefill.
                      Quadratic only within a chunk; linear across chunks.
* ``ssd_recurrent`` — token-by-token reference recurrence (oracle; slow).
* ``ssd_step``      — O(1) single-token decode state update.

The block (``mamba2_init/apply/decode``) follows the Mamba2 layout:
``in_proj -> [z | xBC | dt]``, causal depthwise conv over xBC, SSD core,
D skip, gated RMSNorm, ``out_proj``. B/C are grouped (``n_groups``); heads
within a group share B/C (the multi-value-attention analogue).

Padded SSD heads (pad plan) are masked at out_proj: their ``A_log`` rows
still exist but the output projection columns for padded heads are zeroed
by the mask, so they contribute nothing and receive zero gradient signal
through the output path.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import _normal


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------
def ssd_recurrent(xbar, dA_log, Bm, Cm, state0=None):
    """Token-by-token oracle.  xbar: (B,S,H,P) dt-scaled inputs;
    dA_log: (B,S,H) = dt*A (<=0);  Bm/Cm: (B,S,G,N), heads grouped
    contiguously (head h uses group h // (H//G)).

    Returns (y (B,S,H,P) fp32, final_state (B,H,N,P) fp32).
    """
    b, s, h, p = xbar.shape
    g, n = Bm.shape[2], Bm.shape[3]
    hpg = h // g
    Bh = jnp.repeat(Bm, hpg, axis=2).astype(jnp.float32)     # (B,S,H,N)
    Ch = jnp.repeat(Cm, hpg, axis=2).astype(jnp.float32)
    xf = xbar.astype(jnp.float32)
    da = jnp.exp(dA_log.astype(jnp.float32))                 # (B,S,H)

    def step(state, inp):
        x_t, b_t, c_t, a_t = inp                             # (B,H,P),(B,H,N)...
        state = state * a_t[..., None, None] + \
            b_t[..., :, None] * x_t[..., None, :]            # (B,H,N,P)
        y_t = jnp.einsum("bhn,bhnp->bhp", c_t, state)
        return state, y_t

    state0 = jnp.zeros((b, h, n, p), jnp.float32) if state0 is None \
        else state0.astype(jnp.float32)
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(Bh, 1, 0),
          jnp.moveaxis(Ch, 1, 0), jnp.moveaxis(da, 1, 0))
    state, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1), state                     # (B,S,H,P)


def ssd_chunked(xbar, dA_log, Bm, Cm, chunk: int, state0=None):
    """Chunked SSD scan (the Mamba2 'SSD' algorithm).

    Same signature/semantics as ``ssd_recurrent`` but O(S·L) memory and
    matmul-dominated (MXU-friendly): within-chunk attention-like term +
    lax.scan over per-chunk states.
    """
    b, s, h, p = xbar.shape
    g, n = Bm.shape[2], Bm.shape[3]
    if s % chunk:
        raise ValueError(f"seq {s} not divisible by chunk {chunk}")
    nc, L = s // chunk, chunk
    hpg = h // g

    xf = xbar.astype(jnp.float32).reshape(b, nc, L, h, p)
    la = dA_log.astype(jnp.float32).reshape(b, nc, L, h)     # log a_t
    Bc = Bm.astype(jnp.float32).reshape(b, nc, L, g, n)
    Cc = Cm.astype(jnp.float32).reshape(b, nc, L, g, n)

    seg = jnp.cumsum(la, axis=2)                             # L_i, incl. self
    total = seg[:, :, -1, :]                                 # (B,nc,H)

    # ---- within-chunk (quadratic in L) --------------------------------
    # scores_ij = C_i . B_j per group -> (B,nc,G,L,L)
    scores = jnp.einsum("bclgn,bcmgn->bcglm", Cc, Bc)
    # decay_ij = exp(L_i - L_j) for i>=j else 0 -> (B,nc,H,L,L)
    li = seg[:, :, :, None, :]                               # (B,nc,L,1,H)
    lj = seg[:, :, None, :, :]                               # (B,nc,1,L,H)
    mask = jnp.tril(jnp.ones((L, L), bool))
    decay = jnp.exp(jnp.where(mask[None, None, :, :, None], li - lj, -jnp.inf))
    decay = jnp.moveaxis(decay, -1, 2)                       # (B,nc,H,L,L)
    scores_h = jnp.repeat(scores, hpg, axis=2) * decay       # (B,nc,H,L,L)
    y_intra = jnp.einsum("bchlm,bcmhp->bclhp", scores_h, xf)

    # ---- per-chunk state contribution ---------------------------------
    # S_c = sum_j exp(total - L_j) B_j (x)  xbar_j^T  -> (B,nc,H,N,P)
    w = jnp.exp(total[:, :, None, :] - seg)                  # (B,nc,L,H)
    Bh = jnp.repeat(Bc, hpg, axis=3)                         # (B,nc,L,H,N)
    chunk_states = jnp.einsum("bclh,bclhn,bclhp->bchnp", w, Bh, xf)

    # ---- inter-chunk recurrence ----------------------------------------
    def step(state, inp):
        cs, tot = inp                                        # (B,H,N,P),(B,H)
        out_state = state                                    # state BEFORE chunk
        state = state * jnp.exp(tot)[..., None, None] + cs
        return state, out_state

    state0 = jnp.zeros((b, h, n, p), jnp.float32) if state0 is None \
        else state0.astype(jnp.float32)
    final_state, states_in = jax.lax.scan(
        step, state0, (jnp.moveaxis(chunk_states, 1, 0),
                       jnp.moveaxis(total, 1, 0)))
    states_in = jnp.moveaxis(states_in, 0, 1)                # (B,nc,H,N,P)

    # y_inter_i = exp(L_i) C_i . S_in
    Ch = jnp.repeat(Cc, hpg, axis=3)                         # (B,nc,L,H,N)
    y_inter = jnp.einsum("bclh,bclhn,bchnp->bclhp",
                         jnp.exp(seg), Ch, states_in)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, final_state


def ssd_step(state, x_t, dA_log_t, B_t, C_t):
    """One decode step.  state: (B,H,N,P) fp32; x_t: (B,H,P) dt-scaled;
    dA_log_t: (B,H); B_t/C_t: (B,G,N).  Returns (y (B,H,P), state)."""
    h = x_t.shape[1]
    g = B_t.shape[1]
    hpg = h // g
    Bh = jnp.repeat(B_t, hpg, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(C_t, hpg, axis=1).astype(jnp.float32)
    a = jnp.exp(dA_log_t.astype(jnp.float32))
    state = state * a[..., None, None] + \
        Bh[..., :, None] * x_t.astype(jnp.float32)[..., None, :]
    y = jnp.einsum("bhn,bhnp->bhp", Ch, state)
    return y, state


# ---------------------------------------------------------------------------
# Depthwise causal conv1d
# ---------------------------------------------------------------------------
def causal_conv1d(x, w, b, state=None):
    """x: (B,S,C); w: (W,C); b: (C,).  Left-pads with `state`
    ((B,W-1,C), zeros if None).  Returns (y (B,S,C), new_state)."""
    bsz, s, c = x.shape
    wwin = w.shape[0]
    if state is None:
        state = jnp.zeros((bsz, wwin - 1, c), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    # depthwise conv as sum of shifted scaled copies (W is tiny: 4)
    y = jnp.zeros((bsz, s, c), jnp.float32)
    for i in range(wwin):
        y = y + xp[:, i:i + s, :].astype(jnp.float32) * \
            w[i][None, None, :].astype(jnp.float32)
    y = y + b[None, None, :].astype(jnp.float32)
    new_state = xp[:, s:, :]
    return y.astype(x.dtype), new_state


def conv_step(x_t, w, b, state):
    """One-token conv.  x_t: (B,C); state: (B,W-1,C)."""
    xp = jnp.concatenate([state, x_t[:, None, :]], axis=1)   # (B,W,C)
    y = jnp.einsum("bwc,wc->bc", xp.astype(jnp.float32),
                   w.astype(jnp.float32)) + b.astype(jnp.float32)
    return y.astype(x_t.dtype), xp[:, 1:, :]


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------
def mamba2_init(key, d_model: int, ssm, dtype, n_heads_phys: int = 0):
    """ssm: SSMConfig.  ``n_heads_phys`` >= logical heads (pad plan)."""
    d_in = ssm.d_inner(d_model)
    h_log = ssm.n_heads(d_model)
    h = n_heads_phys or h_log
    p = ssm.head_dim
    d_in_phys = h * p
    g, n = ssm.n_groups, ssm.d_state
    conv_dim = d_in_phys + 2 * g * n
    ks = jax.random.split(key, 4)
    d_proj = 2 * d_in_phys + 2 * g * n + h                   # z|xBC|dt
    params = {
        "in_proj": _normal(ks[0], (d_model, d_proj), dtype, d_model ** -0.5),
        "conv_w": _normal(ks[1], (ssm.conv_width, conv_dim), dtype,
                          conv_dim ** -0.5),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        # A in [-1, -e] roughly: A_log ~ log(Uniform[1,16])
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(dtype),
        "D": jnp.ones((h,), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (h,),
                                       minval=math.log(1e-3),
                                       maxval=math.log(1e-1))))).astype(dtype),
        "norm_scale": jnp.ones((d_in_phys,), dtype),
        "out_proj": _normal(ks[3], (d_in_phys, d_model), dtype,
                            d_in_phys ** -0.5),
    }
    return params


def _mamba2_pre(p, x, ssm, h, compute_dtype):
    """Shared pre-SSD computation. x: (B,S,d_model) ->
    (z, xBC_raw, dt_raw) in compute dtype."""
    proj = x.astype(compute_dtype) @ p["in_proj"].astype(compute_dtype)
    pdim = ssm.head_dim
    d_in = h * pdim
    g, n = ssm.n_groups, ssm.d_state
    z = proj[..., :d_in]
    xBC = proj[..., d_in:d_in + d_in + 2 * g * n]
    dt_raw = proj[..., -h:]
    return z, xBC, dt_raw


def _mamba2_post(p, y, z, x_conv, compute_dtype, head_mask=None):
    """D-skip + gated norm + out_proj.  y,x_conv: (B,S,H,P) fp32/compute."""
    b, s, h, pd = y.shape
    D = p["D"].astype(jnp.float32)
    y = y + D[None, None, :, None] * x_conv.astype(jnp.float32)
    if head_mask is not None:
        y = y * head_mask[None, None, :, None]
    y = y.reshape(b, s, h * pd)
    zf = jax.nn.silu(z.astype(jnp.float32))
    y = y * zf
    # gated RMSNorm
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-5) * \
        p["norm_scale"].astype(jnp.float32)[None, None, :]
    y = y.astype(compute_dtype)
    return y @ p["out_proj"].astype(compute_dtype)


def _split_xbc(xBC, h, pdim, g, n):
    x = xBC[..., : h * pdim]
    Bm = xBC[..., h * pdim: h * pdim + g * n]
    Cm = xBC[..., h * pdim + g * n:]
    return x, Bm, Cm


def mamba2_apply(p, x, ssm, *, compute_dtype, conv_state=None, ssd_state=None,
                 head_mask=None, impl: str = "chunked", chunk: int = 0):
    """Full-sequence Mamba2 block.  x: (B,S,d_model).

    Returns (out (B,S,d_model), (conv_state, ssd_state)) so prefill can
    seed decode.
    """
    b, s, _ = x.shape
    h = p["A_log"].shape[0]
    pdim = ssm.head_dim
    g, n = ssm.n_groups, ssm.d_state
    chunk = chunk or ssm.chunk_size

    z, xBC, dt_raw = _mamba2_pre(p, x, ssm, h, compute_dtype)
    xBC, conv_state = causal_conv1d(xBC, p["conv_w"], p["conv_b"], conv_state)
    xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(compute_dtype)
    xc, Bm, Cm = _split_xbc(xBC, h, pdim, g, n)
    xc = xc.reshape(b, s, h, pdim)
    Bm = Bm.reshape(b, s, g, n)
    Cm = Cm.reshape(b, s, g, n)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))   # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))             # (H,)
    dA_log = dt * A[None, None, :]
    xbar = xc.astype(jnp.float32) * dt[..., None]

    if impl == "chunked" and s % chunk == 0 and s > 1:
        y, ssd_state = ssd_chunked(xbar, dA_log, Bm, Cm, chunk, ssd_state)
    else:
        y, ssd_state = ssd_recurrent(xbar, dA_log, Bm, Cm, ssd_state)

    out = _mamba2_post(p, y, z, xc, compute_dtype, head_mask)
    return out, (conv_state, ssd_state)


def mamba2_decode(p, x_t, ssm, *, compute_dtype, conv_state, ssd_state,
                  head_mask=None):
    """One-token decode.  x_t: (B,d_model); states from prefill.

    Returns (out (B,d_model), (conv_state, ssd_state))."""
    bsz = x_t.shape[0]
    h = p["A_log"].shape[0]
    pdim = ssm.head_dim
    g, n = ssm.n_groups, ssm.d_state

    z, xBC, dt_raw = _mamba2_pre(p, x_t[:, None, :], ssm, h, compute_dtype)
    z, xBC, dt_raw = z[:, 0], xBC[:, 0], dt_raw[:, 0]
    xBC, conv_state = conv_step(xBC, p["conv_w"], p["conv_b"], conv_state)
    xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(compute_dtype)
    xc, Bm, Cm = _split_xbc(xBC, h, pdim, g, n)
    xc = xc.reshape(bsz, h, pdim)
    Bm = Bm.reshape(bsz, g, n)
    Cm = Cm.reshape(bsz, g, n)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))   # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA_log = dt * A[None, :]
    xbar = xc.astype(jnp.float32) * dt[..., None]

    y, ssd_state = ssd_step(ssd_state, xbar, dA_log, Bm, Cm)
    out = _mamba2_post(p, y[:, None], z[:, None], xc[:, None],
                       compute_dtype, head_mask)
    return out[:, 0], (conv_state, ssd_state)
