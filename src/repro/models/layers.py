"""Building-block layers, written as pure functions over pytrees of params.

No flax/haiku offline — a tiny functional convention instead:

* ``init_*(key, ...) -> params`` returns a dict pytree.
* ``apply`` functions take ``(params, x, ...)`` and are jit/pjit friendly.

Parameters are stored in ``param_dtype`` (fp32 master) and cast to the
compute dtype at use (bf16 on TPU), the standard mixed-precision recipe.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def _normal(key, shape, dtype, scale):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def dense_init(key, d_in, d_out, dtype, *, bias=False, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": _normal(key, (d_in, d_out), dtype, scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p, x, compute_dtype=None):
    w = p["w"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
        x = x.astype(compute_dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def norm_init(d, kind, dtype):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_apply(p, x, kind, eps=1e-5):
    """RMSNorm / LayerNorm in fp32, output in x.dtype."""
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    else:
        raise ValueError(kind)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def head_norm_apply(p, x, eps=1e-6):
    """Per-head RMSNorm over head_dim (qk-norm). x: (..., H, D)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D); positions: (B, S) or (S,). Pair layout: [0::2],[1::2]
    interleaved halves (GPT-NeoX style split-half, matching most HF ports)."""
    b, s, h, d = x.shape
    freqs = rope_freqs(d, theta)                       # (D/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(max_len: int, d_model: int):
    pos = jnp.arange(max_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, 2 * dim / d_model)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def mlp_init(key, d_model, d_ff, act, dtype, *, bias=False):
    ks = jax.random.split(key, 3)
    if act == "silu":  # SwiGLU
        return {"gate": dense_init(ks[0], d_model, d_ff, dtype, bias=bias),
                "up": dense_init(ks[1], d_model, d_ff, dtype, bias=bias),
                "down": dense_init(ks[2], d_ff, d_model, dtype, bias=bias,
                                    scale=d_ff ** -0.5)}
    return {"up": dense_init(ks[0], d_model, d_ff, dtype, bias=bias),
            "down": dense_init(ks[1], d_ff, d_model, dtype, bias=bias,
                                scale=d_ff ** -0.5)}


def mlp_apply(p, x, act, compute_dtype):
    if "gate" in p:
        g = dense_apply(p["gate"], x, compute_dtype)
        u = dense_apply(p["up"], x, compute_dtype)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(g.dtype) * u
    else:
        u = dense_apply(p["up"], x, compute_dtype)
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(u.dtype)
    return dense_apply(p["down"], h, compute_dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def embed_init(key, vocab, d_model, dtype):
    return {"table": _normal(key, (vocab, d_model), dtype, 0.02 * math.sqrt(d_model) / math.sqrt(d_model))}


def embed_apply(p, ids, compute_dtype):
    return p["table"].astype(compute_dtype)[ids]


def unembed_apply(table, x, *, vocab_logical: int, fp32: bool = True):
    """x @ table.T with padded-vocab masking. table: (Vp, D)."""
    dt = jnp.float32 if fp32 else x.dtype
    logits = jnp.einsum("...d,vd->...v", x.astype(dt), table.astype(dt))
    vp = table.shape[0]
    if vp != vocab_logical:
        neg = jnp.full((vp - vocab_logical,), -1e30, dt)
        logits = logits.at[..., vocab_logical:].set(neg)
    return logits


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------
def softmax_xent(logits, labels, mask=None):
    """Mean token cross-entropy. logits fp32 (..., V); labels int (...)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
