"""Model zoo: one builder covering every assigned architecture family.

``Model`` is a frozen (hashable) bundle of (ArchConfig, PadPlan,
RunSettings); every entry point below takes it as the static first
argument, so ``jax.jit(fn, static_argnums=0)`` just works.

Entry points
------------
* ``init_params(model, key)``     — parameter pytree (fp32 master).
* ``param_specs(model)``          — ShapeDtypeStructs (dry-run, no alloc).
* ``forward(model, params, batch)``   — full-seq logits (train/prefill math).
* ``loss_fn(model, params, batch)``   — token cross-entropy (+ MoE aux).
* ``init_cache / cache_specs``    — decode-state pytree per family.
* ``prefill(model, params, batch, cache, prompt_lens)``
* ``decode_step(model, params, cache, tokens)``

Cache layouts (leading L axis is scanned):
  dense/moe/vlm: {k,v: (L,B,Smax,Hkv_phys,hd), len: (B,)}
  ssm:           {conv: (L,B,W-1,C), ssd: (L,B,H,N,P) f32, len: (B,)}
  hybrid:        ssm states for all layers + {k,v: (Napp,B,Smax,H,hd)}
  audio(enc-dec):{k,v: (Ld,...), xk,xv: (Ld,B,Senc,H,hd), len}
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ArchConfig, AUDIO, DENSE, ENCDEC, HYBRID,
                                MOE, SSM, VLM)
from repro.distributed.api import shard
from repro.distributed.padding import PadPlan, make_pad_plan
from repro.models import ssm as ssm_mod
from repro.models.attention_impl import attend, decode_attention
from repro.models.common import RunSettings, DEFAULT_SETTINGS
from repro.models.layers import (dense_init, dense_apply, embed_init,
                                 embed_apply, head_norm_apply, mlp_init,
                                 mlp_apply, norm_apply, norm_init,
                                 softmax_xent, unembed_apply, apply_rope,
                                 _normal)
from repro.models.moe import moe_init, moe_apply

ATTN_FAMILIES = (DENSE, MOE, VLM)
LEARNED_POS_CAP = 32_768  # learned position tables are capped (DESIGN.md)


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    plan: PadPlan
    settings: RunSettings = DEFAULT_SETTINGS

    @property
    def compute_dtype(self):
        return jnp.dtype(self.cfg.dtype)

    @property
    def param_dtype(self):
        return jnp.dtype(self.cfg.param_dtype)

    def with_settings(self, **kw) -> "Model":
        import dataclasses
        return dataclasses.replace(
            self, settings=dataclasses.replace(self.settings, **kw))


def build(cfg: ArchConfig, tp: int = 1,
          settings: RunSettings = DEFAULT_SETTINGS) -> Model:
    return Model(cfg=cfg, plan=make_pad_plan(cfg, tp), settings=settings)


# ---------------------------------------------------------------------------
# Attention block
# ---------------------------------------------------------------------------
def _attn_init(key, model: Model, *, cross: bool = False):
    cfg, plan = model.cfg, model.plan
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv_log = plan.n_q, plan.n_kv // plan.kv_rep
    dt = model.param_dtype
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, nq * hd, dt, bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], d, nkv_log * hd, dt, bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], d, nkv_log * hd, dt, bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], nq * hd, d, dt, scale=(nq * hd) ** -0.5),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = {"scale": jnp.ones((hd,), dt)}
        p["k_norm"] = {"scale": jnp.ones((hd,), dt)}
    return p


def _q_proj(p, x, model: Model, positions):
    cfg, plan = model.cfg, model.plan
    cd = model.compute_dtype
    b, s, _ = x.shape
    q = dense_apply(p["wq"], x, cd).reshape(b, s, plan.n_q, cfg.head_dim)
    if "q_norm" in p:
        q = head_norm_apply(p["q_norm"], q)
    if cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
    return shard(q, "batch", "seq", "heads", None)


def _kv_proj(p, x, model: Model, positions):
    """K/V in *physical* head layout (kv_rep applied)."""
    cfg, plan = model.cfg, model.plan
    cd = model.compute_dtype
    b, s, _ = x.shape
    nkv_log = plan.n_kv // plan.kv_rep
    k = dense_apply(p["wk"], x, cd).reshape(b, s, nkv_log, cfg.head_dim)
    v = dense_apply(p["wv"], x, cd).reshape(b, s, nkv_log, cfg.head_dim)
    if "k_norm" in p:
        k = head_norm_apply(p["k_norm"], k)
    if cfg.pos_emb == "rope" and positions is not None:
        k = apply_rope(k, positions, cfg.rope_theta)
    if plan.kv_rep > 1:
        k = jnp.repeat(k, plan.kv_rep, axis=2)
        v = jnp.repeat(v, plan.kv_rep, axis=2)
    k = shard(k, "batch", "kv_seq", "kv_heads", None)
    v = shard(v, "batch", "kv_seq", "kv_heads", None)
    return k, v


def _attn_out(p, ctx, model: Model):
    """ctx: (B,S,nq,hd) -> (B,S,d); padded q heads masked."""
    plan = model.plan
    b, s = ctx.shape[:2]
    if plan.has_q_padding:
        mask = jnp.asarray(plan.q_head_mask(), ctx.dtype)
        ctx = ctx * mask[None, None, :, None]
    ctx = ctx.reshape(b, s, plan.n_q * model.cfg.head_dim)
    return dense_apply(p["wo"], ctx, model.compute_dtype)


def _attn_full(p, x, model: Model, positions, *, causal: bool, kv_x=None,
               return_kv: bool = False):
    """Full-sequence attention (train / prefill / encoder)."""
    cfg, st = model.cfg, model.settings
    q = _q_proj(p, x, model, positions)
    k, v = _kv_proj(p, kv_x if kv_x is not None else x, model, positions)
    impl = st.resolve_attn(q.shape[1])
    ctx = attend(q, k, v, causal=causal, impl=impl,
                 block_q=st.attn_block_q, block_kv=st.attn_block_kv,
                 logit_softcap=cfg.attn_logit_softcap)
    out = _attn_out(p, ctx, model)
    if return_kv:
        return out, (k, v)
    return out


def _attn_decode(p, x_t, model: Model, k_cache, v_cache, cache_len,
                 *, cross: bool = False):
    """One-token attention against a cache.

    x_t: (B,1,d).  For self-attn the new token's K/V is written at
    ``cache_len`` first; for cross-attn the cache is read-only.
    Returns (out (B,1,d), k_cache, v_cache).
    """
    cfg = model.cfg
    bsz = x_t.shape[0]
    positions = cache_len[:, None]                      # (B,1)
    q = _q_proj(p, x_t, model, positions)
    if not cross:
        k_t, v_t = _kv_proj(p, x_t, model, positions)   # (B,1,Hkv,hd)
        bidx = jnp.arange(bsz)
        k_cache = k_cache.at[bidx, cache_len].set(k_t[:, 0])
        v_cache = v_cache.at[bidx, cache_len].set(v_t[:, 0])
        valid_len = cache_len + 1
    else:
        valid_len = jnp.full((bsz,), k_cache.shape[1], jnp.int32)
    k_cache = shard(k_cache, "batch", "kv_seq", "kv_heads", None)
    v_cache = shard(v_cache, "batch", "kv_seq", "kv_heads", None)
    ctx = decode_attention(q, k_cache, v_cache, valid_len,
                           logit_softcap=cfg.attn_logit_softcap)
    out = _attn_out(p, ctx, model)
    return out, k_cache, v_cache


# ---------------------------------------------------------------------------
# Transformer blocks (dense / moe)
# ---------------------------------------------------------------------------
def _block_init(key, model: Model, *, cross: bool = False):
    cfg = model.cfg
    dt = model.param_dtype
    ks = jax.random.split(key, 4)
    p = {"ln1": norm_init(cfg.d_model, cfg.norm, dt),
         "attn": _attn_init(ks[0], model),
         "ln2": norm_init(cfg.d_model, cfg.norm, dt)}
    if cross:
        p["ln_x"] = norm_init(cfg.d_model, cfg.norm, dt)
        p["xattn"] = _attn_init(ks[1], model, cross=True)
    if cfg.family == MOE:
        m = cfg.moe
        p["moe"] = moe_init(ks[2], cfg.d_model, model.plan.n_experts,
                            m.d_expert, cfg.act, dt)
    elif cfg.d_ff:
        p["mlp"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.act, dt,
                            bias=cfg.mlp_bias or cfg.family in (AUDIO, ENCDEC)
                            or cfg.pos_emb == "learned")
    return p


def _ffn_apply(p, x, model: Model):
    """MLP or MoE second half-block. Returns (y, aux)."""
    cfg, st = model.cfg, model.settings
    if "moe" in p:
        y, aux = moe_apply(p["moe"], x, top_k=cfg.moe.top_k,
                           n_experts_logical=model.plan.n_experts_logical,
                           impl=st.moe_impl, compute_dtype=model.compute_dtype)
        return y, aux
    y = mlp_apply(p["mlp"], x, cfg.act, model.compute_dtype)
    return y, None


def _block_apply(p, x, model: Model, positions, *, causal=True, enc_out=None,
                 return_kv=False):
    """Pre-norm transformer block (full-seq). Returns (x, aux, kv?)."""
    cfg = model.cfg
    h = norm_apply(p["ln1"], x, cfg.norm)
    attn = _attn_full(p["attn"], h, model, positions, causal=causal,
                      return_kv=return_kv)
    kv = None
    if return_kv:
        attn, kv = attn
    x = x + attn
    if "xattn" in p:
        h = norm_apply(p["ln_x"], x, cfg.norm)
        x = x + _attn_full(p["xattn"], h, model, None, causal=False,
                           kv_x=enc_out)
    h = norm_apply(p["ln2"], x, cfg.norm)
    y, aux = _ffn_apply(p, h, model)
    x = x + y
    x = shard(x, "batch", "seq", "embed")
    return x, aux, kv


def _block_decode(p, x_t, model: Model, k, v, cache_len, *, xk=None, xv=None):
    """Pre-norm block, one token. Returns (x_t, k, v)."""
    cfg = model.cfg
    h = norm_apply(p["ln1"], x_t, cfg.norm)
    attn, k, v = _attn_decode(p["attn"], h, model, k, v, cache_len)
    x_t = x_t + attn
    if "xattn" in p:
        h = norm_apply(p["ln_x"], x_t, cfg.norm)
        attn, _, _ = _attn_decode(p["xattn"], h, model, xk, xv, cache_len,
                                  cross=True)
        x_t = x_t + attn
    h = norm_apply(p["ln2"], x_t, cfg.norm)
    y, _ = _ffn_apply(p, h, model)
    return x_t + y, k, v


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------
def _stack_init(fn, key, n):
    """vmap an init fn over n split keys -> stacked params (n leading)."""
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(model: Model, key) -> Dict[str, Any]:
    cfg, plan = model.cfg, model.plan
    dt = model.param_dtype
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {}

    if cfg.family in (DENSE, MOE, VLM):
        params["embed"] = embed_init(ks[0], plan.vocab, cfg.d_model, dt)
        params["layers"] = _stack_init(
            lambda k: _block_init(k, model), ks[1], cfg.num_layers)
        params["final_norm"] = norm_init(cfg.d_model, cfg.norm, dt)
        if not cfg.tie_embeddings:
            params["unembed"] = embed_init(ks[2], plan.vocab, cfg.d_model, dt)
        if cfg.pos_emb == "learned":
            n_pos = min(cfg.max_seq_len, LEARNED_POS_CAP)
            params["pos"] = _normal(ks[3], (n_pos, cfg.d_model), dt, 0.02)

    elif cfg.family == SSM:
        params["embed"] = embed_init(ks[0], plan.vocab, cfg.d_model, dt)
        params["layers"] = _stack_init(
            lambda k: {"ln": norm_init(cfg.d_model, cfg.norm, dt),
                       "mixer": ssm_mod.mamba2_init(
                           k, cfg.d_model, cfg.ssm, dt,
                           n_heads_phys=plan.ssm_heads)},
            ks[1], cfg.num_layers)
        params["final_norm"] = norm_init(cfg.d_model, cfg.norm, dt)
        if not cfg.tie_embeddings:
            params["unembed"] = embed_init(ks[2], plan.vocab, cfg.d_model, dt)

    elif cfg.family == HYBRID:
        params["embed"] = embed_init(ks[0], plan.vocab, cfg.d_model, dt)
        params["layers"] = _stack_init(
            lambda k: {"ln": norm_init(cfg.d_model, cfg.norm, dt),
                       "mixer": ssm_mod.mamba2_init(
                           k, cfg.d_model, cfg.ssm, dt,
                           n_heads_phys=plan.ssm_heads)},
            ks[1], cfg.num_layers)
        params["shared_attn"] = _stack_init(
            lambda k: _block_init(k, model), ks[2], cfg.n_shared_attn)
        params["final_norm"] = norm_init(cfg.d_model, cfg.norm, dt)
        if not cfg.tie_embeddings:
            params["unembed"] = embed_init(ks[3], plan.vocab, cfg.d_model, dt)

    elif cfg.family in (ENCDEC, AUDIO):
        # decoder token embedding; encoder input is a precomputed-embedding
        # stub per the assignment (frontend == "embed").
        params["embed"] = embed_init(ks[0], plan.vocab, cfg.d_model, dt)
        params["enc_pos"] = _normal(ks[1], (cfg.enc_seq_len, cfg.d_model),
                                    dt, 0.02)
        n_pos = min(cfg.max_seq_len, LEARNED_POS_CAP)
        params["dec_pos"] = _normal(ks[2], (n_pos, cfg.d_model), dt, 0.02)
        params["enc_layers"] = _stack_init(
            lambda k: _block_init(k, model), ks[3], cfg.n_enc_layers)
        params["dec_layers"] = _stack_init(
            lambda k: _block_init(k, model, cross=True), ks[4],
            cfg.n_dec_layers)
        params["enc_norm"] = norm_init(cfg.d_model, cfg.norm, dt)
        params["final_norm"] = norm_init(cfg.d_model, cfg.norm, dt)
    else:
        raise ValueError(cfg.family)
    return params


def param_specs(model: Model):
    return jax.eval_shape(
        functools.partial(init_params, model), jax.random.key(0))


def param_count(params) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(params)))


# ---------------------------------------------------------------------------
# Scan-over-layers helpers
# ---------------------------------------------------------------------------
def _maybe_remat(fn, model: Model):
    r = model.settings.remat
    if r == "none":
        return fn
    if r == "full":
        return jax.checkpoint(fn,
                              policy=jax.checkpoint_policies.nothing_saveable)
    if r == "dots_saveable":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    raise ValueError(r)


def _scan_blocks(layers, x, body, model: Model, init_aux=None):
    """Run ``body(x, layer_params) -> (x, aux)`` over stacked layers."""
    body = _maybe_remat(body, model)
    if model.settings.scan_layers:
        def sbody(carry, lp):
            return body(carry, lp)
        x, auxs = jax.lax.scan(sbody, x, layers)
        return x, auxs
    n = jax.tree.leaves(layers)[0].shape[0]
    auxs = []
    for i in range(n):
        lp = jax.tree.map(lambda a: a[i], layers)
        x, aux = body(x, lp)
        auxs.append(aux)
    if auxs and auxs[0] is not None:
        auxs = jax.tree.map(lambda *a: jnp.stack(a), *auxs)
    else:
        auxs = None
    return x, auxs


def _scan_or_unroll(model: Model, body, carry, xs):
    """lax.scan when settings.scan_layers else an unrolled python loop.

    The unrolled path exists for the roofline "counting mode": XLA's
    cost_analysis counts a scan body once, so FLOPs/collectives inside
    the layer loop are undercounted by L unless unrolled (see
    benchmarks/roofline_report.py)."""
    if model.settings.scan_layers:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def _collect_aux(auxs) -> Dict[str, jnp.ndarray]:
    if auxs is None:
        return {}
    leaves = jax.tree.leaves(auxs)
    if not leaves:
        return {}
    return jax.tree.map(jnp.sum, auxs)


# ---------------------------------------------------------------------------
# Full-sequence forward
# ---------------------------------------------------------------------------
def _embed_tokens(model: Model, params, tokens):
    x = embed_apply(params["embed"], tokens, model.compute_dtype)
    return shard(x, "batch", "seq", "embed")


def _lm_head(model: Model, params, x):
    cfg = model.cfg
    x = norm_apply(params["final_norm"], x, cfg.norm)
    table = params.get("unembed", params["embed"])["table"]
    logits = unembed_apply(table, x, vocab_logical=model.plan.vocab_logical,
                           fp32=model.settings.logits_fp32)
    return shard(logits, "batch", "seq", "vocab")


def forward(model: Model, params, batch) -> Tuple[jnp.ndarray, Dict]:
    """Full-sequence logits.  batch: {"tokens": (B,S)} (+"embeds" enc-dec).

    Returns (logits (B,S,V_phys), aux dict with MoE losses if any).
    """
    cfg = model.cfg
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.arange(s)

    if cfg.family in (DENSE, MOE, VLM):
        x = _embed_tokens(model, params, tokens)
        if cfg.pos_emb == "learned":
            x = x + params["pos"][:s][None].astype(x.dtype)

        def body(x, lp):
            x, aux, _ = _block_apply(lp, x, model, positions, causal=True)
            return x, aux

        x, auxs = _scan_blocks(params["layers"], x, body, model)
        return _lm_head(model, params, x), _collect_aux(auxs)

    if cfg.family == SSM:
        x = _embed_tokens(model, params, tokens)
        hm = _ssm_head_mask(model)

        def body(x, lp):
            h = norm_apply(lp["ln"], x, cfg.norm)
            y, _ = ssm_mod.mamba2_apply(
                lp["mixer"], h, cfg.ssm, compute_dtype=model.compute_dtype,
                head_mask=hm)
            return x + y, None

        x, _ = _scan_blocks(params["layers"], x, body, model)
        return _lm_head(model, params, x), {}

    if cfg.family == HYBRID:
        return _hybrid_forward(model, params, batch)

    if cfg.family in (ENCDEC, AUDIO):
        enc_out = _encode(model, params, batch["embeds"])
        x = _embed_tokens(model, params, tokens)
        x = x + params["dec_pos"][:s][None].astype(x.dtype)

        def body(x, lp):
            x, aux, _ = _block_apply(lp, x, model, positions, causal=True,
                                     enc_out=enc_out)
            return x, aux

        x, auxs = _scan_blocks(params["dec_layers"], x, body, model)
        return _lm_head(model, params, x), _collect_aux(auxs)

    raise ValueError(cfg.family)


def _ssm_head_mask(model: Model):
    plan = model.plan
    if plan.ssm_heads == plan.ssm_heads_logical:
        return None
    return jnp.asarray(plan.ssm_head_mask(), jnp.float32)


def _encode(model: Model, params, embeds):
    """Encoder over precomputed frame embeddings (frontend stub)."""
    cfg = model.cfg
    s = embeds.shape[1]
    x = embeds.astype(model.compute_dtype)
    x = x + params["enc_pos"][:s][None].astype(x.dtype)
    positions = jnp.arange(s)

    def body(x, lp):
        x, _, _ = _block_apply(lp, x, model, positions, causal=False)
        return x, None

    x, _ = _scan_blocks(params["enc_layers"], x, body, model)
    return norm_apply(params["enc_norm"], x, cfg.norm)


def _hybrid_groups(model: Model) -> Tuple[int, int]:
    cfg = model.cfg
    period = cfg.attn_period or cfg.num_layers
    assert cfg.num_layers % period == 0, (cfg.num_layers, period)
    return cfg.num_layers // period, period


def _hybrid_forward(model: Model, params, batch):
    """Zamba2-style: groups of SSD layers + one *shared* attention block
    applied after each group (weights shared across applications)."""
    cfg = model.cfg
    tokens = batch["tokens"]
    b, s = tokens.shape
    n_groups, period = _hybrid_groups(model)
    positions = jnp.arange(s)
    x = _embed_tokens(model, params, tokens)
    hm = _ssm_head_mask(model)

    # reshape stacked ssm layers (L, ...) -> (n_groups, period, ...)
    grouped = jax.tree.map(
        lambda a: a.reshape((n_groups, period) + a.shape[1:]),
        params["layers"])

    def ssm_body(x, lp):
        h = norm_apply(lp["ln"], x, cfg.norm)
        y, _ = ssm_mod.mamba2_apply(
            lp["mixer"], h, cfg.ssm, compute_dtype=model.compute_dtype,
            head_mask=hm)
        return x + y, None

    def group_body(carry, inp):
        x, app_idx = carry
        group_layers = inp
        x, _ = _scan_blocks(group_layers, x, ssm_body, model)
        # shared attention block (round-robin over n_shared_attn copies)
        blk = jax.tree.map(
            lambda a: a[app_idx % cfg.n_shared_attn], params["shared_attn"])
        x, _, _ = _block_apply(blk, x, model, positions, causal=True)
        return (x, app_idx + 1), None

    if model.settings.scan_layers and cfg.n_shared_attn == 1:
        blk = jax.tree.map(lambda a: a[0], params["shared_attn"])

        def gbody(x, group_layers):
            x, _ = _scan_blocks(group_layers, x, ssm_body, model)
            x, _, _ = _block_apply(blk, x, model, positions, causal=True)
            return x, None

        x, _ = _scan_or_unroll(model, gbody, x, grouped)
    else:
        carry = (x, 0)
        for gi in range(n_groups):
            gl = jax.tree.map(lambda a: a[gi], grouped)
            carry, _ = group_body(carry, gl)
        x = carry[0]
    return _lm_head(model, params, x), {}


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def loss_fn(model: Model, params, batch) -> Tuple[jnp.ndarray, Dict]:
    logits, aux = forward(model, params, batch)
    mask = batch.get("loss_mask")
    loss = softmax_xent(logits, batch["labels"], mask)
    metrics = {"xent": loss}
    if aux:
        m = model.cfg.moe
        loss = loss + m.router_aux_coef * aux.get("aux", 0.0) \
            + m.router_z_coef * aux.get("zloss", 0.0)
        metrics.update(aux)
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------
def _cache_struct(model: Model, batch: int, max_len: int) -> Dict[str, Any]:
    """Shapes/dtypes of the decode cache (dict of (shape, dtype))."""
    cfg, plan = model.cfg, model.plan
    cd = model.compute_dtype
    hd = cfg.head_dim
    out: Dict[str, Tuple[tuple, Any]] = {"len": ((batch,), jnp.int32)}
    if cfg.family in (DENSE, MOE, VLM):
        kv = (cfg.num_layers, batch, max_len, plan.n_kv, hd)
        out["k"] = (kv, cd)
        out["v"] = (kv, cd)
    elif cfg.family in (SSM, HYBRID):
        s = cfg.ssm
        conv_dim = plan.ssm_heads * s.head_dim + 2 * s.n_groups * s.d_state
        out["conv"] = ((cfg.num_layers, batch, s.conv_width - 1, conv_dim), cd)
        out["ssd"] = ((cfg.num_layers, batch, plan.ssm_heads, s.d_state,
                       s.head_dim), jnp.float32)
        if cfg.family == HYBRID:
            napp = _hybrid_groups(model)[0]
            kv = (napp, batch, max_len, plan.n_kv, hd)
            out["k"] = (kv, cd)
            out["v"] = (kv, cd)
    elif cfg.family in (ENCDEC, AUDIO):
        kv = (cfg.n_dec_layers, batch, max_len, plan.n_kv, hd)
        xkv = (cfg.n_dec_layers, batch, cfg.enc_seq_len, plan.n_kv, hd)
        out["k"] = (kv, cd)
        out["v"] = (kv, cd)
        out["xk"] = (xkv, cd)
        out["xv"] = (xkv, cd)
    else:
        raise ValueError(cfg.family)
    return out


def init_cache(model: Model, batch: int, max_len: int):
    return {k: jnp.zeros(shp, dt)
            for k, (shp, dt) in _cache_struct(model, batch, max_len).items()}


def cache_specs(model: Model, batch: int, max_len: int):
    return {k: jax.ShapeDtypeStruct(shp, dt)
            for k, (shp, dt) in _cache_struct(model, batch, max_len).items()}


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------
def prefill(model: Model, params, batch, cache, prompt_lens=None):
    """Full-prompt forward that also fills the decode cache.

    batch: {"tokens": (B,S)} (+"embeds").  ``prompt_lens`` (B,) defaults to
    S for every row.  Returns (logits (B,S,V), cache).
    """
    cfg = model.cfg
    tokens = batch["tokens"]
    b, s = tokens.shape
    if prompt_lens is None:
        prompt_lens = jnp.full((b,), s, jnp.int32)
    positions = jnp.arange(s)
    max_len = None

    if cfg.family in (DENSE, MOE, VLM):
        x = _embed_tokens(model, params, tokens)
        if cfg.pos_emb == "learned":
            x = x + params["pos"][:s][None].astype(x.dtype)

        def body(x, lp):
            x, _, kv = _block_apply(lp, x, model, positions, causal=True,
                                    return_kv=True)
            return x, kv

        x, kvs = _scan_blocks(params["layers"], x, body, model)
        k_new, v_new = kvs                          # (L,B,S,Hkv,hd)
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
        cache["len"] = prompt_lens
        return _lm_head(model, params, x), cache

    if cfg.family == SSM:
        x = _embed_tokens(model, params, tokens)
        hm = _ssm_head_mask(model)

        def body(x, lp):
            h = norm_apply(lp["ln"], x, cfg.norm)
            y, (cs, ss) = ssm_mod.mamba2_apply(
                lp["mixer"], h, cfg.ssm, compute_dtype=model.compute_dtype,
                head_mask=hm)
            return x + y, (cs.astype(cache["conv"].dtype), ss)

        x, states = _scan_blocks(params["layers"], x, body, model)
        cache["conv"], cache["ssd"] = states
        cache["len"] = prompt_lens
        return _lm_head(model, params, x), cache

    if cfg.family == HYBRID:
        return _hybrid_prefill(model, params, batch, cache, prompt_lens)

    if cfg.family in (ENCDEC, AUDIO):
        enc_out = _encode(model, params, batch["embeds"])
        x = _embed_tokens(model, params, tokens)
        x = x + params["dec_pos"][:s][None].astype(x.dtype)

        def body(x, lp):
            # self-attn with kv export
            h = norm_apply(lp["ln1"], x, cfg.norm)
            attn, kv = _attn_full(lp["attn"], h, model, positions,
                                  causal=True, return_kv=True)
            x = x + attn
            h = norm_apply(lp["ln_x"], x, cfg.norm)
            xk, xv = _kv_proj(lp["xattn"], enc_out, model, None)
            q = _q_proj(lp["xattn"], h, model, None)
            impl = model.settings.resolve_attn(q.shape[1])
            ctx = attend(q, xk, xv, causal=False, impl=impl,
                         block_q=model.settings.attn_block_q,
                         block_kv=model.settings.attn_block_kv)
            x = x + _attn_out(lp["xattn"], ctx, model)
            h = norm_apply(lp["ln2"], x, cfg.norm)
            y, _ = _ffn_apply(lp, h, model)
            return x + y, (kv[0], kv[1], xk, xv)

        x, kvs = _scan_blocks(params["dec_layers"], x, body, model)
        k_new, v_new, xk, xv = kvs
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
        cache["xk"] = xk.astype(cache["xk"].dtype)
        cache["xv"] = xv.astype(cache["xv"].dtype)
        cache["len"] = prompt_lens
        return _lm_head(model, params, x), cache

    raise ValueError(cfg.family)


def _hybrid_prefill(model: Model, params, batch, cache, prompt_lens):
    cfg = model.cfg
    tokens = batch["tokens"]
    b, s = tokens.shape
    n_groups, period = _hybrid_groups(model)
    positions = jnp.arange(s)
    x = _embed_tokens(model, params, tokens)
    hm = _ssm_head_mask(model)
    grouped = jax.tree.map(
        lambda a: a.reshape((n_groups, period) + a.shape[1:]),
        params["layers"])
    blk = jax.tree.map(lambda a: a[0], params["shared_attn"])

    def ssm_body(x, lp):
        h = norm_apply(lp["ln"], x, cfg.norm)
        y, (cs, ss) = ssm_mod.mamba2_apply(
            lp["mixer"], h, cfg.ssm, compute_dtype=model.compute_dtype,
            head_mask=hm)
        return x + y, (cs, ss)

    def gbody(x, group_layers):
        x, states = _scan_blocks(group_layers, x, ssm_body, model)
        x, _, kv = _block_apply(blk, x, model, positions, causal=True,
                                return_kv=True)
        return x, (states, kv)

    x, (states, kvs) = _scan_or_unroll(model, gbody, x, grouped)
    conv_s, ssd_s = states                      # (n_groups, period, B, ...)
    cache["conv"] = conv_s.reshape((cfg.num_layers,) + conv_s.shape[2:]) \
        .astype(cache["conv"].dtype)
    cache["ssd"] = ssd_s.reshape((cfg.num_layers,) + ssd_s.shape[2:])
    k_new, v_new = kvs                          # (n_groups, B, S, Hkv, hd)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], k_new.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v_new.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    cache["len"] = prompt_lens
    return _lm_head(model, params, x), cache


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------
def decode_step(model: Model, params, cache, tokens):
    """One autoregressive step.  tokens: (B,) int32 (the current token).

    Returns (logits (B,V_phys), cache with the new token's state written).
    """
    cfg = model.cfg
    b = tokens.shape[0]
    cache_len = cache["len"]

    if cfg.family in (DENSE, MOE, VLM):
        x = _embed_tokens(model, params, tokens[:, None])
        if cfg.pos_emb == "learned":
            x = x + params["pos"][cache_len][:, None].astype(x.dtype)

        def body(x_t, inp):
            lp, k, v = inp
            x_t, k, v = _block_decode(lp, x_t, model, k, v, cache_len)
            return x_t, (k, v)

        x, kv = _scan_or_unroll(model, body, x, (params["layers"],
                                              cache["k"], cache["v"]))
        cache["k"], cache["v"] = kv
        cache["len"] = cache_len + 1
        logits = _lm_head(model, params, x)[:, 0]
        return logits, cache

    if cfg.family == SSM:
        x = _embed_tokens(model, params, tokens[:, None])[:, 0]
        hm = _ssm_head_mask(model)

        def body(x_t, inp):
            lp, cs, ss = inp
            h = norm_apply(lp["ln"], x_t, cfg.norm)
            y, (cs, ss) = ssm_mod.mamba2_decode(
                lp["mixer"], h, cfg.ssm, compute_dtype=model.compute_dtype,
                conv_state=cs, ssd_state=ss, head_mask=hm)
            return x_t + y, (cs, ss)

        x, states = _scan_or_unroll(
            model, body, x, (params["layers"], cache["conv"], cache["ssd"]))
        cache["conv"], cache["ssd"] = states
        cache["len"] = cache_len + 1
        logits = _lm_head(model, params, x[:, None])[:, 0]
        return logits, cache

    if cfg.family == HYBRID:
        n_groups, period = _hybrid_groups(model)
        x = _embed_tokens(model, params, tokens[:, None])[:, 0]
        hm = _ssm_head_mask(model)
        grouped = jax.tree.map(
            lambda a: a.reshape((n_groups, period) + a.shape[1:]),
            params["layers"])
        conv_g = cache["conv"].reshape(
            (n_groups, period) + cache["conv"].shape[1:])
        ssd_g = cache["ssd"].reshape(
            (n_groups, period) + cache["ssd"].shape[1:])
        blk = jax.tree.map(lambda a: a[0], params["shared_attn"])

        def inner(x_t, inp):
            lp, cs, ss = inp
            h = norm_apply(lp["ln"], x_t, cfg.norm)
            y, (cs, ss) = ssm_mod.mamba2_decode(
                lp["mixer"], h, cfg.ssm, compute_dtype=model.compute_dtype,
                conv_state=cs, ssd_state=ss, head_mask=hm)
            return x_t + y, (cs, ss)

        def gbody(x_t, inp):
            gl, cs, ss, k, v = inp
            x_t, states = _scan_or_unroll(model, inner, x_t,
                                          (gl, cs, ss))
            x2, k, v = _block_decode(blk, x_t[:, None], model, k, v,
                                     cache_len)
            return x2[:, 0], (states[0], states[1], k, v)

        x, outs = _scan_or_unroll(model, gbody, x,
                                  (grouped, conv_g, ssd_g,
                                   cache["k"], cache["v"]))
        cs, ss, k, v = outs
        cache["conv"] = cs.reshape(cache["conv"].shape)
        cache["ssd"] = ss.reshape(cache["ssd"].shape)
        cache["k"], cache["v"] = k, v
        cache["len"] = cache_len + 1
        logits = _lm_head(model, params, x[:, None])[:, 0]
        return logits, cache

    if cfg.family in (ENCDEC, AUDIO):
        x = _embed_tokens(model, params, tokens[:, None])
        x = x + params["dec_pos"][cache_len][:, None].astype(x.dtype)

        def body(x_t, inp):
            lp, k, v, xk, xv = inp
            x_t, k, v = _block_decode(lp, x_t, model, k, v, cache_len,
                                      xk=xk, xv=xv)
            return x_t, (k, v)

        x, kv = _scan_or_unroll(
            model, body, x, (params["dec_layers"], cache["k"],
                             cache["v"], cache["xk"], cache["xv"]))
        cache["k"], cache["v"] = kv
        cache["len"] = cache_len + 1
        logits = _lm_head(model, params, x)[:, 0]
        return logits, cache

    raise ValueError(cfg.family)
