"""Shared model-runtime settings and small utilities."""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class RunSettings:
    """Hashable knobs threaded through model apply fns (static under jit).

    These are the levers the §Perf hillclimb moves.
    """

    attn_impl: str = "auto"        # auto | naive | blocked | blocked_causal | pallas
    attn_block_q: int = 1024
    attn_block_kv: int = 1024
    naive_attn_max_seq: int = 2048  # "auto" switches to blocked above this
    remat: str = "full"            # none | full | dots_saveable
    scan_layers: bool = True
    moe_impl: str = "dense_onehot"  # dense_onehot | sort (dropless)
    logits_fp32: bool = True
    # --- sharding-plan knobs (read by distributed.shard_plan) ----------
    embed_shard: str = "vocab"     # vocab (Megatron vocab-parallel) | fsdp
    fsdp_params: bool = True       # False: replicate non-embedding weights
    #                                over "data" (pure TP+DP, no ZeRO-3)

    def resolve_attn(self, seq_len: int) -> str:
        if self.attn_impl != "auto":
            return self.attn_impl
        return "naive" if seq_len <= self.naive_attn_max_seq else "blocked"


DEFAULT_SETTINGS = RunSettings()


def compute_dtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def param_dtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.param_dtype)
