"""Attention compute implementations.

Four paths, all numerically interchangeable (tests assert allclose):

* ``naive``          — materializes (B,H,S,S) scores; small seqs / oracles.
* ``blocked``        — flash-style two-level ``lax.scan`` over q/kv blocks,
                       O(block^2) memory; computes the full S×S rectangle
                       with masking (the *paper-faithful baseline* — this is
                       what a straightforward port does).
* ``blocked_causal`` — beyond-paper §Perf optimization: iterates only the
                       lower-triangle (qb, kb<=qb) block pairs, halving
                       attention FLOPs at long seq (matches what the Pallas
                       kernel does on TPU).
* ``decode``         — one query token against a (possibly huge) KV cache,
                       with fp32 online accumulation. GSPMD shards the KV
                       sequence axis for ``long_500k`` (SP) and inserts the
                       partial-softmax collectives.

All paths take q:(B,Sq,H,D), k/v:(B,Skv,Hkv,D) with H a multiple of Hkv
(GQA groups contiguous: q head i uses kv head i // (H//Hkv)).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_expand(k, n_q_heads):
    """(B,S,Hkv,D) -> (B,S,H,D) by repeating each kv head contiguously."""
    b, s, hkv, d = k.shape
    rep = n_q_heads // hkv
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


# ---------------------------------------------------------------------------
# Naive
# ---------------------------------------------------------------------------
def naive_attention(q, k, v, *, causal: bool, q_offset=0,
                    logit_softcap: float = 0.0):
    """Reference full-materialization attention (fp32 softmax)."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    k = _gqa_expand(k, h)
    v = _gqa_expand(v, h)
    scale = d ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if logit_softcap:
        scores = jnp.tanh(scores / logit_softcap) * logit_softcap
    if causal:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(skv)[None, :]
        scores = jnp.where(qpos >= kpos, scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Blocked (flash-style) — rectangle baseline and causal-triangle variants
# ---------------------------------------------------------------------------
def _flash_inner(q_blk, k, v, *, block_kv, causal, q_pos0, rep, softcap):
    """Online-softmax over kv blocks for one q block.

    q_blk: (B, Bq, H, D); k/v: (B, Skv, Hkv, D) reshaped into kv blocks.
    Returns (B, Bq, H, D).
    """
    b, bq, h, d = q_blk.shape
    skv = k.shape[1]
    nkv = skv // block_kv
    kb = k.reshape(b, nkv, block_kv, k.shape[2], d)
    vb = v.reshape(b, nkv, block_kv, v.shape[2], d)
    scale = d ** -0.5

    def body(carry, inputs):
        o, m, l = carry
        kblk, vblk, kv_idx = inputs          # (B,Bk,Hkv,D)
        kblk = _gqa_expand(kblk, h)
        vblk = _gqa_expand(vblk, h)
        s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, kblk,
                       preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        if causal:
            qpos = q_pos0 + jnp.arange(bq)[:, None]
            kpos = kv_idx * block_kv + jnp.arange(block_kv)[None, :]
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vblk.astype(jnp.float32))
        return (o_new, m_new, l_new), None

    o0 = jnp.zeros((b, h, bq, d), jnp.float32)
    m0 = jnp.full((b, h, bq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, bq), jnp.float32)
    kv_ids = jnp.arange(nkv)
    (o, m, l), _ = jax.lax.scan(
        body, (o0, m0, l0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), kv_ids))
    out = o / jnp.maximum(l[..., None], 1e-30)
    return jnp.moveaxis(out, 1, 2).astype(q_blk.dtype)   # (B,Bq? ->B,q,h,d)


def blocked_attention(q, k, v, *, causal: bool, block_q=1024, block_kv=1024,
                      logit_softcap: float = 0.0):
    """Flash attention computing the full rectangle (masked). Baseline."""
    b, sq, h, d = q.shape
    block_q = min(block_q, sq)
    block_kv = min(block_kv, k.shape[1])
    if sq % block_q or k.shape[1] % block_kv:
        # fall back for ragged shapes (tests)
        return naive_attention(q, k, v, causal=causal,
                               logit_softcap=logit_softcap)
    nq = sq // block_q
    qb = jnp.moveaxis(q.reshape(b, nq, block_q, h, d), 1, 0)

    def per_q_block(q_blk, qi):
        return _flash_inner(q_blk, k, v, block_kv=block_kv, causal=causal,
                            q_pos0=qi * block_q, rep=h // k.shape[2],
                            softcap=logit_softcap)

    out = jax.lax.map(lambda args: per_q_block(*args), (qb, jnp.arange(nq)))
    return jnp.moveaxis(out, 0, 1).reshape(b, sq, h, d)


def blocked_causal_attention(q, k, v, *, block_q=1024, block_kv=1024,
                             logit_softcap: float = 0.0):
    """Causal flash that only visits lower-triangle (qb, kb<=qb) pairs.

    The (qb, kb) pair list is static; a single ``lax.scan`` walks it in
    row-major order (so online softmax state per q block is updated in kv
    order), gathering blocks with dynamic slices. HLO FLOPs are ~half of
    ``blocked_attention`` at large S.
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    if sq % block_q or skv % block_kv or sq != skv:
        return naive_attention(q, k, v, causal=True,
                               logit_softcap=logit_softcap)
    nq, nkv = sq // block_q, skv // block_kv
    # pairs (qi, ki) with ki*block_kv <= qi*block_q + block_q - 1
    pairs = [(qi, ki) for qi in range(nq) for ki in range(nkv)
             if ki * block_kv <= qi * block_q + block_q - 1]
    qis = jnp.array([p[0] for p in pairs], jnp.int32)
    kis = jnp.array([p[1] for p in pairs], jnp.int32)
    scale = d ** -0.5

    qr = q.reshape(b, nq, block_q, h, d)
    kr = k.reshape(b, nkv, block_kv, k.shape[2], d)
    vr = v.reshape(b, nkv, block_kv, v.shape[2], d)

    def body(carry, pair):
        o, m, l = carry                     # (B,nq,H,Bq,D) fp32 etc.
        qi, ki = pair
        q_blk = jax.lax.dynamic_index_in_dim(qr, qi, 1, keepdims=False)
        k_blk = jax.lax.dynamic_index_in_dim(kr, ki, 1, keepdims=False)
        v_blk = jax.lax.dynamic_index_in_dim(vr, ki, 1, keepdims=False)
        k_blk = _gqa_expand(k_blk, h)
        v_blk = _gqa_expand(v_blk, h)
        s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk,
                       preferred_element_type=jnp.float32) * scale
        if logit_softcap:
            s = jnp.tanh(s / logit_softcap) * logit_softcap
        qpos = qi * block_q + jnp.arange(block_q)[:, None]
        kpos = ki * block_kv + jnp.arange(block_kv)[None, :]
        s = jnp.where(qpos >= kpos, s, NEG_INF)

        m_row = jax.lax.dynamic_index_in_dim(m, qi, 1, keepdims=False)
        l_row = jax.lax.dynamic_index_in_dim(l, qi, 1, keepdims=False)
        o_row = jax.lax.dynamic_index_in_dim(o, qi, 1, keepdims=False)
        m_new = jnp.maximum(m_row, s.max(axis=-1))
        alpha = jnp.exp(m_row - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_row * alpha + p.sum(axis=-1)
        o_new = o_row * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, v_blk.astype(jnp.float32))
        o = jax.lax.dynamic_update_index_in_dim(o, o_new, qi, 1)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 1)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 1)
        return (o, m, l), None

    o0 = jnp.zeros((b, nq, h, block_q, d), jnp.float32)
    m0 = jnp.full((b, nq, h, block_q), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, nq, h, block_q), jnp.float32)
    (o, m, l), _ = jax.lax.scan(body, (o0, m0, l0), (qis, kis))
    out = o / jnp.maximum(l[..., None], 1e-30)          # (B,nq,H,Bq,D)
    out = jnp.moveaxis(out, 2, 3).reshape(b, sq, h, d)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------
def decode_attention(q, k_cache, v_cache, cache_len, *,
                     logit_softcap: float = 0.0):
    """q: (B,1,H,D); caches: (B,S,Hkv,D); cache_len: (B,) valid length
    (the new token's kv must already be written at cache_len-1)."""
    b, _, h, d = q.shape
    s = k_cache.shape[1]
    kc = _gqa_expand(k_cache, h)
    vc = _gqa_expand(v_cache, h)
    scale = d ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kc,
                        preferred_element_type=jnp.float32) * scale
    if logit_softcap:
        scores = jnp.tanh(scores / logit_softcap) * logit_softcap
    valid = jnp.arange(s)[None, None, None, :] < cache_len[:, None, None, None]
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(jnp.float32),
                     vc.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------
def attend(q, k, v, *, causal: bool, impl: str, block_q=1024, block_kv=1024,
           q_offset=0, logit_softcap: float = 0.0):
    if impl == "naive":
        return naive_attention(q, k, v, causal=causal, q_offset=q_offset,
                               logit_softcap=logit_softcap)
    if impl == "blocked":
        return blocked_attention(q, k, v, causal=causal, block_q=block_q,
                                 block_kv=block_kv,
                                 logit_softcap=logit_softcap)
    if impl == "blocked_causal":
        if not causal:
            return blocked_attention(q, k, v, causal=False, block_q=block_q,
                                     block_kv=block_kv,
                                     logit_softcap=logit_softcap)
        return blocked_causal_attention(q, k, v, block_q=block_q,
                                        block_kv=block_kv,
                                        logit_softcap=logit_softcap)
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as flash_ops
        return flash_ops.flash_attention(q, k, v, causal=causal)
    raise ValueError(f"unknown attention impl {impl!r}")
