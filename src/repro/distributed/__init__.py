from repro.distributed.padding import PadPlan, make_pad_plan  # noqa: F401
